//! Integration: PJRT runtime executes every AOT artifact and reproduces
//! the Python golden fingerprints — the proof that the Rust request path
//! is numerically equivalent to the L1/L2 stack without Python present.
//!
//! Requires `make artifacts` (the Makefile orders this before cargo test)
//! and the `pjrt` cargo feature (vendored `xla` crate); without the
//! feature this whole file compiles to nothing.
#![cfg(feature = "pjrt")]

use snitch_fm::coordinator::KvCache;
use snitch_fm::runtime::{Arg, Runtime};

fn runtime() -> Runtime {
    Runtime::new().expect("artifacts missing — run `make artifacts`")
}

#[test]
fn all_artifacts_reproduce_golden_outputs() {
    let mut rt = runtime();
    let names: Vec<String> = rt.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
    assert!(names.len() >= 7, "expected >= 7 artifacts, got {names:?}");
    for name in names {
        let outs = rt.run_golden(&name, 1e-3).unwrap_or_else(|e| panic!("{name}: {e:?}"));
        assert!(!outs.is_empty(), "{name}: no outputs");
    }
}

#[test]
fn executables_are_cached() {
    let mut rt = runtime();
    let t0 = std::time::Instant::now();
    rt.load("gpt_head_tiny").unwrap();
    let cold = t0.elapsed();
    let t0 = std::time::Instant::now();
    rt.load("gpt_head_tiny").unwrap();
    let warm = t0.elapsed();
    assert!(warm < cold / 10, "cache ineffective: cold {cold:?} warm {warm:?}");
}

#[test]
fn outputs_are_deterministic_across_runs() {
    let mut rt = runtime();
    let args = rt.manifest_args("kernel_gemm_256").unwrap();
    let a = rt.load("kernel_gemm_256").unwrap().run(&args).unwrap();
    let b = rt.load("kernel_gemm_256").unwrap().run(&args).unwrap();
    assert_eq!(a, b);
}

/// The KV-cache equivalence (paper Sec. II-B) through the actual PJRT
/// executables: prefill S-1 tokens with the NAR block, decode token S-1
/// with the AR block, and compare against the NAR block's row S-1.
#[test]
fn ar_decode_matches_nar_row_through_pjrt() {
    const S: usize = 32;
    const E: usize = 64;
    const HEADS: usize = 4;
    const P: usize = 16;
    const SMAX: usize = 64;

    let mut rt = runtime();
    // The NAR and AR tiny artifacts share weight specs (same seeds).
    let nar_args = rt.manifest_args("gpt_block_nar_tiny").unwrap();
    let x = match &nar_args[0] {
        Arg::F32(d, _) => d.clone(),
        _ => panic!("x should be f32"),
    };
    let weights: Vec<Arg> = nar_args[1..].to_vec();

    // Full NAR pass: reference activations for every row + K/V for the
    // cache. (The artifact is lowered at fixed S=32, so the "prefill" is
    // the first S-1 tokens' K/V sliced out of the full pass — causal
    // masking guarantees rows 0..S-1 are unaffected by row S-1.)
    let full = rt.load("gpt_block_nar_tiny").unwrap().run(&nar_args).unwrap();
    let full_out = &full[0]; // [S, E]
    let (k_full, v_full) = (&full[1], &full[2]); // [H, S, P]

    let slice_heads = |src: &[f32], n: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(HEADS * n * P);
        for h in 0..HEADS {
            let base = h * S * P;
            out.extend_from_slice(&src[base..base + n * P]);
        }
        out
    };
    let mut cache = KvCache::new(HEADS, SMAX, P);
    cache.load_prefill(&slice_heads(k_full, S - 1), &slice_heads(v_full, S - 1), S - 1);

    // AR step for the last token.
    let last = &x[(S - 1) * E..];
    let mut args = vec![
        Arg::f32(last, &[1, E]),
        Arg::f32(cache.k_flat(), &[HEADS, SMAX, P]),
        Arg::f32(cache.v_flat(), &[HEADS, SMAX, P]),
        Arg::I32((S - 1) as i32),
    ];
    args.extend(weights.iter().cloned());
    let step = rt.load("gpt_block_ar_tiny").unwrap().run(&args).unwrap();
    let ar_out = &step[0]; // [1, E]

    let nar_row = &full_out[(S - 1) * E..];
    for (i, (&a, &n)) in ar_out.iter().zip(nar_row).enumerate() {
        assert!(
            (a - n).abs() < 2e-3 + 2e-3 * n.abs(),
            "row {}, col {i}: ar {a} vs nar {n}",
            S - 1
        );
    }
}

/// PJRT executables have fixed shapes; guard that the runtime rejects
/// shape mismatches loudly rather than silently mis-executing.
#[test]
fn wrong_shape_is_rejected() {
    let mut rt = runtime();
    let mut args = rt.manifest_args("gpt_head_tiny").unwrap();
    // Truncate the input vector: 1 x E becomes 1 x (E-1).
    if let Arg::F32(d, shape) = &mut args[0] {
        d.pop();
        shape[1] -= 1;
    }
    let res = rt.load("gpt_head_tiny").unwrap().run(&args);
    assert!(res.is_err(), "shape mismatch must error");
}
