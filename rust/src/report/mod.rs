//! Paper-style table/figure text output + CSV export.

use std::fmt::Write as _;

use crate::coordinator::{Breakdown, KindCycles, RunReport, ServeReport};
use crate::parallel::{DisaggReport, RankedPlan, RouterReport};
use crate::trace::FleetTrace;

/// Version of the serve/router JSON schema. Bumped whenever keys are
/// added or change meaning, so trend tooling can evolve its key set
/// without silently comparing incompatible artifacts. Version 2 = the
/// parallelism-subsystem PR (prefix_late_hits, fused_first_tokens,
/// decode counters, router reports). Version 3 = executed shard plans
/// (tp/pp, collective_cycles, d2d_bytes — the serving TP tax).
/// Version 4 = the event-driven core (engine, arrival/pass event
/// counters, pass-shape memo hits/misses; percentiles now come from
/// streaming sketches — exact below the spill limit, so small-trace
/// values are unchanged). Version 5 = disaggregated serving (TPOT
/// percentiles, kv_imports / imported_kv_tokens, and the disagg report
/// with migration counters and split prefill/decode views). Version 6 =
/// fault injection and recovery (replica_failures, stall_cycles,
/// link_faults, salvaged_requests / salvaged_kv_bytes, retries,
/// recovery_cycles, degraded_capacity_fraction, warnings; the disagg
/// report adds migration_retries / recompute_fallbacks — all zero/empty
/// on a fault-free run). Version 7 = observability (per-phase
/// kernel-class cycle objects `prefill_kind_cycles` /
/// `decode_kind_cycles` / `mixed_kind_cycles` keyed by kernel class;
/// the disagg report now carries `warnings` like every other renderer).
/// Version 8 = precision-policy keys (`kv_format`, the KV storage format
/// name, and `class_precision`, the canonical per-class ladder spec —
/// `kv_format` equals `format` and `class_precision` is empty when the
/// policy is degenerate). The full key changelog lives in
/// `docs/serving.md`.
pub const SERVE_SCHEMA_VERSION: u32 = 8;

/// Render run reports as an aligned text table (one row per run).
pub fn runs_table(rows: &[RunReport]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<10} {:<5} {:<7} {:>6} {:>4} {:>14} {:>12} {:>9} {:>8} {:>10} {:>9}",
        "model",
        "mode",
        "fmt",
        "S",
        "b",
        "throughput",
        "GFLOPS",
        "util%",
        "P[W]",
        "GFLOPS/W",
        "HBM[GB]"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:<10} {:<5} {:<7} {:>6} {:>4} {:>9.2} {:<4} {:>12.1} {:>9.2} {:>8.2} {:>10.1} {:>9.3}",
            r.model,
            r.mode,
            r.format,
            r.seq,
            r.batch,
            r.throughput,
            r.throughput_unit.trim_end_matches("/s"),
            r.gflops,
            r.fpu_utilization * 100.0,
            r.power_w,
            r.gflops_per_w,
            r.hbm_gb,
        );
    }
    s
}

/// CSV export of run reports.
pub fn runs_csv(rows: &[RunReport]) -> String {
    let mut s = String::from(
        "model,mode,format,seq,batch,cycles,seconds,throughput,throughput_unit,decode_throughput,ttft_s,gflops,fpu_utilization,power_w,gflops_per_w,hbm_gb,c2c_gb\n",
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            r.model,
            r.mode,
            r.format,
            r.seq,
            r.batch,
            r.cycles,
            r.seconds,
            r.throughput,
            r.throughput_unit,
            r.decode_throughput,
            r.ttft_s,
            r.gflops,
            r.fpu_utilization,
            r.power_w,
            r.gflops_per_w,
            r.hbm_gb,
            r.c2c_gb
        );
    }
    s
}

/// Render a serving report (the `serve` subcommand's output): aggregate
/// throughput, latency percentiles, TTFT, scheduler counters, and
/// resource use.
pub fn serve_table(r: &ServeReport) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "serving {} ({}) — {} requests, max batch {}",
        r.model, r.format, r.requests, r.max_batch
    );
    if r.kv_format != r.format || !r.class_precision.is_empty() {
        let _ = writeln!(
            s,
            "  precision: compute {}  kv {}{}",
            r.format,
            r.kv_format,
            if r.class_precision.is_empty() {
                String::new()
            } else {
                format!("  ladder {}", r.class_precision)
            }
        );
    }
    let _ = writeln!(
        s,
        "  completed {} / rejected {}{}",
        r.completed,
        r.rejected.len(),
        if r.rejected.is_empty() {
            String::new()
        } else {
            format!(" (ids {:?}: KV exceeds budget)", r.rejected)
        }
    );
    let _ = writeln!(
        s,
        "  tokens: {} prefill ({} chunks) + {} generated in {:.3} s",
        r.prefill_tokens, r.prefill_chunks, r.gen_tokens, r.total_seconds
    );
    let _ = writeln!(
        s,
        "  throughput: {:.1} tokens/s aggregate ({:.1} decode-only), occupancy {:.2}",
        r.tokens_per_s, r.decode_tokens_per_s, r.avg_batch_occupancy
    );
    let _ = writeln!(
        s,
        "  TTFT [s]:    mean {:.4}  p50 {:.4}  p99 {:.4}",
        r.ttft_mean_s, r.ttft_p50_s, r.ttft_p99_s
    );
    let _ = writeln!(
        s,
        "  latency [s]: mean {:.4}  p50 {:.4}  p99 {:.4}",
        r.latency_mean_s, r.latency_p50_s, r.latency_p99_s
    );
    let _ = writeln!(
        s,
        "  TPOT [s]:    mean {:.4}  p50 {:.4}  p99 {:.4}",
        r.tpot_mean_s, r.tpot_p50_s, r.tpot_p99_s
    );
    let _ = writeln!(
        s,
        "  queue [s]:   mean {:.4}  p99 {:.4}  preemptions {}",
        r.queue_mean_s, r.queue_p99_s, r.preemptions
    );
    if r.kv_imports > 0 {
        let _ = writeln!(
            s,
            "  KV imports: {} requests, {} prompt tokens mapped without prefill",
            r.kv_imports, r.imported_kv_tokens
        );
    }
    for c in &r.per_class {
        let _ = writeln!(
            s,
            "  class {}: {} done  TTFT p50 {:.4} p99 {:.4}  latency p50 {:.4} p99 {:.4}",
            c.class, c.completed, c.ttft_p50_s, c.ttft_p99_s, c.latency_p50_s,
            c.latency_p99_s
        );
    }
    let _ = writeln!(
        s,
        "  KV pages: {} x {} tokens, peak {:.2}/{:.2} GB",
        r.total_pages,
        r.page_tokens,
        r.peak_kv_bytes as f64 / 1e9,
        r.kv_budget_bytes as f64 / 1e9,
    );
    let _ = writeln!(
        s,
        "  prefix cache: {}  hit {} tokens ({:.1}%, {} mid-prefill)  pricing-memo hit {:.1}%",
        if r.prefix_cache { "on" } else { "off" },
        r.prefix_hit_tokens,
        r.prefix_hit_rate * 100.0,
        r.prefix_late_hits,
        r.pricing_cache_hit_rate * 100.0,
    );
    if r.token_budget > 0 {
        let _ = writeln!(
            s,
            "  token budget: {} / iteration, {:.1}% filled, {} first tokens fused",
            r.token_budget,
            r.budget_utilization * 100.0,
            r.fused_first_tokens,
        );
    }
    if r.tp > 1 || r.pp > 1 {
        let coll_pct = if r.total_cycles > 0 {
            r.collective_cycles as f64 / r.total_cycles as f64 * 100.0
        } else {
            0.0
        };
        let _ = writeln!(
            s,
            "  shard: tp={} pp={}  collectives {:.3} Mcycles ({:.1}% of wall)  \
             d2d {:.2} GB",
            r.tp,
            r.pp,
            r.collective_cycles as f64 / 1e6,
            coll_pct,
            r.d2d_bytes as f64 / 1e9,
        );
    }
    if r.replica_failures > 0 || r.stall_cycles > 0 || r.link_faults > 0 {
        let _ = writeln!(
            s,
            "  faults: {} replica failures, {} stall cycles, {} link events  \
             ({:.1}% capacity lost)",
            r.replica_failures,
            r.stall_cycles,
            r.link_faults,
            r.degraded_capacity_fraction * 100.0,
        );
        let _ = writeln!(
            s,
            "  recovery: {} requests salvaged ({:.2} GB KV re-exported), \
             {} retries, {:.3} Mcycles recovering",
            r.salvaged_requests,
            r.salvaged_kv_bytes as f64 / 1e9,
            r.retries,
            r.recovery_cycles as f64 / 1e6,
        );
    }
    for w in &r.warnings {
        let _ = writeln!(s, "  warning: {w}");
    }
    let pass_lookups = r.pass_cache_hits + r.pass_cache_misses;
    let _ = writeln!(
        s,
        "  engine {}: {} arrivals, {} passes, pass-memo hit {:.1}%",
        r.engine,
        r.arrival_events,
        r.pass_events,
        if pass_lookups > 0 {
            r.pass_cache_hits as f64 / pass_lookups as f64 * 100.0
        } else {
            0.0
        },
    );
    // Per-phase kernel-class split (Fig. 10 buckets at serving time):
    // one line per pass phase that actually ran, zero classes elided.
    for (phase, kc) in [
        ("prefill", &r.prefill_kind_cycles),
        ("decode", &r.decode_kind_cycles),
        ("mixed", &r.mixed_kind_cycles),
    ] {
        if kc.is_zero() {
            continue;
        }
        let mut line = format!("  {phase} kernel Mcycles:");
        for (kind, cycles) in kc.iter() {
            if cycles > 0 {
                let _ = write!(line, "  {} {:.3}", kind.name(), cycles as f64 / 1e6);
            }
        }
        let _ = writeln!(s, "{line}");
    }
    let _ = writeln!(
        s,
        "  FPU util {:.1}%  power {:.2} W  HBM traffic {:.2} GB",
        r.fpu_utilization * 100.0,
        r.power_w,
        r.hbm_gb,
    );
    s
}

/// Serialize a [`KindCycles`] as a JSON object keyed by kernel class, in
/// canonical [`crate::coordinator::KIND_ORDER`] order.
fn kind_cycles_json(kc: &KindCycles) -> String {
    let fields: Vec<String> = kc
        .iter()
        .map(|(kind, cycles)| format!("\"{}\":{}", kind.name(), cycles))
        .collect();
    format!("{{{}}}", fields.join(","))
}

/// JSON export of a serving report (bench-trend artifacts; scalar summary
/// plus per-class percentiles, no per-request detail).
pub fn serve_json(r: &ServeReport) -> String {
    let classes: Vec<String> = r
        .per_class
        .iter()
        .map(|c| {
            format!(
                "{{\"class\":{},\"completed\":{},\"ttft_p50_s\":{},\"ttft_p99_s\":{},\
                 \"latency_p50_s\":{},\"latency_p99_s\":{}}}",
                c.class, c.completed, c.ttft_p50_s, c.ttft_p99_s, c.latency_p50_s,
                c.latency_p99_s
            )
        })
        .collect();
    format!(
        "{{\"schema_version\":{SERVE_SCHEMA_VERSION},\
         \"model\":\"{}\",\"format\":\"{}\",\"kv_format\":\"{}\",\
         \"class_precision\":\"{}\",\"requests\":{},\"completed\":{},\
         \"rejected\":{},\"max_batch\":{},\"page_tokens\":{},\"total_pages\":{},\
         \"peak_kv_bytes\":{},\"kv_budget_bytes\":{},\"total_seconds\":{},\
         \"prefill_tokens\":{},\"prefill_chunks\":{},\"gen_tokens\":{},\
         \"preemptions\":{},\"tokens_per_s\":{},\"decode_tokens_per_s\":{},\
         \"avg_batch_occupancy\":{},\"ttft_mean_s\":{},\"ttft_p50_s\":{},\
         \"ttft_p99_s\":{},\"latency_p50_s\":{},\"latency_p99_s\":{},\
         \"queue_mean_s\":{},\"queue_p99_s\":{},\"fpu_utilization\":{},\
         \"power_w\":{},\"prefix_cache\":{},\"prefix_hit_tokens\":{},\
         \"prefix_hit_rate\":{},\"prefix_late_hits\":{},\"token_budget\":{},\
         \"budget_utilization\":{},\"fused_first_tokens\":{},\
         \"pricing_cache_hit_rate\":{},\"tp\":{},\"pp\":{},\
         \"collective_cycles\":{},\"d2d_bytes\":{},\
         \"engine\":\"{}\",\"arrival_events\":{},\"pass_events\":{},\
         \"pass_cache_hits\":{},\"pass_cache_misses\":{},\
         \"tpot_mean_s\":{},\"tpot_p50_s\":{},\"tpot_p99_s\":{},\
         \"kv_imports\":{},\"imported_kv_tokens\":{},\
         \"replica_failures\":{},\"stall_cycles\":{},\"link_faults\":{},\
         \"salvaged_requests\":{},\"salvaged_kv_bytes\":{},\"retries\":{},\
         \"recovery_cycles\":{},\"degraded_capacity_fraction\":{},\
         \"prefill_kind_cycles\":{},\"decode_kind_cycles\":{},\
         \"mixed_kind_cycles\":{},\
         \"warnings\":[{}],\"per_class\":[{}]}}",
        r.model,
        r.format,
        r.kv_format,
        r.class_precision,
        r.requests,
        r.completed,
        r.rejected.len(),
        r.max_batch,
        r.page_tokens,
        r.total_pages,
        r.peak_kv_bytes,
        r.kv_budget_bytes,
        r.total_seconds,
        r.prefill_tokens,
        r.prefill_chunks,
        r.gen_tokens,
        r.preemptions,
        r.tokens_per_s,
        r.decode_tokens_per_s,
        r.avg_batch_occupancy,
        r.ttft_mean_s,
        r.ttft_p50_s,
        r.ttft_p99_s,
        r.latency_p50_s,
        r.latency_p99_s,
        r.queue_mean_s,
        r.queue_p99_s,
        r.fpu_utilization,
        r.power_w,
        r.prefix_cache,
        r.prefix_hit_tokens,
        r.prefix_hit_rate,
        r.prefix_late_hits,
        r.token_budget,
        r.budget_utilization,
        r.fused_first_tokens,
        r.pricing_cache_hit_rate,
        r.tp,
        r.pp,
        r.collective_cycles,
        r.d2d_bytes,
        r.engine,
        r.arrival_events,
        r.pass_events,
        r.pass_cache_hits,
        r.pass_cache_misses,
        r.tpot_mean_s,
        r.tpot_p50_s,
        r.tpot_p99_s,
        r.kv_imports,
        r.imported_kv_tokens,
        r.replica_failures,
        r.stall_cycles,
        r.link_faults,
        r.salvaged_requests,
        r.salvaged_kv_bytes,
        r.retries,
        r.recovery_cycles,
        r.degraded_capacity_fraction,
        kind_cycles_json(&r.prefill_kind_cycles),
        kind_cycles_json(&r.decode_kind_cycles),
        kind_cycles_json(&r.mixed_kind_cycles),
        r.warnings
            .iter()
            .map(|w| format!("\"{}\"", w.replace('\\', "\\\\").replace('"', "\\\"")))
            .collect::<Vec<_>>()
            .join(","),
        classes.join(",")
    )
}

/// Render a replica-router report: the routing summary, the merged fleet
/// view, and one line per replica.
pub fn router_table(r: &RouterReport) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "replica router: {} replicas, policy {}, assignment {:?}",
        r.replicas, r.policy, r.assigned
    );
    s.push_str(&serve_table(&r.merged));
    for (i, rep) in r.per_replica.iter().enumerate() {
        let _ = writeln!(
            s,
            "  replica {i}: {} done in {:.3} s  {:.1} tokens/s  hit {:.1}%  p99 TTFT {:.4}",
            rep.completed,
            rep.total_seconds,
            rep.tokens_per_s,
            rep.prefix_hit_rate * 100.0,
            rep.ttft_p99_s,
        );
    }
    s
}

/// JSON export of a replica-router report (merged fleet view plus the
/// full per-replica reports).
pub fn router_json(r: &RouterReport) -> String {
    let per: Vec<String> = r.per_replica.iter().map(serve_json).collect();
    let assigned: Vec<String> = r.assigned.iter().map(|a| a.to_string()).collect();
    format!(
        "{{\"schema_version\":{SERVE_SCHEMA_VERSION},\"replicas\":{},\
         \"policy\":\"{}\",\"assigned\":[{}],\"merged\":{},\"per_replica\":[{}]}}",
        r.replicas,
        r.policy,
        assigned.join(","),
        serve_json(&r.merged),
        per.join(",")
    )
}

/// Render a disaggregated-fleet report: the split summary, migration
/// counters, combined end-to-end percentiles, and the per-stage merged
/// views.
pub fn disagg_table(r: &DisaggReport) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "disaggregated fleet: {} prefill + {} decode replicas, policy {}",
        r.prefill_replicas, r.decode_replicas, r.policy
    );
    let _ = writeln!(
        s,
        "  completed {} / {} requests, rejected {}{}",
        r.completed,
        r.requests,
        r.rejected.len(),
        if r.rejected.is_empty() {
            String::new()
        } else {
            format!(" (ids {:?})", r.rejected)
        }
    );
    let _ = writeln!(
        s,
        "  migrations: {} handoffs, {:.2} GB KV over d2d links, {:.3} Mcycles \
         (overlapped with decode)",
        r.migrations,
        r.migrated_kv_bytes as f64 / 1e9,
        r.migration_cycles as f64 / 1e6,
    );
    if r.migration_retries > 0 || r.recompute_fallbacks > 0 {
        let _ = writeln!(
            s,
            "  corruption: {} migration retries, {} recompute fallbacks",
            r.migration_retries, r.recompute_fallbacks,
        );
    }
    if r.degraded_capacity_fraction > 0.0 {
        let _ = writeln!(
            s,
            "  faults: {:.1}% decode-fleet capacity lost",
            r.degraded_capacity_fraction * 100.0,
        );
    }
    for w in &r.warnings {
        let _ = writeln!(s, "  warning: {w}");
    }
    let _ = writeln!(
        s,
        "  end-to-end TTFT [s]: mean {:.4}  p50 {:.4}  p99 {:.4}",
        r.ttft_mean_s, r.ttft_p50_s, r.ttft_p99_s
    );
    let _ = writeln!(
        s,
        "  TPOT [s]:            mean {:.4}  p50 {:.4}  p99 {:.4}",
        r.tpot_mean_s, r.tpot_p50_s, r.tpot_p99_s
    );
    let _ = writeln!(
        s,
        "  latency [s]:         mean {:.4}  p50 {:.4}  p99 {:.4}",
        r.latency_mean_s, r.latency_p50_s, r.latency_p99_s
    );
    let _ = writeln!(
        s,
        "  {:.1} tokens/s over {:.3} s makespan",
        r.tokens_per_s, r.total_seconds
    );
    let _ = writeln!(s, "prefill stage:");
    s.push_str(&serve_table(&r.prefill));
    let _ = writeln!(s, "decode stage:");
    s.push_str(&serve_table(&r.decode));
    s
}

/// JSON export of a disaggregated-fleet report (combined view plus the
/// two per-stage merged serve reports).
pub fn disagg_json(r: &DisaggReport) -> String {
    format!(
        "{{\"schema_version\":{SERVE_SCHEMA_VERSION},\
         \"prefill_replicas\":{},\"decode_replicas\":{},\"policy\":\"{}\",\
         \"requests\":{},\"completed\":{},\"rejected\":{},\
         \"migrations\":{},\"migrated_kv_bytes\":{},\"migration_cycles\":{},\
         \"ttft_mean_s\":{},\"ttft_p50_s\":{},\"ttft_p99_s\":{},\
         \"tpot_mean_s\":{},\"tpot_p50_s\":{},\"tpot_p99_s\":{},\
         \"latency_mean_s\":{},\"latency_p50_s\":{},\"latency_p99_s\":{},\
         \"total_seconds\":{},\"tokens_per_s\":{},\
         \"migration_retries\":{},\"recompute_fallbacks\":{},\
         \"degraded_capacity_fraction\":{},\"warnings\":[{}],\
         \"prefill\":{},\"decode\":{}}}",
        r.prefill_replicas,
        r.decode_replicas,
        r.policy,
        r.requests,
        r.completed,
        r.rejected.len(),
        r.migrations,
        r.migrated_kv_bytes,
        r.migration_cycles,
        r.ttft_mean_s,
        r.ttft_p50_s,
        r.ttft_p99_s,
        r.tpot_mean_s,
        r.tpot_p50_s,
        r.tpot_p99_s,
        r.latency_mean_s,
        r.latency_p50_s,
        r.latency_p99_s,
        r.total_seconds,
        r.tokens_per_s,
        r.migration_retries,
        r.recompute_fallbacks,
        r.degraded_capacity_fraction,
        r.warnings
            .iter()
            .map(|w| format!("\"{}\"", w.replace('\\', "\\\\").replace('"', "\\\"")))
            .collect::<Vec<_>>()
            .join(","),
        serve_json(&r.prefill),
        serve_json(&r.decode)
    )
}

/// Render a per-track accounting table for a recorded [`FleetTrace`]:
/// one row per replica process (makespan, busy/stall/idle split, span
/// and sample counts) plus a summary line for the KV-migration process.
/// The full event stream lives in the Chrome-trace JSON this rides
/// along with; this is the at-a-glance view for terminals and CI logs.
pub fn trace_summary(t: &FleetTrace) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<14} {:>14} {:>7} {:>7} {:>7} {:>7} {:>9} {:>8}",
        "track", "cycles", "busy%", "stall%", "idle%", "passes", "requests", "samples"
    );
    for (label, rec) in t.replicas() {
        let total = rec.total_cycles().unwrap_or(0);
        let acct = rec.track_accounting();
        let pct = |c: u64| {
            if total > 0 {
                c as f64 / total as f64 * 100.0
            } else {
                0.0
            }
        };
        let _ = writeln!(
            s,
            "{:<14} {:>14} {:>6.1}% {:>6.1}% {:>6.1}% {:>7} {:>9} {:>8}",
            label,
            total,
            pct(acct.busy),
            pct(acct.stall),
            pct(acct.idle),
            rec.passes().len(),
            rec.requests().len(),
            rec.gauges().len(),
        );
    }
    if !t.migrations().is_empty() {
        let bytes: u64 = t.migrations().iter().map(|m| m.bytes).sum();
        let retried: u64 = t
            .migrations()
            .iter()
            .map(|m| m.attempts.saturating_sub(1) as u64)
            .sum();
        let _ = writeln!(
            s,
            "kv-migration: {} handoff spans, {:.2} GB on the wire, {} retried attempts",
            t.migrations().len(),
            bytes as f64 / 1e9,
            retried,
        );
    }
    s
}

/// Render ranked shard plans (the `shard` subcommand): one row per plan,
/// best first.
pub fn shard_table(title: &str, rows: &[RankedPlan]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{title}");
    let _ = writeln!(
        s,
        "{:>4} {:>4} {:>4} {:>5} {:>14} {:>14} {:>12} {:>10}",
        "tp", "pp", "rep", "dies", "Mcyc/token", "tokens/s", "d2d MB/pass", "KV GB"
    );
    for r in rows {
        let _ = writeln!(
            s,
            "{:>4} {:>4} {:>4} {:>5} {:>14.3} {:>14.1} {:>12.3} {:>10.2}",
            r.plan.tp,
            r.plan.pp,
            r.plan.replicas,
            r.plan.dies(),
            r.cost.token_latency_cycles as f64 / 1e6,
            r.cost.tokens_per_s,
            r.cost.total.d2d_bytes as f64 / 1e6,
            r.kv_budget_bytes as f64 / 1e9,
        );
    }
    s
}

/// JSON export of ranked shard plans.
pub fn shard_json(rows: &[RankedPlan]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"tp\":{},\"pp\":{},\"replicas\":{},\"dies\":{},\
                 \"token_latency_cycles\":{},\"steady_cycles\":{},\
                 \"tokens_per_s\":{},\"d2d_bytes\":{},\"kv_budget_bytes\":{}}}",
                r.plan.tp,
                r.plan.pp,
                r.plan.replicas,
                r.plan.dies(),
                r.cost.token_latency_cycles,
                r.cost.steady_cycles,
                r.cost.tokens_per_s,
                r.cost.total.d2d_bytes,
                r.kv_budget_bytes
            )
        })
        .collect();
    format!(
        "{{\"schema_version\":{SERVE_SCHEMA_VERSION},\"plans\":[{}]}}",
        items.join(",")
    )
}

/// JSON export of run reports (bench-trend artifacts).
pub fn runs_json(rows: &[RunReport]) -> String {
    let items: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"model\":\"{}\",\"mode\":\"{}\",\"format\":\"{}\",\"seq\":{},\
                 \"batch\":{},\"cycles\":{},\"seconds\":{},\"throughput\":{},\
                 \"throughput_unit\":\"{}\",\"decode_throughput\":{},\"ttft_s\":{},\
                 \"gflops\":{},\"fpu_utilization\":{},\"power_w\":{},\
                 \"gflops_per_w\":{},\"hbm_gb\":{}}}",
                r.model,
                r.mode,
                r.format,
                r.seq,
                r.batch,
                r.cycles,
                r.seconds,
                r.throughput,
                r.throughput_unit,
                r.decode_throughput,
                r.ttft_s,
                r.gflops,
                r.fpu_utilization,
                r.power_w,
                r.gflops_per_w,
                r.hbm_gb
            )
        })
        .collect();
    format!("[{}]", items.join(","))
}

/// Render a Fig. 10-style latency breakdown.
pub fn breakdown_table(title: &str, b: &Breakdown) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{title} (total {} cycles)", b.total_cycles);
    for share in &b.shares {
        let bar_len = (share.fraction * 40.0).round() as usize;
        let _ = writeln!(
            s,
            "  {:<20} {:>6.1}%  {}",
            share.kind,
            share.fraction * 100.0,
            "#".repeat(bar_len)
        );
    }
    s
}

/// Render a speedup ladder (Fig. 7/8 style): (label, throughput) pairs
/// normalized to the first entry.
pub fn speedup_ladder(title: &str, unit: &str, rows: &[(String, f64)]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{title}");
    let base = rows.first().map(|r| r.1).unwrap_or(1.0);
    for (label, tp) in rows {
        let speedup = if base > 0.0 { tp / base } else { 0.0 };
        let _ = writeln!(s, "  {label:<24} {tp:>10.2} {unit:<9} ({speedup:>5.1}x)");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{FpFormat, PlatformConfig};
    use crate::coordinator::InferenceEngine;
    use crate::model::{Mode, ModelConfig};

    fn sample_report() -> RunReport {
        InferenceEngine::new(PlatformConfig::occamy()).run_nar(
            &ModelConfig::vit_b(),
            197,
            FpFormat::Fp32,
        )
    }

    #[test]
    fn table_contains_model_and_numbers() {
        let t = runs_table(&[sample_report()]);
        assert!(t.contains("vit-b"));
        assert!(t.contains("nar"));
        assert!(t.contains("fp32"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let c = runs_csv(&[sample_report(), sample_report()]);
        assert_eq!(c.lines().count(), 3);
        assert!(c.starts_with("model,mode"));
    }

    #[test]
    fn breakdown_renders_bars() {
        let e = InferenceEngine::new(PlatformConfig::occamy());
        let b = e.breakdown(&ModelConfig::vit_b(), Mode::Nar, 197, FpFormat::Fp32);
        let t = breakdown_table("vit-b fp32", &b);
        assert!(t.contains("gemm"));
        assert!(t.contains('#'));
    }

    #[test]
    fn serve_table_has_percentiles() {
        let e = InferenceEngine::new(PlatformConfig::occamy());
        let w = crate::coordinator::Workload::uniform(4, 16, 8);
        let r = e.serve(&ModelConfig::tiny(), &w, 2, FpFormat::Fp32);
        let t = serve_table(&r);
        assert!(t.contains("tiny"));
        assert!(t.contains("p50"));
        assert!(t.contains("p99"));
        assert!(t.contains("TTFT"));
        assert!(t.contains("tokens/s"));
        assert!(t.contains("KV pages"));
        assert!(t.contains("preemptions"));
    }

    #[test]
    fn serve_json_parses_back() {
        let e = InferenceEngine::new(PlatformConfig::occamy());
        let w = crate::coordinator::Workload::uniform(4, 16, 8).with_priority_classes(2);
        let r = e.serve(&ModelConfig::tiny(), &w, 2, FpFormat::Fp32);
        let v = crate::util::json::parse(&serve_json(&r)).expect("valid JSON");
        assert_eq!(v.req("model").unwrap().as_str(), Some("tiny"));
        assert_eq!(v.req("completed").unwrap().as_u64(), Some(4));
        assert_eq!(v.req("per_class").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.req("ttft_p99_s").unwrap().as_f64().unwrap() > 0.0);
        // PR-3 keys are appended, earlier keys untouched.
        assert_eq!(
            v.req("prefix_cache").unwrap(),
            &crate::util::json::Value::Bool(true)
        );
        assert_eq!(v.req("prefix_hit_tokens").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("token_budget").unwrap().as_u64(), Some(0));
        assert!(v.req("pricing_cache_hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.req("budget_utilization").unwrap().as_f64().is_some());
    }

    #[test]
    fn serve_table_shows_prefix_and_budget_counters() {
        let e = InferenceEngine::new(PlatformConfig::occamy());
        let w = crate::coordinator::Workload::uniform(4, 16, 8);
        let mut opts = crate::coordinator::BatcherConfig::new(2, 0);
        opts.token_budget = 32;
        let r = e.serve_with(&ModelConfig::tiny(), &w, opts, FpFormat::Fp32);
        let t = serve_table(&r);
        assert!(t.contains("prefix cache: on"));
        assert!(t.contains("token budget: 32"));
    }

    #[test]
    fn serve_json_has_schema_version_and_new_counters() {
        let e = InferenceEngine::new(PlatformConfig::occamy());
        let w = crate::coordinator::Workload::uniform(4, 16, 8);
        let r = e.serve(&ModelConfig::tiny(), &w, 2, FpFormat::Fp32);
        let v = crate::util::json::parse(&serve_json(&r)).expect("valid JSON");
        assert_eq!(
            v.req("schema_version").unwrap().as_u64(),
            Some(SERVE_SCHEMA_VERSION as u64)
        );
        assert_eq!(v.req("prefix_late_hits").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("fused_first_tokens").unwrap().as_u64(), Some(0));
        // v8: precision-policy keys — degenerate run, so kv matches the
        // serving format and the ladder spec is empty.
        assert_eq!(v.req("kv_format").unwrap().as_str(), Some("fp32"));
        assert_eq!(v.req("class_precision").unwrap().as_str(), Some(""));
        // v3: executed-shard-plan keys, zero on the single-die engine.
        assert_eq!(v.req("tp").unwrap().as_u64(), Some(1));
        assert_eq!(v.req("pp").unwrap().as_u64(), Some(1));
        assert_eq!(v.req("collective_cycles").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("d2d_bytes").unwrap().as_u64(), Some(0));
        // v4: event-core keys. The default engine is event-driven, every
        // offered request raises an arrival, and every priced iteration
        // raises a pass event.
        assert_eq!(v.req("engine").unwrap().as_str(), Some("event"));
        assert_eq!(v.req("arrival_events").unwrap().as_u64(), Some(4));
        assert!(v.req("pass_events").unwrap().as_u64().unwrap() > 0);
        let hits = v.req("pass_cache_hits").unwrap().as_u64().unwrap();
        let misses = v.req("pass_cache_misses").unwrap().as_u64().unwrap();
        assert_eq!(hits + misses, v.req("pass_events").unwrap().as_u64().unwrap());
        // v5: TPOT percentiles and the imported-KV counters (zero on a
        // symmetric fleet; the disagg decode stage populates them).
        assert!(v.req("tpot_p99_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            v.req("tpot_p50_s").unwrap().as_f64().unwrap()
                <= v.req("tpot_p99_s").unwrap().as_f64().unwrap()
        );
        assert_eq!(v.req("kv_imports").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("imported_kv_tokens").unwrap().as_u64(), Some(0));
        // v6: fault/recovery keys, all zero or empty on a fault-free run.
        assert_eq!(v.req("replica_failures").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("stall_cycles").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("link_faults").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("salvaged_requests").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("salvaged_kv_bytes").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("retries").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("recovery_cycles").unwrap().as_u64(), Some(0));
        assert_eq!(
            v.req("degraded_capacity_fraction").unwrap().as_f64(),
            Some(0.0)
        );
        assert_eq!(v.req("warnings").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn serve_table_surfaces_fault_and_recovery_counters() {
        use crate::coordinator::FaultPlan;
        use crate::parallel::{serve_replicated_with_faults, RoutePolicy};
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let w = crate::coordinator::Workload::uniform(6, 16, 8);
        let opts = crate::coordinator::BatcherConfig::new(2, 0);
        let plan = FaultPlan::parse("fail@0:r0", 1).unwrap();
        let fleet = serve_replicated_with_faults(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            2,
            RoutePolicy::JoinShortestQueue,
            &plan,
        );
        let t = serve_table(&fleet.merged);
        assert!(t.contains("faults: 1 replica failures"), "{t}");
        assert!(t.contains("recovery:"), "{t}");
        let v = crate::util::json::parse(&serve_json(&fleet.merged)).expect("valid JSON");
        assert_eq!(v.req("replica_failures").unwrap().as_u64(), Some(1));
        assert!(v.req("salvaged_requests").unwrap().as_u64().unwrap() > 0);
        assert!(v.req("retries").unwrap().as_u64().unwrap() > 0);
        assert!(
            v.req("degraded_capacity_fraction").unwrap().as_f64().unwrap() > 0.0
        );
    }

    #[test]
    fn disagg_table_and_json_render() {
        use crate::parallel::RoutePolicy;
        let e = InferenceEngine::new(PlatformConfig::with_dies(2));
        let w = crate::coordinator::Workload::uniform(6, 16, 8);
        let opts = crate::coordinator::BatcherConfig::new(2, 0);
        let r = e.serve_disaggregated(
            &ModelConfig::tiny(),
            &w,
            opts,
            FpFormat::Fp32,
            1,
            1,
            RoutePolicy::JoinShortestQueue,
        );
        let t = disagg_table(&r);
        assert!(t.contains("disaggregated fleet: 1 prefill + 1 decode"), "{t}");
        assert!(t.contains("migrations: 6 handoffs"), "{t}");
        assert!(t.contains("prefill stage:"), "{t}");
        assert!(t.contains("decode stage:"), "{t}");
        assert!(t.contains("KV imports: 6 requests"), "{t}");
        let v = crate::util::json::parse(&disagg_json(&r)).expect("valid JSON");
        assert_eq!(
            v.req("schema_version").unwrap().as_u64(),
            Some(SERVE_SCHEMA_VERSION as u64)
        );
        assert_eq!(v.req("migrations").unwrap().as_u64(), Some(6));
        assert!(v.req("migrated_kv_bytes").unwrap().as_u64().unwrap() > 0);
        assert!(v.req("tpot_p99_s").unwrap().as_f64().unwrap() > 0.0);
        // v6 disagg keys: inert without an armed fault plan.
        assert_eq!(v.req("migration_retries").unwrap().as_u64(), Some(0));
        assert_eq!(v.req("recompute_fallbacks").unwrap().as_u64(), Some(0));
        assert_eq!(
            v.req("degraded_capacity_fraction").unwrap().as_f64(),
            Some(0.0)
        );
        assert_eq!(
            v.req("decode").unwrap().req("kv_imports").unwrap().as_u64(),
            Some(6)
        );
        assert_eq!(
            v.req("prefill").unwrap().req("gen_tokens").unwrap().as_u64(),
            Some(0)
        );
    }

    #[test]
    fn serve_table_and_json_surface_the_tp_tax() {
        use crate::parallel::ShardPlan;
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let w = crate::coordinator::Workload::uniform(4, 16, 8);
        let mut opts = crate::coordinator::BatcherConfig::new(2, 0);
        opts.plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
        let e = InferenceEngine::new(p);
        let r = e.serve_with(&cfg, &w, opts, FpFormat::Fp32);
        let t = serve_table(&r);
        assert!(t.contains("shard: tp=2 pp=1"), "{t}");
        assert!(t.contains("d2d"), "{t}");
        let v = crate::util::json::parse(&serve_json(&r)).expect("valid JSON");
        assert_eq!(v.req("tp").unwrap().as_u64(), Some(2));
        assert!(v.req("collective_cycles").unwrap().as_u64().unwrap() > 0);
        assert!(v.req("d2d_bytes").unwrap().as_u64().unwrap() > 0);
    }

    #[test]
    fn router_json_and_table_render() {
        use crate::parallel::RoutePolicy;
        let e = InferenceEngine::new(PlatformConfig::with_dies(2));
        let w = crate::coordinator::Workload::uniform(6, 16, 8);
        let opts = crate::coordinator::BatcherConfig::new(2, 0);
        let r = e.serve_replicated(
            &ModelConfig::tiny(),
            &w,
            opts,
            FpFormat::Fp32,
            2,
            RoutePolicy::JoinShortestQueue,
        );
        let t = router_table(&r);
        assert!(t.contains("replica router: 2 replicas"));
        assert!(t.contains("replica 0:"));
        assert!(t.contains("replica 1:"));
        let v = crate::util::json::parse(&router_json(&r)).expect("valid JSON");
        assert_eq!(v.req("replicas").unwrap().as_u64(), Some(2));
        assert_eq!(v.req("policy").unwrap().as_str(), Some("jsq"));
        assert_eq!(v.req("per_replica").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.req("merged").unwrap().req("completed").unwrap().as_u64(),
            Some(6)
        );
    }

    #[test]
    fn shard_table_and_json_render() {
        use crate::model::Mode;
        use crate::parallel::{best_plans, Objective};
        let ranked = best_plans(
            &ModelConfig::gpt_j(),
            FpFormat::Fp8,
            &PlatformConfig::with_dies(2),
            Mode::Ar,
            4,
            1024,
            Objective::Latency,
        );
        let t = shard_table("plans", &ranked);
        assert!(t.contains("tokens/s"));
        assert!(t.lines().count() >= 2 + ranked.len());
        let v = crate::util::json::parse(&shard_json(&ranked)).expect("valid JSON");
        assert_eq!(
            v.req("plans").unwrap().as_arr().unwrap().len(),
            ranked.len()
        );
    }

    #[test]
    fn runs_json_parses_back() {
        let v = crate::util::json::parse(&runs_json(&[sample_report(), sample_report()]))
            .expect("valid JSON");
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].req("model").unwrap().as_str(), Some("vit-b"));
        assert!(arr[0].req("throughput").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn serve_surfaces_the_per_phase_kind_split() {
        let e = InferenceEngine::new(PlatformConfig::occamy());
        let w = crate::coordinator::Workload::uniform(4, 16, 8);
        let r = e.serve(&ModelConfig::tiny(), &w, 2, FpFormat::Fp32);
        // The split plus the collective tax covers the priced work
        // exactly (v7 invariant — also asserted at the engine layer).
        assert_eq!(
            r.prefill_kind_cycles.total()
                + r.decode_kind_cycles.total()
                + r.mixed_kind_cycles.total()
                + r.collective_cycles,
            r.work.cycles
        );
        let t = serve_table(&r);
        assert!(t.contains("prefill kernel Mcycles:"), "{t}");
        assert!(t.contains("decode kernel Mcycles:"), "{t}");
        let v = crate::util::json::parse(&serve_json(&r)).expect("valid JSON");
        let pre = v.req("prefill_kind_cycles").unwrap();
        assert!(pre.req("gemm").unwrap().as_u64().unwrap() > 0);
        assert!(pre.req("flashattention").unwrap().as_u64().is_some());
        let dec = v.req("decode_kind_cycles").unwrap();
        assert!(dec.req("gemm").unwrap().as_u64().unwrap() > 0);
        // Alternation-mode serve prices no mixed passes.
        let mix = v.req("mixed_kind_cycles").unwrap();
        assert_eq!(mix.req("gemm").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn disagg_json_surfaces_warnings() {
        use crate::parallel::RoutePolicy;
        let e = InferenceEngine::new(PlatformConfig::with_dies(2));
        let w = crate::coordinator::Workload::uniform(4, 16, 8);
        let opts = crate::coordinator::BatcherConfig::new(2, 0);
        let mut r = e.serve_disaggregated(
            &ModelConfig::tiny(),
            &w,
            opts,
            FpFormat::Fp32,
            1,
            1,
            RoutePolicy::JoinShortestQueue,
        );
        let v = crate::util::json::parse(&disagg_json(&r)).expect("valid JSON");
        assert_eq!(v.req("warnings").unwrap().as_arr().unwrap().len(), 0);
        r.warnings.push("synthetic \"quoted\" warning".into());
        let v = crate::util::json::parse(&disagg_json(&r)).expect("valid JSON");
        let warns = v.req("warnings").unwrap().as_arr().unwrap();
        assert_eq!(warns.len(), 1);
        assert_eq!(warns[0].as_str(), Some("synthetic \"quoted\" warning"));
    }

    #[test]
    fn trace_summary_renders_fleet_accounting() {
        use crate::coordinator::FaultPlan;
        use crate::parallel::{serve_disaggregated_traced, RoutePolicy};
        use crate::trace::TraceSettings;
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let w = crate::coordinator::Workload::uniform(6, 16, 8);
        let opts = crate::coordinator::BatcherConfig::new(2, 0);
        let (_, fleet) = serve_disaggregated_traced(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            1,
            1,
            RoutePolicy::JoinShortestQueue,
            &FaultPlan::off(),
            &TraceSettings::default(),
        );
        let t = trace_summary(&fleet);
        assert!(t.contains("busy%"), "{t}");
        assert!(t.contains("prefill 0"), "{t}");
        assert!(t.contains("decode 0"), "{t}");
        assert!(t.contains("kv-migration: 6 handoff spans"), "{t}");
    }

    #[test]
    fn ladder_normalizes_to_first() {
        let s = speedup_ladder(
            "test",
            "tok/s",
            &[("base".into(), 2.0), ("fast".into(), 8.0)],
        );
        assert!(s.contains("4.0x"), "{s}");
    }
}
