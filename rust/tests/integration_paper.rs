//! Integration: paper-shape assertions over the full stack — every
//! headline claim of the evaluation section is encoded as a test band.
//! (Exact numbers live in EXPERIMENTS.md; these tests pin the *shape*:
//! who wins, by roughly what factor, where crossovers fall.)

use snitch_fm::arch::{Features, FpFormat, PlatformConfig};
use snitch_fm::coordinator::{Breakdown, InferenceEngine};
use snitch_fm::coordinator::schedule::model_cost;
use snitch_fm::kernels::{fused_concat_linear_cost, unfused_concat_linear_cost};
use snitch_fm::model::{Mode, ModelConfig};
use snitch_fm::soa;

fn engine() -> InferenceEngine {
    InferenceEngine::new(PlatformConfig::occamy())
}

fn baseline_engine() -> InferenceEngine {
    let mut p = PlatformConfig::occamy();
    p.features = Features::baseline();
    InferenceEngine::new(p)
}

// ---------------------------------------------------------- Fig. 7 (GPT)
#[test]
fn fig7_gpt_ladder_shape() {
    let e = engine();
    let b = baseline_engine();
    for cfg in [ModelConfig::gpt3_xl(), ModelConfig::gpt_j()] {
        for mode in [Mode::Nar, Mode::Ar] {
            let run = |eng: &InferenceEngine, fmt| match mode {
                Mode::Nar => eng.run_nar(&cfg, 1024, fmt),
                Mode::Ar => eng.run_ar_step(&cfg, 1024, fmt),
            };
            let base = run(&b, FpFormat::Fp64).throughput;
            let fp64 = run(&e, FpFormat::Fp64).throughput;
            let fp32 = run(&e, FpFormat::Fp32).throughput;
            let fp16 = run(&e, FpFormat::Fp16).throughput;
            let fp8 = run(&e, FpFormat::Fp8).throughput;
            // Extensions: paper 4.6x (NAR) / 5.0x (AR). Our model gives
            // ~5x in NAR; in AR the token is HBM-bound (the paper's own
            // Table III shows <10% AR utilization, which entails memory-
            // boundedness), so extensions only shave the compute shadow:
            // ~1.1-1.5x. See EXPERIMENTS.md §Deviations.
            let ext = fp64 / base;
            let lo = if mode == Mode::Nar { 3.0 } else { 1.05 };
            assert!((lo..=8.0).contains(&ext), "{} {mode:?} ext {ext}", cfg.name);
            // Each precision step helps, at most the ideal 2x + fitting
            // effects (paper sees up to 2.1x).
            for (lo, hi, name) in [
                (fp32 / fp64, 2.6, "64->32"),
                (fp16 / fp32, 2.6, "32->16"),
                (fp8 / fp16, 2.6, "16->8"),
            ]
            {
                assert!(lo > 1.1 && lo < hi, "{} {mode:?} {name}: {lo}", cfg.name);
            }
            // Overall ladder lands in the paper's order of magnitude
            // (16.1x NAR / 35.6x AR; our per-step ratios compound to more).
            let total = fp8 / base;
            assert!((6.0..=80.0).contains(&total), "{} {mode:?} total {total}", cfg.name);
        }
    }
}

#[test]
fn fig7_absolute_fp8_throughput_near_paper() {
    // Paper: 260 / 142 tokens/s NAR FP8 for GPT3-XL / GPT-J at S=1024.
    let e = engine();
    let xl = e.run_nar(&ModelConfig::gpt3_xl(), 1024, FpFormat::Fp8).throughput;
    let j = e.run_nar(&ModelConfig::gpt_j(), 1024, FpFormat::Fp8).throughput;
    assert!((130.0..=600.0).contains(&xl), "gpt3-xl {xl}");
    assert!((70.0..=300.0).contains(&j), "gpt-j {j}");
    assert!(xl > j, "smaller model must be faster");
}

// ---------------------------------------------------------- Fig. 8 (ViT)
#[test]
fn fig8_vit_ladder_and_absolute() {
    let e = engine();
    let b = baseline_engine();
    // Paper FP8: 26 / 12 / 8 images/s for B/L/H.
    let expected = [
        (ModelConfig::vit_b(), 26.0),
        (ModelConfig::vit_l(), 12.0),
        (ModelConfig::vit_h(), 8.0),
    ];
    let mut prev = f64::MAX;
    for (cfg, paper) in expected {
        let fp8 = e.run_nar(&cfg, cfg.seq, FpFormat::Fp8).throughput;
        assert!(
            fp8 > 0.5 * paper && fp8 < 3.0 * paper,
            "{}: {fp8} vs paper {paper}",
            cfg.name
        );
        assert!(fp8 < prev, "bigger ViT must be slower");
        prev = fp8;
        let base = b.run_nar(&cfg, cfg.seq, FpFormat::Fp64).throughput;
        let total = fp8 / base;
        // Paper: 17.9x total for ViTs.
        assert!((8.0..=80.0).contains(&total), "{} total {total}", cfg.name);
    }
}

// ------------------------------------------------- Fig. 9 (S / clusters)
#[test]
fn fig9_sequence_scaling_monotonic() {
    let e = engine();
    for cfg in [ModelConfig::gpt3_xl(), ModelConfig::gpt_j()] {
        let mut prev_nar = f64::MAX;
        let mut prev_ar = f64::MAX;
        for s in [128u64, 512, 1024, 2048] {
            let nar = e.run_nar(&cfg, s, FpFormat::Fp8).throughput;
            let ar = e.run_ar_step(&cfg, s, FpFormat::Fp8).throughput;
            assert!(nar <= prev_nar, "{} NAR S={s}", cfg.name);
            assert!(ar <= prev_ar, "{} AR S={s}", cfg.name);
            assert!(nar > 5.0 * ar, "{} S={s}: NAR {nar} vs AR {ar}", cfg.name);
            prev_nar = nar;
            prev_ar = ar;
        }
    }
}

#[test]
fn fig9_cluster_scaling_close_to_linear() {
    // Paper: 16 clusters give 12x/11.9x/15.8x over 1 cluster (B/L/H).
    for cfg in [ModelConfig::vit_b(), ModelConfig::vit_l(), ModelConfig::vit_h()] {
        let one = InferenceEngine::new(PlatformConfig::with_clusters(1))
            .run_nar(&cfg, cfg.seq, FpFormat::Fp8)
            .throughput;
        let sixteen = InferenceEngine::new(PlatformConfig::with_clusters(16))
            .run_nar(&cfg, cfg.seq, FpFormat::Fp8)
            .throughput;
        let speedup = sixteen / one;
        assert!((8.0..=16.5).contains(&speedup), "{}: 16-cluster speedup {speedup}", cfg.name);
        // 4 clusters ~ 4x (paper: exactly 4x for all three).
        let four = InferenceEngine::new(PlatformConfig::with_clusters(4))
            .run_nar(&cfg, cfg.seq, FpFormat::Fp8)
            .throughput;
        let s4 = four / one;
        assert!((2.8..=4.4).contains(&s4), "{}: 4-cluster speedup {s4}", cfg.name);
    }
}

// ------------------------------------------------------ Fig. 10 (buckets)
#[test]
fn fig10_breakdown_buckets() {
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::gpt_j();
    // NAR FP32: paper GEMM(mlp) 66%; FA bucket grows FP32 -> FP8.
    let nar32 = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp32, &p);
    let b32 = Breakdown::fig10_buckets(&nar32);
    let frac = |b: &[snitch_fm::coordinator::KernelClassShare], k: &str| {
        b.iter().find(|s| s.kind.starts_with(k)).map(|s| s.fraction).unwrap_or(0.0)
    };
    let gemm32 = frac(&b32, "gemm");
    let fa32 = frac(&b32, "flashattention");
    assert!((0.45..=0.80).contains(&gemm32), "NAR fp32 gemm {gemm32}");
    assert!((0.15..=0.50).contains(&fa32), "NAR fp32 fa {fa32}");
    let nar8 = model_cost(&cfg, Mode::Nar, 1024, FpFormat::Fp8, &p);
    let b8 = Breakdown::fig10_buckets(&nar8);
    assert!(frac(&b8, "flashattention") > fa32, "FA share must grow at FP8");
    // AR: GEMM-dominated (97% FP32 in the paper).
    let ar32 = model_cost(&cfg, Mode::Ar, 1024, FpFormat::Fp32, &p);
    let ba = Breakdown::fig10_buckets(&ar32);
    assert!(frac(&ba, "gemm") + frac(&ba, "flashattention") > 0.90);
    // Activations are never the bottleneck.
    for b in [&b32, &b8, &ba] {
        assert!(frac(b, "layernorm") + frac(b, "gelu") < 0.15);
    }
}

// ----------------------------------------------------- Table III (power)
#[test]
fn table3_power_and_efficiency_bands() {
    let e = engine();
    let cfg = ModelConfig::gpt_j();
    // NAR: power ~5 W, GFLOPS/W ladder roughly doubling per step.
    let mut prev_eff = 0.0;
    for (fmt, paper_eff) in [
        (FpFormat::Fp64, 38.8),
        (FpFormat::Fp32, 78.8),
        (FpFormat::Fp16, 151.0),
        (FpFormat::Fp8, 294.0),
    ] {
        let r = e.run_nar(&cfg, 1024, fmt);
        assert!((3.5..=6.5).contains(&r.power_w), "{fmt} power {}", r.power_w);
        assert!(
            r.gflops_per_w > 0.6 * paper_eff && r.gflops_per_w < 1.6 * paper_eff,
            "{fmt} eff {} vs paper {paper_eff}",
            r.gflops_per_w
        );
        assert!(r.gflops_per_w > prev_eff, "{fmt} must improve efficiency");
        prev_eff = r.gflops_per_w;
    }
    // AR: low power, low utilization.
    for fmt in FpFormat::LADDER {
        let r = e.run_ar_step(&cfg, 1024, fmt);
        assert!((1.8..=3.2).contains(&r.power_w), "{fmt} AR power {}", r.power_w);
        assert!(r.fpu_utilization < 0.15, "{fmt} AR util {}", r.fpu_utilization);
    }
}

// ----------------------------------------------------- Table IV (vs SoA)
#[test]
fn table4_utilization_beats_every_soa_platform() {
    let e = engine();
    let r = e.run_nar(&ModelConfig::gpt3_xl(), 1024, FpFormat::Fp16);
    let ours = soa::OursRow::from_run(r.gflops, r.fpu_utilization, e.platform.total_cores());
    for s in soa::table4_soa() {
        assert!(
            ours.fpu_utilization_pct > s.fpu_utilization_pct,
            "must beat {} ({}% vs {}%)",
            s.name,
            ours.fpu_utilization_pct,
            s.fpu_utilization_pct
        );
    }
    // Paper: 2.04x over Gaudi2 (the best competitor); band 1.3-3x.
    let adv = ours.utilization_advantage();
    assert!((1.3..=3.0).contains(&adv), "advantage {adv}");
    // Throughput/CU comparable to SoA (paper: 0.0056 TFLOPS/CU).
    assert!((0.002..=0.02).contains(&ours.tflops_per_cu), "{}", ours.tflops_per_cu);
}

#[test]
fn table4_h100_vit_comparison() {
    // Paper Sec. VII-E claims 27 samples/s for ViT-L FP8 (0.2/CU, 6/W) —
    // which is inconsistent with the paper's own Fig. 8 (12 images/s for
    // ViT-L FP8). Our simulator reproduces the Fig. 8 operating point, so
    // the honest H100 comparison band is "same order of magnitude per CU
    // and per W", not the paper's >1x headline. See EXPERIMENTS.md.
    let e = engine();
    let r = e.run_nar(&ModelConfig::vit_l(), 197, FpFormat::Fp8);
    let h = soa::h100_vit_l_fp8();
    let per_cu = r.throughput / e.platform.total_cores() as f64;
    let per_w = r.throughput / r.power_w;
    assert!(per_cu > 0.3 * h.samples_per_s_per_cu, "{per_cu} vs {}", h.samples_per_s_per_cu);
    assert!(per_w > 0.4 * h.samples_per_s_per_w, "{per_w} vs {}", h.samples_per_s_per_w);
    // At the paper's claimed 27 samples/s the advantage would reproduce:
    let paper_ours = 27.0;
    assert!(paper_ours / 128.0 > h.samples_per_s_per_cu);
    assert!(paper_ours / 4.5 > h.samples_per_s_per_w);
}

// ------------------------------------------------------ Fig. 1 (traffic)
#[test]
fn fig1_fusion_cuts_hbm_traffic() {
    // Paper: 1.6x fewer HBM reads for GPT-J S=2048 (624 -> 384 MB total
    // block traffic). Our layer-level view: the fused concat+linear moves
    // several times less HBM data than the unfused one.
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::gpt_j();
    let f = fused_concat_linear_cost(2048, cfg.heads, cfg.p, cfg.e, FpFormat::Fp32, &p);
    let u = unfused_concat_linear_cost(2048, cfg.heads, cfg.p, cfg.e, FpFormat::Fp32, &p);
    let ratio = u.hbm_bytes() as f64 / f.hbm_bytes() as f64;
    assert!(ratio > 1.6, "traffic reduction {ratio}");
    assert!(f.c2c_bytes > 0, "fused path must use the c2c interconnect");
    // Whole-block view: with c2c off, total block HBM traffic grows.
    let mut base = PlatformConfig::occamy();
    base.features.cluster_to_cluster = false;
    let opt_cost = model_cost(&cfg, Mode::Nar, 2048, FpFormat::Fp32, &p);
    let base_cost = model_cost(&cfg, Mode::Nar, 2048, FpFormat::Fp32, &base);
    assert!(
        base_cost.total.hbm_bytes() > opt_cost.total.hbm_bytes(),
        "c2c must reduce HBM traffic: {} vs {}",
        base_cost.total.hbm_bytes(),
        opt_cost.total.hbm_bytes()
    );
}

// ------------------------------------------------- Sec. VII-E (academic)
#[test]
fn academic_comparisons_hold() {
    let e = engine();
    // AccelTran: 0.22 W/PE; ours well below (paper: 6.3x better).
    let rj = e.run_nar(&ModelConfig::gpt_j(), 1024, FpFormat::Fp8);
    let w_per_pe = rj.power_w / e.platform.total_cores() as f64;
    assert!(w_per_pe < soa::acceltran().watts_per_pe.unwrap() / 3.0, "{w_per_pe}");
    // Tambe et al.: 489 ms BERT-base; ours (ViT-B FP8) far below (paper 38 ms).
    let rb = e.run_nar(&ModelConfig::vit_b(), 197, FpFormat::Fp8);
    let ms = rb.seconds * 1e3;
    assert!(ms < 120.0, "ViT-B FP8 latency {ms} ms");
}
