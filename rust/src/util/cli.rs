//! Minimal `--flag value` command-line parser (clap is not available in
//! the offline registry). Supports `--key value`, `--key=value`, and bare
//! boolean flags; unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: the subcommand (first bare word) + flag map.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    /// Flags the caller has declared (for unknown-flag errors).
    known: Vec<&'static str>,
}

impl Args {
    /// Parse `std::env::args` (skipping argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>, known: &[&'static str]) -> Result<Args> {
        let mut out = Args { known: known.to_vec(), ..Default::default() };
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                let (key, inline_val) = match flag.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (flag.to_string(), None),
                };
                if !known.contains(&key.as_str()) {
                    bail!("unknown flag --{key}");
                }
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        // Consume the next token unless it is another flag;
                        // bare flags become "true".
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                out.flags.insert(key, val);
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                bail!("unexpected positional argument {tok:?}");
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        debug_assert!(self.known.contains(&key), "flag --{key} was not declared");
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_u32(&self, key: &str, default: u32) -> Result<u32> {
        Ok(self.get_u64(key, default as u64)? as u32)
    }

    /// `get_u64` narrowed to the platform's `usize` with an explicit
    /// range error instead of a silent `as` truncation.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        let v = self.get_u64(key, default as u64)?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("--{key} {v} out of range"))
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, known: &[&'static str]) -> Result<Args> {
        Args::parse(s.split_whitespace().map(String::from), known)
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("run --model gpt-j --seq=2048 --baseline", &["model", "seq", "baseline"])
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("model"), Some("gpt-j"));
        assert_eq!(a.get_u64("seq", 0).unwrap(), 2048);
        assert!(a.get_bool("baseline"));
        assert!(!a.get_bool("model")); // has a non-bool value
    }

    #[test]
    fn defaults() {
        let a = parse("run", &["model", "seq"]).unwrap();
        assert_eq!(a.get_or("model", "gpt-j"), "gpt-j");
        assert_eq!(a.get_u64("seq", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(parse("run --nope 1", &["model"]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("run --seq abc", &["seq"]).unwrap();
        assert!(a.get_u64("seq", 0).is_err());
    }

    #[test]
    fn usize_flag() {
        let a = parse("serve --requests 50000", &["requests"]).unwrap();
        assert_eq!(a.get_usize("requests", 32).unwrap(), 50_000);
        let b = parse("serve", &["requests"]).unwrap();
        assert_eq!(b.get_usize("requests", 32).unwrap(), 32);
    }

    #[test]
    fn float_flag() {
        let a = parse("serve --aging 0.25", &["aging"]).unwrap();
        assert_eq!(a.get_f64("aging", 5.0).unwrap(), 0.25);
        let b = parse("serve", &["aging"]).unwrap();
        assert_eq!(b.get_f64("aging", 5.0).unwrap(), 5.0);
        let c = parse("serve --aging nope", &["aging"]).unwrap();
        assert!(c.get_f64("aging", 5.0).is_err());
    }

    #[test]
    fn double_positional_errors() {
        assert!(parse("run extra", &[]).is_err());
    }
}
