//! Continuous-batching serving coordinator.
//!
//! Admits [`Request`]s against a KV-cache HBM budget, interleaves prefill
//! (NAR) and batched decode (AR) steps, and prices the whole trace on the
//! cycle-level platform model. This is the scheduling layer the paper's
//! single-request engine lacked: batched decode shares one weight stream
//! across all active requests, which is what lifts AR FPU utilization out
//! of the <10% Table III regime.
//!
//! Scheduling policy (deliberately simple, follow-ons in ROADMAP):
//! * FCFS admission — a request is admitted when a batch slot is free AND
//!   its full-length KV cache (at the serving precision) fits in the
//!   remaining HBM budget (weights and all admitted caches are resident;
//!   no paging, no preemption).
//! * Prefill runs as its own NAR pass on admission and briefly stalls the
//!   decode stream (vLLM-style non-chunked prefill).
//! * One decode step advances every active request by one token, priced
//!   as a single batched AR pass at the batch's longest KV length
//!   (conservative: shorter requests ride along for free).

use std::collections::VecDeque;

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::schedule::{model_cost, model_cost_batched};
use crate::coordinator::workload::{Request, Workload};
use crate::energy;
use crate::metrics;
use crate::model::{Mode, ModelConfig};
use crate::sim::KernelCost;

/// Admission limits for the serving loop.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum concurrently decoding requests (batch slots).
    pub max_batch: usize,
    /// HBM bytes available for KV caches (platform capacity minus
    /// resident weights).
    pub kv_budget_bytes: u64,
}

/// Per-request serving outcome.
#[derive(Debug, Clone)]
pub struct RequestStats {
    pub id: usize,
    pub prompt_len: u64,
    pub gen_tokens: u64,
    /// Arrival -> admission (queue wait), seconds.
    pub admitted_s: f64,
    /// Arrival -> first generated token, seconds.
    pub ttft_s: f64,
    /// Arrival -> last generated token, seconds.
    pub latency_s: f64,
}

/// Everything the serving run reports.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub model: String,
    pub format: &'static str,
    /// Requests offered / completed; ids rejected because a single KV
    /// cache exceeds the whole budget.
    pub requests: usize,
    pub completed: usize,
    pub rejected: Vec<usize>,
    pub max_batch: usize,
    pub kv_budget_bytes: u64,
    /// High-water mark of admitted KV bytes (must stay <= budget).
    pub peak_kv_bytes: u64,
    pub total_cycles: u64,
    pub total_seconds: f64,
    pub prefill_tokens: u64,
    pub gen_tokens: u64,
    pub ttft_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    /// Aggregate generated tokens / total wall-clock.
    pub tokens_per_s: f64,
    /// Generated tokens / decode-only wall-clock.
    pub decode_tokens_per_s: f64,
    /// Mean decode batch occupancy (tokens per decode step).
    pub avg_batch_occupancy: f64,
    pub fpu_utilization: f64,
    pub power_w: f64,
    pub hbm_gb: f64,
    pub per_request: Vec<RequestStats>,
}

struct ActiveRequest {
    req: Request,
    kv_len: u64,
    produced: u64,
    admitted_cycle: u64,
    ttft_cycle: Option<u64>,
}

/// Prices a serving trace over one model/platform/precision.
pub struct ContinuousBatcher<'a> {
    pub cfg: &'a ModelConfig,
    pub platform: &'a PlatformConfig,
    pub fmt: FpFormat,
    pub opts: BatcherConfig,
}

impl<'a> ContinuousBatcher<'a> {
    pub fn new(
        cfg: &'a ModelConfig,
        platform: &'a PlatformConfig,
        fmt: FpFormat,
        opts: BatcherConfig,
    ) -> ContinuousBatcher<'a> {
        ContinuousBatcher { cfg, platform, fmt, opts }
    }

    /// Run the whole workload to completion (all requests arrive at t=0)
    /// and return the priced serving report.
    pub fn run(&self, workload: &Workload) -> ServeReport {
        let max_batch = self.opts.max_batch.max(1);
        let budget = self.opts.kv_budget_bytes;

        let mut rejected = Vec::new();
        let mut pending: VecDeque<Request> = VecDeque::new();
        for r in &workload.requests {
            if r.kv_bytes_at(self.cfg, self.fmt) > budget {
                rejected.push(r.id);
            } else {
                pending.push_back(r.clone());
            }
        }

        let mut active: Vec<ActiveRequest> = Vec::new();
        let mut used_kv: u64 = 0;
        let mut peak_kv: u64 = 0;
        let mut time: u64 = 0;
        let mut total = KernelCost::default();
        let mut decode_cycles: u64 = 0;
        let mut decode_tokens: u64 = 0;
        let mut decode_steps: u64 = 0;
        let mut prefill_tokens: u64 = 0;
        let mut done: Vec<RequestStats> = Vec::new();

        loop {
            // ---- admission + prefill --------------------------------
            while active.len() < max_batch {
                let Some(front) = pending.front() else { break };
                let need = front.kv_bytes_at(self.cfg, self.fmt);
                if used_kv + need > budget {
                    break; // FCFS: wait for retirements to free KV space
                }
                let req = pending.pop_front().unwrap();
                used_kv += need;
                peak_kv = peak_kv.max(used_kv);
                let admitted_cycle = time;
                let prefill = model_cost(
                    self.cfg,
                    Mode::Nar,
                    req.prompt_len,
                    self.fmt,
                    self.platform,
                )
                .total;
                time += prefill.cycles;
                total = total.then(prefill);
                prefill_tokens += req.prompt_len;
                if req.gen_tokens == 0 {
                    // Prefill-only request: done at prefill completion.
                    used_kv -= need;
                    done.push(self.stats(&req, admitted_cycle, time, time));
                    continue;
                }
                active.push(ActiveRequest {
                    kv_len: req.prompt_len,
                    produced: 0,
                    admitted_cycle,
                    ttft_cycle: None,
                    req,
                });
            }

            if active.is_empty() {
                // Pending must be empty too: with no active requests the
                // whole budget is free and single-request overflows were
                // rejected upfront, so the admission loop above drains the
                // queue. Guard against a scheduling bug hanging the loop.
                debug_assert!(pending.is_empty());
                break;
            }

            // ---- one batched decode step ----------------------------
            let b = active.len() as u64;
            let kv = active.iter().map(|a| a.kv_len).max().unwrap();
            let step =
                model_cost_batched(self.cfg, Mode::Ar, b, kv, self.fmt, self.platform)
                    .total;
            time += step.cycles;
            total = total.then(step);
            decode_cycles += step.cycles;
            decode_tokens += b;
            decode_steps += 1;

            let mut i = 0;
            while i < active.len() {
                let a = &mut active[i];
                a.kv_len += 1;
                a.produced += 1;
                if a.ttft_cycle.is_none() {
                    a.ttft_cycle = Some(time);
                }
                if a.produced >= a.req.gen_tokens {
                    let a = active.swap_remove(i);
                    used_kv -= a.req.kv_bytes_at(self.cfg, self.fmt);
                    let ttft = a.ttft_cycle.unwrap_or(time);
                    done.push(self.stats(&a.req, a.admitted_cycle, ttft, time));
                } else {
                    i += 1;
                }
            }
        }

        self.report(
            workload, rejected, done, total, time, decode_cycles, decode_tokens,
            decode_steps, prefill_tokens, peak_kv,
        )
    }

    fn stats(
        &self,
        req: &Request,
        admitted_cycle: u64,
        ttft_cycle: u64,
        done_cycle: u64,
    ) -> RequestStats {
        let s = |c| self.platform.cycles_to_seconds(c);
        RequestStats {
            id: req.id,
            prompt_len: req.prompt_len,
            gen_tokens: req.gen_tokens,
            admitted_s: s(admitted_cycle),
            ttft_s: s(ttft_cycle),
            latency_s: s(done_cycle),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn report(
        &self,
        workload: &Workload,
        rejected: Vec<usize>,
        mut done: Vec<RequestStats>,
        total: KernelCost,
        time: u64,
        decode_cycles: u64,
        decode_tokens: u64,
        decode_steps: u64,
        prefill_tokens: u64,
        peak_kv: u64,
    ) -> ServeReport {
        done.sort_by_key(|r| r.id);
        // TTFT is defined over generated tokens: prefill-only requests
        // (gen_tokens == 0) never produce one, so they are excluded from
        // the TTFT aggregates (their per-request ttft_s equals prefill
        // completion).
        let ttfts: Vec<f64> =
            done.iter().filter(|r| r.gen_tokens > 0).map(|r| r.ttft_s).collect();
        let lats: Vec<f64> = done.iter().map(|r| r.latency_s).collect();
        let total_seconds = self.platform.cycles_to_seconds(time);
        let decode_seconds = self.platform.cycles_to_seconds(decode_cycles);
        let gen_tokens: u64 = done.iter().map(|r| r.gen_tokens).sum();
        let power = energy::power_report(&total, self.fmt, self.platform);
        ServeReport {
            model: self.cfg.name.clone(),
            format: self.fmt.name(),
            requests: workload.len(),
            completed: done.len(),
            rejected,
            max_batch: self.opts.max_batch.max(1),
            kv_budget_bytes: self.opts.kv_budget_bytes,
            peak_kv_bytes: peak_kv,
            total_cycles: time,
            total_seconds,
            prefill_tokens,
            gen_tokens,
            ttft_mean_s: metrics::mean(&ttfts),
            ttft_p50_s: metrics::percentile(&ttfts, 50.0),
            ttft_p99_s: metrics::percentile(&ttfts, 99.0),
            latency_mean_s: metrics::mean(&lats),
            latency_p50_s: metrics::percentile(&lats, 50.0),
            latency_p99_s: metrics::percentile(&lats, 99.0),
            tokens_per_s: if total_seconds > 0.0 {
                gen_tokens as f64 / total_seconds
            } else {
                0.0
            },
            decode_tokens_per_s: if decode_seconds > 0.0 {
                decode_tokens as f64 / decode_seconds
            } else {
                0.0
            },
            avg_batch_occupancy: if decode_steps > 0 {
                decode_tokens as f64 / decode_steps as f64
            } else {
                0.0
            },
            fpu_utilization: power.fpu_utilization,
            power_w: power.power_w,
            hbm_gb: total.hbm_bytes() as f64 / 1e9,
            per_request: done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_batcher(
        cfg: &ModelConfig,
        platform: &PlatformConfig,
        max_batch: usize,
        budget: u64,
    ) -> ServeReport {
        let b = ContinuousBatcher::new(
            cfg,
            platform,
            FpFormat::Fp32,
            BatcherConfig { max_batch, kv_budget_bytes: budget },
        );
        b.run(&Workload::uniform(6, 16, 8))
    }

    #[test]
    fn completes_all_requests() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let budget = Request { id: 0, prompt_len: 16, gen_tokens: 8 }.kv_bytes(&cfg) * 3;
        let r = tiny_batcher(&cfg, &p, 4, budget);
        assert_eq!(r.completed, 6);
        assert!(r.rejected.is_empty());
        assert!(r.tokens_per_s > 0.0);
        assert_eq!(r.gen_tokens, 6 * 8);
        assert_eq!(r.prefill_tokens, 6 * 16);
    }

    #[test]
    fn kv_budget_is_never_exceeded() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let one = Request { id: 0, prompt_len: 16, gen_tokens: 8 }.kv_bytes(&cfg);
        // Budget for exactly two concurrent caches, batch slots for four.
        let r = tiny_batcher(&cfg, &p, 4, 2 * one);
        assert_eq!(r.completed, 6);
        assert!(r.peak_kv_bytes <= 2 * one, "{} > {}", r.peak_kv_bytes, 2 * one);
        assert!(r.avg_batch_occupancy <= 2.0 + 1e-9);
    }

    #[test]
    fn oversized_request_rejected_not_wedged() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let mut w = Workload::uniform(2, 16, 8);
        w.requests.push(Request { id: 2, prompt_len: 100_000, gen_tokens: 8 });
        let budget = w.requests[0].kv_bytes(&cfg) * 4;
        let b = ContinuousBatcher::new(
            &cfg,
            &p,
            FpFormat::Fp32,
            BatcherConfig { max_batch: 4, kv_budget_bytes: budget },
        );
        let r = b.run(&w);
        assert_eq!(r.completed, 2);
        assert_eq!(r.rejected, vec![2]);
    }

    #[test]
    fn latency_ordering_sane() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let budget = Request { id: 0, prompt_len: 16, gen_tokens: 8 }.kv_bytes(&cfg) * 8;
        let r = tiny_batcher(&cfg, &p, 8, budget);
        for s in &r.per_request {
            assert!(s.admitted_s <= s.ttft_s, "{s:?}");
            assert!(s.ttft_s <= s.latency_s, "{s:?}");
        }
        assert!(r.ttft_p50_s <= r.ttft_p99_s);
        assert!(r.latency_p50_s <= r.latency_p99_s);
        assert!(r.latency_mean_s <= r.total_seconds);
        // Decode-only throughput excludes prefill stalls, so it can only
        // be faster than the end-to-end rate.
        assert!(r.decode_tokens_per_s >= r.tokens_per_s);
    }

    #[test]
    fn prefill_only_requests_excluded_from_ttft_aggregates() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let mut w = Workload::uniform(2, 16, 4);
        w.requests.push(Request { id: 2, prompt_len: 16, gen_tokens: 0 });
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let b = ContinuousBatcher::new(
            &cfg,
            &p,
            FpFormat::Fp32,
            BatcherConfig { max_batch: 1, kv_budget_bytes: budget },
        );
        let r = b.run(&w);
        assert_eq!(r.completed, 3);
        // Serial admission (max_batch 1) finishes the prefill-only
        // request last, so including it would inflate p99; the TTFT
        // percentiles must cover only the two generating requests.
        let max_gen_ttft = r
            .per_request
            .iter()
            .filter(|s| s.gen_tokens > 0)
            .map(|s| s.ttft_s)
            .fold(0.0, f64::max);
        assert_eq!(r.ttft_p99_s, max_gen_ttft);
        assert!(r.ttft_mean_s <= max_gen_ttft);
    }

    #[test]
    fn bigger_batch_serves_faster() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(8, 16, 16);
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let serial = ContinuousBatcher::new(
            &cfg, &p, FpFormat::Fp32,
            BatcherConfig { max_batch: 1, kv_budget_bytes: budget },
        )
        .run(&w);
        let batched = ContinuousBatcher::new(
            &cfg, &p, FpFormat::Fp32,
            BatcherConfig { max_batch: 8, kv_budget_bytes: budget },
        )
        .run(&w);
        assert!(
            batched.total_seconds < serial.total_seconds,
            "batched {} vs serial {}",
            batched.total_seconds,
            serial.total_seconds
        );
        assert!(batched.tokens_per_s > serial.tokens_per_s);
        assert!(batched.avg_batch_occupancy > serial.avg_batch_occupancy);
    }
}
