//! Run configuration: platform + model + run parameters from a
//! TOML-subset file (see `util::minitoml`), merged with CLI overrides.

use std::path::Path;

use anyhow::{Context, Result};

use crate::arch::{Features, FpFormat, PlatformConfig};
use crate::model::{Mode, ModelConfig};
use crate::util::minitoml::{self, Doc};

/// A complete run description.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub platform: PlatformSection,
    pub model: ModelSection,
    pub run: RunSection,
}

#[derive(Debug, Clone)]
pub struct PlatformSection {
    /// Total clusters (1-4 or a multiple of 4).
    pub clusters: u32,
    pub xssr: bool,
    pub xfrep: bool,
    pub simd: bool,
    pub cluster_to_cluster: bool,
    pub double_buffering: bool,
    pub freq_ghz: f64,
}

impl Default for PlatformSection {
    fn default() -> Self {
        PlatformSection {
            clusters: 16,
            xssr: true,
            xfrep: true,
            simd: true,
            cluster_to_cluster: true,
            double_buffering: true,
            freq_ghz: 1.0,
        }
    }
}

impl PlatformSection {
    fn from_doc(doc: &Doc) -> PlatformSection {
        let d = PlatformSection::default();
        PlatformSection {
            clusters: minitoml::get_u64(doc, "platform", "clusters")
                .map(|v| v as u32)
                .unwrap_or(d.clusters),
            xssr: minitoml::get_bool(doc, "platform", "xssr").unwrap_or(d.xssr),
            xfrep: minitoml::get_bool(doc, "platform", "xfrep").unwrap_or(d.xfrep),
            simd: minitoml::get_bool(doc, "platform", "simd").unwrap_or(d.simd),
            cluster_to_cluster: minitoml::get_bool(doc, "platform", "cluster_to_cluster")
                .unwrap_or(d.cluster_to_cluster),
            double_buffering: minitoml::get_bool(doc, "platform", "double_buffering")
                .unwrap_or(d.double_buffering),
            freq_ghz: minitoml::get_f64(doc, "platform", "freq_ghz").unwrap_or(d.freq_ghz),
        }
    }

    pub fn to_platform(&self) -> PlatformConfig {
        let mut p = PlatformConfig::with_clusters(self.clusters);
        p.freq_ghz = self.freq_ghz;
        p.features = Features {
            xssr: self.xssr,
            xfrep: self.xfrep,
            simd: self.simd,
            cluster_to_cluster: self.cluster_to_cluster,
            double_buffering: self.double_buffering,
        };
        p
    }
}

#[derive(Debug, Clone, Default)]
pub struct ModelSection {
    pub preset: Option<String>,
    pub blocks: Option<u64>,
    pub e: Option<u64>,
    pub p: Option<u64>,
    pub heads: Option<u64>,
    pub ff: Option<u64>,
}

impl ModelSection {
    fn from_doc(doc: &Doc) -> ModelSection {
        ModelSection {
            preset: minitoml::get_str(doc, "model", "preset").map(String::from),
            blocks: minitoml::get_u64(doc, "model", "blocks"),
            e: minitoml::get_u64(doc, "model", "e"),
            p: minitoml::get_u64(doc, "model", "p"),
            heads: minitoml::get_u64(doc, "model", "heads"),
            ff: minitoml::get_u64(doc, "model", "ff"),
        }
    }

    pub fn to_model(&self) -> Result<ModelConfig> {
        let mut cfg = match &self.preset {
            Some(name) => ModelConfig::preset(name)
                .ok_or_else(|| anyhow::anyhow!("unknown model preset: {name}"))?,
            None => ModelConfig::tiny(),
        };
        if let Some(b) = self.blocks {
            cfg.blocks = b;
        }
        if let Some(e) = self.e {
            cfg.e = e;
        }
        if let Some(p) = self.p {
            cfg.p = p;
        }
        if let Some(h) = self.heads {
            cfg.heads = h;
        }
        if let Some(ff) = self.ff {
            cfg.ff = ff;
        }
        Ok(cfg)
    }
}

#[derive(Debug, Clone)]
pub struct RunSection {
    pub mode: String,
    pub format: String,
    pub seq: u64,
}

impl Default for RunSection {
    fn default() -> Self {
        RunSection { mode: "nar".into(), format: "fp32".into(), seq: 0 }
    }
}

impl RunSection {
    fn from_doc(doc: &Doc) -> RunSection {
        let d = RunSection::default();
        RunSection {
            mode: minitoml::get_str(doc, "run", "mode").map(String::from).unwrap_or(d.mode),
            format: minitoml::get_str(doc, "run", "format")
                .map(String::from)
                .unwrap_or(d.format),
            seq: minitoml::get_u64(doc, "run", "seq").unwrap_or(d.seq),
        }
    }

    pub fn mode(&self) -> Result<Mode> {
        parse_mode(&self.mode)
    }

    pub fn format(&self) -> Result<FpFormat> {
        FpFormat::parse(&self.format)
            .ok_or_else(|| anyhow::anyhow!("unknown format: {}", self.format))
    }
}

/// Parse "nar" | "ar".
pub fn parse_mode(s: &str) -> Result<Mode> {
    match s {
        "nar" => Ok(Mode::Nar),
        "ar" => Ok(Mode::Ar),
        other => anyhow::bail!("unknown mode: {other} (want nar|ar)"),
    }
}

/// Parse a config from TOML text.
pub fn parse(text: &str) -> Result<RunConfig> {
    let doc = minitoml::parse(text)?;
    Ok(RunConfig {
        platform: PlatformSection::from_doc(&doc),
        model: ModelSection::from_doc(&doc),
        run: RunSection::from_doc(&doc),
    })
}

/// Load a TOML run config from disk.
pub fn load(path: &Path) -> Result<RunConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing config {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let cfg = parse(
            r#"
            [platform]
            clusters = 8
            xssr = false
            [model]
            preset = "gpt-j"
            [run]
            mode = "ar"
            format = "fp8"
            seq = 2048
            "#,
        )
        .unwrap();
        let p = cfg.platform.to_platform();
        assert_eq!(p.total_clusters(), 8);
        assert!(!p.features.xssr);
        assert!(p.features.xfrep); // default preserved
        let m = cfg.model.to_model().unwrap();
        assert_eq!(m.name, "gpt-j");
        assert_eq!(cfg.run.mode().unwrap(), Mode::Ar);
        assert_eq!(cfg.run.format().unwrap(), FpFormat::Fp8);
        assert_eq!(cfg.run.seq, 2048);
    }

    #[test]
    fn minimal_config_defaults() {
        let cfg = parse("[model]\npreset = \"vit-b\"\n").unwrap();
        assert_eq!(cfg.platform.clusters, 16);
        assert_eq!(cfg.run.mode().unwrap(), Mode::Nar);
        assert_eq!(cfg.run.format().unwrap(), FpFormat::Fp32);
    }

    #[test]
    fn model_overrides() {
        let cfg = parse("[model]\npreset = \"tiny\"\nblocks = 7\nff = 99\n").unwrap();
        let m = cfg.model.to_model().unwrap();
        assert_eq!(m.blocks, 7);
        assert_eq!(m.ff, 99);
        assert_eq!(m.e, 64); // from preset
    }

    #[test]
    fn bad_preset_errors() {
        let cfg = parse("[model]\npreset = \"nope\"\n").unwrap();
        assert!(cfg.model.to_model().is_err());
    }
}
