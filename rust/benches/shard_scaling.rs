//! Multi-die sharding bench — the parallelism-subsystem acceptance sweep.
//!
//! Four claims defended here:
//!
//! 1. Collective pricing is sane: the ring all-reduce undercuts the
//!    binary tree on large payloads (bandwidth-bound) and loses on small
//!    ones (latency-bound); `Auto` always picks the winner.
//! 2. The planner's two objectives pull apart: latency picks a
//!    tensor-parallel plan (the decode weight stream splits across
//!    dies), throughput picks full data parallelism (replica scaling
//!    pays no collective tax) — and both beat the single-engine plan on
//!    their own metric.
//! 3. On a heavy open-loop Poisson trace, serving the planner-selected
//!    throughput plan through the replica router achieves strictly
//!    higher aggregate tokens/s than the single-engine baseline.
//! 4. On a shared-prefix trace, prefix-affinity routing beats
//!    join-shortest-queue on prefix-cache hit rate (JSQ splits template
//!    groups across dies; affinity keeps them on their home replica).
//! 5. Shard plans now EXECUTE through the batcher: on the same two dies,
//!    a served tp=2 engine pays a visible collective tax (nonzero
//!    d2d/collective cycles in its report) but cuts per-token decode
//!    latency, while two data-parallel replicas buy aggregate tokens/s —
//!    the serving-level version of the planner's latency/throughput
//!    split, emitted as `BENCH_shard_serving.json`.
//!
//! `BENCH_SMOKE=1` shrinks the traces; with `BENCH_JSON_DIR` set the
//! results land in `BENCH_shard_scaling.json` / `BENCH_shard_serving.json`
//! for the CI trend comparison (`scripts/bench_trend.py` seeds the
//! baseline on the first run).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, InferenceEngine, Workload};
use snitch_fm::model::{Mode, ModelConfig};
use snitch_fm::parallel::{
    all_reduce_cost, best_plans, Algorithm, Objective, RoutePolicy, ShardPlan,
};
use snitch_fm::report;

fn main() {
    let gpt = ModelConfig::gpt_j();
    let fmt = FpFormat::Fp8;
    let n = if common::smoke() { 16 } else { 40 };
    let mut json = Vec::new();

    // ---- Claim 1: ring vs tree collective pricing across die counts.
    common::header("collectives", "GPT-J all-reduce, ring vs tree, d2d links");
    println!(
        "{:<8} {:>10} {:>12} {:>12} {:>8}",
        "dies", "payload", "ring cyc", "tree cyc", "auto"
    );
    for dies in [2u32, 4, 8] {
        let p = PlatformConfig::with_dies(dies);
        let ranks: Vec<u32> = (0..dies).collect();
        // Decode activation (b=8 x E, latency-bound) and prefill
        // activation (512 x E, bandwidth-bound).
        for payload in [8 * gpt.e * fmt.bytes(), 512 * gpt.e * fmt.bytes()] {
            let ring = all_reduce_cost(payload, &ranks, Algorithm::Ring, fmt, &p);
            let tree = all_reduce_cost(payload, &ranks, Algorithm::Tree, fmt, &p);
            let auto = all_reduce_cost(payload, &ranks, Algorithm::Auto, fmt, &p);
            assert_eq!(auto.cycles, ring.cycles.min(tree.cycles), "auto picks the winner");
            println!(
                "{:<8} {:>10} {:>12} {:>12} {:>8}",
                dies,
                payload,
                ring.cycles,
                tree.cycles,
                if auto.cycles == ring.cycles { "ring" } else { "tree" }
            );
        }
    }
    let p8 = PlatformConfig::with_dies(8);
    let ranks8: Vec<u32> = (0..8).collect();
    let big = 512 * gpt.e * fmt.bytes();
    let ring = all_reduce_cost(big, &ranks8, Algorithm::Ring, fmt, &p8);
    let tree = all_reduce_cost(big, &ranks8, Algorithm::Tree, fmt, &p8);
    assert!(ring.cycles < tree.cycles, "large payloads are bandwidth-bound");

    // ---- Claim 2: planner objectives on 4 dies.
    let dies = 4u32;
    let platform = PlatformConfig::with_dies(dies);
    let (t_plan, by_thr) = common::time_median(3, || {
        best_plans(&gpt, fmt, &platform, Mode::Ar, 8, 1024, Objective::Throughput)
    });
    let by_lat = best_plans(&gpt, fmt, &platform, Mode::Ar, 8, 1024, Objective::Latency);
    common::header("planner", "GPT-J FP8 AR b=8 S=1024 on 4 dies");
    print!("{}", report::shard_table("by throughput:", &by_thr[..by_thr.len().min(5)]));
    print!("{}", report::shard_table("by latency:", &by_lat[..by_lat.len().min(5)]));
    common::report_timing("plan-enumeration", t_plan);
    let single_thr = by_thr
        .iter()
        .find(|r| r.plan == ShardPlan::single())
        .expect("single plan enumerated");
    assert_eq!(by_thr[0].plan, ShardPlan { tp: 1, pp: 1, replicas: 4 });
    assert!(by_thr[0].cost.tokens_per_s > single_thr.cost.tokens_per_s);
    assert!(by_lat[0].plan.tp > 1, "latency plan must shard the weight stream");

    // ---- Claim 3: router throughput on a heavy open-loop trace.
    let e = InferenceEngine::new(platform.clone());
    let heavy = Workload::synthetic(11, n, (48, 160), (8, 24))
        .with_poisson_arrivals(13, 20.0);
    let opts = BatcherConfig::new(8, 0);
    let single = e.serve_with(&gpt, &heavy, opts, fmt);
    let replicas = by_thr[0].plan.replicas as usize;
    let fleet = e.serve_replicated(
        &gpt,
        &heavy,
        opts,
        fmt,
        replicas,
        RoutePolicy::JoinShortestQueue,
    );
    common::header(
        "router",
        "GPT-J FP8, heavy poisson 20/s trace, single engine vs planner plan",
    );
    println!(
        "{:<16} {:>10} {:>10} {:>10}",
        "config", "tokens/s", "ttftP99", "seconds"
    );
    for (label, tok, ttft, secs) in [
        ("single", single.tokens_per_s, single.ttft_p99_s, single.total_seconds),
        (
            "router-jsq-4x",
            fleet.merged.tokens_per_s,
            fleet.merged.ttft_p99_s,
            fleet.merged.total_seconds,
        ),
    ] {
        println!("{label:<16} {tok:>10.2} {ttft:>10.3} {secs:>10.3}");
    }
    assert_eq!(single.completed, n);
    assert_eq!(fleet.merged.completed, n);
    assert_eq!(fleet.merged.gen_tokens, single.gen_tokens, "same service delivered");
    assert!(
        fleet.merged.tokens_per_s > single.tokens_per_s,
        "the planner-selected plan must beat the single engine on aggregate \
         tokens/s: {} !> {}",
        fleet.merged.tokens_per_s,
        single.tokens_per_s
    );
    json.push(format!(
        "{{\"config\":\"single-engine\",\"report\":{}}}",
        report::serve_json(&single)
    ));
    json.push(format!(
        "{{\"config\":\"router-jsq-{replicas}x\",\"report\":{}}}",
        report::serve_json(&fleet.merged)
    ));

    // ---- Claim 4: prefix-affinity routing on a shared-prefix trace.
    // Fanout 4 on 4 dies: each group's members arrive back to back, so
    // JSQ deals them one per replica (no sharing anywhere) while
    // affinity keeps every group on its template's home replica.
    let shared = Workload::synthetic(11, n, (48, 160), (8, 24))
        .with_shared_prefix(1024, 4)
        .with_poisson_arrivals(13, 2.0);
    let jsq = e.serve_replicated(
        &gpt,
        &shared,
        opts,
        fmt,
        replicas,
        RoutePolicy::JoinShortestQueue,
    );
    let aff = e.serve_replicated(
        &gpt,
        &shared,
        opts,
        fmt,
        replicas,
        RoutePolicy::PrefixAffinity,
    );
    common::header(
        "affinity",
        "GPT-J FP8, 1024-token shared prefixes x4, jsq vs prefix-affinity",
    );
    println!(
        "{:<12} {:>10} {:>10} {:>12} {:>10}",
        "policy", "tokens/s", "hit rate", "late hits", "ttftP99"
    );
    for (label, r) in [("jsq", &jsq.merged), ("affinity", &aff.merged)] {
        println!(
            "{label:<12} {:>10.2} {:>9.1}% {:>12} {:>10.3}",
            r.tokens_per_s,
            r.prefix_hit_rate * 100.0,
            r.prefix_late_hits,
            r.ttft_p99_s,
        );
    }
    assert_eq!(jsq.merged.completed, n);
    assert_eq!(aff.merged.completed, n);
    assert!(
        aff.merged.prefix_hit_rate > jsq.merged.prefix_hit_rate,
        "prefix-affinity must beat JSQ on hit rate: {} !> {}",
        aff.merged.prefix_hit_rate,
        jsq.merged.prefix_hit_rate
    );
    json.push(format!(
        "{{\"config\":\"shared-prefix-jsq\",\"report\":{}}}",
        report::serve_json(&jsq.merged)
    ));
    json.push(format!(
        "{{\"config\":\"shared-prefix-affinity\",\"report\":{}}}",
        report::serve_json(&aff.merged)
    ));

    common::write_bench_json("shard_scaling", &format!("[{}]", json.join(",")));

    // ---- Claim 5: served TP vs replication on the same two dies.
    let p2 = PlatformConfig::with_dies(2);
    let e2 = InferenceEngine::new(p2);
    let trace = Workload::synthetic(17, n, (48, 160), (8, 24))
        .with_poisson_arrivals(19, 10.0);
    let single = e2.serve_with(&gpt, &trace, opts, fmt);
    let mut tp_opts = opts;
    tp_opts.plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
    let served_tp = e2.serve_with(&gpt, &trace, tp_opts, fmt);
    let replicated =
        e2.serve_replicated(&gpt, &trace, opts, fmt, 2, RoutePolicy::JoinShortestQueue);
    common::header(
        "shard-serving",
        "GPT-J FP8, poisson 10/s trace: 1 die vs served tp=2 vs 2 replicas",
    );
    println!(
        "{:<14} {:>10} {:>10} {:>12} {:>12}",
        "config", "tokens/s", "ttftP99", "coll Mcyc", "d2d GB"
    );
    for (label, r) in [
        ("single-die", &single),
        ("served-tp2", &served_tp),
        ("replicas-2x", &replicated.merged),
    ] {
        println!(
            "{label:<14} {:>10.2} {:>10.3} {:>12.3} {:>12.3}",
            r.tokens_per_s,
            r.ttft_p99_s,
            r.collective_cycles as f64 / 1e6,
            r.d2d_bytes as f64 / 1e9,
        );
    }
    assert_eq!(single.completed, n);
    assert_eq!(served_tp.completed, n);
    assert_eq!(replicated.merged.completed, n);
    assert_eq!(served_tp.gen_tokens, single.gen_tokens, "same service delivered");
    assert!(
        served_tp.collective_cycles > 0 && served_tp.d2d_bytes > 0,
        "executed TP must charge its all-reduces"
    );
    assert_eq!(single.collective_cycles, 0, "the single die pays no TP tax");
    assert!(
        served_tp.decode_tokens_per_s > single.decode_tokens_per_s,
        "splitting the decode weight stream must outrun the collective tax: \
         {} !> {}",
        served_tp.decode_tokens_per_s,
        single.decode_tokens_per_s
    );
    let serving_json = format!(
        "[{{\"config\":\"single-die\",\"report\":{}}},\
         {{\"config\":\"served-tp2\",\"report\":{}}},\
         {{\"config\":\"replicas-2x\",\"report\":{}}}]",
        report::serve_json(&single),
        report::serve_json(&served_tp),
        report::serve_json(&replicated.merged)
    );
    common::write_bench_json("shard_serving", &serving_json);
}
