//! Shared mini-harness for the paper-reproduction benches.
//!
//! criterion is unavailable in the offline registry, so each bench is a
//! plain `fn main` that (a) regenerates one paper table/figure from the
//! simulator and prints it side-by-side with the paper's numbers, and
//! (b) wall-clock-times the simulator hot path driving it (median of N
//! runs) so `cargo bench` still tracks performance regressions.

use std::time::Instant;

/// Median wall-clock seconds of `f` over `n` runs (after one warmup).
pub fn time_median<T>(n: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut out = f(); // warmup
    let mut samples: Vec<f64> = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        out = f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[samples.len() / 2], out)
}

/// Print a bench timing line in a stable grep-able format.
pub fn report_timing(name: &str, seconds: f64) {
    println!("bench-timing {name}: {:.3} ms/iter", seconds * 1e3);
}

/// Print the paper-vs-measured header for a figure/table.
pub fn header(id: &str, what: &str) {
    println!("==== {id}: {what} ====");
}

/// Whether the bench should run its reduced CI-smoke configuration
/// (`BENCH_SMOKE=1`, set by the CI bench-smoke job).
#[allow(dead_code)]
pub fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some_and(|v| !v.is_empty() && v != "0")
}

/// Write a bench's JSON results to `$BENCH_JSON_DIR/BENCH_<name>.json`
/// when `BENCH_JSON_DIR` is set (the CI job uploads these as workflow
/// artifacts, seeding the perf-trajectory record). A no-op otherwise.
#[allow(dead_code)]
pub fn write_bench_json(name: &str, json: &str) {
    let Some(dir) = std::env::var_os("BENCH_JSON_DIR") else { return };
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    match std::fs::write(&path, json) {
        Ok(()) => println!("bench-json written: {}", path.display()),
        Err(e) => eprintln!("bench-json write failed ({}): {e}", path.display()),
    }
}
