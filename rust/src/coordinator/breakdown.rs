//! Kernel latency breakdown (paper Fig. 10).

use crate::model::LayerKind;

use super::schedule::ModelCost;

/// One kernel class' share of the total latency.
#[derive(Debug, Clone)]
pub struct KernelClassShare {
    pub kind: &'static str,
    pub cycles: u64,
    pub fraction: f64,
}

/// Latency breakdown of a model pass by kernel class.
#[derive(Debug, Clone)]
pub struct Breakdown {
    pub shares: Vec<KernelClassShare>,
    pub total_cycles: u64,
}

impl Breakdown {
    /// Build from a priced model cost, ordered by descending share.
    pub fn from_cost(mc: &ModelCost) -> Breakdown {
        let mut shares: Vec<KernelClassShare> = mc
            .by_kind
            .iter()
            .map(|(kind, cost)| KernelClassShare {
                kind: kind.name(),
                cycles: cost.cycles,
                fraction: if mc.total.cycles > 0 {
                    cost.cycles as f64 / mc.total.cycles as f64
                } else {
                    0.0
                },
            })
            .collect();
        shares.sort_by(|a, b| b.cycles.cmp(&a.cycles));
        Breakdown { shares, total_cycles: mc.total.cycles }
    }

    /// Fraction for a class name ("gemm", "flashattention", ...), 0 if absent.
    pub fn fraction(&self, kind: LayerKind) -> f64 {
        self.shares
            .iter()
            .find(|s| s.kind == kind.name())
            .map(|s| s.fraction)
            .unwrap_or(0.0)
    }

    /// Combined share of the GEMM-like classes (plain + fused concat
    /// linear), the paper's "GEMM" bucket in Fig. 10.
    pub fn gemm_fraction(&self) -> f64 {
        self.fraction(LayerKind::Gemm) + self.fraction(LayerKind::FusedConcatLinear)
    }

    /// Activation bucket (LayerNorm + GELU).
    pub fn activation_fraction(&self) -> f64 {
        self.fraction(LayerKind::Layernorm) + self.fraction(LayerKind::Gelu)
    }

    /// Fig. 10's exact buckets, built from per-label costs: the paper
    /// instruments at MHA-macro-block granularity, so its
    /// "FlashAttention-2" bar covers QKV projections + attention + fused
    /// out-projection, while "GEMM" is the MLP linears. (The GPT-J FP32
    /// NAR split of 66% GEMM then follows directly from the flop ratio
    /// MLP : MHA = 275G : 154G per block.)
    pub fn fig10_buckets(mc: &ModelCost) -> Vec<KernelClassShare> {
        let total = mc.total.cycles.max(1);
        let sum = |labels: &[&str]| -> u64 {
            labels
                .iter()
                .filter_map(|l| mc.by_label.get(l).map(|c| c.cycles))
                .sum()
        };
        let buckets = [
            ("gemm (mlp)", sum(&["mlp-up", "mlp-down"])),
            (
                "flashattention-2 (mha)",
                sum(&["q-proj", "k-proj", "v-proj", "attention", "out-proj"]),
            ),
            ("layernorm", sum(&["ln1", "ln2"])),
            ("gelu", sum(&["gelu"])),
        ];
        buckets
            .iter()
            .map(|&(kind, cycles)| KernelClassShare {
                kind: match kind {
                    "gemm (mlp)" => "gemm (mlp)",
                    "flashattention-2 (mha)" => "flashattention-2 (mha)",
                    "layernorm" => "layernorm",
                    _ => "gelu",
                },
                cycles,
                fraction: cycles as f64 / total as f64,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{FpFormat, PlatformConfig};
    use crate::coordinator::schedule::model_cost;
    use crate::model::{Mode, ModelConfig};

    #[test]
    fn shares_sum_to_one() {
        let mc = model_cost(
            &ModelConfig::gpt_j(),
            Mode::Nar,
            1024,
            FpFormat::Fp32,
            &PlatformConfig::occamy(),
        );
        let b = Breakdown::from_cost(&mc);
        let sum: f64 = b.shares.iter().map(|s| s.fraction).sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
        assert!(b.shares.windows(2).all(|w| w[0].cycles >= w[1].cycles));
    }

    #[test]
    fn buckets_match_fig10_shape() {
        let p = PlatformConfig::occamy();
        let mc = model_cost(&ModelConfig::gpt_j(), Mode::Ar, 1024, FpFormat::Fp32, &p);
        let b = Breakdown::from_cost(&mc);
        // Fig. 10 AR FP32: GEMM ~97%.
        assert!(b.gemm_fraction() > 0.80, "gemm {}", b.gemm_fraction());
        assert!(b.activation_fraction() < 0.10);
    }
}
