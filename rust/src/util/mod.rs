//! Small in-tree utilities.
//!
//! The build environment is fully offline and the vendored registry only
//! carries `xla` + `anyhow`, so the (tiny, well-specified) formats this
//! project consumes — the `manifest.json` our own `aot.py` writes and the
//! TOML-subset run configs — are parsed by the minimal, tested parsers in
//! this module instead of serde_json/toml.

pub mod cli;
pub mod json;
pub mod minitoml;
