//! Batch scaling — the motivation for the serving coordinator. Table III
//! pins single-request AR decode below 10% FPU utilization (every token
//! is a GEMV streaming all weights from HBM for one row of work).
//! Batching b requests turns each decode GEMV into a skinny GEMM (m = b)
//! that reads the weights once per batch, so utilization must rise
//! monotonically with b and close on the NAR band (65-80%).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{InferenceEngine, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::report;

const BATCHES: [u64; 6] = [1, 2, 4, 8, 16, 32];

fn main() {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::gpt_j();
    let seq = 1024;
    let mut json_rows = Vec::new();

    common::header("batch scaling", "GPT-J batched AR decode at KV=1024");
    for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
        let legacy = e.run_ar_step(&cfg, seq, fmt);
        let (t, rows) = common::time_median(3, || {
            BATCHES
                .iter()
                .map(|&b| e.run_ar_step_batched(&cfg, b, seq, fmt))
                .collect::<Vec<_>>()
        });
        println!(
            "{:<6} {:>4} {:>14} {:>9} {:>12}",
            "fmt", "b", "tokens/s", "util%", "vs b=1"
        );
        let mut prev_util = 0.0;
        for r in &rows {
            println!(
                "{:<6} {:>4} {:>14.2} {:>9.2} {:>11.1}x",
                fmt.name(),
                r.batch,
                r.throughput,
                r.fpu_utilization * 100.0,
                r.throughput / rows[0].throughput
            );
            assert!(
                r.fpu_utilization > prev_util,
                "{fmt} b={}: utilization must rise strictly with batch ({} !> {prev_util})",
                r.batch,
                r.fpu_utilization
            );
            prev_util = r.fpu_utilization;
        }
        // b=1 must price exactly like the legacy single-request step.
        assert_eq!(rows[0].cycles, legacy.cycles, "{fmt}: b=1 diverged from run_ar_step");
        assert_eq!(rows[0].fpu_utilization, legacy.fpu_utilization);
        let nar = e.run_nar(&cfg, seq, fmt);
        println!(
            "{:<6}  NAR reference util {:.1}%; b=32 reaches {:.1}% of it\n",
            fmt.name(),
            nar.fpu_utilization * 100.0,
            100.0 * rows.last().unwrap().fpu_utilization / nar.fpu_utilization
        );
        common::report_timing(&format!("batch-sweep-{}", fmt.name()), t);
        json_rows.extend(rows);
    }

    let requests = if common::smoke() { 8 } else { 32 };
    common::header(
        "serving",
        &format!("continuous batching, {requests} requests, batch 8, FP8"),
    );
    let w = Workload::uniform(requests, 1024, 64);
    let (t, r) = common::time_median(3, || e.serve(&cfg, &w, 8, FpFormat::Fp8));
    print!("{}", report::serve_table(&r));
    common::report_timing(&format!("serve-{requests}req-b8"), t);

    common::write_bench_json(
        "batch_scaling",
        &format!(
            "{{\"sweep\":{},\"serve\":{}}}",
            report::runs_json(&json_rows),
            report::serve_json(&r)
        ),
    );
}
