//! Paged KV-cache allocation (vLLM-style PagedAttention bookkeeping) with
//! ref-counted page sharing and a content-addressed prefix cache.
//!
//! The PR-1 batcher reserved every request's *full-length* KV cache
//! (prompt + all tokens it may ever generate) at admission, so the HBM
//! budget was exhausted by reservations that mostly sat empty during
//! decode. This module carves the KV budget into fixed-size pages of
//! `page_tokens` tokens each; a request holds a [`PageTable`] of pages
//! covering exactly the tokens it has materialized so far, grows it
//! on demand one decode token at a time, and returns every page on
//! retirement (or preemption).
//!
//! On top of the pages sits *prefix caching*: every page is ref-counted,
//! so requests whose prompts share a content-identical prefix (system
//! prompt templates, shared few-shot preambles) map the **same physical
//! pages** instead of re-materializing — and, more importantly for the
//! serving numbers, skip the prefill passes for those tokens entirely.
//! The [`PrefixCache`] keys pages by a chained prompt-content hash at
//! page granularity and keeps its own reference on each cached page, so
//! a prefix outlives the request that built it; eviction is ref-count-
//! aware LRU (only pages no request maps anymore are reclaimed — evicting
//! a page something still references would free nothing). Writes never
//! land on a shared page by construction (shared pages are always full
//! prompt pages, appends go past them); [`PagedKvAllocator::ensure_private_tail`]
//! enforces that invariant locally with a copy-on-write fork.
//!
//! The allocator is pure bookkeeping — the timing model prices KV traffic
//! through the kernel costs — but its invariants are the serving
//! scheduler's safety argument: a page is never freed while referenced,
//! bytes in use never exceed the budget, and a drained allocator is
//! whole again.

use std::collections::{BTreeMap, HashMap};

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::kv_cache::KvCache;
use crate::model::ModelConfig;

/// HBM bytes left for KV caches once the model weights are resident at
/// the serving precision — zero when the weights alone exceed capacity
/// (the serve path then rejects everything rather than pretending).
/// Single source of the budget formula for `InferenceEngine` and
/// `ContinuousBatcher`.
pub fn platform_kv_budget_bytes(
    cfg: &ModelConfig,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> u64 {
    platform.interconnect.hbm_capacity_bytes.saturating_sub(cfg.weight_bytes(fmt))
}

/// Geometry of one request's KV footprint: bytes per cached token (across
/// all transformer blocks, K + V, at the pool's KV precision), the page
/// granularity, and the element format the pool stores tokens at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvGeometry {
    /// KV bytes one token occupies across every block (K and V).
    pub token_bytes: u64,
    /// Tokens per page (fixed allocation granularity).
    pub page_tokens: u64,
    /// Element format the pool stores KV tokens at. Pools with different
    /// formats cannot exchange pages byte-for-byte — migrations must
    /// requantize (see [`PagedKvAllocator::import_converting`]).
    pub format: FpFormat,
}

impl KvGeometry {
    /// Geometry for `cfg` stored at `fmt`. Exact element-count round-up
    /// math: one token holds `blocks * 2 * heads * p` elements (K and V
    /// per head per block), each `fmt.bytes()` wide — no intermediate
    /// truncating division through an f32 byte count. Consistent with
    /// [`KvCache::bytes_for`] at FP32 and with `Request::kv_bytes_at`
    /// at every format.
    pub fn new(cfg: &ModelConfig, fmt: FpFormat, page_tokens: u64) -> KvGeometry {
        let f32_token =
            cfg.blocks * KvCache::bytes_for(cfg.heads as usize, 1, cfg.p as usize) as u64;
        let elems = cfg.blocks * 2 * cfg.heads * cfg.p;
        debug_assert_eq!(f32_token, elems * std::mem::size_of::<f32>() as u64);
        KvGeometry {
            token_bytes: elems * fmt.bytes(),
            page_tokens: page_tokens.max(1),
            format: fmt,
        }
    }

    /// Bytes one page occupies.
    pub fn page_bytes(&self) -> u64 {
        self.token_bytes * self.page_tokens
    }

    /// Pages needed to hold `tokens` cached tokens.
    pub fn pages_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.page_tokens)
    }

    /// KV elements one cached token holds (format-independent:
    /// `token_bytes / format.bytes()`, exact by construction).
    pub fn elems_per_token(&self) -> u64 {
        self.token_bytes / self.format.bytes()
    }
}

/// Per-request mapping from KV positions to allocated pages. Page `i`
/// holds tokens `[i * page_tokens, (i + 1) * page_tokens)` of the
/// request's cache. With prefix sharing, leading pages may be mapped by
/// several tables at once (and by the [`PrefixCache`]).
#[derive(Debug, Clone, Default)]
pub struct PageTable {
    pages: Vec<u32>,
}

impl PageTable {
    /// An empty table mapping no pages.
    pub fn new() -> PageTable {
        PageTable::default()
    }

    /// Allocated pages, in position order.
    pub fn pages(&self) -> &[u32] {
        &self.pages
    }

    /// Number of pages mapped.
    pub fn len(&self) -> usize {
        self.pages.len()
    }

    /// Whether the table maps no pages.
    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// Tokens this table can hold.
    pub fn capacity_tokens(&self, geom: &KvGeometry) -> u64 {
        self.pages.len() as u64 * geom.page_tokens
    }
}

/// Fixed-pool page allocator over the HBM KV budget, with per-page
/// reference counts.
///
/// Pages are identified by dense `u32` ids; a never-yet-used id is handed
/// out from a cursor, pages whose last reference drops go to a recycle
/// stack. A freshly grown page is owned by exactly one [`PageTable`];
/// [`Self::share`] maps an existing page into another table and
/// [`Self::retain`] adds a table-less reference (the prefix cache's hold).
/// `in_use` counts *distinct* live pages, so shared pages bill the budget
/// once — the whole point of prefix dedup.
#[derive(Debug, Clone)]
pub struct PagedKvAllocator {
    geom: KvGeometry,
    total_pages: u64,
    next_fresh: u32,
    recycled: Vec<u32>,
    /// Reference count per page id (index). 0 = free/recycled.
    refs: Vec<u32>,
    /// Distinct pages with at least one reference.
    in_use: u64,
    peak_in_use: u64,
}

impl PagedKvAllocator {
    /// Carve `budget_bytes` into pages of `geom.page_bytes()`.
    pub fn new(budget_bytes: u64, geom: KvGeometry) -> PagedKvAllocator {
        let total_pages =
            (budget_bytes / geom.page_bytes().max(1)).min(u32::MAX as u64);
        PagedKvAllocator {
            geom,
            total_pages,
            next_fresh: 0,
            recycled: Vec::new(),
            refs: Vec::new(),
            in_use: 0,
            peak_in_use: 0,
        }
    }

    /// The page geometry this pool was carved with.
    pub fn geometry(&self) -> KvGeometry {
        self.geom
    }

    /// Total pages in the pool (budget / page size).
    pub fn total_pages(&self) -> u64 {
        self.total_pages
    }

    /// Pages currently unmapped and available.
    pub fn free_pages(&self) -> u64 {
        self.total_pages - self.in_use
    }

    /// Distinct pages with at least one live reference.
    pub fn used_pages(&self) -> u64 {
        self.in_use
    }

    /// Bytes currently mapped (always <= the budget by construction).
    /// Shared pages count once.
    pub fn bytes_in_use(&self) -> u64 {
        self.in_use * self.geom.page_bytes()
    }

    /// High-water mark of mapped bytes over the allocator's lifetime.
    pub fn peak_bytes_in_use(&self) -> u64 {
        self.peak_in_use * self.geom.page_bytes()
    }

    /// References currently held on `page` (0 = free).
    pub fn ref_count(&self, page: u32) -> u32 {
        self.refs.get(page as usize).copied().unwrap_or(0)
    }

    /// Whether a request that will cache `tokens` tokens can *ever* be
    /// served from this pool (upfront-rejection check).
    pub fn fits_pool(&self, tokens: u64) -> bool {
        self.geom.pages_for(tokens) <= self.total_pages
    }

    /// Hand out one free page with an initial reference. `None` when the
    /// pool is exhausted.
    fn alloc_page(&mut self) -> Option<u32> {
        if self.free_pages() == 0 {
            return None;
        }
        let id = match self.recycled.pop() {
            Some(id) => id,
            None => {
                let id = self.next_fresh;
                self.next_fresh += 1;
                id
            }
        };
        if self.refs.len() <= id as usize {
            self.refs.resize(id as usize + 1, 0);
        }
        debug_assert_eq!(self.refs[id as usize], 0, "recycled page still referenced");
        self.refs[id as usize] = 1;
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Some(id)
    }

    /// Grow `table` until it holds at least `tokens` tokens. All-or-
    /// nothing: on failure the table is unchanged and `false` returns.
    pub fn try_grow(&mut self, table: &mut PageTable, tokens: u64) -> bool {
        let want = self.geom.pages_for(tokens);
        let have = table.pages.len() as u64;
        if want <= have {
            return true;
        }
        if want - have > self.free_pages() {
            return false;
        }
        for _ in have..want {
            let id = self.alloc_page().expect("free-page check above");
            table.pages.push(id);
        }
        true
    }

    /// Map an existing live page into `table` (prefix hit): the page gains
    /// a reference and bills the budget nothing new.
    pub fn share(&mut self, table: &mut PageTable, page: u32) {
        debug_assert!(self.ref_count(page) >= 1, "sharing a free page");
        self.refs[page as usize] += 1;
        table.pages.push(page);
    }

    /// Add a table-less reference to a live page (the prefix cache's hold
    /// on a registered prefix page).
    pub fn retain(&mut self, page: u32) {
        debug_assert!(self.ref_count(page) >= 1, "retaining a free page");
        self.refs[page as usize] += 1;
    }

    /// Drop one reference to `page`; the last reference frees it back to
    /// the pool.
    pub fn release_page(&mut self, page: u32) {
        let r = &mut self.refs[page as usize];
        debug_assert!(*r >= 1, "releasing a free page");
        *r -= 1;
        if *r == 0 {
            self.recycled.push(page);
            self.in_use -= 1;
        }
    }

    /// Drop `table`'s reference to every page it maps (retirement /
    /// preemption). Pages other tables or the prefix cache still reference
    /// stay live.
    pub fn release(&mut self, table: &mut PageTable) {
        for page in std::mem::take(&mut table.pages) {
            self.release_page(page);
        }
    }

    /// Copy-on-write guard before appending tokens into `table`'s last
    /// page: if that page is shared (another table or the prefix cache
    /// also maps it), fork it — allocate a private copy, drop the shared
    /// reference. Returns `false` (table unchanged) when a fork is needed
    /// but the pool has no free page. A no-op for empty tables and
    /// exclusively-owned tails, which is the only case the scheduler ever
    /// produces (shared pages are full prompt pages; appends land past
    /// them) — the fork keeps that a local invariant instead of a global
    /// argument.
    pub fn ensure_private_tail(&mut self, table: &mut PageTable) -> bool {
        let Some(&last) = table.pages.last() else { return true };
        if self.ref_count(last) == 1 {
            return true;
        }
        let Some(fresh) = self.alloc_page() else { return false };
        *table.pages.last_mut().expect("non-empty") = fresh;
        self.release_page(last);
        true
    }

    /// Export `tokens` cached tokens out of this pool for migration to
    /// another pool (disaggregated prefill → decode handoff). Drops the
    /// table's reference on every page it maps — pages other tables or
    /// the prefix cache still reference stay live here — and returns the
    /// migration manifest: the token count, the page count the content
    /// occupies at this pool's geometry, and the wire bytes the handoff
    /// moves over the die-to-die links. During the in-flight window the
    /// manifest bills *neither* pool; the destination commits pages only
    /// at [`Self::import`].
    pub fn export(&mut self, table: &mut PageTable, tokens: u64) -> KvExport {
        let pages = self.geom.pages_for(tokens);
        self.release(table);
        KvExport {
            tokens,
            pages,
            bytes: pages * self.geom.page_bytes(),
            format: self.geom.format,
        }
    }

    /// Materialize an exported manifest into this pool: grow `table` to
    /// cover `manifest.tokens` tokens. All-or-nothing — on failure the
    /// table and pool are unchanged and the manifest stays in flight for
    /// a retry. The migrated content is always private to the importing
    /// request (prefix sharing is re-established by content hash, never
    /// carried across pools). Same-format pools only: a manifest exported
    /// at a different KV format must go through
    /// [`Self::import_converting`] so the requantization is billed.
    pub fn import(&mut self, table: &mut PageTable, manifest: &KvExport) -> bool {
        debug_assert_eq!(
            manifest.format, self.geom.format,
            "cross-format import must use import_converting"
        );
        self.try_grow(table, manifest.tokens)
    }

    /// [`Self::import`] across KV formats: materialize `manifest.tokens`
    /// tokens into this pool, requantizing from `manifest.format` to the
    /// pool's format. All-or-nothing — `None` leaves the table and pool
    /// unchanged with the manifest still in flight; `Some(elems)` reports
    /// how many KV elements were converted (`tokens * elems_per_token`,
    /// 0 when the formats already match) so the caller can bill the
    /// conversion as [`crate::model::LayerKind::KvDequant`] work. Tokens
    /// never partially map: the destination either holds every exported
    /// token at its own format or none.
    pub fn import_converting(
        &mut self,
        table: &mut PageTable,
        manifest: &KvExport,
    ) -> Option<u64> {
        if !self.try_grow(table, manifest.tokens) {
            return None;
        }
        if manifest.format == self.geom.format {
            return Some(0);
        }
        Some(manifest.tokens * self.geom.elems_per_token())
    }
}

/// Manifest of a KV migration in flight between two [`PagedKvAllocator`]
/// pools: what [`PagedKvAllocator::export`] released at the source and
/// what [`PagedKvAllocator::import`] must materialize at the destination.
/// `bytes` is the wire size the handoff is priced at (whole pages — the
/// transfer moves page frames, not packed tokens).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvExport {
    /// Cached tokens the manifest carries.
    pub tokens: u64,
    /// Pages those tokens occupy at the source geometry.
    pub pages: u64,
    /// Wire bytes moved over the die-to-die links (`pages * page_bytes`).
    pub bytes: u64,
    /// KV element format the source pool stored the tokens at (wire
    /// format of the transfer). The destination requantizes on import
    /// when its own format differs.
    pub format: FpFormat,
}

/// Point-in-time occupancy snapshot of a [`PagedKvAllocator`] pool — the
/// unit the serving telemetry's gauge sampler records at
/// `--metrics-interval` cadence (see `crate::trace`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolGauges {
    /// Total pages the pool was carved into.
    pub total_pages: u64,
    /// Distinct pages currently referenced (shared pages count once).
    pub used_pages: u64,
    /// Bytes currently mapped.
    pub bytes_in_use: u64,
}

impl PagedKvAllocator {
    /// Snapshot the pool occupancy gauges.
    pub fn gauges(&self) -> KvPoolGauges {
        KvPoolGauges {
            total_pages: self.total_pages,
            used_pages: self.in_use,
            bytes_in_use: self.bytes_in_use(),
        }
    }
}

/// Content-addressed index of cached prompt-prefix pages.
///
/// Maps a chained page-content hash (see `Request::prompt_page_hashes`)
/// to the physical page holding that content. The cache holds its own
/// reference on every entry, so prefixes survive the requests that built
/// them; [`Self::evict_lru`] reclaims least-recently-used entries, but
/// only those whose page no request maps anymore (ref count 1 = cache
/// only) — evicting a still-mapped page would free no memory, so such
/// entries are treated as freshly used instead.
#[derive(Debug, Default)]
pub struct PrefixCache {
    /// hash -> (page id, LRU tick of last use).
    by_hash: HashMap<u64, (u32, u64)>,
    /// LRU index: tick -> hash (ticks are unique).
    lru: BTreeMap<u64, u64>,
    tick: u64,
}

impl PrefixCache {
    pub fn new() -> PrefixCache {
        PrefixCache::default()
    }

    /// Cached prefix pages currently indexed.
    pub fn len(&self) -> usize {
        self.by_hash.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_hash.is_empty()
    }

    fn touch(&mut self, hash: u64) {
        if let Some((_, tick)) = self.by_hash.get_mut(&hash) {
            self.lru.remove(tick);
            self.tick += 1;
            *tick = self.tick;
            self.lru.insert(self.tick, hash);
        }
    }

    /// How many leading entries of `hashes` are cached (consecutive from
    /// the chain start; a chained hash can only match if every earlier
    /// page matched too). Read-only — admission uses it to size the page
    /// ask before committing.
    pub fn probe(&self, hashes: &[u64]) -> u64 {
        hashes.iter().take_while(|&&h| self.by_hash.contains_key(&h)).count() as u64
    }

    /// Attach the cached page for `hash` (if any) to the end of `table`.
    /// The caller is responsible for chain alignment: `table` must
    /// already cover exactly the pages before `hash`'s position (true at
    /// admission, where the table is empty, and at mid-prefill chunk
    /// boundaries, where the table covers the materialized prefix).
    /// Returns whether a page was attached.
    pub fn attach_next(
        &mut self,
        alloc: &mut PagedKvAllocator,
        table: &mut PageTable,
        hash: u64,
    ) -> bool {
        let Some(&(page, _)) = self.by_hash.get(&hash) else { return false };
        alloc.share(table, page);
        self.touch(hash);
        true
    }

    /// Attach the longest cached prefix of `hashes` to `table` by sharing
    /// the cached pages (in chain order). Returns the number of pages
    /// attached; the caller skips `attached * page_tokens` tokens of
    /// prefill.
    pub fn attach_prefix(
        &mut self,
        alloc: &mut PagedKvAllocator,
        table: &mut PageTable,
        hashes: &[u64],
    ) -> u64 {
        debug_assert!(table.is_empty(), "prefix attaches at the chain start");
        let mut attached = 0;
        for &h in hashes {
            if !self.attach_next(alloc, table, h) {
                break;
            }
            attached += 1;
        }
        attached
    }

    /// Register `page` as the cached copy of content `hash`. The cache
    /// takes its own reference. A duplicate registration (two requests
    /// prefilled the same content concurrently) keeps the existing entry
    /// and leaves the caller's copy private — later requests converge on
    /// the first copy.
    pub fn insert(&mut self, alloc: &mut PagedKvAllocator, hash: u64, page: u32) {
        if self.by_hash.contains_key(&hash) {
            self.touch(hash);
            return;
        }
        alloc.retain(page);
        self.tick += 1;
        self.by_hash.insert(hash, (page, self.tick));
        self.lru.insert(self.tick, hash);
    }

    /// Pages that evicting the whole cache would free right now (entries
    /// whose page the cache alone references). O(entries) — meant for
    /// cold paths deciding whether an eviction is worth its cost, not for
    /// per-token bookkeeping.
    pub fn reclaimable(&self, alloc: &PagedKvAllocator) -> u64 {
        self.by_hash.values().filter(|&&(p, _)| alloc.ref_count(p) == 1).count() as u64
    }

    /// Evict up to `want` *reclaimable* entries, LRU first, and return how
    /// many pages were actually freed. An entry is reclaimable when the
    /// cache holds the only reference to its page; entries whose page is
    /// still mapped by a request are bumped to most-recently-used instead
    /// (they are, after all, in active use).
    pub fn evict_lru(&mut self, alloc: &mut PagedKvAllocator, want: u64) -> u64 {
        let mut freed = 0;
        let mut scanned = 0;
        let limit = self.lru.len();
        while freed < want && scanned < limit {
            let Some((_, hash)) = self.lru.pop_first() else { break };
            scanned += 1;
            let (page, _) = self.by_hash[&hash];
            if alloc.ref_count(page) == 1 {
                self.by_hash.remove(&hash);
                alloc.release_page(page);
                freed += 1;
            } else {
                // Still mapped by a request: freeing the entry reclaims
                // nothing. Re-file as recently used.
                self.tick += 1;
                self.by_hash.insert(hash, (page, self.tick));
                self.lru.insert(self.tick, hash);
            }
        }
        freed
    }

    /// Drop every entry (and the cache's references). Pages still mapped
    /// by requests stay live.
    pub fn clear(&mut self, alloc: &mut PagedKvAllocator) {
        for (_, (page, _)) in self.by_hash.drain() {
            alloc.release_page(page);
        }
        self.lru.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> KvGeometry {
        KvGeometry { token_bytes: 1024, page_tokens: 16, format: FpFormat::Fp32 }
    }

    #[test]
    fn geometry_matches_request_accounting() {
        use crate::coordinator::workload::Request;
        let cfg = ModelConfig::tiny();
        for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
            let g = KvGeometry::new(&cfg, fmt, 16);
            let r = Request::new(0, 48, 16);
            assert_eq!(g.token_bytes * r.kv_capacity(), r.kv_bytes_at(&cfg, fmt));
            assert_eq!(g.format, fmt);
            assert_eq!(g.elems_per_token(), cfg.blocks * 2 * cfg.heads * cfg.p);
        }
    }

    #[test]
    fn geometry_byte_math_is_exact_round_up() {
        // Satellite fix: token_bytes comes from the element count, never a
        // truncating division through an f32 byte total. Pin every format
        // against the closed-form 2 * blocks * heads * p * bytes.
        for cfg in
            [ModelConfig::tiny(), ModelConfig::gpt_j(), ModelConfig::vit_b()]
        {
            for fmt in FpFormat::ALL {
                let g = KvGeometry::new(&cfg, fmt, 16);
                assert_eq!(
                    g.token_bytes,
                    2 * cfg.blocks * cfg.heads * cfg.p * fmt.bytes(),
                    "{} {}",
                    cfg.name,
                    fmt
                );
                assert_eq!(g.token_bytes, g.elems_per_token() * fmt.bytes());
            }
        }
    }

    #[test]
    fn pages_round_up() {
        let g = geom();
        assert_eq!(g.pages_for(0), 0);
        assert_eq!(g.pages_for(1), 1);
        assert_eq!(g.pages_for(16), 1);
        assert_eq!(g.pages_for(17), 2);
        assert_eq!(g.page_bytes(), 16 * 1024);
    }

    #[test]
    fn grow_is_incremental_and_all_or_nothing() {
        let mut a = PagedKvAllocator::new(4 * 16 * 1024, geom()); // 4 pages
        let mut t = PageTable::new();
        assert!(a.try_grow(&mut t, 17)); // 2 pages
        assert_eq!(t.len(), 2);
        assert_eq!(a.free_pages(), 2);
        assert!(a.try_grow(&mut t, 32)); // already covered
        assert_eq!(t.len(), 2);
        assert!(!a.try_grow(&mut t, 16 * 7)); // needs 5 more than exist
        assert_eq!(t.len(), 2, "failed grow must not partially allocate");
        assert!(a.try_grow(&mut t, 64));
        assert_eq!(a.free_pages(), 0);
    }

    #[test]
    fn release_returns_every_page() {
        let mut a = PagedKvAllocator::new(8 * 16 * 1024, geom());
        let mut t1 = PageTable::new();
        let mut t2 = PageTable::new();
        assert!(a.try_grow(&mut t1, 50));
        assert!(a.try_grow(&mut t2, 60));
        assert_eq!(a.used_pages(), 8);
        assert_eq!(a.peak_bytes_in_use(), 8 * 16 * 1024);
        a.release(&mut t1);
        a.release(&mut t2);
        assert_eq!(a.used_pages(), 0);
        assert_eq!(a.free_pages(), a.total_pages());
        assert!(t1.is_empty() && t2.is_empty());
        // Recycled pages are reusable.
        let mut t3 = PageTable::new();
        assert!(a.try_grow(&mut t3, 8 * 16));
        assert_eq!(a.free_pages(), 0);
    }

    #[test]
    fn pool_fit_check() {
        let a = PagedKvAllocator::new(4 * 16 * 1024, geom());
        assert!(a.fits_pool(64));
        assert!(!a.fits_pool(65));
    }

    #[test]
    fn shared_pages_bill_once_and_survive_one_release() {
        let mut a = PagedKvAllocator::new(4 * 16 * 1024, geom());
        let mut t1 = PageTable::new();
        assert!(a.try_grow(&mut t1, 32)); // 2 pages
        let mut t2 = PageTable::new();
        a.share(&mut t2, t1.pages()[0]);
        a.share(&mut t2, t1.pages()[1]);
        assert_eq!(t2.len(), 2);
        // Dedup: two tables, two distinct pages, half the pool free.
        assert_eq!(a.used_pages(), 2);
        assert_eq!(a.bytes_in_use(), 2 * 16 * 1024);
        assert_eq!(a.ref_count(t1.pages()[0]), 2);
        // Releasing one owner keeps the pages live for the other.
        a.release(&mut t1);
        assert_eq!(a.used_pages(), 2);
        assert_eq!(a.ref_count(t2.pages()[0]), 1);
        // A fresh grow must not hand out the still-referenced pages.
        let mut t3 = PageTable::new();
        assert!(a.try_grow(&mut t3, 32));
        for p in t3.pages() {
            assert!(!t2.pages().contains(p), "live page re-allocated");
        }
        a.release(&mut t2);
        a.release(&mut t3);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn cow_fork_makes_tail_private() {
        let mut a = PagedKvAllocator::new(4 * 16 * 1024, geom());
        let mut t1 = PageTable::new();
        assert!(a.try_grow(&mut t1, 16));
        let shared = t1.pages()[0];
        let mut t2 = PageTable::new();
        a.share(&mut t2, shared);
        assert_eq!(a.ref_count(shared), 2);
        // t2's tail is shared: the fork must swap in a private page.
        assert!(a.ensure_private_tail(&mut t2));
        assert_ne!(t2.pages()[0], shared);
        assert_eq!(a.ref_count(shared), 1);
        assert_eq!(a.ref_count(t2.pages()[0]), 1);
        assert_eq!(a.used_pages(), 2, "fork allocates exactly one page");
        // Exclusive tails are a no-op.
        let before = t1.pages().to_vec();
        assert!(a.ensure_private_tail(&mut t1));
        assert_eq!(t1.pages(), &before[..]);
    }

    #[test]
    fn cow_fork_fails_cleanly_when_pool_dry() {
        let mut a = PagedKvAllocator::new(16 * 1024, geom()); // 1 page
        let mut t1 = PageTable::new();
        assert!(a.try_grow(&mut t1, 16));
        let mut t2 = PageTable::new();
        a.share(&mut t2, t1.pages()[0]);
        assert!(!a.ensure_private_tail(&mut t2), "no free page to fork into");
        assert_eq!(t2.pages(), t1.pages(), "failed fork must not mutate");
        assert_eq!(a.ref_count(t1.pages()[0]), 2);
    }

    #[test]
    fn prefix_cache_attach_insert_and_lru_eviction() {
        let mut a = PagedKvAllocator::new(4 * 16 * 1024, geom());
        let mut cache = PrefixCache::new();
        // Build two prefix pages and register them.
        let mut owner = PageTable::new();
        assert!(a.try_grow(&mut owner, 32));
        cache.insert(&mut a, 100, owner.pages()[0]);
        cache.insert(&mut a, 101, owner.pages()[1]);
        assert_eq!(cache.len(), 2);
        assert_eq!(a.ref_count(owner.pages()[0]), 2); // owner + cache
        // Owner retires; the prefix survives on the cache's references.
        let kept: Vec<u32> = owner.pages().to_vec();
        a.release(&mut owner);
        assert_eq!(a.used_pages(), 2);
        assert_eq!(cache.reclaimable(&a), 2, "cache-only pages are reclaimable");
        // A new request hits the full chain, a diverging one only page 0.
        assert_eq!(cache.probe(&[100, 101]), 2);
        assert_eq!(cache.probe(&[100, 999]), 1);
        assert_eq!(cache.probe(&[999, 101]), 0, "chain must match from the start");
        let mut t = PageTable::new();
        assert_eq!(cache.attach_prefix(&mut a, &mut t, &[100, 101]), 2);
        assert_eq!(t.pages(), &kept[..]);
        assert_eq!(cache.reclaimable(&a), 0, "mapped pages free nothing");
        // Eviction skips the still-mapped pages (freeing them reclaims
        // nothing) ...
        assert_eq!(cache.evict_lru(&mut a, 2), 0);
        assert_eq!(cache.len(), 2);
        // ... and reclaims them LRU-first once the mapper is gone.
        a.release(&mut t);
        assert_eq!(cache.evict_lru(&mut a, 1), 1);
        assert_eq!(cache.len(), 1);
        // attach touched 100 before 101, so 100 was least recently used.
        assert_eq!(cache.probe(&[101]), 1);
        assert_eq!(cache.probe(&[100, 101]), 0);
        assert_eq!(a.used_pages(), 1);
        assert_eq!(cache.evict_lru(&mut a, 1), 1);
        assert_eq!(a.used_pages(), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn export_import_conserves_pages_across_pools() {
        let mut src = PagedKvAllocator::new(8 * 16 * 1024, geom());
        let mut dst = PagedKvAllocator::new(8 * 16 * 1024, geom());
        let mut t = PageTable::new();
        assert!(src.try_grow(&mut t, 40)); // 3 pages
        assert_eq!(src.used_pages(), 3);
        let manifest = src.export(&mut t, 40);
        assert_eq!(
            manifest,
            KvExport {
                tokens: 40,
                pages: 3,
                bytes: 3 * 16 * 1024,
                format: FpFormat::Fp32
            }
        );
        // In flight: billed to neither pool, table empty.
        assert_eq!(src.used_pages(), 0);
        assert_eq!(dst.used_pages(), 0);
        assert!(t.is_empty());
        assert!(dst.import(&mut t, &manifest));
        assert_eq!(dst.used_pages(), manifest.pages);
        assert_eq!(t.capacity_tokens(&geom()), 48);
        dst.release(&mut t);
        assert_eq!(dst.used_pages(), 0);
    }

    #[test]
    fn export_leaves_shared_pages_live_and_import_is_all_or_nothing() {
        let mut src = PagedKvAllocator::new(4 * 16 * 1024, geom());
        let mut cache = PrefixCache::new();
        let mut t = PageTable::new();
        assert!(src.try_grow(&mut t, 32)); // 2 pages
        cache.insert(&mut src, 42, t.pages()[0]);
        let manifest = src.export(&mut t, 32);
        // The cached prefix page survives the export on the cache's ref.
        assert_eq!(src.used_pages(), 1);
        assert_eq!(cache.probe(&[42]), 1);
        assert_eq!(cache.reclaimable(&src), 1);
        // A destination too small refuses the whole manifest.
        let mut dst = PagedKvAllocator::new(16 * 1024, geom()); // 1 page
        assert!(!dst.import(&mut t, &manifest));
        assert_eq!(dst.used_pages(), 0);
        assert!(t.is_empty(), "failed import must not partially map");
        cache.clear(&mut src);
        assert_eq!(src.used_pages(), 0);
    }

    #[test]
    fn cross_format_import_requantizes_all_or_nothing() {
        let cfg = ModelConfig::tiny();
        let g16 = KvGeometry::new(&cfg, FpFormat::Fp16, 16);
        let g8 = KvGeometry::new(&cfg, FpFormat::Fp8, 16);
        let mut src = PagedKvAllocator::new(8 * g16.page_bytes(), g16);
        let mut t = PageTable::new();
        assert!(src.try_grow(&mut t, 40)); // 3 pages at fp16
        let manifest = src.export(&mut t, 40);
        assert_eq!(manifest.format, FpFormat::Fp16);
        // Importing into an fp8 pool requantizes every element, and the
        // element count is billed at the destination's per-token density.
        let mut dst = PagedKvAllocator::new(8 * g8.page_bytes(), g8);
        let converted = dst.import_converting(&mut t, &manifest);
        assert_eq!(converted, Some(40 * g8.elems_per_token()));
        assert_eq!(dst.used_pages(), g8.pages_for(40));
        dst.release(&mut t);
        // Same-format conversion is free (0 elements converted).
        let mut dst16 = PagedKvAllocator::new(8 * g16.page_bytes(), g16);
        assert_eq!(dst16.import_converting(&mut t, &manifest), Some(0));
        dst16.release(&mut t);
        // A destination too small refuses the whole manifest: no tokens
        // map, no conversion is billed.
        let mut tiny = PagedKvAllocator::new(g8.page_bytes(), g8); // 1 page
        assert_eq!(tiny.import_converting(&mut t, &manifest), None);
        assert_eq!(tiny.used_pages(), 0);
        assert!(t.is_empty(), "failed converting import must not partially map");
    }

    #[test]
    fn duplicate_registration_keeps_first_copy() {
        let mut a = PagedKvAllocator::new(4 * 16 * 1024, geom());
        let mut cache = PrefixCache::new();
        let mut t1 = PageTable::new();
        let mut t2 = PageTable::new();
        assert!(a.try_grow(&mut t1, 16));
        assert!(a.try_grow(&mut t2, 16));
        cache.insert(&mut a, 7, t1.pages()[0]);
        cache.insert(&mut a, 7, t2.pages()[0]); // concurrent duplicate
        assert_eq!(cache.len(), 1);
        assert_eq!(a.ref_count(t1.pages()[0]), 2, "first copy cached");
        assert_eq!(a.ref_count(t2.pages()[0]), 1, "duplicate stays private");
    }
}
