//! Continuous-batching serving scheduler: paged KV, chunked prefill,
//! priority-aware admission.
//!
//! Admits [`Request`]s against a paged HBM KV budget, interleaves prefill
//! chunks (NAR) with ragged batched decode (AR) steps, and prices the
//! whole trace on the cycle-level platform model. PR 1's batcher was the
//! FCFS skeleton of this; this version closes its tracked simplifications:
//!
//! * **Paged KV** ([`super::kv_paging`]) — fixed-size pages allocated on
//!   demand as tokens materialize, freed at retirement, instead of a
//!   full-length (prompt + max generation) reservation at admission. When
//!   decode outgrows the pool, the lowest-priority / youngest resident is
//!   preempted vLLM-recompute-style: its pages are freed and it re-queues
//!   to re-prefill prompt + already-produced tokens.
//! * **Chunked prefill** — prompts prefill in `prefill_chunk`-token NAR
//!   passes (each attending to the request's cached context so far),
//!   interleaved with decode steps, so a long prompt no longer stalls the
//!   decode stream or the time-to-first-token of everything queued behind
//!   it. `prefill_chunk = 0` restores monolithic prefill.
//! * **Priority + aging admission** — requests carry a priority class
//!   (0 = most urgent); the queue admits by effective class, where waiting
//!   `aging_promote_s` seconds promotes a request one class (so no class
//!   starves). Within a class, FCFS by arrival.
//! * **Open-loop arrivals** — requests arrive per their `arrival_ns`
//!   stamps ([`Workload::with_poisson_arrivals`]); the scheduler idles
//!   forward to the next arrival when the system drains.
//! * **Ragged decode pricing** — one decode step advances every active
//!   request by one token, priced with per-request KV lengths
//!   (`model_cost_decode`) instead of the batch-max length.

use std::collections::VecDeque;

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::kv_paging::{KvGeometry, PagedKvAllocator, PageTable};
use crate::coordinator::schedule::{block_cost_batched, model_cost_decode};
use crate::coordinator::workload::{Request, Workload};
use crate::energy;
use crate::metrics;
use crate::model::{Mode, ModelConfig};
use crate::sim::KernelCost;

/// Scheduling policy knobs for the serving loop.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum concurrently resident requests (batch slots).
    pub max_batch: usize,
    /// HBM bytes available for KV caches (platform capacity minus
    /// resident weights).
    pub kv_budget_bytes: u64,
    /// KV page size in tokens (paged-allocator granularity).
    pub page_tokens: u64,
    /// Prefill chunk in tokens; 0 = monolithic prefill (whole prompt in
    /// one NAR pass, the PR-1 behavior).
    pub prefill_chunk: u64,
    /// Reserve pages for the full prompt + generation at admission
    /// (legacy full-length reservation semantics, page-granular). Used as
    /// the baseline the paged mode is measured against.
    pub reserve_full: bool,
    /// Seconds of queue wait that promote a request one priority class
    /// (anti-starvation aging); 0 disables aging. The default (5 s) is
    /// sized to the simulated platform's serving timescale, where a
    /// single GPT-class prefill takes seconds — small enough to prevent
    /// starvation, large enough that classes actually separate.
    pub aging_promote_s: f64,
}

impl BatcherConfig {
    /// Paged, non-chunked, single-class defaults at the given budget.
    /// `kv_budget_bytes = 0` means "the platform's KV budget" (HBM
    /// capacity minus resident weights); [`ContinuousBatcher::new`]
    /// resolves it.
    pub fn new(max_batch: usize, kv_budget_bytes: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            kv_budget_bytes,
            page_tokens: 16,
            prefill_chunk: 0,
            reserve_full: false,
            aging_promote_s: 5.0,
        }
    }
}

/// Per-request serving outcome. Latency-like fields are relative to the
/// request's arrival (for t=0 closed-loop traces they coincide with
/// absolute trace time, PR 1's convention).
#[derive(Debug, Clone)]
pub struct RequestStats {
    pub id: usize,
    pub class: u8,
    pub prompt_len: u64,
    pub gen_tokens: u64,
    /// Absolute arrival time, seconds.
    pub arrival_s: f64,
    /// Arrival -> first admission (queue wait), seconds.
    pub admitted_s: f64,
    /// Arrival -> first generated token, seconds.
    pub ttft_s: f64,
    /// Arrival -> last generated token, seconds.
    pub latency_s: f64,
    /// Times this request was preempted (pages reclaimed, recompute).
    pub preemptions: u32,
}

/// Latency percentiles of one priority class.
#[derive(Debug, Clone)]
pub struct ClassStats {
    pub class: u8,
    pub completed: usize,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
}

/// Everything the serving run reports.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub model: String,
    pub format: &'static str,
    /// Requests offered / completed; ids rejected because a single KV
    /// cache can never fit the page pool (plus, as a release-build
    /// diagnostic only, a job abandoned by the unreachable lone-resident
    /// stall guard).
    pub requests: usize,
    pub completed: usize,
    pub rejected: Vec<usize>,
    pub max_batch: usize,
    pub kv_budget_bytes: u64,
    /// Paged-allocator geometry: tokens per page / pages in the pool.
    pub page_tokens: u64,
    pub total_pages: u64,
    /// High-water mark of mapped KV bytes (must stay <= budget).
    pub peak_kv_bytes: u64,
    pub total_cycles: u64,
    pub total_seconds: f64,
    /// Prompt tokens prefilled, including recompute after preemption.
    pub prefill_tokens: u64,
    /// Prefill NAR passes issued (chunks).
    pub prefill_chunks: u64,
    pub gen_tokens: u64,
    /// Preemptions (a resident request evicted for pages).
    pub preemptions: u64,
    pub ttft_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    /// Admission delay (arrival -> admission) aggregates.
    pub queue_mean_s: f64,
    pub queue_p99_s: f64,
    /// Aggregate generated tokens / total wall-clock.
    pub tokens_per_s: f64,
    /// Generated tokens / decode-only wall-clock.
    pub decode_tokens_per_s: f64,
    /// Mean decode batch occupancy (tokens per decode step).
    pub avg_batch_occupancy: f64,
    pub fpu_utilization: f64,
    pub power_w: f64,
    pub hbm_gb: f64,
    /// Per-priority-class percentiles (one entry per class present).
    pub per_class: Vec<ClassStats>,
    pub per_request: Vec<RequestStats>,
}

/// A request's scheduler-side state that survives preemption.
#[derive(Debug, Clone)]
struct Job {
    req: Request,
    arrival_cycle: u64,
    /// Tokens that must be prefilled before (more) decode: the prompt,
    /// plus already-produced tokens after a recompute preemption.
    prefill_target: u64,
    /// Tokens generated so far (credited once; never re-generated).
    produced: u64,
    preemptions: u32,
    first_admitted_cycle: Option<u64>,
    ttft_cycle: Option<u64>,
}

/// A resident request (holds pages).
struct ActiveJob {
    job: Job,
    prefill_done: u64,
    /// Tokens currently materialized in KV.
    kv_len: u64,
    table: PageTable,
}

/// Prices a serving trace over one model/platform/precision.
pub struct ContinuousBatcher<'a> {
    pub cfg: &'a ModelConfig,
    pub platform: &'a PlatformConfig,
    pub fmt: FpFormat,
    pub opts: BatcherConfig,
}

/// Counters threaded through one run.
#[derive(Default)]
struct RunCounters {
    total: KernelCost,
    decode_cycles: u64,
    decode_tokens: u64,
    decode_steps: u64,
    prefill_tokens: u64,
    prefill_chunks: u64,
    preemptions: u64,
}

impl<'a> ContinuousBatcher<'a> {
    /// `opts.kv_budget_bytes = 0` resolves to the platform budget: HBM
    /// capacity minus the resident weights at the serving precision
    /// (zero when the weights alone overflow — everything then rejects
    /// rather than pretending).
    pub fn new(
        cfg: &'a ModelConfig,
        platform: &'a PlatformConfig,
        fmt: FpFormat,
        mut opts: BatcherConfig,
    ) -> ContinuousBatcher<'a> {
        if opts.kv_budget_bytes == 0 {
            opts.kv_budget_bytes =
                super::kv_paging::platform_kv_budget_bytes(cfg, fmt, platform);
        }
        ContinuousBatcher { cfg, platform, fmt, opts }
    }

    /// Scheduling key: most urgent first — effective (aged) class, then
    /// FCFS by arrival, then id. Admission, prefill, and decode ordering
    /// all use this one key.
    fn sched_key(job: &Job, time: u64, aging_cycles: u64) -> (u8, u64, usize) {
        (Self::effective_class(job, time, aging_cycles), job.arrival_cycle, job.req.id)
    }

    fn aging_cycles(&self) -> u64 {
        if self.opts.aging_promote_s <= 0.0 {
            0
        } else {
            (self.opts.aging_promote_s * self.platform.freq_ghz * 1e9) as u64
        }
    }

    /// Class after aging: waiting promotes one class per aging interval.
    fn effective_class(job: &Job, time: u64, aging_cycles: u64) -> u8 {
        if aging_cycles == 0 {
            return job.req.class;
        }
        let promoted = (time.saturating_sub(job.arrival_cycle) / aging_cycles)
            .min(u8::MAX as u64) as u8;
        job.req.class.saturating_sub(promoted)
    }

    /// Pages a job needs at admission time.
    fn admission_pages(&self, geom: &KvGeometry, job: &Job) -> u64 {
        if self.opts.reserve_full {
            geom.pages_for(job.prefill_target + (job.req.gen_tokens - job.produced))
        } else {
            geom.pages_for(job.prefill_target)
        }
    }

    /// Run the whole workload to completion and return the priced report.
    pub fn run(&self, workload: &Workload) -> ServeReport {
        let geom = KvGeometry::new(self.cfg, self.fmt, self.opts.page_tokens);
        let mut alloc = PagedKvAllocator::new(self.opts.kv_budget_bytes, geom);
        let aging_cycles = self.aging_cycles();

        let mut rejected = Vec::new();
        let mut arrivals: VecDeque<Job> = VecDeque::new();
        {
            let mut jobs: Vec<Job> = Vec::new();
            for r in &workload.requests {
                if !alloc.fits_pool(r.kv_capacity()) {
                    rejected.push(r.id);
                    continue;
                }
                jobs.push(Job {
                    arrival_cycle: self.platform.ns_to_cycles(r.arrival_ns as f64),
                    prefill_target: r.prompt_len,
                    produced: 0,
                    preemptions: 0,
                    first_admitted_cycle: None,
                    ttft_cycle: None,
                    req: r.clone(),
                });
            }
            jobs.sort_by_key(|j| (j.arrival_cycle, j.req.id));
            arrivals.extend(jobs);
        }

        let mut ready: Vec<Job> = Vec::new();
        let mut active: Vec<ActiveJob> = Vec::new();
        let mut done: Vec<RequestStats> = Vec::new();
        let mut c = RunCounters::default();
        let mut time: u64 = 0;

        loop {
            while arrivals.front().is_some_and(|j| j.arrival_cycle <= time) {
                ready.push(arrivals.pop_front().unwrap());
            }

            self.admit(&mut ready, &mut active, &mut alloc, &geom, time, aging_cycles);

            if active.is_empty() {
                debug_assert!(
                    ready.is_empty(),
                    "admission must drain the queue when the pool is free"
                );
                match arrivals.front() {
                    Some(next) if ready.is_empty() => {
                        // System idle: jump to the next arrival.
                        time = time.max(next.arrival_cycle);
                        continue;
                    }
                    None if ready.is_empty() => break,
                    _ => break, // wedged-queue guard (upfront reject covers this)
                }
            }

            let mut progressed = false;
            progressed |=
                self.prefill_quanta(&mut active, &mut alloc, &mut c, &mut time, aging_cycles);
            self.retire_finished(&mut active, &mut alloc, &mut done, time);
            progressed |= self.decode_step(
                &mut active,
                &mut ready,
                &mut alloc,
                &mut done,
                &mut c,
                &mut time,
                aging_cycles,
            );

            if !progressed {
                // Every resident job is stalled on pages: reclaim from the
                // least urgent one so the rest can move.
                if active.len() > 1 {
                    if let Some(v) = Self::victim_index(&active, None) {
                        Self::preempt(&mut active, v, &mut ready, &mut alloc, &mut c);
                    }
                } else {
                    // A lone resident can always grow (oversize requests
                    // were rejected against the whole pool upfront).
                    debug_assert!(false, "lone resident job stalled");
                    if let Some(mut a) = active.pop() {
                        alloc.release(&mut a.table);
                        rejected.push(a.job.req.id);
                    }
                }
            }
        }

        self.report(workload, rejected, done, &alloc, c, time)
    }

    /// Admit ready jobs by effective priority while slots and pages allow.
    fn admit(
        &self,
        ready: &mut Vec<Job>,
        active: &mut Vec<ActiveJob>,
        alloc: &mut PagedKvAllocator,
        geom: &KvGeometry,
        time: u64,
        aging_cycles: u64,
    ) {
        while active.len() < self.opts.max_batch.max(1) && !ready.is_empty() {
            let best = (0..ready.len())
                .min_by_key(|&i| Self::sched_key(&ready[i], time, aging_cycles))
                .unwrap();
            if self.admission_pages(geom, &ready[best]) > alloc.free_pages() {
                // Strict priority: lower classes do not jump the head of
                // the queue on pages; retirements will free them.
                break;
            }
            let mut job = ready.swap_remove(best);
            let mut table = PageTable::new();
            if self.opts.reserve_full {
                let reserved = alloc.try_grow(
                    &mut table,
                    job.prefill_target + (job.req.gen_tokens - job.produced),
                );
                debug_assert!(reserved, "admission check guarantees the reservation");
            }
            if job.first_admitted_cycle.is_none() {
                job.first_admitted_cycle = Some(time);
            }
            active.push(ActiveJob { job, prefill_done: 0, kv_len: 0, table });
        }
    }

    /// Advance every prefilling job by one chunk (priority order). Returns
    /// whether any prefill work ran.
    fn prefill_quanta(
        &self,
        active: &mut [ActiveJob],
        alloc: &mut PagedKvAllocator,
        c: &mut RunCounters,
        time: &mut u64,
        aging_cycles: u64,
    ) -> bool {
        let mut order: Vec<usize> = (0..active.len())
            .filter(|&i| active[i].prefill_done < active[i].job.prefill_target)
            .collect();
        order.sort_by_key(|&i| Self::sched_key(&active[i].job, *time, aging_cycles));
        let mut ran = false;
        for i in order {
            let a = &mut active[i];
            let remaining = a.job.prefill_target - a.prefill_done;
            let quantum = match self.opts.prefill_chunk {
                0 => remaining,
                chunk => remaining.min(chunk),
            };
            if !alloc.try_grow(&mut a.table, a.prefill_done + quantum) {
                continue; // wait for pages; decode/retirements will free some
            }
            let cost = block_cost_batched(
                self.cfg,
                Mode::Nar,
                1,
                quantum,
                a.prefill_done,
                self.fmt,
                self.platform,
            )
            .total
            .repeat(self.cfg.blocks);
            *time += cost.cycles;
            c.total = c.total.then(cost);
            a.prefill_done += quantum;
            a.kv_len = a.prefill_done;
            c.prefill_tokens += quantum;
            c.prefill_chunks += 1;
            ran = true;
        }
        ran
    }

    /// Retire jobs that need no (further) decode (prefill-only requests).
    fn retire_finished(
        &self,
        active: &mut Vec<ActiveJob>,
        alloc: &mut PagedKvAllocator,
        done: &mut Vec<RequestStats>,
        time: u64,
    ) {
        let mut i = 0;
        while i < active.len() {
            let a = &active[i];
            if a.prefill_done >= a.job.prefill_target
                && a.job.produced >= a.job.req.gen_tokens
            {
                let mut a = active.swap_remove(i);
                alloc.release(&mut a.table);
                let ttft = a.job.ttft_cycle.unwrap_or(time);
                done.push(self.finish_stats(&a.job, ttft, time));
            } else {
                i += 1;
            }
        }
    }

    /// One ragged batched decode step over every fully-prefilled resident
    /// job, growing pages on demand (preempting less urgent residents when
    /// the pool is dry). Returns whether a step ran.
    #[allow(clippy::too_many_arguments)]
    fn decode_step(
        &self,
        active: &mut Vec<ActiveJob>,
        ready: &mut Vec<Job>,
        alloc: &mut PagedKvAllocator,
        done: &mut Vec<RequestStats>,
        c: &mut RunCounters,
        time: &mut u64,
        aging_cycles: u64,
    ) -> bool {
        let mut order: Vec<usize> = (0..active.len())
            .filter(|&i| {
                active[i].prefill_done >= active[i].job.prefill_target
                    && active[i].job.produced < active[i].job.req.gen_tokens
            })
            .collect();
        order.sort_by_key(|&i| Self::sched_key(&active[i].job, *time, aging_cycles));
        // Index-stable id list (preemption below reshuffles `active`).
        let ids: Vec<usize> = order.iter().map(|&i| active[i].job.req.id).collect();

        let mut stepped: Vec<usize> = Vec::new();
        for id in ids {
            'grow: loop {
                let Some(i) = active.iter().position(|a| a.job.req.id == id) else {
                    break 'grow; // preempted while growing others
                };
                let want = active[i].kv_len + 1;
                if alloc.try_grow(&mut active[i].table, want) {
                    stepped.push(id);
                    break 'grow;
                }
                match Self::victim_index(active, Some(i)) {
                    Some(v) => Self::preempt(active, v, ready, alloc, c),
                    None => break 'grow, // nobody less urgent; wait a step
                }
            }
        }
        // A job that grew early can itself be evicted while later jobs
        // grow; only still-resident jobs take part in the step.
        stepped.retain(|id| active.iter().any(|a| a.job.req.id == *id));
        if stepped.is_empty() {
            return false;
        }

        let kv_lens: Vec<u64> = stepped
            .iter()
            .map(|id| active.iter().find(|a| a.job.req.id == *id).unwrap().kv_len)
            .collect();
        let cost = model_cost_decode(self.cfg, &kv_lens, self.fmt, self.platform).total;
        *time += cost.cycles;
        c.total = c.total.then(cost);
        c.decode_cycles += cost.cycles;
        c.decode_tokens += stepped.len() as u64;
        c.decode_steps += 1;

        for id in stepped {
            let i = active.iter().position(|a| a.job.req.id == id).unwrap();
            let a = &mut active[i];
            a.kv_len += 1;
            a.job.produced += 1;
            if a.job.ttft_cycle.is_none() {
                a.job.ttft_cycle = Some(*time);
            }
            if a.job.produced >= a.job.req.gen_tokens {
                let mut a = active.swap_remove(i);
                alloc.release(&mut a.table);
                let ttft = a.job.ttft_cycle.unwrap_or(*time);
                done.push(self.finish_stats(&a.job, ttft, *time));
            }
        }
        true
    }

    /// Pick the preemption victim: the least urgent resident (highest
    /// class, then latest first admission, then highest id). With
    /// `protect` set, that index is excluded and only jobs at the same or
    /// a less urgent static class than it qualify.
    fn victim_index(active: &[ActiveJob], protect: Option<usize>) -> Option<usize> {
        let floor = protect.map(|i| active[i].job.req.class);
        (0..active.len())
            .filter(|&i| Some(i) != protect)
            .filter(|&i| floor.is_none_or(|f| active[i].job.req.class >= f))
            .max_by_key(|&i| {
                let j = &active[i].job;
                (j.req.class, j.first_admitted_cycle, j.req.id)
            })
    }

    /// Evict a resident job: free its pages and requeue it to recompute
    /// (re-prefill prompt + already-produced tokens, then resume decode).
    fn preempt(
        active: &mut Vec<ActiveJob>,
        victim: usize,
        ready: &mut Vec<Job>,
        alloc: &mut PagedKvAllocator,
        c: &mut RunCounters,
    ) {
        let mut a = active.swap_remove(victim);
        alloc.release(&mut a.table);
        a.job.preemptions += 1;
        a.job.prefill_target = a.job.req.prompt_len + a.job.produced;
        c.preemptions += 1;
        ready.push(a.job);
    }

    fn finish_stats(&self, job: &Job, ttft_cycle: u64, done_cycle: u64) -> RequestStats {
        let s = |cyc: u64| self.platform.cycles_to_seconds(cyc);
        let arrival = job.arrival_cycle;
        RequestStats {
            id: job.req.id,
            class: job.req.class,
            prompt_len: job.req.prompt_len,
            gen_tokens: job.req.gen_tokens,
            arrival_s: s(arrival),
            admitted_s: s(job
                .first_admitted_cycle
                .unwrap_or(done_cycle)
                .saturating_sub(arrival)),
            ttft_s: s(ttft_cycle.saturating_sub(arrival)),
            latency_s: s(done_cycle.saturating_sub(arrival)),
            preemptions: job.preemptions,
        }
    }

    fn report(
        &self,
        workload: &Workload,
        rejected: Vec<usize>,
        mut done: Vec<RequestStats>,
        alloc: &PagedKvAllocator,
        c: RunCounters,
        time: u64,
    ) -> ServeReport {
        done.sort_by_key(|r| r.id);
        // TTFT is defined over generated tokens: prefill-only requests
        // (gen_tokens == 0) never produce one, so they are excluded from
        // the TTFT aggregates (their per-request ttft_s equals prefill
        // completion).
        let ttfts: Vec<f64> =
            done.iter().filter(|r| r.gen_tokens > 0).map(|r| r.ttft_s).collect();
        let lats: Vec<f64> = done.iter().map(|r| r.latency_s).collect();
        let queues: Vec<f64> = done.iter().map(|r| r.admitted_s).collect();
        let total_seconds = self.platform.cycles_to_seconds(time);
        let decode_seconds = self.platform.cycles_to_seconds(c.decode_cycles);
        let gen_tokens: u64 = done.iter().map(|r| r.gen_tokens).sum();
        let power = energy::power_report(&c.total, self.fmt, self.platform);

        let mut classes: Vec<u8> = done.iter().map(|r| r.class).collect();
        classes.sort_unstable();
        classes.dedup();
        let per_class = classes
            .into_iter()
            .map(|class| {
                let t: Vec<f64> = done
                    .iter()
                    .filter(|r| r.class == class && r.gen_tokens > 0)
                    .map(|r| r.ttft_s)
                    .collect();
                let l: Vec<f64> = done
                    .iter()
                    .filter(|r| r.class == class)
                    .map(|r| r.latency_s)
                    .collect();
                ClassStats {
                    class,
                    completed: l.len(),
                    ttft_p50_s: metrics::percentile(&t, 50.0),
                    ttft_p99_s: metrics::percentile(&t, 99.0),
                    latency_p50_s: metrics::percentile(&l, 50.0),
                    latency_p99_s: metrics::percentile(&l, 99.0),
                }
            })
            .collect();

        let per_s = |tokens: u64, seconds: f64| {
            if seconds > 0.0 {
                tokens as f64 / seconds
            } else {
                0.0
            }
        };
        ServeReport {
            model: self.cfg.name.clone(),
            format: self.fmt.name(),
            requests: workload.len(),
            completed: done.len(),
            rejected,
            max_batch: self.opts.max_batch.max(1),
            kv_budget_bytes: self.opts.kv_budget_bytes,
            page_tokens: alloc.geometry().page_tokens,
            total_pages: alloc.total_pages(),
            peak_kv_bytes: alloc.peak_bytes_in_use(),
            total_cycles: time,
            total_seconds,
            prefill_tokens: c.prefill_tokens,
            prefill_chunks: c.prefill_chunks,
            gen_tokens,
            preemptions: c.preemptions,
            ttft_mean_s: metrics::mean(&ttfts),
            ttft_p50_s: metrics::percentile(&ttfts, 50.0),
            ttft_p99_s: metrics::percentile(&ttfts, 99.0),
            latency_mean_s: metrics::mean(&lats),
            latency_p50_s: metrics::percentile(&lats, 50.0),
            latency_p99_s: metrics::percentile(&lats, 99.0),
            queue_mean_s: metrics::mean(&queues),
            queue_p99_s: metrics::percentile(&queues, 99.0),
            tokens_per_s: per_s(gen_tokens, total_seconds),
            decode_tokens_per_s: per_s(c.decode_tokens, decode_seconds),
            avg_batch_occupancy: if c.decode_steps > 0 {
                c.decode_tokens as f64 / c.decode_steps as f64
            } else {
                0.0
            },
            fpu_utilization: power.fpu_utilization,
            power_w: power.power_w,
            hbm_gb: c.total.hbm_bytes() as f64 / 1e9,
            per_class,
            per_request: done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_cfg(
        cfg: &ModelConfig,
        platform: &PlatformConfig,
        w: &Workload,
        opts: BatcherConfig,
    ) -> ServeReport {
        ContinuousBatcher::new(cfg, platform, FpFormat::Fp32, opts).run(w)
    }

    fn tiny_batcher(
        cfg: &ModelConfig,
        platform: &PlatformConfig,
        max_batch: usize,
        budget: u64,
    ) -> ServeReport {
        run_cfg(
            cfg,
            platform,
            &Workload::uniform(6, 16, 8),
            BatcherConfig::new(max_batch, budget),
        )
    }

    #[test]
    fn completes_all_requests() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Ample budget: all four slots can hold full-length caches with
        // page-rounding slack, so nothing is evicted.
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        let r = tiny_batcher(&cfg, &p, 4, budget);
        assert_eq!(r.completed, 6);
        assert!(r.rejected.is_empty());
        assert!(r.tokens_per_s > 0.0);
        assert_eq!(r.gen_tokens, 6 * 8);
        assert_eq!(r.prefill_tokens, 6 * 16);
        assert_eq!(r.preemptions, 0);
    }

    #[test]
    fn kv_budget_is_never_exceeded() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let one = Request::new(0, 16, 8).kv_bytes(&cfg);
        // Pool for exactly two full-length caches, batch slots for four.
        for reserve_full in [false, true] {
            let mut opts = BatcherConfig::new(4, 2 * one);
            opts.reserve_full = reserve_full;
            let r = run_cfg(&cfg, &p, &Workload::uniform(6, 16, 8), opts);
            assert_eq!(r.completed, 6, "reserve_full={reserve_full}");
            assert!(
                r.peak_kv_bytes <= 2 * one,
                "{} > {} (reserve_full={reserve_full})",
                r.peak_kv_bytes,
                2 * one
            );
        }
        // Full reservation caps concurrency at the reservation count;
        // paged admission packs more residents into the same budget.
        let mut full = BatcherConfig::new(4, 2 * one);
        full.reserve_full = true;
        let rf = run_cfg(&cfg, &p, &Workload::uniform(6, 16, 8), full);
        assert!(rf.avg_batch_occupancy <= 2.0 + 1e-9);
        assert_eq!(rf.preemptions, 0, "reservations never need eviction");
    }

    #[test]
    fn paged_admission_beats_full_reservation_occupancy() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Short prompts, long generations: reservations are mostly air.
        let w = Workload::uniform(8, 16, 48);
        let budget = Request::new(0, 16, 48).kv_bytes(&cfg) * 2;
        let mut full = BatcherConfig::new(8, budget);
        full.reserve_full = true;
        let paged = BatcherConfig::new(8, budget);
        let rf = run_cfg(&cfg, &p, &w, full);
        let rp = run_cfg(&cfg, &p, &w, paged);
        assert_eq!(rf.completed, 8);
        assert_eq!(rp.completed, 8);
        assert!(
            rp.avg_batch_occupancy > rf.avg_batch_occupancy,
            "paged {} vs reserved {}",
            rp.avg_batch_occupancy,
            rf.avg_batch_occupancy
        );
        assert!(rp.total_seconds < rf.total_seconds);
    }

    #[test]
    fn oversized_request_rejected_not_wedged() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let mut w = Workload::uniform(2, 16, 8);
        w.requests.push(Request::new(2, 100_000, 8));
        let budget = w.requests[0].kv_bytes(&cfg) * 4;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(4, budget));
        assert_eq!(r.completed, 2);
        assert_eq!(r.rejected, vec![2]);
    }

    #[test]
    fn latency_ordering_sane() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        let r = tiny_batcher(&cfg, &p, 8, budget);
        for s in &r.per_request {
            assert!(s.admitted_s <= s.ttft_s, "{s:?}");
            assert!(s.ttft_s <= s.latency_s, "{s:?}");
        }
        assert!(r.ttft_p50_s <= r.ttft_p99_s);
        assert!(r.latency_p50_s <= r.latency_p99_s);
        assert!(r.latency_mean_s <= r.total_seconds);
        // Decode-only throughput excludes prefill stalls, so it can only
        // be faster than the end-to-end rate.
        assert!(r.decode_tokens_per_s >= r.tokens_per_s);
    }

    #[test]
    fn prefill_only_requests_excluded_from_ttft_aggregates() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let mut w = Workload::uniform(2, 16, 4);
        w.requests.push(Request::new(2, 16, 0));
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(1, budget));
        assert_eq!(r.completed, 3);
        // Serial admission (max_batch 1) finishes the prefill-only
        // request last, so including it would inflate p99; the TTFT
        // percentiles must cover only the two generating requests.
        let max_gen_ttft = r
            .per_request
            .iter()
            .filter(|s| s.gen_tokens > 0)
            .map(|s| s.ttft_s)
            .fold(0.0, f64::max);
        assert_eq!(r.ttft_p99_s, max_gen_ttft);
        assert!(r.ttft_mean_s <= max_gen_ttft);
    }

    #[test]
    fn bigger_batch_serves_faster() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(8, 16, 16);
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let serial = run_cfg(&cfg, &p, &w, BatcherConfig::new(1, budget));
        let batched = run_cfg(&cfg, &p, &w, BatcherConfig::new(8, budget));
        assert!(
            batched.total_seconds < serial.total_seconds,
            "batched {} vs serial {}",
            batched.total_seconds,
            serial.total_seconds
        );
        assert!(batched.tokens_per_s > serial.tokens_per_s);
        assert!(batched.avg_batch_occupancy > serial.avg_batch_occupancy);
    }

    #[test]
    fn chunked_prefill_conserves_tokens_and_counts_chunks() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(3, 100, 4);
        let budget = Request::new(0, 100, 4).kv_bytes(&cfg) * 4;
        let mut opts = BatcherConfig::new(4, budget);
        opts.prefill_chunk = 32;
        let r = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(r.completed, 3);
        assert_eq!(r.preemptions, 0);
        // Conservation: every prompt token prefilled exactly once.
        assert_eq!(r.prefill_tokens, 3 * 100);
        // 100 tokens in 32-token chunks = 4 chunks per request.
        assert_eq!(r.prefill_chunks, 3 * 4);
    }

    #[test]
    fn priority_class_zero_beats_class_two_on_ttft() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // 8 identical requests, alternating urgent/patient, one slot.
        let mut w = Workload::uniform(8, 32, 8);
        for r in &mut w.requests {
            r.class = if r.id % 2 == 0 { 0 } else { 2 };
        }
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let mut opts = BatcherConfig::new(1, budget);
        opts.aging_promote_s = 1e6; // effectively no aging in this trace
        let r = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(r.completed, 8);
        assert_eq!(r.per_class.len(), 2);
        let c0 = &r.per_class[0];
        let c2 = &r.per_class[1];
        assert_eq!((c0.class, c2.class), (0, 2));
        assert!(
            c0.ttft_p99_s < c2.ttft_p99_s,
            "urgent {} vs patient {}",
            c0.ttft_p99_s,
            c2.ttft_p99_s
        );
        // All class-0 requests finish before any class-2 request starts
        // decoding (single slot, strict priority, no aging).
        let worst_urgent = c0.latency_p99_s;
        let best_patient = r
            .per_request
            .iter()
            .filter(|s| s.class == 2)
            .map(|s| s.ttft_s)
            .fold(f64::MAX, f64::min);
        assert!(worst_urgent <= best_patient);
    }

    #[test]
    fn aging_promotes_waiting_requests() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // A patient request queued behind a stream of urgent ones: with
        // aggressive aging it must be admitted before the urgent tail.
        let mut w = Workload::uniform(9, 32, 8);
        for r in &mut w.requests {
            r.class = if r.id == 0 { 3 } else { 0 };
        }
        let budget = w.requests[0].kv_bytes(&cfg) * 9;
        let mut opts = BatcherConfig::new(1, budget);
        opts.aging_promote_s = 1e-6; // promotes one class every 1000 cycles
        let aged = run_cfg(&cfg, &p, &w, opts);
        let patient_aged = aged.per_request.iter().find(|s| s.id == 0).unwrap();
        let mut no_aging = BatcherConfig::new(1, budget);
        no_aging.aging_promote_s = 0.0;
        let strict = run_cfg(&cfg, &p, &w, no_aging);
        let patient_strict = strict.per_request.iter().find(|s| s.id == 0).unwrap();
        assert!(
            patient_aged.admitted_s < patient_strict.admitted_s,
            "aging must cut the patient request's queue wait: {} vs {}",
            patient_aged.admitted_s,
            patient_strict.admitted_s
        );
    }

    #[test]
    fn poisson_arrivals_respected() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(6, 16, 8).with_poisson_arrivals(11, 50.0);
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(4, budget));
        assert_eq!(r.completed, 6);
        for s in &r.per_request {
            let arrival_s = w.requests[s.id].arrival_ns as f64 / 1e9;
            assert!((s.arrival_s - arrival_s).abs() < 1e-6, "{s:?}");
        }
        // The trace cannot finish before the last arrival.
        let last = w.requests.iter().map(|r| r.arrival_ns).max().unwrap();
        assert!(r.total_seconds >= last as f64 / 1e9);
    }

    #[test]
    fn preemption_recomputes_and_completes() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Long generations against a pool sized for ~1.2 full caches:
        // decode growth must evict and recompute, yet everyone finishes.
        let w = Workload::uniform(3, 16, 64);
        let budget = Request::new(0, 16, 64).kv_bytes(&cfg) * 12 / 10;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(3, budget));
        assert_eq!(r.completed, 3, "{:?}", r.rejected);
        assert_eq!(r.gen_tokens, 3 * 64);
        assert!(r.preemptions > 0, "pool pressure must trigger eviction");
        // Recompute re-prefills prompt + produced tokens.
        assert!(r.prefill_tokens > 3 * 16);
        assert!(r.peak_kv_bytes <= budget);
        let preempted: u32 = r.per_request.iter().map(|s| s.preemptions).sum();
        assert_eq!(preempted as u64, r.preemptions);
    }
}
