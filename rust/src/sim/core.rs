//! Single Snitch-core instruction timing (paper Sec. IV-A).
//!
//! The core is a single-issue in-order RV32 pipeline coupled to a 64-bit
//! SIMD FPU. The two ISA extensions the paper ablates shape the inner loop:
//!
//! * **Xssr** — stream semantic registers: operand loads become implicit
//!   register reads, removing the 2 explicit loads per FMA.
//! * **Xfrep** — hardware loop buffer: removes the per-iteration index
//!   update + compare + branch overhead and frees the integer pipe.
//!
//! With both on, the inner loop of a dot product is literally one `fmadd`
//! per cycle (per SIMD lane), so FPU utilization approaches 90% — the
//! mechanism behind the 4.1-5.0x "optimized FP64" bars of Fig. 7/8.

use crate::arch::{ClusterConfig, Features, FpFormat};

/// Per-element cycle cost of transcendental/elementwise FP32 ops in
/// software on Snitch (no hardware exp/div). Used by softmax/layernorm/
/// GELU models.
pub mod opcost {
    /// exp() via polynomial + scaling (softmax).
    pub const EXP: u64 = 22;
    /// Division (softmax normalize, layernorm).
    pub const DIV: u64 = 12;
    /// sqrt / rsqrt (layernorm).
    pub const SQRT: u64 = 14;
    /// Pack/unpack + convert between FP32 and a narrow format, per element
    /// (amortized over SIMD, conversions are vectorized 1 elem/lane/cycle).
    pub const CONVERT: u64 = 1;
    /// Polynomial i-GELU (few FMAs + select), per element.
    pub const IGELU: u64 = 8;
    /// Max/add/mul style simple vector op, per element.
    pub const SIMPLE: u64 = 1;
}

/// Timing model of one compute core under a given feature set.
#[derive(Debug, Clone, Copy)]
pub struct CoreModel {
    pub cluster: ClusterConfig,
    pub features: Features,
}

impl CoreModel {
    pub fn new(cluster: ClusterConfig, features: Features) -> CoreModel {
        CoreModel { cluster, features }
    }

    /// Effective SIMD lanes for `fmt` (1 when the SIMD feature is ablated;
    /// the baseline implementation issues scalar FP64-datapath ops).
    pub fn lanes(&self, fmt: FpFormat) -> u64 {
        if self.features.simd {
            fmt.simd_lanes()
        } else {
            1
        }
    }

    /// Cycles for one dot product of length `k` on this core (the GEMM
    /// inner loop), including stream setup and pipeline drain.
    pub fn dot_cycles(&self, k: u64, fmt: FpFormat) -> u64 {
        if k == 0 {
            return 0;
        }
        let lanes = self.lanes(fmt);
        let iters = k.div_ceil(lanes);
        let c = &self.cluster;
        // Issue cost of one FMA iteration.
        let mut per_iter = 1;
        if !self.features.xssr {
            // Two explicit operand loads on the single-issue core.
            per_iter += 2 * c.load_cycles_per_op;
        }
        if !self.features.xfrep {
            // Software loop: index update + compare + branch.
            per_iter += c.loop_overhead_cycles;
        }
        let mut cycles = iters * per_iter;
        // RAW stalls: the kernel library unrolls by `unroll` accumulators to
        // cover the FPU latency; without FREP+SSR the loop body is long
        // enough that the latency is already hidden by the overhead.
        if self.features.xfrep && self.features.xssr {
            // Drain of the unrolled accumulator chain + final reduction of
            // `unroll` partial sums.
            cycles += c.fpu_latency + c.unroll;
        } else if iters < c.fpu_latency {
            cycles += c.fpu_latency - iters;
        }
        // Stream/loop configuration before the first FMA.
        cycles += self.setup_cycles();
        cycles
    }

    /// Setup cost before an inner loop can issue (SSR/FREP config, or plain
    /// loop prologue).
    pub fn setup_cycles(&self) -> u64 {
        if self.features.xssr || self.features.xfrep {
            self.cluster.ssr_setup_cycles
        } else {
            3
        }
    }

    /// Cycles for a `rows x cols` GEMM tile slice with dot length `k` on
    /// ONE core. Setup is paid once per tile (the SSR address generator
    /// re-streams without reconfiguration), and the accumulator-chain
    /// drain is paid once per output row: consecutive output elements keep
    /// independent accumulators in flight, so the FPU pipeline never
    /// bubbles between dots — only at row boundaries.
    pub fn row_dots_cycles(&self, rows: u64, cols: u64, k: u64, fmt: FpFormat) -> u64 {
        if rows == 0 || cols == 0 || k == 0 {
            return 0;
        }
        let lanes = self.lanes(fmt);
        let iters = k.div_ceil(lanes);
        let c = &self.cluster;
        let mut per_iter = 1;
        if !self.features.xssr {
            per_iter += 2 * c.load_cycles_per_op;
        }
        if !self.features.xfrep {
            per_iter += c.loop_overhead_cycles;
        }
        let mut cycles = self.setup_cycles() + rows * cols * iters * per_iter;
        if self.features.xfrep && self.features.xssr {
            cycles += rows * (c.fpu_latency + c.unroll);
            // Sustained-rate derate (bank conflicts, SSR rewinds, loop
            // nest): only the streamed fast path is near enough to ideal
            // for this to matter; the baseline's overheads are explicit.
            cycles = (cycles as f64 / c.compute_efficiency).ceil() as u64;
        }
        cycles
    }

    /// Cycles for a vectorizable elementwise pass over `n` elements with a
    /// per-element op cost of `op_cycles` (FP32 datapath: softmax exp,
    /// conversions, GELU polynomial...). SSR streaming removes the
    /// load/store overhead; SIMD divides by lanes for simple ops but NOT
    /// for the iterative software routines (exp/div/sqrt), which are
    /// scalar FP32 loops.
    pub fn elementwise_cycles(
        &self,
        n: u64,
        op_cycles: u64,
        fmt: FpFormat,
        vectorizable: bool,
    ) -> u64 {
        if n == 0 {
            return 0;
        }
        let lanes = if vectorizable { self.lanes(fmt) } else { 1 };
        let iters = n.div_ceil(lanes);
        let mut per_iter = op_cycles;
        if !self.features.xssr {
            per_iter += 2 * self.cluster.load_cycles_per_op;
        }
        if !self.features.xfrep {
            per_iter += self.cluster.loop_overhead_cycles;
        }
        self.setup_cycles() + iters * per_iter
    }

    /// Cycles for a row reduction (sum/max) of length `k` (layernorm
    /// statistics, softmax row max/sum). Streams at 1 elem/lane/cycle.
    pub fn reduction_cycles(&self, k: u64, fmt: FpFormat) -> u64 {
        // Same structure as a dot product without the second operand.
        self.dot_cycles(k, fmt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn optimized() -> CoreModel {
        CoreModel::new(ClusterConfig::default(), Features::all())
    }

    fn baseline() -> CoreModel {
        CoreModel::new(ClusterConfig::default(), Features::none())
    }

    #[test]
    fn optimized_dot_is_one_fma_per_cycle() {
        // Long FP64 dot: cycles/iter -> 1 (utilization -> 90%+, Sec. IV-A).
        let m = optimized();
        let k = 10_000;
        let cycles = m.dot_cycles(k, FpFormat::Fp64);
        let per_iter = cycles as f64 / k as f64;
        assert!(per_iter < 1.05, "per-iter {per_iter} should approach 1.0");
    }

    #[test]
    fn baseline_dot_is_about_5x_slower() {
        // 2 loads (2 cy each) + fma + loop overhead = ~5x one FMA/cycle:
        // this is the paper's 4.1-5.0x extension speedup (Fig. 7/8).
        let k = 4096;
        let base = baseline().dot_cycles(k, FpFormat::Fp64);
        let opt = optimized().dot_cycles(k, FpFormat::Fp64);
        let ratio = base as f64 / opt as f64;
        assert!((4.0..=7.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn simd_scales_dot_throughput() {
        let m = optimized();
        let k = 8192;
        let f64c = m.dot_cycles(k, FpFormat::Fp64) as f64;
        let f32c = m.dot_cycles(k, FpFormat::Fp32) as f64;
        let f16c = m.dot_cycles(k, FpFormat::Fp16) as f64;
        let f8c = m.dot_cycles(k, FpFormat::Fp8) as f64;
        assert!((1.8..=2.1).contains(&(f64c / f32c)));
        assert!((1.8..=2.1).contains(&(f32c / f16c)));
        assert!((1.8..=2.1).contains(&(f16c / f8c)));
    }

    #[test]
    fn no_simd_in_baseline() {
        let m = baseline();
        let k = 1024;
        // Baseline ablation may not exploit packed SIMD: FP8 as slow as FP64.
        assert_eq!(m.dot_cycles(k, FpFormat::Fp8), m.dot_cycles(k, FpFormat::Fp64));
    }

    #[test]
    fn ssr_only_and_frep_only_are_intermediate() {
        let k = 4096;
        let base = baseline().dot_cycles(k, FpFormat::Fp64);
        let opt = optimized().dot_cycles(k, FpFormat::Fp64);
        let ssr_only = CoreModel::new(
            ClusterConfig::default(),
            Features { xssr: true, ..Features::none() },
        )
        .dot_cycles(k, FpFormat::Fp64);
        let frep_only = CoreModel::new(
            ClusterConfig::default(),
            Features { xfrep: true, ..Features::none() },
        )
        .dot_cycles(k, FpFormat::Fp64);
        assert!(opt < ssr_only && ssr_only < base);
        assert!(opt < frep_only && frep_only < base);
    }

    #[test]
    fn elementwise_scalar_vs_vector() {
        let m = optimized();
        let vec = m.elementwise_cycles(1024, 1, FpFormat::Fp8, true);
        let scal = m.elementwise_cycles(1024, 1, FpFormat::Fp8, false);
        assert!(scal > 7 * vec, "scalar {scal} vs vector {vec}");
    }

    #[test]
    fn zero_work_is_free() {
        let m = optimized();
        assert_eq!(m.dot_cycles(0, FpFormat::Fp32), 0);
        assert_eq!(m.elementwise_cycles(0, 5, FpFormat::Fp32, true), 0);
        assert_eq!(m.row_dots_cycles(0, 8, 8, FpFormat::Fp32), 0);
    }
}
