"""Pure-jnp correctness oracles for every Pallas kernel in this package.

These are the ground truth the pytest suite compares the Pallas kernels
against (L1 correctness signal). They intentionally use the most direct
jnp formulation — no tiling, no online softmax — so a bug in the tiled
kernels cannot be replicated here.
"""

import jax
import jax.numpy as jnp

# i-GELU polynomial coefficients (Kim et al., I-BERT). erf(x) is
# approximated on |x| <= -b by sign(x) * (a*(|x|+b)^2 + c) with:
IGELU_A = -0.2888
IGELU_B = -1.769
IGELU_C = 1.0


def gemm(a, b, alpha=1.0):
    """C = alpha * A @ B, accumulating in fp32."""
    acc = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    return (alpha * acc).astype(a.dtype)


def softmax(x, axis=-1):
    """Numerically-stable softmax in fp32 (the paper keeps softmax at FP32)."""
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=axis, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


def layernorm(x, gamma, beta, eps=1e-5):
    """Row-wise layer normalization; statistics in fp32."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) / jnp.sqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def i_erf(x):
    """I-BERT polynomial approximation of erf, evaluated in fp32."""
    x = x.astype(jnp.float32)
    sign = jnp.sign(x)
    ax = jnp.minimum(jnp.abs(x), -IGELU_B)
    l = IGELU_A * (ax + IGELU_B) ** 2 + IGELU_C
    return sign * l


def i_gelu(x):
    """i-GELU: x * 0.5 * (1 + i_erf(x / sqrt(2))) — the paper's GELU.

    Polynomial-only (no tanh, no division) as in Kim et al. [46].
    """
    x32 = x.astype(jnp.float32)
    return (x32 * 0.5 * (1.0 + i_erf(x32 / jnp.sqrt(2.0).astype(jnp.float32)))).astype(
        x.dtype
    )


def attention(q, k, v, causal=False, scale=None):
    """Plain O(S^2) scaled-dot-product attention, one head.

    q: [Sq, P], k: [Skv, P], v: [Skv, P]. Softmax in fp32.
    """
    p = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(p))
    s = jnp.matmul(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    if causal:
        sq, skv = s.shape
        # Query i (global position i + Skv - Sq) attends to keys 0..pos.
        offset = skv - sq
        mask = jnp.tril(jnp.ones((sq, skv), dtype=bool), k=offset)
        s = jnp.where(mask, s, -jnp.inf)
    a = softmax(s, axis=-1)
    return jnp.matmul(a.astype(jnp.float32), v.astype(jnp.float32)).astype(q.dtype)


def mha(x1, x2, wq, wk, wv, wo, n_heads, causal=False):
    """Full multi-head attention: projections, per-head attention, concat, out proj.

    x1: [S1, E], x2: [S2, E]; wq/wk/wv: [E, H*P]; wo: [H*P, E].
    """
    s1, e = x1.shape
    hp = wq.shape[1]
    p = hp // n_heads
    q = gemm(x1, wq).reshape(s1, n_heads, p)
    k = gemm(x2, wk).reshape(x2.shape[0], n_heads, p)
    v = gemm(x2, wv).reshape(x2.shape[0], n_heads, p)
    heads = []
    for h in range(n_heads):
        heads.append(attention(q[:, h], k[:, h], v[:, h], causal=causal))
    cat = jnp.concatenate(heads, axis=-1)
    return gemm(cat, wo)


def mlp(x, w1, b1, w2, b2):
    """Transformer MLP: Linear -> i-GELU -> Linear."""
    h = gemm(x, w1) + b1.astype(x.dtype)
    h = i_gelu(h)
    return gemm(h, w2) + b2.astype(x.dtype)


def transformer_block(x, params, n_heads, causal=False):
    """Pre-LN transformer block as used by both ViT and GPT model families."""
    h = layernorm(x, params["ln1_g"], params["ln1_b"])
    h = mha(h, h, params["wq"], params["wk"], params["wv"], params["wo"], n_heads,
            causal=causal)
    x = x + h
    h = layernorm(x, params["ln2_g"], params["ln2_b"])
    h = mlp(h, params["w1"], params["b1"], params["w2"], params["b2"])
    return x + h
