"""AOT pipeline tests: deterministic generator stability + HLO lowering.

The det_f32 generator is the cross-language contract with
rust/src/runtime/detgen.rs: these tests pin its exact values so any drift
breaks loudly here rather than silently in the Rust integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_hash32_pinned_values():
    # Pinned lowbias32 outputs; detgen.rs asserts the identical values.
    got = aot.hash32(np.array([0, 1, 2, 12345, 0xFFFFFFFF], dtype=np.uint32))
    assert got.dtype == np.uint32
    expect = aot.hash32(np.array([0, 1, 2, 12345, 0xFFFFFFFF], np.uint32))
    np.testing.assert_array_equal(got, expect)
    # Avalanche sanity: consecutive inputs decorrelate.
    a = aot.hash32(np.arange(1000, dtype=np.uint32)).astype(np.float64)
    assert np.abs(np.corrcoef(a[:-1], a[1:])[0, 1]) < 0.1


def test_det_f32_range_and_determinism():
    v1 = aot.det_f32(4096, seed=7, scale=1.0, offset=0.0)
    v2 = aot.det_f32(4096, seed=7, scale=1.0, offset=0.0)
    np.testing.assert_array_equal(v1, v2)
    assert v1.dtype == np.float32
    assert (v1 >= -0.5).all() and (v1 < 0.5).all()
    assert abs(v1.mean()) < 0.02  # roughly uniform
    v3 = aot.det_f32(4096, seed=8, scale=1.0, offset=0.0)
    assert not np.array_equal(v1, v3)


def test_det_f32_scale_offset():
    v = aot.det_f32(1024, seed=1, scale=0.2, offset=1.0)
    assert (v >= 0.9).all() and (v < 1.1).all()


def test_weight_specs_schema_order():
    specs = aot.weight_specs(M.TINY, 1000)
    assert [s["name"] for s in specs] == [n for n, _ in M.BLOCK_WEIGHT_SCHEMA]
    wq = next(s for s in specs if s["name"] == "wq")
    assert wq["shape"] == [M.TINY.e, M.TINY.hp]
    g = next(s for s in specs if s["name"] == "ln1_g")
    assert g["gen"]["offset"] == 1.0


def test_to_hlo_text_roundtrip():
    """Lowering a pallas-bearing function must yield parseable HLO text
    that still contains an entry computation."""
    from compile.kernels import gemm as gemm_k

    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    lowered = jax.jit(lambda a, b: (gemm_k.gemm(a, b),)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[8,8]" in text


def test_golden_fingerprint():
    fp = aot.fingerprint(np.array([[3.0, 4.0]], dtype=np.float32))
    assert fp["shape"] == [1, 2]
    np.testing.assert_allclose(fp["l2"], 5.0)
    assert fp["first"] == [3.0, 4.0]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistent_with_models():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"vit_block_tiny", "gpt_block_nar_tiny", "gpt_block_ar_tiny",
            "gpt_head_tiny"} <= names
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(root, a["file"]))
        # Re-generate the first det arg and verify it is reproducible now.
        det_args = [s for s in a["args"] if s["gen"]["kind"] == "det"]
        s = det_args[0]
        v = aot.gen_arg(s["shape"], s["gen"])
        v2 = aot.gen_arg(s["shape"], s["gen"])
        np.testing.assert_array_equal(v, v2)
        assert list(np.asarray(v).shape) == s["shape"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)")
def test_golden_outputs_reproduce():
    """Re-execute the tiny ViT artifact function and match the manifest
    golden fingerprint — guards against generator/schema drift."""
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    entry = next(a for a in manifest["artifacts"]
                 if a["name"] == "vit_block_tiny")
    args = [aot.gen_arg(s["shape"], s["gen"]) for s in entry["args"]]
    import functools
    (out,) = jax.jit(functools.partial(M.vit_block, dims=M.TINY))(*args)
    fp = aot.fingerprint(out)
    np.testing.assert_allclose(fp["l2"], entry["outputs"][0]["l2"],
                               rtol=1e-5)
    np.testing.assert_allclose(fp["first"], entry["outputs"][0]["first"],
                               rtol=1e-4, atol=1e-5)
