"""Tiled GEMM Pallas kernel mirroring the paper's spatio-temporal tiling.

The paper (Sec. V-A1) tiles C = alpha * A @ B spatially over clusters on M
and temporally on K so that one (bm, bk) tile of A, one (bk, bn) tile of B
and the (bm, bn) accumulator fit the 128 kB cluster SPM, with the inner
dot-product running on FREP+SSR. Here BlockSpec expresses the same HBM<->SPM
schedule: grid = (M/bm, N/bn, K/bk) with the K axis innermost (sequential),
accumulating into an fp32 scratch tile — the analogue of the paper's partial
C accumulation across temporal tiles t_0..t_E.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import pick_block
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, alpha, k_tiles):
    """One (bm, bn) output tile; invoked k_tiles times along the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == k_tiles - 1)
    def _store():
        o_ref[...] = (alpha * acc_ref[...]).astype(o_ref.dtype)



@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "alpha"))
def gemm(a, b, bm=64, bn=64, bk=64, alpha=1.0):
    """C = alpha * A @ B with (bm, bn, bk) SPM-resident tiles.

    a: [M, K], b: [K, N] -> [M, N] in a.dtype, fp32 accumulation (the
    analogue of Snitch's expanding SIMD dot product, which accumulates
    FP8/FP16 inputs at higher precision).

    Block sizes are clamped to divisors of the problem dims so every grid
    step maps to a full tile.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    k_tiles = k // bk
    grid = (m // bm, n // bn, k_tiles)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, alpha=alpha, k_tiles=k_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(a, b)


def spm_footprint_bytes(bm, bn, bk, itemsize):
    """SPM bytes a double-buffered (bm, bn, bk) GEMM tile set occupies.

    Mirrors rust/src/tiling: 2x (A tile + B tile) input buffers (double
    buffering) + fp32 accumulator + output tile.
    """
    a_t = bm * bk * itemsize
    b_t = bk * bn * itemsize
    acc = bm * bn * 4
    out = bm * bn * itemsize
    return 2 * (a_t + b_t) + acc + out
