//! Batch-aware pricing + serving coordinator invariants:
//!
//! * b = 1 prices identically to the single-request path (the refactor
//!   must not move any legacy number),
//! * batched AR FPU utilization is monotonically non-decreasing in b,
//! * the batcher never admits more KV bytes than the budget,
//! * serving reports are internally consistent.

mod common;

use common::Rng;
use snitch_fm::arch::{FpFormat, MemLevel, PlatformConfig};
use snitch_fm::coordinator::schedule::{
    block_cost, block_cost_batched, layer_cost, model_cost, model_cost_batched,
};
use snitch_fm::coordinator::{
    BatcherConfig, ContinuousBatcher, InferenceEngine, Request, Workload,
};
use snitch_fm::kernels;
use snitch_fm::kernels::gemm::OperandHome;
use snitch_fm::metrics;
use snitch_fm::model::{block_layers, Family, LayerKind, Mode, ModelConfig};

fn random_cfg(rng: &mut Rng) -> ModelConfig {
    let heads = rng.pick(&[4u64, 8, 12, 16]);
    ModelConfig {
        name: "prop".into(),
        family: Family::Gpt,
        blocks: rng.next(1, 4),
        e: rng.pick(&[256u64, 512, 768, 1024]),
        p: rng.pick(&[32u64, 64, 128]),
        heads,
        ff: rng.pick(&[512u64, 1024, 4096]),
        seq: 256,
    }
}

#[test]
fn b1_prices_identically_to_single_request_path() {
    let p = PlatformConfig::occamy();
    let mut rng = Rng(0xB1);
    for _ in 0..25 {
        let cfg = random_cfg(&mut rng);
        let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8]);
        let (mode, s, kv) = if rng.next(0, 1) == 0 {
            (Mode::Nar, rng.next(32, 512), 0)
        } else {
            (Mode::Ar, 1, rng.next(16, 1024))
        };
        let single = block_cost(&cfg, mode, s, kv, fmt, &p);
        let batched = block_cost_batched(&cfg, mode, 1, s, kv, fmt, &p);
        assert_eq!(single.total, batched.total, "{cfg:?} {mode:?} {fmt}");
        assert_eq!(single.cycles, batched.cycles);
        let seq = if mode == Mode::Nar { s } else { kv };
        let m1 = model_cost(&cfg, mode, seq, fmt, &p);
        let mb = model_cost_batched(&cfg, mode, 1, seq, fmt, &p);
        assert_eq!(m1.total, mb.total);
    }
}

#[test]
fn unified_layer_dispatch_matches_direct_kernel_calls() {
    // The old schedule had two FusedConcatLinear dispatch sites (one of
    // them guessing P from K); the unified path must price every layer
    // exactly as a direct kernel call with the exact geometry. GEMM
    // layers dispatch on stacked rows alone: below the skinny threshold
    // (16 * clusters rows) the cheaper of the M-split and N-split
    // schedules wins, independent of the batch dimension.
    let p = PlatformConfig::occamy();
    for cfg in [ModelConfig::vit_b(), ModelConfig::gpt_j(), ModelConfig::tiny()] {
        for (mode, s, kv) in [(Mode::Nar, cfg.seq, 0), (Mode::Ar, 1, 256)] {
            if cfg.family == Family::Vit && mode == Mode::Ar {
                continue;
            }
            for layer in block_layers(&cfg, mode, s, kv) {
                let fmt = FpFormat::Fp32;
                let got = layer_cost(&layer, fmt, &p);
                let want = match layer.kind {
                    LayerKind::Gemm => {
                        let home = OperandHome {
                            a: if layer.fused_input {
                                MemLevel::Spm
                            } else {
                                MemLevel::Hbm
                            },
                            b: MemLevel::Hbm,
                            c: MemLevel::Hbm,
                        };
                        let msplit =
                            kernels::gemm_cost(layer.m, layer.k, layer.n, fmt, &p, home);
                        if layer.m < p.total_clusters() as u64 * 16 {
                            let nsplit = kernels::gemv_cost(
                                layer.m, layer.k, layer.n, fmt, &p, home,
                            );
                            if nsplit.cycles < msplit.cycles {
                                nsplit
                            } else {
                                msplit
                            }
                        } else {
                            msplit
                        }
                    }
                    LayerKind::FlashAttention => kernels::flash_attention_cost(
                        cfg.heads, layer.n, layer.skv, cfg.p, fmt, layer.causal, &p,
                    ),
                    LayerKind::FusedConcatLinear => kernels::fused_concat_linear_cost(
                        layer.m, cfg.heads, cfg.p, layer.n, fmt, &p,
                    ),
                    LayerKind::Layernorm => {
                        kernels::layernorm_cost(layer.m, layer.k, fmt, &p)
                    }
                    LayerKind::Gelu => {
                        kernels::gelu_cost(layer.m, layer.k, fmt, layer.fused_input, &p)
                    }
                };
                assert_eq!(got, want, "{} {:?} {mode:?}", cfg.name, layer.label);
            }
        }
    }
}

#[test]
fn ar_utilization_monotone_in_batch() {
    let p = PlatformConfig::occamy();
    for (cfg, fmt) in [
        (ModelConfig::gpt_j(), FpFormat::Fp32),
        (ModelConfig::gpt_j(), FpFormat::Fp8),
        (ModelConfig::gpt3_xl(), FpFormat::Fp32),
    ] {
        let mut prev = 0.0;
        for b in [1u64, 2, 4, 8, 16, 32] {
            let mc = model_cost_batched(&cfg, Mode::Ar, b, 1024, fmt, &p);
            let util = metrics::fpu_utilization(&mc.total, fmt, &p);
            assert!(
                util >= prev,
                "{} {fmt} b={b}: util {util} < {prev}",
                cfg.name
            );
            prev = util;
        }
        // ...and the lift is substantial, heading for the NAR band.
        let one = model_cost_batched(&cfg, Mode::Ar, 1, 1024, fmt, &p);
        let u1 = metrics::fpu_utilization(&one.total, fmt, &p);
        assert!(prev > 5.0 * u1, "{}: b=32 util {prev} vs b=1 {u1}", cfg.name);
    }
}

#[test]
fn batched_flops_exactly_linear_in_b() {
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::gpt_j();
    let one = model_cost_batched(&cfg, Mode::Ar, 1, 512, FpFormat::Fp32, &p);
    for b in [2u64, 4, 8, 32] {
        let mb = model_cost_batched(&cfg, Mode::Ar, b, 512, FpFormat::Fp32, &p);
        assert_eq!(mb.total.flops, b * one.total.flops, "b={b}");
        // Batched cycles grow sublinearly: that is the amortization.
        assert!(mb.cycles < b * one.cycles, "b={b}");
    }
}

#[test]
fn batcher_never_exceeds_kv_budget() {
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::tiny();
    let mut rng = Rng(0xC0FFEE);
    for _ in 0..20 {
        let n = rng.next(1, 12) as usize;
        let w = Workload::synthetic(rng.next(1, 1 << 30), n, (8, 64), (4, 32));
        let one = w.requests.iter().map(|r| r.kv_bytes(&cfg)).max().unwrap();
        let budget = one * rng.next(1, 4);
        let max_batch = rng.next(1, 8) as usize;
        let mut opts = BatcherConfig::new(max_batch, budget);
        opts.prefill_chunk = rng.next(0, 24);
        opts.page_tokens = rng.next(1, 32);
        opts.reserve_full = rng.next(0, 1) == 1;
        let b = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts);
        let r = b.run(&w);
        assert!(
            r.peak_kv_bytes <= budget,
            "peak {} > budget {budget} ({opts:?})",
            r.peak_kv_bytes
        );
        assert!(r.avg_batch_occupancy <= max_batch as f64 + 1e-9);
        assert_eq!(r.completed + r.rejected.len(), n, "no request lost ({opts:?})");
        assert_eq!(
            r.gen_tokens,
            w.requests
                .iter()
                .filter(|q| !r.rejected.contains(&q.id))
                .map(|q| q.gen_tokens)
                .sum::<u64>(),
            "every admitted request generates exactly its tokens ({opts:?})"
        );
    }
}

#[test]
fn serve_report_consistent_end_to_end() {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::gpt_j();
    let w = Workload::uniform(32, 1024, 64);
    let r = e.serve(&cfg, &w, 8, FpFormat::Fp8);
    assert_eq!(r.completed, 32);
    assert!(r.rejected.is_empty());
    assert_eq!(r.gen_tokens, 32 * 64);
    assert_eq!(r.prefill_tokens, 32 * 1024);
    assert!(r.ttft_p50_s <= r.ttft_p99_s);
    assert!(r.latency_p50_s <= r.latency_p99_s);
    assert!(r.ttft_mean_s <= r.latency_mean_s);
    assert!(r.decode_tokens_per_s >= r.tokens_per_s);
    assert!(r.avg_batch_occupancy > 1.0, "{}", r.avg_batch_occupancy);
    // Serving at batch 8 must beat 32 sequential run_generate calls.
    let serial = e.run_generate(&cfg, 1024, 64, FpFormat::Fp8);
    let serial_tokens_per_s = serial.throughput;
    assert!(
        r.tokens_per_s > 2.0 * serial_tokens_per_s,
        "serving {} vs serial {serial_tokens_per_s}",
        r.tokens_per_s
    );
    // Utilization climbs well above the single-request AR ceiling.
    let single = e.run_ar_step(&cfg, 1024, FpFormat::Fp8);
    assert!(r.fpu_utilization > 2.0 * single.fpu_utilization);
}

#[test]
fn run_batch_b1_equals_run_generate() {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::tiny();
    let a = e.run_generate(&cfg, 32, 8, FpFormat::Fp32);
    let b = e.run_batch(&cfg, 1, 32, 8, FpFormat::Fp32);
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.throughput, b.throughput);
    assert_eq!(a.decode_throughput, b.decode_throughput);
}

#[test]
fn rejected_oversize_request_reported() {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::gpt_j();
    let mut w = Workload::uniform(2, 128, 16);
    // A single request whose KV cache alone dwarfs the HBM budget.
    w.requests.push(Request::new(2, 40_000_000, 1));
    let r = e.serve(&cfg, &w, 4, FpFormat::Fp8);
    assert_eq!(r.completed, 2);
    assert_eq!(r.rejected, vec![2]);
}
