//! Throughput / utilization metrics (the quantities the paper reports).

pub mod sketch;

use crate::arch::{FpFormat, PlatformConfig};
use crate::sim::KernelCost;

/// Achieved GFLOPS of a priced kernel/model on the platform.
pub fn achieved_gflops(cost: &KernelCost, platform: &PlatformConfig) -> f64 {
    if cost.cycles == 0 {
        return 0.0;
    }
    cost.flops as f64 / cost.cycles as f64 * platform.freq_ghz
}

/// FPU utilization = achieved / peak throughput (paper Table III/IV:
/// "the ratio between the throughput achieved and the ideal maximum
/// throughput of the platform").
pub fn fpu_utilization(cost: &KernelCost, fmt: FpFormat, platform: &PlatformConfig) -> f64 {
    let peak = platform.peak_gflops(fmt);
    if peak == 0.0 {
        return 0.0;
    }
    achieved_gflops(cost, platform) / peak
}

/// Tokens/s for a NAR pass producing `s` tokens in `cycles`.
pub fn tokens_per_second_nar(s: u64, cycles: u64, platform: &PlatformConfig) -> f64 {
    if cycles == 0 {
        return 0.0;
    }
    s as f64 / platform.cycles_to_seconds(cycles)
}

/// Tokens/s for AR decode at `cycles` per token.
pub fn tokens_per_second_ar(cycles_per_token: u64, platform: &PlatformConfig) -> f64 {
    if cycles_per_token == 0 {
        return 0.0;
    }
    1.0 / platform.cycles_to_seconds(cycles_per_token)
}

/// Images/s for an encoder model at `cycles` per image.
pub fn images_per_second(cycles_per_image: u64, platform: &PlatformConfig) -> f64 {
    tokens_per_second_ar(cycles_per_image, platform)
}

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// A sample vector sorted once, answering any number of nearest-rank
/// percentile queries in O(1) each. The serving report reads four-plus
/// percentiles per metric (and per priority class) from the same data;
/// the free-function [`percentile`] re-sorted the samples on every call,
/// which dominated `ServeReport` construction on large traces.
#[derive(Debug, Clone)]
pub struct Percentiles {
    sorted: Vec<f64>,
}

impl Percentiles {
    /// Take ownership of the samples and sort them once. Uses
    /// `f64::total_cmp`, so NaN samples (which `partial_cmp` would panic
    /// on) sort to the end instead of aborting the whole report.
    pub fn new(mut xs: Vec<f64>) -> Percentiles {
        xs.sort_by(f64::total_cmp);
        Percentiles { sorted: xs }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Nearest-rank percentile (`q` in 0..=100); 0 for an empty sample.
    pub fn p(&self, q: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let rank = (q / 100.0 * self.sorted.len() as f64).ceil() as usize;
        self.sorted[rank.clamp(1, self.sorted.len()) - 1]
    }

    /// Arithmetic mean; 0 for an empty sample.
    pub fn mean(&self) -> f64 {
        mean(&self.sorted)
    }
}

/// Nearest-rank percentile (`q` in 0..=100); 0 for an empty slice.
/// One-shot convenience over [`Percentiles`] — sorts per call, so batch
/// queries over the same data should build a `Percentiles` instead.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    Percentiles::new(xs.to_vec()).p(q)
}

/// Effective HBM bandwidth in GB/s over the run.
pub fn hbm_bandwidth_gbps(cost: &KernelCost, platform: &PlatformConfig) -> f64 {
    if cost.cycles == 0 {
        return 0.0;
    }
    cost.hbm_bytes() as f64 / platform.cycles_to_seconds(cost.cycles) / 1e9
}

/// Fig. 1 traffic accounting: *unique tensor bytes* read from HBM by one
/// transformer block in NAR mode (the paper's 624 -> 384 MB annotation
/// counts tensors, not per-cluster DMA traffic — broadcast re-reads are
/// a platform artifact, not algorithmic traffic).
///
/// `fused`: the concat+linear runs on the c2c reduction tree, so neither
/// the per-head outputs nor the reduction partials touch HBM; unfused,
/// the concat tensor round-trips and the `C*G - 1` pairwise reduction
/// partials are read back through main memory.
pub fn fig1_unique_hbm_reads(
    cfg: &crate::model::ModelConfig,
    s: u64,
    fmt: FpFormat,
    fused: bool,
    platform: &PlatformConfig,
) -> u64 {
    let el = fmt.bytes();
    let weights = cfg.params_per_block() * el;
    let se = s * cfg.e * el;
    let shp = s * cfg.hp() * el;
    let sff = s * cfg.ff * el;
    // ln1 in + qkv in + Q,K,V + ln2 in + mlp-up in + mlp-down in.
    let activations = se + se + 3 * shp + se + se + sff;
    let mut reads = weights + activations;
    if !fused {
        // Concat tensor read back + tree-reduction partials via HBM.
        let partials = (platform.total_clusters() as u64).saturating_sub(1) * se;
        reads += shp + partials;
    }
    reads
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_bounds() {
        let p = PlatformConfig::occamy();
        // 512 FLOP/cycle at FP32 peak -> util 1.0 when achieving exactly that.
        let cost = KernelCost { cycles: 1000, flops: 512_000, ..Default::default() };
        let u = fpu_utilization(&cost, FpFormat::Fp32, &p);
        assert!((u - 1.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_conversions() {
        let p = PlatformConfig::occamy(); // 1 GHz
        assert_eq!(tokens_per_second_ar(1_000_000_000, &p), 1.0);
        assert_eq!(tokens_per_second_nar(1024, 1_000_000_000, &p), 1024.0);
        assert_eq!(images_per_second(500_000_000, &p), 2.0);
    }

    #[test]
    fn bandwidth() {
        let p = PlatformConfig::occamy();
        let cost = KernelCost {
            cycles: 1_000_000_000,
            hbm_read_bytes: 100_000_000_000,
            ..Default::default()
        };
        assert!((hbm_bandwidth_gbps(&cost, &p) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn fig1_fusion_saves_unique_traffic() {
        let p = PlatformConfig::occamy();
        let cfg = crate::model::ModelConfig::gpt_j();
        let fused = fig1_unique_hbm_reads(&cfg, 2048, FpFormat::Fp16, true, &p);
        let unfused = fig1_unique_hbm_reads(&cfg, 2048, FpFormat::Fp16, false, &p);
        let ratio = unfused as f64 / fused as f64;
        // Paper Fig. 1: 1.6x (624 -> 384 MB); our accounting: ~1.4-1.6x.
        assert!((1.2..=1.8).contains(&ratio), "ratio {ratio}");
        // Weights dominate the fused traffic.
        assert!(fused > cfg.params_per_block() * 2);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 99.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles_struct_matches_free_function() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0, 9.5, 0.25];
        let p = Percentiles::new(xs.to_vec());
        assert_eq!(p.len(), xs.len());
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(p.p(q), percentile(&xs, q), "q={q}");
        }
        assert_eq!(p.mean(), mean(&xs));
        let empty = Percentiles::new(Vec::new());
        assert!(empty.is_empty());
        assert_eq!(empty.p(50.0), 0.0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // Regression: the old sort used partial_cmp().unwrap(), which
        // aborted on any NaN latency sample. NaNs now sort last under
        // total_cmp, so finite percentiles below the NaN tail are sane.
        let xs = vec![2.0, f64::NAN, 1.0, 3.0];
        let p = Percentiles::new(xs);
        assert_eq!(p.len(), 4);
        assert_eq!(p.p(25.0), 1.0);
        assert_eq!(p.p(50.0), 2.0);
        assert!(p.p(100.0).is_nan());
    }

    #[test]
    fn zero_cycles_safe() {
        let p = PlatformConfig::occamy();
        let z = KernelCost::default();
        assert_eq!(achieved_gflops(&z, &p), 0.0);
        assert_eq!(fpu_utilization(&z, FpFormat::Fp8, &p), 0.0);
    }
}
