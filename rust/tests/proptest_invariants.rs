//! Property-based invariant tests over the coordinator substrate.
//!
//! The offline registry carries no proptest, so this file uses a small
//! seeded-LCG case generator (`cases`) — deterministic, shrink-free, but
//! sweeping hundreds of random parameter combinations per invariant.

mod common;

use common::Rng;
use snitch_fm::arch::{Features, FpFormat, MemLevel, PlatformConfig};
use snitch_fm::coordinator::schedule::{block_cost, model_cost};
use snitch_fm::coordinator::{
    layer_cost, BatcherConfig, ContinuousBatcher, FaultPlan, KvCache, KvExport, KvGeometry,
    LayerCostCache, PageTable, PagedKvAllocator, PrefixCache, Workload,
};
use snitch_fm::kernels::{flash_attention_cost, gemm_cost, layernorm_cost};
use snitch_fm::kernels::gemm::OperandHome;
use snitch_fm::model::{Layer, LayerKind, Mode, ModelConfig};
use snitch_fm::parallel::{
    serve_disaggregated_with_faults, serve_replicated_traced, serve_replicated_with_faults,
    RoutePolicy,
};
use snitch_fm::sim::noc;
use snitch_fm::trace::TraceSettings;
use snitch_fm::tiling::{plan_flash_attention, plan_gemm, plan_gemm_wide};

const CASES: usize = 300;

#[test]
fn gemm_plans_always_fit_spm_double_buffered() {
    let mut rng = Rng(1);
    for _ in 0..CASES {
        let m = rng.next(1, 8192);
        let k = rng.next(1, 16384);
        let n = rng.next(1, 16384);
        let fmt = rng.pick(&FpFormat::ALL);
        let clusters = rng.pick(&[1u32, 4, 8, 16]);
        let p = PlatformConfig::with_clusters(clusters);
        let plan = plan_gemm(m, k, n, fmt, &p);
        assert!(
            plan.spm_bytes(fmt, true) <= p.cluster.spm_bytes,
            "{fmt} {m}x{k}x{n} c{clusters}: {plan:?} = {}B",
            plan.spm_bytes(fmt, true)
        );
        assert!(plan.bm >= 1 && plan.bn >= 1 && plan.bk >= 1);
        assert!(plan.bm <= plan.rows.max(1) && plan.bn <= n && plan.bk <= k);
        // The plan's steps cover the whole per-cluster iteration space.
        let expect =
            plan.rows.div_ceil(plan.bm) * n.div_ceil(plan.bn) * k.div_ceil(plan.bk);
        assert_eq!(plan.steps, expect);
    }
}

#[test]
fn gemv_plans_fit_and_cover() {
    let mut rng = Rng(2);
    for _ in 0..CASES {
        let m = rng.next(1, 8);
        let k = rng.next(1, 16384);
        let n = rng.next(1, 32768);
        let fmt = rng.pick(&FpFormat::ALL);
        let p = PlatformConfig::occamy();
        let plan = plan_gemm_wide(m, k, n, fmt, &p);
        assert!(plan.spm_bytes(fmt, true) <= p.cluster.spm_bytes, "{plan:?}");
        assert!(plan.bn >= 1 && plan.bk >= 1);
    }
}

#[test]
fn fa_plans_fit_spm() {
    let mut rng = Rng(3);
    for _ in 0..CASES {
        let heads = rng.next(1, 32);
        let sq = rng.next(1, 4096);
        let skv = rng.next(1, 4096);
        let pdim = rng.pick(&[32u64, 64, 80, 128, 256]);
        let fmt = rng.pick(&FpFormat::ALL);
        let p = PlatformConfig::occamy();
        let plan = plan_flash_attention(heads, sq, skv, pdim, fmt, &p);
        assert!(
            plan.spm_bytes(pdim, fmt, true) <= p.cluster.spm_bytes,
            "h{heads} {sq}x{skv} p{pdim} {fmt}: {plan:?}"
        );
        assert_eq!(plan.kv_steps, skv.div_ceil(plan.bkv));
        assert_eq!(plan.q_steps, sq.div_ceil(plan.bq));
    }
}

#[test]
fn reduction_tree_delivers_every_partial_exactly_once() {
    for clusters in [1u32, 2, 4, 8, 16] {
        let p = if clusters <= 4 {
            PlatformConfig::with_clusters(clusters)
        } else {
            PlatformConfig::with_clusters(clusters)
        };
        let sched = noc::reduction_schedule(&p);
        // Union of senders = {1..n-1}; receiver of the last level is 0.
        let mut senders: Vec<u32> = sched.iter().flatten().map(|s| s.src).collect();
        senders.sort_unstable();
        let expect: Vec<u32> = (1..clusters).collect();
        assert_eq!(senders, expect, "clusters={clusters}");
        // No cluster receives after it has sent (tree property).
        let mut sent = vec![false; clusters as usize];
        for level in &sched {
            for step in level {
                assert!(!sent[step.dst as usize], "dst {} already sent", step.dst);
                sent[step.src as usize] = true;
            }
        }
    }
}

#[test]
fn gemm_cost_monotonic_in_problem_size() {
    let mut rng = Rng(4);
    let p = PlatformConfig::occamy();
    for _ in 0..60 {
        let m = rng.next(64, 2048);
        let k = rng.next(64, 4096);
        let n = rng.next(64, 4096);
        let a = gemm_cost(m, k, n, FpFormat::Fp32, &p, OperandHome::default());
        let b = gemm_cost(2 * m, k, n, FpFormat::Fp32, &p, OperandHome::default());
        assert!(b.cycles >= a.cycles, "2x rows not slower: {m}x{k}x{n}");
        assert_eq!(b.flops, 2 * a.flops);
    }
}

#[test]
fn flops_invariant_under_features_and_format() {
    // The useful work is a property of the problem, not the platform.
    let mut rng = Rng(5);
    for _ in 0..40 {
        let m = rng.next(16, 1024);
        let k = rng.next(16, 2048);
        let n = rng.next(16, 2048);
        let mut costs = Vec::new();
        for fmt in FpFormat::LADDER {
            for features in [Features::all(), Features::baseline()] {
                let mut p = PlatformConfig::occamy();
                p.features = features;
                costs.push(gemm_cost(m, k, n, fmt, &p, OperandHome::default()).flops);
            }
        }
        assert!(costs.windows(2).all(|w| w[0] == w[1]), "{m}x{k}x{n}: {costs:?}");
    }
}

#[test]
fn extensions_never_hurt() {
    let mut rng = Rng(6);
    for _ in 0..40 {
        let m = rng.next(64, 2048);
        let k = rng.next(64, 2048);
        let n = rng.next(64, 2048);
        let fmt = rng.pick(&[FpFormat::Fp64, FpFormat::Fp32]);
        let opt = PlatformConfig::occamy();
        let mut base = PlatformConfig::occamy();
        base.features = Features::baseline();
        let co = gemm_cost(m, k, n, fmt, &opt, OperandHome::default());
        let cb = gemm_cost(m, k, n, fmt, &base, OperandHome::default());
        assert!(co.cycles <= cb.cycles, "{fmt} {m}x{k}x{n}: opt {} base {}", co.cycles, cb.cycles);
    }
}

#[test]
fn more_clusters_never_slower_for_big_workloads() {
    let mut rng = Rng(7);
    for _ in 0..30 {
        let s = rng.next(512, 2048);
        let heads = 16;
        let pdim = rng.pick(&[64u64, 128]);
        let small = flash_attention_cost(
            heads, s, s, pdim, FpFormat::Fp32, false, &PlatformConfig::with_clusters(4));
        let big = flash_attention_cost(
            heads, s, s, pdim, FpFormat::Fp32, false, &PlatformConfig::with_clusters(16));
        assert!(big.cycles <= small.cycles, "s={s} p={pdim}");
    }
}

#[test]
fn block_cost_sums_layer_costs() {
    let mut rng = Rng(8);
    let p = PlatformConfig::occamy();
    for _ in 0..20 {
        let cfg = ModelConfig {
            name: "prop".into(),
            family: snitch_fm::model::Family::Gpt,
            blocks: 1,
            e: rng.pick(&[256u64, 512, 1024]),
            p: rng.pick(&[32u64, 64]),
            heads: rng.pick(&[4u64, 8, 16]),
            ff: rng.pick(&[1024u64, 4096]),
            seq: 256,
        };
        let bc = block_cost(&cfg, Mode::Nar, 256, 0, FpFormat::Fp32, &p);
        let kind_sum: u64 = bc.by_kind.values().map(|c| c.cycles).sum();
        let label_sum: u64 = bc.by_label.values().map(|c| c.cycles).sum();
        assert_eq!(kind_sum, bc.cycles);
        assert_eq!(label_sum, bc.cycles);
        assert!(bc.total.flops > 0);
    }
}

#[test]
fn ar_cost_grows_with_kv_length() {
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::gpt3_xl();
    let mut prev = 0;
    for kv in [128u64, 512, 1024, 2048] {
        let c = model_cost(&cfg, Mode::Ar, kv, FpFormat::Fp32, &p);
        assert!(c.cycles >= prev, "kv={kv}");
        prev = c.cycles;
    }
}

#[test]
fn layernorm_cost_scales_linearly() {
    let mut rng = Rng(9);
    let p = PlatformConfig::occamy();
    for _ in 0..30 {
        let s = rng.next(64, 2048);
        let e = rng.next(64, 8192);
        let one = layernorm_cost(s, e, FpFormat::Fp32, &p);
        let two = layernorm_cost(2 * s, e, FpFormat::Fp32, &p);
        let ratio = two.cycles as f64 / one.cycles.max(1) as f64;
        assert!((1.0..=3.0).contains(&ratio), "s={s} e={e}: ratio {ratio}");
    }
}

#[test]
fn kv_cache_prefill_then_steps_random() {
    let mut rng = Rng(10);
    for _ in 0..50 {
        let heads = rng.next(1, 8) as usize;
        let p = rng.next(2, 32) as usize;
        let cap = rng.next(4, 64) as usize;
        let n = rng.next(1, cap as u64) as usize;
        let mut cache = KvCache::new(heads, cap, p);
        let k: Vec<f32> = (0..heads * n * p).map(|i| i as f32).collect();
        cache.load_prefill(&k, &k, n);
        assert_eq!(cache.len(), n);
        // Every prefilled vector is retrievable at the right offset.
        let h = rng.next(0, heads as u64 - 1) as usize;
        let t = rng.next(0, n as u64 - 1) as usize;
        let expect0 = (h * n + t) * p;
        assert_eq!(cache.k_at(h, t)[0], expect0 as f32);
        // Steps up to capacity never panic.
        let size = cache.k_flat().len();
        for _ in n..cap {
            cache.store_step(vec![0.0; size], vec![0.0; size]);
        }
        assert_eq!(cache.len(), cap);
        assert_eq!(cache.remaining(), 0);
    }
}

#[test]
fn refcounted_allocator_sharing_invariants() {
    // Random interleavings of grow / release / share (prefix hit) /
    // cache-register / LRU-evict / CoW-fork. After every operation:
    // a page referenced by any table is live, ref counts cover table
    // occupancy, distinct-page accounting matches bytes_in_use, and the
    // budget holds. Draining tables + cache makes the pool whole.
    use std::collections::HashMap;
    let mut rng = Rng(0x5A5A);
    for case in 0..40 {
        let page_tokens = rng.next(1, 32);
        let geom = KvGeometry {
            token_bytes: rng.next(1, 2048),
            page_tokens,
            format: FpFormat::Fp32,
        };
        let total_pages = rng.next(2, 48);
        let mut alloc = PagedKvAllocator::new(total_pages * geom.page_bytes(), geom);
        let mut cache = PrefixCache::new();
        let mut tables: Vec<PageTable> =
            (0..rng.next(2, 6)).map(|_| PageTable::new()).collect();
        let mut next_hash = 0u64;
        for _ in 0..300 {
            let i = rng.next(0, tables.len() as u64 - 1) as usize;
            match rng.next(0, 5) {
                0 => {
                    let want = rng.next(0, total_pages * page_tokens);
                    let _ = alloc.try_grow(&mut tables[i], want);
                }
                1 => alloc.release(&mut tables[i]),
                2 => {
                    // Prefix hit: map another table's page here too (the
                    // page id is copied out before the mutable share).
                    let j = rng.next(0, tables.len() as u64 - 1) as usize;
                    if i != j && !tables[j].is_empty() {
                        let p = tables[j].pages()
                            [rng.next(0, tables[j].len() as u64 - 1) as usize];
                        alloc.share(&mut tables[i], p);
                    }
                }
                3 => {
                    // Register a page in the prefix cache.
                    if !tables[i].is_empty() {
                        next_hash += 1;
                        let p = tables[i].pages()
                            [rng.next(0, tables[i].len() as u64 - 1) as usize];
                        cache.insert(&mut alloc, next_hash, p);
                    }
                }
                4 => {
                    let _ = cache.evict_lru(&mut alloc, rng.next(1, 4));
                }
                _ => {
                    let _ = alloc.ensure_private_tail(&mut tables[i]);
                }
            }
            let mut occupancy: HashMap<u32, u32> = HashMap::new();
            for t in &tables {
                for &p in t.pages() {
                    assert!(
                        alloc.ref_count(p) >= 1,
                        "case {case}: page {p} freed while a table references it"
                    );
                    *occupancy.entry(p).or_default() += 1;
                }
            }
            for (&p, &n) in &occupancy {
                assert!(
                    alloc.ref_count(p) >= n,
                    "case {case}: page {p} ref count {} below occupancy {n}",
                    alloc.ref_count(p)
                );
            }
            assert!(occupancy.len() as u64 <= alloc.used_pages(), "case {case}");
            assert!(alloc.used_pages() <= total_pages, "case {case}: over budget");
            assert_eq!(
                alloc.bytes_in_use(),
                alloc.used_pages() * geom.page_bytes(),
                "case {case}: dedup bytes accounting drifted"
            );
            assert_eq!(alloc.free_pages() + alloc.used_pages(), alloc.total_pages());
        }
        for t in &mut tables {
            alloc.release(t);
        }
        cache.clear(&mut alloc);
        assert_eq!(alloc.used_pages(), 0, "case {case}: drained pool must be whole");
        assert_eq!(alloc.free_pages(), alloc.total_pages());
    }
}

#[test]
fn layer_cost_memo_bit_identical_to_uncached() {
    // Transparency: the memoized pricing path must return the exact
    // KernelCost of the uncached path for arbitrary layer signatures,
    // on the first (miss) and second (hit) lookup alike.
    let p = PlatformConfig::occamy();
    let mut cache = LayerCostCache::new(&p);
    let mut rng = Rng(0x3E30);
    for _ in 0..150 {
        let kind = match rng.next(0, 4) {
            0 => LayerKind::Gemm,
            1 => LayerKind::FlashAttention,
            2 => LayerKind::FusedConcatLinear,
            3 => LayerKind::Layernorm,
            _ => LayerKind::Gelu,
        };
        let layer = Layer {
            kind,
            label: "prop",
            b: rng.next(1, 8),
            m: rng.next(1, 512),
            k: rng.next(1, 2048),
            n: rng.next(1, 2048),
            skv: rng.next(1, 2048),
            heads: rng.next(1, 16),
            p: rng.pick(&[32u64, 64, 128]),
            causal: rng.next(0, 1) == 1,
            fused_input: rng.next(0, 1) == 1,
        };
        let fmt = rng.pick(&FpFormat::ALL);
        for pass in 0..2 {
            assert_eq!(
                cache.layer_cost(&layer, fmt, &p),
                layer_cost(&layer, fmt, &p),
                "pass {pass}: {layer:?} {fmt}"
            );
        }
    }
    assert!(cache.hits() >= 150, "every second lookup must hit");
}

#[test]
fn prefix_hits_conserve_tokens_end_to_end() {
    // With an ample page pool (no preemption), every prompt token is
    // accounted exactly once: either prefilled or served from the prefix
    // cache — across chunk sizes, page sizes, token budgets and fanouts.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let mut rng = Rng(0xBEEF);
    for case in 0..15 {
        let n = rng.next(4, 16) as usize;
        let w = Workload::synthetic(rng.next(1, 1 << 20), n, (4, 48), (1, 8))
            .with_shared_prefix(rng.next(0, 64), rng.next(1, 4) as usize)
            .with_poisson_arrivals(rng.next(1, 1 << 20), 1000.0);
        let page_tokens = rng.next(1, 24);
        let geom = KvGeometry::new(&cfg, FpFormat::Fp32, page_tokens);
        let budget = w
            .requests
            .iter()
            .map(|r| geom.pages_for(r.kv_capacity()) * geom.page_bytes())
            .sum::<u64>()
            * 2;
        let mut opts = BatcherConfig::new(rng.next(1, 6) as usize, budget);
        opts.page_tokens = page_tokens;
        opts.prefill_chunk = rng.next(0, 24);
        if rng.next(0, 1) == 1 {
            opts.token_budget = rng.next(8, 64);
        }
        let r = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts).run(&w);
        assert_eq!(r.completed, n, "case {case}");
        assert_eq!(r.preemptions, 0, "case {case}");
        assert_eq!(
            r.prefill_tokens + r.prefix_hit_tokens,
            w.total_prompt_tokens(),
            "case {case}: token conservation with prefix hits ({opts:?})"
        );
        assert_eq!(r.gen_tokens, w.total_gen_tokens(), "case {case}");
        assert!(r.peak_kv_bytes <= budget, "case {case}");
    }
}

#[test]
fn kv_migration_conserves_pages_across_pools() {
    // The disagg handoff invariants, swept over random geometries:
    // exporting a prompt frees at the source exactly the pages the
    // destination maps at import (same geometry both sides), the
    // in-flight manifest bills NEITHER pool, and prefix-cache references
    // survive the export untouched. Draining both pools makes them whole.
    let mut rng = Rng(0x1116);
    for case in 0..60 {
        let page_tokens = rng.next(1, 32);
        let geom = KvGeometry {
            token_bytes: rng.next(1, 2048),
            page_tokens,
            format: FpFormat::Fp32,
        };
        let total_pages = rng.next(4, 64);
        let mut src = PagedKvAllocator::new(total_pages * geom.page_bytes(), geom);
        let mut dst = PagedKvAllocator::new(total_pages * geom.page_bytes(), geom);
        let mut cache = PrefixCache::new();
        let tokens = rng.next(1, total_pages * page_tokens / 2);
        let mut t = PageTable::new();
        assert!(src.try_grow(&mut t, tokens), "case {case}: ample pool must admit");
        let grown = src.used_pages();
        assert_eq!(grown, geom.pages_for(tokens), "case {case}");
        // Pin a random prefix of the prompt's pages in the prefix cache.
        let cached = rng.next(0, t.len() as u64);
        for (i, &p) in t.pages()[..cached as usize].iter().enumerate() {
            cache.insert(&mut src, 0x1000 + i as u64, p);
        }
        let manifest = src.export(&mut t, tokens);
        assert!(t.is_empty(), "case {case}: export drops every table ref");
        assert_eq!(manifest.tokens, tokens);
        assert_eq!(manifest.pages, grown, "case {case}: manifest covers the prompt");
        assert_eq!(manifest.bytes, grown * geom.page_bytes());
        // Prefix-cache refs survive; everything else is freed at the source.
        assert_eq!(
            src.used_pages(),
            cached,
            "case {case}: only cache-pinned pages survive the export"
        );
        // In-flight window: the manifest bills neither pool.
        assert_eq!(dst.used_pages(), 0, "case {case}");
        assert_eq!(
            src.bytes_in_use() + dst.bytes_in_use(),
            cached * geom.page_bytes(),
            "case {case}: no double-billing while the migration is in flight"
        );
        // Import maps exactly the pages the export freed (same geometry).
        assert!(dst.import(&mut t, &manifest), "case {case}");
        assert_eq!(dst.used_pages(), manifest.pages, "case {case}: freed == mapped");
        assert_eq!(t.len() as u64, manifest.pages, "case {case}");
        // Drain both pools -> whole.
        dst.release(&mut t);
        cache.clear(&mut src);
        assert_eq!(src.used_pages(), 0, "case {case}: drained source must be whole");
        assert_eq!(dst.used_pages(), 0, "case {case}: drained destination must be whole");
        assert_eq!(src.free_pages(), src.total_pages());
        assert_eq!(dst.free_pages(), dst.total_pages());
    }
}

#[test]
fn kv_migration_import_is_all_or_nothing() {
    // A destination that cannot hold the whole manifest refuses it and is
    // left byte-identical; the manifest stays in flight and lands intact
    // on a later retry once capacity frees up.
    let mut rng = Rng(0xF117);
    for case in 0..60 {
        let page_tokens = rng.next(1, 16);
        let geom = KvGeometry {
            token_bytes: rng.next(1, 512),
            page_tokens,
            format: FpFormat::Fp32,
        };
        let src_pages = rng.next(3, 32);
        let mut src = PagedKvAllocator::new(src_pages * geom.page_bytes(), geom);
        let mut t = PageTable::new();
        // >= 2 pages so "one page short" is a real pool.
        let tokens = rng.next(page_tokens + 1, src_pages * page_tokens);
        assert!(src.try_grow(&mut t, tokens), "case {case}");
        let manifest = src.export(&mut t, tokens);
        assert!(manifest.pages >= 2, "case {case}");
        assert_eq!(src.used_pages(), 0, "case {case}");

        // One page short: the import must refuse and change nothing.
        let mut small =
            PagedKvAllocator::new((manifest.pages - 1) * geom.page_bytes(), geom);
        assert!(!small.import(&mut t, &manifest), "case {case}: must refuse");
        assert!(t.is_empty(), "case {case}: failed import maps nothing");
        assert_eq!(small.used_pages(), 0, "case {case}: failed import bills nothing");

        // Exactly-fitting pool, pre-occupied by a resident request: still
        // refuses; after the resident drains, the retry lands the whole
        // manifest.
        let mut dst = PagedKvAllocator::new(manifest.pages * geom.page_bytes(), geom);
        let mut resident = PageTable::new();
        assert!(dst.try_grow(&mut resident, 1), "case {case}");
        assert!(!dst.import(&mut t, &manifest), "case {case}: occupied pool refuses");
        assert_eq!(dst.used_pages(), 1, "case {case}: refusal leaves the resident");
        dst.release(&mut resident);
        assert!(dst.import(&mut t, &manifest), "case {case}: retry succeeds");
        assert_eq!(dst.used_pages(), manifest.pages, "case {case}");
        assert_eq!(
            manifest,
            KvExport {
                tokens,
                pages: geom.pages_for(tokens),
                bytes: geom.pages_for(tokens) * geom.page_bytes(),
                format: FpFormat::Fp32
            },
            "case {case}: the manifest is immutable across retries"
        );
        dst.release(&mut t);
        assert_eq!(dst.free_pages(), dst.total_pages(), "case {case}");
    }
}

#[test]
fn kv_migration_across_formats_requantizes_all_or_nothing() {
    // Mixed-format pools: importing a manifest into a pool with a
    // *different* KV format must requantize every token — billed as
    // converted elements for the caller to price as KvDequant work — or
    // refuse outright leaving the destination untouched. Tokens never
    // partially map, and a same-format import through the converting
    // path bills zero conversions.
    let cfg = ModelConfig::tiny();
    let mut rng = Rng(0xA8F0);
    for case in 0..60 {
        let page_tokens = rng.next(1, 16);
        let src_fmt = rng.pick(&FpFormat::ALL);
        let dst_fmt = rng.pick(&FpFormat::ALL);
        let src_geom = KvGeometry::new(&cfg, src_fmt, page_tokens);
        let dst_geom = KvGeometry::new(&cfg, dst_fmt, page_tokens);
        let pool_pages = rng.next(4, 32);
        let mut src =
            PagedKvAllocator::new(pool_pages * src_geom.page_bytes(), src_geom);
        let mut t = PageTable::new();
        let tokens = rng.next(1, pool_pages * page_tokens / 2);
        assert!(src.try_grow(&mut t, tokens), "case {case}");
        let manifest = src.export(&mut t, tokens);
        assert_eq!(
            manifest.format, src_fmt,
            "case {case}: the manifest carries the wire format"
        );
        assert_eq!(src.used_pages(), 0, "case {case}");

        // Destination one page short of the whole manifest: the
        // converting import refuses and changes nothing — no partial
        // requantization ever lands.
        if dst_geom.pages_for(tokens) >= 2 {
            let mut small = PagedKvAllocator::new(
                (dst_geom.pages_for(tokens) - 1) * dst_geom.page_bytes(),
                dst_geom,
            );
            assert_eq!(
                small.import_converting(&mut t, &manifest),
                None,
                "case {case}: short pool must refuse"
            );
            assert!(t.is_empty(), "case {case}: refused import maps nothing");
            assert_eq!(
                small.used_pages(),
                0,
                "case {case}: refused import bills nothing"
            );
        }

        // Ample destination: the whole manifest lands at the pool's own
        // geometry and the conversion count is exact — every cached
        // element once, zero when the formats already match.
        let mut dst =
            PagedKvAllocator::new(pool_pages * dst_geom.page_bytes(), dst_geom);
        let billed = dst.import_converting(&mut t, &manifest);
        let expect = if src_fmt == dst_fmt {
            0
        } else {
            tokens * dst_geom.elems_per_token()
        };
        assert_eq!(billed, Some(expect), "case {case}: conversion billing");
        assert_eq!(
            dst.used_pages(),
            dst_geom.pages_for(tokens),
            "case {case}: destination holds every token at its own geometry"
        );
        assert!(
            t.capacity_tokens(&dst_geom) >= tokens,
            "case {case}: table covers the migrated tokens"
        );
        dst.release(&mut t);
        assert_eq!(dst.free_pages(), dst.total_pages(), "case {case}");
    }
}

#[test]
fn json_parser_roundtrips_random_nesting() {
    use snitch_fm::util::json;
    let mut rng = Rng(11);
    for _ in 0..100 {
        // Build a random nested doc and print it via Display, re-parse it.
        let n = rng.next(1, 6);
        let items: Vec<String> = (0..n)
            .map(|i| {
                format!(
                    "{{\"k{i}\": [{}, {}.5, \"s{i}\"]}}",
                    rng.next(0, 99),
                    rng.next(0, 99)
                )
            })
            .collect();
        let doc = format!("[{}]", items.join(","));
        let v = json::parse(&doc).expect("parse");
        let v2 = json::parse(&v.to_string()).expect("reparse");
        assert_eq!(v, v2);
    }
}

#[test]
fn tracing_is_passive_and_partitions_every_makespan() {
    // Arming the trace recorder must never perturb the schedule: the
    // traced fleet report is bit-identical to the untraced one across
    // random fleet sizes, arrival processes, prefix sharing, chunking
    // and token budgets — and every replica's recorder tiles its own
    // makespan exactly (busy + stall + idle, no gaps, no overlap).
    let cfg = ModelConfig::tiny();
    let mut rng = Rng(0x7ACE);
    for case in 0..15 {
        let replicas = rng.next(1, 3) as usize;
        let p = PlatformConfig::with_dies(replicas as u32);
        let n = rng.next(4, 14) as usize;
        let mut w = Workload::synthetic(rng.next(1, 1 << 20), n, (8, 64), (2, 10))
            .with_poisson_arrivals(rng.next(1, 1 << 20), 900.0);
        if rng.next(0, 1) == 1 {
            w = w.with_shared_prefix(rng.next(0, 32), rng.next(1, 3) as usize);
        }
        let mut opts = BatcherConfig::new(rng.next(2, 5) as usize, 0);
        opts.prefill_chunk = rng.next(0, 24);
        if rng.next(0, 1) == 1 {
            opts.token_budget = rng.next(16, 64);
        }
        let plain = serve_replicated_with_faults(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            replicas,
            RoutePolicy::JoinShortestQueue,
            &FaultPlan::off(),
        );
        let settings = TraceSettings { metrics_interval_us: rng.next(10, 2_000) as f64 };
        let (traced, fleet) = serve_replicated_traced(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            replicas,
            RoutePolicy::JoinShortestQueue,
            &FaultPlan::off(),
            &settings,
        );
        assert_eq!(plain.merged, traced.merged, "case {case}: tracing changed the merge");
        assert_eq!(plain.per_replica, traced.per_replica, "case {case}");
        assert_eq!(fleet.replicas().len(), replicas, "case {case}");
        for ((label, rec), rep) in fleet.replicas().iter().zip(&traced.per_replica) {
            let total = rec.total_cycles().expect("finished recorder");
            assert_eq!(total, rep.total_cycles, "case {case} {label}");
            let acct = rec.track_accounting();
            assert_eq!(
                acct.busy + acct.stall + acct.idle,
                total,
                "case {case} {label}: spans must tile the makespan"
            );
            assert_eq!(acct.busy, rep.work.cycles, "case {case} {label}");
            assert_eq!(acct.stall, 0, "case {case} {label}: no faults, no stalls");
        }
    }
}

#[test]
fn fault_recovery_never_loses_or_duplicates_a_request() {
    // Conservation across failure / re-route / retry: the merged fleet
    // view partitions the offered ids into completions and rejections —
    // no request vanishes with its replica and none is served twice.
    let mut rng = Rng(0xFA01);
    let cfg = ModelConfig::tiny();
    for case in 0..40 {
        let replicas = rng.next(2, 4) as usize;
        let n = rng.next(6, 20) as usize;
        let p = PlatformConfig::with_dies(replicas as u32);
        let w = Workload::synthetic(rng.next(1, 1 << 16), n, (8, 64), (2, 12))
            .with_poisson_arrivals(rng.next(1, 1 << 16), 1_500.0);
        let mut parts = Vec::new();
        for _ in 0..rng.next(1, 2) {
            let at = rng.next(0, 60) as f64 / 4e3;
            parts.push(if rng.next(0, 1) == 0 {
                format!("fail@{at}:r{}", rng.next(0, replicas as u64 - 1))
            } else {
                format!("die@{at}")
            });
        }
        let plan = FaultPlan::parse(&parts.join(","), rng.next(0, 1 << 30)).unwrap();
        let fleet = serve_replicated_with_faults(
            &cfg,
            &p,
            FpFormat::Fp32,
            BatcherConfig::new(4, 0),
            &w,
            replicas,
            RoutePolicy::JoinShortestQueue,
            &plan,
        );
        assert_eq!(fleet.merged.requests, n, "case {case}");
        assert_eq!(fleet.merged.completed + fleet.merged.rejected.len(), n, "case {case}");
        let mut ids: Vec<usize> = fleet.merged.per_request.iter().map(|s| s.id).collect();
        ids.extend(fleet.merged.rejected.iter().copied());
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "case {case}: a request was lost or served twice");
        let f = fleet.merged.degraded_capacity_fraction;
        assert!((0.0..=1.0).contains(&f), "case {case}: fraction {f}");
    }
}

#[test]
fn salvage_respects_every_survivors_kv_budget() {
    // Salvaged KV pages are freed on the failed die and re-allocated on
    // the adopter exactly once: under a deliberately tight pool, no
    // replica's peak residency ever exceeds its own budget, and a pool
    // that died with its replica (`die@`) re-exports nothing.
    let mut rng = Rng(0xFA02);
    let cfg = ModelConfig::tiny();
    for case in 0..25 {
        let n = rng.next(6, 16) as usize;
        let p = PlatformConfig::with_dies(2);
        let w = Workload::uniform(n, 24, 6);
        let one = w.requests[0].kv_bytes(&cfg);
        let opts = BatcherConfig::new(3, rng.next(2, 4) * one);
        let at = rng.next(0, 40) as f64 / 4e3;
        let kind = if rng.next(0, 1) == 0 { "fail" } else { "die" };
        let plan =
            FaultPlan::parse(&format!("{kind}@{at}:r0"), rng.next(0, 1 << 20)).unwrap();
        let fleet = serve_replicated_with_faults(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            2,
            RoutePolicy::JoinShortestQueue,
            &plan,
        );
        for (i, r) in fleet.per_replica.iter().enumerate() {
            assert!(
                r.peak_kv_bytes <= r.kv_budget_bytes,
                "case {case}: salvage blew replica {i}'s pool: {} > {}",
                r.peak_kv_bytes,
                r.kv_budget_bytes
            );
        }
        if kind == "die" {
            assert_eq!(
                fleet.merged.salvaged_kv_bytes, 0,
                "case {case}: a dead pool re-exports nothing"
            );
        }
        assert_eq!(fleet.merged.completed + fleet.merged.rejected.len(), n, "case {case}");
    }
}

#[test]
fn corrupted_migrations_bill_the_link_once_per_attempt() {
    // Every migration attempt — first try, corruption retry, and the
    // final attempt before a recompute fallback — moves the payload and
    // bills the link exactly once: bytes and cycles scale with the
    // attempt count, never more, never less. Uniform requests make the
    // per-attempt price a constant the invariant can divide out.
    let mut rng = Rng(0xFA03);
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(4);
    let w = Workload::uniform(10, 32, 6);
    let opts = BatcherConfig::new(4, 0);
    let clean = serve_disaggregated_with_faults(
        &cfg,
        &p,
        FpFormat::Fp32,
        opts,
        &w,
        2,
        2,
        RoutePolicy::JoinShortestQueue,
        &FaultPlan::off(),
    );
    assert_eq!(clean.migrations, 10);
    let bytes_per = clean.migrated_kv_bytes / clean.migrations;
    let cycles_per = clean.migration_cycles / clean.migrations;
    assert!(bytes_per > 0 && cycles_per > 0);
    for case in 0..25 {
        let prob = rng.next(0, 100) as f64 / 100.0;
        let plan =
            FaultPlan::parse(&format!("corrupt:{prob}"), rng.next(0, 1 << 30)).unwrap();
        let r = serve_disaggregated_with_faults(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            2,
            2,
            RoutePolicy::JoinShortestQueue,
            &plan,
        );
        let attempts = r.migrations + r.migration_retries;
        assert_eq!(
            r.migrated_kv_bytes,
            bytes_per * attempts,
            "case {case} (p={prob}): bytes must scale with attempts"
        );
        assert_eq!(
            r.migration_cycles,
            cycles_per * attempts,
            "case {case} (p={prob}): link cycles must scale with attempts"
        );
        assert_eq!(r.decode.kv_imports, r.migrations - r.recompute_fallbacks, "case {case}");
        assert!(r.migration_retries <= 2 * r.migrations, "case {case}: retry cap");
        assert_eq!(r.completed + r.rejected.len(), 10, "case {case}");
    }
}
