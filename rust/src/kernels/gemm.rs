//! GEMM timing model (paper Sec. V-A1, Fig. 5).
//!
//! `C[M,N] = A[M,K] @ B[K,N]`, spatially tiled on M across clusters
//! (B broadcast), temporally tiled on K/N/M to fit the SPM, inner loop on
//! FREP+SSR with 8-way unrolling, SIMD lanes per format, DMA
//! double-buffered. The GEMV variant (`gemv_cost`) models the AR mode's
//! matrix-vector path where N is split across clusters instead and the
//! whole weight matrix streams from HBM.

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::sim::cluster::{ClusterSim, TilePhase};
use crate::sim::core::CoreModel;
use crate::sim::dma::Transfer;
use crate::sim::{KernelCost, MultiClusterSim};
use crate::tiling::{plan_gemm, plan_gemm_wide, GemmPlan};

/// Where the operands live before the kernel runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperandHome {
    /// A (activations): usually HBM, or a peer cluster SPM when fused.
    pub a: MemLevel,
    /// B (weights): HBM.
    pub b: MemLevel,
    /// C destination.
    pub c: MemLevel,
}

impl Default for OperandHome {
    fn default() -> Self {
        OperandHome { a: MemLevel::Hbm, b: MemLevel::Hbm, c: MemLevel::Hbm }
    }
}

/// All operands already SPM-resident (fused callers).
pub fn spm_resident() -> OperandHome {
    OperandHome { a: MemLevel::Spm, b: MemLevel::Spm, c: MemLevel::Spm }
}

/// One transformer GEMM's per-cluster schedule as homogeneous phase
/// groups `(phase, count)` — see `ClusterSim::run_grouped`.
///
/// Loop order mirrors Fig. 5-B: the broadcast B temporal tile stays
/// SPM-resident across the (inner) M loop; a single temporal tile of A
/// and of the partial C is (re)loaded at each step, with partial C
/// accumulation across K steps. Edge tiles are approximated as full
/// tiles (worst-case share, consistent with `run_all_clusters`); exact
/// FLOPs are pinned by the callers.
fn cluster_phase_groups(
    plan: &GemmPlan,
    k: u64,
    n: u64,
    fmt: FpFormat,
    core: &CoreModel,
    cores: u64,
    home: OperandHome,
) -> Vec<(TilePhase, u64)> {
    let el = fmt.bytes();
    let (bm, bn, bk) = (plan.bm, plan.bn, plan.bk);
    let m_tiles = plan.rows.div_ceil(bm);
    let n_tiles = n.div_ceil(bn);
    let k_tiles = k.div_ceil(bk);
    let rows_per_core = bm.div_ceil(cores);
    let compute = core.row_dots_cycles(rows_per_core, bn, bk, fmt);
    let flops = 2 * bm * bn * bk;
    let acc_el = fmt.accumulation_format().bytes().max(el);
    let c_roundtrip = k_tiles > 1 && m_tiles > 1;

    // Phase shape for one (ki-class, mi-class) cell.
    let make = |ki_first: bool, ki_last: bool, mi_first: bool| -> TilePhase {
        let mut phase = TilePhase::compute(compute, flops);
        if home.a != MemLevel::Spm {
            phase = phase.with_transfer(Transfer::d2(bm * bk * el, bm, home.a));
        }
        // B tile loaded once per (n,k) step, resident across M.
        if mi_first && home.b != MemLevel::Spm {
            phase = phase.with_transfer(Transfer::d2(bk * bn * el, bk, home.b));
        }
        if home.c != MemLevel::Spm {
            if c_roundtrip {
                // Partial C round trip (Fig. 5-B: "summed together with
                // the previous ones"): read back the partial unless this
                // is the first K step, write it always.
                if !ki_first {
                    phase = phase.with_transfer(Transfer::d2(bm * bn * acc_el, bm, home.c));
                }
                phase = phase.with_transfer(
                    Transfer::d2(bm * bn * if ki_last { el } else { acc_el }, bm, home.c)
                        .to_write(),
                );
            } else if ki_last {
                // Accumulator stays in SPM; single final write.
                phase =
                    phase.with_transfer(Transfer::d2(bm * bn * el, bm, home.c).to_write());
            }
        }
        phase
    };

    // ki classes: first / middle / last; mi classes: first / rest.
    let k_first = 1u64;
    let k_last = if k_tiles > 1 { 1 } else { 0 };
    let k_mid = k_tiles - k_first - k_last;
    let m_first = 1u64;
    let m_rest = m_tiles - 1;
    let mut groups = Vec::with_capacity(6);
    for (ki_first, ki_last, k_count) in [
        (true, k_tiles == 1, k_first),
        (false, false, k_mid),
        (false, true, k_last),
    ] {
        for (mi_first, m_count) in [(true, m_first), (false, m_rest)] {
            let count = n_tiles * k_count * m_count;
            if count > 0 {
                groups.push((make(ki_first, ki_last, mi_first), count));
            }
        }
    }
    groups
}

fn run_all_clusters(
    plan: &GemmPlan,
    active_clusters: u64,
    k: u64,
    n: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
    home: OperandHome,
) -> KernelCost {
    let core = CoreModel::new(platform.cluster, platform.features);
    let cores = platform.cluster.compute_cores;
    let groups = cluster_phase_groups(plan, k, n, fmt, &core, cores, home);
    let csim = ClusterSim::new(platform).with_hbm_sharers(active_clusters);
    let one = csim.run_grouped(&groups);
    // All active clusters run the same schedule in parallel (their row
    // shares differ by at most one tile); the slowest one is `one`.
    let sim = MultiClusterSim::new(platform);
    let per: Vec<KernelCost> = (0..active_clusters).map(|_| one).collect();
    sim.parallel(&per)
}

/// Cost of a full GEMM on the platform (M spatially split over clusters).
pub fn gemm_cost(
    m: u64,
    k: u64,
    n: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
    home: OperandHome,
) -> KernelCost {
    if m == 0 || k == 0 || n == 0 {
        return KernelCost::default();
    }
    if m < platform.total_clusters() as u64 {
        return gemv_cost(m, k, n, fmt, platform, home);
    }
    let plan = plan_gemm(m, k, n, fmt, platform);
    let active = m.div_ceil(plan.rows).min(platform.total_clusters() as u64);
    let mut cost = run_all_clusters(&plan, active, k, n, fmt, platform, home);
    // Every cluster is modeled with the worst-case row share, which
    // overcounts the remainder rows; pin the exact useful work.
    cost.flops = 2 * m * k * n;
    cost
}

/// AR-mode matrix-vector product: M is tiny, so clusters split N; the
/// entire B matrix streams from HBM (the KV-cache/weight traffic that
/// caps AR utilization below 10%, Table III).
pub fn gemv_cost(
    m: u64,
    k: u64,
    n: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
    home: OperandHome,
) -> KernelCost {
    if m == 0 || k == 0 || n == 0 {
        return KernelCost::default();
    }
    let plan = plan_gemm_wide(m, k, n, fmt, platform);
    let cols = plan.bn.max(1);
    let active = n.div_ceil(n.div_ceil(platform.total_clusters() as u64).max(cols))
        .min(platform.total_clusters() as u64)
        .max(1);
    // Reuse the phase builder with the cluster owning `cols_share` columns.
    let core = CoreModel::new(platform.cluster, platform.features);
    let cores = platform.cluster.compute_cores;
    let el = fmt.bytes();
    let cols_share = n.div_ceil(active);
    let n_tiles = cols_share.div_ceil(plan.bn);
    let k_tiles = k.div_ceil(plan.bk);
    let (bn, bk) = (plan.bn, plan.bk);
    // M rows are few: parallelize the output columns across cores.
    // Grouped phases (see ClusterSim::run_grouped); edge tiles priced as
    // full tiles, exact flops pinned below.
    let cols_per_core = bn.div_ceil(cores);
    let compute = core.row_dots_cycles(m * cols_per_core, 1, bk, fmt);
    let flops = 2 * m * bn * bk;
    let make = |ni_first: bool, ki_last: bool| -> TilePhase {
        let mut phase = TilePhase::compute(compute, flops);
        if home.a != MemLevel::Spm && ni_first {
            // The activation vector is loaded once per k tile.
            phase = phase.with_transfer(Transfer::d1(m * bk * el, home.a));
        }
        if home.b != MemLevel::Spm {
            phase = phase.with_transfer(Transfer::d2(bk * bn * el, bk, home.b));
        }
        if ki_last && home.c != MemLevel::Spm {
            phase = phase.with_transfer(Transfer::d1(m * bn * el, home.c).to_write());
        }
        phase
    };
    let mut groups = Vec::with_capacity(4);
    for (ni_first, n_count) in [(true, 1u64), (false, n_tiles - 1)] {
        for (ki_last, k_count) in [(false, k_tiles - 1), (true, 1u64)] {
            let count = n_count * k_count;
            if count > 0 {
                groups.push((make(ni_first, ki_last), count));
            }
        }
    }
    let mut csim = ClusterSim::new(platform).with_hbm_sharers(active);
    // AR/GEMV weight streaming cannot saturate HBM (see
    // `InterconnectConfig::gemv_hbm_efficiency`).
    csim.dma = csim.dma.with_hbm_derate(platform.interconnect.gemv_hbm_efficiency);
    let one = csim.run_grouped(&groups);
    let sim = MultiClusterSim::new(platform);
    let per: Vec<KernelCost> = (0..active).map(|_| one).collect();
    let mut cost = sim.parallel(&per);
    cost.flops = 2 * m * k * n; // exact useful work (see gemm_cost)
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Features;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn flop_accounting_exact() {
        let c = gemm_cost(1024, 1024, 1024, FpFormat::Fp32, &occ(), OperandHome::default());
        // All clusters together must perform exactly 2*M*K*N flops.
        assert_eq!(c.flops, 2 * 1024 * 1024 * 1024);
    }

    #[test]
    fn fpu_utilization_compute_bound() {
        // Big square FP32 GEMM must exceed 70% FPU utilization on the
        // optimized platform (paper: 79.7% for the NAR workload).
        let p = occ();
        let c = gemm_cost(2048, 2048, 2048, FpFormat::Fp32, &p, OperandHome::default());
        let peak = p.total_clusters() as f64 * p.cluster.peak_flop_per_cycle(FpFormat::Fp32) as f64;
        let util = c.flops as f64 / (c.cycles as f64 * peak);
        assert!(util > 0.70, "util {util}");
        assert!(util <= 1.0);
    }

    #[test]
    fn baseline_much_slower() {
        let m = 1024;
        let opt = gemm_cost(m, 2048, 2048, FpFormat::Fp64, &occ(), OperandHome::default());
        let mut base_p = occ();
        base_p.features = Features::none();
        let base = gemm_cost(m, 2048, 2048, FpFormat::Fp64, &base_p, OperandHome::default());
        let ratio = base.cycles as f64 / opt.cycles as f64;
        // Paper Fig. 7/8: 4.1-5.0x from the extensions (+ double buffering).
        assert!((3.5..=8.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn precision_ladder_speeds_up() {
        let mut prev = u64::MAX;
        for fmt in FpFormat::LADDER {
            let c = gemm_cost(1024, 4096, 4096, fmt, &occ(), OperandHome::default());
            assert!(c.cycles < prev, "{fmt} not faster: {} !< {prev}", c.cycles);
            prev = c.cycles;
        }
    }

    #[test]
    fn gemv_is_memory_bound() {
        // AR-mode GEMV: exposed DMA must dominate compute.
        let c = gemv_cost(1, 4096, 4096, FpFormat::Fp32, &occ(), OperandHome::default());
        assert!(c.dma_exposed_cycles > c.compute_cycles,
                "dma {} vs compute {}", c.dma_exposed_cycles, c.compute_cycles);
        // Utilization far below the NAR regime.
        let p = occ();
        let peak = p.total_clusters() as f64 * p.cluster.peak_flop_per_cycle(FpFormat::Fp32) as f64;
        let util = c.flops as f64 / (c.cycles as f64 * peak);
        assert!(util < 0.25, "util {util}");
    }

    #[test]
    fn spm_resident_skips_hbm() {
        let c = gemm_cost(1024, 512, 512, FpFormat::Fp32, &occ(), spm_resident());
        assert_eq!(c.hbm_bytes(), 0);
        assert_eq!(c.dma_transfers, 0);
    }

    #[test]
    fn hbm_traffic_accounting() {
        let (m, k, n) = (1024u64, 1024u64, 1024u64);
        let c = gemm_cost(m, k, n, FpFormat::Fp32, &occ(), OperandHome::default());
        // Reads >= A once + B once (B is broadcast per cluster, so more).
        let min_read = (m * k + k * n) * 4;
        assert!(c.hbm_read_bytes >= min_read);
        // The Fig. 5-B dataflow re-streams partial C tiles across K steps,
        // so writes are at least one full C and at most k_tiles copies.
        assert!(c.hbm_write_bytes >= m * n * 4);
        assert!(c.hbm_write_bytes <= 32 * m * n * 4);
    }

    #[test]
    fn zero_dims_free() {
        assert_eq!(gemm_cost(0, 10, 10, FpFormat::Fp32, &occ(), OperandHome::default()).cycles, 0);
    }
}
