//! Floating-point formats supported by the Snitch SIMD FPU (paper Sec. IV-A1).
//!
//! The 64-bit-wide FPU packs 1/2/4/8 lanes for 64/32/16/8-bit formats, and
//! offers *expanding* (widening) SIMD dot products that take FP8/FP16 inputs
//! and accumulate at FP16/FP32 — the reason low-precision GEMMs keep the
//! speedup of narrow inputs without losing long-accumulation accuracy.

use std::fmt;

/// One of the six FP formats of the Snitch FPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpFormat {
    /// IEEE-754 binary64.
    Fp64,
    /// IEEE-754 binary32.
    Fp32,
    /// IEEE-754 binary16.
    Fp16,
    /// BrainFloat16 (8-bit exponent, 7-bit mantissa).
    Bf16,
    /// FP8 E5M2 (paper's "FP8").
    Fp8,
    /// FP8 E4M3 (paper's "FP8ALT").
    Fp8Alt,
}

impl FpFormat {
    /// All formats, widest first.
    pub const ALL: [FpFormat; 6] = [
        FpFormat::Fp64,
        FpFormat::Fp32,
        FpFormat::Fp16,
        FpFormat::Bf16,
        FpFormat::Fp8,
        FpFormat::Fp8Alt,
    ];

    /// The four formats the paper's precision ladder sweeps (Fig. 7/8).
    pub const LADDER: [FpFormat; 4] =
        [FpFormat::Fp64, FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8];

    /// Size of one element in bytes.
    pub const fn bytes(self) -> u64 {
        match self {
            FpFormat::Fp64 => 8,
            FpFormat::Fp32 => 4,
            FpFormat::Fp16 | FpFormat::Bf16 => 2,
            FpFormat::Fp8 | FpFormat::Fp8Alt => 1,
        }
    }

    /// SIMD lanes in the 64-bit FPU datapath (1 FMA per lane per cycle).
    pub const fn simd_lanes(self) -> u64 {
        8 / self.bytes()
    }

    /// Format elements are *accumulated* in by the widening dot-product
    /// extension (paper Sec. IV-A1): FP8 -> FP16, FP16 -> FP32; wider
    /// formats accumulate natively.
    pub const fn accumulation_format(self) -> FpFormat {
        match self {
            FpFormat::Fp8 | FpFormat::Fp8Alt => FpFormat::Fp16,
            FpFormat::Fp16 | FpFormat::Bf16 => FpFormat::Fp32,
            other => other,
        }
    }

    /// True for the sub-32-bit formats that need pack/unpack conversions
    /// around the FP32 softmax/activation islands (paper Sec. VII-C).
    pub const fn needs_fp32_conversion(self) -> bool {
        matches!(
            self,
            FpFormat::Fp16 | FpFormat::Bf16 | FpFormat::Fp8 | FpFormat::Fp8Alt
        )
    }

    /// Short lowercase name used in CLI args / configs / reports.
    pub const fn name(self) -> &'static str {
        match self {
            FpFormat::Fp64 => "fp64",
            FpFormat::Fp32 => "fp32",
            FpFormat::Fp16 => "fp16",
            FpFormat::Bf16 => "bf16",
            FpFormat::Fp8 => "fp8",
            FpFormat::Fp8Alt => "fp8alt",
        }
    }

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<FpFormat> {
        match s.to_ascii_lowercase().as_str() {
            "fp64" | "f64" => Some(FpFormat::Fp64),
            "fp32" | "f32" => Some(FpFormat::Fp32),
            "fp16" | "f16" => Some(FpFormat::Fp16),
            "bf16" => Some(FpFormat::Bf16),
            "fp8" | "f8" | "e5m2" => Some(FpFormat::Fp8),
            "fp8alt" | "e4m3" => Some(FpFormat::Fp8Alt),
            _ => None,
        }
    }
}

impl fmt::Display for FpFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for FpFormat {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        FpFormat::parse(s).ok_or_else(|| format!("unknown FP format: {s}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widening_accumulation() {
        assert_eq!(FpFormat::Fp8.accumulation_format(), FpFormat::Fp16);
        assert_eq!(FpFormat::Fp16.accumulation_format(), FpFormat::Fp32);
        assert_eq!(FpFormat::Fp64.accumulation_format(), FpFormat::Fp64);
    }

    #[test]
    fn parse_roundtrip() {
        for f in FpFormat::ALL {
            assert_eq!(FpFormat::parse(f.name()), Some(f));
        }
        assert_eq!(FpFormat::parse("nope"), None);
    }

    #[test]
    fn conversion_islands() {
        assert!(!FpFormat::Fp64.needs_fp32_conversion());
        assert!(!FpFormat::Fp32.needs_fp32_conversion());
        assert!(FpFormat::Fp8.needs_fp32_conversion());
        assert!(FpFormat::Bf16.needs_fp32_conversion());
    }
}
