//! Property tests for the paged KV allocator and the chunked-prefill
//! scheduler (seeded-LCG case generation; no proptest in the offline
//! registry):
//!
//! * allocator: no page is ever owned twice, mapped bytes never exceed
//!   the budget, and releasing every table makes the pool whole;
//! * chunked prefill: prompt tokens are conserved (each prefilled exactly
//!   once absent preemption), and the TTFT of a short request admitted
//!   behind a long prompt strictly improves over monolithic prefill;
//! * end-to-end: chunked prefill cuts p99 TTFT on a mixed interactive +
//!   batch-ingest trace (the `serve` acceptance configuration).

mod common;

use std::collections::HashSet;

use common::Rng;
use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{
    BatcherConfig, ContinuousBatcher, InferenceEngine, KvGeometry, PagedKvAllocator,
    PageTable, Request, Workload,
};
use snitch_fm::model::ModelConfig;

#[test]
fn allocator_never_double_allocates_and_respects_budget() {
    let mut rng = Rng(0xA110C);
    for case in 0..50 {
        let page_tokens = rng.next(1, 64);
        let token_bytes = rng.next(1, 4096);
        let geom = KvGeometry { token_bytes, page_tokens, format: FpFormat::Fp32 };
        let total_pages = rng.next(1, 64);
        let budget = total_pages * geom.page_bytes() + rng.next(0, geom.page_bytes() - 1);
        let mut alloc = PagedKvAllocator::new(budget, geom);
        assert_eq!(alloc.total_pages(), total_pages, "case {case}");

        let mut tables: Vec<PageTable> = (0..rng.next(1, 8)).map(|_| PageTable::new()).collect();
        for _ in 0..200 {
            let i = rng.next(0, tables.len() as u64 - 1) as usize;
            match rng.next(0, 3) {
                0 => {
                    // Grow to a random token count (may fail; must not corrupt).
                    let want = rng.next(0, total_pages * page_tokens + page_tokens);
                    let before = tables[i].len();
                    let ok = alloc.try_grow(&mut tables[i], want);
                    if !ok {
                        assert_eq!(tables[i].len(), before, "failed grow mutated table");
                    } else {
                        assert!(tables[i].capacity_tokens(&geom) >= want);
                    }
                }
                1 => alloc.release(&mut tables[i]),
                _ => {
                    // Grow by one token past current capacity (decode step).
                    let want = tables[i].capacity_tokens(&geom) + 1;
                    let _ = alloc.try_grow(&mut tables[i], want);
                }
            }
            // Invariants after every operation.
            let mut seen = HashSet::new();
            let mut mapped = 0u64;
            for t in &tables {
                for &p in t.pages() {
                    assert!((p as u64) < alloc.total_pages(), "page id out of range");
                    assert!(seen.insert(p), "page {p} owned twice (case {case})");
                }
                mapped += t.len() as u64;
            }
            assert_eq!(mapped, alloc.used_pages());
            assert!(alloc.bytes_in_use() <= budget, "over budget (case {case})");
            assert_eq!(alloc.free_pages() + alloc.used_pages(), alloc.total_pages());
        }
        for t in &mut tables {
            alloc.release(t);
        }
        assert_eq!(alloc.used_pages(), 0, "drained pool must be whole (case {case})");
        assert_eq!(alloc.free_pages(), alloc.total_pages());
    }
}

#[test]
fn chunked_prefill_conserves_prompt_tokens() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let mut rng = Rng(0xC0DE);
    for case in 0..25 {
        let n = rng.next(1, 10) as usize;
        let w = Workload::synthetic(rng.next(1, 1 << 30), n, (8, 96), (2, 16));
        // Budget generous enough (page-rounding included) that nothing is
        // rejected or preempted: conservation then means every prompt
        // token prefilled exactly once.
        let page_tokens = rng.next(1, 32);
        let geom = KvGeometry::new(&cfg, FpFormat::Fp32, page_tokens);
        let budget = w
            .requests
            .iter()
            .map(|r| geom.pages_for(r.kv_capacity()) * geom.page_bytes())
            .sum::<u64>()
            * 2;
        let mut opts = BatcherConfig::new(rng.next(1, 6) as usize, budget);
        opts.prefill_chunk = rng.next(0, 48);
        opts.page_tokens = page_tokens;
        let r = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts).run(&w);
        assert_eq!(r.completed, n, "case {case}");
        assert_eq!(r.preemptions, 0, "case {case}");
        assert_eq!(
            r.prefill_tokens,
            w.total_prompt_tokens(),
            "case {case}: chunking must conserve prompt tokens ({opts:?})"
        );
        assert_eq!(r.gen_tokens, w.total_gen_tokens(), "case {case}");
        // Chunk accounting: ceil(prompt/chunk) passes per request.
        if opts.prefill_chunk > 0 {
            let expect: u64 =
                w.requests.iter().map(|q| q.prompt_len.div_ceil(opts.prefill_chunk)).sum();
            assert_eq!(r.prefill_chunks, expect, "case {case}");
        } else {
            assert_eq!(r.prefill_chunks, n as u64, "case {case}");
        }
    }
}

#[test]
fn short_request_behind_long_prompt_ttft_strictly_improves() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    // A long prompt admitted first, a short interactive request right
    // behind it, both resident (two slots).
    let mut w = Workload::default();
    w.requests.push(Request::new(0, 256, 8));
    w.requests.push(Request::new(1, 16, 8));
    let budget = Request::new(0, 256, 8).kv_bytes(&cfg) * 4;

    let mono = BatcherConfig::new(2, budget);
    let r_mono = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, mono).run(&w);
    let mut chunked = mono;
    chunked.prefill_chunk = 32;
    let r_chunk = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, chunked).run(&w);

    let ttft = |r: &snitch_fm::coordinator::ServeReport, id: usize| {
        r.per_request.iter().find(|s| s.id == id).unwrap().ttft_s
    };
    assert!(
        ttft(&r_chunk, 1) < ttft(&r_mono, 1),
        "short request behind a long prompt must see first token sooner \
         with chunked prefill: {} !< {}",
        ttft(&r_chunk, 1),
        ttft(&r_mono, 1)
    );
    // Same tokens served either way.
    assert_eq!(r_chunk.gen_tokens, r_mono.gen_tokens);
    assert_eq!(r_chunk.prefill_tokens, r_mono.prefill_tokens);
}

#[test]
fn chunked_prefill_cuts_p99_ttft_on_mixed_trace() {
    // The acceptance scenario behind `serve --prefill-chunk`: a long
    // batch-ingest prompt (prefill-only, patient class) admitted at t=0
    // plus short interactive requests arriving just behind it open-loop.
    // Slots cover every request, so with monolithic prefill each short's
    // first token waits for the entire long prompt, while chunking bounds
    // that wait to one chunk. p99 TTFT spans the interactive requests
    // (prefill-only requests generate nothing), and must drop.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    // Rate 1e6/s: every short arrives within ~20 us, far inside the long
    // prompt's prefill.
    let mut w = Workload::default();
    w.requests.push(Request::new(0, 512, 0).with_class(1));
    let mut shorts = Workload::synthetic(9, 12, (8, 32), (4, 12))
        .with_poisson_arrivals(5, 1e6);
    for s in &mut shorts.requests {
        s.id += 1;
        s.arrival_ns += 1; // strictly after the long prompt
    }
    w.requests.extend(shorts.requests);
    let budget = Request::new(0, 512, 0).kv_bytes(&cfg) * 16;

    let mono = BatcherConfig::new(16, budget);
    let r_mono = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, mono).run(&w);
    let mut chunked = mono;
    chunked.prefill_chunk = 32;
    let r_chunk = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, chunked).run(&w);

    assert_eq!(r_mono.completed, 13);
    assert_eq!(r_chunk.completed, 13);
    assert!(
        r_chunk.ttft_p99_s < r_mono.ttft_p99_s,
        "chunked p99 TTFT {} !< monolithic {}",
        r_chunk.ttft_p99_s,
        r_mono.ttft_p99_s
    );
    // p50 improves too: the benefit is not confined to the tail.
    assert!(r_chunk.ttft_p50_s < r_mono.ttft_p50_s);
}

#[test]
fn followers_hit_pages_registered_mid_prefill() {
    // Prefix pages are registered chunk by chunk as a leader prefills: a
    // follower admitted mid-prefill attaches the pages registered so far,
    // and the chunk-boundary re-probe picks up every later template page
    // as whichever request gets there first registers it — so the shared
    // 96 tokens are materialized exactly once across the pair.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let mut w = Workload::uniform(2, 32, 4).with_shared_prefix(96, 2);
    // The follower arrives 1 ns in: admitted right after the leader's
    // first 16-token chunk, when exactly one template page is registered.
    w.requests[1].arrival_ns = 1;
    let budget = Request::new(0, 128, 4).kv_bytes(&cfg) * 8;
    let mut opts = BatcherConfig::new(4, budget);
    opts.prefill_chunk = 16;
    let r = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts).run(&w);
    assert_eq!(r.completed, 2);
    assert_eq!(
        r.prefix_hit_tokens, 96,
        "the template must be prefilled exactly once across the pair"
    );
    assert!(r.prefix_late_hits > 0, "re-probe must land mid-prefill hits");
    assert!(r.prefix_late_hits < r.prefix_hit_tokens, "admission hit too");
    // Every prompt token of both requests is covered exactly once.
    assert_eq!(r.prefill_tokens + r.prefix_hit_tokens, 2 * 128);
    assert_eq!(r.gen_tokens, 2 * 4);
}

#[test]
fn token_budget_open_loop_trace_completes_and_fills_budget() {
    // Sarathi-style mixed passes under the full feature stack: priority
    // classes, shared prefixes, open-loop arrivals, chunk cap.
    let cfg = ModelConfig::tiny();
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let w = Workload::synthetic(7, 24, (8, 64), (2, 12))
        .with_priority_classes(3)
        .with_shared_prefix(32, 4)
        .with_poisson_arrivals(9, 500.0);
    let mut opts = BatcherConfig::new(8, 0);
    opts.token_budget = 48;
    opts.prefill_chunk = 16;
    let r = e.serve_with(&cfg, &w, opts, FpFormat::Fp32);
    assert_eq!(r.completed, 24);
    assert_eq!(r.gen_tokens, w.total_gen_tokens());
    assert_eq!(r.prefill_tokens + r.prefix_hit_tokens, w.total_prompt_tokens());
    assert!(
        r.budget_utilization > 0.0 && r.budget_utilization <= 1.0,
        "{}",
        r.budget_utilization
    );
    assert!(r.peak_kv_bytes <= e.kv_budget_bytes(&cfg, FpFormat::Fp32));
}

#[test]
fn no_prefix_cache_path_is_deterministic_and_hit_free() {
    // The `--no-prefix-cache --prefill-chunk` configuration is the PR-2
    // code path: no hits, no sharing, and exactly reproducible.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let w = Workload::synthetic(11, 12, (8, 96), (2, 10))
        .with_poisson_arrivals(4, 200.0);
    let mut opts = BatcherConfig::new(4, 0);
    opts.prefill_chunk = 32;
    opts.prefix_cache = false;
    let a = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts).run(&w);
    let b = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp32, opts).run(&w);
    assert!(!a.prefix_cache);
    assert_eq!(a.prefix_hit_tokens, 0);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.ttft_p99_s, b.ttft_p99_s);
    assert_eq!(a.latency_p99_s, b.latency_p99_s);
    assert_eq!(a.prefill_chunks, b.prefill_chunks);
    assert_eq!(a.tokens_per_s, b.tokens_per_s);
}

#[test]
fn serve_with_peak_kv_within_engine_budget() {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::tiny();
    let w = Workload::synthetic(3, 16, (8, 64), (4, 32));
    for chunk in [0u64, 16] {
        let mut opts = BatcherConfig::new(4, 0);
        opts.prefill_chunk = chunk;
        let r = e.serve_with(&cfg, &w, opts, FpFormat::Fp32);
        assert_eq!(r.completed, 16);
        assert!(r.peak_kv_bytes <= e.kv_budget_bytes(&cfg, FpFormat::Fp32));
        assert!(r.total_pages > 0);
    }
}
