//! Shard-plan enumeration and selection.
//!
//! Enumerates every legal `{tp, pp, replicas}` assignment for the
//! platform's die count, prices each with [`shard::plan_cost`], and ranks
//! them by the chosen objective:
//!
//! * [`Objective::Latency`] — minimize the modeled per-token latency
//!   through the pipe (interactive serving; favors TP, then PP).
//! * [`Objective::Throughput`] — maximize aggregate tokens/s at the
//!   priced batch (batch serving; favors replicas, whose scaling pays no
//!   collective tax).
//!
//! Ties break toward fewer dies, then lexicographic `(tp, pp, replicas)`
//! so the ranking is fully deterministic.

use crate::arch::{FpFormat, PlatformConfig, PrecisionPolicy};
use crate::coordinator::schedule::{kv_requant_layer, layer_cost_with_kv, model_cost_batched};
use crate::coordinator::workload::Workload;
use crate::model::{Mode, ModelConfig};
use crate::parallel::shard::{plan_cost, PlanCost, ShardPlan};

/// What the planner optimizes for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Cheapest modeled per-token latency.
    Latency,
    /// Highest aggregate tokens/s across replicas.
    Throughput,
}

impl Objective {
    /// Parse `latency` | `throughput`.
    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "latency" => Some(Objective::Latency),
            "throughput" => Some(Objective::Throughput),
            _ => None,
        }
    }

    /// The CLI/report spelling of the objective.
    pub const fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Throughput => "throughput",
        }
    }
}

/// One plan with its priced pass and per-replica KV budget.
#[derive(Debug, Clone)]
pub struct RankedPlan {
    /// The `{tp, pp, replicas}` assignment.
    pub plan: ShardPlan,
    /// Its priced decode step (see [`plan_cost`]).
    pub cost: PlanCost,
    /// KV budget one replica offers the serving scheduler (whole-model
    /// token bytes; see [`ShardPlan::replica_kv_budget_bytes`]).
    pub kv_budget_bytes: u64,
}

/// Every legal plan for `cfg` on the platform's dies, unranked.
pub fn enumerate_plans(cfg: &ModelConfig, platform: &PlatformConfig) -> Vec<ShardPlan> {
    let dies = platform.die.dies.max(1);
    let mut out = Vec::new();
    for tp in 1..=dies {
        for pp in 1..=dies {
            for replicas in 1..=dies {
                let plan = ShardPlan { tp, pp, replicas };
                if plan.dies() <= dies && plan.is_legal(cfg, platform) {
                    out.push(plan);
                }
            }
        }
    }
    out
}

/// Price every legal plan for a decode step at KV length `s` and batch
/// `b`, ranked best-first by `objective`.
pub fn best_plans(
    cfg: &ModelConfig,
    fmt: FpFormat,
    platform: &PlatformConfig,
    mode: Mode,
    b: u64,
    s: u64,
    objective: Objective,
) -> Vec<RankedPlan> {
    best_plans_policy(cfg, PrecisionPolicy::uniform(fmt), platform, mode, b, s, objective)
}

/// [`best_plans`] under a decoupled precision policy: passes price at
/// `policy.compute` and every plan's per-replica KV budget is recomputed
/// from the policy's weight/KV formats
/// ([`ShardPlan::replica_kv_budget_bytes_policy`]), so a narrow KV format
/// surfaces as a larger budget in the ranking. The uniform policy is
/// bit-identical to the format-scalar version.
pub fn best_plans_policy(
    cfg: &ModelConfig,
    policy: PrecisionPolicy,
    platform: &PlatformConfig,
    mode: Mode,
    b: u64,
    s: u64,
    objective: Objective,
) -> Vec<RankedPlan> {
    let mut ranked: Vec<RankedPlan> = enumerate_plans(cfg, platform)
        .into_iter()
        .map(|plan| RankedPlan {
            plan,
            cost: plan_cost(cfg, plan, mode, b, s, policy.compute, platform),
            kv_budget_bytes: plan.replica_kv_budget_bytes_policy(cfg, policy, platform),
        })
        .collect();
    let tie = |p: &ShardPlan| (p.dies(), p.tp, p.pp, p.replicas);
    match objective {
        Objective::Latency => {
            ranked.sort_by_key(|r| (r.cost.token_latency_cycles, tie(&r.plan)));
        }
        Objective::Throughput => {
            ranked.sort_by(|a, b| {
                b.cost
                    .tokens_per_s
                    .partial_cmp(&a.cost.tokens_per_s)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| tie(&a.plan).cmp(&tie(&b.plan)))
            });
        }
    }
    ranked
}

/// A disaggregated fleet split candidate: `prefill + decode` replicas at
/// the same die budget, with its modeled steady-state request rate.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSplit {
    /// Replicas dedicated to prefill.
    pub prefill: usize,
    /// Replicas dedicated to decode.
    pub decode: usize,
    /// Modeled request throughput (requests/s): the slower stage
    /// bottlenecks the pipe.
    pub rate: f64,
    /// Which stage bottlenecks this split (`"prefill"` | `"decode"`).
    pub bottleneck: &'static str,
}

/// The fleet-split ranking [`rank_fleet_splits`] produces.
#[derive(Debug, Clone, PartialEq)]
pub struct SplitRanking {
    /// Every `{prefill, decode}` split of the replica budget, best-first.
    pub splits: Vec<FleetSplit>,
    /// The same replicas run symmetrically (each doing both phases), as
    /// the reference the splits are ranked against. On pure *throughput*
    /// the symmetric fleet is never behind — disaggregation's win is
    /// isolation (p99 TPOT), which `benches/disagg_serving.rs` measures.
    pub symmetric_rate: f64,
}

/// Rank every `{prefill: p, decode: d}` split of `replicas` engines for
/// `workload`'s mean request shape, best-first by modeled request rate.
///
/// The model prices one NAR prefill pass at the mean prompt (prefill is
/// compute-bound, so a prefill replica serves `1/prefill_seconds`
/// requests/s) and one AR decode step at batch `max_batch` and the mean
/// full context (decode is memory-bound; a decode replica amortizes the
/// step over the batch, serving `b / (gen * step_seconds)` requests/s).
/// Ties break toward fewer prefill replicas — decode capacity is where
/// the platform's AR utilization is weakest — making the ranking fully
/// deterministic. Powers `serve --disagg auto`.
pub fn rank_fleet_splits(
    cfg: &ModelConfig,
    fmt: FpFormat,
    platform: &PlatformConfig,
    workload: &Workload,
    max_batch: usize,
    replicas: usize,
) -> SplitRanking {
    rank_fleet_splits_policy(
        cfg,
        PrecisionPolicy::uniform(fmt),
        platform,
        workload,
        max_batch,
        replicas,
    )
}

/// [`rank_fleet_splits`] under a decoupled precision policy: both stage
/// passes price at `policy.compute`, and when KV is stored narrower than
/// compute each pass additionally bills the per-block requant kernel
/// ([`kv_requant_layer`]) its shape implies — prefill writes the prompt's
/// KV, a decode step reads the full context back. The uniform policy is
/// bit-identical to the format-scalar version (the conversion terms are
/// exactly zero).
pub fn rank_fleet_splits_policy(
    cfg: &ModelConfig,
    policy: PrecisionPolicy,
    platform: &PlatformConfig,
    workload: &Workload,
    max_batch: usize,
    replicas: usize,
) -> SplitRanking {
    let n = workload.len().max(1) as u64;
    let mean_prompt = (workload.total_prompt_tokens() / n).max(1);
    let mean_gen = (workload.total_gen_tokens() / n).max(1);
    let b = max_batch.max(1) as u64;
    let mut prefill_cycles =
        model_cost_batched(cfg, Mode::Nar, 1, mean_prompt, policy.compute, platform).cycles;
    let mut step_cycles =
        model_cost_batched(cfg, Mode::Ar, b, mean_prompt + mean_gen, policy.compute, platform)
            .cycles;
    if policy.kv_conversion_active() {
        if let Some(layer) = kv_requant_layer(cfg, &[(mean_prompt, 0)], &[]) {
            prefill_cycles +=
                layer_cost_with_kv(&layer, policy.compute, policy.kv, platform).cycles
                    * cfg.blocks;
        }
        let decode_kv = vec![mean_prompt + mean_gen; b as usize];
        if let Some(layer) = kv_requant_layer(cfg, &[], &decode_kv) {
            step_cycles += layer_cost_with_kv(&layer, policy.compute, policy.kv, platform).cycles
                * cfg.blocks;
        }
    }
    let prefill_s = platform.cycles_to_seconds(prefill_cycles);
    let step_s = platform.cycles_to_seconds(step_cycles);
    let decode_req_s = step_s * mean_gen as f64 / b as f64;
    let r = replicas.max(2);
    let mut splits: Vec<FleetSplit> = (1..r)
        .map(|p| {
            let d = r - p;
            let prefill_rate = p as f64 / prefill_s;
            let decode_rate = d as f64 / decode_req_s;
            let (rate, bottleneck) = if prefill_rate <= decode_rate {
                (prefill_rate, "prefill")
            } else {
                (decode_rate, "decode")
            };
            FleetSplit { prefill: p, decode: d, rate, bottleneck }
        })
        .collect();
    splits.sort_by(|x, y| {
        y.rate
            .partial_cmp(&x.rate)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| x.prefill.cmp(&y.prefill))
    });
    SplitRanking { splits, symmetric_rate: r as f64 / (prefill_s + decode_req_s) }
}

/// Whether an offered die budget can hold two `tp x pp` replica groups
/// at all — the precondition `serve --disagg auto` checks before asking
/// [`rank_fleet_splits`] for a {prefill, decode} split. A single die, or
/// a `tp * pp` product already consuming every offered die, leaves no
/// room for a second group; the CLI then degrades to the symmetric
/// fleet with a warning instead of bailing. `offered_dies == 0` means
/// no explicit budget was given (the package is free to grow).
pub fn disagg_split_feasible(tp: u32, pp: u32, offered_dies: u32) -> bool {
    offered_dies == 0 || tp * pp * 2 <= offered_dies
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_parse() {
        assert_eq!(Objective::parse("latency"), Some(Objective::Latency));
        assert_eq!(Objective::parse("throughput"), Some(Objective::Throughput));
        assert_eq!(Objective::parse("nope"), None);
    }

    #[test]
    fn single_die_has_exactly_the_degenerate_plan() {
        let cfg = ModelConfig::gpt_j();
        let plans = enumerate_plans(&cfg, &PlatformConfig::occamy());
        assert_eq!(plans, vec![ShardPlan::single()]);
    }

    #[test]
    fn enumeration_is_bounded_and_legal() {
        let cfg = ModelConfig::gpt_j(); // 16 heads: tp in {1,2,4} on 4 dies
        let p = PlatformConfig::with_dies(4);
        let plans = enumerate_plans(&cfg, &p);
        assert!(plans.contains(&ShardPlan::single()));
        assert!(plans.contains(&ShardPlan { tp: 2, pp: 2, replicas: 1 }));
        assert!(plans.contains(&ShardPlan { tp: 1, pp: 1, replicas: 4 }));
        for plan in &plans {
            assert!(plan.dies() <= 4, "{plan:?}");
            assert!(plan.is_legal(&cfg, &p), "{plan:?}");
        }
        // tp=3 never divides 16 heads.
        assert!(!plans.iter().any(|p| p.tp == 3));
    }

    #[test]
    fn throughput_objective_picks_full_data_parallelism() {
        // Replica scaling pays no collective tax, so at a fixed per-engine
        // batch the throughput-optimal plan uses every die as a replica.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let ranked = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Throughput);
        let best = &ranked[0];
        assert_eq!(best.plan, ShardPlan { tp: 1, pp: 1, replicas: 4 });
        let single = ranked
            .iter()
            .find(|r| r.plan == ShardPlan::single())
            .expect("single plan enumerated");
        assert!(best.cost.tokens_per_s > single.cost.tokens_per_s);
    }

    #[test]
    fn split_ranking_covers_every_split_and_is_deterministic() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(8);
        let w = crate::coordinator::workload::Workload::synthetic(32, 5, (64, 256), (16, 128));
        let a = rank_fleet_splits(&cfg, FpFormat::Fp8, &p, &w, 8, 8);
        let b = rank_fleet_splits(&cfg, FpFormat::Fp8, &p, &w, 8, 8);
        assert_eq!(a, b);
        assert_eq!(a.splits.len(), 7, "every {{p, d}} with p + d = 8, p >= 1, d >= 1");
        let mut sums: Vec<usize> = a.splits.iter().map(|s| s.prefill + s.decode).collect();
        sums.dedup();
        assert_eq!(sums, vec![8]);
        // Best-first: rates never increase down the ranking.
        for pair in a.splits.windows(2) {
            assert!(pair[0].rate >= pair[1].rate);
        }
        assert!(a.symmetric_rate > 0.0);
    }

    #[test]
    fn chatty_decode_trace_ranks_decode_heavy_splits_first() {
        // Short prompts, long generations: decode work dominates, so the
        // best split dedicates most dies to decode.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(8);
        let w = crate::coordinator::workload::Workload::uniform(16, 16, 256);
        let ranked = rank_fleet_splits(&cfg, FpFormat::Fp8, &p, &w, 8, 8);
        let best = &ranked.splits[0];
        assert!(
            best.decode > best.prefill,
            "chatty trace must go decode-heavy: {best:?}"
        );
    }

    #[test]
    fn latency_objective_picks_a_sharded_plan() {
        // Decode is weight-streaming-bound: splitting the stream across
        // dies must beat the single engine on per-token latency.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let ranked = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Latency);
        let best = &ranked[0];
        assert!(best.plan.tp > 1, "latency plan must shard: {:?}", best.plan);
        let single = ranked
            .iter()
            .find(|r| r.plan == ShardPlan::single())
            .expect("single plan enumerated");
        assert!(best.cost.token_latency_cycles < single.cost.token_latency_cycles);
    }
}
