//! i-GELU timing model (paper Sec. V-A4).
//!
//! The GELU is approximated with the i-GELU polynomial (Kim et al.) to
//! avoid division/tanh; evaluated in FP32 (with pack/unpack conversions in
//! the low-precision variants) and usually *fused* with the preceding
//! Linear layer, in which case the activations are already SPM-resident
//! and no HBM traffic occurs.

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::sim::cluster::{ClusterSim, TilePhase};
use crate::sim::core::{opcost, CoreModel};
use crate::sim::dma::Transfer;
use crate::sim::{KernelCost, MultiClusterSim};

/// Cost of i-GELU over an `s x f` tensor. `fused` = the input is already
/// in SPM from the preceding Linear (paper's layer fusion) and the output
/// stays there for the next GEMM.
pub fn gelu_cost(
    s: u64,
    f: u64,
    fmt: FpFormat,
    fused: bool,
    platform: &PlatformConfig,
) -> KernelCost {
    if s == 0 || f == 0 {
        return KernelCost::default();
    }
    let clusters = platform.total_clusters() as u64;
    let core = CoreModel::new(platform.cluster, platform.features);
    let cores = platform.cluster.compute_cores;
    let el = fmt.bytes();
    let rows = s.div_ceil(clusters).max(1).min(s);
    let active = s.div_ceil(rows).min(clusters);
    let elems_per_core = (rows * f).div_ceil(cores);

    // Polynomial evaluated on the FP32 lanes; conversions for narrow io.
    let mut compute =
        core.elementwise_cycles(elems_per_core, opcost::IGELU, FpFormat::Fp32, true);
    if fmt.needs_fp32_conversion() {
        compute += 2 * core.elementwise_cycles(elems_per_core, opcost::CONVERT, fmt, true);
    }
    let flops = rows * f * opcost::IGELU; // polynomial FMAs
    let mut phase = TilePhase::compute(compute, flops);
    if !fused {
        phase = phase
            .with_transfer(Transfer::d2(rows * f * el, rows, MemLevel::Hbm))
            .with_transfer(Transfer::d2(rows * f * el, rows, MemLevel::Hbm).to_write());
    }
    let csim = ClusterSim::new(platform).with_hbm_sharers(active);
    let one = csim.run(&[phase]);
    let sim = MultiClusterSim::new(platform);
    let per: Vec<KernelCost> = (0..active).map(|_| one).collect();
    sim.parallel(&per)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn fused_has_no_hbm_traffic() {
        let c = gelu_cost(1024, 8192, FpFormat::Fp32, true, &occ());
        assert_eq!(c.hbm_bytes(), 0);
        let u = gelu_cost(1024, 8192, FpFormat::Fp32, false, &occ());
        assert_eq!(u.hbm_bytes(), 2 * 1024 * 8192 * 4);
        assert!(u.cycles > c.cycles);
    }

    #[test]
    fn narrow_formats_pay_conversions() {
        let f32c = gelu_cost(1024, 8192, FpFormat::Fp32, true, &occ());
        let f8c = gelu_cost(1024, 8192, FpFormat::Fp8, true, &occ());
        // FP8 GELU is NOT 4x faster: polynomial runs on the FP32 island.
        assert!(f8c.cycles * 3 > f32c.cycles, "f8 {} f32 {}", f8c.cycles, f32c.cycles);
    }

    #[test]
    fn scales_with_elements() {
        let a = gelu_cost(256, 1024, FpFormat::Fp32, true, &occ());
        let b = gelu_cost(1024, 1024, FpFormat::Fp32, true, &occ());
        assert!(b.cycles > 3 * a.cycles);
    }
}
