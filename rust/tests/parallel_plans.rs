//! Integration and property tests for the multi-die parallelism
//! subsystem: collective-pricing invariants (symmetry, monotonicity),
//! shard-plan degeneracy (the single plan is bit-identical to the
//! single-engine paths), planner selection, and the replica router.

mod common;

use common::Rng;
use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::schedule::block_cost_batched;
use snitch_fm::coordinator::{BatcherConfig, InferenceEngine, Workload};
use snitch_fm::model::{Mode, ModelConfig};
use snitch_fm::parallel::{
    all_gather_cost, all_reduce_cost, best_plans, p2p_cost, reduce_scatter_cost,
    serve_replicated, sharded_block_cost, Algorithm, Objective, RoutePolicy, ShardPlan,
};

const CASES: usize = 100;

#[test]
fn ring_all_reduce_symmetric_in_rank_order() {
    // The collective's cost may depend on the rank COUNT only: any
    // permutation (and any choice) of die ids prices identically.
    let p = PlatformConfig::with_dies(8);
    let mut rng = Rng(0xD1E5);
    for _ in 0..CASES {
        let n = rng.next(2, 8) as u32;
        let bytes = rng.next(1, 1 << 22);
        let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8]);
        let forward: Vec<u32> = (0..n).collect();
        let mut reversed = forward.clone();
        reversed.reverse();
        // A rotated id window exercises non-zero-based rank sets.
        let shifted: Vec<u32> = (0..n).map(|i| (i + 8 - n) % 8).collect();
        for alg in [Algorithm::Ring, Algorithm::Tree, Algorithm::Auto] {
            let a = all_reduce_cost(bytes, &forward, alg, fmt, &p);
            assert_eq!(a, all_reduce_cost(bytes, &reversed, alg, fmt, &p));
            assert_eq!(a, all_reduce_cost(bytes, &shifted, alg, fmt, &p));
        }
    }
}

#[test]
fn collective_cost_monotone_in_payload() {
    let p = PlatformConfig::with_dies(8);
    let mut rng = Rng(0xB17E5);
    for _ in 0..CASES {
        let n = rng.next(2, 8) as u32;
        let ranks: Vec<u32> = (0..n).collect();
        let small = rng.next(1, 1 << 20);
        let big = small + rng.next(1 << 12, 1 << 22);
        let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp8]);
        for alg in [Algorithm::Ring, Algorithm::Tree] {
            let a = all_reduce_cost(small, &ranks, alg, fmt, &p);
            let b = all_reduce_cost(big, &ranks, alg, fmt, &p);
            assert!(a.cycles <= b.cycles, "{alg:?} n={n} {small} vs {big}");
            assert!(a.d2d_bytes < b.d2d_bytes);
        }
        assert!(
            reduce_scatter_cost(small, &ranks, fmt, &p).cycles
                <= reduce_scatter_cost(big, &ranks, fmt, &p).cycles
        );
        assert!(
            all_gather_cost(small, &ranks, &p).cycles
                <= all_gather_cost(big, &ranks, &p).cycles
        );
        assert!(p2p_cost(small, &p).cycles <= p2p_cost(big, &p).cycles);
    }
}

#[test]
fn ring_all_reduce_monotone_in_rank_count() {
    // More ranks move more total bytes per die (2B(n-1)/n) and pay more
    // per-step latency, so the ring cost grows strictly with the count.
    let p = PlatformConfig::with_dies(16);
    let mut rng = Rng(0x4A11);
    for _ in 0..CASES {
        let bytes = rng.next(1, 1 << 22);
        let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp8]);
        let mut prev = 0u64;
        for n in 2..=16u32 {
            let ranks: Vec<u32> = (0..n).collect();
            let c = all_reduce_cost(bytes, &ranks, Algorithm::Ring, fmt, &p);
            assert!(
                c.cycles > prev,
                "ring n={n} bytes={bytes}: {} !> {prev}",
                c.cycles
            );
            prev = c.cycles;
        }
        // The tree grows with its level count (non-strict within a level
        // plateau: 5..=8 ranks share ceil(log2 n) = 3).
        let mut prev = 0u64;
        for n in 2..=16u32 {
            let ranks: Vec<u32> = (0..n).collect();
            let c = all_reduce_cost(bytes, &ranks, Algorithm::Tree, fmt, &p);
            assert!(c.cycles >= prev, "tree n={n} bytes={bytes}");
            prev = c.cycles;
        }
    }
}

#[test]
fn sharded_tp1_pricing_bit_identical_to_block_cost_batched() {
    // The acceptance property: the degenerate shard plan reproduces the
    // existing pricing exactly, across modes, shapes, and precisions.
    let p = PlatformConfig::occamy();
    let mut rng = Rng(0x5EED);
    for model in [ModelConfig::tiny(), ModelConfig::gpt_j(), ModelConfig::vit_b()] {
        for _ in 0..20 {
            let b = rng.next(1, 8);
            let s = rng.next(1, 512);
            let kv = rng.next(0, 1024);
            let fmt = rng.pick(&[FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8]);
            for (mode, s, kv) in [(Mode::Nar, s, kv), (Mode::Ar, 1, kv)] {
                let sharded = sharded_block_cost(&model, 1, mode, b, s, kv, fmt, &p);
                let batched = block_cost_batched(&model, mode, b, s, kv, fmt, &p).total;
                assert_eq!(sharded, batched, "{} {mode:?} b={b} s={s} kv={kv}", model.name);
            }
        }
    }
}

#[test]
fn planner_objectives_disagree_and_both_beat_single() {
    let cfg = ModelConfig::gpt_j();
    let p = PlatformConfig::with_dies(4);
    let fmt = FpFormat::Fp8;
    let by_tp = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Latency);
    let by_thr = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Throughput);
    let single_lat = by_tp
        .iter()
        .find(|r| r.plan == ShardPlan::single())
        .unwrap()
        .cost
        .token_latency_cycles;
    let single_thr = by_thr
        .iter()
        .find(|r| r.plan == ShardPlan::single())
        .unwrap()
        .cost
        .tokens_per_s;
    assert!(by_tp[0].cost.token_latency_cycles < single_lat);
    assert!(by_thr[0].cost.tokens_per_s > single_thr);
    // Latency shards the weight stream; throughput replicates engines.
    assert!(by_tp[0].plan.tp > 1);
    assert_eq!(by_thr[0].plan.replicas, 4);
}

#[test]
fn router_single_replica_bit_identical_to_serve_with() {
    // Acceptance: ShardPlan { tp: 1, pp: 1, replicas: 1 } through the
    // router reproduces today's serve metrics bit-for-bit.
    let cfg = ModelConfig::tiny();
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let w = Workload::synthetic(7, 16, (8, 64), (2, 12))
        .with_shared_prefix(32, 4)
        .with_poisson_arrivals(9, 500.0);
    let mut opts = BatcherConfig::new(4, 0);
    opts.prefill_chunk = 16;
    let direct = e.serve_with(&cfg, &w, opts, FpFormat::Fp32);
    let routed = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        1,
        RoutePolicy::PrefixAffinity,
    );
    assert_eq!(routed.replicas, 1);
    assert_eq!(routed.assigned, vec![16]);
    let m = &routed.merged;
    assert_eq!(m.total_cycles, direct.total_cycles);
    assert_eq!(m.completed, direct.completed);
    assert_eq!(m.tokens_per_s, direct.tokens_per_s);
    assert_eq!(m.decode_tokens_per_s, direct.decode_tokens_per_s);
    assert_eq!(m.ttft_p50_s, direct.ttft_p50_s);
    assert_eq!(m.ttft_p99_s, direct.ttft_p99_s);
    assert_eq!(m.latency_p99_s, direct.latency_p99_s);
    assert_eq!(m.prefill_tokens, direct.prefill_tokens);
    assert_eq!(m.prefix_hit_tokens, direct.prefix_hit_tokens);
    assert_eq!(m.peak_kv_bytes, direct.peak_kv_bytes);
    assert_eq!(m.preemptions, direct.preemptions);
    assert_eq!(m.per_request.len(), direct.per_request.len());
}

#[test]
fn router_replicas_serve_everything_and_cut_wall_clock() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(4);
    let e = InferenceEngine::new(p);
    // Closed-loop heavy load: a single engine serializes, replicas split.
    let w = Workload::synthetic(3, 32, (16, 96), (4, 16));
    let opts = BatcherConfig::new(4, 0);
    let single = e.serve_with(&cfg, &w, opts, FpFormat::Fp32);
    let fleet = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        4,
        RoutePolicy::JoinShortestQueue,
    );
    assert_eq!(fleet.merged.completed, 32);
    assert_eq!(fleet.merged.gen_tokens, w.total_gen_tokens());
    assert_eq!(fleet.assigned.iter().sum::<usize>(), 32);
    assert!(fleet.per_replica.iter().all(|r| !r.per_request.is_empty()));
    assert!(
        fleet.merged.total_seconds < single.total_seconds,
        "4 replicas must finish sooner: {} !< {}",
        fleet.merged.total_seconds,
        single.total_seconds
    );
    assert!(fleet.merged.tokens_per_s > single.tokens_per_s);
    // Budget accounting spans the fleet.
    assert_eq!(
        fleet.merged.kv_budget_bytes,
        fleet.per_replica.iter().map(|r| r.kv_budget_bytes).sum::<u64>()
    );
}

#[test]
fn prefix_affinity_beats_jsq_hit_rate_on_shared_prefix_trace() {
    let cfg = ModelConfig::tiny();
    let e = InferenceEngine::new(PlatformConfig::with_dies(4));
    // 8 templates x 4 requests each, all offered at once (heavy load):
    // JSQ round-robins and splits every group across the dies (zero
    // sharing within any replica), while affinity keeps each group on
    // its template's home replica, where the admission probe and the
    // mid-prefill re-probe deduplicate the template.
    let w = Workload::uniform(32, 24, 6).with_shared_prefix(64, 4);
    let opts = BatcherConfig::new(4, 0);
    let jsq = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        4,
        RoutePolicy::JoinShortestQueue,
    );
    let aff = e.serve_replicated(
        &cfg,
        &w,
        opts,
        FpFormat::Fp32,
        4,
        RoutePolicy::PrefixAffinity,
    );
    assert_eq!(jsq.merged.completed, 32);
    assert_eq!(aff.merged.completed, 32);
    assert!(
        aff.merged.prefix_hit_rate > jsq.merged.prefix_hit_rate,
        "affinity routing must beat JSQ on hit rate: {} !> {}",
        aff.merged.prefix_hit_rate,
        jsq.merged.prefix_hit_rate
    );
    // Both serve the same tokens; conservation holds fleet-wide.
    assert_eq!(aff.merged.gen_tokens, jsq.merged.gen_tokens);
    assert_eq!(
        aff.merged.prefill_tokens + aff.merged.prefix_hit_tokens,
        w.total_prompt_tokens()
    );
}

#[test]
fn replica_kv_budgets_are_independent() {
    // Each replica prices against its own die's budget: a pool sized for
    // ~2 requests per replica still serves 4x that across the fleet
    // without the budget ever being exceeded on any die.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(2);
    let w = Workload::uniform(8, 16, 8);
    let one = w.requests[0].kv_bytes(&cfg);
    let opts = BatcherConfig::new(4, 2 * one);
    let fleet = serve_replicated(
        &cfg,
        &p,
        FpFormat::Fp32,
        opts,
        &w,
        2,
        RoutePolicy::JoinShortestQueue,
    );
    assert_eq!(fleet.merged.completed, 8);
    for r in &fleet.per_replica {
        assert!(r.peak_kv_bytes <= 2 * one, "per-die budget respected");
    }
    assert!(fleet.merged.peak_kv_bytes <= 4 * one, "fleet peak sums the dies");
}
