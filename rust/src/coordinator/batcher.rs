//! Continuous-batching serving scheduler: paged KV with prefix sharing,
//! chunked prefill, token-budget mixed iterations, priority-aware
//! admission.
//!
//! Admits [`Request`]s against a paged HBM KV budget, interleaves prefill
//! work (NAR) with ragged batched decode (AR), and prices the whole trace
//! on the cycle-level platform model through a memoized layer-pricing
//! cache. PR 2 built the paged/chunked/priority skeleton; this version
//! closes its tracked simplifications:
//!
//! * **Prefix caching with ref-counted page sharing**
//!   ([`super::kv_paging::PrefixCache`]) — prompt pages are content-hashed
//!   at page granularity; a request whose prompt prefix is already cached
//!   maps the cached pages (copy-on-write-guarded, billed to the budget
//!   once) and *skips the prefill passes for those tokens entirely*, so
//!   shared-system-prompt traffic ([`Workload::with_shared_prefix`]) sees
//!   both TTFT and tokens/s improve. Eviction is ref-count-aware LRU.
//!   `prefix_cache = false` (`--no-prefix-cache`) keeps the PR-2 code
//!   path: identical pricing and scheduling, except that the iteration's
//!   priority order is now computed once at iteration start (see
//!   [`Self::iteration_order`] for the one aging corner this refines).
//! * **Token-budget mixed iterations** (Sarathi-style) — with
//!   `token_budget > 0`, each iteration fills one budget with decode
//!   tokens first and prefill-chunk tokens after, priced as a *single
//!   fused pass* ([`crate::coordinator::schedule::model_total_mixed`])
//!   that streams the weights once,
//!   killing the prefill/decode pass-alternation overhead. A pass that
//!   completes a prompt's prefill also *emits the first token* (the last
//!   prompt position's output), cutting budget-mode TTFT by one
//!   iteration at zero extra cost (`fused_first_tokens`).
//!   `token_budget = 0` keeps the legacy one-chunk-per-resident
//!   alternation.
//! * **Mid-prefill prefix re-probing** — a resident request re-checks
//!   the prefix cache at chunk boundaries for pages registered *after*
//!   its admission and attaches every contiguously cached one instead of
//!   prefilling it (counter `prefix_late_hits`), so concurrent requests
//!   behind one template materialize it exactly once between them.
//! * **Memoized layer pricing** ([`LayerCostCache`]) — every pricing call
//!   goes through an interned signature -> `KernelCost` memo (platform-
//!   generation tagged), making long open-loop traces tractable; the memo
//!   is bit-transparent, so no number changes.
//! * **Paged KV** — fixed-size pages allocated on demand, freed at
//!   retirement; when the pool runs dry the scheduler first reclaims
//!   unreferenced cached prefix pages, then preempts the least urgent
//!   resident vLLM-recompute-style.
//! * **Chunked prefill** — prompts prefill in `prefill_chunk`-token NAR
//!   passes attending to the cached context; 0 = monolithic.
//! * **Priority + aging admission / open-loop arrivals / ragged decode
//!   pricing** — unchanged from PR 2; the per-iteration priority order is
//!   now computed once and shared by every stage of the iteration.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};

use crate::arch::{FpFormat, PlatformConfig, PrecisionPolicy};
use crate::coordinator::breakdown::KindCycles;
use crate::coordinator::faults::{FaultKind, ReplicaFaults, SalvagedRequest};
use crate::coordinator::kv_paging::{
    KvExport, KvGeometry, PagedKvAllocator, PageTable, PrefixCache,
};
use crate::coordinator::schedule::LayerCostCache;
use crate::coordinator::workload::{ClassLadder, Request, Workload};
use crate::energy;
use crate::metrics::sketch::StreamSketch;
use crate::model::ModelConfig;
use crate::parallel::collectives::degrade_link;
use crate::parallel::shard::{plan_pass_cost_policy, ShardPlan};
use crate::sim::KernelCost;
use crate::trace::{PassPhase, TraceRecorder, TraceSettings};

/// Which serving core prices the trace. Both produce bit-identical
/// schedules and reports (`ServeReport::same_outcome`, asserted by the
/// equivalence suite); they differ only in how much work the run loop
/// performs per scheduling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// Event-heap core with pass-shape memoized pricing (default): idle
    /// wall-clock between arrivals costs zero work, and repeated pass
    /// shapes skip layer assembly and platform fingerprinting entirely.
    Event,
    /// Per-iteration scanning loop (PR 2-5 behavior), kept as the oracle
    /// the event core is asserted against and for `serve --engine iter`.
    Iteration,
}

impl EngineMode {
    /// Parse `event` or `iter` (the `serve --engine` flag).
    pub fn parse(s: &str) -> Option<EngineMode> {
        match s {
            "event" => Some(EngineMode::Event),
            "iter" => Some(EngineMode::Iteration),
            _ => None,
        }
    }

    /// Stable label reported as `ServeReport::engine`.
    pub const fn name(self) -> &'static str {
        match self {
            EngineMode::Event => "event",
            EngineMode::Iteration => "iter",
        }
    }
}

/// Scheduling policy knobs for the serving loop.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Maximum concurrently resident requests (batch slots).
    pub max_batch: usize,
    /// HBM bytes available for KV caches (platform capacity minus
    /// resident weights).
    pub kv_budget_bytes: u64,
    /// KV page size in tokens (paged-allocator granularity).
    pub page_tokens: u64,
    /// Prefill chunk in tokens; 0 = monolithic prefill (whole prompt in
    /// one NAR pass, the PR-1 behavior). With a token budget this is a
    /// per-request cap on the tokens one iteration may prefill.
    pub prefill_chunk: u64,
    /// Reserve pages for the full prompt + generation at admission
    /// (legacy full-length reservation semantics, page-granular). Used as
    /// the baseline the paged mode is measured against; disables prefix
    /// caching to keep the baseline pure.
    pub reserve_full: bool,
    /// Seconds of queue wait that promote a request one priority class
    /// (anti-starvation aging); 0 disables aging. The default (5 s) is
    /// sized to the simulated platform's serving timescale, where a
    /// single GPT-class prefill takes seconds — small enough to prevent
    /// starvation, large enough that classes actually separate.
    pub aging_promote_s: f64,
    /// Content-addressed prefix caching over the page pool: requests
    /// whose prompts share a cached prefix map the cached pages and skip
    /// those prefill tokens. `false` restores PR-2 behavior bit-for-bit.
    pub prefix_cache: bool,
    /// Per-iteration token budget shared between prefill chunks and
    /// decode tokens, priced as one fused mixed pass (Sarathi-style);
    /// 0 = legacy prefill/decode pass alternation.
    pub token_budget: u64,
    /// Shard plan ONE engine executes: with `tp > 1` every pass prices
    /// through the TP-rank-local layers plus the per-block all-reduces,
    /// with `pp > 1` each pass crosses the pipeline stages and their
    /// activation sends, and a zero `kv_budget_bytes` resolves to
    /// [`ShardPlan::replica_kv_budget_bytes`]. The `replicas` field is
    /// ignored here — data parallelism is the router's job
    /// ([`crate::parallel::router`]). The default single plan is
    /// bit-identical to the unsharded engine.
    pub plan: ShardPlan,
    /// Serving core (see [`EngineMode`]); reports are bit-identical
    /// either way, so this is purely a simulator-performance knob.
    pub engine: EngineMode,
    /// Emit the full [`ServeReport::per_request`] detail vector. `false`
    /// (`serve --no-per-request`) drops it after the aggregates are
    /// computed — million-request fleet traces then cost O(1) report
    /// memory instead of O(trace). Every aggregate, sketch, and counter
    /// is unchanged either way.
    pub per_request: bool,
    /// KV-cache storage format; `None` keeps KV at the serving (compute)
    /// precision, which is bit-identical to the pre-policy behavior. A
    /// narrower format (e.g. FP8 KV under FP16 compute) shrinks every
    /// page, budget, export, and migration proportionally and bills a
    /// per-block dequant-on-read kernel ([`LayerKind::KvDequant`]).
    ///
    /// [`LayerKind::KvDequant`]: crate::model::LayerKind::KvDequant
    pub kv_format: Option<FpFormat>,
    /// Per-priority-class compute-precision ladder: requests are priced
    /// at their class' rung instead of the engine-wide format. The rung
    /// is chosen from the request's *static* arrival class (aging
    /// promotes scheduling priority, not precision). Trivial (empty)
    /// ladder = every class at the engine format, bit-identical.
    pub class_precision: ClassLadder,
}

impl BatcherConfig {
    /// Paged, non-chunked, single-class, prefix-cached defaults at the
    /// given budget. `kv_budget_bytes = 0` means "the platform's KV
    /// budget" (HBM capacity minus resident weights);
    /// [`ContinuousBatcher::new`] resolves it.
    pub fn new(max_batch: usize, kv_budget_bytes: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            kv_budget_bytes,
            page_tokens: 16,
            prefill_chunk: 0,
            reserve_full: false,
            aging_promote_s: 5.0,
            prefix_cache: true,
            token_budget: 0,
            plan: ShardPlan::single(),
            engine: EngineMode::Event,
            per_request: true,
            kv_format: None,
            class_precision: ClassLadder::default(),
        }
    }

    /// The [`PrecisionPolicy`] these options imply for an engine serving
    /// at `fmt`: weights and compute at `fmt`, KV at [`Self::kv_format`]
    /// (defaulting to `fmt`). The router uses this to size disagg
    /// migration manifests with the same KV geometry the engines use.
    pub fn policy_for(&self, fmt: FpFormat) -> PrecisionPolicy {
        PrecisionPolicy { weights: fmt, compute: fmt, kv: self.kv_format.unwrap_or(fmt) }
    }
}

/// Per-request serving outcome. Latency-like fields are relative to the
/// request's arrival (for t=0 closed-loop traces they coincide with
/// absolute trace time, PR 1's convention).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestStats {
    /// Request id (stable across engines and replicas).
    pub id: usize,
    /// Static priority class the request arrived with.
    pub class: u8,
    /// Prompt tokens materialized before decode.
    pub prompt_len: u64,
    /// Tokens the request generated.
    pub gen_tokens: u64,
    /// Absolute arrival time, seconds.
    pub arrival_s: f64,
    /// Arrival -> first admission (queue wait), seconds.
    pub admitted_s: f64,
    /// Arrival -> first generated token, seconds.
    pub ttft_s: f64,
    /// Arrival -> last generated token, seconds.
    pub latency_s: f64,
    /// Times this request was preempted (pages reclaimed, recompute).
    pub preemptions: u32,
    /// Prompt tokens served from the prefix cache (prefill skipped).
    pub prefix_hit_tokens: u64,
    /// Times this request was salvaged off a failed replica and re-routed
    /// (filled in by the fleet router; always 0 on a single-engine run).
    pub retries: u32,
    /// Cycles failure recovery inserted before this request could restart
    /// on a survivor: the wait on the failed replica plus the KV
    /// re-export transfer. Latency-like fields restart at the re-arrival,
    /// so this carries the gap (0 without faults).
    pub recovery_cycles: u64,
}

/// Latency percentiles of one priority class.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassStats {
    /// Priority class these aggregates cover.
    pub class: u8,
    /// Requests of this class completed.
    pub completed: usize,
    /// Median time-to-first-token, seconds.
    pub ttft_p50_s: f64,
    /// 99th-percentile time-to-first-token, seconds.
    pub ttft_p99_s: f64,
    /// Median end-to-end latency, seconds.
    pub latency_p50_s: f64,
    /// 99th-percentile end-to-end latency, seconds.
    pub latency_p99_s: f64,
    /// Streaming sample sketch behind the TTFT percentiles; the replica
    /// router merges these instead of re-walking the union of
    /// per-request stats.
    pub ttft: StreamSketch,
    /// Streaming sample sketch behind the latency percentiles.
    pub latency: StreamSketch,
}

/// Everything the serving run reports.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Model name served.
    pub model: String,
    /// Serving precision name (`"fp32"`, `"fp8"`, ...).
    pub format: &'static str,
    /// KV-cache storage format name; equals [`Self::format`] unless the
    /// run decoupled KV precision (`--kv-format`).
    pub kv_format: &'static str,
    /// Canonical class-precision ladder spec the run served under
    /// (`"hi:fp16,lo:fp8"`-style; empty = trivial ladder). Reports served
    /// under different ladders or KV formats must not be merged.
    pub class_precision: String,
    /// Requests offered to the scheduler.
    pub requests: usize,
    /// Requests served to completion.
    pub completed: usize,
    /// Ids rejected because a single KV cache can never fit the page
    /// pool (plus, as a release-build diagnostic only, a job abandoned
    /// by the unreachable lone-resident stall guard).
    pub rejected: Vec<usize>,
    /// Batch-slot cap the run was configured with.
    pub max_batch: usize,
    /// HBM bytes the KV page pool was carved from.
    pub kv_budget_bytes: u64,
    /// Paged-allocator geometry: tokens per page.
    pub page_tokens: u64,
    /// Pages in the pool (`kv_budget_bytes / page_bytes`).
    pub total_pages: u64,
    /// High-water mark of mapped KV bytes (must stay <= budget; shared
    /// prefix pages count once, cached-but-idle pages count until
    /// evicted).
    pub peak_kv_bytes: u64,
    /// Wall-clock of the whole trace, cycles.
    pub total_cycles: u64,
    /// Wall-clock of the whole trace, seconds.
    pub total_seconds: f64,
    /// Prompt tokens prefilled, including recompute after preemption and
    /// excluding prefix-cache hits.
    pub prefill_tokens: u64,
    /// Prefill NAR passes issued (chunks).
    pub prefill_chunks: u64,
    /// Tokens generated across completed requests.
    pub gen_tokens: u64,
    /// Preemptions (a resident request evicted for pages).
    pub preemptions: u64,
    /// Mean time-to-first-token, seconds (generating requests only).
    pub ttft_mean_s: f64,
    /// Median time-to-first-token, seconds.
    pub ttft_p50_s: f64,
    /// 99th-percentile time-to-first-token, seconds.
    pub ttft_p99_s: f64,
    /// Mean end-to-end request latency, seconds.
    pub latency_mean_s: f64,
    /// Median end-to-end request latency, seconds.
    pub latency_p50_s: f64,
    /// 99th-percentile end-to-end request latency, seconds.
    pub latency_p99_s: f64,
    /// Mean time-per-output-token, seconds: per-request decode pace
    /// `(latency - ttft) / (gen_tokens - 1)` over requests generating at
    /// least two tokens — the SLO decode-side percentiles, split from
    /// TTFT exactly as disaggregated serving splits the phases.
    pub tpot_mean_s: f64,
    /// Median time-per-output-token, seconds.
    pub tpot_p50_s: f64,
    /// 99th-percentile time-per-output-token, seconds.
    pub tpot_p99_s: f64,
    /// Mean admission delay (arrival -> first admission), seconds.
    pub queue_mean_s: f64,
    /// 99th-percentile admission delay, seconds.
    pub queue_p99_s: f64,
    /// Aggregate generated tokens / total wall-clock.
    pub tokens_per_s: f64,
    /// Generated tokens / decode wall-clock. In token-budget mode decode
    /// shares its passes with prefill chunks, so the denominator covers
    /// every pass that advanced at least one decode token.
    pub decode_tokens_per_s: f64,
    /// Decode tokens advanced (raw counter behind `decode_tokens_per_s`
    /// and `avg_batch_occupancy`; the replica router merges these).
    pub decode_tokens: u64,
    /// Cycles spent in decode-carrying passes.
    pub decode_cycles: u64,
    /// Decode-carrying passes run.
    pub decode_steps: u64,
    /// Mean decode batch occupancy (decode tokens per decode-carrying
    /// pass).
    pub avg_batch_occupancy: f64,
    /// Mean FPU utilization over every priced pass.
    pub fpu_utilization: f64,
    /// Mean power draw over the trace, watts.
    pub power_w: f64,
    /// HBM traffic the trace moved, gigabytes.
    pub hbm_gb: f64,
    /// Whether prefix caching was active for this run.
    pub prefix_cache: bool,
    /// Prompt tokens served from the prefix cache instead of prefill.
    pub prefix_hit_tokens: u64,
    /// prefix_hit_tokens / (prefix_hit_tokens + prefill_tokens): the
    /// fraction of required prompt work the cache absorbed.
    pub prefix_hit_rate: f64,
    /// Prompt tokens attached from the prefix cache *after* admission —
    /// a resident request re-probing at chunk boundaries for pages
    /// registered since (subset of `prefix_hit_tokens`).
    pub prefix_late_hits: u64,
    /// Per-iteration token budget (0 = legacy alternation).
    pub token_budget: u64,
    /// Mean fraction of the token budget filled per mixed iteration
    /// (0 when the budget mode is off).
    pub budget_utilization: f64,
    /// First tokens emitted by the same fused pass that completed a
    /// prompt's prefill (token-budget mode): the last prompt position's
    /// output IS the first generated token, so no extra pass — or budget
    /// token — is spent, and TTFT drops by one iteration.
    pub fused_first_tokens: u64,
    /// Fraction of layer-pricing lookups served by the memo.
    pub pricing_cache_hit_rate: f64,
    /// Layer-pricing memo hits (the router recomputes the fleet rate
    /// from these raw counters, never from the rates).
    pub pricing_cache_hits: u64,
    /// Layer-pricing memo misses.
    pub pricing_cache_misses: u64,
    /// Budget tokens claimed in token-budget mode (raw counter behind
    /// `budget_utilization`).
    pub budget_tokens: u64,
    /// Budgeted mixed iterations run in token-budget mode.
    pub budget_iterations: u64,
    /// Requests admitted with pre-migrated KV (disaggregated serving:
    /// the prompt's pages were prefilled on another die and imported
    /// here, so the request entered decode with zero prefill passes).
    pub kv_imports: u64,
    /// Prompt tokens those imports materialized without prefill.
    pub imported_kv_tokens: u64,
    /// Tensor-parallel degree of the shard plan this engine executed
    /// (`tp = pp = 1` is the single-die engine, whose report is
    /// bit-identical to before shard plans existed).
    pub tp: u32,
    /// Pipeline-parallel degree of the executed shard plan.
    pub pp: u32,
    /// Cycles inside TP all-reduces and PP activation sends across the
    /// whole trace (0 on the single-die engine) — the communication share
    /// of `total_cycles`.
    pub collective_cycles: u64,
    /// Bytes the trace moved over the die-to-die links.
    pub d2d_bytes: u64,
    /// Compute cycles of prefill-only passes split by kernel class
    /// (canonical [`crate::coordinator::breakdown::KIND_ORDER`] order;
    /// collective cycles excluded, so across the three phase splits
    /// `total() + collective_cycles == ` the cycles of every priced
    /// pass). Deterministic, hence covered by [`Self::same_outcome`].
    pub prefill_kind_cycles: KindCycles,
    /// Compute cycles of decode-only passes split by kernel class.
    pub decode_kind_cycles: KindCycles,
    /// Compute cycles of fused mixed passes (token-budget mode) split by
    /// kernel class.
    pub mixed_kind_cycles: KindCycles,
    /// Aggregate kernel resources of every priced pass. Rate-like report
    /// fields (FPU utilization, power) derive from this, and the router
    /// merges it to recompute fleet rates from raw counters.
    pub work: KernelCost,
    /// Serving core that produced this report (`"event"` / `"iter"`).
    pub engine: &'static str,
    /// Arrival events fired (admissible requests entering the ready
    /// queue); identical across engines by construction.
    pub arrival_events: u64,
    /// Priced passes completed (prefill chunks, decode steps, and fused
    /// mixed iterations all count once); identical across engines.
    pub pass_events: u64,
    /// Pass-shape memo hits (event core only; 0 on the iteration core,
    /// which prices every pass through the layer memo).
    pub pass_cache_hits: u64,
    /// Pass-shape memo misses (event core only).
    pub pass_cache_misses: u64,
    /// Permanent replica failures this report covers (0 or 1 for one
    /// engine; the fleet merge sums them).
    pub replica_failures: u64,
    /// Cycles the engine(s) spent frozen in injected stalls.
    pub stall_cycles: u64,
    /// Link-degradation fault events applied while serving.
    pub link_faults: u64,
    /// Requests salvaged off failed replicas (re-routed by the fleet
    /// router; rejected when no survivor exists to adopt them).
    pub salvaged_requests: u64,
    /// KV bytes re-exported over the d2d links for salvaged requests
    /// whose pool survived the failure.
    pub salvaged_kv_bytes: u64,
    /// Re-route retries across the fleet (per-request `retries` summed;
    /// the router fills this in, single engines report 0).
    pub retries: u64,
    /// Cycles failure recovery inserted across all salvaged requests
    /// (per-request `recovery_cycles` summed; router-filled).
    pub recovery_cycles: u64,
    /// Fraction of nominal serving capacity lost to faults: stall time
    /// plus post-failure dead time over replicas x fleet wall-clock.
    /// Exactly 0.0 on a fault-free run.
    pub degraded_capacity_fraction: f64,
    /// Human-readable warnings (e.g. `--disagg auto` falling back to the
    /// symmetric fleet). Empty on clean runs.
    pub warnings: Vec<String>,
    /// Streaming sketch behind the TTFT percentile scalars: exact below
    /// [`crate::metrics::sketch::EXACT_LIMIT`] samples, ~1% relative
    /// error above, mergeable across replicas.
    pub ttft_sketch: StreamSketch,
    /// Streaming sketch behind the latency percentiles.
    pub latency_sketch: StreamSketch,
    /// Streaming sketch behind the time-per-output-token percentiles.
    pub tpot_sketch: StreamSketch,
    /// Streaming sketch behind the queue-wait percentiles.
    pub queue_sketch: StreamSketch,
    /// Per-priority-class percentiles (one entry per class present).
    pub per_class: Vec<ClassStats>,
    /// Per-request detail, sorted by id. Empty when
    /// [`BatcherConfig::per_request`] is off (the aggregates above are
    /// computed first and are unchanged).
    pub per_request: Vec<RequestStats>,
}

impl ServeReport {
    /// Whether two reports describe the same served schedule bit-for-bit
    /// — counters, work, per-request stats, percentiles — ignoring only
    /// the engine-identity fields (`engine`, pass-memo counters) that
    /// legitimately differ between the event-driven and iteration cores.
    pub fn same_outcome(&self, other: &ServeReport) -> bool {
        let mut a = self.clone();
        a.engine = other.engine;
        a.pass_cache_hits = other.pass_cache_hits;
        a.pass_cache_misses = other.pass_cache_misses;
        a == *other
    }
}

/// TTFT / latency / TPOT / queue-wait percentile sets plus the per-class
/// breakdown over a set of per-request outcomes. TTFT is defined over
/// generated tokens: prefill-only requests (`gen_tokens == 0`) never
/// produce one, so they are excluded from the TTFT aggregates (their
/// per-request `ttft_s` equals prefill completion). TPOT — the decode
/// pace `(latency - ttft) / (gen_tokens - 1)` — needs at least two
/// generated tokens to be defined. Shared by the single-engine
/// [`ContinuousBatcher`] report and the replica router's merged fleet
/// view, so the two can never drift apart.
pub(crate) fn latency_aggregates(
    done: &[RequestStats],
) -> (StreamSketch, StreamSketch, StreamSketch, StreamSketch, Vec<ClassStats>) {
    let mut agg = LatencyAgg::default();
    for r in done {
        agg.push(r);
    }
    agg.finish()
}

/// Incremental form of [`latency_aggregates`]: one `push` per completed
/// request, `finish` yields the four fleet sketches plus the per-class
/// breakdown. The materializing report path and the `--no-per-request`
/// streaming path both feed this in retirement order, which is what
/// keeps their aggregates bit-identical (exact-mode sketches compare by
/// their sample vectors, so push *order* matters even though every
/// percentile/mean query is order-independent).
#[derive(Default)]
pub(crate) struct LatencyAgg {
    ttft: StreamSketch,
    lat: StreamSketch,
    tpot: StreamSketch,
    queue: StreamSketch,
    /// Per-class (ttft, latency) sketches, keyed — and later emitted —
    /// in class order, samples in encounter order.
    classes: BTreeMap<u8, (StreamSketch, StreamSketch)>,
}

impl LatencyAgg {
    pub(crate) fn push(&mut self, r: &RequestStats) {
        if r.gen_tokens > 0 {
            self.ttft.push(r.ttft_s);
        }
        if r.gen_tokens > 1 {
            self.tpot.push((r.latency_s - r.ttft_s) / (r.gen_tokens - 1) as f64);
        }
        self.lat.push(r.latency_s);
        self.queue.push(r.admitted_s);
        let (t, l) = self.classes.entry(r.class).or_default();
        if r.gen_tokens > 0 {
            t.push(r.ttft_s);
        }
        l.push(r.latency_s);
    }

    pub(crate) fn finish(
        self,
    ) -> (StreamSketch, StreamSketch, StreamSketch, StreamSketch, Vec<ClassStats>) {
        let per_class = self
            .classes
            .into_iter()
            .map(|(class, (t, l))| ClassStats {
                class,
                completed: l.count() as usize,
                ttft_p50_s: t.p(50.0),
                ttft_p99_s: t.p(99.0),
                latency_p50_s: l.p(50.0),
                latency_p99_s: l.p(99.0),
                ttft: t,
                latency: l,
            })
            .collect();
        (self.ttft, self.lat, self.tpot, self.queue, per_class)
    }
}

/// A request's scheduler-side state that survives preemption.
#[derive(Debug, Clone)]
struct Job {
    req: Request,
    arrival_cycle: u64,
    /// Tokens that must be materialized before (more) decode: the prompt,
    /// plus already-produced tokens after a recompute preemption.
    prefill_target: u64,
    /// Tokens generated so far (credited once; never re-generated).
    produced: u64,
    preemptions: u32,
    /// Prompt tokens served from the prefix cache across the job's life.
    prefix_hit_tokens: u64,
    first_admitted_cycle: Option<u64>,
    ttft_cycle: Option<u64>,
}

/// A resident request (holds pages).
struct ActiveJob {
    job: Job,
    /// Tokens materialized toward `prefill_target` (prefix hits included).
    prefill_done: u64,
    /// Tokens currently materialized in KV.
    kv_len: u64,
    table: PageTable,
    /// Content hashes of the prompt's full pages (empty when prefix
    /// caching is off).
    page_hashes: Vec<u64>,
    /// Leading prompt pages already registered in (or attached from) the
    /// prefix cache.
    registered: u64,
}

impl ActiveJob {
    fn prefilling(&self) -> bool {
        self.prefill_done < self.job.prefill_target
    }

    fn decodable(&self) -> bool {
        self.prefill_done >= self.job.prefill_target
            && self.job.produced < self.job.req.gen_tokens
    }
}

/// Prices a serving trace over one model/platform/precision.
///
/// ```
/// use snitch_fm::arch::{FpFormat, PlatformConfig};
/// use snitch_fm::coordinator::{BatcherConfig, ContinuousBatcher, Workload};
/// use snitch_fm::model::ModelConfig;
///
/// let cfg = ModelConfig::tiny();
/// let platform = PlatformConfig::occamy();
/// let batcher = ContinuousBatcher::new(
///     &cfg,
///     &platform,
///     FpFormat::Fp32,
///     BatcherConfig::new(4, 0), // 4 slots, platform KV budget
/// );
/// let report = batcher.run(&Workload::uniform(6, 16, 8));
/// assert_eq!(report.completed, 6);
/// assert!(report.tokens_per_s > 0.0);
/// ```
pub struct ContinuousBatcher<'a> {
    /// Model being served.
    pub cfg: &'a ModelConfig,
    /// Platform pricing every pass.
    pub platform: &'a PlatformConfig,
    /// Serving precision.
    pub fmt: FpFormat,
    /// Resolved precision policy: weights/compute at [`Self::fmt`], KV at
    /// [`BatcherConfig::kv_format`] (defaulting to `fmt`). Validated
    /// against the format lattice by [`Self::new`], along with every
    /// class-precision rung.
    pub policy: PrecisionPolicy,
    /// Scheduling policy (budget resolved by [`Self::new`]).
    pub opts: BatcherConfig,
    /// Injected faults this engine will observe, in cycle order (empty =
    /// fault-free, bit-identical serving). Set via [`Self::with_faults`];
    /// the fleet router derives one view per replica from the
    /// [`crate::coordinator::faults::FaultPlan`].
    pub faults: ReplicaFaults,
}

/// Shape of one priced pass: prefill (tokens, kv-context) pairs plus the
/// ragged decode kv lengths, in scheduler order, and the (compute, kv)
/// precision pair the pass was priced at. Two passes with equal keys
/// price identically (the layer list is a pure function of the shape and
/// the precision pair, and the platform never changes mid-run), which is
/// what makes the pass memo bit-transparent. The precision fields keep
/// ladder rungs from colliding: the same ragged shape priced at FP16 and
/// FP8 occupies two distinct memo slots.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PassKey {
    prefills: Vec<(u64, u64)>,
    decode_kv: Vec<u64>,
    compute: FpFormat,
    kv: FpFormat,
}

impl Default for PassKey {
    fn default() -> PassKey {
        // The format fields are overwritten before every memo probe; any
        // placeholder works (FpFormat deliberately has no Default).
        PassKey {
            prefills: Vec::new(),
            decode_kv: Vec::new(),
            compute: FpFormat::Fp32,
            kv: FpFormat::Fp32,
        }
    }
}

/// Memoized outcome of a pass shape, plus how many layer-memo lookups
/// pricing it performed. On a hit those lookups are replayed as credits
/// into [`LayerCostCache::add_hits`] so `pricing_cache_hits/misses` stay
/// identical to the uncached path (every replayed lookup would have been
/// a guaranteed hit).
struct PassCost {
    total: KernelCost,
    collective_cycles: u64,
    /// Compute-cycle split by kernel class (memoized with the total so a
    /// hit replays the same per-phase breakdown the fresh pricing made).
    kind_cycles: KindCycles,
    lookups: u64,
}

/// Pass-shape -> priced-cost memo (event core only). Long traces repeat
/// a small set of shapes (every decode step of a given ragged batch,
/// every like-sized prefill chunk), so after warmup the per-pass cost
/// drops from layer assembly + platform fingerprint + ~10 layer-memo
/// probes to one hash lookup against the reused `key` scratch.
#[derive(Default)]
struct PassMemo {
    map: HashMap<PassKey, PassCost>,
    /// Reused lookup key: the hit path allocates nothing.
    key: PassKey,
    hits: u64,
    misses: u64,
}

/// Discrete events the event core schedules through its heap. Arrivals
/// carry the job; the other kinds are completion markers the iteration
/// body records when it applies the corresponding state change, so the
/// whole schedule flows through — and is ordered by — the one heap.
#[derive(Debug)]
enum EventKind {
    Arrival(Job),
    PassComplete,
    Retire,
    Preemption,
    /// An injected fault was applied at this cycle (stall, link
    /// degradation, or replica failure). Like the other markers the state
    /// change already happened when the fault fired from the plan's
    /// cursor; the event keeps the fault visible in the heap's ordered
    /// record of the schedule.
    Fault,
}

#[derive(Debug)]
struct Event {
    cycle: u64,
    /// Push order; ties on `cycle` fire in insertion order, making the
    /// pop sequence fully deterministic.
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.cycle == other.cycle && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    /// Reversed, so `BinaryHeap` (a max-heap) pops the earliest event
    /// first.
    fn cmp(&self, other: &Event) -> Ordering {
        (other.cycle, other.seq).cmp(&(self.cycle, self.seq))
    }
}

/// Where the event core's arrivals come from.
enum ArrivalSource<'w> {
    /// Materialized workload, pre-sorted by (arrival_cycle, id).
    Queue(VecDeque<Job>),
    /// Lazy seeded generator in non-decreasing arrival order
    /// ([`Workload::stream_poisson`] and friends): million-request traces
    /// cost O(resident set) memory, not O(trace).
    Stream(Box<dyn Iterator<Item = Request> + 'w>),
}

/// The event core's heap plus its lazy arrival source. Invariants:
/// at most one arrival event is resident at a time (the source is pulled
/// as each one fires); completion markers are pushed at the advancing
/// clock, so pops are non-decreasing in `cycle` (debug-asserted); ties
/// fire in push order via `seq`.
struct EventQueue<'w> {
    heap: BinaryHeap<Event>,
    source: ArrivalSource<'w>,
    seq: u64,
    last_fired: u64,
    /// Requests pulled from a streamed source (rejected or queued); the
    /// materialized path counts offered requests upfront instead.
    offered: usize,
}

impl<'w> EventQueue<'w> {
    fn new(
        source: ArrivalSource<'w>,
        b: &ContinuousBatcher,
        st: &mut RunState,
    ) -> EventQueue<'w> {
        let mut q = EventQueue {
            heap: BinaryHeap::new(),
            source,
            seq: 0,
            last_fired: 0,
            offered: 0,
        };
        q.pull_arrival(b, st);
        q
    }

    fn push(&mut self, cycle: u64, kind: EventKind) {
        self.heap.push(Event { cycle, seq: self.seq, kind });
        self.seq += 1;
    }

    /// Move the source's next admissible job into the heap. Streamed
    /// requests that can never fit the page pool are rejected here,
    /// exactly like the legacy loop's upfront scan.
    fn pull_arrival(&mut self, b: &ContinuousBatcher, st: &mut RunState) {
        match &mut self.source {
            ArrivalSource::Queue(jobs) => {
                if let Some(j) = jobs.pop_front() {
                    self.push(j.arrival_cycle, EventKind::Arrival(j));
                }
            }
            ArrivalSource::Stream(it) => {
                for r in it.by_ref() {
                    self.offered += 1;
                    if !st.alloc.fits_pool(r.kv_capacity()) {
                        if let Some(rec) = st.trace.as_mut() {
                            rec.request_rejected(r.id, st.time);
                        }
                        st.rejected.push(r.id);
                        continue;
                    }
                    let j = b.job_of(r);
                    debug_assert!(
                        j.arrival_cycle >= self.last_fired,
                        "streamed arrivals must be in non-decreasing time order"
                    );
                    self.push(j.arrival_cycle, EventKind::Arrival(j));
                    break;
                }
            }
        }
    }

    /// Fire every event due at the current clock: arrivals enqueue their
    /// job (and pull the next one from the source); completion markers
    /// are popped and checked against the monotone-pop invariant — their
    /// state change already happened synchronously when the iteration
    /// body recorded them.
    fn fire_due(&mut self, b: &ContinuousBatcher, st: &mut RunState) {
        while self.heap.peek().is_some_and(|e| e.cycle <= st.time) {
            let e = self.heap.pop().unwrap();
            debug_assert!(
                e.cycle >= self.last_fired,
                "event heap must pop in non-decreasing cycle order"
            );
            self.last_fired = e.cycle;
            match e.kind {
                EventKind::Arrival(job) => {
                    st.ready.push(job);
                    st.c.arrival_events += 1;
                    self.pull_arrival(b, st);
                }
                EventKind::PassComplete
                | EventKind::Retire
                | EventKind::Preemption
                | EventKind::Fault => {}
            }
        }
    }

    /// Cycle of the next scheduled arrival, if any. After `fire_due`
    /// every remaining event is a strictly-future arrival (markers always
    /// fire on the turn after they are pushed).
    fn next_arrival_cycle(&self) -> Option<u64> {
        let e = self.heap.peek()?;
        debug_assert!(matches!(e.kind, EventKind::Arrival(_)));
        Some(e.cycle)
    }

    /// Failure teardown: drain every not-yet-fired arrival — the resident
    /// heap event plus the rest of the source — into jobs, applying the
    /// same admission-feasibility rejection the live path would have.
    /// Sorted like `materialized_jobs`, so the salvage hand-off is
    /// deterministic for either source kind.
    fn drain_pending(&mut self, b: &ContinuousBatcher, st: &mut RunState) -> Vec<Job> {
        let mut jobs: Vec<Job> = std::mem::take(&mut self.heap)
            .into_vec()
            .into_iter()
            .filter_map(|e| match e.kind {
                EventKind::Arrival(j) => Some(j),
                _ => None,
            })
            .collect();
        match &mut self.source {
            ArrivalSource::Queue(rest) => jobs.extend(rest.drain(..)),
            ArrivalSource::Stream(it) => {
                for r in it.by_ref() {
                    self.offered += 1;
                    if !st.alloc.fits_pool(r.kv_capacity()) {
                        if let Some(rec) = st.trace.as_mut() {
                            rec.request_rejected(r.id, st.time);
                        }
                        st.rejected.push(r.id);
                        continue;
                    }
                    jobs.push(b.job_of(r));
                }
            }
        }
        jobs.sort_by_key(|j| (j.arrival_cycle, j.req.id));
        jobs
    }
}

/// Counters threaded through one run.
#[derive(Default)]
struct RunCounters {
    total: KernelCost,
    decode_cycles: u64,
    decode_tokens: u64,
    decode_steps: u64,
    prefill_tokens: u64,
    prefill_chunks: u64,
    preemptions: u64,
    prefix_hit_tokens: u64,
    /// Prompt tokens attached by mid-prefill re-probes (also counted in
    /// `prefix_hit_tokens`).
    prefix_late_hits: u64,
    /// Cycles inside TP all-reduces / PP sends (sharded plans only).
    collective_cycles: u64,
    /// Compute cycles of prefill-only passes split by kernel class.
    prefill_kind_cycles: KindCycles,
    /// Compute cycles of decode-only passes split by kernel class.
    decode_kind_cycles: KindCycles,
    /// Compute cycles of fused mixed passes split by kernel class.
    mixed_kind_cycles: KindCycles,
    /// Requests admitted with pre-migrated KV / prompt tokens those
    /// imports materialized without prefill (disaggregated decode dies).
    kv_imports: u64,
    imported_kv_tokens: u64,
    /// First tokens emitted from prefill-completing fused passes.
    fused_first_tokens: u64,
    /// Tokens claimed / iterations run in token-budget mode.
    budget_tokens: u64,
    budget_iterations: u64,
    /// Arrival events fired (jobs entering the ready queue from the
    /// arrival source; preemption re-queues do not count).
    arrival_events: u64,
    /// Priced passes completed.
    pass_events: u64,
    /// Cycles this engine spent frozen in injected stalls.
    stall_cycles: u64,
    /// Link-degradation fault events applied.
    link_faults: u64,
    /// Permanent failures this engine suffered (0 or 1).
    replica_failures: u64,
    /// Requests salvaged at the failure teardown.
    salvaged_requests: u64,
    /// KV bytes re-exportable from the surviving pool at teardown.
    salvaged_kv_bytes: u64,
}

/// Where retired requests go. With `per_request` on, the full
/// [`RequestStats`] vec is kept (Keep). Under `--no-per-request` the
/// stats are folded straight into the aggregate sketches at retirement
/// (Fold) and the vec is never materialized — the carried ROADMAP item —
/// so a million-request trace costs O(1) report memory inside the run
/// loop, not just at report time. Both variants feed [`LatencyAgg`] in
/// retirement order, which keeps their sketches bit-identical.
enum DoneLog {
    /// Materialize per-request stats (sorted by id at report time).
    Keep(Vec<RequestStats>),
    /// Stream every retirement into the aggregates; keep only scalars.
    Fold {
        agg: LatencyAgg,
        completed: usize,
        gen_tokens: u64,
        retries: u64,
        recovery_cycles: u64,
    },
}

impl DoneLog {
    fn push(&mut self, r: RequestStats) {
        match self {
            DoneLog::Keep(v) => v.push(r),
            DoneLog::Fold { agg, completed, gen_tokens, retries, recovery_cycles } => {
                agg.push(&r);
                *completed += 1;
                *gen_tokens += r.gen_tokens;
                *retries += r.retries as u64;
                *recovery_cycles += r.recovery_cycles;
            }
        }
    }

    /// Requests retired so far (the event core counts Retire markers off
    /// this, so it must work for both variants).
    fn completed(&self) -> usize {
        match self {
            DoneLog::Keep(v) => v.len(),
            DoneLog::Fold { completed, .. } => *completed,
        }
    }
}

/// Mutable state of one serving run, threaded through the per-iteration
/// stages (the fields are split-borrowed, so stages can touch tables,
/// the allocator and the prefix cache at once).
struct RunState {
    ready: Vec<Job>,
    active: Vec<ActiveJob>,
    done: DoneLog,
    rejected: Vec<usize>,
    alloc: PagedKvAllocator,
    cache: PrefixCache,
    costs: LayerCostCache,
    c: RunCounters,
    time: u64,
    /// Pass-shape memo (event core only; `None` keeps the iteration core
    /// pricing every pass through the layer memo, bit-identically).
    pass_memo: Option<PassMemo>,
    /// Cursor into the engine's sorted fault stream: events before it
    /// already fired. Both cores advance it at the same decision points,
    /// so injected faults land on identical schedule boundaries.
    fault_cursor: usize,
    /// Degraded-link pricing platform, swapped in by a `link@` fault
    /// (`None` = nominal; pricing then uses the borrowed platform
    /// reference untouched, keeping fault-free runs bit-identical).
    degraded: Option<PlatformConfig>,
    /// Set when a permanent `fail@`/`die@` fault fired; carries whether
    /// the KV pool survived (salvaged requests can re-export their pages)
    /// and stops the run loop at the next decision point.
    failed: Option<bool>,
    /// Requests torn off this engine by a permanent failure, for the
    /// fleet router to re-route (empty without faults).
    salvaged: Vec<SalvagedRequest>,
    /// Cycle-level trace recorder (`serve --trace`). `None` — the
    /// default — short-circuits every hook, so untraced runs are
    /// bit-identical to the pre-trace engine; when armed the recorder is
    /// strictly passive (it never reads back into scheduling), so traced
    /// reports stay bit-identical too ([`ServeReport::same_outcome`],
    /// asserted by the equivalence suite).
    trace: Option<TraceRecorder>,
    /// Reused per-iteration buffers — the event core's hot loop allocates
    /// nothing on a memoized decode step. Shared by both engines, so the
    /// reuse cannot change behavior.
    order_buf: Vec<usize>,
    stepped_buf: Vec<usize>,
    kv_buf: Vec<u64>,
}

impl<'a> ContinuousBatcher<'a> {
    /// `opts.kv_budget_bytes = 0` resolves to the engine's shard-plan
    /// budget ([`ShardPlan::replica_kv_budget_bytes`]): for the single
    /// plan that is exactly the platform budget — HBM capacity minus the
    /// resident weights at the serving precision (zero when the weights
    /// alone overflow — everything then rejects rather than pretending) —
    /// and for a sharded plan the per-die weight shards and split KV
    /// heads grow what one replica can cache.
    pub fn new(
        cfg: &'a ModelConfig,
        platform: &'a PlatformConfig,
        fmt: FpFormat,
        mut opts: BatcherConfig,
    ) -> ContinuousBatcher<'a> {
        assert!(
            opts.plan.tp.max(1) * opts.plan.pp.max(1) <= platform.die.dies.max(1),
            "shard plan tp={} x pp={} exceeds the package's {} dies",
            opts.plan.tp.max(1),
            opts.plan.pp.max(1),
            platform.die.dies
        );
        let policy = opts.policy_for(fmt);
        if let Some(err) = policy.validity_error() {
            panic!("invalid precision policy: {err}");
        }
        for rung in opts.class_precision.rungs() {
            let p = PrecisionPolicy { compute: rung, ..policy };
            if let Some(err) = p.validity_error() {
                panic!("invalid class-precision rung {}: {err}", rung.name());
            }
        }
        if opts.kv_budget_bytes == 0 {
            opts.kv_budget_bytes =
                opts.plan.replica_kv_budget_bytes_policy(cfg, policy, platform);
        }
        ContinuousBatcher { cfg, platform, fmt, policy, opts, faults: ReplicaFaults::none() }
    }

    /// Arm this engine with an injected-fault stream (this replica's view
    /// of the fleet's [`crate::coordinator::faults::FaultPlan`]). An
    /// empty stream is exactly the fault-free engine.
    pub fn with_faults(mut self, faults: ReplicaFaults) -> ContinuousBatcher<'a> {
        self.faults = faults;
        self
    }

    /// Price one iteration's fused pass under the engine's shard plan
    /// (bit-identical to [`crate::coordinator::schedule::model_total_mixed`]
    /// on the single plan), crediting the TP/PP communication share to
    /// the collective counter.
    ///
    /// With the pass memo armed (event core), a repeated pass shape is
    /// served from one hash lookup — same total, same collective cycles,
    /// and the layer-memo lookups the uncached pricing would have made
    /// are replayed as hits, so every counter in the report stays
    /// bit-identical to the iteration core.
    fn price_pass(
        &self,
        st: &mut RunState,
        prefills: &[(u64, u64)],
        decode_kv: &[u64],
    ) -> KernelCost {
        st.c.pass_events += 1;
        self.price_group(st, prefills, decode_kv, self.policy, 0)
    }

    /// Price one iteration whose requests sit on different rungs of the
    /// class-precision ladder: `pfmts`/`dfmts` give each prefill/decode
    /// entry's compute format, parallel to `prefills`/`decode_kv`. The
    /// pass splits into one homogeneous sub-pass per distinct format (in
    /// first-appearance order), priced back-to-back — still ONE scheduler
    /// pass event, one clock advance by the summed cycles. With a single
    /// distinct format this is exactly one group, and with the trivial
    /// ladder the call sites skip straight to [`Self::price_pass`].
    fn price_pass_rungs(
        &self,
        st: &mut RunState,
        prefills: &[(u64, u64)],
        pfmts: &[FpFormat],
        decode_kv: &[u64],
        dfmts: &[FpFormat],
    ) -> KernelCost {
        debug_assert_eq!(prefills.len(), pfmts.len());
        debug_assert_eq!(decode_kv.len(), dfmts.len());
        st.c.pass_events += 1;
        let mut fmts: Vec<FpFormat> = Vec::new();
        for f in pfmts.iter().chain(dfmts.iter()) {
            if !fmts.contains(f) {
                fmts.push(*f);
            }
        }
        if fmts.len() <= 1 {
            let policy = PrecisionPolicy {
                compute: fmts.first().copied().unwrap_or(self.policy.compute),
                ..self.policy
            };
            return self.price_group(st, prefills, decode_kv, policy, 0);
        }
        let mut total = KernelCost::default();
        for f in fmts {
            let gp: Vec<(u64, u64)> = prefills
                .iter()
                .zip(pfmts.iter())
                .filter(|&(_, pf)| *pf == f)
                .map(|(p, _)| *p)
                .collect();
            let gd: Vec<u64> = decode_kv
                .iter()
                .zip(dfmts.iter())
                .filter(|&(_, df)| *df == f)
                .map(|(d, _)| *d)
                .collect();
            let policy = PrecisionPolicy { compute: f, ..self.policy };
            let cost = self.price_group(st, &gp, &gd, policy, total.cycles);
            total = total.then(cost);
        }
        total
    }

    /// Price one homogeneous group of a pass at `policy`, with the trace
    /// span offset `offset` cycles past the current clock (sub-passes of
    /// a laddered iteration trace back-to-back). This is the whole legacy
    /// `price_pass` body except the pass-event increment, which the two
    /// public entry points own so a laddered iteration still counts once.
    fn price_group(
        &self,
        st: &mut RunState,
        prefills: &[(u64, u64)],
        decode_kv: &[u64],
        policy: PrecisionPolicy,
        offset: u64,
    ) -> KernelCost {
        let RunState { pass_memo, costs, c, degraded, time, trace, .. } = st;
        // A live `link@` fault swaps in a degraded-bandwidth platform for
        // pricing; fault-free runs borrow the nominal reference untouched.
        let platform = degraded.as_ref().unwrap_or(self.platform);
        let (total, collective_cycles, kind_cycles) = if let Some(memo) = pass_memo.as_mut()
        {
            memo.key.prefills.clear();
            memo.key.prefills.extend_from_slice(prefills);
            memo.key.decode_kv.clear();
            memo.key.decode_kv.extend_from_slice(decode_kv);
            memo.key.compute = policy.compute;
            memo.key.kv = policy.kv;
            if let Some(pc) = memo.map.get(&memo.key) {
                memo.hits += 1;
                costs.add_hits(pc.lookups);
                (pc.total, pc.collective_cycles, pc.kind_cycles)
            } else {
                let before = costs.hits() + costs.misses();
                let pass = plan_pass_cost_policy(
                    costs,
                    self.cfg,
                    self.opts.plan,
                    prefills,
                    decode_kv,
                    policy,
                    platform,
                );
                let lookups = costs.hits() + costs.misses() - before;
                memo.misses += 1;
                memo.map.insert(
                    memo.key.clone(),
                    PassCost {
                        total: pass.total,
                        collective_cycles: pass.collective_cycles,
                        kind_cycles: pass.kind_cycles,
                        lookups,
                    },
                );
                (pass.total, pass.collective_cycles, pass.kind_cycles)
            }
        } else {
            let pass = plan_pass_cost_policy(
                costs,
                self.cfg,
                self.opts.plan,
                prefills,
                decode_kv,
                policy,
                platform,
            );
            (pass.total, pass.collective_cycles, pass.kind_cycles)
        };
        c.collective_cycles += collective_cycles;
        // Phase is a pure function of the pass shape, so the per-phase
        // split is identical across cores and memo hits.
        let phase = if decode_kv.is_empty() {
            PassPhase::Prefill
        } else if prefills.is_empty() {
            PassPhase::Decode
        } else {
            PassPhase::Mixed
        };
        match phase {
            PassPhase::Prefill => c.prefill_kind_cycles.accum(&kind_cycles),
            PassPhase::Decode => c.decode_kind_cycles.accum(&kind_cycles),
            PassPhase::Mixed => c.mixed_kind_cycles.accum(&kind_cycles),
        }
        if let Some(rec) = trace.as_mut() {
            // Every call site advances the clock by exactly this pass's
            // cycles right after pricing, so the span is [now, now + c].
            let prefill_tokens: u64 = prefills.iter().map(|&(s, _)| s).sum();
            rec.pass(
                phase,
                *time + offset,
                *time + offset + total.cycles,
                (prefills.len() + decode_kv.len()) as u64,
                prefill_tokens,
                decode_kv.len() as u64,
                kind_cycles,
                collective_cycles,
            );
        }
        total
    }

    /// Whether this run deduplicates shared prompt prefixes. Off under
    /// `reserve_full` so the legacy-reservation baseline stays pure.
    fn prefix_caching(&self) -> bool {
        self.opts.prefix_cache && !self.opts.reserve_full
    }

    /// Whether any priority class maps to a non-default precision rung.
    /// When false every call site takes the exact legacy pricing path —
    /// no per-request format vectors are even allocated.
    fn ladder_active(&self) -> bool {
        !self.opts.class_precision.is_trivial()
    }

    /// Compute rung for a request: its *static* arrival class' ladder
    /// entry (aging promotes scheduling priority, not precision),
    /// defaulting to the engine format.
    fn rung_of(&self, req: &Request) -> FpFormat {
        self.opts.class_precision.rung_for(req.class, self.fmt)
    }

    /// Scheduling key: most urgent first — effective (aged) class, then
    /// FCFS by arrival, then id. Admission, prefill, and decode ordering
    /// all use this one key.
    fn sched_key(job: &Job, time: u64, aging_cycles: u64) -> (u8, u64, usize) {
        (Self::effective_class(job, time, aging_cycles), job.arrival_cycle, job.req.id)
    }

    fn aging_cycles(&self) -> u64 {
        if self.opts.aging_promote_s <= 0.0 {
            0
        } else {
            (self.opts.aging_promote_s * self.platform.freq_ghz * 1e9) as u64
        }
    }

    /// Class after aging: waiting promotes one class per aging interval.
    fn effective_class(job: &Job, time: u64, aging_cycles: u64) -> u8 {
        if aging_cycles == 0 {
            return job.req.class;
        }
        let promoted = (time.saturating_sub(job.arrival_cycle) / aging_cycles)
            .min(u8::MAX as u64) as u8;
        job.req.class.saturating_sub(promoted)
    }

    /// Pages a job must be able to map at admission time, net of the
    /// cached prefix pages it would share (those bill the pool nothing
    /// new).
    fn admission_pages(&self, geom: &KvGeometry, job: &Job, cached_hits: u64) -> u64 {
        if self.opts.reserve_full {
            geom.pages_for(job.prefill_target + (job.req.gen_tokens - job.produced))
        } else {
            geom.pages_for(job.prefill_target).saturating_sub(cached_hits)
        }
    }

    fn fresh_state(&self) -> RunState {
        let geom = KvGeometry::new(self.cfg, self.policy.kv, self.opts.page_tokens);
        RunState {
            ready: Vec::new(),
            active: Vec::new(),
            done: if self.opts.per_request {
                DoneLog::Keep(Vec::new())
            } else {
                DoneLog::Fold {
                    agg: LatencyAgg::default(),
                    completed: 0,
                    gen_tokens: 0,
                    retries: 0,
                    recovery_cycles: 0,
                }
            },
            rejected: Vec::new(),
            alloc: PagedKvAllocator::new(self.opts.kv_budget_bytes, geom),
            cache: PrefixCache::new(),
            costs: LayerCostCache::new(self.platform),
            c: RunCounters::default(),
            time: 0,
            pass_memo: None,
            fault_cursor: 0,
            degraded: None,
            failed: None,
            salvaged: Vec::new(),
            trace: None,
            order_buf: Vec::new(),
            stepped_buf: Vec::new(),
            kv_buf: Vec::new(),
        }
    }

    /// A fresh scheduler-side job for `r`.
    fn job_of(&self, r: Request) -> Job {
        Job {
            arrival_cycle: self.platform.ns_to_cycles(r.arrival_ns as f64),
            prefill_target: r.prompt_len,
            produced: 0,
            preemptions: 0,
            prefix_hit_tokens: 0,
            first_admitted_cycle: None,
            ttft_cycle: None,
            req: r,
        }
    }

    /// Upfront admission-feasibility scan + arrival sort, shared by both
    /// engines so rejected ids appear in identical (workload) order.
    fn materialized_jobs(&self, workload: &Workload, st: &mut RunState) -> VecDeque<Job> {
        let mut jobs: Vec<Job> = Vec::new();
        for r in &workload.requests {
            if !st.alloc.fits_pool(r.kv_capacity()) {
                if let Some(rec) = st.trace.as_mut() {
                    rec.request_rejected(r.id, st.time);
                }
                st.rejected.push(r.id);
                continue;
            }
            jobs.push(self.job_of(r.clone()));
        }
        jobs.sort_by_key(|j| (j.arrival_cycle, j.req.id));
        jobs.into()
    }

    /// Fire every injected fault due at the current clock. Stalls freeze
    /// the clock forward (passes are atomic, so faults land on iteration
    /// boundaries in both cores); link faults swap the pricing platform
    /// and flush the pass-shape memo (its cached costs priced the old
    /// bandwidth); a permanent failure latches `st.failed` and stops the
    /// fault stream — the run loop tears down at its next decision point.
    /// Returns whether anything fired (the caller loops to a fixpoint
    /// with arrival draining, since a stall can make new arrivals due).
    fn fire_due_faults(&self, st: &mut RunState) -> bool {
        let mut fired = false;
        while st.failed.is_none() {
            let Some(ev) = self.faults.events.get(st.fault_cursor) else { break };
            if ev.cycle > st.time {
                break;
            }
            st.fault_cursor += 1;
            fired = true;
            if let Some(rec) = st.trace.as_mut() {
                // Marked at the schedule boundary the fault lands on (its
                // plan cycle may fall mid-pass; passes are atomic).
                rec.fault(st.time, ev.kind.label());
                if let FaultKind::ReplicaStall { cycles } = ev.kind {
                    rec.stall(st.time, st.time + cycles);
                }
            }
            match ev.kind {
                FaultKind::ReplicaStall { cycles } => {
                    st.time += cycles;
                    st.c.stall_cycles += cycles;
                }
                FaultKind::LinkDegrade { fraction } => {
                    st.c.link_faults += 1;
                    st.degraded = if fraction < 1.0 {
                        Some(degrade_link(self.platform, fraction))
                    } else {
                        None
                    };
                    if let Some(m) = st.pass_memo.as_mut() {
                        m.map.clear();
                    }
                }
                FaultKind::ReplicaFail { pool_survives } => {
                    st.failed = Some(pool_survives);
                }
            }
        }
        fired
    }

    /// Cycle of the next pending fault, if the engine is still alive.
    /// Idle jumps clamp to this so a fault inside an idle gap fires at
    /// its own cycle, not at the next arrival.
    fn next_fault_cycle(&self, st: &RunState) -> Option<u64> {
        if st.failed.is_some() {
            return None;
        }
        self.faults.events.get(st.fault_cursor).map(|e| e.cycle)
    }

    /// Fixed-cadence gauge sampling (`serve --trace --metrics-interval`):
    /// resident set, queue depth, KV pool fill, aggregate FPU utilization
    /// so far, and cumulative d2d link bytes. A no-op — without even
    /// computing the gauge values — when tracing is off or between
    /// cadence boundaries. Samples land at scheduling decision points
    /// (passes are atomic), so one sample covers each crossed boundary.
    fn sample_gauges(&self, st: &mut RunState) {
        if !st.trace.as_ref().is_some_and(|r| r.sample_due(st.time)) {
            return;
        }
        let fpu =
            energy::power_report(&st.c.total, self.fmt, self.platform).fpu_utilization;
        let kv = st.alloc.gauges();
        let resident = st.active.len() as u64;
        let queue_depth = st.ready.len() as u64;
        let d2d = st.c.total.d2d_bytes;
        if let Some(rec) = st.trace.as_mut() {
            rec.maybe_sample(st.time, resident, queue_depth, kv, fpu, d2d);
        }
    }

    /// Permanent-failure teardown: release every resident page and hand
    /// back all unfinished work as [`SalvagedRequest`]s for the fleet
    /// router to re-route. An in-flight request that finished prefill on
    /// a surviving pool re-exports its prompt KV (priced by the router
    /// over the link state at the failure); everything else — mid-prefill
    /// residents, the ready queue, arrivals that never landed — recomputes
    /// from scratch on the adopting replica. Already-produced tokens are
    /// regenerated (the failed replica's output is gone), and prefix /
    /// preemption history does not transfer.
    fn salvage(&self, st: &mut RunState, pending: Vec<Job>, pool_survives: bool) {
        let fail_cycle = st.time;
        st.c.replica_failures += 1;
        let geom = st.alloc.geometry();
        let mut out: Vec<SalvagedRequest> = Vec::new();
        for mut a in st.active.drain(..) {
            st.alloc.release(&mut a.table);
            let salvable = pool_survives && !a.prefilling();
            let mut req = a.job.req;
            req.kv_imported = salvable;
            let export_bytes = if salvable {
                geom.pages_for(req.prompt_len) * geom.page_bytes()
            } else {
                0
            };
            out.push(SalvagedRequest { req, fail_cycle, export_bytes });
        }
        for job in st.ready.drain(..).chain(pending) {
            let mut req = job.req;
            req.kv_imported = false;
            out.push(SalvagedRequest { req, fail_cycle, export_bytes: 0 });
        }
        out.sort_by_key(|s| s.req.id);
        if let Some(rec) = st.trace.as_mut() {
            for s in &out {
                rec.request_salvaged(s.req.id, fail_cycle);
            }
        }
        st.c.salvaged_requests += out.len() as u64;
        st.c.salvaged_kv_bytes += out.iter().map(|s| s.export_bytes).sum::<u64>();
        st.salvaged = out;
    }

    /// Run the workload through the configured core and return the final
    /// state plus the offered-request count (shared by [`Self::run`] and
    /// [`Self::run_salvage`] and their traced variants; `trace` arms the
    /// passive recorder, `None` is the zero-cost default).
    fn run_state(&self, workload: &Workload, trace: Option<TraceRecorder>) -> (RunState, usize) {
        let mut st = self.fresh_state();
        st.trace = trace;
        match self.opts.engine {
            EngineMode::Iteration => {
                self.run_iteration_loop(&mut st, workload);
                (st, workload.len())
            }
            EngineMode::Event => {
                let jobs = self.materialized_jobs(workload, &mut st);
                self.run_event(&mut st, ArrivalSource::Queue(jobs));
                (st, workload.len())
            }
        }
    }

    /// Run the whole workload to completion and return the priced report.
    /// Dispatches on [`BatcherConfig::engine`]; the two cores produce
    /// bit-identical reports ([`ServeReport::same_outcome`]). If a
    /// permanent fault kills the engine mid-trace, unfinished requests
    /// are reported as rejected — standalone engines have no fleet to
    /// adopt them (use [`Self::run_salvage`] from a router instead).
    pub fn run(&self, workload: &Workload) -> ServeReport {
        let (mut st, offered) = self.run_state(workload, None);
        for s in std::mem::take(&mut st.salvaged) {
            st.rejected.push(s.req.id);
        }
        self.report(offered, st)
    }

    /// [`Self::run`] with cycle-level tracing armed: returns the report
    /// plus the sealed [`TraceRecorder`] holding the run's span record
    /// (pass/stall tiling, request lifecycles, gauge samples). The
    /// recorder is strictly passive — the report is bit-identical to
    /// [`Self::run`] on the same workload ([`ServeReport::same_outcome`]).
    pub fn run_traced(
        &self,
        workload: &Workload,
        settings: &TraceSettings,
    ) -> (ServeReport, TraceRecorder) {
        let rec = TraceRecorder::new(settings, self.platform.freq_ghz);
        let (mut st, offered) = self.run_state(workload, Some(rec));
        for s in std::mem::take(&mut st.salvaged) {
            st.rejected.push(s.req.id);
        }
        let mut rec = st.trace.take().expect("recorder armed above");
        rec.finish(st.time);
        (self.report(offered, st), rec)
    }

    /// [`Self::run`], but a permanent fault's unfinished requests come
    /// back as [`SalvagedRequest`]s (with their re-exportable KV sizes)
    /// instead of rejections, for the fleet router to re-route.
    pub fn run_salvage(&self, workload: &Workload) -> (ServeReport, Vec<SalvagedRequest>) {
        let (mut st, offered) = self.run_state(workload, None);
        let salvaged = std::mem::take(&mut st.salvaged);
        (self.report(offered, st), salvaged)
    }

    /// [`Self::run_salvage`] with cycle-level tracing armed (the form the
    /// fleet router's `--trace` path uses, so failed-replica traces keep
    /// their salvage markers).
    pub fn run_salvage_traced(
        &self,
        workload: &Workload,
        settings: &TraceSettings,
    ) -> (ServeReport, Vec<SalvagedRequest>, TraceRecorder) {
        let rec = TraceRecorder::new(settings, self.platform.freq_ghz);
        let (mut st, offered) = self.run_state(workload, Some(rec));
        let salvaged = std::mem::take(&mut st.salvaged);
        let mut rec = st.trace.take().expect("recorder armed above");
        rec.finish(st.time);
        (self.report(offered, st), salvaged, rec)
    }

    /// Serve a lazy arrival stream (e.g. [`Workload::stream_poisson`])
    /// through the event core without materializing the trace: memory is
    /// O(resident set + completed stats), so million-request fleet shards
    /// are cheap. The stream must yield non-decreasing arrival times
    /// (debug-asserted), which every seeded generator does. Like
    /// [`Self::run`], a permanent fault rejects the unfinished tail.
    pub fn serve_stream<I>(&self, arrivals: I) -> ServeReport
    where
        I: Iterator<Item = Request>,
    {
        let mut st = self.fresh_state();
        let offered = self.run_event(&mut st, ArrivalSource::Stream(Box::new(arrivals)));
        for s in std::mem::take(&mut st.salvaged) {
            st.rejected.push(s.req.id);
        }
        self.report(offered, st)
    }

    /// The legacy per-iteration loop (PR 2-5), kept verbatim as the
    /// oracle the event core is asserted against. Every scheduling stage
    /// it calls is shared with [`Self::run_event`].
    fn run_iteration_loop(&self, st: &mut RunState, workload: &Workload) {
        let aging_cycles = self.aging_cycles();
        let mut arrivals = self.materialized_jobs(workload, st);

        loop {
            // Fixpoint: drain due arrivals, then due faults (a stall can
            // advance the clock past more arrivals — and those past more
            // faults). The event core runs the identical fixpoint, so
            // faults land on the same schedule boundaries.
            loop {
                while arrivals.front().is_some_and(|j| j.arrival_cycle <= st.time) {
                    st.ready.push(arrivals.pop_front().unwrap());
                    st.c.arrival_events += 1;
                }
                if !self.fire_due_faults(st) {
                    break;
                }
            }
            self.sample_gauges(st);
            if let Some(pool_survives) = st.failed {
                let pending: Vec<Job> = arrivals.drain(..).collect();
                self.salvage(st, pending, pool_survives);
                break;
            }

            self.admit(st, aging_cycles);

            if st.active.is_empty() {
                debug_assert!(
                    st.ready.is_empty(),
                    "admission must drain the queue when the pool is free"
                );
                match arrivals.front() {
                    Some(next) if st.ready.is_empty() => {
                        // System idle: jump to the next arrival — or to a
                        // fault due sooner (it may stall or kill first).
                        let jump = self
                            .next_fault_cycle(st)
                            .map_or(next.arrival_cycle, |f| f.min(next.arrival_cycle));
                        st.time = st.time.max(jump);
                        continue;
                    }
                    None if st.ready.is_empty() => break,
                    _ => break, // wedged-queue guard (upfront reject covers this)
                }
            }

            // One priority order per iteration, shared by every stage
            // (ids, so stages survive `active` reshuffles).
            let mut order = std::mem::take(&mut st.order_buf);
            self.iteration_order_into(st, aging_cycles, &mut order);
            let progressed = if self.opts.token_budget > 0 {
                let p = self.mixed_iteration(st, &order);
                self.retire_finished(st);
                p
            } else {
                let mut p = self.prefill_quanta(st, &order);
                self.retire_finished(st);
                p |= self.decode_step(st, &order);
                p
            };
            st.order_buf = order;

            if !progressed {
                // Every resident job is stalled on pages. Reclaim idle
                // cached prefix pages first; only then evict a resident.
                if st.cache.evict_lru(&mut st.alloc, 1) > 0 {
                    continue;
                }
                if st.active.len() > 1 {
                    if let Some(v) = Self::victim_index(&st.active, None) {
                        Self::preempt(st, v);
                    }
                } else {
                    // A lone resident can always grow (oversize requests
                    // were rejected against the whole pool upfront, and
                    // cached pages were just drained).
                    debug_assert!(false, "lone resident job stalled");
                    if let Some(mut a) = st.active.pop() {
                        st.alloc.release(&mut a.table);
                        if let Some(rec) = st.trace.as_mut() {
                            rec.request_rejected(a.job.req.id, st.time);
                        }
                        st.rejected.push(a.job.req.id);
                    }
                }
            }
        }
    }

    /// The event-driven core. Control flow is owned by the event heap:
    /// arrivals stream in lazily (one resident event at a time), the
    /// iteration body records pass-completion / retirement / preemption
    /// markers at the advanced clock, and idle gaps cost exactly one
    /// heap peek — the clock jumps straight to the next arrival.
    ///
    /// Decision points coincide with the iteration core's loop exactly:
    /// events ≤ now fire, admission runs, then either the clock jumps to
    /// the next arrival (nothing resident) or one iteration of the
    /// *shared* scheduling stages runs. With the pass memo arming
    /// [`Self::price_pass`], the only differences are loop bookkeeping —
    /// which is why reports are bit-identical (asserted by the
    /// equivalence suite).
    ///
    /// Returns the number of requests the arrival source offered.
    fn run_event(&self, st: &mut RunState, source: ArrivalSource<'_>) -> usize {
        let aging_cycles = self.aging_cycles();
        st.pass_memo = Some(PassMemo::default());
        let mut q = EventQueue::new(source, self, st);

        loop {
            // Same drain-arrivals / fire-faults fixpoint as the iteration
            // core; each applied fault additionally leaves a marker event
            // at its cycle, fired (as a no-op) by the next `fire_due`.
            loop {
                q.fire_due(self, st);
                if !self.fire_due_faults(st) {
                    break;
                }
                q.push(st.time, EventKind::Fault);
            }
            self.sample_gauges(st);
            if let Some(pool_survives) = st.failed {
                let pending = q.drain_pending(self, st);
                self.salvage(st, pending, pool_survives);
                break;
            }

            self.admit(st, aging_cycles);

            if st.active.is_empty() {
                debug_assert!(
                    st.ready.is_empty(),
                    "admission must drain the queue when the pool is free"
                );
                match q.next_arrival_cycle() {
                    Some(next) if st.ready.is_empty() => {
                        // System idle: jump to the next arrival — or to a
                        // fault due sooner (it may stall or kill first).
                        let jump =
                            self.next_fault_cycle(st).map_or(next, |f| f.min(next));
                        st.time = st.time.max(jump);
                        continue;
                    }
                    None if st.ready.is_empty() => break,
                    _ => break, // wedged-queue guard (reject-on-pull covers this)
                }
            }

            let mut order = std::mem::take(&mut st.order_buf);
            self.iteration_order_into(st, aging_cycles, &mut order);
            let time_before = st.time;
            let retired_before = st.done.completed();
            let progressed = if self.opts.token_budget > 0 {
                let p = self.mixed_iteration(st, &order);
                self.retire_finished(st);
                p
            } else {
                let mut p = self.prefill_quanta(st, &order);
                self.retire_finished(st);
                p |= self.decode_step(st, &order);
                p
            };
            st.order_buf = order;

            // Record the iteration's outcome on the heap: its priced
            // passes completed at the advanced clock, retirements at the
            // same instant. They fire — and check the monotone-pop
            // invariant — on the next turn.
            if st.time > time_before {
                q.push(st.time, EventKind::PassComplete);
            }
            for _ in retired_before..st.done.completed() {
                q.push(st.time, EventKind::Retire);
            }

            if !progressed {
                if st.cache.evict_lru(&mut st.alloc, 1) > 0 {
                    continue;
                }
                if st.active.len() > 1 {
                    if let Some(v) = Self::victim_index(&st.active, None) {
                        Self::preempt(st, v);
                        q.push(st.time, EventKind::Preemption);
                    }
                } else {
                    debug_assert!(false, "lone resident job stalled");
                    if let Some(mut a) = st.active.pop() {
                        st.alloc.release(&mut a.table);
                        if let Some(rec) = st.trace.as_mut() {
                            rec.request_rejected(a.job.req.id, st.time);
                        }
                        st.rejected.push(a.job.req.id);
                    }
                }
            }
        }

        q.offered
    }

    /// The iteration's scheduling order: every resident job's id, most
    /// urgent first. Computed once per iteration and passed to each stage
    /// (PR 2 re-sorted per stage); stages filter it for eligibility.
    ///
    /// Deliberate refinement over PR 2: the order is evaluated at
    /// iteration-start time, so an aging promotion that lands *mid*-
    /// iteration (while a prefill pass advances the clock) no longer
    /// reorders that same iteration's decode stage — the iteration is
    /// atomic with respect to aging. On traces where no promotion falls
    /// inside an iteration (aging off, or any bounded trace with the
    /// defaults), scheduling is identical to PR 2.
    /// Fills the caller's reused buffer (taken out of `RunState` for the
    /// duration of the iteration) instead of allocating: indices are
    /// sorted by the scheduling key, then rewritten to ids in place.
    fn iteration_order_into(&self, st: &RunState, aging_cycles: u64, order: &mut Vec<usize>) {
        order.clear();
        order.extend(0..st.active.len());
        order.sort_by_key(|&i| Self::sched_key(&st.active[i].job, st.time, aging_cycles));
        for slot in order.iter_mut() {
            *slot = st.active[*slot].job.req.id;
        }
    }

    /// Admit ready jobs by effective priority while slots and pages allow,
    /// attaching cached prompt prefixes (and skipping their prefill).
    fn admit(&self, st: &mut RunState, aging_cycles: u64) {
        while st.active.len() < self.opts.max_batch.max(1) && !st.ready.is_empty() {
            let best = (0..st.ready.len())
                .min_by_key(|&i| Self::sched_key(&st.ready[i], st.time, aging_cycles))
                .unwrap();
            let geom = st.alloc.geometry();
            // Disaggregated handoff: a request whose prompt KV migrated in
            // from a prefill die materializes the imported pages at
            // admission and enters decode directly — no prefill passes, no
            // prefix probing (the migrated copy is private; crediting it
            // to the cache would misattribute the migration's savings).
            // After a preemption the imported copy is gone, so the request
            // recomputes like any other (this die holds full weights).
            let imported =
                st.ready[best].req.kv_imported && st.ready[best].preemptions == 0;
            let page_hashes = if self.prefix_caching() && !imported {
                st.ready[best].req.prompt_page_hashes(geom.page_tokens)
            } else {
                Vec::new()
            };
            let hits = st.cache.probe(&page_hashes);
            let need = self.admission_pages(&geom, &st.ready[best], hits);
            if need > st.alloc.free_pages() {
                // Idle cached prefixes are reclaimable capacity — but only
                // spend them when they actually cover the shortfall;
                // otherwise the admission fails anyway and the evicted
                // prefixes (hot system prompts other queued requests would
                // hit) would be destroyed for nothing.
                let missing = need - st.alloc.free_pages();
                if st.cache.reclaimable(&st.alloc) >= missing {
                    st.cache.evict_lru(&mut st.alloc, missing);
                }
                if need > st.alloc.free_pages() {
                    // Strict priority: lower classes do not jump the head
                    // of the queue on pages; retirements will free them.
                    break;
                }
            }
            let mut job = st.ready.swap_remove(best);
            let mut table = PageTable::new();
            // Under pool pressure the eviction above may have reclaimed
            // some of the very entries just probed, so the attach can come
            // up short of the probe; the job then prefills those tokens
            // like any miss (later grows reclaim/preempt as usual).
            let attached = st.cache.attach_prefix(&mut st.alloc, &mut table, &page_hashes);
            debug_assert!(attached <= hits, "attach cannot exceed the probe");
            let hit_tokens = attached * geom.page_tokens;
            job.prefix_hit_tokens += hit_tokens;
            st.c.prefix_hit_tokens += hit_tokens;
            if self.opts.reserve_full {
                let reserved = st.alloc.try_grow(
                    &mut table,
                    job.prefill_target + (job.req.gen_tokens - job.produced),
                );
                debug_assert!(reserved, "admission check guarantees the reservation");
            }
            let start_tokens = if imported {
                let manifest = KvExport {
                    tokens: job.prefill_target,
                    pages: geom.pages_for(job.prefill_target),
                    bytes: geom.pages_for(job.prefill_target) * geom.page_bytes(),
                    format: geom.format,
                };
                if !self.opts.reserve_full {
                    // Under reserve_full the reservation above already
                    // mapped the prompt pages (and the decode tail).
                    let mapped = st.alloc.import(&mut table, &manifest);
                    debug_assert!(mapped, "admission check sized the import");
                }
                st.c.kv_imports += 1;
                st.c.imported_kv_tokens += manifest.tokens;
                job.prefill_target
            } else {
                hit_tokens
            };
            if job.first_admitted_cycle.is_none() {
                job.first_admitted_cycle = Some(st.time);
            }
            if let Some(rec) = st.trace.as_mut() {
                rec.request_admitted(job.req.id, job.arrival_cycle, st.time, job.req.prompt_len);
            }
            st.active.push(ActiveJob {
                job,
                prefill_done: start_tokens,
                kv_len: start_tokens,
                table,
                page_hashes,
                registered: attached,
            });
        }
    }

    /// Grow `table` to `tokens`, reclaiming idle cached prefix pages when
    /// the pool alone cannot satisfy it. All-or-nothing like `try_grow`.
    fn grow_reclaiming(
        alloc: &mut PagedKvAllocator,
        cache: &mut PrefixCache,
        table: &mut PageTable,
        tokens: u64,
    ) -> bool {
        if alloc.try_grow(table, tokens) {
            return true;
        }
        let missing = alloc
            .geometry()
            .pages_for(tokens)
            .saturating_sub(table.len() as u64)
            .saturating_sub(alloc.free_pages());
        cache.evict_lru(alloc, missing);
        alloc.try_grow(table, tokens)
    }

    /// Extend a table that is being *written* from `have` to `want`
    /// tokens: when the write lands inside the current tail page, the
    /// copy-on-write guard forks it first (structurally a no-op — shared
    /// pages are full prompt pages and writes land past them — but the
    /// fork keeps that invariant local).
    fn grow_written(
        alloc: &mut PagedKvAllocator,
        cache: &mut PrefixCache,
        table: &mut PageTable,
        have: u64,
        want: u64,
    ) -> bool {
        let inside_tail = have % alloc.geometry().page_tokens != 0;
        if inside_tail
            && !alloc.ensure_private_tail(table)
            // The fork itself needs a free page: reclaim one and retry.
            && (cache.evict_lru(alloc, 1) == 0 || !alloc.ensure_private_tail(table))
        {
            return false;
        }
        Self::grow_reclaiming(alloc, cache, table, want)
    }

    /// Make room for one more decode token of job `id`, preempting less
    /// urgent residents if reclaiming cached pages is not enough. Returns
    /// whether the token's page is mapped (false also when the job itself
    /// got preempted while others grew).
    fn grow_for_decode(&self, st: &mut RunState, id: usize) -> bool {
        loop {
            let Some(i) = st.active.iter().position(|a| a.job.req.id == id) else {
                return false;
            };
            let ok = {
                let RunState { active, alloc, cache, .. } = &mut *st;
                let a = &mut active[i];
                Self::grow_written(alloc, cache, &mut a.table, a.kv_len, a.kv_len + 1)
            };
            if ok {
                return true;
            }
            match Self::victim_index(&st.active, Some(i)) {
                Some(v) => Self::preempt(st, v),
                None => return false, // nobody less urgent; wait a step
            }
        }
    }

    /// Register newly materialized full prompt pages in the prefix cache
    /// (up to the prompt boundary; generated tokens are never shareable).
    fn register_prompt_pages(st: &mut RunState, i: usize) {
        let RunState { active, alloc, cache, .. } = &mut *st;
        let a = &mut active[i];
        let pt = alloc.geometry().page_tokens;
        let full = (a.prefill_done.min(a.job.req.prompt_len) / pt)
            .min(a.page_hashes.len() as u64);
        while a.registered < full {
            let idx = a.registered as usize;
            cache.insert(alloc, a.page_hashes[idx], a.table.pages()[idx]);
            a.registered += 1;
        }
    }

    /// Mid-prefill prefix re-probe (a ROADMAP follow-on, now closed): at
    /// a chunk boundary, a resident request re-checks the cache for its
    /// upcoming prompt pages — pages another request registered *after*
    /// this one was admitted — and attaches every contiguously cached one,
    /// skipping their prefill. Returns the tokens attached. Only fires at
    /// exact page boundaries (where the chain stays aligned); a no-op
    /// when prefix caching is off, so the PR-2/PR-3 paths are unchanged.
    fn late_prefix_attach(&self, st: &mut RunState, i: usize) -> u64 {
        if !self.prefix_caching() {
            return 0;
        }
        let RunState { active, alloc, cache, c, .. } = &mut *st;
        let a = &mut active[i];
        let pt = alloc.geometry().page_tokens;
        if a.prefill_done % pt != 0 || a.table.len() as u64 != a.prefill_done / pt {
            return 0;
        }
        let mut tokens = 0;
        while a.prefill_done < a.job.prefill_target {
            let idx = (a.prefill_done / pt) as usize;
            // Chain alignment: every earlier prompt page must already be
            // registered/attached for hash `idx` to be meaningful.
            if idx >= a.page_hashes.len() || a.registered as usize != idx {
                break;
            }
            if !cache.attach_next(alloc, &mut a.table, a.page_hashes[idx]) {
                break;
            }
            a.registered += 1;
            a.prefill_done += pt;
            a.kv_len = a.prefill_done;
            tokens += pt;
        }
        if tokens > 0 {
            a.job.prefix_hit_tokens += tokens;
            c.prefix_hit_tokens += tokens;
            c.prefix_late_hits += tokens;
        }
        tokens
    }

    /// Advance every prefilling job by one chunk (shared priority order).
    /// Returns whether any prefill work ran. Legacy (non-budget) path:
    /// each chunk is its own NAR pass.
    fn prefill_quanta(&self, st: &mut RunState, order: &[usize]) -> bool {
        let mut ran = false;
        for &id in order {
            let Some(i) = st.active.iter().position(|a| a.job.req.id == id) else {
                continue;
            };
            if !st.active[i].prefilling() {
                continue;
            }
            // Pages registered since admission are attached, not redone.
            ran |= self.late_prefix_attach(st, i) > 0;
            if !st.active[i].prefilling() {
                continue;
            }
            let remaining = st.active[i].job.prefill_target - st.active[i].prefill_done;
            let quantum = match self.opts.prefill_chunk {
                0 => remaining,
                chunk => remaining.min(chunk),
            };
            let grown = {
                let RunState { active, alloc, cache, .. } = &mut *st;
                let a = &mut active[i];
                Self::grow_written(
                    alloc,
                    cache,
                    &mut a.table,
                    a.prefill_done,
                    a.prefill_done + quantum,
                )
            };
            if !grown {
                continue; // wait for pages; decode/retirements will free some
            }
            let chunk = [(quantum, st.active[i].prefill_done)];
            let cost = if self.ladder_active() {
                let f = [self.rung_of(&st.active[i].job.req)];
                self.price_pass_rungs(st, &chunk, &f, &[], &[])
            } else {
                self.price_pass(st, &chunk, &[])
            };
            if let Some(rec) = st.trace.as_mut() {
                rec.prefill_chunk(id, st.time, st.time + cost.cycles, quantum);
            }
            st.time += cost.cycles;
            st.c.total = st.c.total.then(cost);
            let a = &mut st.active[i];
            a.prefill_done += quantum;
            a.kv_len = a.prefill_done;
            st.c.prefill_tokens += quantum;
            st.c.prefill_chunks += 1;
            Self::register_prompt_pages(st, i);
            ran = true;
        }
        ran
    }

    /// Retire jobs that need no (further) decode (prefill-only requests).
    fn retire_finished(&self, st: &mut RunState) {
        let mut i = 0;
        while i < st.active.len() {
            let a = &st.active[i];
            if a.prefill_done >= a.job.prefill_target
                && a.job.produced >= a.job.req.gen_tokens
            {
                let mut a = st.active.swap_remove(i);
                st.alloc.release(&mut a.table);
                if let Some(rec) = st.trace.as_mut() {
                    rec.request_retired(a.job.req.id, st.time, a.job.produced);
                }
                let ttft = a.job.ttft_cycle.unwrap_or(st.time);
                st.done.push(self.finish_stats(&a.job, ttft, st.time));
            } else {
                i += 1;
            }
        }
    }

    /// One ragged batched decode step over every fully-prefilled resident
    /// job (shared priority order), growing pages on demand. Returns
    /// whether a step ran. Legacy (non-budget) path.
    fn decode_step(&self, st: &mut RunState, order: &[usize]) -> bool {
        let mut stepped = std::mem::take(&mut st.stepped_buf);
        stepped.clear();
        for &id in order {
            let eligible = st.active.iter().any(|a| a.job.req.id == id && a.decodable());
            if eligible && self.grow_for_decode(st, id) {
                stepped.push(id);
            }
        }
        // A job that grew early can itself be evicted while later jobs
        // grow; only still-resident jobs take part in the step.
        stepped.retain(|id| st.active.iter().any(|a| a.job.req.id == *id));
        if stepped.is_empty() {
            st.stepped_buf = stepped;
            return false;
        }

        let mut kv_lens = std::mem::take(&mut st.kv_buf);
        kv_lens.clear();
        kv_lens.extend(
            stepped
                .iter()
                .map(|id| st.active.iter().find(|a| a.job.req.id == *id).unwrap().kv_len),
        );
        let cost = if self.ladder_active() {
            let dfmts: Vec<FpFormat> = stepped
                .iter()
                .map(|id| {
                    let a = st.active.iter().find(|a| a.job.req.id == *id).unwrap();
                    self.rung_of(&a.job.req)
                })
                .collect();
            self.price_pass_rungs(st, &[], &[], &kv_lens, &dfmts)
        } else {
            self.price_pass(st, &[], &kv_lens)
        };
        st.time += cost.cycles;
        st.c.total = st.c.total.then(cost);
        st.c.decode_cycles += cost.cycles;
        st.c.decode_tokens += stepped.len() as u64;
        st.c.decode_steps += 1;

        self.apply_decode(st, &stepped);
        st.stepped_buf = stepped;
        st.kv_buf = kv_lens;
        true
    }

    /// Credit one decoded token to each job in `stepped` (TTFT on the
    /// first, inline retirement on the last).
    fn apply_decode(&self, st: &mut RunState, stepped: &[usize]) {
        for &id in stepped {
            let i = st.active.iter().position(|a| a.job.req.id == id).unwrap();
            let a = &mut st.active[i];
            a.kv_len += 1;
            a.job.produced += 1;
            if a.job.ttft_cycle.is_none() {
                a.job.ttft_cycle = Some(st.time);
            }
            if a.job.produced >= a.job.req.gen_tokens {
                let mut a = st.active.swap_remove(i);
                st.alloc.release(&mut a.table);
                if let Some(rec) = st.trace.as_mut() {
                    rec.request_retired(a.job.req.id, st.time, a.job.produced);
                }
                let ttft = a.job.ttft_cycle.unwrap_or(st.time);
                st.done.push(self.finish_stats(&a.job, ttft, st.time));
            }
        }
    }

    /// One Sarathi-style mixed iteration: a single token budget is filled
    /// with decode tokens first (latency), then prefill-chunk tokens, and
    /// the whole claim is priced as one fused pass that streams the
    /// weights once. Returns whether any work ran.
    fn mixed_iteration(&self, st: &mut RunState, order: &[usize]) -> bool {
        let budget = self.opts.token_budget.max(1);
        let mut left = budget;

        // Phase 1: decode claims, most urgent first.
        let mut decode_ids: Vec<usize> = Vec::new();
        for &id in order {
            if left == 0 {
                break;
            }
            let eligible = st.active.iter().any(|a| a.job.req.id == id && a.decodable());
            if eligible && self.grow_for_decode(st, id) {
                decode_ids.push(id);
                left -= 1;
            }
        }
        // Decode growth can preempt earlier claimants; drop them and
        // return their budget slots, so prefill can use what the pass
        // will not actually spend on decode.
        decode_ids.retain(|id| st.active.iter().any(|a| a.job.req.id == *id));
        left = budget - decode_ids.len() as u64;

        // Phase 2: prefill chunks from the remaining budget. Pages
        // registered since admission are attached instead of prefilled
        // (free: attaches consume no budget tokens).
        let mut late_attached = 0u64;
        let mut prefill_claims: Vec<(usize, u64, u64)> = Vec::new(); // (id, quantum, kv)
        for &id in order {
            if left == 0 {
                break;
            }
            let Some(i) = st.active.iter().position(|a| a.job.req.id == id) else {
                continue;
            };
            if !st.active[i].prefilling() {
                continue;
            }
            late_attached += self.late_prefix_attach(st, i);
            if !st.active[i].prefilling() {
                continue;
            }
            let remaining = st.active[i].job.prefill_target - st.active[i].prefill_done;
            let cap = match self.opts.prefill_chunk {
                0 => u64::MAX,
                chunk => chunk,
            };
            let quantum = remaining.min(cap).min(left);
            let grown = {
                let RunState { active, alloc, cache, .. } = &mut *st;
                let a = &mut active[i];
                Self::grow_written(
                    alloc,
                    cache,
                    &mut a.table,
                    a.prefill_done,
                    a.prefill_done + quantum,
                )
            };
            if !grown {
                continue; // wait for pages
            }
            prefill_claims.push((id, quantum, st.active[i].prefill_done));
            left -= quantum;
        }

        if decode_ids.is_empty() && prefill_claims.is_empty() {
            // Attach-only iterations still made progress (prefill skipped
            // forward); there is just nothing to price.
            return late_attached > 0;
        }

        let kv_lens: Vec<u64> = decode_ids
            .iter()
            .map(|id| st.active.iter().find(|a| a.job.req.id == *id).unwrap().kv_len)
            .collect();
        let prefills: Vec<(u64, u64)> =
            prefill_claims.iter().map(|&(_, q, kv)| (q, kv)).collect();
        let cost = if self.ladder_active() {
            let pfmts: Vec<FpFormat> = prefill_claims
                .iter()
                .map(|&(id, _, _)| {
                    let a = st.active.iter().find(|a| a.job.req.id == id).unwrap();
                    self.rung_of(&a.job.req)
                })
                .collect();
            let dfmts: Vec<FpFormat> = decode_ids
                .iter()
                .map(|id| {
                    let a = st.active.iter().find(|a| a.job.req.id == *id).unwrap();
                    self.rung_of(&a.job.req)
                })
                .collect();
            self.price_pass_rungs(st, &prefills, &pfmts, &kv_lens, &dfmts)
        } else {
            self.price_pass(st, &prefills, &kv_lens)
        };
        if let Some(rec) = st.trace.as_mut() {
            for &(id, quantum, _) in &prefill_claims {
                rec.prefill_chunk(id, st.time, st.time + cost.cycles, quantum);
            }
        }
        st.time += cost.cycles;
        st.c.total = st.c.total.then(cost);
        let prefill_claimed: u64 = prefills.iter().map(|&(s, _)| s).sum();
        st.c.budget_tokens += kv_lens.len() as u64 + prefill_claimed;
        st.c.budget_iterations += 1;
        if !decode_ids.is_empty() {
            st.c.decode_cycles += cost.cycles;
            st.c.decode_tokens += decode_ids.len() as u64;
            st.c.decode_steps += 1;
        }

        for &(id, quantum, _) in &prefill_claims {
            let i = st
                .active
                .iter()
                .position(|a| a.job.req.id == id)
                .expect("prefill claimants cannot be preempted after phase 1");
            let a = &mut st.active[i];
            a.prefill_done += quantum;
            a.kv_len = a.prefill_done;
            // A pass that completes a prompt's prefill computed the last
            // prompt position's output — which IS the next generated
            // token. Emit it from this same fused pass (no extra compute,
            // no budget token; ROADMAP follow-on, now closed): TTFT for
            // budget-mode runs drops by one iteration. The counter only
            // tracks genuine *first* tokens — a preempted request's
            // recompute completion emits too, but its first token was
            // already delivered before the preemption.
            let emit = a.prefill_done >= a.job.prefill_target
                && a.job.produced < a.job.req.gen_tokens;
            let first_emit = emit && a.job.ttft_cycle.is_none();
            if emit {
                a.job.produced += 1;
            }
            if first_emit {
                a.job.ttft_cycle = Some(st.time);
            }
            st.c.prefill_tokens += quantum;
            st.c.prefill_chunks += 1;
            if first_emit {
                st.c.fused_first_tokens += 1;
            }
            Self::register_prompt_pages(st, i);
        }
        self.apply_decode(st, &decode_ids);
        true
    }

    /// Pick the preemption victim: the least urgent resident (highest
    /// class, then latest first admission, then highest id). With
    /// `protect` set, that index is excluded and only jobs at the same or
    /// a less urgent static class than it qualify.
    fn victim_index(active: &[ActiveJob], protect: Option<usize>) -> Option<usize> {
        let floor = protect.map(|i| active[i].job.req.class);
        (0..active.len())
            .filter(|&i| Some(i) != protect)
            .filter(|&i| floor.is_none_or(|f| active[i].job.req.class >= f))
            .max_by_key(|&i| {
                let j = &active[i].job;
                (j.req.class, j.first_admitted_cycle, j.req.id)
            })
    }

    /// Evict a resident job: free its pages and requeue it to recompute
    /// (re-prefill prompt + already-produced tokens, then resume decode —
    /// often partly from the prefix cache it populated itself).
    fn preempt(st: &mut RunState, victim: usize) {
        let mut a = st.active.swap_remove(victim);
        st.alloc.release(&mut a.table);
        if let Some(rec) = st.trace.as_mut() {
            rec.request_preempted(a.job.req.id, st.time);
        }
        a.job.preemptions += 1;
        a.job.prefill_target = a.job.req.prompt_len + a.job.produced;
        st.c.preemptions += 1;
        st.ready.push(a.job);
    }

    fn finish_stats(&self, job: &Job, ttft_cycle: u64, done_cycle: u64) -> RequestStats {
        let s = |cyc: u64| self.platform.cycles_to_seconds(cyc);
        let arrival = job.arrival_cycle;
        RequestStats {
            id: job.req.id,
            class: job.req.class,
            prompt_len: job.req.prompt_len,
            gen_tokens: job.req.gen_tokens,
            arrival_s: s(arrival),
            admitted_s: s(job
                .first_admitted_cycle
                .unwrap_or(done_cycle)
                .saturating_sub(arrival)),
            ttft_s: s(ttft_cycle.saturating_sub(arrival)),
            latency_s: s(done_cycle.saturating_sub(arrival)),
            preemptions: job.preemptions,
            prefix_hit_tokens: job.prefix_hit_tokens,
            // Retry/recovery accounting is a fleet concern: the router
            // patches these by id when it re-routes salvaged requests.
            retries: 0,
            recovery_cycles: 0,
        }
    }

    fn report(&self, offered: usize, st: RunState) -> ServeReport {
        let RunState { done, rejected, alloc, costs, c, time, pass_memo, .. } = st;
        // Sketch-backed aggregates: exact (bit-identical to the sorted
        // sample vectors of PR 3-5) below the sketch's reservoir limit,
        // ~1%-error log-histograms above it. Both [`DoneLog`] variants
        // feed the sketches in retirement order, so `--no-per-request`
        // (which never materialized the vec inside the run loop) matches
        // the detail path bit-for-bit.
        let (ttft, lat, tpot, queue, per_class, completed, gen_tokens, retries, recovery, per_request) =
            match done {
                DoneLog::Keep(mut v) => {
                    let (t, l, tp, q, pc) = latency_aggregates(&v);
                    let completed = v.len();
                    let gen: u64 = v.iter().map(|r| r.gen_tokens).sum();
                    let retries: u64 = v.iter().map(|r| r.retries as u64).sum();
                    let recovery: u64 = v.iter().map(|r| r.recovery_cycles).sum();
                    v.sort_by_key(|r| r.id);
                    (t, l, tp, q, pc, completed, gen, retries, recovery, v)
                }
                DoneLog::Fold { agg, completed, gen_tokens, retries, recovery_cycles } => {
                    let (t, l, tp, q, pc) = agg.finish();
                    (t, l, tp, q, pc, completed, gen_tokens, retries, recovery_cycles, Vec::new())
                }
            };
        let total_seconds = self.platform.cycles_to_seconds(time);
        let decode_seconds = self.platform.cycles_to_seconds(c.decode_cycles);
        let power = energy::power_report(&c.total, self.fmt, self.platform);

        let per_s = |tokens: u64, seconds: f64| {
            if seconds > 0.0 {
                tokens as f64 / seconds
            } else {
                0.0
            }
        };
        let hit_denom = c.prefix_hit_tokens + c.prefill_tokens;
        ServeReport {
            model: self.cfg.name.clone(),
            format: self.fmt.name(),
            kv_format: self.policy.kv.name(),
            class_precision: self.opts.class_precision.to_spec(),
            requests: offered,
            completed,
            rejected,
            max_batch: self.opts.max_batch.max(1),
            kv_budget_bytes: self.opts.kv_budget_bytes,
            page_tokens: alloc.geometry().page_tokens,
            total_pages: alloc.total_pages(),
            peak_kv_bytes: alloc.peak_bytes_in_use(),
            total_cycles: time,
            total_seconds,
            prefill_tokens: c.prefill_tokens,
            prefill_chunks: c.prefill_chunks,
            gen_tokens,
            preemptions: c.preemptions,
            ttft_mean_s: ttft.mean(),
            ttft_p50_s: ttft.p(50.0),
            ttft_p99_s: ttft.p(99.0),
            latency_mean_s: lat.mean(),
            latency_p50_s: lat.p(50.0),
            latency_p99_s: lat.p(99.0),
            tpot_mean_s: tpot.mean(),
            tpot_p50_s: tpot.p(50.0),
            tpot_p99_s: tpot.p(99.0),
            queue_mean_s: queue.mean(),
            queue_p99_s: queue.p(99.0),
            tokens_per_s: per_s(gen_tokens, total_seconds),
            decode_tokens_per_s: per_s(c.decode_tokens, decode_seconds),
            decode_tokens: c.decode_tokens,
            decode_cycles: c.decode_cycles,
            decode_steps: c.decode_steps,
            avg_batch_occupancy: if c.decode_steps > 0 {
                c.decode_tokens as f64 / c.decode_steps as f64
            } else {
                0.0
            },
            fpu_utilization: power.fpu_utilization,
            power_w: power.power_w,
            hbm_gb: c.total.hbm_bytes() as f64 / 1e9,
            prefix_cache: self.prefix_caching(),
            prefix_hit_tokens: c.prefix_hit_tokens,
            prefix_hit_rate: if hit_denom > 0 {
                c.prefix_hit_tokens as f64 / hit_denom as f64
            } else {
                0.0
            },
            prefix_late_hits: c.prefix_late_hits,
            token_budget: self.opts.token_budget,
            budget_utilization: if c.budget_iterations > 0 {
                c.budget_tokens as f64
                    / (c.budget_iterations * self.opts.token_budget.max(1)) as f64
            } else {
                0.0
            },
            fused_first_tokens: c.fused_first_tokens,
            pricing_cache_hit_rate: costs.hit_rate(),
            pricing_cache_hits: costs.hits(),
            pricing_cache_misses: costs.misses(),
            budget_tokens: c.budget_tokens,
            budget_iterations: c.budget_iterations,
            kv_imports: c.kv_imports,
            imported_kv_tokens: c.imported_kv_tokens,
            tp: self.opts.plan.tp.max(1),
            pp: self.opts.plan.pp.max(1),
            collective_cycles: c.collective_cycles,
            d2d_bytes: c.total.d2d_bytes,
            prefill_kind_cycles: c.prefill_kind_cycles,
            decode_kind_cycles: c.decode_kind_cycles,
            mixed_kind_cycles: c.mixed_kind_cycles,
            work: c.total,
            engine: self.opts.engine.name(),
            arrival_events: c.arrival_events,
            pass_events: c.pass_events,
            pass_cache_hits: pass_memo.as_ref().map_or(0, |m| m.hits),
            pass_cache_misses: pass_memo.as_ref().map_or(0, |m| m.misses),
            replica_failures: c.replica_failures,
            stall_cycles: c.stall_cycles,
            link_faults: c.link_faults,
            salvaged_requests: c.salvaged_requests,
            salvaged_kv_bytes: c.salvaged_kv_bytes,
            retries,
            recovery_cycles: recovery,
            // One engine's degraded share is its stall time; the fleet
            // merge recomputes this over replicas x fleet wall-clock,
            // folding in post-failure dead time.
            degraded_capacity_fraction: if time > 0 {
                (c.stall_cycles as f64 / time as f64).clamp(0.0, 1.0)
            } else {
                0.0
            },
            warnings: Vec::new(),
            ttft_sketch: ttft,
            latency_sketch: lat,
            tpot_sketch: tpot,
            queue_sketch: queue,
            per_class,
            per_request,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_cfg(
        cfg: &ModelConfig,
        platform: &PlatformConfig,
        w: &Workload,
        opts: BatcherConfig,
    ) -> ServeReport {
        ContinuousBatcher::new(cfg, platform, FpFormat::Fp32, opts).run(w)
    }

    fn tiny_batcher(
        cfg: &ModelConfig,
        platform: &PlatformConfig,
        max_batch: usize,
        budget: u64,
    ) -> ServeReport {
        run_cfg(
            cfg,
            platform,
            &Workload::uniform(6, 16, 8),
            BatcherConfig::new(max_batch, budget),
        )
    }

    #[test]
    fn completes_all_requests() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Ample budget: all four slots can hold full-length caches with
        // page-rounding slack, so nothing is evicted.
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        let r = tiny_batcher(&cfg, &p, 4, budget);
        assert_eq!(r.completed, 6);
        assert!(r.rejected.is_empty());
        assert!(r.tokens_per_s > 0.0);
        assert_eq!(r.gen_tokens, 6 * 8);
        assert_eq!(r.prefill_tokens, 6 * 16);
        assert_eq!(r.preemptions, 0);
        // Unique prompt content: registrations, but no cross-request hits.
        assert_eq!(r.prefix_hit_tokens, 0);
        assert!(r.pricing_cache_hit_rate > 0.0, "decode steps must re-hit the memo");
    }

    #[test]
    fn kv_budget_is_never_exceeded() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let one = Request::new(0, 16, 8).kv_bytes(&cfg);
        // Pool for exactly two full-length caches, batch slots for four.
        for reserve_full in [false, true] {
            let mut opts = BatcherConfig::new(4, 2 * one);
            opts.reserve_full = reserve_full;
            let r = run_cfg(&cfg, &p, &Workload::uniform(6, 16, 8), opts);
            assert_eq!(r.completed, 6, "reserve_full={reserve_full}");
            assert!(
                r.peak_kv_bytes <= 2 * one,
                "{} > {} (reserve_full={reserve_full})",
                r.peak_kv_bytes,
                2 * one
            );
        }
        // Full reservation caps concurrency at the reservation count;
        // paged admission packs more residents into the same budget.
        let mut full = BatcherConfig::new(4, 2 * one);
        full.reserve_full = true;
        let rf = run_cfg(&cfg, &p, &Workload::uniform(6, 16, 8), full);
        assert!(rf.avg_batch_occupancy <= 2.0 + 1e-9);
        assert_eq!(rf.preemptions, 0, "reservations never need eviction");
        assert!(!rf.prefix_cache, "reserve_full disables prefix caching");
    }

    #[test]
    fn paged_admission_beats_full_reservation_occupancy() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Short prompts, long generations: reservations are mostly air.
        let w = Workload::uniform(8, 16, 48);
        let budget = Request::new(0, 16, 48).kv_bytes(&cfg) * 2;
        let mut full = BatcherConfig::new(8, budget);
        full.reserve_full = true;
        let paged = BatcherConfig::new(8, budget);
        let rf = run_cfg(&cfg, &p, &w, full);
        let rp = run_cfg(&cfg, &p, &w, paged);
        assert_eq!(rf.completed, 8);
        assert_eq!(rp.completed, 8);
        assert!(
            rp.avg_batch_occupancy > rf.avg_batch_occupancy,
            "paged {} vs reserved {}",
            rp.avg_batch_occupancy,
            rf.avg_batch_occupancy
        );
        assert!(rp.total_seconds < rf.total_seconds);
    }

    #[test]
    fn oversized_request_rejected_not_wedged() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let mut w = Workload::uniform(2, 16, 8);
        w.requests.push(Request::new(2, 100_000, 8));
        let budget = w.requests[0].kv_bytes(&cfg) * 4;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(4, budget));
        assert_eq!(r.completed, 2);
        assert_eq!(r.rejected, vec![2]);
    }

    #[test]
    fn latency_ordering_sane() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        let r = tiny_batcher(&cfg, &p, 8, budget);
        for s in &r.per_request {
            assert!(s.admitted_s <= s.ttft_s, "{s:?}");
            assert!(s.ttft_s <= s.latency_s, "{s:?}");
        }
        assert!(r.ttft_p50_s <= r.ttft_p99_s);
        assert!(r.latency_p50_s <= r.latency_p99_s);
        assert!(r.latency_mean_s <= r.total_seconds);
        // Decode-only throughput excludes prefill stalls, so it can only
        // be faster than the end-to-end rate.
        assert!(r.decode_tokens_per_s >= r.tokens_per_s);
    }

    #[test]
    fn prefill_only_requests_excluded_from_ttft_aggregates() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let mut w = Workload::uniform(2, 16, 4);
        w.requests.push(Request::new(2, 16, 0));
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(1, budget));
        assert_eq!(r.completed, 3);
        // Serial admission (max_batch 1) finishes the prefill-only
        // request last, so including it would inflate p99; the TTFT
        // percentiles must cover only the two generating requests.
        let max_gen_ttft = r
            .per_request
            .iter()
            .filter(|s| s.gen_tokens > 0)
            .map(|s| s.ttft_s)
            .fold(0.0, f64::max);
        assert_eq!(r.ttft_p99_s, max_gen_ttft);
        assert!(r.ttft_mean_s <= max_gen_ttft);
    }

    #[test]
    fn bigger_batch_serves_faster() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(8, 16, 16);
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let serial = run_cfg(&cfg, &p, &w, BatcherConfig::new(1, budget));
        let batched = run_cfg(&cfg, &p, &w, BatcherConfig::new(8, budget));
        assert!(
            batched.total_seconds < serial.total_seconds,
            "batched {} vs serial {}",
            batched.total_seconds,
            serial.total_seconds
        );
        assert!(batched.tokens_per_s > serial.tokens_per_s);
        assert!(batched.avg_batch_occupancy > serial.avg_batch_occupancy);
    }

    #[test]
    fn chunked_prefill_conserves_tokens_and_counts_chunks() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(3, 100, 4);
        let budget = Request::new(0, 100, 4).kv_bytes(&cfg) * 4;
        let mut opts = BatcherConfig::new(4, budget);
        opts.prefill_chunk = 32;
        let r = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(r.completed, 3);
        assert_eq!(r.preemptions, 0);
        // Conservation: every prompt token prefilled exactly once.
        assert_eq!(r.prefill_tokens, 3 * 100);
        // 100 tokens in 32-token chunks = 4 chunks per request.
        assert_eq!(r.prefill_chunks, 3 * 4);
    }

    #[test]
    fn priority_class_zero_beats_class_two_on_ttft() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // 8 identical requests, alternating urgent/patient, one slot.
        let mut w = Workload::uniform(8, 32, 8);
        for r in &mut w.requests {
            r.class = if r.id % 2 == 0 { 0 } else { 2 };
        }
        let budget = w.requests[0].kv_bytes(&cfg) * 8;
        let mut opts = BatcherConfig::new(1, budget);
        opts.aging_promote_s = 1e6; // effectively no aging in this trace
        let r = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(r.completed, 8);
        assert_eq!(r.per_class.len(), 2);
        let c0 = &r.per_class[0];
        let c2 = &r.per_class[1];
        assert_eq!((c0.class, c2.class), (0, 2));
        assert!(
            c0.ttft_p99_s < c2.ttft_p99_s,
            "urgent {} vs patient {}",
            c0.ttft_p99_s,
            c2.ttft_p99_s
        );
        // All class-0 requests finish before any class-2 request starts
        // decoding (single slot, strict priority, no aging).
        let worst_urgent = c0.latency_p99_s;
        let best_patient = r
            .per_request
            .iter()
            .filter(|s| s.class == 2)
            .map(|s| s.ttft_s)
            .fold(f64::MAX, f64::min);
        assert!(worst_urgent <= best_patient);
    }

    #[test]
    fn aging_promotes_waiting_requests() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // A patient request queued behind a stream of urgent ones: with
        // aggressive aging it must be admitted before the urgent tail.
        let mut w = Workload::uniform(9, 32, 8);
        for r in &mut w.requests {
            r.class = if r.id == 0 { 3 } else { 0 };
        }
        let budget = w.requests[0].kv_bytes(&cfg) * 9;
        let mut opts = BatcherConfig::new(1, budget);
        opts.aging_promote_s = 1e-6; // promotes one class every 1000 cycles
        let aged = run_cfg(&cfg, &p, &w, opts);
        let patient_aged = aged.per_request.iter().find(|s| s.id == 0).unwrap();
        let mut no_aging = BatcherConfig::new(1, budget);
        no_aging.aging_promote_s = 0.0;
        let strict = run_cfg(&cfg, &p, &w, no_aging);
        let patient_strict = strict.per_request.iter().find(|s| s.id == 0).unwrap();
        assert!(
            patient_aged.admitted_s < patient_strict.admitted_s,
            "aging must cut the patient request's queue wait: {} vs {}",
            patient_aged.admitted_s,
            patient_strict.admitted_s
        );
    }

    #[test]
    fn poisson_arrivals_respected() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(6, 16, 8).with_poisson_arrivals(11, 50.0);
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(4, budget));
        assert_eq!(r.completed, 6);
        for s in &r.per_request {
            let arrival_s = w.requests[s.id].arrival_ns as f64 / 1e9;
            assert!((s.arrival_s - arrival_s).abs() < 1e-6, "{s:?}");
        }
        // The trace cannot finish before the last arrival.
        let last = w.requests.iter().map(|r| r.arrival_ns).max().unwrap();
        assert!(r.total_seconds >= last as f64 / 1e9);
    }

    #[test]
    fn preemption_recomputes_and_completes() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Long generations against a pool sized for ~1.2 full caches:
        // decode growth must evict and recompute, yet everyone finishes.
        let w = Workload::uniform(3, 16, 64);
        let budget = Request::new(0, 16, 64).kv_bytes(&cfg) * 12 / 10;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(3, budget));
        assert_eq!(r.completed, 3, "{:?}", r.rejected);
        assert_eq!(r.gen_tokens, 3 * 64);
        assert!(r.preemptions > 0, "pool pressure must trigger eviction");
        // Recompute re-prefills prompt + produced tokens (some prompt
        // pages may come back from the prefix cache).
        assert!(r.prefill_tokens + r.prefix_hit_tokens > 3 * 16);
        assert!(r.peak_kv_bytes <= budget);
        let preempted: u32 = r.per_request.iter().map(|s| s.preemptions).sum();
        assert_eq!(preempted as u64, r.preemptions);
    }

    #[test]
    fn shared_prefix_hits_skip_prefill_and_cut_ttft() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // 6 requests sharing one 64-token template (page-aligned), spread
        // out in time so the first prefills it and the rest arrive after.
        let w = Workload::uniform(6, 32, 8)
            .with_shared_prefix(64, 6)
            .with_poisson_arrivals(3, 2.0);
        let budget = Request::new(0, 96, 8).kv_bytes(&cfg) * 12;
        let on = BatcherConfig::new(4, budget);
        let mut off = on;
        off.prefix_cache = false;
        let r_on = run_cfg(&cfg, &p, &w, on);
        let r_off = run_cfg(&cfg, &p, &w, off);
        assert_eq!(r_on.completed, 6);
        assert_eq!(r_off.completed, 6);
        assert_eq!(r_off.prefix_hit_tokens, 0);
        assert!(r_on.prefix_cache && !r_off.prefix_cache);
        // Followers skip the shared 64 tokens entirely.
        assert!(
            r_on.prefix_hit_tokens > 0,
            "shared template must hit the cache"
        );
        assert_eq!(
            r_on.prefix_hit_tokens + r_on.prefill_tokens,
            6 * 96,
            "hits + prefill must cover every prompt token exactly once"
        );
        assert!(r_on.prefix_hit_rate > 0.0 && r_on.prefix_hit_rate < 1.0);
        // Less prefill work: the trace finishes sooner and first tokens
        // come earlier.
        assert!(r_on.total_seconds < r_off.total_seconds);
        assert!(r_on.ttft_p99_s <= r_off.ttft_p99_s);
        assert!(r_on.tokens_per_s > r_off.tokens_per_s);
        // Same service delivered.
        assert_eq!(r_on.gen_tokens, r_off.gen_tokens);
    }

    #[test]
    fn prefix_cache_off_matches_on_without_sharing() {
        // With unique prompt content, ample budget and no preemption, the
        // cache never hits, so ON and OFF must produce the same trace
        // timing (cache retention only shows up in the page watermark).
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::synthetic(5, 10, (8, 80), (2, 12));
        let budget = Request::new(0, 200, 20).kv_bytes(&cfg) * 16;
        let mut on = BatcherConfig::new(4, budget);
        on.prefill_chunk = 24;
        let mut off = on;
        off.prefix_cache = false;
        let r_on = run_cfg(&cfg, &p, &w, on);
        let r_off = run_cfg(&cfg, &p, &w, off);
        assert_eq!(r_on.prefix_hit_tokens, 0);
        assert_eq!(r_on.total_cycles, r_off.total_cycles);
        assert_eq!(r_on.prefill_tokens, r_off.prefill_tokens);
        assert_eq!(r_on.prefill_chunks, r_off.prefill_chunks);
        assert_eq!(r_on.ttft_p99_s, r_off.ttft_p99_s);
        assert_eq!(r_on.latency_p99_s, r_off.latency_p99_s);
        assert_eq!(r_on.tokens_per_s, r_off.tokens_per_s);
    }

    #[test]
    fn token_budget_serves_everything_and_fills_budget() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(6, 48, 12);
        let budget = Request::new(0, 48, 12).kv_bytes(&cfg) * 12;
        let mut opts = BatcherConfig::new(4, budget);
        opts.token_budget = 32;
        opts.prefill_chunk = 16;
        let r = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(r.completed, 6);
        assert_eq!(r.gen_tokens, 6 * 12);
        assert_eq!(r.prefill_tokens + r.prefix_hit_tokens, 6 * 48);
        assert_eq!(r.token_budget, 32);
        assert!(
            r.budget_utilization > 0.0 && r.budget_utilization <= 1.0,
            "{}",
            r.budget_utilization
        );
        assert!(r.tokens_per_s > 0.0);
    }

    #[test]
    fn budget_mode_emits_first_token_from_prefill_completing_pass() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::uniform(1, 48, 4);
        let budget = Request::new(0, 48, 4).kv_bytes(&cfg) * 4;
        let mut opts = BatcherConfig::new(2, budget);
        opts.token_budget = 64; // the whole prompt fits one fused pass
        let r = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(r.completed, 1);
        assert_eq!(r.fused_first_tokens, 1);
        // The first token rides the prefill-completing pass itself, so
        // TTFT equals exactly that one pass — no extra decode iteration.
        use crate::coordinator::schedule::model_total_mixed;
        let mut costs = LayerCostCache::new(&p);
        let prefill =
            model_total_mixed(&mut costs, &cfg, &[(48, 0)], &[], FpFormat::Fp32, &p);
        let expect = p.cycles_to_seconds(prefill.cycles);
        let ttft = r.per_request[0].ttft_s;
        assert!((ttft - expect).abs() < 1e-12, "ttft {ttft} != prefill pass {expect}");
        // One fewer decode pass: 4 tokens, the first one free.
        assert_eq!(r.decode_tokens, 3);
        assert_eq!(r.gen_tokens, 4);
    }

    #[test]
    fn mid_prefill_reprobe_attaches_late_registered_pages() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Two requests share a 64-token template and are admitted in the
        // same instant, so the admission probe misses for BOTH (nothing
        // registered yet). The chunk-boundary re-probe then lets each
        // pick up the template pages the other registered mid-prefill.
        let w = Workload::uniform(2, 32, 4).with_shared_prefix(64, 2);
        let budget = Request::new(0, 96, 4).kv_bytes(&cfg) * 8;
        let mut opts = BatcherConfig::new(2, budget);
        opts.prefill_chunk = 16;
        let r = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(r.completed, 2);
        assert!(r.prefix_late_hits > 0, "re-probe must attach late pages");
        assert!(r.prefix_hit_tokens >= r.prefix_late_hits);
        // The template is materialized exactly once across the pair.
        assert_eq!(r.prefix_hit_tokens, 64);
        assert_eq!(r.prefill_tokens + r.prefix_hit_tokens, 2 * 96);
        // Without the cache nothing is shared — and the shared run can
        // only finish sooner (it prefills strictly fewer tokens).
        let mut off = opts;
        off.prefix_cache = false;
        let r_off = run_cfg(&cfg, &p, &w, off);
        assert_eq!(r_off.prefix_late_hits, 0);
        assert_eq!(r_off.prefill_tokens, 2 * 96);
        assert!(r.total_seconds <= r_off.total_seconds);
    }

    #[test]
    fn sharded_engine_charges_collectives_and_completes() {
        // tiny has 4 heads / ff 128, so tp=2 splits exactly. The sharded
        // engine must serve the same trace to completion while pricing
        // every pass's all-reduces: nonzero collective cycles and d2d
        // traffic, both accounted inside the wall clock / work totals.
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let w = Workload::uniform(6, 32, 8);
        let budget = Request::new(0, 32, 8).kv_bytes(&cfg) * 8;
        let single = run_cfg(&cfg, &p, &w, BatcherConfig::new(4, budget));
        let mut opts = BatcherConfig::new(4, budget);
        opts.plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
        let sharded = run_cfg(&cfg, &p, &w, opts);
        assert_eq!(sharded.completed, 6);
        assert_eq!(sharded.gen_tokens, single.gen_tokens);
        assert_eq!((sharded.tp, sharded.pp), (2, 1));
        assert!(sharded.collective_cycles > 0, "TP must charge all-reduces");
        assert!(sharded.d2d_bytes > 0);
        assert_eq!(sharded.d2d_bytes, sharded.work.d2d_bytes);
        assert!(sharded.collective_cycles < sharded.total_cycles);
        // The single-die run stays collective-free.
        assert_eq!((single.tp, single.pp), (1, 1));
        assert_eq!(single.collective_cycles, 0);
        assert_eq!(single.d2d_bytes, 0);
    }

    #[test]
    fn sharded_plan_resolves_its_own_kv_budget() {
        // A zero budget resolves to the plan's per-replica budget — the
        // platform budget on the single plan (bit-identical), the larger
        // sharded pool under TP.
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let fmt = FpFormat::Fp32;
        let single = ContinuousBatcher::new(&cfg, &p, fmt, BatcherConfig::new(4, 0));
        assert_eq!(
            single.opts.kv_budget_bytes,
            crate::coordinator::kv_paging::platform_kv_budget_bytes(&cfg, fmt, &p)
        );
        let mut opts = BatcherConfig::new(4, 0);
        opts.plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
        let sharded = ContinuousBatcher::new(&cfg, &p, fmt, opts);
        assert_eq!(
            sharded.opts.kv_budget_bytes,
            opts.plan.replica_kv_budget_bytes(&cfg, fmt, &p)
        );
        assert!(sharded.opts.kv_budget_bytes > single.opts.kv_budget_bytes);
    }

    #[test]
    fn imported_kv_enters_decode_without_prefill() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let mut w = Workload::uniform(4, 64, 8);
        for r in &mut w.requests {
            *r = r.clone().with_imported_kv();
        }
        let budget = Request::new(0, 64, 8).kv_bytes(&cfg) * 8;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(4, budget));
        assert_eq!(r.completed, 4);
        assert_eq!(r.gen_tokens, 4 * 8);
        // The whole point: zero prefill work on the decode die.
        assert_eq!(r.prefill_tokens, 0);
        assert_eq!(r.prefill_chunks, 0);
        assert_eq!(r.kv_imports, 4);
        assert_eq!(r.imported_kv_tokens, 4 * 64);
        assert_eq!(r.prefix_hit_tokens, 0, "imports are not cache hits");
        // The same trace without the marker prefills every prompt token
        // and can only take longer.
        let plain = run_cfg(
            &cfg,
            &p,
            &Workload::uniform(4, 64, 8),
            BatcherConfig::new(4, budget),
        );
        assert_eq!(plain.kv_imports, 0);
        assert_eq!(plain.prefill_tokens, 4 * 64);
        assert!(r.total_seconds < plain.total_seconds);
        assert!(r.ttft_p99_s < plain.ttft_p99_s);
    }

    #[test]
    fn imported_kv_preemption_falls_back_to_recompute() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Pool sized for ~1.2 full caches: decode growth must preempt, and
        // a preempted import recomputes its prompt like any request.
        let mut w = Workload::uniform(3, 16, 64);
        for r in &mut w.requests {
            *r = r.clone().with_imported_kv();
        }
        let budget = Request::new(0, 16, 64).kv_bytes(&cfg) * 12 / 10;
        let r = run_cfg(&cfg, &p, &w, BatcherConfig::new(3, budget));
        assert_eq!(r.completed, 3, "{:?}", r.rejected);
        assert_eq!(r.gen_tokens, 3 * 64);
        assert!(r.preemptions > 0, "pool pressure must trigger eviction");
        assert!(
            r.prefill_tokens > 0,
            "a preempted import must recompute its prompt"
        );
        assert!(r.peak_kv_bytes <= budget);
    }

    #[test]
    fn per_request_gate_drops_detail_only() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let w = Workload::synthetic(5, 12, (8, 64), (2, 12)).with_priority_classes(2);
        let budget = Request::new(0, 128, 12).kv_bytes(&cfg) * 16;
        let on = BatcherConfig::new(4, budget);
        let mut off = on;
        off.per_request = false;
        let r_on = run_cfg(&cfg, &p, &w, on);
        let r_off = run_cfg(&cfg, &p, &w, off);
        assert!(!r_on.per_request.is_empty());
        assert!(r_off.per_request.is_empty());
        // Everything except the detail vector is bit-identical.
        let mut masked = r_on.clone();
        masked.per_request = Vec::new();
        assert_eq!(masked, r_off);
    }

    #[test]
    fn tpot_is_the_decode_pace() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        let r = tiny_batcher(&cfg, &p, 4, budget);
        assert!(r.tpot_p50_s > 0.0);
        assert!(r.tpot_p50_s <= r.tpot_p99_s);
        // The p99 of this small (exact-sketch) trace is the worst
        // per-request decode pace.
        let worst = r
            .per_request
            .iter()
            .map(|s| (s.latency_s - s.ttft_s) / (s.gen_tokens - 1) as f64)
            .fold(0.0, f64::max);
        assert!((r.tpot_p99_s - worst).abs() < 1e-12, "{} vs {worst}", r.tpot_p99_s);
        // TPOT excludes prefill and queueing, so the paced decode span
        // fits inside every request's end-to-end latency.
        for s in &r.per_request {
            assert!(r.tpot_p50_s * (s.gen_tokens - 1) as f64 <= s.latency_s);
        }
    }

    #[test]
    fn token_budget_mixed_pass_beats_alternation() {
        // Prefill chunks and decode tokens priced as one fused pass must
        // serve a mixed trace faster than the legacy chunk/decode
        // alternation that streams the weights once per stage.
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        // Long prompts keep prefill running while earlier requests decode.
        let w = Workload::uniform(6, 128, 24);
        let budget = Request::new(0, 128, 24).kv_bytes(&cfg) * 12;
        let mut legacy = BatcherConfig::new(6, budget);
        legacy.prefill_chunk = 32;
        let mut fused = legacy;
        fused.token_budget = 64;
        let r_legacy = run_cfg(&cfg, &p, &w, legacy);
        let r_fused = run_cfg(&cfg, &p, &w, fused);
        assert_eq!(r_legacy.completed, 6);
        assert_eq!(r_fused.completed, 6);
        assert_eq!(r_legacy.gen_tokens, r_fused.gen_tokens);
        assert!(
            r_fused.total_seconds < r_legacy.total_seconds,
            "fused {} !< alternation {}",
            r_fused.total_seconds,
            r_legacy.total_seconds
        );
    }

    #[test]
    fn kind_cycles_split_covers_compute_and_phases() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let budget = Request::new(0, 16, 8).kv_bytes(&cfg) * 8;
        // Alternation mode: prefill-only and decode-only passes.
        let r = tiny_batcher(&cfg, &p, 4, budget);
        let split = r.prefill_kind_cycles.total()
            + r.decode_kind_cycles.total()
            + r.mixed_kind_cycles.total();
        assert_eq!(split + r.collective_cycles, r.work.cycles);
        assert!(r.prefill_kind_cycles.total() > 0);
        assert!(r.decode_kind_cycles.total() > 0);
        assert!(r.mixed_kind_cycles.is_zero(), "no fused passes without a budget");
        // Budget mode: decode+prefill claims fuse into mixed passes.
        let mut opts = BatcherConfig::new(4, budget);
        opts.token_budget = 16;
        let rb = run_cfg(&cfg, &p, &Workload::uniform(6, 16, 8), opts);
        let splitb = rb.prefill_kind_cycles.total()
            + rb.decode_kind_cycles.total()
            + rb.mixed_kind_cycles.total();
        assert_eq!(splitb + rb.collective_cycles, rb.work.cycles);
        assert!(rb.mixed_kind_cycles.total() > 0);
    }

    #[test]
    fn traced_run_is_bit_identical_and_seals_the_recorder() {
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::occamy();
        let one = Request::new(0, 16, 8).kv_bytes(&cfg);
        // Tight pool: preemption/recompute traffic exercises the
        // lifecycle hooks beyond the happy path.
        let w = Workload::uniform(6, 16, 8);
        let b = ContinuousBatcher::new(
            &cfg,
            &p,
            FpFormat::Fp32,
            BatcherConfig::new(4, 2 * one),
        );
        let plain = b.run(&w);
        let (traced, rec) = b.run_traced(&w, &TraceSettings::default());
        assert!(plain.same_outcome(&traced), "tracing must be strictly passive");
        assert_eq!(rec.total_cycles(), Some(traced.total_cycles));
        // Pass spans tile the busy time exactly...
        let busy: u64 = rec.passes().iter().map(|s| s.end - s.start).sum();
        assert_eq!(busy, traced.work.cycles);
        let acct = rec.track_accounting();
        assert_eq!(acct.busy + acct.stall + acct.idle, traced.total_cycles);
        // ...chunk spans conserve the prefill counter, and lifecycles
        // conserve completions.
        let chunk_tokens: u64 = rec.chunks().iter().map(|c| c.tokens).sum();
        assert_eq!(chunk_tokens, traced.prefill_tokens);
        assert_eq!(
            rec.requests().iter().filter(|r| r.finished).count(),
            traced.completed
        );
    }
}
