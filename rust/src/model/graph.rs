//! Layer-graph expansion: one transformer block -> the kernel sequence the
//! coordinator schedules (paper Fig. 1/2 block topology, with the fusions
//! of Sec. V-B applied).

use super::{Family, Mode, ModelConfig};

/// Kernel class a layer belongs to (the Fig. 10 breakdown categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Plain GEMM (projections, MLP linears).
    Gemm,
    /// FlashAttention-2 fused attention.
    FlashAttention,
    /// Fused Concat+Linear with tree reduction.
    FusedConcatLinear,
    /// LayerNorm.
    Layernorm,
    /// i-GELU (fused with the preceding linear).
    Gelu,
}

impl LayerKind {
    pub const fn name(self) -> &'static str {
        match self {
            LayerKind::Gemm => "gemm",
            LayerKind::FlashAttention => "flashattention",
            LayerKind::FusedConcatLinear => "fused-concat-linear",
            LayerKind::Layernorm => "layernorm",
            LayerKind::Gelu => "gelu",
        }
    }
}

/// One layer instance of the block with concrete dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub kind: LayerKind,
    pub label: &'static str,
    /// GEMM: (m, k, n). FA: (heads, sq; skv via `skv`). LN/GELU: (rows, cols).
    pub m: u64,
    pub k: u64,
    pub n: u64,
    /// FA only: KV length (= S in NAR self-attention; cache length in AR).
    pub skv: u64,
    /// GPT causal masking.
    pub causal: bool,
    /// Activations arrive SPM-resident from the previous fused layer.
    pub fused_input: bool,
}

/// Expand one transformer block at sequence length `s` (NAR) or for one
/// token against a `kv_len`-entry cache (AR) into its kernel sequence.
pub fn block_layers(cfg: &ModelConfig, mode: Mode, s: u64, kv_len: u64) -> Vec<Layer> {
    let causal = cfg.family == Family::Gpt;
    let (sq, skv) = match mode {
        Mode::Nar => (s, s),
        Mode::Ar => (1, kv_len + 1),
    };
    let hp = cfg.hp();
    vec![
        Layer { kind: LayerKind::Layernorm, label: "ln1", m: sq, k: cfg.e, n: cfg.e, skv: 0, causal: false, fused_input: false },
        Layer { kind: LayerKind::Gemm, label: "q-proj", m: sq, k: cfg.e, n: hp, skv: 0, causal: false, fused_input: false },
        Layer { kind: LayerKind::Gemm, label: "k-proj", m: sq, k: cfg.e, n: hp, skv: 0, causal: false, fused_input: false },
        Layer { kind: LayerKind::Gemm, label: "v-proj", m: sq, k: cfg.e, n: hp, skv: 0, causal: false, fused_input: false },
        Layer { kind: LayerKind::FlashAttention, label: "attention", m: cfg.heads, k: cfg.p, n: sq, skv, causal, fused_input: false },
        Layer { kind: LayerKind::FusedConcatLinear, label: "out-proj", m: sq, k: hp, n: cfg.e, skv: 0, causal: false, fused_input: true },
        Layer { kind: LayerKind::Layernorm, label: "ln2", m: sq, k: cfg.e, n: cfg.e, skv: 0, causal: false, fused_input: false },
        Layer { kind: LayerKind::Gemm, label: "mlp-up", m: sq, k: cfg.e, n: cfg.ff, skv: 0, causal: false, fused_input: false },
        Layer { kind: LayerKind::Gelu, label: "gelu", m: sq, k: cfg.ff, n: cfg.ff, skv: 0, causal: false, fused_input: true },
        Layer { kind: LayerKind::Gemm, label: "mlp-down", m: sq, k: cfg.ff, n: cfg.e, skv: 0, causal: false, fused_input: true },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nar_block_layers() {
        let cfg = ModelConfig::gpt_j();
        let ls = block_layers(&cfg, Mode::Nar, 1024, 0);
        assert_eq!(ls.len(), 10);
        let att = ls.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.m, 16);
        assert_eq!(att.n, 1024);
        assert_eq!(att.skv, 1024);
        assert!(att.causal);
    }

    #[test]
    fn vit_not_causal() {
        let cfg = ModelConfig::vit_b();
        let ls = block_layers(&cfg, Mode::Nar, 197, 0);
        let att = ls.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert!(!att.causal);
    }

    #[test]
    fn ar_block_single_query() {
        let cfg = ModelConfig::gpt_j();
        let ls = block_layers(&cfg, Mode::Ar, 1, 512);
        let att = ls.iter().find(|l| l.kind == LayerKind::FlashAttention).unwrap();
        assert_eq!(att.n, 1); // one query
        assert_eq!(att.skv, 513); // cache + current token
        let q = ls.iter().find(|l| l.label == "q-proj").unwrap();
        assert_eq!(q.m, 1);
    }

    #[test]
    fn fusions_marked() {
        let cfg = ModelConfig::vit_b();
        let ls = block_layers(&cfg, Mode::Nar, 197, 0);
        assert!(ls.iter().find(|l| l.label == "gelu").unwrap().fused_input);
        assert!(ls.iter().find(|l| l.label == "out-proj").unwrap().fused_input);
        assert!(!ls.iter().find(|l| l.label == "q-proj").unwrap().fused_input);
    }
}
