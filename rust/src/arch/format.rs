//! Floating-point formats supported by the Snitch SIMD FPU (paper Sec. IV-A1).
//!
//! The 64-bit-wide FPU packs 1/2/4/8 lanes for 64/32/16/8-bit formats, and
//! offers *expanding* (widening) SIMD dot products that take FP8/FP16 inputs
//! and accumulate at FP16/FP32 — the reason low-precision GEMMs keep the
//! speedup of narrow inputs without losing long-accumulation accuracy.

use std::fmt;

/// One of the six FP formats of the Snitch FPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpFormat {
    /// IEEE-754 binary64.
    Fp64,
    /// IEEE-754 binary32.
    Fp32,
    /// IEEE-754 binary16.
    Fp16,
    /// BrainFloat16 (8-bit exponent, 7-bit mantissa).
    Bf16,
    /// FP8 E5M2 (paper's "FP8").
    Fp8,
    /// FP8 E4M3 (paper's "FP8ALT").
    Fp8Alt,
}

impl FpFormat {
    /// All formats, widest first.
    pub const ALL: [FpFormat; 6] = [
        FpFormat::Fp64,
        FpFormat::Fp32,
        FpFormat::Fp16,
        FpFormat::Bf16,
        FpFormat::Fp8,
        FpFormat::Fp8Alt,
    ];

    /// The four formats the paper's precision ladder sweeps (Fig. 7/8).
    pub const LADDER: [FpFormat; 4] =
        [FpFormat::Fp64, FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8];

    /// Size of one element in bytes.
    pub const fn bytes(self) -> u64 {
        match self {
            FpFormat::Fp64 => 8,
            FpFormat::Fp32 => 4,
            FpFormat::Fp16 | FpFormat::Bf16 => 2,
            FpFormat::Fp8 | FpFormat::Fp8Alt => 1,
        }
    }

    /// SIMD lanes in the 64-bit FPU datapath (1 FMA per lane per cycle).
    pub const fn simd_lanes(self) -> u64 {
        8 / self.bytes()
    }

    /// Format elements are *accumulated* in by the widening dot-product
    /// extension (paper Sec. IV-A1): FP8 -> FP16, FP16 -> FP32; wider
    /// formats accumulate natively.
    pub const fn accumulation_format(self) -> FpFormat {
        match self {
            FpFormat::Fp8 | FpFormat::Fp8Alt => FpFormat::Fp16,
            FpFormat::Fp16 | FpFormat::Bf16 => FpFormat::Fp32,
            other => other,
        }
    }

    /// True for the sub-32-bit formats that need pack/unpack conversions
    /// around the FP32 softmax/activation islands (paper Sec. VII-C).
    pub const fn needs_fp32_conversion(self) -> bool {
        matches!(
            self,
            FpFormat::Fp16 | FpFormat::Bf16 | FpFormat::Fp8 | FpFormat::Fp8Alt
        )
    }

    /// Short lowercase name used in CLI args / configs / reports.
    pub const fn name(self) -> &'static str {
        match self {
            FpFormat::Fp64 => "fp64",
            FpFormat::Fp32 => "fp32",
            FpFormat::Fp16 => "fp16",
            FpFormat::Bf16 => "bf16",
            FpFormat::Fp8 => "fp8",
            FpFormat::Fp8Alt => "fp8alt",
        }
    }

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<FpFormat> {
        match s.to_ascii_lowercase().as_str() {
            "fp64" | "f64" => Some(FpFormat::Fp64),
            "fp32" | "f32" => Some(FpFormat::Fp32),
            "fp16" | "f16" => Some(FpFormat::Fp16),
            "bf16" => Some(FpFormat::Bf16),
            "fp8" | "f8" | "e5m2" => Some(FpFormat::Fp8),
            "fp8alt" | "e4m3" => Some(FpFormat::Fp8Alt),
            _ => None,
        }
    }
}

impl fmt::Display for FpFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Cycles one 64-bit SIMD vector of KV elements costs to convert between
/// the cache and compute precisions (pack/unpack through the FPU's
/// widening datapath, paper Sec. IV-A1). One cycle to unpack/expand, one
/// to repack/round — conversions ride the FMA pipeline, so there is no
/// separate quant unit to model.
pub const KV_CONVERT_CYCLES_PER_VEC: u64 = 2;

/// First-class serving precision: which format the resident weights are
/// stored at, which format the kernels compute in, and which format the
/// KV cache is held at. The legacy single-scalar precision is the
/// *degenerate* policy ([`PrecisionPolicy::uniform`]), which every
/// pricing path reproduces bit-for-bit.
///
/// Validity lattice ([`PrecisionPolicy::validity_error`]): the KV format
/// must be *narrower-or-equal* to the compute format — attention reads
/// widen kv -> compute, and widening preserves the compute format's
/// accumulation rules ([`FpFormat::accumulation_format`]). A KV cache
/// wider than the compute format would force narrowing reads (losing the
/// stored precision every pass) and is rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrecisionPolicy {
    /// Format the resident weights are stored (and streamed) at.
    pub weights: FpFormat,
    /// Format the kernels compute in (SIMD lanes, accumulation rules).
    pub compute: FpFormat,
    /// Format the KV cache is stored at (paged-pool token bytes, export
    /// wire bytes). Narrower-or-equal to `compute`.
    pub kv: FpFormat,
}

impl PrecisionPolicy {
    /// The degenerate single-format policy: weights, compute, and KV all
    /// at `fmt` — exactly the legacy serving precision.
    pub const fn uniform(fmt: FpFormat) -> PrecisionPolicy {
        PrecisionPolicy { weights: fmt, compute: fmt, kv: fmt }
    }

    /// Whether this is a degenerate (single-format) policy.
    pub fn is_uniform(&self) -> bool {
        self.weights == self.compute && self.compute == self.kv
    }

    /// Whether KV reads must widen kv -> compute (and writes narrow
    /// back), i.e. whether dequant-on-read cycles are billed.
    pub fn kv_conversion_active(&self) -> bool {
        self.kv != self.compute
    }

    /// Why this policy is invalid on the kv/compute lattice, or `None`
    /// when legal.
    pub fn validity_error(&self) -> Option<String> {
        if self.kv.bytes() > self.compute.bytes() {
            return Some(format!(
                "kv format {} is wider than compute format {} (kv must be narrower-or-equal)",
                self.kv, self.compute
            ));
        }
        if self.kv.accumulation_format().bytes() > self.compute.accumulation_format().bytes()
        {
            return Some(format!(
                "kv format {} accumulates wider than compute format {} allows",
                self.kv, self.compute
            ));
        }
        None
    }
}

impl std::str::FromStr for FpFormat {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        FpFormat::parse(s).ok_or_else(|| format!("unknown FP format: {s}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widening_accumulation() {
        assert_eq!(FpFormat::Fp8.accumulation_format(), FpFormat::Fp16);
        assert_eq!(FpFormat::Fp16.accumulation_format(), FpFormat::Fp32);
        assert_eq!(FpFormat::Fp64.accumulation_format(), FpFormat::Fp64);
    }

    #[test]
    fn parse_roundtrip() {
        for f in FpFormat::ALL {
            assert_eq!(FpFormat::parse(f.name()), Some(f));
        }
        assert_eq!(FpFormat::parse("nope"), None);
    }

    #[test]
    fn policy_lattice_rejects_wide_kv() {
        // kv must be narrower-or-equal to compute.
        for f in FpFormat::ALL {
            assert!(PrecisionPolicy::uniform(f).validity_error().is_none(), "{f}");
            assert!(PrecisionPolicy::uniform(f).is_uniform());
            assert!(!PrecisionPolicy::uniform(f).kv_conversion_active());
        }
        let ok = PrecisionPolicy {
            weights: FpFormat::Fp16,
            compute: FpFormat::Fp16,
            kv: FpFormat::Fp8,
        };
        assert!(ok.validity_error().is_none());
        assert!(ok.kv_conversion_active());
        assert!(!ok.is_uniform());
        let bad = PrecisionPolicy {
            weights: FpFormat::Fp16,
            compute: FpFormat::Fp16,
            kv: FpFormat::Fp32,
        };
        assert!(bad.validity_error().is_some());
        // Equal-width distinct formats (bf16 kv under fp16 compute) sit on
        // the lattice: same bytes, conversion still billed.
        let eq = PrecisionPolicy {
            weights: FpFormat::Fp16,
            compute: FpFormat::Fp16,
            kv: FpFormat::Bf16,
        };
        assert!(eq.validity_error().is_none());
        assert!(eq.kv_conversion_active());
    }

    #[test]
    fn conversion_islands() {
        assert!(!FpFormat::Fp64.needs_fp32_conversion());
        assert!(!FpFormat::Fp32.needs_fp32_conversion());
        assert!(FpFormat::Fp8.needs_fp32_conversion());
        assert!(FpFormat::Bf16.needs_fp32_conversion());
    }
}
