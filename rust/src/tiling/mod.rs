//! Spatio-temporal tile planning (paper Sec. V-A1, Fig. 5).
//!
//! Decides how a GEMM / FlashAttention-2 workload is split:
//!
//! * **spatially** across clusters — M-rows for GEMMs (B broadcast),
//!   heads for attention, K/heads for the fused concat+linear layer;
//! * **temporally** across iterations of one cluster — tiles sized so a
//!   double-buffered working set fits the 128 kB L1 SPM.
//!
//! The planner mirrors `python/compile/kernels/*.spm_footprint_bytes` so
//! the artifacts' BlockSpec schedule and the simulated schedule agree.

use crate::arch::{FpFormat, PlatformConfig};

/// Tile plan for one cluster's share of a GEMM `C[M,N] = A[M,K] @ B[K,N]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmPlan {
    /// Rows of C assigned to this cluster (spatial share of M).
    pub rows: u64,
    /// Temporal tile sizes.
    pub bm: u64,
    pub bn: u64,
    pub bk: u64,
    /// Number of temporal steps = ceil(rows/bm)*ceil(N/bn)*ceil(K/bk).
    pub steps: u64,
}

impl GemmPlan {
    /// Bytes of SPM this plan's working set occupies (double-buffered
    /// inputs + accumulator at the widening-accumulation precision + output).
    pub fn spm_bytes(&self, fmt: FpFormat, double_buffered: bool) -> u64 {
        let el = fmt.bytes();
        let acc_el = fmt.accumulation_format().bytes().max(4); // stats fp32
        let a = self.bm * self.bk * el;
        let b = self.bk * self.bn * el;
        let acc = self.bm * self.bn * acc_el;
        let out = self.bm * self.bn * el;
        let inputs = if double_buffered { 2 * (a + b) } else { a + b };
        inputs + acc + out
    }
}

/// Tile plan for one cluster's share of FlashAttention-2 (one head at a
/// time; Sq x Skv attention with projection dim P).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaPlan {
    /// Heads assigned to this cluster (temporal if > 1).
    pub heads: u64,
    pub bq: u64,
    pub bkv: u64,
    /// KV-tile steps per q tile.
    pub kv_steps: u64,
    /// Q-tile steps per head.
    pub q_steps: u64,
}

impl FaPlan {
    /// SPM footprint: Q tile + double-buffered K/V tiles + fp32 accumulator
    /// + (m, l) statistics + output tile.
    pub fn spm_bytes(&self, p: u64, fmt: FpFormat, double_buffered: bool) -> u64 {
        let el = fmt.bytes();
        let q = self.bq * p * el;
        let kv = 2 * self.bkv * p * el;
        let kv_buf = if double_buffered { 2 * kv } else { kv };
        let acc = self.bq * p * 4;
        let stats = 2 * self.bq * 4;
        let out = self.bq * p * el;
        q + kv_buf + acc + stats + out
    }
}

/// Largest power-of-two <= x (tiles are pow2 for bank-conflict-free SPM
/// interleaving), never below 1.
fn pow2_floor(x: u64) -> u64 {
    if x == 0 {
        1
    } else {
        1u64 << (63 - x.leading_zeros() as u64)
    }
}

/// Plan the per-cluster GEMM tiling for `clusters` clusters.
///
/// Strategy (paper): split M spatially; temporally maximize `bk` first
/// (longest FREP inner loop amortizes SSR setup), then `bn`, then `bm`,
/// subject to the SPM budget.
pub fn plan_gemm(
    m: u64,
    k: u64,
    n: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> GemmPlan {
    let clusters = platform.total_clusters() as u64;
    let spm = platform.cluster.spm_bytes;
    let db = platform.features.double_buffering;
    // Spatial share of M; at least one row. When M < clusters the extra
    // clusters split N instead (handled by the caller via `plan_gemm_wide`).
    let rows = m.div_ceil(clusters).max(1).min(m);

    let mut bm = pow2_floor(rows.min(64));
    let mut bn = pow2_floor(n.min(512));
    let mut bk = pow2_floor(k.min(512));
    // Shrink until the working set fits: bm first (cheapest to iterate),
    // then bn, then bk — preserving the long inner loop as long as possible.
    loop {
        let plan = GemmPlan { rows, bm: bm.min(rows), bn: bn.min(n), bk: bk.min(k), steps: 0 };
        if plan.spm_bytes(fmt, db) <= spm {
            break;
        }
        if bm > 8 {
            bm /= 2;
        } else if bn > 32 {
            bn /= 2;
        } else if bk > 32 {
            bk /= 2;
        } else if bn > 8 {
            bn /= 2;
        } else if bk > 8 {
            bk /= 2;
        } else {
            break; // degenerate; smallest tiles
        }
    }
    let bm = bm.min(rows);
    let bn = bn.min(n);
    let bk = bk.min(k);
    let steps = rows.div_ceil(bm) * n.div_ceil(bn) * k.div_ceil(bk);
    GemmPlan { rows, bm, bn, bk, steps }
}

/// GEMV/wide variant: when M is tiny (AR mode, M=1..8), clusters split the
/// *N* dimension spatially instead (each cluster owns a slab of output
/// columns and the full K).
pub fn plan_gemm_wide(
    m: u64,
    k: u64,
    n: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> GemmPlan {
    let clusters = platform.total_clusters() as u64;
    let spm = platform.cluster.spm_bytes;
    let db = platform.features.double_buffering;
    let cols = n.div_ceil(clusters).max(1).min(n);
    let mut bn = pow2_floor(cols.min(256));
    let mut bk = pow2_floor(k.min(1024));
    loop {
        let plan = GemmPlan { rows: m, bm: m, bn: bn.min(cols), bk: bk.min(k), steps: 0 };
        if plan.spm_bytes(fmt, db) <= spm {
            break;
        }
        if bk > 64 {
            bk /= 2;
        } else if bn > 8 {
            bn /= 2;
        } else if bk > 8 {
            bk /= 2;
        } else {
            break;
        }
    }
    let bn = bn.min(cols);
    let bk = bk.min(k);
    let steps = cols.div_ceil(bn) * k.div_ceil(bk);
    GemmPlan { rows: m, bm: m, bn, bk, steps }
}

/// Plan FlashAttention-2: heads spatial over clusters (temporal when
/// H > clusters), (bq, bkv) sized to SPM (paper Sec. V-A2).
pub fn plan_flash_attention(
    heads: u64,
    sq: u64,
    skv: u64,
    p: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> FaPlan {
    let clusters = platform.total_clusters() as u64;
    let spm = platform.cluster.spm_bytes;
    let db = platform.features.double_buffering;
    let heads_per_cluster = heads.div_ceil(clusters).max(1);
    let mut bq = pow2_floor(sq.min(64));
    let mut bkv = pow2_floor(skv.min(128));
    loop {
        let plan = FaPlan {
            heads: heads_per_cluster,
            bq: bq.min(sq),
            bkv: bkv.min(skv),
            kv_steps: 0,
            q_steps: 0,
        };
        if plan.spm_bytes(p, fmt, db) <= spm {
            break;
        }
        if bkv > 16 {
            bkv /= 2;
        } else if bq > 8 {
            bq /= 2;
        } else if bkv > 4 {
            bkv /= 2;
        } else {
            break;
        }
    }
    let bq = bq.min(sq);
    let bkv = bkv.min(skv);
    FaPlan {
        heads: heads_per_cluster,
        bq,
        bkv,
        kv_steps: skv.div_ceil(bkv),
        q_steps: sq.div_ceil(bq),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn gemm_plan_fits_spm() {
        for fmt in FpFormat::LADDER {
            for (m, k, n) in [(1024, 4096, 4096), (197, 768, 768), (2048, 16384, 4096)] {
                let p = plan_gemm(m, k, n, fmt, &occ());
                assert!(
                    p.spm_bytes(fmt, true) <= occ().cluster.spm_bytes,
                    "{fmt} {m}x{k}x{n}: {:?} = {} B",
                    p,
                    p.spm_bytes(fmt, true)
                );
                assert!(p.steps >= 1);
                assert!(p.bm <= p.rows && p.bn <= n && p.bk <= k);
            }
        }
    }

    #[test]
    fn gemm_spatial_split_on_m() {
        // 1024 rows over 16 clusters = 64 rows each.
        let p = plan_gemm(1024, 1024, 1024, FpFormat::Fp32, &occ());
        assert_eq!(p.rows, 64);
    }

    #[test]
    fn wide_plan_splits_n() {
        // AR GEMV: M=1, big N -> each cluster owns N/16 columns.
        let p = plan_gemm_wide(1, 4096, 16384, FpFormat::Fp32, &occ());
        assert_eq!(p.rows, 1);
        assert!(p.bn <= 1024);
        assert!(p.spm_bytes(FpFormat::Fp32, true) <= occ().cluster.spm_bytes);
    }

    #[test]
    fn lower_precision_allows_bigger_tiles() {
        // The Fig. 7 observation: FP32 tiles fit better than FP64 ones,
        // improving parallelization beyond the pure SIMD factor.
        let p64 = plan_gemm(2048, 4096, 4096, FpFormat::Fp64, &occ());
        let p8 = plan_gemm(2048, 4096, 4096, FpFormat::Fp8, &occ());
        let elems64 = p64.bm * p64.bk + p64.bk * p64.bn;
        let elems8 = p8.bm * p8.bk + p8.bk * p8.bn;
        assert!(elems8 >= elems64);
    }

    #[test]
    fn fa_plan_fits_spm() {
        for fmt in FpFormat::LADDER {
            for (h, sq, skv, p) in [(16, 1024, 1024, 128), (12, 197, 197, 64), (16, 1, 2048, 256)] {
                let plan = plan_flash_attention(h, sq, skv, p, fmt, &occ());
                assert!(
                    plan.spm_bytes(p, fmt, true) <= occ().cluster.spm_bytes,
                    "{fmt} h{h} {sq}x{skv} p{p}: {plan:?}"
                );
                assert_eq!(plan.kv_steps, skv.div_ceil(plan.bkv));
            }
        }
    }

    #[test]
    fn fa_heads_temporal_when_fewer_clusters() {
        let four = PlatformConfig::with_clusters(4);
        let plan = plan_flash_attention(16, 197, 197, 64, FpFormat::Fp32, &four);
        assert_eq!(plan.heads, 4); // 16 heads / 4 clusters
        let sixteen = occ();
        let plan = plan_flash_attention(16, 197, 197, 64, FpFormat::Fp32, &sixteen);
        assert_eq!(plan.heads, 1);
    }

    #[test]
    fn pow2_floor_values() {
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(2), 2);
        assert_eq!(pow2_floor(3), 2);
        assert_eq!(pow2_floor(64), 64);
        assert_eq!(pow2_floor(197), 128);
        assert_eq!(pow2_floor(0), 1);
    }
}
