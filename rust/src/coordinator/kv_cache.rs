//! Decode-time KV cache (paper Sec. II-B).
//!
//! Fixed-capacity per-block K/V buffers the AR artifacts update in place:
//! the Rust coordinator owns the flat `[H, Smax, P]` f32 buffers, hands
//! them to the PJRT executable each step, and swaps in the returned
//! updated caches. Capacity is fixed at allocation so the decode loop
//! never reallocates (the hot-path requirement of §Perf).

/// KV cache for one transformer block.
#[derive(Debug, Clone)]
pub struct KvCache {
    heads: usize,
    capacity: usize,
    p: usize,
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// Allocate an empty cache of `capacity` tokens.
    pub fn new(heads: usize, capacity: usize, p: usize) -> KvCache {
        KvCache {
            heads,
            capacity,
            p,
            len: 0,
            k: vec![0.0; heads * capacity * p],
            v: vec![0.0; heads * capacity * p],
        }
    }

    /// Tokens currently cached.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum tokens.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Remaining slots.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Flat `[H, Smax, P]` K buffer (PJRT argument layout).
    pub fn k_flat(&self) -> &[f32] {
        &self.k
    }

    /// Flat `[H, Smax, P]` V buffer.
    pub fn v_flat(&self) -> &[f32] {
        &self.v
    }

    /// Bulk-load prefill K/V of `n` tokens from `[H, n, P]`-shaped slices
    /// (the NAR block's returned caches).
    pub fn load_prefill(&mut self, k: &[f32], v: &[f32], n: usize) {
        assert!(n <= self.capacity, "prefill {n} exceeds capacity {}", self.capacity);
        assert_eq!(k.len(), self.heads * n * self.p);
        assert_eq!(v.len(), self.heads * n * self.p);
        for h in 0..self.heads {
            let src = h * n * self.p..(h * n + n) * self.p;
            let dst = h * self.capacity * self.p;
            self.k[dst..dst + n * self.p].copy_from_slice(&k[src.clone()]);
            self.v[dst..dst + n * self.p].copy_from_slice(&v[src]);
        }
        self.len = n;
    }

    /// Replace the whole cache with the executable's returned buffers
    /// (already `[H, Smax, P]`) and advance the length by one.
    pub fn store_step(&mut self, k: Vec<f32>, v: Vec<f32>) {
        assert_eq!(k.len(), self.k.len(), "returned K cache has wrong size");
        assert_eq!(v.len(), self.v.len(), "returned V cache has wrong size");
        assert!(self.len < self.capacity, "KV cache full");
        self.k = k;
        self.v = v;
        self.len += 1;
    }

    /// K vector of head `h`, token `t` (testing/inspection).
    pub fn k_at(&self, h: usize, t: usize) -> &[f32] {
        let base = (h * self.capacity + t) * self.p;
        &self.k[base..base + self.p]
    }

    /// V vector of head `h`, token `t`.
    pub fn v_at(&self, h: usize, t: usize) -> &[f32] {
        let base = (h * self.capacity + t) * self.p;
        &self.v[base..base + self.p]
    }

    /// Cache bytes at f32 (both K and V).
    pub fn bytes(&self) -> usize {
        Self::bytes_for(self.heads, self.capacity, self.p)
    }

    /// Bytes a cache of this geometry occupies (both K and V, f32) —
    /// what [`KvCache::bytes`] reports, without allocating the buffers.
    /// The serving batcher sizes its HBM admission budget with this.
    pub fn bytes_for(heads: usize, capacity: usize, p: usize) -> usize {
        2 * heads * capacity * p * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_layout() {
        let mut c = KvCache::new(2, 8, 4);
        // K for 3 tokens, [H=2, n=3, P=4], distinguishable values.
        let k: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..24).map(|i| 100.0 + i as f32).collect();
        c.load_prefill(&k, &v, 3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.k_at(0, 0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(c.k_at(0, 2), &[8.0, 9.0, 10.0, 11.0]);
        // Head 1 starts at capacity stride, not token stride.
        assert_eq!(c.k_at(1, 0), &[12.0, 13.0, 14.0, 15.0]);
        assert_eq!(c.v_at(1, 2), &[120.0, 121.0, 122.0, 123.0]);
    }

    #[test]
    fn step_advances_len() {
        let mut c = KvCache::new(1, 4, 2);
        let size = c.k_flat().len();
        c.store_step(vec![1.0; size], vec![2.0; size]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.remaining(), 3);
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 2);
        let size = c.k_flat().len();
        c.store_step(vec![0.0; size], vec![0.0; size]);
        c.store_step(vec![0.0; size], vec![0.0; size]);
    }

    #[test]
    #[should_panic(expected = "exceeds capacity")]
    fn prefill_overflow_panics() {
        let mut c = KvCache::new(1, 2, 2);
        c.load_prefill(&[0.0; 6], &[0.0; 6], 3);
    }

    #[test]
    fn bytes_accounting() {
        let c = KvCache::new(16, 1024, 256);
        assert_eq!(c.bytes(), 2 * 16 * 1024 * 256 * 4);
        // Allocation-free sizing matches the allocated cache exactly.
        assert_eq!(KvCache::bytes_for(16, 1024, 256), c.bytes());
    }
}
