"""AOT lowering: JAX/Pallas model blocks -> HLO text artifacts for Rust.

Emits one `artifacts/<name>.hlo.txt` per model-block variant plus
`artifacts/manifest.json` describing, for each artifact:
  * the ordered argument list (name, shape, dtype, deterministic generator
    spec) so the Rust coordinator can recreate the exact inputs,
  * the output arity/shapes,
  * golden output fingerprints (L2 norm + first elements) computed by
    executing the jitted function here, so Rust integration tests can
    verify the PJRT round-trip numerically without Python at runtime.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/load_hlo/gen_hlo.py.

Inputs use a cross-language deterministic generator (`det_f32`): a 32-bit
integer hash both Python and Rust evaluate bit-identically, so no binary
tensor files need to ship with the artifacts.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# Per-argument seed stride; any odd constant works, it only needs to match
# rust/src/runtime/detgen.rs.
SEED_STRIDE = 0x9E3779B1


def hash32(x: np.ndarray) -> np.ndarray:
    """lowbias32 integer hash (u32 -> u32); identical in detgen.rs."""
    x = x.astype(np.uint32)
    x ^= x >> 16
    x *= np.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= np.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def det_f32(n: int, seed: int, scale: float, offset: float) -> np.ndarray:
    """Deterministic f32 vector in [offset - scale/2, offset + scale/2).

    Every op here (u32 hash, exact u32->f64, /2^32, -0.5, f64->f32 round,
    f32 mul/add) is bit-exact across numpy and Rust.
    """
    i = np.arange(n, dtype=np.uint64) + np.uint64(seed & 0xFFFFFFFF)
    h = hash32(i.astype(np.uint32))
    base = (h.astype(np.float64) / 2.0**32 - 0.5).astype(np.float32)
    return base * np.float32(scale) + np.float32(offset)


def gen_arg(shape, spec):
    """Materialize one argument from its generator spec."""
    if spec["kind"] == "det":
        n = int(np.prod(shape)) if shape else 1
        v = det_f32(n, spec["seed"], spec["scale"], spec["offset"])
        return v.reshape(shape) if shape else v[0]
    if spec["kind"] == "i32":
        return np.int32(spec["value"])
    raise ValueError(f"unknown generator kind {spec['kind']}")


def weight_specs(dims: M.ModelDims, seed0: int):
    """Generator specs for the block weight schema, fan-in scaled."""
    shapes = M.weight_shapes(dims)
    specs = []
    for idx, (name, _) in enumerate(M.BLOCK_WEIGHT_SCHEMA):
        shape = shapes[name]
        seed = (seed0 + (idx + 1) * SEED_STRIDE) & 0xFFFFFFFF
        if name in ("ln1_g", "ln2_g"):
            scale, offset = 0.2, 1.0      # gamma ~ 1
        elif len(shape) == 1:
            scale, offset = 0.2, 0.0      # biases / beta, small
        else:
            # ~ +-1/sqrt(fan_in): keeps activations O(1) through deep stacks
            scale, offset = 2.0 / float(shape[0]) ** 0.5, 0.0
        specs.append({
            "name": name, "shape": list(shape), "dtype": "f32",
            "gen": {"kind": "det", "seed": int(seed), "scale": scale,
                    "offset": offset},
        })
    return specs


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def fingerprint(arr) -> dict:
    a = np.asarray(arr, dtype=np.float32).ravel()
    return {
        "shape": list(np.asarray(arr).shape),
        "l2": float(np.linalg.norm(a.astype(np.float64))),
        "first": [float(x) for x in a[:4]],
    }


def build_artifact(name, fn, arg_specs, out_dir, run_golden=True):
    """Lower `fn` at the spec'd shapes, dump HLO text, return manifest entry."""
    args = [gen_arg(s["shape"], s["gen"]) for s in arg_specs]
    abstract = [
        jax.ShapeDtypeStruct(tuple(s["shape"]),
                             jnp.int32 if s["dtype"] == "i32" else jnp.float32)
        for s in arg_specs
    ]
    lowered = jax.jit(fn).lower(*abstract)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "args": arg_specs,
        "outputs": [],
    }
    if run_golden:
        outs = jax.jit(fn)(*args)
        entry["outputs"] = [fingerprint(o) for o in outs]
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO"
          + ("" if run_golden else " (no golden run)"))
    return entry


def act_spec(name, shape, seed, scale=1.0, offset=0.0):
    return {"name": name, "shape": list(shape), "dtype": "f32",
            "gen": {"kind": "det", "seed": int(seed & 0xFFFFFFFF),
                    "scale": scale, "offset": offset}}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-golden", action="store_true",
                    help="lower only, skip golden execution (faster)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"artifacts": [], "seed_stride": SEED_STRIDE}

    tiny = M.TINY
    vitb = M.VIT_B
    print("lowering artifacts:")

    # --- tiny ViT encoder block (integration tests, quickstart) ----------
    specs = [act_spec("x", (tiny.seq, tiny.e), 1)] + weight_specs(tiny, 1000)
    manifest["artifacts"].append(build_artifact(
        "vit_block_tiny",
        functools.partial(M.vit_block, dims=tiny),
        specs, args.out_dir))

    # --- real-shape ViT-B encoder block (quickstart numerics) ------------
    specs = [act_spec("x", (vitb.seq, vitb.e), 2)] + weight_specs(vitb, 2000)
    manifest["artifacts"].append(build_artifact(
        "vit_block_vitb",
        functools.partial(M.vit_block, dims=vitb),
        specs, args.out_dir))

    # --- tiny GPT decoder block, NAR/prefill ------------------------------
    specs = [act_spec("x", (tiny.seq, tiny.e), 3)] + weight_specs(tiny, 3000)
    manifest["artifacts"].append(build_artifact(
        "gpt_block_nar_tiny",
        functools.partial(M.gpt_block_nar, dims=tiny),
        specs, args.out_dir))

    # --- tiny GPT decoder block, AR/decode (fixed-capacity cache) ---------
    smax = 64
    kv_len = 17  # golden run: 17 valid cache entries before this step
    specs = (
        [act_spec("x", (1, tiny.e), 4),
         act_spec("k_cache", (tiny.heads, smax, tiny.p), 5, scale=0.5),
         act_spec("v_cache", (tiny.heads, smax, tiny.p), 6, scale=0.5),
         {"name": "kv_len", "shape": [], "dtype": "i32",
          "gen": {"kind": "i32", "value": kv_len}}]
        + weight_specs(tiny, 3000)  # same weights as the NAR block
    )
    manifest["artifacts"].append(build_artifact(
        "gpt_block_ar_tiny",
        functools.partial(M.gpt_block_ar, dims=tiny),
        specs, args.out_dir))

    # --- tiny LM head ------------------------------------------------------
    vocab = 256
    specs = [
        act_spec("x", (1, tiny.e), 7),
        act_spec("ln_g", (tiny.e,), 8, scale=0.2, offset=1.0),
        act_spec("ln_b", (tiny.e,), 9, scale=0.2),
        act_spec("w_head", (tiny.e, vocab), 10, scale=2.0 / tiny.e**0.5),
    ]
    manifest["artifacts"].append(build_artifact(
        "gpt_head_tiny", M.gpt_head, specs, args.out_dir))

    # --- standalone kernel artifacts (runtime microbenches) ---------------
    from .kernels import gemm as gemm_k
    from .kernels import flash_attention as fa

    specs = [act_spec("a", (256, 256), 11), act_spec("b", (256, 256), 12)]
    manifest["artifacts"].append(build_artifact(
        "kernel_gemm_256",
        lambda a, b: (gemm_k.gemm(a, b),),
        specs, args.out_dir))

    specs = [act_spec("q", (4, 256, 64), 13), act_spec("k", (4, 256, 64), 14),
             act_spec("v", (4, 256, 64), 15)]
    manifest["artifacts"].append(build_artifact(
        "kernel_fa_h4_s256",
        lambda q, k, v: (fa.flash_attention(q, k, v, causal=True),),
        specs, args.out_dir))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
          f"to {args.out_dir}")


if __name__ == "__main__":
    main()
