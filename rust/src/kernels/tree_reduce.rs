//! Fused Concat + Linear with logarithmic cluster-to-cluster reduction
//! (paper Sec. V-B, Fig. 6 right).
//!
//! After FA-2, each cluster holds its heads' output tiles in SPM. The
//! final linear projection W_L is tiled row-wise on the heads dimension
//! (the GEMM's K), so every cluster computes a *partial* S x E output from
//! its local heads — no concat materialization — and the partials are
//! summed pairwise over the hierarchical interconnect in log2(C·G) levels.
//! The unfused alternative (`unfused_concat_linear_cost`) bounces the
//! per-head outputs and the concatenated matrix through HBM; the delta is
//! the Fig. 1 HBM-traffic reduction (624 -> 384 MB on GPT-J).

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::kernels::gemm::{gemm_cost, OperandHome};
use crate::sim::core::{opcost, CoreModel};
use crate::sim::{KernelCost, MultiClusterSim};

/// Fused path: per-cluster partial GEMM (A tiles SPM-resident from FA-2,
/// W_L rows from HBM) + binary-tree reduction of the S x E partials.
pub fn fused_concat_linear_cost(
    s: u64,
    heads: u64,
    p: u64,
    e: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    if s == 0 || heads == 0 || p == 0 || e == 0 {
        return KernelCost::default();
    }
    let clusters = platform.total_clusters() as u64;
    let heads_per_cluster = heads.div_ceil(clusters).max(1);
    let k_local = heads_per_cluster * p;

    // Each cluster: S x k_local @ k_local x E partial GEMM. The activations
    // (head outputs) are SPM-resident; W_L row-tiles stream from HBM.
    // Every cluster runs the FULL S rows (K-spatial tiling, Fig. 5-A).
    let home = OperandHome { a: MemLevel::Spm, b: MemLevel::Hbm, c: MemLevel::Spm };
    // Model one cluster's GEMM on a single-cluster platform view so M is
    // not re-split spatially, then combine.
    let one_cluster = single_cluster_view(platform);
    let partial = gemm_cost(s, k_local, e, fmt, &one_cluster, home);

    let sim = MultiClusterSim::new(platform);
    let active = heads.min(clusters).max(1);
    let per: Vec<KernelCost> = (0..active).map(|_| partial).collect();
    let mut total = sim.parallel(&per);

    // Tree reduction of the S x E fp32 partial tiles.
    let core = CoreModel::new(platform.cluster, platform.features);
    let cores = platform.cluster.compute_cores;
    let tile_bytes = s * e * fmt.accumulation_format().bytes().max(2);
    let add_cycles =
        core.elementwise_cycles((s * e).div_ceil(cores), opcost::SIMPLE, FpFormat::Fp32, true);
    let red = sim.tree_reduce(tile_bytes, add_cycles);
    total.cycles += red.cycles;
    total.c2c_bytes += red.c2c_bytes;
    total.hbm_read_bytes += red.hbm_bytes / 2;
    total.hbm_write_bytes += red.hbm_bytes / 2;
    total.flops += (active.saturating_sub(1)) * s * e; // pairwise adds
    // Final store of the reduced S x E result to HBM.
    total.hbm_write_bytes += s * e * fmt.bytes();
    total
}

/// Unfused baseline: per-head outputs written to HBM, concatenated matrix
/// read back, plain M-spatial GEMM with A from HBM, result to HBM.
pub fn unfused_concat_linear_cost(
    s: u64,
    heads: u64,
    p: u64,
    e: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    if s == 0 || heads == 0 || p == 0 || e == 0 {
        return KernelCost::default();
    }
    let el = fmt.bytes();
    let hp = heads * p;
    // Write per-head outputs to HBM (the Concat materialization)...
    let mut total = KernelCost {
        hbm_write_bytes: s * hp * el,
        // ...cost of those writes: modeled as one streaming pass.
        ..Default::default()
    };
    let sim = MultiClusterSim::new(platform);
    let dma = crate::sim::dma::DmaEngine::new(platform)
        .with_hbm_sharers(platform.total_clusters() as u64);
    let write_cycles = dma.transfer_cycles(crate::sim::dma::Transfer::d2(
        s * hp * el / platform.total_clusters() as u64,
        s,
        MemLevel::Hbm,
    ));
    total.cycles += write_cycles + 50;
    total.dma_transfers += platform.total_clusters() as u64;
    // ...then the ordinary GEMM reads the concatenated matrix back.
    let g = gemm_cost(s, hp, e, fmt, platform, OperandHome::default());
    total = total.then(g);
    let _ = sim;
    total
}

/// A copy of the platform with a single cluster (for pricing one cluster's
/// local share of a K-spatial GEMM).
fn single_cluster_view(platform: &PlatformConfig) -> PlatformConfig {
    PlatformConfig { groups: 1, clusters_per_group: 1, ..platform.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn fused_saves_hbm_traffic() {
        // The core Fig. 1 claim: fusion removes the concat round trip.
        let (s, h, p, e) = (2048, 16, 256, 4096); // GPT-J attention out-proj
        let fused = fused_concat_linear_cost(s, h, p, e, FpFormat::Fp32, &occ());
        let unfused = unfused_concat_linear_cost(s, h, p, e, FpFormat::Fp32, &occ());
        assert!(
            fused.hbm_bytes() < unfused.hbm_bytes(),
            "fused {} vs unfused {}",
            fused.hbm_bytes(),
            unfused.hbm_bytes()
        );
        // Concat tensor is S x H*P: the unfused path moves it twice more.
        let delta = unfused.hbm_bytes() - fused.hbm_bytes();
        let concat_bytes = s * h * p * 4;
        assert!(delta >= concat_bytes, "delta {delta} concat {concat_bytes}");
    }

    #[test]
    fn fused_not_slower_and_saves_traffic() {
        // Both variants are compute-bound in NAR (K-split and M-split do
        // the same FLOPs); the paper's fusion win is the HBM traffic and
        // its energy, not raw NAR latency. The fused path must not lose
        // more than the reduction overhead (<10%) while saving traffic.
        let (s, h, p, e) = (1024, 16, 128, 2048);
        let fused = fused_concat_linear_cost(s, h, p, e, FpFormat::Fp32, &occ());
        let unfused = unfused_concat_linear_cost(s, h, p, e, FpFormat::Fp32, &occ());
        assert!(
            (fused.cycles as f64) < 1.10 * unfused.cycles as f64,
            "fused {} vs unfused {}",
            fused.cycles,
            unfused.cycles
        );
        assert!(fused.hbm_bytes() < unfused.hbm_bytes() / 2);
    }

    #[test]
    fn reduction_traffic_is_c2c() {
        let fused = fused_concat_linear_cost(1024, 16, 128, 2048, FpFormat::Fp32, &occ());
        assert!(fused.c2c_bytes > 0);
    }

    #[test]
    fn single_cluster_degenerates() {
        let one = PlatformConfig::with_clusters(1);
        let fused = fused_concat_linear_cost(256, 16, 64, 768, FpFormat::Fp32, &one);
        assert_eq!(fused.c2c_bytes, 0);
        assert!(fused.cycles > 0);
    }

    #[test]
    fn flops_include_partial_adds() {
        let (s, h, p, e) = (256u64, 16u64, 64u64, 768u64);
        let fused = fused_concat_linear_cost(s, h, p, e, FpFormat::Fp32, &occ());
        let gemm_flops = 2 * s * (h * p) * e;
        assert!(fused.flops >= gemm_flops, "{} >= {gemm_flops}", fused.flops);
    }
}
