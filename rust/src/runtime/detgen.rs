//! Deterministic cross-language tensor generator.
//!
//! Bit-exact mirror of `python/compile/aot.py::det_f32`: a lowbias32
//! integer hash mapped to f32 in `[offset - scale/2, offset + scale/2)`.
//! Every operation (u32 wrap-mul, exact u32→f64, /2^32, f64→f32 round,
//! f32 mul/add) is IEEE-deterministic in both numpy and Rust, so the Rust
//! integration tests can regenerate the exact inputs the Python golden
//! run used — no tensor files ship with the artifacts.

/// lowbias32 hash (u32 -> u32).
pub fn hash32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7FEB_352D);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846C_A68B);
    x ^= x >> 16;
    x
}

/// Deterministic f32 vector of length `n`.
pub fn det_f32(n: usize, seed: u32, scale: f32, offset: f32) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = hash32((i as u32).wrapping_add(seed));
            let base = (h as f64 / 4294967296.0 - 0.5) as f32;
            base * scale + offset
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_and_determinism() {
        let v1 = det_f32(4096, 7, 1.0, 0.0);
        let v2 = det_f32(4096, 7, 1.0, 0.0);
        assert_eq!(v1, v2);
        assert!(v1.iter().all(|&x| (-0.5..0.5).contains(&x)));
        let mean: f32 = v1.iter().sum::<f32>() / v1.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        let v3 = det_f32(4096, 8, 1.0, 0.0);
        assert_ne!(v1, v3);
    }

    #[test]
    fn scale_offset() {
        let v = det_f32(1024, 1, 0.2, 1.0);
        assert!(v.iter().all(|&x| (0.9..1.1).contains(&x)));
    }

    #[test]
    fn hash_avalanche() {
        // Consecutive inputs must decorrelate (same check as test_aot.py).
        let a: Vec<f64> = (0..1000u32).map(|i| hash32(i) as f64).collect();
        let mean = a.iter().sum::<f64>() / a.len() as f64;
        let mut num = 0.0;
        let mut den = 0.0;
        for w in a.windows(2) {
            num += (w[0] - mean) * (w[1] - mean);
            den += (w[0] - mean) * (w[0] - mean);
        }
        assert!((num / den).abs() < 0.1);
    }
}
