//! Minimal JSON parser (objects, arrays, strings, numbers, bools, null).
//!
//! Complete for the JSON that `python/compile/aot.py` emits (json.dump of
//! plain dicts/lists/floats/ints/strings); standard escape sequences are
//! supported, \uXXXX is decoded for the BMP.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing ergonomics).
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => write!(f, "{n}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {} (found {:?})", b as char, self.pos,
                  self.peek().map(|c| c as char))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => bail!("expected ',' or ']' (found {:?})", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                other => bail!("expected ',' or '}}' (found {:?})", other.map(|c| c as char)),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let v = parse(
            r#"{"artifacts": [{"name": "t", "args": [{"shape": [2, 3],
                "gen": {"kind": "det", "seed": 5, "scale": 0.5}}],
                "outputs": [{"l2": 1.25e2, "first": [-0.5, 0.25]}]}],
                "seed_stride": 2654435761}"#,
        )
        .unwrap();
        let a = &v.req("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.req("name").unwrap().as_str(), Some("t"));
        let shape = a.req("args").unwrap().as_arr().unwrap()[0]
            .req("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_u64(), Some(3));
        let l2 = a.req("outputs").unwrap().as_arr().unwrap()[0].req("l2").unwrap();
        assert_eq!(l2.as_f64(), Some(125.0));
        assert_eq!(v.req("seed_stride").unwrap().as_u64(), Some(2654435761));
    }

    #[test]
    fn scalars() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("-1.5e-3").unwrap().as_f64(), Some(-0.0015));
        assert_eq!(parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
        assert_eq!(parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn negative_not_u64() {
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_i64(), Some(-3));
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
    }
}
