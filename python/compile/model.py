"""L2: JAX transformer blocks assembled from the L1 Pallas kernels.

Covers both families the paper benchmarks (Table II):
  * encoder-only ViT blocks (non-causal MHSA)        -> `vit_block`
  * decoder-only GPT blocks, NAR mode (causal MHSA,
    returns K/V for the cache)                       -> `gpt_block_nar`
  * decoder-only GPT blocks, AR mode (single query
    against a fixed-capacity KV cache + write-back)  -> `gpt_block_ar`
  * final LayerNorm + LM head                        -> `gpt_head`

Everything here is build-time only: `aot.py` lowers these functions to HLO
text once; the Rust coordinator owns weights/caches at runtime and feeds
them in as parameters. Python never sits on the request path.

All blocks are pre-LN (GPT-2/ViT style). The MLP fuses Linear+i-GELU in a
single lowered module, mirroring the paper's layer-fusion (Sec. V-B): no
intermediate leaves the artifact boundary (= no HBM round trip).
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import flash_attention as fa
from .kernels import gelu as gelu_k
from .kernels import gemm as gemm_k
from .kernels import layernorm as ln_k


@dataclasses.dataclass(frozen=True)
class ModelDims:
    """Hyperparameters of one Table-II model (or a tiny test stand-in)."""

    name: str
    blocks: int
    e: int      # embedding dim E
    p: int      # per-head projection dim P
    heads: int  # H
    ff: int     # MLP hidden dim FF
    seq: int    # default sequence length S

    @property
    def hp(self) -> int:
        return self.heads * self.p


# Table II presets (S for GPT is the paper's sweep default of 1024).
VIT_B = ModelDims("vit-b", 12, 768, 64, 12, 3072, 197)
VIT_L = ModelDims("vit-l", 24, 1024, 64, 16, 4096, 197)
VIT_H = ModelDims("vit-h", 32, 1280, 80, 16, 5120, 197)
GPT3_XL = ModelDims("gpt3-xl", 40, 2048, 128, 16, 8192, 1024)
GPT_J = ModelDims("gpt-j", 28, 4096, 256, 16, 16384, 1024)
# Tiny stand-in: same topology, CPU-executable in integration tests.
TINY = ModelDims("tiny", 2, 64, 16, 4, 128, 32)

PRESETS = {m.name: m for m in (VIT_B, VIT_L, VIT_H, GPT3_XL, GPT_J, TINY)}

# Ordered weight-argument schema for one transformer block. The Rust side
# re-creates the exact argument order from the manifest.
BLOCK_WEIGHT_SCHEMA: List[Tuple[str, str]] = [
    ("ln1_g", "e"), ("ln1_b", "e"),
    ("wq", "e.hp"), ("wk", "e.hp"), ("wv", "e.hp"), ("wo", "hp.e"),
    ("ln2_g", "e"), ("ln2_b", "e"),
    ("w1", "e.ff"), ("b1", "ff"), ("w2", "ff.e"), ("b2", "e"),
]


def weight_shapes(dims: ModelDims) -> Dict[str, Tuple[int, ...]]:
    """Concrete shapes for the block weight schema."""
    table = {"e": (dims.e,), "ff": (dims.ff,),
             "e.hp": (dims.e, dims.hp), "hp.e": (dims.hp, dims.e),
             "e.ff": (dims.e, dims.ff), "ff.e": (dims.ff, dims.e)}
    return {name: table[kind] for name, kind in BLOCK_WEIGHT_SCHEMA}


def _split_heads(x, heads, p):
    """[S, H*P] -> [H, S, P] (paper: heads map to clusters)."""
    s = x.shape[0]
    return x.reshape(s, heads, p).transpose(1, 0, 2)


def _merge_heads(x):
    """[H, S, P] -> [S, H*P] (the Concat the paper fuses into the out-proj)."""
    h, s, p = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * p)


def _mha(x, w, dims: ModelDims, causal: bool):
    """Pre-LN MHA with the FA-2 Pallas kernel, fused concat+out-proj."""
    h = ln_k.layernorm(x, w["ln1_g"], w["ln1_b"])
    q = _split_heads(gemm_k.gemm(h, w["wq"]), dims.heads, dims.p)
    k = _split_heads(gemm_k.gemm(h, w["wk"]), dims.heads, dims.p)
    v = _split_heads(gemm_k.gemm(h, w["wv"]), dims.heads, dims.p)
    o = fa.flash_attention(q, k, v, causal=causal)
    att = gemm_k.gemm(_merge_heads(o), w["wo"])
    return x + att, k, v


def _mlp(x, w):
    """Pre-LN MLP with fused Linear+i-GELU (paper Sec. V-B)."""
    h = ln_k.layernorm(x, w["ln2_g"], w["ln2_b"])
    h = gelu_k.i_gelu(gemm_k.gemm(h, w["w1"]) + w["b1"].astype(h.dtype))
    return x + gemm_k.gemm(h, w["w2"]) + w["b2"].astype(x.dtype)


def vit_block(x, *weights, dims: ModelDims):
    """Encoder block: x [S, E] -> (out [S, E],) (non-causal MHSA)."""
    w = dict(zip([n for n, _ in BLOCK_WEIGHT_SCHEMA], weights))
    y, _, _ = _mha(x, w, dims, causal=False)
    return (_mlp(y, w),)


def gpt_block_nar(x, *weights, dims: ModelDims):
    """Decoder block in NAR/prefill mode.

    x [S, E] -> (out [S, E], k [H, S, P], v [H, S, P]); the caller stores
    k/v in the KV cache for subsequent AR steps.
    """
    w = dict(zip([n for n, _ in BLOCK_WEIGHT_SCHEMA], weights))
    y, k, v = _mha(x, w, dims, causal=True)
    return _mlp(y, w), k, v


def gpt_block_ar(x, k_cache, v_cache, kv_len, *weights, dims: ModelDims):
    """Decoder block in AR/decode mode for a single new token.

    x:        [1, E]           the new token's activations
    k_cache:  [H, Smax, P]     fixed-capacity cache (garbage beyond kv_len)
    v_cache:  [H, Smax, P]
    kv_len:   i32 scalar       number of valid cache entries (tokens so far)

    Returns (out [1, E], k_cache', v_cache') with the new K/V written at
    position kv_len. The attention is the paper's AR matrix-vector path:
    one query row against kv_len+1 keys; invalid cache slots are masked.
    A single fixed-Smax artifact serves every decode step, so the Rust
    coordinator keeps one executable and two flat buffers per block.
    """
    w = dict(zip([n for n, _ in BLOCK_WEIGHT_SCHEMA], weights))
    h = ln_k.layernorm(x, w["ln1_g"], w["ln1_b"])
    q = _split_heads(gemm_k.gemm(h, w["wq"]), dims.heads, dims.p)   # [H,1,P]
    k_new = _split_heads(gemm_k.gemm(h, w["wk"]), dims.heads, dims.p)
    v_new = _split_heads(gemm_k.gemm(h, w["wv"]), dims.heads, dims.p)
    # KV-cache append at kv_len (paper Sec. II-B: K/V of previous tokens are
    # stored to avoid recomputation).
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, kv_len, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, kv_len, 0))
    smax = k_cache.shape[1]
    # One query against kv_len+1 keys, masked fp32 softmax (paper keeps
    # softmax in FP32 in every precision variant).
    scale = 1.0 / float(dims.p) ** 0.5
    s = jnp.einsum("hqp,hkp->hqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale       # [H,1,Smax]
    valid = jnp.arange(smax) <= kv_len                        # current token included
    s = jnp.where(valid[None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p_ = jnp.exp(s - m)
    a = p_ / jnp.sum(p_, axis=-1, keepdims=True)
    o = jnp.einsum("hqk,hkp->hqp", a, v_cache.astype(jnp.float32)).astype(x.dtype)
    att = gemm_k.gemm(_merge_heads(o), w["wo"])
    y = x + att
    return _mlp(y, w), k_cache, v_cache


def gpt_head(x, ln_g, ln_b, w_head):
    """Final LayerNorm + LM head: x [1, E] -> (logits [1, V],)."""
    h = ln_k.layernorm(x, ln_g, ln_b)
    return (gemm_k.gemm(h, w_head),)
