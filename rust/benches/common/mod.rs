//! Shared mini-harness for the paper-reproduction benches.
//!
//! criterion is unavailable in the offline registry, so each bench is a
//! plain `fn main` that (a) regenerates one paper table/figure from the
//! simulator and prints it side-by-side with the paper's numbers, and
//! (b) wall-clock-times the simulator hot path driving it (median of N
//! runs) so `cargo bench` still tracks performance regressions.

use std::time::Instant;

/// Median wall-clock seconds of `f` over `n` runs (after one warmup).
pub fn time_median<T>(n: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut out = f(); // warmup
    let mut samples: Vec<f64> = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        out = f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[samples.len() / 2], out)
}

/// Print a bench timing line in a stable grep-able format.
pub fn report_timing(name: &str, seconds: f64) {
    println!("bench-timing {name}: {:.3} ms/iter", seconds * 1e3);
}

/// Print the paper-vs-measured header for a figure/table.
pub fn header(id: &str, what: &str) {
    println!("==== {id}: {what} ====");
}
