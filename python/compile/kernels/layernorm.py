"""LayerNorm Pallas kernel (paper Sec. V-A3).

The paper tiles LayerNorm spatially on the row dimension across clusters and
normalizes the rows of each block in parallel on the 8 compute cores, with
the width-wise accumulations running on SSR+FREP. The Pallas grid mirrors
the row-block tiling; statistics are computed in fp32 (SIMD lanes only help
the elementwise scale/shift, as in the paper's low-precision variants).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import pick_block


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)



@functools.partial(jax.jit, static_argnames=("eps", "br"))
def layernorm(x, gamma, beta, eps=1e-5, br=64):
    """Row-normalize x: [S, E] with per-feature gamma/beta: [E]."""
    s, e = x.shape
    br = pick_block(s, br)
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(s // br,),
        in_specs=[
            pl.BlockSpec((br, e), lambda i: (i, 0)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, e), x.dtype),
        interpret=True,
    )(x, gamma, beta)
