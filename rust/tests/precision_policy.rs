//! End-to-end invariants of the split-precision serving policy
//! (`--kv-format`, `--class-precision`):
//!
//! * the degenerate policy (KV format = compute format, trivial ladder)
//!   is bit-identical to the legacy single-scalar precision across the
//!   single-engine, replicated, sharded, disaggregated and faulted
//!   serving paths;
//! * a narrow KV cache strictly improves residency (fewer preemptions,
//!   higher batch occupancy) on a KV-pressured trace at an identical
//!   byte budget;
//! * dequant-on-read work is billed under its own kernel class exactly
//!   when the policy splits the formats, and never otherwise;
//! * the layer-cost memo keys the (compute, kv) precision pair, so
//!   ladder rungs sharing a shape never alias each other's prices;
//! * fleet merges reject reports served under different policies.

use snitch_fm::arch::{FpFormat, PlatformConfig, PrecisionPolicy};
use snitch_fm::coordinator::{
    kv_requant_layer, model_total_mixed_by_kind, model_total_mixed_policy_by_kind,
    BatcherConfig, ClassLadder, ContinuousBatcher, FaultPlan, LayerCostCache, Workload,
};
use snitch_fm::model::{LayerKind, ModelConfig};
use snitch_fm::parallel::{
    merge_reports, serve_disaggregated_with_faults, serve_replicated_with_faults,
    RoutePolicy, ShardPlan,
};

fn pressured_workload() -> Workload {
    Workload::synthetic(0x9C1A, 24, (16, 96), (8, 48))
        .with_poisson_arrivals(0x51ED, 1200.0)
}

#[test]
fn degenerate_policy_is_bit_identical_single_engine() {
    // Spelling the policy out (`kv_format` = base format, empty ladder)
    // must reproduce the legacy run bit-for-bit, counters and
    // per-request stats included.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let w = pressured_workload();
    for fmt in [FpFormat::Fp32, FpFormat::Fp16, FpFormat::Fp8] {
        let mut opts = BatcherConfig::new(4, 0);
        opts.prefill_chunk = 24;
        let legacy = ContinuousBatcher::new(&cfg, &p, fmt, opts.clone()).run(&w);
        let mut explicit = opts.clone();
        explicit.kv_format = Some(fmt);
        explicit.class_precision = ClassLadder::parse("").unwrap();
        let spelled = ContinuousBatcher::new(&cfg, &p, fmt, explicit).run(&w);
        assert!(
            legacy.same_outcome(&spelled),
            "{fmt}: explicit degenerate policy must be bit-identical"
        );
        assert_eq!(spelled.kv_format, fmt.name());
        assert_eq!(spelled.class_precision, "");
    }
}

#[test]
fn degenerate_policy_is_bit_identical_replicated_sharded_disagg_faulted() {
    let cfg = ModelConfig::tiny();
    let w = pressured_workload();
    let faults = FaultPlan::parse("stall@0.001:40000,die@0.003", 7).unwrap();

    // Replicated fleet, fault plan armed.
    let p2 = PlatformConfig::with_dies(2);
    let mut opts = BatcherConfig::new(4, 0);
    opts.prefill_chunk = 16;
    let legacy = serve_replicated_with_faults(
        &cfg, &p2, FpFormat::Fp16, opts.clone(), &w, 2,
        RoutePolicy::JoinShortestQueue, &faults,
    );
    let mut explicit = opts.clone();
    explicit.kv_format = Some(FpFormat::Fp16);
    let spelled = serve_replicated_with_faults(
        &cfg, &p2, FpFormat::Fp16, explicit, &w, 2,
        RoutePolicy::JoinShortestQueue, &faults,
    );
    assert!(legacy.merged.same_outcome(&spelled.merged));
    for (a, b) in legacy.per_replica.iter().zip(&spelled.per_replica) {
        assert!(a.same_outcome(b), "per-replica schedules must match");
    }

    // Tensor-parallel sharded replica.
    let mut sharded = BatcherConfig::new(4, 0);
    sharded.plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
    let mut sharded_explicit = sharded.clone();
    sharded_explicit.kv_format = Some(FpFormat::Fp16);
    let a = ContinuousBatcher::new(&cfg, &p2, FpFormat::Fp16, sharded).run(&w);
    let b = ContinuousBatcher::new(&cfg, &p2, FpFormat::Fp16, sharded_explicit).run(&w);
    assert!(a.same_outcome(&b), "sharded degenerate policy must be bit-identical");

    // Disaggregated prefill/decode fleet.
    let legacy_d = serve_disaggregated_with_faults(
        &cfg, &p2, FpFormat::Fp16, opts.clone(), &w, 1, 1,
        RoutePolicy::JoinShortestQueue, &FaultPlan::off(),
    );
    let mut explicit_d = opts.clone();
    explicit_d.kv_format = Some(FpFormat::Fp16);
    let spelled_d = serve_disaggregated_with_faults(
        &cfg, &p2, FpFormat::Fp16, explicit_d, &w, 1, 1,
        RoutePolicy::JoinShortestQueue, &FaultPlan::off(),
    );
    assert!(legacy_d.prefill.same_outcome(&spelled_d.prefill));
    assert!(legacy_d.decode.same_outcome(&spelled_d.decode));
    assert_eq!(legacy_d.migrations, spelled_d.migrations);
    assert_eq!(legacy_d.migrated_kv_bytes, spelled_d.migrated_kv_bytes);
    assert_eq!(legacy_d.migration_cycles, spelled_d.migration_cycles);
}

#[test]
fn narrow_kv_improves_residency_at_equal_budget() {
    // FP16 compute either way; the only difference is the KV pool
    // density. At an identical byte budget the FP8 cache holds twice the
    // tokens, so the pressured trace preempts less and keeps more
    // requests resident. Compute pricing is unchanged (the kernels bill
    // at the compute format), so the win is purely residency.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let w = Workload::uniform(16, 32, 48);
    let budget = snitch_fm::coordinator::Request::new(0, 32, 48)
        .kv_bytes_at(&cfg, FpFormat::Fp16)
        * 3;
    let mut wide = BatcherConfig::new(8, budget);
    wide.page_tokens = 8;
    let mut narrow = wide.clone();
    narrow.kv_format = Some(FpFormat::Fp8);
    let rw = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp16, wide).run(&w);
    let rn = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp16, narrow).run(&w);
    assert_eq!(rw.completed, 16);
    assert_eq!(rn.completed, 16);
    assert_eq!(rw.kv_budget_bytes, rn.kv_budget_bytes, "same byte budget");
    assert!(
        rn.total_pages > rw.total_pages,
        "narrow KV carves more pages from the same bytes"
    );
    assert!(
        rw.preemptions > 0,
        "the trace must actually pressure the wide pool ({} preemptions)",
        rw.preemptions
    );
    assert!(
        rn.preemptions < rw.preemptions,
        "fp8 KV {} vs fp16 KV {} preemptions",
        rn.preemptions,
        rw.preemptions
    );
    assert!(
        rn.avg_batch_occupancy > rw.avg_batch_occupancy,
        "fp8 KV {} vs fp16 KV {} occupancy",
        rn.avg_batch_occupancy,
        rw.avg_batch_occupancy
    );
    assert_eq!(rn.kv_format, "fp8");
    assert_eq!(rn.format, "fp16");
}

#[test]
fn dequant_billed_as_kernel_class_iff_conversion_active() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let prefills = [(48u64, 0u64), (16, 8)];
    let decode_kv = [64u64, 128];

    // Degenerate policy: no KvDequant cycles, totals bit-identical to
    // the legacy uniform walk.
    let mut costs = LayerCostCache::new(&p);
    let (legacy, legacy_kinds) = model_total_mixed_by_kind(
        &mut costs, &cfg, &prefills, &decode_kv, FpFormat::Fp16, &p,
    );
    let (uni, uni_kinds) = model_total_mixed_policy_by_kind(
        &mut costs, &cfg, &prefills, &decode_kv,
        PrecisionPolicy::uniform(FpFormat::Fp16), &p,
    );
    assert_eq!(legacy.cycles, uni.cycles);
    assert_eq!(legacy_kinds, uni_kinds);
    assert_eq!(uni_kinds.get(LayerKind::KvDequant), 0);

    // Split policy: the same pass gains a nonzero KvDequant bucket and
    // every other bucket is untouched (the conversion tax is additive).
    let split = PrecisionPolicy {
        weights: FpFormat::Fp16,
        compute: FpFormat::Fp16,
        kv: FpFormat::Fp8,
    };
    assert!(split.validity_error().is_none());
    let (tot, kinds) = model_total_mixed_policy_by_kind(
        &mut costs, &cfg, &prefills, &decode_kv, split, &p,
    );
    assert!(kinds.get(LayerKind::KvDequant) > 0);
    assert_eq!(
        tot.cycles - kinds.get(LayerKind::KvDequant),
        uni.cycles,
        "dequant is an additive tax on the uniform pass"
    );
    for kind in [
        LayerKind::Gemm,
        LayerKind::FlashAttention,
        LayerKind::FusedConcatLinear,
        LayerKind::Layernorm,
        LayerKind::Gelu,
    ] {
        assert_eq!(kinds.get(kind), uni_kinds.get(kind), "{kind:?}");
    }
}

#[test]
fn layer_memo_keys_the_precision_pair() {
    // The same requant shape priced under two policies must occupy two
    // memo slots with different prices — rungs never alias.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let layer = kv_requant_layer(&cfg, &[(32, 0)], &[96]).expect("nonempty pass");
    let mut costs = LayerCostCache::new(&p);
    costs.ensure_platform(&p);
    let same = costs.layer_cost_kv(&layer, FpFormat::Fp16, FpFormat::Fp16, &p);
    let split = costs.layer_cost_kv(&layer, FpFormat::Fp16, FpFormat::Fp8, &p);
    let split32 = costs.layer_cost_kv(&layer, FpFormat::Fp32, FpFormat::Fp8, &p);
    assert_eq!(same.cycles, 0, "kv == compute converts nothing");
    assert!(split.cycles > 0);
    assert!(split32.cycles >= split.cycles);
    assert_eq!(costs.len(), 3, "three precision pairs, three memo slots");
    // A repeat probe hits the memo, not a fresh pricing.
    let again = costs.layer_cost_kv(&layer, FpFormat::Fp16, FpFormat::Fp8, &p);
    assert_eq!(again, split);
    assert_eq!(costs.len(), 3);
}

#[test]
fn class_ladder_rungs_price_differently_and_report_their_spec() {
    // Two copies of one trace, classes split 0/1. With `hi` buying FP32
    // compute on an FP16 engine, the run must cost strictly more than
    // the flat FP16 run (same schedule shape, wider rung on half the
    // passes) and the report must carry the canonical spec.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let mut w = Workload::uniform(8, 32, 16);
    for (i, r) in w.requests.iter_mut().enumerate() {
        if i % 2 == 1 {
            *r = r.clone().with_class(1);
        }
    }
    let flat = BatcherConfig::new(4, 0);
    let mut laddered = flat.clone();
    laddered.class_precision = ClassLadder::parse("hi:fp32").unwrap();
    let rf = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp16, flat).run(&w);
    let rl = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp16, laddered).run(&w);
    assert_eq!(rf.completed, 8);
    assert_eq!(rl.completed, 8);
    assert_eq!(rl.class_precision, "hi:fp32");
    assert_eq!(rf.class_precision, "");
    assert!(
        rl.total_cycles > rf.total_cycles,
        "fp32 rung must cost more than flat fp16 ({} vs {})",
        rl.total_cycles,
        rf.total_cycles
    );
    // Canonical spec round-trips through the parser.
    let reparsed = ClassLadder::parse(&rl.class_precision).unwrap();
    assert_eq!(reparsed.to_spec(), rl.class_precision);
}

#[test]
fn ladder_rungs_validate_against_the_kv_lattice() {
    // An fp8 bulk rung over an fp16 KV cache would widen the cache past
    // the rung's compute format — rejected up front, spec unchanged.
    let err = ClassLadder::parse("lo:fp9");
    assert!(err.is_err(), "unknown format must be rejected");
    let lad = ClassLadder::parse("lo:fp8").unwrap();
    let bad = PrecisionPolicy {
        weights: FpFormat::Fp16,
        compute: lad.rung_for(1, FpFormat::Fp16),
        kv: FpFormat::Fp16,
    };
    assert!(bad.validity_error().is_some());
    // The same rung over an fp8 KV cache is legal.
    let good = PrecisionPolicy { kv: FpFormat::Fp8, ..bad };
    assert!(good.validity_error().is_none());
}

#[test]
#[should_panic(expected = "cannot be merged")]
fn merge_rejects_cross_policy_reports() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::occamy();
    let w = Workload::uniform(4, 16, 8);
    let a = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp16, BatcherConfig::new(2, 0))
        .run(&w);
    let mut opts = BatcherConfig::new(2, 0);
    opts.kv_format = Some(FpFormat::Fp8);
    let b = ContinuousBatcher::new(&cfg, &p, FpFormat::Fp16, opts).run(&w);
    let _ = merge_reports(&[a, b], FpFormat::Fp16, &p);
}
