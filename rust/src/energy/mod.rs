//! Activity-based power/energy model (paper Table III).
//!
//! Calibrated against the silicon measurements of Table III, which are
//! remarkably well fit by a single linear law across all four precisions
//! and both modes:
//!
//! ```text
//!   P [W] ~= P_STATIC + P_ACTIVE * fpu_utilization
//! ```
//!
//! (FP32: (8.6%, 2.2 W) and (79.7%, 5.2 W) give P = 1.84 + 4.22*u; the
//! other three precisions fit within 0.06 W of the same line.) The model
//! therefore uses the mean fit constants and derives GFLOPS/W from the
//! simulated utilization — the substitution for the paper's physical
//! power measurement (DESIGN.md §1).

use crate::arch::{FpFormat, PlatformConfig};
use crate::metrics;
use crate::sim::KernelCost;

/// Idle/static platform power (W): clock tree, SPM leakage, NoC idle.
pub const P_STATIC_W: f64 = 1.78;
/// Dynamic power at 100% FPU utilization minus static (W).
pub const P_ACTIVE_W: f64 = 4.25;

/// Power/efficiency summary for one run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerReport {
    pub power_w: f64,
    pub gflops_per_w: f64,
    pub fpu_utilization: f64,
    pub energy_j: f64,
}

/// Estimate power and efficiency for a priced run.
pub fn power_report(
    cost: &KernelCost,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> PowerReport {
    let util = metrics::fpu_utilization(cost, fmt, platform);
    let power = P_STATIC_W + P_ACTIVE_W * util;
    let gflops = metrics::achieved_gflops(cost, platform);
    let seconds = platform.cycles_to_seconds(cost.cycles);
    PowerReport {
        power_w: power,
        gflops_per_w: if power > 0.0 { gflops / power } else { 0.0 },
        fpu_utilization: util,
        energy_j: power * seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn calibration_matches_table3_fp32_nar() {
        // Synthetic run at exactly the paper's FP32 NAR utilization
        // (79.7%) must land near 5.2 W and 78.8 GFLOPS/W.
        let p = occ();
        let peak = p.peak_gflops(FpFormat::Fp32); // 512
        let util = 0.797;
        let cycles = 1_000_000u64;
        let flops = (peak * util * cycles as f64 / p.freq_ghz) as u64;
        let cost = KernelCost { cycles, flops, ..Default::default() };
        let r = power_report(&cost, FpFormat::Fp32, &p);
        assert!((r.power_w - 5.2).abs() < 0.15, "power {}", r.power_w);
        assert!((r.gflops_per_w - 78.8).abs() < 4.0, "eff {}", r.gflops_per_w);
    }

    #[test]
    fn calibration_matches_table3_fp8_nar() {
        let p = occ();
        let peak = p.peak_gflops(FpFormat::Fp8); // 2048
        let util = 0.652;
        let cycles = 1_000_000u64;
        let flops = (peak * util * cycles as f64 / p.freq_ghz) as u64;
        let r = power_report(
            &KernelCost { cycles, flops, ..Default::default() },
            FpFormat::Fp8,
            &p,
        );
        assert!((r.power_w - 4.5).abs() < 0.15, "power {}", r.power_w);
        assert!((r.gflops_per_w - 294.0).abs() < 15.0, "eff {}", r.gflops_per_w);
    }

    #[test]
    fn calibration_matches_table3_ar() {
        // AR FP32: util 8.46% -> ~2.2 W, ~20.1 GFLOPS/W.
        let p = occ();
        let peak = p.peak_gflops(FpFormat::Fp32);
        let util = 0.0846;
        let cycles = 1_000_000u64;
        let flops = (peak * util * cycles as f64 / p.freq_ghz) as u64;
        let r = power_report(
            &KernelCost { cycles, flops, ..Default::default() },
            FpFormat::Fp32,
            &p,
        );
        assert!((r.power_w - 2.2).abs() < 0.15, "power {}", r.power_w);
        assert!((r.gflops_per_w - 20.1).abs() < 2.0, "eff {}", r.gflops_per_w);
    }

    #[test]
    fn energy_integrates_power() {
        let p = occ();
        let cost = KernelCost { cycles: 1_000_000_000, flops: 0, ..Default::default() };
        let r = power_report(&cost, FpFormat::Fp32, &p);
        // 1 s at idle power.
        assert!((r.energy_j - P_STATIC_W).abs() < 1e-9);
    }
}
