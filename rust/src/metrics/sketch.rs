//! Mergeable streaming percentile sketch for fleet-scale serving runs.
//!
//! The serving report used to keep every latency sample in a `Vec<f64>`
//! until the end of the run, which is fine for 50k requests and fatal
//! for the 1M-request fleet traces: 64 replicas x 1M samples x 3 metrics
//! is gigabytes of `f64`s that exist only to answer a handful of
//! percentile queries. [`StreamSketch`] replaces that with a two-mode
//! structure:
//!
//! * **Exact mode** (n <= [`EXACT_LIMIT`]): samples are kept verbatim and
//!   percentiles are answered by the same nearest-rank rule as
//!   [`crate::metrics::Percentiles`], so every existing small-trace test
//!   keeps passing *bit-exactly*. Merging two exact sketches whose
//!   combined size still fits stays exact (percentiles depend only on the
//!   sample multiset, so merge order is irrelevant).
//! * **Histogram mode** (n > [`EXACT_LIMIT`], or merged beyond it): a
//!   fixed-size log-spaced histogram. Bucket `i` covers
//!   `[MIN_TRACKABLE * GAMMA^i, MIN_TRACKABLE * GAMMA^(i+1))` and queries
//!   return the geometric midpoint of the winning bucket, clamped to the
//!   exact observed `[min, max]`.
//!
//! # Error bounds
//!
//! With `GAMMA = 1.02`, any sample in `[MIN_TRACKABLE, MAX_TRACKABLE]`
//! lands in a bucket whose representative value is within a factor
//! `sqrt(GAMMA)` of the true sample, i.e. a **relative error of at most
//! ~1%** (`sqrt(1.02) - 1 ~= 0.995%`) on every quantile. Samples below
//! `MIN_TRACKABLE` (1 ns — far below a single simulator cycle) collapse
//! into an underflow bucket reported as `min`; samples above
//! `MAX_TRACKABLE` clamp into the last bucket and are reported as at
//! most `max`. Counts, `sum`, `min` and `max` are always exact, so
//! `mean()` is exact in both modes. Merging histograms adds bucket
//! counts and is exact with respect to the already-bucketed data:
//! merge order never changes any answer.

use super::Percentiles;

/// Largest sample count served in exact mode. Every trace the unit-test
/// suite replays sits far below this, which is what keeps the sketch
/// drop-in bit-compatible with the old sort-everything path.
pub const EXACT_LIMIT: usize = 4096;

/// Log-histogram growth factor; relative error is `sqrt(GAMMA) - 1`.
const GAMMA: f64 = 1.02;
/// Smallest distinguishable sample: 1 ns (sub-cycle at 1 GHz).
const MIN_TRACKABLE: f64 = 1e-9;
/// Bucket count. `MIN_TRACKABLE * GAMMA^2176 ~= 5e9` seconds, so the
/// dynamic range spans one nanosecond to ~160 simulated years.
const NUM_BINS: usize = 2176;

#[derive(Debug, Clone, PartialEq)]
enum Repr {
    /// Raw samples, insertion order (queries sort a copy).
    Exact(Vec<f64>),
    /// Fixed log-spaced histogram plus exact moments.
    Hist {
        bins: Vec<u64>,
        /// Samples `< MIN_TRACKABLE` (zeros, negatives, non-finite).
        underflow: u64,
        count: u64,
        sum: f64,
        min: f64,
        max: f64,
    },
}

/// Mergeable streaming percentile sketch (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSketch {
    repr: Repr,
}

impl Default for StreamSketch {
    fn default() -> Self {
        StreamSketch::new()
    }
}

fn bin_index(x: f64) -> Option<usize> {
    if x.is_nan() || x < MIN_TRACKABLE {
        return None; // underflow (zeros, negatives, NaN)
    }
    let i = ((x / MIN_TRACKABLE).ln() / GAMMA.ln()).floor() as usize;
    Some(i.min(NUM_BINS - 1))
}

fn bin_value(i: usize) -> f64 {
    // Geometric midpoint of bucket i: off by at most sqrt(GAMMA).
    MIN_TRACKABLE * GAMMA.powi(i as i32) * GAMMA.sqrt()
}

impl StreamSketch {
    pub fn new() -> StreamSketch {
        StreamSketch { repr: Repr::Exact(Vec::new()) }
    }

    /// Build a sketch from a sample slice (exact if it fits).
    pub fn from_samples(xs: &[f64]) -> StreamSketch {
        let mut s = StreamSketch::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    /// True while every sample is still held verbatim.
    pub fn is_exact(&self) -> bool {
        matches!(self.repr, Repr::Exact(_))
    }

    pub fn count(&self) -> u64 {
        match &self.repr {
            Repr::Exact(v) => v.len() as u64,
            Repr::Hist { count, .. } => *count,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Record one sample; spills exact -> histogram past [`EXACT_LIMIT`].
    pub fn push(&mut self, x: f64) {
        match &mut self.repr {
            Repr::Exact(v) => {
                v.push(x);
                if v.len() > EXACT_LIMIT {
                    self.spill();
                }
            }
            Repr::Hist { .. } => self.hist_push(x),
        }
    }

    fn spill(&mut self) {
        let samples = match std::mem::replace(&mut self.repr, Repr::Exact(Vec::new())) {
            Repr::Exact(v) => v,
            hist => {
                self.repr = hist;
                return;
            }
        };
        self.repr = Repr::Hist {
            bins: vec![0; NUM_BINS],
            underflow: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        };
        for x in samples {
            self.hist_push(x);
        }
    }

    fn hist_push(&mut self, x: f64) {
        let Repr::Hist { bins, underflow, count, sum, min, max } = &mut self.repr else {
            unreachable!("hist_push on exact repr");
        };
        match bin_index(x) {
            Some(i) => bins[i] += 1,
            None => *underflow += 1,
        }
        *count += 1;
        if x.is_finite() {
            *sum += x;
            *min = min.min(x);
            *max = max.max(x);
        }
    }

    /// Fold another sketch in. Exact + exact stays exact while the
    /// combined sample count fits [`EXACT_LIMIT`]; anything bigger (or
    /// already spilled) merges as histograms by adding bucket counts.
    /// The result is independent of merge order in both modes.
    pub fn merge(&mut self, other: &StreamSketch) {
        if let (Repr::Exact(a), Repr::Exact(b)) = (&self.repr, &other.repr) {
            if a.len() + b.len() <= EXACT_LIMIT {
                let Repr::Exact(a) = &mut self.repr else { unreachable!() };
                a.extend_from_slice(b);
                return;
            }
        }
        if self.is_exact() {
            self.spill();
        }
        let mut other = other.clone();
        if other.is_exact() {
            other.spill();
        }
        let Repr::Hist { bins, underflow, count, sum, min, max } = &mut self.repr else {
            unreachable!()
        };
        let Repr::Hist {
            bins: ob,
            underflow: ou,
            count: oc,
            sum: os,
            min: omin,
            max: omax,
        } = &other.repr
        else {
            unreachable!()
        };
        for (b, o) in bins.iter_mut().zip(ob) {
            *b += o;
        }
        *underflow += ou;
        *count += oc;
        *sum += os;
        *min = min.min(*omin);
        *max = max.max(*omax);
    }

    /// Nearest-rank percentile (`q` in 0..=100); 0 for an empty sketch.
    /// Exact mode reproduces [`Percentiles::p`] bit-for-bit; histogram
    /// mode is within ~1% relative error (see module docs).
    pub fn p(&self, q: f64) -> f64 {
        match &self.repr {
            Repr::Exact(v) => Percentiles::new(v.clone()).p(q),
            Repr::Hist { bins, underflow, count, min, max, .. } => {
                if *count == 0 {
                    return 0.0;
                }
                let rank = (q / 100.0 * *count as f64).ceil() as u64;
                let rank = rank.clamp(1, *count);
                let mut seen = *underflow;
                if rank <= seen {
                    // Underflow bucket: every sample there is < 1 ns, so
                    // the observed min is the best available answer.
                    return if min.is_finite() { *min } else { 0.0 };
                }
                for (i, n) in bins.iter().enumerate() {
                    seen += n;
                    if rank <= seen {
                        let v = bin_value(i);
                        // Never report outside the observed range.
                        return v.clamp(*min, *max);
                    }
                }
                *max
            }
        }
    }

    /// Exact arithmetic mean over finite samples; 0 when empty. The
    /// exact arm sums in *sorted* order — exactly what the report's old
    /// `Percentiles::mean` did — so small-trace means stay bit-identical
    /// to the pre-sketch code (f64 addition is order-sensitive in the
    /// last ulp).
    pub fn mean(&self) -> f64 {
        match &self.repr {
            Repr::Exact(v) => Percentiles::new(v.clone()).mean(),
            Repr::Hist { count, sum, .. } => {
                if *count == 0 {
                    0.0
                } else {
                    sum / *count as f64
                }
            }
        }
    }

    /// Exact observed maximum over finite samples; 0 when empty.
    pub fn max(&self) -> f64 {
        let m = match &self.repr {
            Repr::Exact(v) => v
                .iter()
                .copied()
                .filter(|x| x.is_finite())
                .fold(f64::NEG_INFINITY, f64::max),
            Repr::Hist { max, .. } => *max,
        };
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_mode_matches_percentiles_bitwise() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 7919) % 1000) as f64 / 3.0).collect();
        let sk = StreamSketch::from_samples(&xs);
        assert!(sk.is_exact());
        let p = Percentiles::new(xs.clone());
        for q in [0.0, 1.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(sk.p(q), p.p(q), "q={q}");
        }
        assert_eq!(sk.mean(), p.mean());
        assert_eq!(sk.count(), 1000);
    }

    #[test]
    fn exact_merge_stays_exact_and_order_free() {
        let a: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..100).map(|i| (i * 3) as f64).collect();
        let mut ab = StreamSketch::from_samples(&a);
        ab.merge(&StreamSketch::from_samples(&b));
        let mut ba = StreamSketch::from_samples(&b);
        ba.merge(&StreamSketch::from_samples(&a));
        assert!(ab.is_exact() && ba.is_exact());
        let mut union = a.clone();
        union.extend_from_slice(&b);
        let p = Percentiles::new(union);
        for q in [10.0, 50.0, 95.0, 99.0] {
            assert_eq!(ab.p(q), p.p(q));
            assert_eq!(ab.p(q), ba.p(q));
        }
    }

    #[test]
    fn spills_past_limit_and_bounds_error() {
        let xs: Vec<f64> = (1..=20_000).map(|i| i as f64 * 1e-4).collect();
        let sk = StreamSketch::from_samples(&xs);
        assert!(!sk.is_exact());
        assert_eq!(sk.count(), 20_000);
        let p = Percentiles::new(xs);
        for q in [1.0, 10.0, 50.0, 90.0, 99.0, 99.9] {
            let exact = p.p(q);
            let approx = sk.p(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.011, "q={q}: exact {exact}, sketch {approx}, rel {rel}");
        }
        // Moments stay exact.
        assert!((sk.mean() - p.mean()).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_matches_single_stream() {
        let a: Vec<f64> = (1..=10_000).map(|i| (i as f64).sqrt()).collect();
        let b: Vec<f64> = (1..=10_000).map(|i| (i as f64).ln().max(1e-6)).collect();
        let mut merged = StreamSketch::from_samples(&a);
        merged.merge(&StreamSketch::from_samples(&b));
        let mut single = StreamSketch::from_samples(&a);
        for &x in &b {
            single.push(x);
        }
        for q in [5.0, 50.0, 95.0, 99.9] {
            assert_eq!(merged.p(q), single.p(q), "q={q}");
        }
        assert_eq!(merged.count(), single.count());
    }

    #[test]
    fn underflow_and_empty_are_sane() {
        let empty = StreamSketch::new();
        assert_eq!(empty.p(50.0), 0.0);
        assert_eq!(empty.mean(), 0.0);
        assert!(empty.is_empty());

        let mut sk = StreamSketch::new();
        for _ in 0..(EXACT_LIMIT + 10) {
            sk.push(0.0);
        }
        assert!(!sk.is_exact());
        assert_eq!(sk.p(99.0), 0.0);
        assert_eq!(sk.mean(), 0.0);
    }

    #[test]
    fn quantiles_clamped_to_observed_range() {
        let xs: Vec<f64> = (0..(EXACT_LIMIT as u64 + 100)).map(|i| 1.0 + i as f64 * 1e-6).collect();
        let sk = StreamSketch::from_samples(&xs);
        let lo = xs[0];
        let hi = xs[xs.len() - 1];
        for q in [0.0, 50.0, 100.0] {
            let v = sk.p(q);
            assert!((lo..=hi).contains(&v), "q={q} -> {v} outside [{lo}, {hi}]");
        }
    }
}
