"""FlashAttention-2 forward pass as a Pallas kernel (paper Sec. V-A2).

The paper maps one attention head to one Snitch cluster; within the cluster
the FA-2 KV-tile loop runs time-iteratively with the running row statistics
(m, l) and the output accumulator resident in the 128 kB SPM. The BlockSpec
grid below expresses exactly that schedule:

  grid = (heads, Sq/bq, Skv/bkv)   -- kv axis innermost / sequential

with per-(head, q-tile) scratch carrying (acc, m, l) across kv steps — the
SPM-resident state of the paper's dataflow. Softmax statistics are computed
in fp32 regardless of the i/o dtype, matching the paper's FP32 softmax
island inside FP16/FP8 attention (conversions at the QK^T output and before
the A@V GEMM).

interpret=True: CPU PJRT cannot execute Mosaic custom calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import pick_block
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # large-negative instead of -inf: keeps FP16 masks finite


def _fa2_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale, kv_tiles, bq, bkv, causal, skv_total, sq_total):
    """One (head, q-tile) FA-2 state machine stepped over kv tiles."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)   # [bq, P]
    k = k_ref[0].astype(jnp.float32)   # [bkv, P]
    v = v_ref[0].astype(jnp.float32)   # [bkv, P]

    # S tile = scaled Q K^T, in fp32 (paper: conversion after QK^T GEMM).
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bkv]

    if causal:
        # Global positions: query row r -> qi*bq + r (+ offset when the
        # query block is a suffix of the kv sequence, i.e. AR decode).
        offset = skv_total - sq_total
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + offset
        k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    # Online softmax update (FlashAttention-2, Alg. 1).
    m_prev = m_ref[...]                        # [bq]
    m_cur = jnp.max(s, axis=-1)                # [bq]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])            # [bq, bkv]
    alpha = jnp.exp(m_prev - m_new)            # rescale of previous state
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    # Paper: convert P back to the low-precision io dtype before the A@V
    # GEMM so it runs on the SIMD lanes; accumulate fp32.
    p_lp = p.astype(o_ref.dtype).astype(jnp.float32)
    acc_ref[...] = alpha[:, None] * acc_ref[...] + jnp.dot(
        p_lp, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == kv_tiles - 1)
    def _finalize():
        # Rows that attended to nothing (fully masked) get 0, not NaN.
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)



@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv"))
def flash_attention(q, k, v, causal=False, bq=64, bkv=64):
    """Multi-head FA-2 forward. q: [H, Sq, P], k/v: [H, Skv, P] -> [H, Sq, P].

    The H grid axis is the paper's head->cluster spatial mapping; bq/bkv are
    the SPM-resident temporal tiles.
    """
    h, sq, p = q.shape
    h2, skv, p2 = k.shape
    assert (h, p) == (h2, p2), "q/k head or projection mismatch"
    assert v.shape == k.shape, "k/v shape mismatch"
    bq = pick_block(sq, bq)
    bkv = pick_block(skv, bkv)
    kv_tiles = skv // bkv
    scale = 1.0 / float(p) ** 0.5
    grid = (h, sq // bq, kv_tiles)
    return pl.pallas_call(
        functools.partial(
            _fa2_kernel,
            scale=scale,
            kv_tiles=kv_tiles,
            bq=bq,
            bkv=bkv,
            causal=causal,
            skv_total=skv,
            sq_total=sq,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, p), lambda hh, qi, ki: (hh, qi, 0)),
            pl.BlockSpec((1, bkv, p), lambda hh, qi, ki: (hh, ki, 0)),
            pl.BlockSpec((1, bkv, p), lambda hh, qi, ki: (hh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, p), lambda hh, qi, ki: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, p), jnp.float32),  # output accumulator
            pltpu.VMEM((bq,), jnp.float32),    # running max m
            pltpu.VMEM((bq,), jnp.float32),    # running sum l
        ],
        interpret=True,
    )(q, k, v)


def spm_footprint_bytes(bq, bkv, p, itemsize):
    """SPM bytes for one cluster's double-buffered FA-2 tile set."""
    q_t = bq * p * itemsize
    kv_t = 2 * bkv * p * itemsize
    acc = bq * p * 4
    stats = 2 * bq * 4
    out = bq * p * itemsize
    return q_t + 2 * kv_t + acc + stats + out
