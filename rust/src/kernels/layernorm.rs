//! LayerNorm timing model (paper Sec. V-A3).
//!
//! Rows tile spatially across clusters; each cluster's 8 cores normalize
//! rows in parallel with SSR+FREP accumulations; statistics in FP32.

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::sim::cluster::{ClusterSim, TilePhase};
use crate::sim::core::{opcost, CoreModel};
use crate::sim::dma::Transfer;
use crate::sim::{KernelCost, MultiClusterSim};

/// Cost of layer-normalizing an `s x e` activation tensor.
pub fn layernorm_cost(s: u64, e: u64, fmt: FpFormat, platform: &PlatformConfig) -> KernelCost {
    if s == 0 || e == 0 {
        return KernelCost::default();
    }
    let clusters = platform.total_clusters() as u64;
    let core = CoreModel::new(platform.cluster, platform.features);
    let cores = platform.cluster.compute_cores;
    let el = fmt.bytes();
    let rows = s.div_ceil(clusters).max(1).min(s);
    let active = s.div_ceil(rows).min(clusters);

    // Temporal tiling if a row block exceeds the SPM budget (2 buffers +
    // output); rows are normalized independently so tiles split on rows.
    let spm = platform.cluster.spm_bytes;
    let bytes_per_row = e * el * 3; // in (x2 double buffer) + out
    let rows_per_tile = (spm / bytes_per_row.max(1)).clamp(1, rows);
    let tiles = rows.div_ceil(rows_per_tile);

    let mut phases = Vec::with_capacity(tiles as usize);
    for t in 0..tiles {
        let r = rows_per_tile.min(rows - t * rows_per_tile);
        let rows_per_core = r.div_ceil(cores);
        // Per row: mean (sum reduce), variance (fma reduce), then the
        // elementwise normalize (sub, mul-rsqrt, gamma/beta fma).
        let mut compute = 0;
        compute += rows_per_core * core.reduction_cycles(e, FpFormat::Fp32);
        compute += rows_per_core * core.reduction_cycles(e, FpFormat::Fp32);
        compute += rows_per_core
            * core.elementwise_cycles(e, opcost::SIMPLE * 3, fmt, true);
        // rsqrt per row (scalar).
        compute += rows_per_core * opcost::SQRT;
        if fmt.needs_fp32_conversion() {
            compute += 2 * rows_per_core * core.elementwise_cycles(e, opcost::CONVERT, fmt, true);
        }
        let flops = r * (2 * e + 2 * e + 3 * e);
        let phase = TilePhase::compute(compute, flops)
            .with_transfer(Transfer::d2(r * e * el, r, MemLevel::Hbm))
            .with_transfer(Transfer::d2(r * e * el, r, MemLevel::Hbm).to_write());
        phases.push(phase);
    }

    let csim = ClusterSim::new(platform).with_hbm_sharers(active);
    let one = csim.run(&phases);
    let sim = MultiClusterSim::new(platform);
    let per: Vec<KernelCost> = (0..active).map(|_| one).collect();
    sim.parallel(&per)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn layernorm_linear_in_rows() {
        let a = layernorm_cost(1024, 4096, FpFormat::Fp32, &occ());
        let b = layernorm_cost(2048, 4096, FpFormat::Fp32, &occ());
        let ratio = b.cycles as f64 / a.cycles as f64;
        assert!((1.6..=2.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn layernorm_is_cheap_vs_gemm() {
        // Fig. 10: activation layers have limited latency impact.
        use crate::kernels::gemm::{gemm_cost, OperandHome};
        let ln = layernorm_cost(1024, 4096, FpFormat::Fp32, &occ());
        let g = gemm_cost(1024, 4096, 4096, FpFormat::Fp32, &occ(), OperandHome::default());
        assert!(ln.cycles * 10 < g.cycles, "ln {} vs gemm {}", ln.cycles, g.cycles);
    }

    #[test]
    fn single_row_works() {
        let c = layernorm_cost(1, 4096, FpFormat::Fp32, &occ());
        assert!(c.cycles > 0);
        assert_eq!(c.flops, 7 * 4096);
    }

    #[test]
    fn traffic_reads_and_writes_tensor_once() {
        let c = layernorm_cost(1024, 1024, FpFormat::Fp32, &occ());
        assert_eq!(c.hbm_read_bytes, 1024 * 1024 * 4);
        assert_eq!(c.hbm_write_bytes, 1024 * 1024 * 4);
    }
}
