//! End-to-end autoregressive generation — the full system composed.
//!
//! A 2-block GPT (the `tiny` preset, same topology as GPT-J) runs entirely
//! in Rust on the request path:
//!
//!   prompt tokens -> embedding lookup (Rust)
//!     -> NAR prefill through the `gpt_block_nar_tiny` PJRT executable,
//!        filling the per-block KV caches (paper Sec. II-B)
//!     -> AR decode loop through `gpt_block_ar_tiny` (one token per step,
//!        fixed-capacity cache updated in place)
//!     -> `gpt_head_tiny` logits -> greedy argmax -> next token
//!
//! and reports both the *measured* tokens/s of the numeric path (CPU PJRT)
//! and the *simulated* tokens/s of the same workload on the 16-cluster
//! RISC-V platform. Python never runs.
//!
//! Run: `cargo run --release --example generate` (after `make artifacts`).

use anyhow::Result;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{InferenceEngine, KvCache};
use snitch_fm::model::ModelConfig;
use snitch_fm::runtime::{detgen, Arg, GenSpec, Runtime};

const BLOCKS: usize = 2;
const VOCAB: usize = 256;
const E: usize = 64;
const HEADS: usize = 4;
const P: usize = 16;
const SMAX: usize = 64;
const PROMPT_LEN: usize = 32; // = the NAR artifact's S
const GEN_TOKENS: usize = 24;

/// Per-block weights: the artifact takes weights as runtime arguments, so
/// each block gets its own deterministic tensors (same shapes/scales as
/// the manifest specs, block-specific seeds).
fn block_weights(rt: &Runtime, artifact: &str, skip: usize, block: usize) -> Result<Vec<Arg>> {
    let entry = rt.manifest.get(artifact)?;
    let mut out = Vec::new();
    for spec in entry.args.iter().skip(skip) {
        match &spec.gen {
            GenSpec::Det { seed, scale, offset } => {
                let seed = seed.wrapping_add(block as u32 * 0x0051_F0C1);
                let data =
                    detgen::det_f32(spec.element_count(), seed, *scale as f32, *offset as f32);
                let shape: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                out.push(Arg::F32(data, shape));
            }
            GenSpec::I32 { value } => out.push(Arg::I32(*value)),
        }
    }
    Ok(out)
}

fn main() -> Result<()> {
    let mut rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform_name());

    // Deterministic embedding table + per-block weights.
    let embed = detgen::det_f32(VOCAB * E, 0xE11B_ED01, 1.0, 0.0);
    let nar_weights: Vec<Vec<Arg>> = (0..BLOCKS)
        .map(|b| block_weights(&rt, "gpt_block_nar_tiny", 1, b))
        .collect::<Result<_>>()?;
    // AR artifact: args are [x, k_cache, v_cache, kv_len, weights...].
    let ar_weights: Vec<Vec<Arg>> = (0..BLOCKS)
        .map(|b| block_weights(&rt, "gpt_block_ar_tiny", 4, b))
        .collect::<Result<_>>()?;
    let head_args = block_weights(&rt, "gpt_head_tiny", 1, 0)?;

    // Prompt: deterministic pseudo-tokens.
    let prompt: Vec<usize> =
        (0..PROMPT_LEN).map(|i| detgen::hash32(i as u32) as usize % VOCAB).collect();
    let lookup = |tok: usize| -> Vec<f32> { embed[tok * E..(tok + 1) * E].to_vec() };

    // --- prefill (NAR) ----------------------------------------------------
    let t0 = std::time::Instant::now();
    let mut caches: Vec<KvCache> = (0..BLOCKS).map(|_| KvCache::new(HEADS, SMAX, P)).collect();
    let mut x: Vec<f32> = prompt.iter().flat_map(|&t| lookup(t)).collect();
    for (b, cache) in caches.iter_mut().enumerate() {
        let mut args = vec![Arg::f32(&x, &[PROMPT_LEN, E])];
        args.extend(nar_weights[b].iter().cloned());
        let outs = rt.load("gpt_block_nar_tiny")?.run(&args)?;
        x = outs[0].clone();
        cache.load_prefill(&outs[1], &outs[2], PROMPT_LEN);
    }
    let prefill_s = t0.elapsed().as_secs_f64();
    println!(
        "prefill: {PROMPT_LEN} tokens through {BLOCKS} blocks in {:.1} ms",
        prefill_s * 1e3
    );

    // --- decode (AR) -------------------------------------------------------
    let t0 = std::time::Instant::now();
    let mut last = *prompt.last().unwrap();
    let mut generated = Vec::with_capacity(GEN_TOKENS);
    for _step in 0..GEN_TOKENS {
        let mut h = lookup(last);
        for (b, cache) in caches.iter_mut().enumerate() {
            let kv_len = cache.len() as i32;
            let mut args = vec![
                Arg::f32(&h, &[1, E]),
                Arg::f32(cache.k_flat(), &[HEADS, SMAX, P]),
                Arg::f32(cache.v_flat(), &[HEADS, SMAX, P]),
                Arg::I32(kv_len),
            ];
            args.extend(ar_weights[b].iter().cloned());
            let mut outs = rt.load("gpt_block_ar_tiny")?.run(&args)?;
            let v_new = outs.pop().unwrap();
            let k_new = outs.pop().unwrap();
            h = outs.pop().unwrap();
            cache.store_step(k_new, v_new);
        }
        // LM head -> greedy next token.
        let mut args = vec![Arg::f32(&h, &[1, E])];
        args.extend(head_args.iter().cloned());
        let logits = &rt.load("gpt_head_tiny")?.run(&args)?[0];
        last = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        generated.push(last);
    }
    let decode_s = t0.elapsed().as_secs_f64();
    println!("decoded {GEN_TOKENS} tokens: {generated:?}");
    println!(
        "numeric path (CPU PJRT): {:.1} tokens/s",
        GEN_TOKENS as f64 / decode_s
    );
    assert_eq!(caches[0].len(), PROMPT_LEN + GEN_TOKENS);

    // --- the same workload priced on the simulated platform ---------------
    let engine = InferenceEngine::new(PlatformConfig::occamy());
    let tiny = ModelConfig::tiny();
    for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
        let r = engine.run_generate(&tiny, PROMPT_LEN as u64, GEN_TOKENS as u64, fmt);
        println!(
            "simulated 16-cluster platform ({}): {:.1} tokens/s, util {:.1}%",
            fmt.name(),
            r.throughput,
            r.fpu_utilization * 100.0
        );
    }
    println!("generate OK");
    Ok(())
}
