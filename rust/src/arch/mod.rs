//! Platform description of the many-tiny-core RISC-V target (paper Sec. IV).
//!
//! Everything the timing simulator, tile planner and energy model need to
//! know about the hardware lives here: floating-point formats and their
//! SIMD widths, the Snitch compute-cluster microarchitecture, the
//! hierarchical multi-cluster interconnect, and which ISA extensions /
//! platform features are enabled (the knobs Fig. 7/8 ablate).

mod format;
mod platform;

pub use format::{FpFormat, PrecisionPolicy, KV_CONVERT_CYCLES_PER_VEC};
pub use platform::{
    ClusterConfig, DieLinkConfig, Features, InterconnectConfig, MemLevel, PlatformConfig,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_flops_match_paper() {
        // Paper Sec. IV-A1: 16 / 32 / 64 / 128 FLOP/cycle per cluster for
        // FP64 / FP32 / FP16 / FP8 over 8 compute cores.
        let c = ClusterConfig::default();
        assert_eq!(c.peak_flop_per_cycle(FpFormat::Fp64), 16);
        assert_eq!(c.peak_flop_per_cycle(FpFormat::Fp32), 32);
        assert_eq!(c.peak_flop_per_cycle(FpFormat::Fp16), 64);
        assert_eq!(c.peak_flop_per_cycle(FpFormat::Bf16), 64);
        assert_eq!(c.peak_flop_per_cycle(FpFormat::Fp8), 128);
        assert_eq!(c.peak_flop_per_cycle(FpFormat::Fp8Alt), 128);
    }

    #[test]
    fn simd_lanes() {
        assert_eq!(FpFormat::Fp64.simd_lanes(), 1);
        assert_eq!(FpFormat::Fp32.simd_lanes(), 2);
        assert_eq!(FpFormat::Fp16.simd_lanes(), 4);
        assert_eq!(FpFormat::Fp8.simd_lanes(), 8);
    }

    #[test]
    fn format_bytes() {
        assert_eq!(FpFormat::Fp64.bytes(), 8);
        assert_eq!(FpFormat::Fp32.bytes(), 4);
        assert_eq!(FpFormat::Fp16.bytes(), 2);
        assert_eq!(FpFormat::Fp8.bytes(), 1);
    }

    #[test]
    fn occamy_preset_matches_paper() {
        // Table I "Ours": 16 clusters, 9 cores/cluster, 128 kB SPM, HBM.
        let p = PlatformConfig::occamy();
        assert_eq!(p.total_clusters(), 16);
        assert_eq!(p.cluster.compute_cores, 8);
        assert_eq!(p.cluster.spm_bytes, 128 * 1024);
        assert_eq!(p.interconnect.hbm_bw_gbps, 410.0);
        // Peak platform FP32: 16 clusters * 32 FLOP/cycle * 1 GHz.
        assert_eq!(p.peak_gflops(FpFormat::Fp32), 512.0);
    }

    #[test]
    fn baseline_preset_disables_extensions() {
        let p = PlatformConfig::occamy_baseline();
        assert!(!p.features.xssr);
        assert!(!p.features.xfrep);
        assert!(!p.features.cluster_to_cluster);
        assert!(!p.features.simd);
    }

    #[test]
    fn static_dma_overhead_is_115ns() {
        // Paper Sec. VI-B: 27 ns setup + 88 ns HBM round trip = 115 ns.
        let p = PlatformConfig::occamy();
        assert_eq!(p.interconnect.dma_static_overhead_ns(), 115.0);
        // At 1 GHz that is 115 cycles.
        assert_eq!(p.ns_to_cycles(p.interconnect.dma_static_overhead_ns()), 115);
    }

    #[test]
    fn scaled_presets() {
        for (n, want_groups) in [(1u32, 1u32), (4, 1), (8, 2), (16, 4)] {
            let p = PlatformConfig::with_clusters(n);
            assert_eq!(p.total_clusters(), n);
            assert_eq!(p.groups, want_groups);
        }
    }
}
