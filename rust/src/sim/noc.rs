//! Hierarchical interconnect topology helpers (paper Sec. IV-B, Fig. 4).
//!
//! Determines which [`MemLevel`] a cluster-to-cluster transfer rides and
//! models the binary reduction tree the fused Concat+Linear layer uses
//! (paper Sec. V-B): at tree level `d`, cluster `i` sends its partial tile
//! to cluster `i - 2^d` if `i mod 2^(d+1) == 2^d`.

use crate::arch::{MemLevel, PlatformConfig};

/// Identifies one cluster as (group, index-within-group).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterId {
    pub group: u32,
    pub index: u32,
}

impl ClusterId {
    /// Flat id in [0, C*G).
    pub fn flat(&self, p: &PlatformConfig) -> u32 {
        self.group * p.clusters_per_group + self.index
    }

    /// From a flat id.
    pub fn from_flat(flat: u32, p: &PlatformConfig) -> ClusterId {
        ClusterId { group: flat / p.clusters_per_group, index: flat % p.clusters_per_group }
    }
}

/// The interconnect level a transfer between two clusters traverses.
pub fn path_level(src: ClusterId, dst: ClusterId) -> MemLevel {
    if src == dst {
        MemLevel::Spm
    } else if src.group == dst.group {
        MemLevel::PeerClusterSameGroup
    } else {
        MemLevel::PeerClusterOtherGroup
    }
}

/// One send in the binary reduction tree.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReductionStep {
    pub level: u32,
    pub src: u32,
    pub dst: u32,
    pub link: MemLevel,
}

/// Depth of the binary reduction tree over `n` clusters:
/// `d = ceil(log2(n))` (paper: d = log2(C*G)).
pub fn tree_depth(n: u32) -> u32 {
    if n <= 1 {
        0
    } else {
        32 - (n - 1).leading_zeros()
    }
}

/// The Sec. V-B binary reduction tree over `n` abstract participants,
/// grouped by level: at level `d`, participant `i` sends its partial to
/// `i - 2^d` when `i mod 2^(d+1) == 2^d`. Returned as `(src, dst)` pairs
/// per level. The cluster-level [`reduction_schedule`] annotates these
/// pairs with interconnect links; the die-level collectives
/// (`crate::parallel::collectives`) run the same schedule over dies.
pub fn pair_schedule(n: u32) -> Vec<Vec<(u32, u32)>> {
    let depth = tree_depth(n);
    let mut levels = Vec::with_capacity(depth as usize);
    for d in 0..depth {
        let stride = 1u32 << d;
        let mut steps = Vec::new();
        let mut i = stride;
        while i < n {
            steps.push((i, i - stride));
            i += stride * 2;
        }
        levels.push(steps);
    }
    levels
}

/// All sends of the binary reduction tree over the platform's clusters,
/// grouped by level. Clusters are numbered so that same-group pairs reduce
/// first (level 0..log2(C)) and cross-group reductions happen last —
/// "first among clusters in a group and then among groups" (Sec. V-B).
pub fn reduction_schedule(p: &PlatformConfig) -> Vec<Vec<ReductionStep>> {
    pair_schedule(p.total_clusters())
        .into_iter()
        .enumerate()
        .map(|(d, pairs)| {
            pairs
                .into_iter()
                .map(|(src, dst)| ReductionStep {
                    level: d as u32,
                    src,
                    dst,
                    link: path_level(
                        ClusterId::from_flat(src, p),
                        ClusterId::from_flat(dst, p),
                    ),
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_matches_paper_formula() {
        // d = log2(C*G): 16 clusters -> 4 levels.
        assert_eq!(tree_depth(16), 4);
        assert_eq!(tree_depth(8), 3);
        assert_eq!(tree_depth(4), 2);
        assert_eq!(tree_depth(1), 0);
        assert_eq!(tree_depth(2), 1);
    }

    #[test]
    fn schedule_covers_every_cluster_once() {
        // Every cluster except 0 sends exactly once across all levels
        // (each partial is delivered exactly once).
        let p = PlatformConfig::occamy();
        let sched = reduction_schedule(&p);
        assert_eq!(sched.len(), 4);
        let mut senders: Vec<u32> = sched.iter().flatten().map(|s| s.src).collect();
        senders.sort_unstable();
        let expect: Vec<u32> = (1..16).collect();
        assert_eq!(senders, expect);
    }

    #[test]
    fn intra_group_reductions_first() {
        // With 4 clusters/group, levels 0-1 stay inside a group and levels
        // 2-3 cross groups.
        let p = PlatformConfig::occamy();
        let sched = reduction_schedule(&p);
        for step in sched[0].iter().chain(sched[1].iter()) {
            assert_eq!(step.link, MemLevel::PeerClusterSameGroup, "{step:?}");
        }
        for step in sched[2].iter().chain(sched[3].iter()) {
            assert_eq!(step.link, MemLevel::PeerClusterOtherGroup, "{step:?}");
        }
    }

    #[test]
    fn level_parallelism_halves() {
        let p = PlatformConfig::occamy();
        let sched = reduction_schedule(&p);
        assert_eq!(sched[0].len(), 8);
        assert_eq!(sched[1].len(), 4);
        assert_eq!(sched[2].len(), 2);
        assert_eq!(sched[3].len(), 1);
    }

    #[test]
    fn pair_schedule_matches_cluster_schedule() {
        let p = PlatformConfig::occamy();
        let pairs = pair_schedule(p.total_clusters());
        let sched = reduction_schedule(&p);
        assert_eq!(pairs.len(), sched.len());
        for (lvl, steps) in pairs.iter().zip(&sched) {
            let got: Vec<(u32, u32)> = steps.iter().map(|s| (s.src, s.dst)).collect();
            assert_eq!(lvl, &got);
        }
        // Non-power-of-two participant counts still deliver every partial
        // exactly once.
        let mut senders: Vec<u32> =
            pair_schedule(6).into_iter().flatten().map(|(s, _)| s).collect();
        senders.sort_unstable();
        assert_eq!(senders, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn flat_roundtrip() {
        let p = PlatformConfig::occamy();
        for f in 0..p.total_clusters() {
            assert_eq!(ClusterId::from_flat(f, &p).flat(&p), f);
        }
    }
}
