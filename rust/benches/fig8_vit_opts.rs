//! Fig. 8 — impact of SW optimizations on the ViT model class.
//! Paper headlines: up to 17.9x total speedup (4.1x from extensions,
//! 1.6x FP32, 1.5x FP16, rest FP8); 26 / 12 / 8 images/s at FP8.

mod common;

use snitch_fm::arch::{Features, FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::ModelConfig;
use snitch_fm::report;

fn ladder(cfg: &ModelConfig) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    let mut base = PlatformConfig::occamy();
    base.features = Features::baseline();
    rows.push((
        "baseline fp64".to_string(),
        InferenceEngine::new(base).run_nar(cfg, cfg.seq, FpFormat::Fp64).throughput,
    ));
    let e = InferenceEngine::new(PlatformConfig::occamy());
    for fmt in FpFormat::LADDER {
        rows.push((
            format!("optimized {}", fmt.name()),
            e.run_nar(cfg, cfg.seq, fmt).throughput,
        ));
    }
    rows
}

fn main() {
    common::header("Fig. 8", "ViT SW-optimization ladder");
    let paper_fp8 = [("vit-b", 26.0), ("vit-l", 12.0), ("vit-h", 8.0)];
    for (name, paper) in paper_fp8 {
        let cfg = ModelConfig::preset(name).unwrap();
        let (t, rows) = common::time_median(5, || ladder(&cfg));
        print!("{}", report::speedup_ladder(&format!("{name} (ours)"), "img/s", &rows));
        let total = rows.last().unwrap().1 / rows[0].1;
        println!(
            "  paper: FP8 {paper} images/s (17.9x max total) | ours: FP8 {:.1} images/s ({total:.1}x total)\n",
            rows.last().unwrap().1
        );
        common::report_timing(name, t);
    }
}
