//! Shard-plan enumeration and selection.
//!
//! Enumerates every legal `{tp, pp, replicas}` assignment for the
//! platform's die count, prices each with [`shard::plan_cost`], and ranks
//! them by the chosen objective:
//!
//! * [`Objective::Latency`] — minimize the modeled per-token latency
//!   through the pipe (interactive serving; favors TP, then PP).
//! * [`Objective::Throughput`] — maximize aggregate tokens/s at the
//!   priced batch (batch serving; favors replicas, whose scaling pays no
//!   collective tax).
//!
//! Ties break toward fewer dies, then lexicographic `(tp, pp, replicas)`
//! so the ranking is fully deterministic.

use crate::arch::{FpFormat, PlatformConfig};
use crate::model::{Mode, ModelConfig};
use crate::parallel::shard::{plan_cost, PlanCost, ShardPlan};

/// What the planner optimizes for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Cheapest modeled per-token latency.
    Latency,
    /// Highest aggregate tokens/s across replicas.
    Throughput,
}

impl Objective {
    /// Parse `latency` | `throughput`.
    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "latency" => Some(Objective::Latency),
            "throughput" => Some(Objective::Throughput),
            _ => None,
        }
    }

    pub const fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Throughput => "throughput",
        }
    }
}

/// One plan with its priced pass and per-replica KV budget.
#[derive(Debug, Clone)]
pub struct RankedPlan {
    pub plan: ShardPlan,
    pub cost: PlanCost,
    /// KV budget one replica offers the serving scheduler (whole-model
    /// token bytes; see [`ShardPlan::replica_kv_budget_bytes`]).
    pub kv_budget_bytes: u64,
}

/// Every legal plan for `cfg` on the platform's dies, unranked.
pub fn enumerate_plans(cfg: &ModelConfig, platform: &PlatformConfig) -> Vec<ShardPlan> {
    let dies = platform.die.dies.max(1);
    let mut out = Vec::new();
    for tp in 1..=dies {
        for pp in 1..=dies {
            for replicas in 1..=dies {
                let plan = ShardPlan { tp, pp, replicas };
                if plan.dies() <= dies && plan.is_legal(cfg, platform) {
                    out.push(plan);
                }
            }
        }
    }
    out
}

/// Price every legal plan for a decode step at KV length `s` and batch
/// `b`, ranked best-first by `objective`.
pub fn best_plans(
    cfg: &ModelConfig,
    fmt: FpFormat,
    platform: &PlatformConfig,
    mode: Mode,
    b: u64,
    s: u64,
    objective: Objective,
) -> Vec<RankedPlan> {
    let mut ranked: Vec<RankedPlan> = enumerate_plans(cfg, platform)
        .into_iter()
        .map(|plan| RankedPlan {
            plan,
            cost: plan_cost(cfg, plan, mode, b, s, fmt, platform),
            kv_budget_bytes: plan.replica_kv_budget_bytes(cfg, fmt, platform),
        })
        .collect();
    let tie = |p: &ShardPlan| (p.dies(), p.tp, p.pp, p.replicas);
    match objective {
        Objective::Latency => {
            ranked.sort_by_key(|r| (r.cost.token_latency_cycles, tie(&r.plan)));
        }
        Objective::Throughput => {
            ranked.sort_by(|a, b| {
                b.cost
                    .tokens_per_s
                    .partial_cmp(&a.cost.tokens_per_s)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| tie(&a.plan).cmp(&tie(&b.plan)))
            });
        }
    }
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_parse() {
        assert_eq!(Objective::parse("latency"), Some(Objective::Latency));
        assert_eq!(Objective::parse("throughput"), Some(Objective::Throughput));
        assert_eq!(Objective::parse("nope"), None);
    }

    #[test]
    fn single_die_has_exactly_the_degenerate_plan() {
        let cfg = ModelConfig::gpt_j();
        let plans = enumerate_plans(&cfg, &PlatformConfig::occamy());
        assert_eq!(plans, vec![ShardPlan::single()]);
    }

    #[test]
    fn enumeration_is_bounded_and_legal() {
        let cfg = ModelConfig::gpt_j(); // 16 heads: tp in {1,2,4} on 4 dies
        let p = PlatformConfig::with_dies(4);
        let plans = enumerate_plans(&cfg, &p);
        assert!(plans.contains(&ShardPlan::single()));
        assert!(plans.contains(&ShardPlan { tp: 2, pp: 2, replicas: 1 }));
        assert!(plans.contains(&ShardPlan { tp: 1, pp: 1, replicas: 4 }));
        for plan in &plans {
            assert!(plan.dies() <= 4, "{plan:?}");
            assert!(plan.is_legal(&cfg, &p), "{plan:?}");
        }
        // tp=3 never divides 16 heads.
        assert!(!plans.iter().any(|p| p.tp == 3));
    }

    #[test]
    fn throughput_objective_picks_full_data_parallelism() {
        // Replica scaling pays no collective tax, so at a fixed per-engine
        // batch the throughput-optimal plan uses every die as a replica.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let ranked = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Throughput);
        let best = &ranked[0];
        assert_eq!(best.plan, ShardPlan { tp: 1, pp: 1, replicas: 4 });
        let single = ranked
            .iter()
            .find(|r| r.plan == ShardPlan::single())
            .expect("single plan enumerated");
        assert!(best.cost.tokens_per_s > single.cost.tokens_per_s);
    }

    #[test]
    fn latency_objective_picks_a_sharded_plan() {
        // Decode is weight-streaming-bound: splitting the stream across
        // dies must beat the single engine on per-token latency.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let ranked = best_plans(&cfg, fmt, &p, Mode::Ar, 8, 1024, Objective::Latency);
        let best = &ranked[0];
        assert!(best.plan.tp > 1, "latency plan must shard: {:?}", best.plan);
        let single = ranked
            .iter()
            .find(|r| r.plan == ShardPlan::single())
            .expect("single plan enumerated");
        assert!(best.cost.token_latency_cycles < single.cost.token_latency_cycles);
    }
}
