//! Collective-communication pricing over the die-to-die interconnect.
//!
//! Ranks are dies: each participant is one full G x C cluster die
//! ([`crate::arch::DieLinkConfig`] describes the links joining them).
//! Two algorithm families are priced:
//!
//! * **Ring** — the bandwidth-optimal schedule: an all-reduce moves
//!   `2 * (n-1)/n * payload` bytes per die in `2*(n-1)` steps of
//!   `payload/n` each (reduce-scatter then all-gather).
//! * **Binary tree** — the latency-optimal schedule for small payloads,
//!   running the Sec. V-B reduction tree ([`noc::pair_schedule`]) over
//!   dies instead of clusters: `ceil(log2 n)` levels up (reduce), the
//!   same levels down (broadcast), full payload per hop.
//!
//! Contention: a die drives concurrent die-to-die transfers with its
//! dedicated DMA engines (`DieLinkConfig::dma_engines`); transfers beyond
//! that share the link bandwidth, which is what makes a ring step (one
//! send + one receive in flight per die) slower on a single-engine die.
//! Reduction arithmetic is priced with the cluster core model spread over
//! the whole die, accumulating in FP32 like the Sec. V-B tree.
//!
//! All costs depend on the rank *count* only — every die pair rides the
//! same link class — so collective pricing is symmetric in rank order by
//! construction (property-tested in `tests/parallel_plans.rs`).

use crate::arch::{FpFormat, PlatformConfig};
use crate::sim::core::{opcost, CoreModel};
use crate::sim::noc;
use crate::sim::KernelCost;

/// Synchronization cost charged once per collective step/level (matches
/// the cluster-level barrier the multi-cluster engine charges).
const SYNC_CYCLES: u64 = 50;

/// Collective algorithm selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Bandwidth-optimal ring schedule.
    Ring,
    /// Latency-optimal binary tree (the Sec. V-B schedule over dies).
    Tree,
    /// Price both and take the cheaper (what the shard pricing uses).
    Auto,
}

/// Die-to-die link timing derived from the platform's `DieLinkConfig`.
struct DieLink<'a> {
    p: &'a PlatformConfig,
}

impl DieLink<'_> {
    fn bytes_per_cycle(&self) -> f64 {
        (self.p.die.link_gbps / self.p.freq_ghz).max(1e-9)
    }

    /// Static cycles before a die-to-die payload streams: DMA setup plus
    /// the package-level hop latency.
    fn static_cycles(&self) -> u64 {
        self.p
            .ns_to_cycles(self.p.interconnect.dma_setup_ns + self.p.die.latency_ns)
    }

    /// Cycles for one transfer while the die drives `concurrent`
    /// transfers at once: transfers beyond the dedicated DMA engines
    /// share the link bandwidth.
    fn transfer_cycles(&self, bytes: u64, concurrent: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let engines = self.p.die.dma_engines.max(1);
        let sharers = concurrent.max(1).div_ceil(engines).max(1);
        let bpc = self.bytes_per_cycle() / sharers as f64;
        self.static_cycles() + (bytes as f64 / bpc).ceil() as u64
    }
}

/// Cycles one die needs to elementwise-add `elems` partial elements,
/// spread over all its compute cores (FP32 accumulation, as in the
/// Sec. V-B tree reduction).
fn add_cycles(elems: u64, platform: &PlatformConfig) -> u64 {
    if elems == 0 {
        return 0;
    }
    let core = CoreModel::new(platform.cluster, platform.features);
    core.elementwise_cycles(
        elems.div_ceil(platform.total_cores()),
        opcost::SIMPLE,
        FpFormat::Fp32,
        true,
    )
}

fn elems_of(bytes: u64, fmt: FpFormat) -> u64 {
    bytes.div_ceil(fmt.bytes().max(1))
}

fn check_ranks(ranks: &[u32], platform: &PlatformConfig) {
    debug_assert!(
        ranks.iter().all(|&r| r < platform.die.dies),
        "rank ids {ranks:?} exceed the package's {} dies",
        platform.die.dies
    );
    #[cfg(debug_assertions)]
    {
        let mut seen = ranks.to_vec();
        seen.sort_unstable();
        seen.dedup();
        debug_assert_eq!(seen.len(), ranks.len(), "duplicate rank ids {ranks:?}");
    }
}

/// Ring all-reduce: reduce-scatter then all-gather, `payload/n` bytes per
/// step, every die sending and receiving concurrently.
fn ring_all_reduce(bytes: u64, n: u64, fmt: FpFormat, p: &PlatformConfig) -> KernelCost {
    let link = DieLink { p };
    let chunk = bytes.div_ceil(n);
    let chunk_elems = elems_of(chunk, fmt);
    let xfer = link.transfer_cycles(chunk, 2);
    let rs = (n - 1) * (xfer + add_cycles(chunk_elems, p) + SYNC_CYCLES);
    let ag = (n - 1) * (xfer + SYNC_CYCLES);
    KernelCost {
        cycles: rs + ag,
        flops: n * (n - 1) * chunk_elems,
        d2d_bytes: n * 2 * (n - 1) * chunk,
        dma_transfers: n * 2 * (n - 1),
        ..Default::default()
    }
}

/// Binary-tree all-reduce: the Sec. V-B pair schedule over dies (reduce
/// up), then the mirrored broadcast (down), full payload per hop.
fn tree_all_reduce(bytes: u64, n: u64, fmt: FpFormat, p: &PlatformConfig) -> KernelCost {
    let link = DieLink { p };
    let elems = elems_of(bytes, fmt);
    let levels = noc::pair_schedule(n as u32);
    let mut c = KernelCost::default();
    for level in &levels {
        if level.is_empty() {
            continue;
        }
        // All of a level's sends ride disjoint die pairs in parallel.
        c.cycles += link.transfer_cycles(bytes, 1) + add_cycles(elems, p) + SYNC_CYCLES;
        c.flops += elems * level.len() as u64;
        c.d2d_bytes += bytes * level.len() as u64;
        c.dma_transfers += level.len() as u64;
    }
    for level in levels.iter().rev() {
        if level.is_empty() {
            continue;
        }
        c.cycles += link.transfer_cycles(bytes, 1) + SYNC_CYCLES;
        c.d2d_bytes += bytes * level.len() as u64;
        c.dma_transfers += level.len() as u64;
    }
    c
}

/// Price an all-reduce of `bytes` across `ranks` dies. Zero-cost for a
/// single rank or an empty payload. Cost depends only on the rank count
/// (all die pairs are equidistant), so it is symmetric in rank order.
pub fn all_reduce_cost(
    bytes: u64,
    ranks: &[u32],
    alg: Algorithm,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    check_ranks(ranks, platform);
    let n = ranks.len() as u64;
    if n <= 1 || bytes == 0 {
        return KernelCost::default();
    }
    match alg {
        Algorithm::Ring => ring_all_reduce(bytes, n, fmt, platform),
        Algorithm::Tree => tree_all_reduce(bytes, n, fmt, platform),
        Algorithm::Auto => {
            let ring = ring_all_reduce(bytes, n, fmt, platform);
            let tree = tree_all_reduce(bytes, n, fmt, platform);
            if tree.cycles < ring.cycles {
                tree
            } else {
                ring
            }
        }
    }
}

/// Ring reduce-scatter: each die ends with the reduced `payload/n` shard.
pub fn reduce_scatter_cost(
    bytes: u64,
    ranks: &[u32],
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    check_ranks(ranks, platform);
    let n = ranks.len() as u64;
    if n <= 1 || bytes == 0 {
        return KernelCost::default();
    }
    let link = DieLink { p: platform };
    let chunk = bytes.div_ceil(n);
    let chunk_elems = elems_of(chunk, fmt);
    let xfer = link.transfer_cycles(chunk, 2);
    KernelCost {
        cycles: (n - 1) * (xfer + add_cycles(chunk_elems, platform) + SYNC_CYCLES),
        flops: n * (n - 1) * chunk_elems,
        d2d_bytes: n * (n - 1) * chunk,
        dma_transfers: n * (n - 1),
        ..Default::default()
    }
}

/// Ring all-gather: each die starts with a `payload/n` shard and ends
/// with the full payload.
pub fn all_gather_cost(bytes: u64, ranks: &[u32], platform: &PlatformConfig) -> KernelCost {
    check_ranks(ranks, platform);
    let n = ranks.len() as u64;
    if n <= 1 || bytes == 0 {
        return KernelCost::default();
    }
    let link = DieLink { p: platform };
    let chunk = bytes.div_ceil(n);
    let xfer = link.transfer_cycles(chunk, 2);
    KernelCost {
        cycles: (n - 1) * (xfer + SYNC_CYCLES),
        d2d_bytes: n * (n - 1) * chunk,
        dma_transfers: n * (n - 1),
        ..Default::default()
    }
}

/// A copy of `platform` whose die-to-die links run at `fraction` of
/// nominal bandwidth — the pricing view of a `link@` fault. Every
/// collective and p2p transfer prices through
/// [`DieLink::bytes_per_cycle`], so scaling `link_gbps` is the single
/// choke point: TP all-reduces, PP activation sends, and disaggregated
/// KV migrations all slow down together while compute is untouched.
/// `fraction` is clamped to `(0, 1]`; 1.0 returns an identical platform
/// (fault-free pricing stays bit-identical because callers keep using
/// the *original* reference in that case).
pub fn degrade_link(platform: &PlatformConfig, fraction: f64) -> PlatformConfig {
    let f = if fraction.is_finite() { fraction.clamp(1e-6, 1.0) } else { 1.0 };
    let mut p = platform.clone();
    p.die.link_gbps *= f;
    p
}

/// Point-to-point die-to-die send (a pipeline stage shipping its output
/// activations to the next stage's die).
pub fn p2p_cost(bytes: u64, platform: &PlatformConfig) -> KernelCost {
    if bytes == 0 {
        return KernelCost::default();
    }
    let link = DieLink { p: platform };
    KernelCost {
        cycles: link.transfer_cycles(bytes, 1),
        d2d_bytes: bytes,
        dma_transfers: 1,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dies(n: u32) -> PlatformConfig {
        PlatformConfig::with_dies(n)
    }

    fn ranks(n: u32) -> Vec<u32> {
        (0..n).collect()
    }

    #[test]
    fn degenerate_forms_are_free() {
        let p = dies(4);
        let f = FpFormat::Fp16;
        assert_eq!(all_reduce_cost(1 << 20, &ranks(1), Algorithm::Auto, f, &p).cycles, 0);
        assert_eq!(all_reduce_cost(0, &ranks(4), Algorithm::Ring, f, &p).cycles, 0);
        assert_eq!(reduce_scatter_cost(0, &ranks(4), f, &p).cycles, 0);
        assert_eq!(all_gather_cost(1 << 20, &ranks(1), &p).cycles, 0);
        assert_eq!(p2p_cost(0, &p).cycles, 0);
    }

    #[test]
    fn ring_beats_tree_on_large_payloads_and_loses_on_small() {
        let p = dies(8);
        let f = FpFormat::Fp32;
        let big = 64 << 20;
        let ring = all_reduce_cost(big, &ranks(8), Algorithm::Ring, f, &p);
        let tree = all_reduce_cost(big, &ranks(8), Algorithm::Tree, f, &p);
        assert!(ring.cycles < tree.cycles, "ring {} vs tree {}", ring.cycles, tree.cycles);
        // A tiny payload is latency-bound: fewer hops win.
        let small = 256;
        let ring = all_reduce_cost(small, &ranks(8), Algorithm::Ring, f, &p);
        let tree = all_reduce_cost(small, &ranks(8), Algorithm::Tree, f, &p);
        assert!(tree.cycles < ring.cycles, "tree {} vs ring {}", tree.cycles, ring.cycles);
        // Auto picks the winner on both.
        for bytes in [small, big] {
            let auto = all_reduce_cost(bytes, &ranks(8), Algorithm::Auto, f, &p);
            let best = all_reduce_cost(bytes, &ranks(8), Algorithm::Ring, f, &p)
                .cycles
                .min(all_reduce_cost(bytes, &ranks(8), Algorithm::Tree, f, &p).cycles);
            assert_eq!(auto.cycles, best);
        }
    }

    #[test]
    fn all_reduce_composes_reduce_scatter_and_all_gather() {
        let p = dies(4);
        let f = FpFormat::Fp32;
        let bytes = 1 << 20;
        let ar = all_reduce_cost(bytes, &ranks(4), Algorithm::Ring, f, &p);
        let rs = reduce_scatter_cost(bytes, &ranks(4), f, &p);
        let ag = all_gather_cost(bytes, &ranks(4), &p);
        assert_eq!(ar.cycles, rs.cycles + ag.cycles);
        assert_eq!(ar.d2d_bytes, rs.d2d_bytes + ag.d2d_bytes);
        assert_eq!(ar.flops, rs.flops);
    }

    #[test]
    fn single_engine_die_pays_ring_contention() {
        let mut one = dies(4);
        one.die.dma_engines = 1;
        let two = dies(4);
        let f = FpFormat::Fp32;
        let a = all_reduce_cost(8 << 20, &ranks(4), Algorithm::Ring, f, &one);
        let b = all_reduce_cost(8 << 20, &ranks(4), Algorithm::Ring, f, &two);
        assert!(
            a.cycles > b.cycles,
            "send+receive on one DMA engine must halve the ring bandwidth: {} !> {}",
            a.cycles,
            b.cycles
        );
    }

    #[test]
    fn degraded_links_grow_every_transfer_cost() {
        let p = dies(4);
        let half = degrade_link(&p, 0.5);
        let f = FpFormat::Fp32;
        let bytes = 8 << 20;
        // Compute model untouched; only link bandwidth scales.
        assert_eq!(half.cluster, p.cluster);
        assert!((half.die.link_gbps - p.die.link_gbps * 0.5).abs() < 1e-9);
        let ar_n = all_reduce_cost(bytes, &ranks(4), Algorithm::Ring, f, &p);
        let ar_d = all_reduce_cost(bytes, &ranks(4), Algorithm::Ring, f, &half);
        assert!(ar_d.cycles > ar_n.cycles, "{} !> {}", ar_d.cycles, ar_n.cycles);
        // Moved bytes are identical — only the time to move them grows.
        assert_eq!(ar_d.d2d_bytes, ar_n.d2d_bytes);
        let p2p_n = p2p_cost(bytes, &p);
        let p2p_d = p2p_cost(bytes, &half);
        assert!(p2p_d.cycles > p2p_n.cycles);
        // Unit fraction (and nonsense inputs) degrade nothing.
        assert_eq!(degrade_link(&p, 1.0), p);
        assert_eq!(degrade_link(&p, f64::NAN), p);
        // The clamp keeps a zero-bandwidth spec finite and positive.
        assert!(degrade_link(&p, 0.0).die.link_gbps > 0.0);
    }

    #[test]
    fn p2p_scales_with_bytes_and_counts_traffic() {
        let p = dies(2);
        let small = p2p_cost(4 << 10, &p);
        let large = p2p_cost(4 << 20, &p);
        assert!(large.cycles > small.cycles);
        assert_eq!(large.d2d_bytes, 4 << 20);
        assert_eq!(large.hbm_read_bytes + large.hbm_write_bytes, 0);
    }
}
