//! Fault tolerance: the PR-8 headline claim — the fleet degrades
//! proportionally, not catastrophically, when replicas fail mid-trace.
//!
//! A 4-replica fleet serves an open-loop Poisson trace while 0, 1, 2,
//! then 3 of its replicas fail permanently partway through (`fail@` —
//! the KV pool survives, so salvaged in-flight requests re-export their
//! pages over the d2d links instead of recomputing prefill). Survivors
//! adopt the failed replicas' backlog through the router's penalized
//! re-routing.
//!
//! Claims defended here:
//!
//! 1. **Graceful degradation.** Every request completes at every failure
//!    count short of fleet death, goodput falls monotonically but stays
//!    above a fraction of the surviving-capacity share (never a cliff),
//!    and `degraded_capacity_fraction` grows with the failure count.
//! 2. **`--faults off` is inert.** The armed-but-off path is
//!    bit-identical (`same_outcome`) to the PR-7 fleet.
//! 3. **Reproducibility.** Identical fault specs and seeds replay
//!    byte-identical reports.
//!
//! Short mode (`BENCH_SMOKE=1`) serves 160 requests instead of 640; with
//! `BENCH_JSON_DIR` set the results land in `BENCH_faults.json`
//! (the healthy fleet's tokens_per_s / ttft_p99_s are trend-tracked).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, FaultPlan, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::parallel::{serve_replicated, serve_replicated_with_faults, RoutePolicy};

const SEED: u64 = 0xFA157;
const REPLICAS: usize = 4;

fn main() {
    let cfg = ModelConfig::tiny();
    let fmt = FpFormat::Fp8;
    let platform = PlatformConfig::with_dies(REPLICAS as u32);
    let n = if common::smoke() { 160 } else { 640 };
    let workload = Workload::synthetic(SEED, n, (16, 96), (8, 32))
        .with_poisson_arrivals(SEED ^ 0x7EA, 2_500.0);
    let opts = BatcherConfig::new(8, 0);
    let policy = RoutePolicy::JoinShortestQueue;

    // ---- Part 1: goodput vs replicas failed mid-trace ----
    let (t_base, base) = common::time_median(3, || {
        serve_replicated(&cfg, &platform, fmt, opts, &workload, REPLICAS, policy)
    });
    assert_eq!(base.merged.completed, n, "healthy fleet must serve the whole trace");
    let horizon = base.merged.total_seconds;
    // Victims fall at 30% / 45% / 60% of the healthy fleet's makespan:
    // late enough that each carries real in-flight state to salvage,
    // early enough that survivors re-run a meaningful backlog.
    let fail_at = [0.30 * horizon, 0.45 * horizon, 0.60 * horizon];

    let mut goodput = vec![base.merged.tokens_per_s];
    let mut ttft_p99 = vec![base.merged.ttft_p99_s];
    let mut tpot_p99 = vec![base.merged.tpot_p99_s];
    let mut degraded = vec![base.merged.degraded_capacity_fraction];
    let mut t_fail = 0.0;
    for k in 1..REPLICAS {
        let spec: Vec<String> =
            (0..k).map(|i| format!("fail@{}:r{i}", fail_at[i])).collect();
        let plan = FaultPlan::parse(&spec.join(","), SEED).unwrap();
        let (t, r) = common::time_median(3, || {
            serve_replicated_with_faults(
                &cfg, &platform, fmt, opts, &workload, REPLICAS, policy, &plan,
            )
        });
        if k == 1 {
            t_fail = t;
        }
        assert_eq!(r.merged.replica_failures, k as u64, "{k} failures must fire");
        assert_eq!(
            r.merged.completed, n,
            "{k} failed: survivors must still serve every request"
        );
        assert!(r.merged.rejected.is_empty());
        assert!(r.merged.salvaged_requests > 0, "{k} failed: backlog must be salvaged");
        // Reproducibility: the same spec + seed replays byte-identically.
        let again = serve_replicated_with_faults(
            &cfg, &platform, fmt, opts, &workload, REPLICAS, policy, &plan,
        );
        assert!(again.merged.same_outcome(&r.merged), "{k} failed: replay must match");
        goodput.push(r.merged.tokens_per_s);
        ttft_p99.push(r.merged.ttft_p99_s);
        tpot_p99.push(r.merged.tpot_p99_s);
        degraded.push(r.merged.degraded_capacity_fraction);
    }

    common::header(
        "fault tolerance",
        "4-replica fleet, permanent replica failures mid-trace, KV salvage on",
    );
    println!(
        "{n} requests, {} gen tokens, failures at {:.4}/{:.4}/{:.4} s of a {:.4} s trace",
        workload.total_gen_tokens(),
        fail_at[0],
        fail_at[1],
        fail_at[2],
        horizon
    );
    for k in 0..REPLICAS {
        println!(
            "{k} failed: {:>8.1} tokens/s  TTFT p99 {:.4}  TPOT p99 {:.6}  \
             capacity lost {:.1}%",
            goodput[k],
            ttft_p99[k],
            tpot_p99[k],
            degraded[k] * 100.0
        );
    }
    common::report_timing("faults-healthy", t_base);
    common::report_timing("faults-1-failed", t_fail);

    // Graceful, proportional, non-catastrophic: goodput never rises as
    // more replicas die, never falls below a conservative fraction of
    // the surviving-capacity share, and the modeled capacity loss grows.
    for k in 1..REPLICAS {
        assert!(
            goodput[k] <= goodput[k - 1] * 1.001,
            "goodput must not rise with more failures: {} vs {} at k={k}",
            goodput[k],
            goodput[k - 1]
        );
        let share = (REPLICAS - k) as f64 / REPLICAS as f64;
        assert!(
            goodput[k] >= goodput[0] * share * 0.25,
            "catastrophic collapse at k={k}: {:.1} tokens/s vs healthy {:.1} \
             (surviving share {share:.2})",
            goodput[k],
            goodput[0]
        );
        assert!(
            degraded[k] > degraded[k - 1],
            "capacity loss must grow with the failure count"
        );
        assert!(degraded[k] < 1.0);
    }

    // ---- Part 2: `--faults off` is bit-identical to the PR-7 fleet ----
    let off = FaultPlan::parse("off", SEED).unwrap();
    assert!(off.is_off());
    let armed = serve_replicated_with_faults(
        &cfg, &platform, fmt, opts, &workload, REPLICAS, policy, &off,
    );
    assert!(
        armed.merged.same_outcome(&base.merged),
        "--faults off must be bit-identical to the plain fleet"
    );
    for (a, b) in armed.per_replica.iter().zip(&base.per_replica) {
        assert!(a.same_outcome(b));
    }
    println!("faults off: bit-identical to the plain fleet; replays deterministic");

    common::write_bench_json(
        "faults",
        &format!(
            "{{\"requests\":{n},\"replicas\":{REPLICAS},\
             \"baseline\":{{\"tokens_per_s\":{},\"ttft_p99_s\":{}}},\
             \"goodput_by_failures\":[{}],\"ttft_p99_by_failures\":[{}],\
             \"tpot_p99_by_failures\":[{}],\"degraded_fraction_by_failures\":[{}],\
             \"goodput_ratio_1_failed\":{}}}",
            goodput[0],
            ttft_p99[0],
            goodput.iter().map(f64::to_string).collect::<Vec<_>>().join(","),
            ttft_p99.iter().map(f64::to_string).collect::<Vec<_>>().join(","),
            tpot_p99.iter().map(f64::to_string).collect::<Vec<_>>().join(","),
            degraded.iter().map(f64::to_string).collect::<Vec<_>>().join(","),
            goodput[1] / goodput[0],
        ),
    );
}
