//! Cluster / group / platform configuration (paper Sec. IV, Fig. 3-4).

use super::FpFormat;

/// ISA extensions and platform features the paper ablates (Fig. 7/8).
///
/// The "baseline" bars of the software-optimization figures disable all of
/// these; the "optimized" bars enable all of them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Features {
    /// Stream Semantic Registers: operands stream to the FPU with hardware
    /// address generation, removing explicit loads from the inner loop.
    pub xssr: bool,
    /// FREP instruction-repetition buffer: zero-overhead inner loops.
    pub xfrep: bool,
    /// Packed-SIMD FPU lanes (and the widening dot-product extension).
    pub simd: bool,
    /// Direct cluster-to-cluster DMA over the hierarchical interconnect
    /// (when off, inter-cluster traffic bounces through HBM).
    pub cluster_to_cluster: bool,
    /// DMA double buffering (overlap transfers with compute).
    pub double_buffering: bool,
}

impl Features {
    /// Everything on — the paper's optimized configuration.
    pub const fn all() -> Features {
        Features {
            xssr: true,
            xfrep: true,
            simd: true,
            cluster_to_cluster: true,
            double_buffering: true,
        }
    }

    /// The paper's baseline configuration (Sec. VII-A): no Xssr, no Xfrep,
    /// no SIMD exploitation, no cluster-to-cluster transfers. The DMA
    /// double buffering is part of the base platform and stays on.
    pub const fn baseline() -> Features {
        Features {
            xssr: false,
            xfrep: false,
            simd: false,
            cluster_to_cluster: false,
            double_buffering: true,
        }
    }

    /// Everything off (double buffering included) — ablation floor.
    pub const fn none() -> Features {
        Features {
            xssr: false,
            xfrep: false,
            simd: false,
            cluster_to_cluster: false,
            double_buffering: false,
        }
    }
}

impl Default for Features {
    fn default() -> Self {
        Features::all()
    }
}

/// One Snitch compute cluster (paper Sec. IV-A, Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    /// Parallel compute cores (the 9th core is the DMA/coordination core).
    pub compute_cores: u64,
    /// Tightly-coupled L1 scratchpad size in bytes (128 kB, 32 banks).
    pub spm_bytes: u64,
    /// SPM banks (64-bit wide, single-cycle interconnect).
    pub spm_banks: u64,
    /// FPU pipeline latency in cycles (RAW distance an unrolled inner loop
    /// must cover; the paper unrolls by 8).
    pub fpu_latency: u64,
    /// Inner-loop unroll factor used by the kernel library.
    pub unroll: u64,
    /// Fixed cycles to configure an SSR stream / FREP loop before the
    /// first FMA issues.
    pub ssr_setup_cycles: u64,
    /// Per-iteration integer overhead (index update + compare + branch) of
    /// a software loop on the single-issue Snitch core when FREP is off.
    pub loop_overhead_cycles: u64,
    /// Cycles per element for explicit loads when SSR is off. Two operand
    /// loads per FMA on a single-issue core.
    pub load_cycles_per_op: u64,
    /// Sustained fraction of the ideal issue rate the optimized GEMM inner
    /// loop achieves (TCDM bank conflicts, SSR rewinds at row boundaries,
    /// loop-nest bookkeeping outside FREP). Zaruba et al. report the
    /// Snitch cluster reaching "the 90% region" on streamed FP kernels;
    /// 0.87 lands the end-to-end NAR utilization in Table III's band.
    pub compute_efficiency: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            compute_cores: 8,
            spm_bytes: 128 * 1024,
            spm_banks: 32,
            fpu_latency: 3,
            unroll: 8,
            ssr_setup_cycles: 10,
            loop_overhead_cycles: 1,
            load_cycles_per_op: 2,
            compute_efficiency: 0.87,
        }
    }
}

impl ClusterConfig {
    /// Peak FLOP/cycle of the whole cluster for `fmt` (2 FLOP per FMA per
    /// SIMD lane per core). Matches paper Sec. IV-A1: 16/32/64/128.
    pub fn peak_flop_per_cycle(&self, fmt: FpFormat) -> u64 {
        2 * fmt.simd_lanes() * self.compute_cores
    }
}

/// Bandwidths / latencies of the hierarchical interconnect (Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterconnectConfig {
    /// Cluster-to-SPM peak bandwidth, GB/s (level 0).
    pub spm_bw_gbps: f64,
    /// Per-link cluster-to-cluster bandwidth inside a group, GB/s.
    pub intra_group_link_gbps: f64,
    /// Per-link group-to-group bandwidth, GB/s.
    pub inter_group_link_gbps: f64,
    /// Aggregate HBM bandwidth over all channels, GB/s.
    pub hbm_bw_gbps: f64,
    /// HBM channels.
    pub hbm_channels: u64,
    /// Sustained per-cluster HBM bandwidth in bytes/cycle (paper: 56
    /// B/cycle measured with 4 clusters/group, reads and writes alike).
    pub per_cluster_hbm_bytes_per_cycle: f64,
    /// HBM round-trip latency, ns (paper: 88 ns per channel).
    pub hbm_latency_ns: f64,
    /// DMA transfer setup time, ns (paper: 27 ns measured from RTL).
    pub dma_setup_ns: f64,
    /// Fraction of HBM bandwidth the AR-mode GEMV access pattern sustains
    /// (short strided weight rows, no reuse, one token in flight).
    /// Calibrated to Table III's <10% AR FPU utilization and the Fig. 9 AR
    /// throughput range; NAR's blocked GEMMs are unaffected.
    pub gemv_hbm_efficiency: f64,
    /// Total HBM capacity in bytes (8 HBM2E channels). Bounds what the
    /// serving coordinator may resident-ize: model weights + the KV
    /// caches of all admitted requests must fit.
    pub hbm_capacity_bytes: u64,
}

impl Default for InterconnectConfig {
    fn default() -> Self {
        InterconnectConfig {
            spm_bw_gbps: 256.0,
            intra_group_link_gbps: 64.0,
            inter_group_link_gbps: 64.0,
            hbm_bw_gbps: 410.0,
            hbm_channels: 8,
            per_cluster_hbm_bytes_per_cycle: 56.0,
            hbm_latency_ns: 88.0,
            dma_setup_ns: 27.0,
            gemv_hbm_efficiency: 0.15,
            hbm_capacity_bytes: 32 * (1 << 30),
        }
    }
}

impl InterconnectConfig {
    /// Static cost of one DMA transfer touching main memory:
    /// setup + HBM round trip (paper Sec. VI-B: 115 ns total).
    pub fn dma_static_overhead_ns(&self) -> f64 {
        self.dma_setup_ns + self.hbm_latency_ns
    }
}

/// Die-to-die interconnect of a multi-die package (paper Sec. IV-B: the
/// hierarchical interconnect's top level — "wide" links with dedicated
/// DMA engines bridging dies). One die is the full G x C cluster platform
/// below; the parallelism subsystem (`crate::parallel`) prices tensor/
/// pipeline/data-parallel shard plans across `dies` of them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DieLinkConfig {
    /// Dies in the package (1 = the single-die silicon; collectives and
    /// shard plans degenerate to no-ops).
    pub dies: u32,
    /// Per-direction die-to-die link bandwidth, GB/s. Modeled after the
    /// Occamy wide link: on the order of the inter-group crossbar.
    pub link_gbps: f64,
    /// Die-to-die hop latency, ns (serdes + channel, longer than the 88 ns
    /// on-die HBM round trip).
    pub latency_ns: f64,
    /// Dedicated die-to-die DMA engines per die. Concurrent transfers a
    /// die drives beyond this share the link bandwidth (the contention
    /// model of `parallel::collectives`).
    pub dma_engines: u64,
}

impl Default for DieLinkConfig {
    fn default() -> Self {
        DieLinkConfig {
            dies: 1,
            link_gbps: 64.0,
            latency_ns: 150.0,
            dma_engines: 2,
        }
    }
}

/// Memory hierarchy level a transfer source/destination lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemLevel {
    /// Cluster-local L1 scratchpad.
    Spm,
    /// Another cluster's SPM in the same group.
    PeerClusterSameGroup,
    /// Another cluster's SPM in a different group.
    PeerClusterOtherGroup,
    /// Main HBM memory.
    Hbm,
}

/// The full scalable platform: G groups x C clusters (paper Sec. IV-B).
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformConfig {
    /// Groups (G).
    pub groups: u32,
    /// Clusters per group (C).
    pub clusters_per_group: u32,
    /// Core clock in GHz (paper: 1 GHz, 12 nm).
    pub freq_ghz: f64,
    pub cluster: ClusterConfig,
    pub interconnect: InterconnectConfig,
    /// Die-to-die package topology (dies = 1 on the single-die silicon).
    pub die: DieLinkConfig,
    pub features: Features,
}

impl PlatformConfig {
    /// The paper's measured configuration: 16 clusters (4 groups x 4),
    /// silicon-proven in Occamy, all extensions enabled.
    pub fn occamy() -> PlatformConfig {
        PlatformConfig {
            groups: 4,
            clusters_per_group: 4,
            freq_ghz: 1.0,
            cluster: ClusterConfig::default(),
            interconnect: InterconnectConfig::default(),
            die: DieLinkConfig::default(),
            features: Features::all(),
        }
    }

    /// A multi-die package of `dies` Occamy dies (each the full 16-cluster
    /// silicon) joined by the wide die-to-die links. The per-die compute
    /// and memory model is unchanged; `crate::parallel` maps shard plans
    /// onto the dies.
    pub fn with_dies(dies: u32) -> PlatformConfig {
        assert!(dies > 0, "need at least one die");
        PlatformConfig {
            die: DieLinkConfig { dies, ..DieLinkConfig::default() },
            ..PlatformConfig::occamy()
        }
    }

    /// Baseline ablation: same silicon, extensions and c2c disabled
    /// (the leftmost bars of Fig. 7/8).
    pub fn occamy_baseline() -> PlatformConfig {
        PlatformConfig {
            features: Features::baseline(),
            ..PlatformConfig::occamy()
        }
    }

    /// A platform with `n` total clusters, grouped 4-per-group like the
    /// silicon (used by the Fig. 9 cluster-scaling sweep).
    pub fn with_clusters(n: u32) -> PlatformConfig {
        assert!(n > 0, "need at least one cluster");
        let (groups, cpg) = if n <= 4 { (1, n) } else { ((n + 3) / 4, 4) };
        assert_eq!(groups * cpg, n, "cluster count must be 1-4 or a multiple of 4");
        PlatformConfig {
            groups,
            clusters_per_group: cpg,
            ..PlatformConfig::occamy()
        }
    }

    /// Total clusters C*G.
    pub fn total_clusters(&self) -> u32 {
        self.groups * self.clusters_per_group
    }

    /// Total compute cores.
    pub fn total_cores(&self) -> u64 {
        self.total_clusters() as u64 * self.cluster.compute_cores
    }

    /// Platform peak GFLOPS for `fmt` (SIMD assumed on; the *baseline*
    /// ablation caps lanes at 1 inside the core model instead).
    pub fn peak_gflops(&self, fmt: FpFormat) -> f64 {
        self.total_clusters() as f64
            * self.cluster.peak_flop_per_cycle(fmt) as f64
            * self.freq_ghz
    }

    /// Convert wall-clock ns to core cycles (rounded up).
    pub fn ns_to_cycles(&self, ns: f64) -> u64 {
        (ns * self.freq_ghz).ceil() as u64
    }

    /// Convert cycles to seconds.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_ghz * 1e9)
    }

    /// Bytes/cycle available on a link of `level` for one cluster.
    pub fn link_bytes_per_cycle(&self, level: MemLevel) -> f64 {
        let gbps = match level {
            MemLevel::Spm => self.interconnect.spm_bw_gbps,
            MemLevel::PeerClusterSameGroup => self.interconnect.intra_group_link_gbps,
            MemLevel::PeerClusterOtherGroup => self.interconnect.inter_group_link_gbps,
            MemLevel::Hbm => {
                return self.interconnect.per_cluster_hbm_bytes_per_cycle;
            }
        };
        gbps / self.freq_ghz
    }
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig::occamy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_bandwidths() {
        let p = PlatformConfig::occamy();
        assert_eq!(p.link_bytes_per_cycle(MemLevel::Spm), 256.0);
        assert_eq!(p.link_bytes_per_cycle(MemLevel::PeerClusterSameGroup), 64.0);
        assert_eq!(p.link_bytes_per_cycle(MemLevel::Hbm), 56.0);
    }

    #[test]
    fn cycles_conversions() {
        let p = PlatformConfig::occamy();
        assert_eq!(p.ns_to_cycles(88.0), 88);
        assert!((p.cycles_to_seconds(1_000_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn invalid_cluster_count_panics() {
        PlatformConfig::with_clusters(6);
    }

    #[test]
    fn total_cores_occamy() {
        assert_eq!(PlatformConfig::occamy().total_cores(), 128);
    }

    #[test]
    fn hbm_capacity_is_32_gib() {
        let p = PlatformConfig::occamy();
        assert_eq!(p.interconnect.hbm_capacity_bytes, 32 * (1u64 << 30));
    }

    #[test]
    fn single_die_by_default_and_with_dies_scales() {
        assert_eq!(PlatformConfig::occamy().die.dies, 1);
        let p = PlatformConfig::with_dies(4);
        assert_eq!(p.die.dies, 4);
        // The per-die platform below is unchanged.
        assert_eq!(p.total_clusters(), 16);
        assert_eq!(p.total_cores(), 128);
    }

    #[test]
    #[should_panic]
    fn zero_dies_panics() {
        PlatformConfig::with_dies(0);
    }
}
