//! Shard plans: tensor / pipeline / data parallelism across dies, and
//! the sharded pricing built on them.
//!
//! A [`ShardPlan`] maps a model onto `tp * pp * replicas` dies:
//!
//! * `tp` — tensor-parallel ranks per pipeline stage. Each block's
//!   projections are column/row-split Megatron-style
//!   ([`crate::model::block_layers_sharded`]); the row-split halves leave
//!   partial activations that cost one all-reduce each per block. KV
//!   heads split with the attention heads, so each rank stores `1/tp` of
//!   every request's KV pages — the per-replica paged-KV pool grows
//!   accordingly ([`ShardPlan::replica_kv_budget_bytes`]).
//! * `pp` — pipeline stages. Blocks are cut into `pp` contiguous runs;
//!   each stage boundary ships the `rows x E` activations to the next
//!   stage's die ([`collectives::p2p_cost`]).
//! * `replicas` — data-parallel engine replicas, each a full `tp x pp`
//!   instance served by the replica router ([`super::router`]).
//!
//! The degenerate plan `tp = 1, pp = 1, replicas = 1` prices
//! bit-identically to [`block_cost_batched`] / the single-engine serve
//! path (asserted in `tests/parallel_plans.rs`).

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::kv_paging::KvGeometry;
use crate::coordinator::schedule::layer_cost;
use crate::model::{block_layers_sharded, Mode, ModelConfig};
use crate::parallel::collectives::{self, Algorithm};
use crate::sim::KernelCost;

/// One way to spread a model over the package's dies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Tensor-parallel ranks per pipeline stage.
    pub tp: u32,
    /// Pipeline stages.
    pub pp: u32,
    /// Data-parallel engine replicas.
    pub replicas: u32,
}

impl ShardPlan {
    /// The degenerate single-engine plan (bit-identical to today's
    /// pricing and scheduling).
    pub fn single() -> ShardPlan {
        ShardPlan { tp: 1, pp: 1, replicas: 1 }
    }

    /// Dies the plan occupies.
    pub fn dies(&self) -> u32 {
        self.tp * self.pp * self.replicas
    }

    /// Why this plan cannot run `cfg` on `platform`, or `None` if legal:
    /// every factor >= 1, the dies fit the package, `tp` divides the
    /// head and MLP dimensions (column/row splits must be exact), and
    /// `pp` does not exceed the block count.
    pub fn legality_error(&self, cfg: &ModelConfig, platform: &PlatformConfig) -> Option<String> {
        if self.tp == 0 || self.pp == 0 || self.replicas == 0 {
            return Some("tp/pp/replicas must all be >= 1".into());
        }
        if self.dies() > platform.die.dies {
            return Some(format!(
                "plan needs {} dies, package has {}",
                self.dies(),
                platform.die.dies
            ));
        }
        if cfg.heads % self.tp as u64 != 0 {
            return Some(format!("tp={} does not divide heads={}", self.tp, cfg.heads));
        }
        if cfg.ff % self.tp as u64 != 0 {
            return Some(format!("tp={} does not divide ff={}", self.tp, cfg.ff));
        }
        if self.pp as u64 > cfg.blocks {
            return Some(format!("pp={} exceeds blocks={}", self.pp, cfg.blocks));
        }
        None
    }

    pub fn is_legal(&self, cfg: &ModelConfig, platform: &PlatformConfig) -> bool {
        self.legality_error(cfg, platform).is_none()
    }

    /// Blocks per pipeline stage (earlier stages take the remainder).
    pub fn stage_blocks(&self, cfg: &ModelConfig) -> Vec<u64> {
        let pp = self.pp.max(1) as u64;
        let base = cfg.blocks / pp;
        let extra = cfg.blocks % pp;
        (0..pp).map(|i| base + u64::from(i < extra)).collect()
    }

    /// The KV budget ONE replica of this plan offers the serving
    /// scheduler, expressed in whole-model token bytes (what the
    /// batcher's [`KvGeometry`] accounts in).
    ///
    /// Each die holds its `1/(tp*pp)` weight shard, leaving
    /// `hbm_capacity - weights/(tp*pp)` bytes for KV. A cached token
    /// costs a die only its share — `token_bytes * stage_share / tp`
    /// (KV heads split across TP ranks, blocks across stages) — so the
    /// replica's capacity in tokens is bounded by its most loaded stage,
    /// and that capacity is handed back in full-token bytes. The single
    /// plan reproduces `platform_kv_budget_bytes` exactly.
    pub fn replica_kv_budget_bytes(
        &self,
        cfg: &ModelConfig,
        fmt: FpFormat,
        platform: &PlatformConfig,
    ) -> u64 {
        if self.tp <= 1 && self.pp <= 1 {
            // Exactly the single-engine budget formula, bit-for-bit.
            return platform
                .interconnect
                .hbm_capacity_bytes
                .saturating_sub(cfg.weight_bytes(fmt));
        }
        let shards = self.tp as u64 * self.pp as u64;
        let per_die_weights = cfg.weight_bytes(fmt) / shards.max(1);
        let per_die_free = platform
            .interconnect
            .hbm_capacity_bytes
            .saturating_sub(per_die_weights);
        let token_bytes = KvGeometry::new(cfg, fmt, 1).token_bytes.max(1);
        let max_stage = self.stage_blocks(cfg).into_iter().max().unwrap_or(cfg.blocks);
        // A die on the most loaded stage stores this much of each token.
        let per_die_token = (token_bytes * max_stage)
            .div_ceil(cfg.blocks.max(1))
            .div_ceil((self.tp as u64).max(1))
            .max(1);
        (per_die_free / per_die_token) * token_bytes
    }
}

/// Cost of one transformer block on ONE TP rank, including the induced
/// all-reduces (cheapest of ring/tree per payload). At `tp = 1` this is
/// bit-identical to `block_cost_batched(...).total`: same layers, same
/// pricing order, no collective.
#[allow(clippy::too_many_arguments)]
pub fn sharded_block_cost(
    cfg: &ModelConfig,
    tp: u32,
    mode: Mode,
    b: u64,
    s: u64,
    kv_len: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    let sb = block_layers_sharded(cfg, mode, b.max(1), s, kv_len, tp.max(1) as u64);
    let mut total = KernelCost::default();
    for layer in &sb.layers {
        total = total.then(layer_cost(layer, fmt, platform));
    }
    let ranks: Vec<u32> = (0..tp.max(1)).collect();
    for &elems in &sb.allreduce_elems {
        total = total.then(collectives::all_reduce_cost(
            elems * fmt.bytes(),
            &ranks,
            Algorithm::Auto,
            fmt,
            platform,
        ));
    }
    total
}

/// A plan priced on a concrete model pass.
#[derive(Debug, Clone)]
pub struct PlanCost {
    pub plan: ShardPlan,
    /// Per-stage cycles of one pass (blocks share + TP collectives).
    pub stage_cycles: Vec<u64>,
    /// One token (AR) / one pass (NAR) through the whole pipe: the sum of
    /// the stages plus the inter-stage activation sends.
    pub token_latency_cycles: u64,
    /// Steady-state step cycles with the pipe full (the slowest stage
    /// plus its outbound send) — the per-replica throughput bound.
    pub steady_cycles: u64,
    /// Aggregate resources of one pass across all of one replica's dies.
    pub total: KernelCost,
    /// Aggregate tokens/s across all replicas at the priced batch.
    pub tokens_per_s: f64,
}

/// Price one model pass under `plan`: per-stage sharded block costs, the
/// pipeline's activation sends, pipe latency and steady-state rate, and
/// the aggregate tokens/s `replicas` such engines deliver.
///
/// In AR mode `s` is the KV length and each pass advances `b` tokens per
/// replica; in NAR mode each pass produces `b * s` tokens. Pipeline
/// stages are assumed kept full by independent requests (the serving
/// router's job), so the steady rate is bounded by the slowest stage.
pub fn plan_cost(
    cfg: &ModelConfig,
    plan: ShardPlan,
    mode: Mode,
    b: u64,
    s: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> PlanCost {
    let plan = ShardPlan {
        tp: plan.tp.max(1),
        pp: plan.pp.max(1),
        replicas: plan.replicas.max(1),
    };
    let b = b.max(1);
    let (bs, kv) = match mode {
        Mode::Nar => (s, 0),
        Mode::Ar => (1, s),
    };
    let one = sharded_block_cost(cfg, plan.tp, mode, b, bs, kv, fmt, platform);
    let stage_blocks = plan.stage_blocks(cfg);
    let stage_cycles: Vec<u64> =
        stage_blocks.iter().map(|&blocks| one.cycles * blocks).collect();

    // Each boundary ships the b*rows x E activations; the tp ranks of a
    // stage each send their row shard to the peer rank in parallel.
    let rows = b * bs;
    let send_bytes = (rows * cfg.e * fmt.bytes()).div_ceil(plan.tp as u64);
    let send = if plan.pp > 1 {
        collectives::p2p_cost(send_bytes, platform)
    } else {
        KernelCost::default()
    };

    let mut total = KernelCost::default();
    for &blocks in &stage_blocks {
        total = total.then(one.repeat(blocks));
    }
    for _ in 1..plan.pp {
        total = total.then(send);
    }

    let token_latency_cycles = stage_cycles.iter().sum::<u64>()
        + (plan.pp as u64 - 1) * send.cycles;
    let steady_cycles = stage_cycles
        .iter()
        .enumerate()
        .map(|(i, &c)| c + if i + 1 < plan.pp as usize { send.cycles } else { 0 })
        .max()
        .unwrap_or(0);

    let tokens_per_pass = match mode {
        Mode::Nar => b * s,
        Mode::Ar => b,
    };
    let steady_s = platform.cycles_to_seconds(steady_cycles.max(1));
    let tokens_per_s = plan.replicas as f64 * tokens_per_pass as f64 / steady_s;

    PlanCost {
        plan,
        stage_cycles,
        token_latency_cycles,
        steady_cycles,
        total,
        tokens_per_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::block_cost_batched;

    #[test]
    fn stage_blocks_cover_all_blocks() {
        let cfg = ModelConfig::gpt_j(); // 28 blocks
        for pp in [1u32, 2, 3, 4, 7] {
            let plan = ShardPlan { tp: 1, pp, replicas: 1 };
            let stages = plan.stage_blocks(&cfg);
            assert_eq!(stages.len(), pp as usize);
            assert_eq!(stages.iter().sum::<u64>(), cfg.blocks);
            assert!(stages.iter().max().unwrap() - stages.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn legality_rules() {
        let cfg = ModelConfig::gpt_j(); // 16 heads
        let p = PlatformConfig::with_dies(4);
        assert!(ShardPlan::single().is_legal(&cfg, &p));
        assert!(ShardPlan { tp: 2, pp: 2, replicas: 1 }.is_legal(&cfg, &p));
        // Too many dies.
        assert!(!ShardPlan { tp: 4, pp: 2, replicas: 1 }.is_legal(&cfg, &p));
        // tp must divide heads (ViT-B has 12).
        let vit = ModelConfig::vit_b();
        assert!(!ShardPlan { tp: 8, pp: 1, replicas: 1 }
            .is_legal(&vit, &PlatformConfig::with_dies(8)));
        assert!(ShardPlan { tp: 4, pp: 1, replicas: 1 }
            .is_legal(&vit, &PlatformConfig::with_dies(8)));
        // pp bounded by blocks.
        let tiny = ModelConfig::tiny(); // 2 blocks
        assert!(!ShardPlan { tp: 1, pp: 3, replicas: 1 }
            .is_legal(&tiny, &PlatformConfig::with_dies(8)));
    }

    #[test]
    fn single_plan_budget_matches_platform_budget() {
        use crate::coordinator::kv_paging::platform_kv_budget_bytes;
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::occamy();
        for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
            let single = ShardPlan::single().replica_kv_budget_bytes(&cfg, fmt, &p);
            assert_eq!(single, platform_kv_budget_bytes(&cfg, fmt, &p));
        }
    }

    #[test]
    fn tp_sharding_grows_the_replica_kv_pool() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let single = ShardPlan::single().replica_kv_budget_bytes(&cfg, fmt, &p);
        let tp2 = ShardPlan { tp: 2, pp: 1, replicas: 1 }
            .replica_kv_budget_bytes(&cfg, fmt, &p);
        // Two dies hold half the weights each and split every token's KV
        // heads: the replica fits strictly more tokens.
        assert!(tp2 > single, "tp2 {tp2} !> single {single}");
    }

    #[test]
    fn sharded_tp1_block_cost_bit_identical() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::occamy();
        for (mode, b, s, kv) in
            [(Mode::Nar, 1, 256, 0), (Mode::Nar, 4, 64, 512), (Mode::Ar, 8, 1, 1024)]
        {
            for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
                let sharded = sharded_block_cost(&cfg, 1, mode, b, s, kv, fmt, &p);
                let batched = block_cost_batched(&cfg, mode, b, s, kv, fmt, &p).total;
                assert_eq!(sharded, batched, "{mode:?} b={b} s={s} {fmt:?}");
            }
        }
    }

    #[test]
    fn tp_sharding_cuts_decode_step_latency() {
        // GPT-J decode is weight-streaming-bound: halving each rank's
        // weight stream must beat the (activation-sized) all-reduce.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let one = plan_cost(&cfg, ShardPlan::single(), Mode::Ar, 4, 1024, fmt, &p);
        let tp2 = plan_cost(
            &cfg,
            ShardPlan { tp: 2, pp: 1, replicas: 1 },
            Mode::Ar,
            4,
            1024,
            fmt,
            &p,
        );
        assert!(
            tp2.token_latency_cycles < one.token_latency_cycles,
            "tp2 {} !< single {}",
            tp2.token_latency_cycles,
            one.token_latency_cycles
        );
        assert!(tp2.total.d2d_bytes > 0, "the all-reduce must show up as d2d traffic");
    }

    #[test]
    fn pipeline_raises_steady_rate_but_not_latency() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let one = plan_cost(&cfg, ShardPlan::single(), Mode::Ar, 4, 1024, fmt, &p);
        let pp4 = plan_cost(
            &cfg,
            ShardPlan { tp: 1, pp: 4, replicas: 1 },
            Mode::Ar,
            4,
            1024,
            fmt,
            &p,
        );
        // A 4-stage pipe steps ~4x faster once full...
        assert!(pp4.steady_cycles < one.steady_cycles / 2);
        assert!(pp4.tokens_per_s > one.tokens_per_s);
        // ...but a single token still traverses every block plus sends.
        assert!(pp4.token_latency_cycles >= one.token_latency_cycles);
    }

    #[test]
    fn replicas_multiply_throughput_only() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let one = plan_cost(&cfg, ShardPlan::single(), Mode::Ar, 4, 1024, fmt, &p);
        let dp4 = plan_cost(
            &cfg,
            ShardPlan { tp: 1, pp: 1, replicas: 4 },
            Mode::Ar,
            4,
            1024,
            fmt,
            &p,
        );
        assert_eq!(dp4.token_latency_cycles, one.token_latency_cycles);
        assert!((dp4.tokens_per_s - 4.0 * one.tokens_per_s).abs() < 1e-6);
    }
}
