//! End-to-end inference pricing: full NAR passes, AR generation loops,
//! and the run reports the CLI/benches print.

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::breakdown::Breakdown;
use crate::coordinator::schedule::{block_cost, model_cost};
use crate::energy;
use crate::metrics;
use crate::model::{Family, Mode, ModelConfig};
use crate::sim::KernelCost;

/// Everything the paper reports about one run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub model: String,
    pub mode: &'static str,
    pub format: &'static str,
    pub seq: u64,
    pub cycles: u64,
    pub seconds: f64,
    /// tokens/s (GPT) or images/s (ViT).
    pub throughput: f64,
    pub throughput_unit: &'static str,
    pub gflops: f64,
    pub fpu_utilization: f64,
    pub power_w: f64,
    pub gflops_per_w: f64,
    pub hbm_gb: f64,
    pub c2c_gb: f64,
}

/// Prices full model passes on the simulated platform.
#[derive(Debug, Clone)]
pub struct InferenceEngine {
    pub platform: PlatformConfig,
}

impl InferenceEngine {
    pub fn new(platform: PlatformConfig) -> InferenceEngine {
        InferenceEngine { platform }
    }

    fn report(
        &self,
        cfg: &ModelConfig,
        mode: Mode,
        fmt: FpFormat,
        seq: u64,
        cost: KernelCost,
        throughput: f64,
        unit: &'static str,
    ) -> RunReport {
        let power = energy::power_report(&cost, fmt, &self.platform);
        RunReport {
            model: cfg.name.clone(),
            mode: match mode {
                Mode::Nar => "nar",
                Mode::Ar => "ar",
            },
            format: fmt.name(),
            seq,
            cycles: cost.cycles,
            seconds: self.platform.cycles_to_seconds(cost.cycles),
            throughput,
            throughput_unit: unit,
            gflops: metrics::achieved_gflops(&cost, &self.platform),
            fpu_utilization: power.fpu_utilization,
            power_w: power.power_w,
            gflops_per_w: power.gflops_per_w,
            hbm_gb: cost.hbm_bytes() as f64 / 1e9,
            c2c_gb: cost.c2c_bytes as f64 / 1e9,
        }
    }

    /// One NAR pass (prompt encoding / ViT classification / training fwd):
    /// produces `seq` tokens (GPT) or one classification (ViT).
    pub fn run_nar(&self, cfg: &ModelConfig, seq: u64, fmt: FpFormat) -> RunReport {
        let mc = model_cost(cfg, Mode::Nar, seq, fmt, &self.platform);
        let (tp, unit) = match cfg.family {
            Family::Gpt => (
                metrics::tokens_per_second_nar(seq, mc.cycles, &self.platform),
                "tokens/s",
            ),
            Family::Vit => {
                (metrics::images_per_second(mc.cycles, &self.platform), "images/s")
            }
        };
        self.report(cfg, Mode::Nar, fmt, seq, mc.total, tp, unit)
    }

    /// Steady-state AR decode at KV length `seq`: cycles for ONE token.
    pub fn run_ar_step(&self, cfg: &ModelConfig, seq: u64, fmt: FpFormat) -> RunReport {
        let mc = model_cost(cfg, Mode::Ar, seq, fmt, &self.platform);
        let tp = metrics::tokens_per_second_ar(mc.cycles, &self.platform);
        self.report(cfg, Mode::Ar, fmt, seq, mc.total, tp, "tokens/s")
    }

    /// Full generation: prefill `prompt_len` tokens (NAR) then decode
    /// `gen_tokens` autoregressively, KV growing each step.
    pub fn run_generate(
        &self,
        cfg: &ModelConfig,
        prompt_len: u64,
        gen_tokens: u64,
        fmt: FpFormat,
    ) -> RunReport {
        let mut total = model_cost(cfg, Mode::Nar, prompt_len, fmt, &self.platform).total;
        for t in 0..gen_tokens {
            let kv = prompt_len + t;
            let step = block_cost(cfg, Mode::Ar, 1, kv, fmt, &self.platform)
                .total
                .repeat(cfg.blocks);
            total = total.then(step);
        }
        let tp = if total.cycles > 0 {
            gen_tokens as f64 / self.platform.cycles_to_seconds(total.cycles)
        } else {
            0.0
        };
        self.report(cfg, Mode::Ar, fmt, prompt_len + gen_tokens, total, tp, "tokens/s")
    }

    /// Fig. 10 latency breakdown for a pass.
    pub fn breakdown(&self, cfg: &ModelConfig, mode: Mode, seq: u64, fmt: FpFormat) -> Breakdown {
        let mc = model_cost(cfg, mode, seq, fmt, &self.platform);
        Breakdown::from_cost(&mc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> InferenceEngine {
        InferenceEngine::new(PlatformConfig::occamy())
    }

    #[test]
    fn nar_utilization_in_paper_band() {
        // Table III: GPT-J S=1024 NAR utilizations 65-80%.
        let e = engine();
        let cfg = ModelConfig::gpt_j();
        for (fmt, lo, hi) in [
            (FpFormat::Fp64, 0.55, 0.95),
            (FpFormat::Fp32, 0.55, 0.95),
            (FpFormat::Fp16, 0.45, 0.90),
            (FpFormat::Fp8, 0.40, 0.85),
        ] {
            let r = e.run_nar(&cfg, 1024, fmt);
            assert!(
                (lo..=hi).contains(&r.fpu_utilization),
                "{fmt}: util {}",
                r.fpu_utilization
            );
        }
    }

    #[test]
    fn ar_utilization_below_15pct() {
        // Table III: AR utilization < 10% at every precision.
        let e = engine();
        let cfg = ModelConfig::gpt_j();
        for fmt in FpFormat::LADDER {
            let r = e.run_ar_step(&cfg, 1024, fmt);
            assert!(r.fpu_utilization < 0.15, "{fmt}: util {}", r.fpu_utilization);
            assert!(r.fpu_utilization > 0.005, "{fmt}: util {}", r.fpu_utilization);
        }
    }

    #[test]
    fn nar_beats_ar_in_utilization() {
        let e = engine();
        let cfg = ModelConfig::gpt3_xl();
        let nar = e.run_nar(&cfg, 1024, FpFormat::Fp32);
        let ar = e.run_ar_step(&cfg, 1024, FpFormat::Fp32);
        assert!(nar.fpu_utilization > 5.0 * ar.fpu_utilization);
    }

    #[test]
    fn vit_reports_images_per_second() {
        let e = engine();
        let r = e.run_nar(&ModelConfig::vit_b(), 197, FpFormat::Fp8);
        assert_eq!(r.throughput_unit, "images/s");
        // Paper: 26 images/s for ViT-B FP8 — same order of magnitude.
        assert!(r.throughput > 5.0 && r.throughput < 120.0, "{}", r.throughput);
    }

    #[test]
    fn generate_slower_than_single_step_estimate() {
        let e = engine();
        let cfg = ModelConfig::tiny();
        let gen = e.run_generate(&cfg, 16, 8, FpFormat::Fp32);
        let step = e.run_ar_step(&cfg, 16, FpFormat::Fp32);
        assert!(gen.cycles > step.cycles, "prefill + 8 steps > 1 step");
    }

    #[test]
    fn power_between_idle_and_max() {
        let e = engine();
        let r = e.run_nar(&ModelConfig::gpt_j(), 1024, FpFormat::Fp32);
        assert!(r.power_w > energy::P_STATIC_W);
        assert!(r.power_w < energy::P_STATIC_W + energy::P_ACTIVE_W);
    }
}
