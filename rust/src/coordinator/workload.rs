//! Serving workloads: the requests a multi-user deployment throws at the
//! platform (the ROADMAP's "heavy traffic" scenario the single-request
//! engine could not even express).
//!
//! A [`Request`] is a prompt to prefill plus a number of tokens to decode,
//! stamped with an arrival time (open-loop traces) and a priority class;
//! a [`Workload`] is the trace of requests handed to the continuous
//! batcher. Synthetic workloads are generated with a seeded LCG so every
//! serving experiment is exactly reproducible.

use crate::arch::FpFormat;
use crate::coordinator::kv_cache::KvCache;
use crate::model::ModelConfig;

/// One inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Stable id (index in the workload; reports key on it).
    pub id: usize,
    /// Prompt tokens to prefill (NAR pass).
    pub prompt_len: u64,
    /// Tokens to generate autoregressively.
    pub gen_tokens: u64,
    /// Arrival time in nanoseconds since trace start (0 = closed-loop
    /// "all offered at once", the legacy behavior).
    pub arrival_ns: u64,
    /// Priority class: 0 is most urgent, larger is more patient. The
    /// scheduler ages waiting requests toward class 0 so no class starves.
    pub class: u8,
    /// Leading prompt tokens drawn from a shared content template (a
    /// system prompt / few-shot preamble); 0 = fully unique content.
    /// Requests with the same `prefix_seed` have content-identical
    /// prompts over `min(prefix_len)` leading tokens, which is what the
    /// prefix cache deduplicates.
    pub prefix_len: u64,
    /// Content identity of the shared template (only meaningful when
    /// `prefix_len > 0`).
    pub prefix_seed: u64,
    /// The prompt's KV pages arrive pre-materialized from another pool
    /// (disaggregated serving: prefill ran on a prefill die and the pages
    /// were migrated here). The batcher admits such a request directly
    /// into decode — no prefill passes — but a preemption falls back to
    /// ordinary recompute, since the migrated copy is gone.
    pub kv_imported: bool,
}

/// SplitMix64 finalizer: the content/identity mixer behind the modeled
/// prompt tokens and the page-hash chains (the simulator stores no real
/// token ids — serving only needs content *identity* for prefix dedup).
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl Request {
    /// A class-0 request arriving at t=0 with unique prompt content.
    pub fn new(id: usize, prompt_len: u64, gen_tokens: u64) -> Request {
        Request {
            id,
            prompt_len,
            gen_tokens,
            arrival_ns: 0,
            class: 0,
            prefix_len: 0,
            prefix_seed: 0,
            kv_imported: false,
        }
    }

    /// Set the priority class (0 = most urgent).
    pub fn with_class(mut self, class: u8) -> Request {
        self.class = class;
        self
    }

    /// Set the arrival timestamp (nanoseconds since trace start).
    pub fn with_arrival_ns(mut self, arrival_ns: u64) -> Request {
        self.arrival_ns = arrival_ns;
        self
    }

    /// Mark the prompt's KV as migrated in from another pool (see
    /// [`Request::kv_imported`]).
    pub fn with_imported_kv(mut self) -> Request {
        self.kv_imported = true;
        self
    }

    /// Mark the first `prefix_len` prompt tokens as drawn from the shared
    /// template `prefix_seed` (content-identical across requests with the
    /// same seed).
    pub fn with_prefix(mut self, prefix_seed: u64, prefix_len: u64) -> Request {
        self.prefix_seed = prefix_seed;
        self.prefix_len = prefix_len.min(self.prompt_len);
        self
    }

    /// Modeled content id of prompt token `t`: template-derived inside the
    /// shared prefix, request-unique past it.
    pub fn prompt_token_id(&self, t: u64) -> u64 {
        let seed = if t < self.prefix_len {
            self.prefix_seed
        } else {
            splitmix(self.id as u64 ^ 0xC0FF_EE00_D15C_0DE5)
        };
        splitmix(seed ^ splitmix(t.wrapping_add(1)))
    }

    /// Chained content hashes of the prompt's *full* pages at `page_tokens`
    /// granularity: hash `k` commits to every prompt token in pages
    /// `0..=k`, so two requests share hash `k` exactly when their prompts
    /// agree on the first `(k+1) * page_tokens` tokens (vLLM-style block
    /// hashing). The trailing partial page (if any) is excluded — it is
    /// not content-addressable and is where generated tokens land.
    pub fn prompt_page_hashes(&self, page_tokens: u64) -> Vec<u64> {
        let pt = page_tokens.max(1);
        let full = self.prompt_len / pt;
        let mut out = Vec::with_capacity(full as usize);
        let mut h: u64 = 0x243F_6A88_85A3_08D3;
        for page in 0..full {
            for t in page * pt..(page + 1) * pt {
                h = splitmix(h ^ self.prompt_token_id(t));
            }
            out.push(h);
        }
        out
    }

    /// KV slots this request needs at its longest (prompt + generation).
    pub fn kv_capacity(&self) -> u64 {
        self.prompt_len + self.gen_tokens
    }

    /// HBM bytes the request's KV caches occupy across all blocks at full
    /// length, sized exactly like the runtime [`KvCache`] buffers
    /// (f32 K + V).
    pub fn kv_bytes(&self, cfg: &ModelConfig) -> u64 {
        cfg.blocks
            * KvCache::bytes_for(
                cfg.heads as usize,
                self.kv_capacity() as usize,
                cfg.p as usize,
            ) as u64
    }

    /// KV bytes at the given cache precision — full-length, the quantity
    /// the legacy batcher reserved at admission. Exact element-count math
    /// (`capacity * blocks * 2 * heads * p` elements, each `fmt.bytes()`
    /// wide), in lockstep with `KvGeometry::new`; the paged allocator maps
    /// `KvGeometry::token_bytes` (this value divided by `kv_capacity`)
    /// one page at a time.
    pub fn kv_bytes_at(&self, cfg: &ModelConfig, fmt: FpFormat) -> u64 {
        let elems = self.kv_capacity() * cfg.blocks * 2 * cfg.heads * cfg.p;
        debug_assert_eq!(elems * std::mem::size_of::<f32>() as u64, self.kv_bytes(cfg));
        elems * fmt.bytes()
    }
}

/// Deterministic 64-bit LCG shared by the synthetic generators.
#[derive(Debug, Clone)]
struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Lcg {
        Lcg(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1))
    }

    fn next(&mut self, lo: u64, hi: u64) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        lo + (self.0 >> 33) % (hi - lo + 1)
    }

    /// Uniform in (0, 1]. One `next` draw only carries 31 random bits
    /// (the generator emits `state >> 33`), so a 53-bit mantissa is
    /// assembled from two draws.
    fn unit(&mut self) -> f64 {
        let hi = self.next(0, (1 << 27) - 1);
        let lo = self.next(0, (1 << 26) - 1);
        (((hi << 26) | lo) + 1) as f64 / (1u64 << 53) as f64
    }
}

/// A trace of requests to serve.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    /// The requests, in id order.
    pub requests: Vec<Request>,
}

impl Workload {
    /// `n` identical requests (throughput benchmarking).
    pub fn uniform(n: usize, prompt_len: u64, gen_tokens: u64) -> Workload {
        Workload {
            requests: (0..n).map(|id| Request::new(id, prompt_len, gen_tokens)).collect(),
        }
    }

    /// `n` requests with prompt/generation lengths drawn uniformly from
    /// the inclusive ranges by a seeded LCG (deterministic).
    pub fn synthetic(
        seed: u64,
        n: usize,
        prompt_range: (u64, u64),
        gen_range: (u64, u64),
    ) -> Workload {
        let mut rng = Lcg::new(seed);
        let requests = (0..n)
            .map(|id| {
                Request::new(
                    id,
                    rng.next(prompt_range.0, prompt_range.1).max(1),
                    rng.next(gen_range.0, gen_range.1).max(1),
                )
            })
            .collect();
        Workload { requests }
    }

    /// Stamp open-loop Poisson arrivals: exponential inter-arrival gaps at
    /// `rate_per_s` requests/second, drawn from a seeded stream. Requests
    /// keep their id order (= arrival order).
    pub fn with_poisson_arrivals(mut self, seed: u64, rate_per_s: f64) -> Workload {
        assert!(rate_per_s > 0.0, "arrival rate must be positive");
        let mut rng = Lcg::new(seed ^ 0xA1217);
        let mut t_ns = 0u64;
        for r in &mut self.requests {
            let gap_s = -rng.unit().ln() / rate_per_s;
            t_ns += (gap_s * 1e9).round() as u64;
            r.arrival_ns = t_ns;
        }
        self
    }

    /// Prepend a shared system-prompt template to every request's prompt:
    /// groups of `fanout` consecutive requests (by id) share one
    /// `prefix_tokens`-token template, each group drawing a distinct
    /// template. Models the dominant real-world sharing pattern — many
    /// user turns behind a handful of system prompts — the prefix cache
    /// exists to exploit. A no-op when either argument is 0.
    pub fn with_shared_prefix(mut self, prefix_tokens: u64, fanout: usize) -> Workload {
        if prefix_tokens == 0 || fanout == 0 {
            return self;
        }
        for r in &mut self.requests {
            let group = (r.id / fanout) as u64;
            r.prompt_len += prefix_tokens;
            r.prefix_len = prefix_tokens;
            r.prefix_seed = splitmix(0x5EED_0F5E_ED0F_5EED ^ group);
        }
        self
    }

    /// Assign `classes` priority classes round-robin by id (class 0 = most
    /// urgent). A no-op for `classes <= 1`.
    pub fn with_priority_classes(mut self, classes: u8) -> Workload {
        if classes > 1 {
            for r in &mut self.requests {
                r.class = (r.id % classes as usize) as u8;
            }
        }
        self
    }

    /// Number of requests in the trace.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Whether the trace is empty.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Total tokens the workload generates (the numerator of aggregate
    /// tokens/s).
    pub fn total_gen_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.gen_tokens).sum()
    }

    /// Total prompt tokens across requests.
    pub fn total_prompt_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.prompt_len).sum()
    }

    /// Lazy Poisson trace: yields the *same* request stream as
    /// `Workload::uniform(n, ..).with_poisson_arrivals(seed, rate)` (same
    /// seeded LCG, same gap arithmetic — asserted in a test) without
    /// materializing `n` `Request`s up front. Million-request traces cost
    /// O(1) memory on the generator side; the event-driven batcher pulls
    /// one arrival at a time.
    pub fn stream_poisson(
        seed: u64,
        rate_per_s: f64,
        n: usize,
        prompt_len: u64,
        gen_tokens: u64,
    ) -> ArrivalStream {
        assert!(rate_per_s > 0.0, "arrival rate must be positive");
        ArrivalStream::new(seed, n, prompt_len, gen_tokens, RateShape::Constant(rate_per_s))
    }

    /// Lazy diurnal trace: an inhomogeneous Poisson process whose rate
    /// swings sinusoidally between `base_per_s` (trough, at t = 0) and
    /// `peak_per_s` over each `period_s`-second "day". Each inter-arrival
    /// gap is drawn exponentially at the instantaneous rate — a standard
    /// piecewise approximation that keeps the generator O(1) per request
    /// and exactly reproducible from the seed.
    pub fn stream_diurnal(
        seed: u64,
        base_per_s: f64,
        peak_per_s: f64,
        period_s: f64,
        n: usize,
        prompt_len: u64,
        gen_tokens: u64,
    ) -> ArrivalStream {
        assert!(base_per_s > 0.0, "trough arrival rate must be positive");
        assert!(peak_per_s >= base_per_s, "peak rate must be >= base rate");
        assert!(period_s > 0.0, "diurnal period must be positive");
        ArrivalStream::new(
            seed,
            n,
            prompt_len,
            gen_tokens,
            RateShape::Diurnal { base_per_s, peak_per_s, period_s },
        )
    }
}

/// Rate shape of a streamed arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
enum RateShape {
    Constant(f64),
    Diurnal { base_per_s: f64, peak_per_s: f64, period_s: f64 },
}

impl RateShape {
    fn rate_at(&self, t_s: f64) -> f64 {
        match *self {
            RateShape::Constant(r) => r,
            RateShape::Diurnal { base_per_s, peak_per_s, period_s } => {
                let phase = 2.0 * std::f64::consts::PI * (t_s / period_s);
                base_per_s + (peak_per_s - base_per_s) * 0.5 * (1.0 - phase.cos())
            }
        }
    }
}

/// Seeded lazy arrival generator (see [`Workload::stream_poisson`] /
/// [`Workload::stream_diurnal`]): an iterator of `Request`s in
/// non-decreasing arrival order with ascending ids. Cloning snapshots the
/// generator state, so the same trace can be replayed (e.g. once through
/// the event core and once materialized through the legacy loop).
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    rng: Lcg,
    t_ns: u64,
    next_id: usize,
    n: usize,
    prompt_len: u64,
    gen_tokens: u64,
    classes: u8,
    shape: RateShape,
}

impl ArrivalStream {
    fn new(
        seed: u64,
        n: usize,
        prompt_len: u64,
        gen_tokens: u64,
        shape: RateShape,
    ) -> ArrivalStream {
        ArrivalStream {
            // Same derived seed as `with_poisson_arrivals`, so the
            // constant-rate stream is draw-for-draw identical to the
            // materialized stamping.
            rng: Lcg::new(seed ^ 0xA1217),
            t_ns: 0,
            next_id: 0,
            n,
            prompt_len,
            gen_tokens,
            classes: 1,
            shape,
        }
    }

    /// Assign priority classes round-robin by id, matching
    /// [`Workload::with_priority_classes`]. A no-op for `classes <= 1`.
    pub fn with_priority_classes(mut self, classes: u8) -> ArrivalStream {
        self.classes = classes.max(1);
        self
    }

    /// Requests remaining in the stream.
    pub fn remaining(&self) -> usize {
        self.n - self.next_id
    }

    /// Drain the stream into a materialized [`Workload`] (legacy-loop
    /// comparisons and small tests; defeats the purpose at fleet scale).
    pub fn materialize(self) -> Workload {
        Workload { requests: self.collect() }
    }
}

impl Iterator for ArrivalStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.next_id >= self.n {
            return None;
        }
        let rate = self.shape.rate_at(self.t_ns as f64 / 1e9);
        let gap_s = -self.rng.unit().ln() / rate;
        self.t_ns += (gap_s * 1e9).round() as u64;
        let id = self.next_id;
        self.next_id += 1;
        let mut r = Request::new(id, self.prompt_len, self.gen_tokens)
            .with_arrival_ns(self.t_ns);
        if self.classes > 1 {
            r.class = (id % self.classes as usize) as u8;
        }
        Some(r)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.remaining();
        (left, Some(left))
    }
}

/// Arrival process selector (the `serve --arrival` flag).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Closed-loop: every request is offered at t=0 (legacy default).
    Batch,
    /// Open-loop Poisson arrivals at the given rate.
    Poisson {
        /// Mean arrival rate in requests/second.
        rate_per_s: f64,
    },
}

impl Arrival {
    /// Parse `batch` or `poisson:<rate>` (rate in requests/second).
    pub fn parse(s: &str) -> Option<Arrival> {
        if s == "batch" {
            return Some(Arrival::Batch);
        }
        let rate = s.strip_prefix("poisson:")?.parse::<f64>().ok()?;
        (rate > 0.0 && rate.is_finite()).then_some(Arrival::Poisson { rate_per_s: rate })
    }
}

/// Shared-prefix scenario selector (the `serve --shared-prefix` flag):
/// `<tokens>x<fanout>` — groups of `fanout` requests share a
/// `tokens`-token system-prompt template (see
/// [`Workload::with_shared_prefix`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SharedPrefix {
    /// Template length in tokens.
    pub tokens: u64,
    /// Requests per template group.
    pub fanout: usize,
}

impl SharedPrefix {
    /// Parse `<tokens>x<fanout>`, e.g. `2048x8`.
    pub fn parse(s: &str) -> Option<SharedPrefix> {
        let (t, f) = s.split_once('x')?;
        let tokens = t.parse::<u64>().ok()?;
        let fanout = f.parse::<usize>().ok()?;
        (tokens > 0 && fanout > 0).then_some(SharedPrefix { tokens, fanout })
    }
}

/// Map from priority class to compute-precision rung (the
/// `serve --class-precision` flag): urgent classes can buy wider compute
/// while patient bulk traffic rides a narrow rung on the same replica.
///
/// Grammar (strict — every malformed spec is rejected, never silently
/// defaulted): comma-separated `<key>:<fmt>` entries where `<key>` is
/// `hi` (class 0), `lo` (every class >= 1 without an exact entry), or a
/// decimal class number, and `<fmt>` is an [`FpFormat`] name. Duplicate
/// keys (including `hi` vs `0`) are an error. Classes without a matching
/// entry serve at the engine's base format. The rung is resolved from the
/// class the request *arrived* with — aging promotion changes scheduling
/// priority, not precision.
#[derive(Clone, Copy)]
pub struct ClassLadder {
    /// Exact per-class rungs (index = class). `exact[0]` is the `hi` key.
    exact: [Option<FpFormat>; 256],
    /// Fallback rung for classes >= 1 without an exact entry (`lo`).
    low: Option<FpFormat>,
}

impl Default for ClassLadder {
    fn default() -> ClassLadder {
        ClassLadder { exact: [None; 256], low: None }
    }
}

impl PartialEq for ClassLadder {
    fn eq(&self, other: &ClassLadder) -> bool {
        self.low == other.low && self.exact[..] == other.exact[..]
    }
}

impl Eq for ClassLadder {}

impl std::fmt::Debug for ClassLadder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ClassLadder").field("spec", &self.to_spec()).finish()
    }
}

impl ClassLadder {
    /// Parse the strict `--class-precision` grammar (see the type docs).
    pub fn parse(spec: &str) -> Result<ClassLadder, String> {
        let mut out = ClassLadder::default();
        if spec.is_empty() {
            return Ok(out);
        }
        for seg in spec.split(',') {
            let Some((key, fmt_name)) = seg.split_once(':') else {
                return Err(format!("class-precision entry `{seg}` is not <class>:<format>"));
            };
            let Some(fmt) = FpFormat::parse(fmt_name) else {
                return Err(format!("class-precision entry `{seg}`: unknown format `{fmt_name}`"));
            };
            match key {
                "hi" => {
                    if out.exact[0].is_some() {
                        return Err("class-precision maps class 0 (`hi`) twice".into());
                    }
                    out.exact[0] = Some(fmt);
                }
                "lo" => {
                    if out.low.is_some() {
                        return Err("class-precision maps `lo` twice".into());
                    }
                    out.low = Some(fmt);
                }
                _ => {
                    let Ok(class) = key.parse::<u8>() else {
                        return Err(format!(
                            "class-precision entry `{seg}`: key must be `hi`, `lo`, or a class number 0-255"
                        ));
                    };
                    if out.exact[class as usize].is_some() {
                        return Err(format!("class-precision maps class {class} twice"));
                    }
                    out.exact[class as usize] = Some(fmt);
                }
            }
        }
        Ok(out)
    }

    /// The compute rung class `class` serves at, falling back to the
    /// engine's base format. Exact entries win over `lo`; `lo` never
    /// applies to class 0.
    pub fn rung_for(&self, class: u8, default: FpFormat) -> FpFormat {
        self.exact[class as usize]
            .or(if class > 0 { self.low } else { None })
            .unwrap_or(default)
    }

    /// Whether no class is remapped (every request serves at the base
    /// format).
    pub fn is_trivial(&self) -> bool {
        self.low.is_none() && self.exact.iter().all(|e| e.is_none())
    }

    /// Canonical spec string (`hi` first, numeric classes ascending, `lo`
    /// last); empty for the trivial ladder. Round-trips through
    /// [`ClassLadder::parse`].
    pub fn to_spec(&self) -> String {
        let mut parts = Vec::new();
        if let Some(f) = self.exact[0] {
            parts.push(format!("hi:{f}"));
        }
        for (class, f) in self.exact.iter().enumerate().skip(1) {
            if let Some(f) = f {
                parts.push(format!("{class}:{f}"));
            }
        }
        if let Some(f) = self.low {
            parts.push(format!("lo:{f}"));
        }
        parts.join(",")
    }

    /// Every distinct rung the ladder can resolve to (for upfront policy
    /// validation), the base format excluded unless mapped explicitly.
    pub fn rungs(&self) -> Vec<FpFormat> {
        let mut out = Vec::new();
        for f in self.exact.iter().flatten().chain(self.low.iter()) {
            if !out.contains(f) {
                out.push(*f);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_workload_shape() {
        let w = Workload::uniform(4, 128, 32);
        assert_eq!(w.len(), 4);
        assert_eq!(w.total_gen_tokens(), 4 * 32);
        assert_eq!(w.total_prompt_tokens(), 4 * 128);
        assert_eq!(w.requests[3].id, 3);
        assert_eq!(w.requests[0].kv_capacity(), 160);
        assert_eq!(w.requests[0].arrival_ns, 0);
        assert_eq!(w.requests[0].class, 0);
    }

    #[test]
    fn synthetic_deterministic_and_in_range() {
        let a = Workload::synthetic(7, 32, (64, 512), (16, 128));
        let b = Workload::synthetic(7, 32, (64, 512), (16, 128));
        assert_eq!(a.requests, b.requests);
        for r in &a.requests {
            assert!((64..=512).contains(&r.prompt_len), "{r:?}");
            assert!((16..=128).contains(&r.gen_tokens), "{r:?}");
        }
        // Different seeds differ (overwhelmingly likely over 32 draws).
        let c = Workload::synthetic(8, 32, (64, 512), (16, 128));
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn poisson_arrivals_deterministic_monotone_and_rate_shaped() {
        let w = Workload::uniform(256, 64, 16).with_poisson_arrivals(3, 100.0);
        let w2 = Workload::uniform(256, 64, 16).with_poisson_arrivals(3, 100.0);
        assert_eq!(w.requests, w2.requests);
        let mut prev = 0;
        for r in &w.requests {
            assert!(r.arrival_ns >= prev, "{r:?}");
            prev = r.arrival_ns;
        }
        // Mean inter-arrival over 256 draws should land near 1/rate = 10ms
        // (law of large numbers; the band is generous).
        let mean_gap_s = prev as f64 / 1e9 / 256.0;
        assert!((0.005..=0.02).contains(&mean_gap_s), "mean gap {mean_gap_s}");
        // A faster rate compresses the trace.
        let fast = Workload::uniform(256, 64, 16).with_poisson_arrivals(3, 1000.0);
        assert!(fast.requests.last().unwrap().arrival_ns < prev);
    }

    #[test]
    fn stream_poisson_matches_materialized_stamping() {
        // The lazy generator must be draw-for-draw identical to
        // uniform().with_poisson_arrivals() — the event core's streamed
        // serving path relies on it to stay comparable with the legacy
        // loop on the same trace.
        let streamed: Vec<Request> = Workload::stream_poisson(3, 100.0, 256, 64, 16).collect();
        let stamped = Workload::uniform(256, 64, 16).with_poisson_arrivals(3, 100.0);
        assert_eq!(streamed, stamped.requests);
        // materialize() is the same thing packaged as a Workload.
        let w = Workload::stream_poisson(3, 100.0, 256, 64, 16).materialize();
        assert_eq!(w.requests, stamped.requests);
        // Classes ride along round-robin.
        let classy: Vec<u8> = Workload::stream_poisson(3, 100.0, 6, 64, 16)
            .with_priority_classes(3)
            .map(|r| r.class)
            .collect();
        assert_eq!(classy, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn stream_diurnal_modulates_rate_deterministically() {
        let n = 4096;
        let a: Vec<Request> = Workload::stream_diurnal(9, 10.0, 1000.0, 60.0, n, 64, 8).collect();
        let b: Vec<Request> = Workload::stream_diurnal(9, 10.0, 1000.0, 60.0, n, 64, 8).collect();
        assert_eq!(a, b, "seeded stream replays identically");
        assert_eq!(a.len(), n);
        let mut prev = 0;
        for r in &a {
            assert!(r.arrival_ns >= prev, "{r:?}");
            prev = r.arrival_ns;
        }
        // The first quarter-period hugs the trough rate; mid-period runs
        // near the peak, so arrivals bunch there: count arrivals in the
        // trough window [0, 15s) vs the peak window [22.5s, 37.5s).
        let in_window = |lo_s: f64, hi_s: f64| {
            a.iter()
                .filter(|r| {
                    let t = r.arrival_ns as f64 / 1e9;
                    t >= lo_s && t < hi_s
                })
                .count()
        };
        let trough = in_window(0.0, 15.0);
        let peak = in_window(22.5, 37.5);
        assert!(
            peak > trough * 4,
            "diurnal peak window should dominate: trough={trough} peak={peak}"
        );
        // size_hint is exact, so collect() pre-allocates.
        let mut s = Workload::stream_diurnal(9, 10.0, 1000.0, 60.0, 8, 64, 8);
        assert_eq!(s.size_hint(), (8, Some(8)));
        s.next();
        assert_eq!(s.remaining(), 7);
    }

    #[test]
    fn priority_classes_round_robin() {
        let w = Workload::uniform(6, 64, 16).with_priority_classes(3);
        let classes: Vec<u8> = w.requests.iter().map(|r| r.class).collect();
        assert_eq!(classes, vec![0, 1, 2, 0, 1, 2]);
        // <= 1 class is a no-op.
        let w = Workload::uniform(3, 64, 16).with_priority_classes(1);
        assert!(w.requests.iter().all(|r| r.class == 0));
    }

    #[test]
    fn imported_kv_marker_defaults_off() {
        let r = Request::new(0, 64, 16);
        assert!(!r.kv_imported);
        let m = r.clone().with_imported_kv();
        assert!(m.kv_imported);
        // Everything else is untouched — the marker only changes how the
        // batcher admits the request.
        assert_eq!((m.id, m.prompt_len, m.gen_tokens), (r.id, r.prompt_len, r.gen_tokens));
    }

    #[test]
    fn arrival_parse() {
        assert_eq!(Arrival::parse("batch"), Some(Arrival::Batch));
        assert_eq!(
            Arrival::parse("poisson:4.5"),
            Some(Arrival::Poisson { rate_per_s: 4.5 })
        );
        assert_eq!(Arrival::parse("poisson:0"), None);
        assert_eq!(Arrival::parse("poisson:"), None);
        assert_eq!(Arrival::parse("uniform"), None);
    }

    #[test]
    fn shared_prefix_extends_prompts_and_groups_content() {
        let w = Workload::uniform(6, 64, 16).with_shared_prefix(32, 3);
        for r in &w.requests {
            assert_eq!(r.prompt_len, 96);
            assert_eq!(r.prefix_len, 32);
        }
        // Same group -> same template; different groups diverge.
        assert_eq!(w.requests[0].prefix_seed, w.requests[2].prefix_seed);
        assert_ne!(w.requests[0].prefix_seed, w.requests[3].prefix_seed);
        // No-op forms.
        let w0 = Workload::uniform(2, 64, 16).with_shared_prefix(0, 3);
        assert_eq!(w0.requests[0].prefix_len, 0);
        assert_eq!(w0.requests[0].prompt_len, 64);
    }

    #[test]
    fn page_hashes_share_exactly_the_common_prefix() {
        let w = Workload::uniform(4, 64, 16).with_shared_prefix(32, 2);
        let pt = 16;
        let a = w.requests[0].prompt_page_hashes(pt);
        let b = w.requests[1].prompt_page_hashes(pt);
        let c = w.requests[2].prompt_page_hashes(pt);
        // 96-token prompts -> 6 full pages; the 32-token template covers
        // the first two.
        assert_eq!(a.len(), 6);
        assert_eq!(a[..2], b[..2], "template pages identical within a group");
        assert_ne!(a[2], b[2], "user-suffix pages diverge");
        assert_ne!(a[0], c[0], "different templates never match");
        // Chained: even identical suffix content cannot re-align after a
        // divergence (hash k commits to pages 0..=k).
        assert_ne!(a[3], b[3]);
        // Deterministic.
        assert_eq!(a, w.requests[0].prompt_page_hashes(pt));
        // Partial tail pages are excluded.
        let r = Request::new(0, 60, 8);
        assert_eq!(r.prompt_page_hashes(16).len(), 3);
    }

    #[test]
    fn shared_prefix_parse() {
        assert_eq!(
            SharedPrefix::parse("2048x8"),
            Some(SharedPrefix { tokens: 2048, fanout: 8 })
        );
        assert_eq!(SharedPrefix::parse("0x8"), None);
        assert_eq!(SharedPrefix::parse("64x0"), None);
        assert_eq!(SharedPrefix::parse("64"), None);
        assert_eq!(SharedPrefix::parse("x"), None);
    }

    #[test]
    fn kv_bytes_matches_allocated_caches() {
        let cfg = ModelConfig::tiny();
        let r = Request::new(0, 24, 8);
        let one_block =
            KvCache::new(cfg.heads as usize, 32, cfg.p as usize).bytes() as u64;
        assert_eq!(r.kv_bytes(&cfg), cfg.blocks * one_block);
    }

    #[test]
    fn class_ladder_parse_resolve_and_roundtrip() {
        let l = ClassLadder::parse("hi:fp16,lo:fp8").unwrap();
        assert!(!l.is_trivial());
        assert_eq!(l.rung_for(0, FpFormat::Fp32), FpFormat::Fp16);
        assert_eq!(l.rung_for(1, FpFormat::Fp32), FpFormat::Fp8);
        assert_eq!(l.rung_for(255, FpFormat::Fp32), FpFormat::Fp8);
        assert_eq!(l.to_spec(), "hi:fp16,lo:fp8");
        assert_eq!(ClassLadder::parse(&l.to_spec()).unwrap(), l);
        assert_eq!(l.rungs(), vec![FpFormat::Fp16, FpFormat::Fp8]);
        // Exact numeric entries win over `lo`; unmapped classes fall back
        // to the engine format; `lo` never covers class 0.
        let l = ClassLadder::parse("2:bf16,lo:fp8").unwrap();
        assert_eq!(l.rung_for(2, FpFormat::Fp16), FpFormat::Bf16);
        assert_eq!(l.rung_for(1, FpFormat::Fp16), FpFormat::Fp8);
        assert_eq!(l.rung_for(0, FpFormat::Fp16), FpFormat::Fp16);
        assert_eq!(l.to_spec(), "2:bf16,lo:fp8");
        // Trivial forms.
        let t = ClassLadder::parse("").unwrap();
        assert!(t.is_trivial());
        assert_eq!(t.to_spec(), "");
        assert_eq!(t.rung_for(3, FpFormat::Fp16), FpFormat::Fp16);
        assert_eq!(ClassLadder::default(), t);
    }

    #[test]
    fn class_ladder_rejects_malformed_specs() {
        // Strict grammar: nothing silently defaults.
        for bad in [
            "fp16",          // no key
            "hi:",           // empty format
            "hi:fp17",       // unknown format
            "hi:fp16,hi:fp8",// duplicate key
            "0:fp16,hi:fp8", // hi aliases class 0
            "lo:fp8,lo:fp16",// duplicate lo
            "256:fp8",       // class out of u8 range
            "-1:fp8",        // not a class
            "mid:fp8",       // unknown key
            ",",             // empty segments
            "hi:fp16,",      // trailing empty segment
        ] {
            assert!(ClassLadder::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn kv_bytes_scale_with_serving_precision() {
        let cfg = ModelConfig::gpt_j();
        let r = Request::new(0, 1024, 64);
        assert_eq!(r.kv_bytes_at(&cfg, FpFormat::Fp32), r.kv_bytes(&cfg));
        assert_eq!(r.kv_bytes_at(&cfg, FpFormat::Fp8), r.kv_bytes(&cfg) / 4);
        assert_eq!(r.kv_bytes_at(&cfg, FpFormat::Fp16), r.kv_bytes(&cfg) / 2);
    }
}
