//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT-compiled ViT encoder-block artifacts (Pallas+JAX, lowered
//!    at build time) through the PJRT CPU runtime and verify their numerics
//!    against the golden fingerprints — no Python anywhere.
//! 2. Price a full ViT-B inference on the simulated 16-cluster RISC-V
//!    platform and print the paper's metrics (images/s, FPU utilization,
//!    power, GFLOPS/W).
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::ModelConfig;
use snitch_fm::report;
use snitch_fm::runtime::Runtime;

fn main() -> Result<()> {
    // --- numerics through PJRT ------------------------------------------
    let mut rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform_name());
    for name in ["vit_block_tiny", "vit_block_vitb"] {
        let t0 = std::time::Instant::now();
        let outs = rt.run_golden(name, 1e-3)?;
        println!(
            "  {name}: numerics OK ({} outputs, {} elements, {:.1} ms)",
            outs.len(),
            outs[0].len(),
            t0.elapsed().as_secs_f64() * 1e3
        );
    }

    // --- platform timing ---------------------------------------------------
    let engine = InferenceEngine::new(PlatformConfig::occamy());
    let vit_b = ModelConfig::vit_b();
    let mut rows = Vec::new();
    for fmt in FpFormat::LADDER {
        rows.push(engine.run_nar(&vit_b, vit_b.seq, fmt));
    }
    println!();
    println!("ViT-B on the simulated 16-cluster platform:");
    print!("{}", report::runs_table(&rows));
    println!(
        "paper reference: 26 images/s at FP8 (Fig. 8), >79% FPU util (abstract)"
    );
    Ok(())
}
