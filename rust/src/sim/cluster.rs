//! Cluster-level tile pipeline with double buffering (paper Sec. V-B1).
//!
//! A kernel executes on a cluster as a sequence of *tile phases*: load the
//! next tile (DMA), compute on the current tile (8 cores), store results.
//! With double buffering the DMA core preloads tile i+1 while the compute
//! cores chew on tile i, so the steady-state cost per tile is
//! `max(compute, transfer)`; without it the phases serialize.

use crate::arch::{Features, PlatformConfig};
use crate::sim::dma::{DmaEngine, Transfer};
use crate::sim::KernelCost;

/// One tile's worth of work on a cluster.
#[derive(Debug, Clone, Default)]
pub struct TilePhase {
    /// Compute cycles on the slowest core of the cluster for this tile.
    pub compute_cycles: u64,
    /// Transfers the DMA core must complete for this tile (in + out).
    pub transfers: Vec<Transfer>,
    /// Useful FLOPs in this tile (bookkeeping).
    pub flops: u64,
}

impl TilePhase {
    pub fn compute(compute_cycles: u64, flops: u64) -> TilePhase {
        TilePhase { compute_cycles, transfers: Vec::new(), flops }
    }

    pub fn with_transfer(mut self, t: Transfer) -> TilePhase {
        self.transfers.push(t);
        self
    }
}

/// Simulates one cluster executing a pipeline of tile phases.
#[derive(Debug, Clone)]
pub struct ClusterSim {
    pub features: Features,
    pub dma: DmaEngine,
}

impl ClusterSim {
    pub fn new(platform: &PlatformConfig) -> ClusterSim {
        ClusterSim { features: platform.features, dma: DmaEngine::new(platform) }
    }

    /// How many clusters concurrently share the HBM while this kernel runs.
    pub fn with_hbm_sharers(mut self, sharers: u64) -> ClusterSim {
        self.dma = self.dma.with_hbm_sharers(sharers);
        self
    }

    /// Run a pipeline made of homogeneous phase *groups*: `(phase, count)`
    /// means `count` back-to-back repetitions of `phase`. Steady-state
    /// double buffering makes repeated phases cost `max(compute, dma)`
    /// each, so groups collapse to one evaluation + a multiply — the §Perf
    /// fast path that avoids materializing hundreds of thousands of
    /// identical `TilePhase` values for heavily-tiled GEMMs. Group
    /// boundaries use the steady-state approximation (the next group's
    /// DMA overlaps this group's last compute), exact for uniform
    /// pipelines and off by at most one tile at each seam otherwise.
    pub fn run_grouped(&self, groups: &[(TilePhase, u64)]) -> KernelCost {
        let mut cost = KernelCost::default();
        let groups: Vec<&(TilePhase, u64)> = groups.iter().filter(|(_, n)| *n > 0).collect();
        if groups.is_empty() {
            return cost;
        }
        for (p, n) in groups.iter().copied() {
            cost.flops += p.flops * n;
            cost.dma_transfers += p.transfers.len() as u64 * n;
            for t in &p.transfers {
                use crate::arch::MemLevel::*;
                match t.level {
                    Hbm => {
                        if t.write {
                            cost.hbm_write_bytes += t.bytes * n;
                        } else {
                            cost.hbm_read_bytes += t.bytes * n;
                        }
                    }
                    PeerClusterSameGroup | PeerClusterOtherGroup => {
                        cost.c2c_bytes += t.bytes * n
                    }
                    Spm => {}
                }
            }
        }
        let dma: Vec<u64> =
            groups.iter().map(|(p, _)| self.dma.batch_cycles(&p.transfers)).collect();
        let total_compute: u64 =
            groups.iter().map(|(p, n)| p.compute_cycles * n).sum();
        if self.features.double_buffering {
            // Prologue: first group's first DMA; steady state per group.
            let mut cycles = dma[0];
            let mut exposed = dma[0];
            for (i, (p, n)) in groups.iter().copied().enumerate() {
                let step = p.compute_cycles.max(dma[i]);
                cycles += step * n;
                exposed += step.saturating_sub(p.compute_cycles) * n;
            }
            // Epilogue correction: the very last phase has no next DMA to
            // hide, so it costs its compute only — already within the
            // steady-state bound; keep the conservative estimate.
            cost.cycles = cycles;
            cost.compute_cycles = total_compute;
            cost.dma_exposed_cycles = exposed;
        } else {
            let total_dma: u64 = groups.iter().zip(&dma).map(|((_, n), d)| d * n).sum();
            cost.cycles = total_compute + total_dma;
            cost.compute_cycles = total_compute;
            cost.dma_exposed_cycles = total_dma;
        }
        cost
    }

    /// Run a pipeline of tile phases on this cluster and return its cost.
    ///
    /// Double buffering (when enabled and SPM budget was planned for it by
    /// the tiling layer): prologue loads tile 0, then steady state takes
    /// `max(compute_i, dma_{i+1})`, with an epilogue of the last compute
    /// and store. Without double buffering everything serializes.
    pub fn run(&self, phases: &[TilePhase]) -> KernelCost {
        let mut cost = KernelCost::default();
        if phases.is_empty() {
            return cost;
        }
        for p in phases {
            cost.flops += p.flops;
            cost.dma_transfers += p.transfers.len() as u64;
            for t in &p.transfers {
                use crate::arch::MemLevel::*;
                match t.level {
                    Hbm => {
                        if t.write {
                            cost.hbm_write_bytes += t.bytes;
                        } else {
                            cost.hbm_read_bytes += t.bytes;
                        }
                    }
                    PeerClusterSameGroup | PeerClusterOtherGroup => {
                        cost.c2c_bytes += t.bytes
                    }
                    Spm => {}
                }
            }
        }
        let dma_cycles: Vec<u64> =
            phases.iter().map(|p| self.dma.batch_cycles(&p.transfers)).collect();
        let total_compute: u64 = phases.iter().map(|p| p.compute_cycles).sum();
        let total_dma: u64 = dma_cycles.iter().sum();

        if self.features.double_buffering {
            // Prologue: DMA of tile 0 exposed. Steady state: tile i compute
            // overlaps tile i+1 DMA. Epilogue: last compute.
            let mut cycles = dma_cycles[0];
            let mut exposed = dma_cycles[0];
            for i in 0..phases.len() {
                let next_dma = dma_cycles.get(i + 1).copied().unwrap_or(0);
                let step = phases[i].compute_cycles.max(next_dma);
                cycles += step;
                exposed += step.saturating_sub(phases[i].compute_cycles);
            }
            cost.cycles = cycles;
            cost.compute_cycles = total_compute;
            cost.dma_exposed_cycles = exposed;
        } else {
            cost.cycles = total_compute + total_dma;
            cost.compute_cycles = total_compute;
            cost.dma_exposed_cycles = total_dma;
        }
        cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MemLevel;

    fn phases() -> Vec<TilePhase> {
        (0..8)
            .map(|_| {
                TilePhase::compute(1000, 2000)
                    .with_transfer(Transfer::d1(20_000, MemLevel::Hbm))
            })
            .collect()
    }

    #[test]
    fn double_buffering_hides_transfers() {
        let p = PlatformConfig::occamy();
        let db = ClusterSim::new(&p).run(&phases());
        let mut nodb_platform = p.clone();
        nodb_platform.features.double_buffering = false;
        let nodb = ClusterSim::new(&nodb_platform).run(&phases());
        assert!(db.cycles < nodb.cycles);
        // Transfer (115 + 358 = ~473cy) < compute (1000cy): fully hidden in
        // steady state, only the prologue exposed.
        let dma_one = DmaEngine::new(&p).transfer_cycles(Transfer::d1(20_000, MemLevel::Hbm));
        assert_eq!(db.cycles, dma_one + 8 * 1000);
    }

    #[test]
    fn dma_bound_pipeline() {
        // When transfers dominate, steady-state cost per tile is the DMA
        // time, not the compute time.
        let p = PlatformConfig::occamy();
        let big: Vec<TilePhase> = (0..4)
            .map(|_| {
                TilePhase::compute(100, 10)
                    .with_transfer(Transfer::d1(1 << 20, MemLevel::Hbm))
            })
            .collect();
        let cost = ClusterSim::new(&p).run(&big);
        let dma_one =
            DmaEngine::new(&p).transfer_cycles(Transfer::d1(1 << 20, MemLevel::Hbm));
        // prologue + 3 steady DMA steps + final compute-only step
        assert_eq!(cost.cycles, dma_one + 3 * dma_one + 100);
        assert!(cost.dma_exposed_cycles > cost.compute_cycles);
    }

    #[test]
    fn bookkeeping_sums() {
        let p = PlatformConfig::occamy();
        let cost = ClusterSim::new(&p).run(&phases());
        assert_eq!(cost.flops, 8 * 2000);
        assert_eq!(cost.dma_transfers, 8);
        assert_eq!(cost.hbm_read_bytes, 8 * 20_000);
    }

    #[test]
    fn empty_pipeline_is_free() {
        let p = PlatformConfig::occamy();
        assert_eq!(ClusterSim::new(&p).run(&[]).cycles, 0);
    }
}
