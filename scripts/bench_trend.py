#!/usr/bin/env python3
"""Compare the current run's BENCH_*.json artifacts against the previous
CI run's and flag perf regressions.

Usage: bench_trend.py <previous-artifact-dir> <current-dir>

Both directories are searched recursively for BENCH_*.json files
(downloaded artifacts nest under per-artifact subdirectories). For every
JSON object that carries serving metrics, the script compares:

  * tokens_per_s            — lower is worse (regression if -10%)
  * ttft_p99_s              — higher is worse (regression if +10%)
  * trace_overhead_ratio    — higher is worse (regression if +10%)

A relative drop only counts as a regression when the absolute change
also clears the metric's noise floor (FLOORS below): tiny smoke configs
report tiny absolute values where a sub-floor wiggle can read as a
double-digit percentage. Sub-floor changes are logged informationally.

Regressions are emitted as GitHub Actions ::warning annotations
(advisory: the exit code is 0 unless BENCH_TREND_STRICT=1), improvements
and unchanged metrics as plain log lines. Entries are keyed by
(file name, json path), so sweep configurations line up by label across
runs; keys present on only one side are reported informationally.

First run (no previous artifacts anywhere): the script reports that the
current run seeds the baseline and exits 0 — no warnings, even under
BENCH_TREND_STRICT, since there is nothing to compare against yet.
Unreadable *previous* artifacts are downgraded to informational notes
(stale or partial downloads should not spam warnings); unreadable
*current* artifacts still warn.
"""

import json
import os
import sys
from pathlib import Path

THRESHOLD = 0.10
# metric name -> True when larger values are better
METRICS = {
    "tokens_per_s": True,
    "ttft_p99_s": False,
    "trace_overhead_ratio": False,
    "decode_tokens_per_s": True,
    "preemption_ratio": False,
}
# metric name -> absolute change below which a relative move is treated
# as noise, never a regression. Smoke-mode sweeps include configs with
# single-digit tokens/s and sub-millisecond TTFTs, where a last-ulp or
# rounding change clears the 10% bar without meaning anything. The trace
# overhead ratio divides two wall-clock medians of a short smoke run, so
# scheduler jitter alone moves it by tenths — only a shift clearing 0.25x
# absolute says the recorder itself got slower.
FLOORS = {
    "tokens_per_s": 5.0,
    "ttft_p99_s": 1e-4,
    "trace_overhead_ratio": 0.25,
    # Smoke-mode decode rates sit in the same range as tokens_per_s.
    "decode_tokens_per_s": 5.0,
    # The preemption ratio divides two small integer counters; a single
    # preemption either side of a ~10-count smoke baseline moves it by
    # tenths without meaning anything.
    "preemption_ratio": 0.15,
}


def find_bench_files(root):
    """Map file name -> path for every BENCH_*.json under root."""
    out = {}
    for path in sorted(Path(root).rglob("BENCH_*.json")):
        out.setdefault(path.name, path)
    return out


def extract_metrics(node, path, out):
    """Collect (json-path, metric, value) triples from nested JSON."""
    if isinstance(node, dict):
        label = node.get("config")
        prefix = f"{path}/{label}" if isinstance(label, str) else path
        for key, val in node.items():
            if key in METRICS and isinstance(val, (int, float)):
                out[(prefix, key)] = float(val)
            else:
                extract_metrics(val, f"{prefix}/{key}", out)
    elif isinstance(node, list):
        for i, item in enumerate(node):
            # Lists of {"config": ...} entries key by label, not index.
            sub = path if isinstance(item, dict) and "config" in item else f"{path}[{i}]"
            extract_metrics(item, sub, out)


def load_metrics(path, warn=True):
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        if warn:
            print(f"::warning::bench-trend: unreadable {path}: {e}")
        else:
            print(f"bench-trend: previous artifact {path} unreadable ({e}); "
                  f"treating its metrics as absent")
        return {}
    out = {}
    extract_metrics(doc, "", out)
    return out


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    prev_dir, cur_dir = sys.argv[1], sys.argv[2]
    prev_files = find_bench_files(prev_dir) if os.path.isdir(prev_dir) else {}
    cur_files = find_bench_files(cur_dir)
    if not prev_files:
        # First run of the trajectory: nothing to diff. The fresh
        # BENCH_*.json files uploaded by this run become the baseline the
        # next run compares against. Always exit 0 here — a missing
        # history is not a regression, strict mode or not.
        print(f"bench-trend: no previous artifacts under {prev_dir!r} — "
              f"{len(cur_files)} current artifact(s) seed the baseline")
        return
    if not cur_files:
        print("::warning::bench-trend: no current BENCH_*.json files found")
        return

    regressions = []
    for name, cur_path in sorted(cur_files.items()):
        prev_path = prev_files.get(name)
        if prev_path is None:
            print(f"bench-trend: {name}: new benchmark, no history yet")
            continue
        prev = load_metrics(prev_path, warn=False)
        cur = load_metrics(cur_path)
        for key in sorted(cur):
            if key not in prev:
                print(f"bench-trend: {name}{key[0]}: new metric {key[1]}")
                continue
            where, metric = key
            old, new = prev[key], cur[key]
            if old <= 0:
                continue
            change = (new - old) / old
            worse = -change if METRICS[metric] else change
            arrow = f"{old:.4g} -> {new:.4g} ({change:+.1%})"
            if worse > THRESHOLD and abs(new - old) < FLOORS.get(metric, 0.0):
                print(f"bench-trend: {name}{where} {metric} {arrow} "
                      f"below noise floor ({FLOORS[metric]:g}), ignored")
            elif worse > THRESHOLD:
                regressions.append((name, where, metric, arrow))
                print(f"::warning file={name}::bench-trend regression: "
                      f"{name}{where} {metric} {arrow}")
            else:
                print(f"bench-trend: {name}{where} {metric} {arrow}")

    if regressions:
        print(f"bench-trend: {len(regressions)} regression(s) > "
              f"{THRESHOLD:.0%} vs previous run")
        if os.environ.get("BENCH_TREND_STRICT") == "1":
            sys.exit(1)
    else:
        print("bench-trend: no regressions vs previous run")


if __name__ == "__main__":
    main()
