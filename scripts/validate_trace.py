#!/usr/bin/env python3
"""Validate a `snitch-fm serve --trace` Chrome trace-event JSON file.

Usage: validate_trace.py <trace.json> [<trace.json> ...]

Checks (stdlib only, no Perfetto needed):

  * Well-formedness — the document is an object with a `traceEvents`
    list; every event is an object carrying the keys its phase requires
    (`X` complete events: numeric ts/dur and an args object; `i` instant
    events: ts and a scope; `C` counters: numeric args values; `M`
    metadata: a string args.name). Unknown phases are errors.
  * Monotone timestamps — every ts and dur is finite and non-negative;
    counter series (per pid + counter name) never step backwards in
    file order, matching the recorder's in-order gauge sampling.
  * Track shape — complete events sharing a (pid, tid) track are either
    disjoint or properly nested (a request's prefill-chunk spans sit
    inside its serve span; the engine track's pass/stall/idle spans tile
    without overlap). A small epsilon absorbs the 3-decimal microsecond
    rounding of the exporter.
  * pid/tid consistency — every pid referenced by an event has a
    process_name metadata record, and no (pid, tid) pair is named twice
    with conflicting thread names.

Exit code 0 when every file passes; 1 with per-violation lines on
stderr otherwise. A passing file gets a one-line summary on stdout.
"""

import json
import math
import sys
from collections import defaultdict

# 3-decimal microsecond printing means adjacent/nested span boundaries
# can disagree by a last digit; anything under 2 ns of overlap is
# formatting, not a recorder bug.
EPSILON_US = 0.002

KNOWN_PHASES = {"X", "i", "C", "M"}


def fail(errors, msg):
    errors.append(msg)


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def check_event(i, ev, errors):
    """Per-event key/type checks. Returns the phase or None if broken."""
    if not isinstance(ev, dict):
        fail(errors, f"event {i}: not an object")
        return None
    ph = ev.get("ph")
    if ph not in KNOWN_PHASES:
        fail(errors, f"event {i}: unknown phase {ph!r}")
        return None
    if not isinstance(ev.get("name"), str) or not ev["name"]:
        fail(errors, f"event {i} ({ph}): missing/empty name")
    for key in ("pid", "tid"):
        if not isinstance(ev.get(key), int) or isinstance(ev.get(key), bool):
            fail(errors, f"event {i} ({ph} {ev.get('name')!r}): non-integer {key}")
            return None
    if ph in ("X", "i", "C"):
        if not is_num(ev.get("ts")) or ev["ts"] < 0:
            fail(errors, f"event {i} ({ph} {ev.get('name')!r}): bad ts {ev.get('ts')!r}")
            return None
    if ph == "X":
        if not is_num(ev.get("dur")) or ev["dur"] < 0:
            fail(errors, f"event {i} (X {ev.get('name')!r}): bad dur {ev.get('dur')!r}")
            return None
        if not isinstance(ev.get("args"), dict):
            fail(errors, f"event {i} (X {ev.get('name')!r}): args must be an object")
    elif ph == "i":
        if ev.get("s") not in ("t", "p", "g"):
            fail(errors, f"event {i} (i {ev.get('name')!r}): bad scope {ev.get('s')!r}")
    elif ph == "C":
        args = ev.get("args")
        if not isinstance(args, dict) or not args or not all(is_num(v) for v in args.values()):
            fail(errors, f"event {i} (C {ev.get('name')!r}): counter args must be numeric")
    elif ph == "M":
        args = ev.get("args")
        if not isinstance(args, dict) or not isinstance(args.get("name"), str):
            fail(errors, f"event {i} (M {ev.get('name')!r}): metadata needs args.name")
    return ph


def check_track_nesting(track, spans, errors):
    """Spans on one track must be disjoint or properly nested."""
    spans.sort(key=lambda s: (s[0], -s[1]))
    stack = []  # open (start, end, name) intervals, innermost last
    for start, end, name in spans:
        while stack and start >= stack[-1][1] - EPSILON_US:
            stack.pop()
        if stack and end > stack[-1][1] + EPSILON_US:
            fail(
                errors,
                f"track pid={track[0]} tid={track[1]}: {name!r} "
                f"[{start:.3f}, {end:.3f}] overlaps {stack[-1][2]!r} "
                f"[{stack[-1][0]:.3f}, {stack[-1][1]:.3f}] without nesting",
            )
            continue
        stack.append((start, end, name))


def validate(path):
    errors = []
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"], ""
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        return ["document must be an object with a traceEvents list"], ""
    events = doc["traceEvents"]
    if not events:
        return ["traceEvents is empty"], ""

    named_pids = {}  # pid -> process name
    thread_names = {}  # (pid, tid) -> thread name
    used_pids = set()
    tracks = defaultdict(list)  # (pid, tid) -> [(start, end, name)] for X events
    counter_last = {}  # (pid, counter name) -> last ts
    counts = defaultdict(int)

    for i, ev in enumerate(events):
        ph = check_event(i, ev, errors)
        if ph is None:
            continue
        counts[ph] += 1
        pid, tid = ev["pid"], ev["tid"]
        if ph == "M":
            if ev["name"] == "process_name":
                prev = named_pids.setdefault(pid, ev["args"]["name"])
                if prev != ev["args"]["name"]:
                    fail(errors, f"pid {pid} named twice: {prev!r} vs {ev['args']['name']!r}")
            elif ev["name"] == "thread_name":
                prev = thread_names.setdefault((pid, tid), ev["args"]["name"])
                if prev != ev["args"]["name"]:
                    fail(
                        errors,
                        f"pid {pid} tid {tid} named twice: "
                        f"{prev!r} vs {ev['args']['name']!r}",
                    )
            continue
        used_pids.add(pid)
        if ph == "X":
            tracks[(pid, tid)].append((ev["ts"], ev["ts"] + ev["dur"], ev["name"]))
        elif ph == "C":
            key = (pid, ev["name"])
            last = counter_last.get(key)
            if last is not None and ev["ts"] < last - EPSILON_US:
                fail(
                    errors,
                    f"counter {ev['name']!r} pid {pid}: ts stepped back "
                    f"{last:.3f} -> {ev['ts']:.3f}",
                )
            counter_last[key] = ev["ts"]

    for pid in sorted(used_pids):
        if pid not in named_pids:
            fail(errors, f"pid {pid} has events but no process_name metadata")
    if counts["X"] == 0:
        fail(errors, "no complete (X) events — the trace records no spans")
    for track, spans in sorted(tracks.items()):
        check_track_nesting(track, spans, errors)

    summary = (
        f"{len(events)} events ({counts['X']} spans, {counts['i']} instants, "
        f"{counts['C']} counter samples, {counts['M']} metadata) across "
        f"{len(named_pids)} processes / {len(tracks)} span tracks"
    )
    return errors, summary


def main():
    if len(sys.argv) < 2:
        sys.exit(__doc__)
    bad = 0
    for path in sys.argv[1:]:
        errors, summary = validate(path)
        if errors:
            bad += 1
            for e in errors[:50]:
                print(f"validate_trace: {path}: {e}", file=sys.stderr)
            if len(errors) > 50:
                print(
                    f"validate_trace: {path}: ... {len(errors) - 50} more",
                    file=sys.stderr,
                )
        else:
            print(f"validate_trace: {path}: OK — {summary}")
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
