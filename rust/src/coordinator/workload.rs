//! Serving workloads: the requests a multi-user deployment throws at the
//! platform (the ROADMAP's "heavy traffic" scenario the single-request
//! engine could not even express).
//!
//! A [`Request`] is a prompt to prefill plus a number of tokens to decode;
//! a [`Workload`] is the batch of requests handed to the continuous
//! batcher. Synthetic workloads are generated with a seeded LCG so every
//! serving experiment is exactly reproducible.

use crate::arch::FpFormat;
use crate::coordinator::kv_cache::KvCache;
use crate::model::ModelConfig;

/// One inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Stable id (index in the workload; reports key on it).
    pub id: usize,
    /// Prompt tokens to prefill (NAR pass).
    pub prompt_len: u64,
    /// Tokens to generate autoregressively.
    pub gen_tokens: u64,
}

impl Request {
    /// KV slots this request needs at its longest (prompt + generation).
    pub fn kv_capacity(&self) -> u64 {
        self.prompt_len + self.gen_tokens
    }

    /// HBM bytes the request's KV caches occupy across all blocks at full
    /// length, sized exactly like the runtime [`KvCache`] buffers
    /// (f32 K + V).
    pub fn kv_bytes(&self, cfg: &ModelConfig) -> u64 {
        cfg.blocks
            * KvCache::bytes_for(
                cfg.heads as usize,
                self.kv_capacity() as usize,
                cfg.p as usize,
            ) as u64
    }

    /// KV bytes at the serving precision — the quantity the batcher
    /// admits against the HBM budget, consistent with the cost models
    /// streaming KV at `fmt` (the f32 [`KvCache`] geometry scaled to the
    /// element size).
    pub fn kv_bytes_at(&self, cfg: &ModelConfig, fmt: FpFormat) -> u64 {
        self.kv_bytes(cfg) / std::mem::size_of::<f32>() as u64 * fmt.bytes()
    }
}

/// A batch of requests to serve.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub requests: Vec<Request>,
}

impl Workload {
    /// `n` identical requests (throughput benchmarking).
    pub fn uniform(n: usize, prompt_len: u64, gen_tokens: u64) -> Workload {
        Workload {
            requests: (0..n).map(|id| Request { id, prompt_len, gen_tokens }).collect(),
        }
    }

    /// `n` requests with prompt/generation lengths drawn uniformly from
    /// the inclusive ranges by a seeded LCG (deterministic).
    pub fn synthetic(
        seed: u64,
        n: usize,
        prompt_range: (u64, u64),
        gen_range: (u64, u64),
    ) -> Workload {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
        let mut next = |lo: u64, hi: u64| {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            lo + (state >> 33) % (hi - lo + 1)
        };
        let requests = (0..n)
            .map(|id| Request {
                id,
                prompt_len: next(prompt_range.0, prompt_range.1).max(1),
                gen_tokens: next(gen_range.0, gen_range.1).max(1),
            })
            .collect();
        Workload { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Total tokens the workload generates (the numerator of aggregate
    /// tokens/s).
    pub fn total_gen_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.gen_tokens).sum()
    }

    /// Total prompt tokens across requests.
    pub fn total_prompt_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.prompt_len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_workload_shape() {
        let w = Workload::uniform(4, 128, 32);
        assert_eq!(w.len(), 4);
        assert_eq!(w.total_gen_tokens(), 4 * 32);
        assert_eq!(w.total_prompt_tokens(), 4 * 128);
        assert_eq!(w.requests[3].id, 3);
        assert_eq!(w.requests[0].kv_capacity(), 160);
    }

    #[test]
    fn synthetic_deterministic_and_in_range() {
        let a = Workload::synthetic(7, 32, (64, 512), (16, 128));
        let b = Workload::synthetic(7, 32, (64, 512), (16, 128));
        assert_eq!(a.requests, b.requests);
        for r in &a.requests {
            assert!((64..=512).contains(&r.prompt_len), "{r:?}");
            assert!((16..=128).contains(&r.gen_tokens), "{r:?}");
        }
        // Different seeds differ (overwhelmingly likely over 32 draws).
        let c = Workload::synthetic(8, 32, (64, 512), (16, 128));
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn kv_bytes_matches_allocated_caches() {
        let cfg = ModelConfig::tiny();
        let r = Request { id: 0, prompt_len: 24, gen_tokens: 8 };
        let one_block =
            KvCache::new(cfg.heads as usize, 32, cfg.p as usize).bytes() as u64;
        assert_eq!(r.kv_bytes(&cfg), cfg.blocks * one_block);
    }

    #[test]
    fn kv_bytes_scale_with_serving_precision() {
        let cfg = ModelConfig::gpt_j();
        let r = Request { id: 0, prompt_len: 1024, gen_tokens: 64 };
        assert_eq!(r.kv_bytes_at(&cfg, FpFormat::Fp32), r.kv_bytes(&cfg));
        assert_eq!(r.kv_bytes_at(&cfg, FpFormat::Fp8), r.kv_bytes(&cfg) / 4);
        assert_eq!(r.kv_bytes_at(&cfg, FpFormat::Fp16), r.kv_bytes(&cfg) / 2);
    }
}
