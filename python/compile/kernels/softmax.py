"""Row-softmax Pallas kernel.

Standalone (non-fused) softmax used by the *baseline* attention path — the
unfused implementation Fig. 7/8 compare FlashAttention-2 against. Always
computed in fp32 internally (the paper never lowers softmax precision).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import pick_block


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)



@functools.partial(jax.jit, static_argnames=("br",))
def softmax(x, br=64):
    """Softmax over the last axis of x: [S, N], row-block tiled."""
    s, n = x.shape
    br = pick_block(s, br)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(s // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, n), x.dtype),
        interpret=True,
    )(x)
