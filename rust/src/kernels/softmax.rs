//! Standalone row-softmax timing model.
//!
//! Used by the *unfused* attention baseline (the configuration
//! FlashAttention-2 is compared against for the Fig. 1 memory analysis):
//! the S x S score matrix round-trips through HBM around the softmax.
//! Always evaluated in FP32.

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::sim::cluster::{ClusterSim, TilePhase};
use crate::sim::core::{opcost, CoreModel};
use crate::sim::dma::Transfer;
use crate::sim::{KernelCost, MultiClusterSim};

/// Cost of softmax over the rows of an `s x n` matrix. `resident` = input
/// and output stay in SPM (fused caller); otherwise HBM round trip.
pub fn softmax_cost(
    s: u64,
    n: u64,
    fmt: FpFormat,
    resident: bool,
    platform: &PlatformConfig,
) -> KernelCost {
    if s == 0 || n == 0 {
        return KernelCost::default();
    }
    let clusters = platform.total_clusters() as u64;
    let core = CoreModel::new(platform.cluster, platform.features);
    let cores = platform.cluster.compute_cores;
    let el = fmt.bytes();
    let rows = s.div_ceil(clusters).max(1).min(s);
    let active = s.div_ceil(rows).min(clusters);
    let rows_per_core = rows.div_ceil(cores);

    // Per row: max reduce, exp (scalar fp32), sum reduce, divide.
    let mut compute = 0;
    compute += rows_per_core * core.reduction_cycles(n, FpFormat::Fp32);
    compute += rows_per_core * core.elementwise_cycles(n, opcost::EXP, FpFormat::Fp32, false);
    compute += rows_per_core * core.reduction_cycles(n, FpFormat::Fp32);
    compute += rows_per_core * core.elementwise_cycles(n, opcost::DIV, FpFormat::Fp32, false);
    if fmt.needs_fp32_conversion() {
        compute += 2 * rows_per_core * core.elementwise_cycles(n, opcost::CONVERT, fmt, true);
    }
    let flops = rows * n * 4;
    let mut phase = TilePhase::compute(compute, flops);
    if !resident {
        phase = phase
            .with_transfer(Transfer::d2(rows * n * el, rows, MemLevel::Hbm))
            .with_transfer(Transfer::d2(rows * n * el, rows, MemLevel::Hbm).to_write());
    }
    let csim = ClusterSim::new(platform).with_hbm_sharers(active);
    let one = csim.run(&[phase]);
    let sim = MultiClusterSim::new(platform);
    let per: Vec<KernelCost> = (0..active).map(|_| one).collect();
    sim.parallel(&per)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn exp_dominates() {
        // The exponential is the expensive part (paper Sec. I).
        let c = softmax_cost(1024, 1024, FpFormat::Fp32, true, &occ());
        let rows_per_core = (1024u64 / 16) / 8;
        let core = CoreModel::new(occ().cluster, occ().features);
        let exp_only =
            rows_per_core * core.elementwise_cycles(1024, opcost::EXP, FpFormat::Fp32, false);
        assert!(exp_only as f64 > 0.5 * c.compute_cycles as f64);
    }

    #[test]
    fn unfused_pays_hbm_roundtrip() {
        let r = softmax_cost(2048, 2048, FpFormat::Fp32, true, &occ());
        let u = softmax_cost(2048, 2048, FpFormat::Fp32, false, &occ());
        assert_eq!(r.hbm_bytes(), 0);
        assert_eq!(u.hbm_bytes(), 2 * 2048 * 2048 * 4);
        assert!(u.cycles >= r.cycles);
    }

    #[test]
    fn fp8_still_runs_exp_in_fp32() {
        let f32c = softmax_cost(1024, 1024, FpFormat::Fp32, true, &occ());
        let f8c = softmax_cost(1024, 1024, FpFormat::Fp8, true, &occ());
        // No 4x here: conversions even add work.
        assert!(f8c.compute_cycles >= f32c.compute_cycles);
    }
}
