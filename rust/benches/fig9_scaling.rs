//! Fig. 9 — scalability. Left panes: GPT throughput vs sequence length
//! (paper: GPT3-XL 429->136 tok/s NAR, 7.9->5.8 AR; GPT-J 174->74 NAR,
//! 3.8->1 AR over S=128..2048). Right pane: ViT images/s vs clusters
//! (paper: 4x/8x/16x clusters give up to 4/7.9/15.8x on ViT-H).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::ModelConfig;

fn seq_sweep(fmt: FpFormat) -> Vec<(String, u64, f64, f64)> {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let mut out = Vec::new();
    for cfg in [ModelConfig::gpt3_xl(), ModelConfig::gpt_j()] {
        for s in [128u64, 256, 512, 1024, 2048] {
            let nar = e.run_nar(&cfg, s, fmt).throughput;
            let ar = e.run_ar_step(&cfg, s, fmt).throughput;
            out.push((cfg.name.clone(), s, nar, ar));
        }
    }
    out
}

fn cluster_sweep(fmt: FpFormat) -> Vec<(String, u32, f64)> {
    let mut out = Vec::new();
    for cfg in [ModelConfig::vit_b(), ModelConfig::vit_l(), ModelConfig::vit_h()] {
        for clusters in [1u32, 4, 8, 16] {
            let e = InferenceEngine::new(PlatformConfig::with_clusters(clusters));
            out.push((cfg.name.clone(), clusters, e.run_nar(&cfg, cfg.seq, fmt).throughput));
        }
    }
    out
}

fn main() {
    let fmt = FpFormat::Fp8;
    common::header("Fig. 9 (left)", "GPT throughput vs sequence length, FP8");
    let (t1, rows) = common::time_median(3, || seq_sweep(fmt));
    println!("{:<10} {:>6} {:>12} {:>10}", "model", "S", "NAR tok/s", "AR tok/s");
    for (m, s, nar, ar) in &rows {
        println!("{m:<10} {s:>6} {nar:>12.1} {ar:>10.2}");
    }
    println!("paper: gpt3-xl 429->136 NAR / 7.9->5.8 AR; gpt-j 174->74 NAR / 3.8->1 AR");
    println!("(our per-token cost is flop-accurate, so the NAR slope is shallower; see EXPERIMENTS.md)\n");
    common::report_timing("fig9-seq-sweep", t1);

    common::header("Fig. 9 (right)", "ViT images/s vs clusters, FP8");
    let (t2, rows) = common::time_median(3, || cluster_sweep(fmt));
    println!("{:<8} {:>4} {:>12} {:>9}", "model", "C", "images/s", "speedup");
    let mut base = 1.0;
    for (m, c, tp) in &rows {
        if *c == 1 {
            base = *tp;
        }
        println!("{m:<8} {c:>4} {tp:>12.2} {:>8.1}x", tp / base);
    }
    println!("paper: (4,6,12)x B, (4,6,11.9)x L, (4,7.9,15.8)x H for 4/8/16 clusters");
    common::report_timing("fig9-cluster-sweep", t2);
}
