//! Fig. 10 — kernel latency breakdown, GPT-J / GPT3-XL at FP32 and FP8 in
//! NAR and AR. Paper (GPT-J): GEMM 66% (FP32) / 36% (FP8) of NAR latency,
//! 97% / 89% of AR; the FlashAttention-2 bucket grows at FP8 because its
//! softmax island stays FP32. The paper instruments at MHA-macro-block
//! granularity (see Breakdown::fig10_buckets).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::schedule::model_cost;
use snitch_fm::coordinator::Breakdown;
use snitch_fm::model::{Mode, ModelConfig};

fn main() {
    common::header("Fig. 10", "kernel latency breakdown (MHA-block granularity)");
    let p = PlatformConfig::occamy();
    let paper_gptj = [
        (Mode::Nar, FpFormat::Fp32, 66.0),
        (Mode::Nar, FpFormat::Fp8, 36.0),
        (Mode::Ar, FpFormat::Fp32, 97.0),
        (Mode::Ar, FpFormat::Fp8, 89.0),
    ];
    for cfg in [ModelConfig::gpt_j(), ModelConfig::gpt3_xl()] {
        for (mode, fmt, paper_gemm) in paper_gptj {
            let label = format!(
                "{} {} {}",
                cfg.name,
                if mode == Mode::Nar { "nar" } else { "ar" },
                fmt.name()
            );
            let (t, mc) = common::time_median(3, || model_cost(&cfg, mode, 1024, fmt, &p));
            let buckets = Breakdown::fig10_buckets(&mc);
            print!("{label}: ");
            for b in &buckets {
                print!("{}={:.1}%  ", b.kind, b.fraction * 100.0);
            }
            if cfg.name == "gpt-j" {
                print!("| paper GEMM(mlp) {paper_gemm}%");
            }
            println!();
            common::report_timing(&label.replace(' ', "-"), t);
        }
        println!();
    }
}
