"""Hypothesis property sweeps over the Pallas kernels' shape/dtype space.

The session contract: hypothesis sweeps the kernels' shapes/dtypes and
asserts allclose against ref.py. Shapes are drawn small enough that the
interpret-mode grid stays fast, but cover odd/prime/degenerate dims.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention as fa
from compile.kernels import gelu as gelu_k
from compile.kernels import gemm as gemm_k
from compile.kernels import layernorm as ln_k
from compile.kernels import ref
from compile.kernels import softmax as sm_k
from compile.kernels.util import pick_block

DIMS = st.integers(min_value=1, max_value=48)
BLOCKS = st.integers(min_value=1, max_value=64)
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])
TOL = {jnp.float32: 1e-4, jnp.bfloat16: 5e-2}

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * 0.5).astype(dtype)


def _close(got, want, dtype):
    t = TOL[dtype]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=t, atol=t)


@given(m=DIMS, n=DIMS, k=DIMS, bm=BLOCKS, bn=BLOCKS, bk=BLOCKS,
       dtype=DTYPES, seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_gemm_property(m, n, k, bm, bn, bk, dtype, seed):
    a, b = _rand((m, k), dtype, seed), _rand((k, n), dtype, seed + 1)
    _close(gemm_k.gemm(a, b, bm=bm, bn=bn, bk=bk), ref.gemm(a, b), dtype)


@given(h=st.integers(1, 4), sq=st.integers(1, 32), skv=st.integers(1, 32),
       p=st.sampled_from([4, 8, 16]), bq=BLOCKS, bkv=BLOCKS,
       causal=st.booleans(), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_fa_property(h, sq, skv, p, bq, bkv, causal, seed):
    if causal and sq > skv:
        sq = skv  # causal requires the query block to be a suffix of kv
    q = _rand((h, sq, p), jnp.float32, seed)
    k = _rand((h, skv, p), jnp.float32, seed + 1)
    v = _rand((h, skv, p), jnp.float32, seed + 2)
    got = fa.flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv)
    want = np.stack([ref.attention(q[i], k[i], v[i], causal=causal)
                     for i in range(h)])
    _close(got, want, jnp.float32)


@given(s=DIMS, e=st.integers(2, 48), br=BLOCKS, dtype=DTYPES,
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_layernorm_property(s, e, br, dtype, seed):
    x = _rand((s, e), dtype, seed)
    g = (1.0 + _rand((e,), np.float32, seed + 1) * 0.2).astype(dtype)
    b = (_rand((e,), np.float32, seed + 2) * 0.2).astype(dtype)
    _close(ln_k.layernorm(x, g, b, br=br),
           ref.layernorm(x, g, b), dtype)


@given(s=DIMS, f=DIMS, br=BLOCKS, dtype=DTYPES, seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_gelu_property(s, f, br, dtype, seed):
    x = _rand((s, f), dtype, seed)
    _close(gelu_k.i_gelu(x, br=br), ref.i_gelu(x), dtype)


@given(s=DIMS, n=DIMS, br=BLOCKS, seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_softmax_property(s, n, br, seed):
    x = _rand((s, n), jnp.float32, seed)
    _close(sm_k.softmax(x, br=br), ref.softmax(x), jnp.float32)


@given(dim=st.integers(1, 4096), want=st.integers(1, 256))
@settings(max_examples=200, deadline=None)
def test_pick_block_property(dim, want):
    b = pick_block(dim, want)
    assert 1 <= b <= dim
    assert dim % b == 0
