//! Fig. 1 — memory-transfer analysis of the attention block. Paper: the
//! fused concat+linear with c2c tree reduction cuts GPT-J (NAR, S=2048)
//! block HBM reads by 1.6x (624 MB -> 384 MB).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::schedule::model_cost;
use snitch_fm::kernels::{fused_concat_linear_cost, unfused_concat_linear_cost};
use snitch_fm::model::{Mode, ModelConfig};

fn main() {
    common::header("Fig. 1", "HBM traffic of the fused concat+linear, GPT-J S=2048");
    let p = PlatformConfig::occamy();
    let cfg = ModelConfig::gpt_j();
    let s = 2048;

    let (t, (f, u)) = common::time_median(5, || {
        (
            fused_concat_linear_cost(s, cfg.heads, cfg.p, cfg.e, FpFormat::Fp32, &p),
            unfused_concat_linear_cost(s, cfg.heads, cfg.p, cfg.e, FpFormat::Fp32, &p),
        )
    });
    println!("layer view (concat+linear only):");
    println!(
        "  fused   (c2c reduction): {:>8.1} MB HBM, {:>8.1} MB c2c",
        f.hbm_bytes() as f64 / 1e6,
        f.c2c_bytes as f64 / 1e6
    );
    println!("  unfused (HBM bounce):    {:>8.1} MB HBM", u.hbm_bytes() as f64 / 1e6);
    println!("  reduction: {:.2}x", u.hbm_bytes() as f64 / f.hbm_bytes() as f64);
    common::report_timing("fig1-layer", t);

    // Whole-block unique-tensor view: the paper's 624 -> 384 MB annotation
    // counts tensor bytes (weights alone exceed 384 MB at FP32, so Fig. 1
    // is a <=FP16 precision view; we report FP16).
    let fmt = FpFormat::Fp16;
    let fused = snitch_fm::metrics::fig1_unique_hbm_reads(&cfg, s, fmt, true, &p);
    let unfused = snitch_fm::metrics::fig1_unique_hbm_reads(&cfg, s, fmt, false, &p);
    println!("\nunique HBM reads per transformer block (FP16, S=2048):");
    println!("  with c2c fusion:    {:>8.1} MB (paper: 384 MB)", fused as f64 / 1e6);
    println!("  without c2c fusion: {:>8.1} MB (paper: 624 MB)", unfused as f64 / 1e6);
    println!("  reduction: {:.2}x (paper: 1.6x)", unfused as f64 / fused as f64);

    // Actual simulated DMA traffic (includes per-cluster broadcasts and
    // partial-C round trips — the platform view rather than the tensor
    // view; fusion still wins).
    let mut base = p.clone();
    base.features.cluster_to_cluster = false;
    let opt = model_cost(&cfg, Mode::Nar, s, FpFormat::Fp32, &p);
    let off = model_cost(&cfg, Mode::Nar, s, FpFormat::Fp32, &base);
    println!(
        "\nsimulated DMA reads per block (FP32): fused {:.1} MB vs unfused {:.1} MB ({:.2}x)",
        opt.total.hbm_read_bytes as f64 / cfg.blocks as f64 / 1e6,
        off.total.hbm_read_bytes as f64 / cfg.blocks as f64 / 1e6,
        off.total.hbm_read_bytes as f64 / opt.total.hbm_read_bytes as f64
    );
}
