//! The inference coordinator (Layer 3).
//!
//! Owns the mapping from model graphs to the platform: prices every layer
//! with the kernel timing models (`schedule`), aggregates per-kernel-class
//! breakdowns (`breakdown`, Fig. 10), runs end-to-end NAR/AR passes and
//! batched multi-request runs (`engine`), schedules multi-user serving
//! traffic with continuous batching against the HBM KV budget
//! (`workload`, `batcher`), and manages the decode-time KV cache
//! (`kv_cache`) used by the numeric runtime path.

pub mod batcher;
pub mod breakdown;
pub mod engine;
pub mod kv_cache;
pub mod schedule;
pub mod workload;

pub use batcher::{BatcherConfig, ContinuousBatcher, RequestStats, ServeReport};
pub use breakdown::{Breakdown, KernelClassShare};
pub use engine::{InferenceEngine, RunReport};
pub use kv_cache::KvCache;
pub use schedule::{
    block_cost, block_cost_batched, layer_cost, model_cost, model_cost_batched, ModelCost,
};
pub use workload::{Request, Workload};
