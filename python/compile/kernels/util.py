"""Shared tiling helpers for the Pallas kernels.

Pallas BlockSpecs require the grid to cover the array exactly, so block
sizes must divide the dimension. ViT sequence lengths (S=197, prime) have
no useful divisors; in that case we fall back to a single full-dimension
tile, which is exactly what the paper does when a tensor fits the cluster
SPM outright (temporal tiling degenerates to one time step).
"""

# A tile this small under-utilizes the (simulated) SIMD lanes and explodes
# the interpret-mode grid; prefer one full tile instead when affordable.
_MIN_USEFUL_BLOCK = 16
# Largest dimension we are willing to hold as a single tile.
_FULL_TILE_CAP = 4096


def pick_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` <= `want`, falling back to `dim` itself
    when only degenerate divisors exist (e.g. prime dims like S=197)."""
    b = max(1, min(dim, want))
    while dim % b != 0:
        b -= 1
    if b < _MIN_USEFUL_BLOCK and dim <= _FULL_TILE_CAP:
        return dim
    return b
