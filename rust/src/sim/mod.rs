//! Cycle-level timing model of the Snitch many-core platform.
//!
//! This is the substrate that replaces the paper's cycle-accurate RTL
//! simulation (see DESIGN.md §1 for the substitution argument). It is an
//! *analytical + event* model: per-core instruction-issue arithmetic for
//! the kernels' inner loops (`core`), DMA/interconnect transfer timing with
//! contention (`dma`, `noc`), cluster-level double-buffered tile pipelines
//! (`cluster`), and a multi-cluster engine for barriers and the
//! logarithmic reduction tree (`engine`).
//!
//! Everything is deterministic and integer-cycled, so results are exactly
//! reproducible across runs and platforms.

pub mod cluster;
pub mod core;
pub mod dma;
pub mod engine;
pub mod noc;

pub use cluster::{ClusterSim, TilePhase};
pub use core::CoreModel;
pub use dma::{DmaEngine, Transfer};
pub use engine::{MultiClusterSim, ReductionOutcome};

/// Aggregate cost of running a kernel (or kernel fragment) on the platform.
///
/// Produced by every kernel timing model in [`crate::kernels`]; consumed by
/// the coordinator, the energy model and the report generators.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KernelCost {
    /// Wall-clock cycles on the critical path (max over clusters).
    pub cycles: u64,
    /// Cycles the critical cluster spent in FPU compute.
    pub compute_cycles: u64,
    /// Cycles the critical cluster spent waiting on DMA (not hidden by
    /// double buffering).
    pub dma_exposed_cycles: u64,
    /// Useful FLOPs of the whole kernel (all clusters).
    pub flops: u64,
    /// Bytes read from HBM (all clusters).
    pub hbm_read_bytes: u64,
    /// Bytes written to HBM (all clusters).
    pub hbm_write_bytes: u64,
    /// Bytes moved cluster-to-cluster (all clusters).
    pub c2c_bytes: u64,
    /// Bytes moved over the die-to-die links (all dies; collectives and
    /// pipeline sends of the parallelism subsystem).
    pub d2d_bytes: u64,
    /// Number of DMA transfers issued (for static-overhead accounting).
    pub dma_transfers: u64,
}

impl KernelCost {
    /// Sequential composition: `self` then `other`.
    pub fn then(self, other: KernelCost) -> KernelCost {
        KernelCost {
            cycles: self.cycles + other.cycles,
            compute_cycles: self.compute_cycles + other.compute_cycles,
            dma_exposed_cycles: self.dma_exposed_cycles + other.dma_exposed_cycles,
            flops: self.flops + other.flops,
            hbm_read_bytes: self.hbm_read_bytes + other.hbm_read_bytes,
            hbm_write_bytes: self.hbm_write_bytes + other.hbm_write_bytes,
            c2c_bytes: self.c2c_bytes + other.c2c_bytes,
            d2d_bytes: self.d2d_bytes + other.d2d_bytes,
            dma_transfers: self.dma_transfers + other.dma_transfers,
        }
    }

    /// Repeat this cost `n` times back-to-back.
    pub fn repeat(self, n: u64) -> KernelCost {
        KernelCost {
            cycles: self.cycles * n,
            compute_cycles: self.compute_cycles * n,
            dma_exposed_cycles: self.dma_exposed_cycles * n,
            flops: self.flops * n,
            hbm_read_bytes: self.hbm_read_bytes * n,
            hbm_write_bytes: self.hbm_write_bytes * n,
            c2c_bytes: self.c2c_bytes * n,
            d2d_bytes: self.d2d_bytes * n,
            dma_transfers: self.dma_transfers * n,
        }
    }

    /// Total HBM traffic in bytes.
    pub fn hbm_bytes(&self) -> u64 {
        self.hbm_read_bytes + self.hbm_write_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_composition() {
        let a = KernelCost { cycles: 10, flops: 100, hbm_read_bytes: 5, ..Default::default() };
        let b = KernelCost { cycles: 20, flops: 50, hbm_write_bytes: 7, ..Default::default() };
        let c = a.then(b);
        assert_eq!(c.cycles, 30);
        assert_eq!(c.flops, 150);
        assert_eq!(c.hbm_bytes(), 12);
        let r = a.repeat(3);
        assert_eq!(r.cycles, 30);
        assert_eq!(r.flops, 300);
    }
}
