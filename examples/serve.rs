//! Multi-user serving scenario — the continuous-batching coordinator end
//! to end.
//!
//! A mixed workload of 32 requests (chat-style prompts, varying lengths)
//! hits a 16-cluster platform serving GPT-J at FP8. The batcher admits
//! requests FCFS against the HBM KV budget (capacity minus resident
//! weights), interleaves prefill with batched decode, and the cycle model
//! prices the whole trace: per-request latency percentiles, TTFT, and
//! aggregate tokens/s.
//!
//! Run: `cargo run --release --example serve`

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, InferenceEngine, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::report;

fn main() {
    let engine = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::gpt_j();
    let fmt = FpFormat::Fp8;

    println!(
        "KV budget: {:.1} GB of {:.1} GB HBM after {:.1} GB of {} weights\n",
        engine.kv_budget_bytes(&cfg, fmt) as f64 / 1e9,
        engine.platform.interconnect.hbm_capacity_bytes as f64 / 1e9,
        cfg.weight_bytes(fmt) as f64 / 1e9,
        fmt.name(),
    );

    // Chat-style mix: prompts 256..1024 tokens, replies 32..128 tokens,
    // three priority classes, arriving open-loop at 2 requests/s.
    let workload = Workload::synthetic(42, 32, (256, 1024), (32, 128))
        .with_priority_classes(3)
        .with_poisson_arrivals(42, 2.0);

    // Sweep the batch limit: more concurrent requests amortize the weight
    // stream (throughput up) at a modest per-request latency cost.
    println!(
        "{:<6} {:>12} {:>14} {:>10} {:>10} {:>9}",
        "batch", "tokens/s", "decode tok/s", "p50 [s]", "p99 [s]", "util%"
    );
    for max_batch in [1usize, 4, 8, 16] {
        let r = engine.serve(&cfg, &workload, max_batch, fmt);
        println!(
            "{:<6} {:>12.1} {:>14.1} {:>10.3} {:>10.3} {:>9.2}",
            max_batch,
            r.tokens_per_s,
            r.decode_tokens_per_s,
            r.latency_p50_s,
            r.latency_p99_s,
            r.fpu_utilization * 100.0,
        );
    }

    // Chunked prefill: long prompts stop stalling queued requests, so
    // TTFT drops while aggregate throughput stays in the same band.
    println!(
        "\n{:<10} {:>12} {:>12} {:>12}",
        "chunk", "tokens/s", "TTFT p50[s]", "TTFT p99[s]"
    );
    for chunk in [0u64, 512, 256, 128] {
        let mut opts = BatcherConfig::new(8, 0);
        opts.prefill_chunk = chunk;
        let r = engine.serve_with(&cfg, &workload, opts, fmt);
        let label = if chunk == 0 {
            "mono".to_string()
        } else {
            chunk.to_string()
        };
        println!(
            "{label:<10} {:>12.1} {:>12.3} {:>12.3}",
            r.tokens_per_s, r.ttft_p50_s, r.ttft_p99_s
        );
    }

    println!("\nfull report at batch 8, chunk 256:");
    let mut opts = BatcherConfig::new(8, 0);
    opts.prefill_chunk = 256;
    let r = engine.serve_with(&cfg, &workload, opts, fmt);
    print!("{}", report::serve_table(&r));
}
