//! Kernel latency breakdown (paper Fig. 10).

use crate::model::LayerKind;

use super::schedule::ModelCost;

/// Kernel classes in canonical counter order; [`kind_index`] maps a
/// [`LayerKind`] to its slot in this table (and in [`KindCycles`]).
pub const KIND_ORDER: [LayerKind; 6] = [
    LayerKind::Gemm,
    LayerKind::FlashAttention,
    LayerKind::FusedConcatLinear,
    LayerKind::Layernorm,
    LayerKind::Gelu,
    LayerKind::KvDequant,
];

/// Slot of `kind` in [`KIND_ORDER`] / [`KindCycles`].
pub const fn kind_index(kind: LayerKind) -> usize {
    match kind {
        LayerKind::Gemm => 0,
        LayerKind::FlashAttention => 1,
        LayerKind::FusedConcatLinear => 2,
        LayerKind::Layernorm => 3,
        LayerKind::Gelu => 4,
        LayerKind::KvDequant => 5,
    }
}

/// Dense per-kernel-class cycle accumulator (slots ordered by
/// [`KIND_ORDER`]). The serving counters keep one of these per pass phase
/// so `ServeReport` can attribute cycles to kernel classes without hashing
/// on the pricing hot path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KindCycles(pub [u64; 6]);

impl KindCycles {
    /// Add `cycles` to `kind`'s slot.
    pub fn add(&mut self, kind: LayerKind, cycles: u64) {
        self.0[kind_index(kind)] += cycles;
    }

    /// Accumulate another counter into this one, slot by slot.
    pub fn accum(&mut self, other: &KindCycles) {
        for (a, b) in self.0.iter_mut().zip(other.0.iter()) {
            *a += b;
        }
    }

    /// Cycles attributed to `kind`.
    pub fn get(&self, kind: LayerKind) -> u64 {
        self.0[kind_index(kind)]
    }

    /// Sum over every kernel class.
    pub fn total(&self) -> u64 {
        self.0.iter().sum()
    }

    /// True when no cycles have been recorded.
    pub fn is_zero(&self) -> bool {
        self.0.iter().all(|&c| c == 0)
    }

    /// Per-slot scaling (repeat over `n` identical blocks).
    pub fn scaled(&self, n: u64) -> KindCycles {
        let mut out = *self;
        for c in out.0.iter_mut() {
            *c *= n;
        }
        out
    }

    /// `(kind, cycles)` pairs in canonical order.
    pub fn iter(&self) -> impl Iterator<Item = (LayerKind, u64)> + '_ {
        KIND_ORDER.iter().zip(self.0.iter()).map(|(k, c)| (*k, *c))
    }
}

/// One kernel class' share of the total latency.
#[derive(Debug, Clone)]
pub struct KernelClassShare {
    pub kind: &'static str,
    pub cycles: u64,
    pub fraction: f64,
}

/// Latency breakdown of a model pass by kernel class.
#[derive(Debug, Clone)]
pub struct Breakdown {
    pub shares: Vec<KernelClassShare>,
    pub total_cycles: u64,
}

impl Breakdown {
    /// Build from a priced model cost, ordered by descending share.
    pub fn from_cost(mc: &ModelCost) -> Breakdown {
        let mut shares: Vec<KernelClassShare> = mc
            .by_kind
            .iter()
            .map(|(kind, cost)| KernelClassShare {
                kind: kind.name(),
                cycles: cost.cycles,
                fraction: if mc.total.cycles > 0 {
                    cost.cycles as f64 / mc.total.cycles as f64
                } else {
                    0.0
                },
            })
            .collect();
        shares.sort_by(|a, b| b.cycles.cmp(&a.cycles));
        Breakdown { shares, total_cycles: mc.total.cycles }
    }

    /// Fraction for a class name ("gemm", "flashattention", ...), 0 if absent.
    pub fn fraction(&self, kind: LayerKind) -> f64 {
        self.shares
            .iter()
            .find(|s| s.kind == kind.name())
            .map(|s| s.fraction)
            .unwrap_or(0.0)
    }

    /// Combined share of the GEMM-like classes (plain + fused concat
    /// linear), the paper's "GEMM" bucket in Fig. 10.
    pub fn gemm_fraction(&self) -> f64 {
        self.fraction(LayerKind::Gemm) + self.fraction(LayerKind::FusedConcatLinear)
    }

    /// Activation bucket (LayerNorm + GELU).
    pub fn activation_fraction(&self) -> f64 {
        self.fraction(LayerKind::Layernorm) + self.fraction(LayerKind::Gelu)
    }

    /// Fig. 10's exact buckets, built from per-label costs: the paper
    /// instruments at MHA-macro-block granularity, so its
    /// "FlashAttention-2" bar covers QKV projections + attention + fused
    /// out-projection, while "GEMM" is the MLP linears. (The GPT-J FP32
    /// NAR split of 66% GEMM then follows directly from the flop ratio
    /// MLP : MHA = 275G : 154G per block.)
    pub fn fig10_buckets(mc: &ModelCost) -> Vec<KernelClassShare> {
        let total = mc.total.cycles.max(1);
        let sum = |labels: &[&str]| -> u64 {
            labels
                .iter()
                .filter_map(|l| mc.by_label.get(l).map(|c| c.cycles))
                .sum()
        };
        let buckets = [
            ("gemm (mlp)", sum(&["mlp-up", "mlp-down"])),
            (
                "flashattention-2 (mha)",
                sum(&["q-proj", "k-proj", "v-proj", "attention", "out-proj"]),
            ),
            ("layernorm", sum(&["ln1", "ln2"])),
            ("gelu", sum(&["gelu"])),
        ];
        buckets
            .iter()
            .map(|&(kind, cycles)| KernelClassShare {
                kind: match kind {
                    "gemm (mlp)" => "gemm (mlp)",
                    "flashattention-2 (mha)" => "flashattention-2 (mha)",
                    "layernorm" => "layernorm",
                    _ => "gelu",
                },
                cycles,
                fraction: cycles as f64 / total as f64,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{FpFormat, PlatformConfig};
    use crate::coordinator::schedule::model_cost;
    use crate::model::{Mode, ModelConfig};

    #[test]
    fn shares_sum_to_one() {
        let mc = model_cost(
            &ModelConfig::gpt_j(),
            Mode::Nar,
            1024,
            FpFormat::Fp32,
            &PlatformConfig::occamy(),
        );
        let b = Breakdown::from_cost(&mc);
        let sum: f64 = b.shares.iter().map(|s| s.fraction).sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
        assert!(b.shares.windows(2).all(|w| w[0].cycles >= w[1].cycles));
    }

    #[test]
    fn kind_cycles_accumulates_in_canonical_order() {
        let mut kc = KindCycles::default();
        assert!(kc.is_zero());
        kc.add(LayerKind::Gemm, 10);
        kc.add(LayerKind::Gelu, 5);
        kc.add(LayerKind::Gemm, 2);
        assert_eq!(kc.get(LayerKind::Gemm), 12);
        assert_eq!(kc.get(LayerKind::Gelu), 5);
        assert_eq!(kc.total(), 17);
        let mut other = KindCycles::default();
        other.add(LayerKind::FlashAttention, 3);
        kc.accum(&other);
        assert_eq!(kc.total(), 20);
        assert_eq!(kc.scaled(2).total(), 40);
        // Every LayerKind has a distinct slot matching KIND_ORDER.
        for (i, kind) in KIND_ORDER.iter().enumerate() {
            assert_eq!(kind_index(*kind), i);
        }
        let pairs: Vec<_> = kc.iter().collect();
        assert_eq!(pairs[0], (LayerKind::Gemm, 12));
        assert_eq!(pairs[1], (LayerKind::FlashAttention, 3));
    }

    #[test]
    fn buckets_match_fig10_shape() {
        let p = PlatformConfig::occamy();
        let mc = model_cost(&ModelConfig::gpt_j(), Mode::Ar, 1024, FpFormat::Fp32, &p);
        let b = Breakdown::from_cost(&mc);
        // Fig. 10 AR FP32: GEMM ~97%.
        assert!(b.gemm_fraction() > 0.80, "gemm {}", b.gemm_fraction());
        assert!(b.activation_fraction() < 0.10);
    }
}
