//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path (paper architecture: Python only at build time).
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO *text* is the
//! interchange format because jax >= 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` bindings are not in the offline registry, so the whole
//! numeric path is gated behind the `pjrt` cargo feature. Without it the
//! same API surface exists but `Runtime::new` returns a descriptive error
//! — the timing simulator, serving coordinator, benches, and CLI (minus
//! `validate`) are fully functional without PJRT.

pub mod detgen;
pub mod manifest;

pub use manifest::{ArtifactEntry, GenSpec, Manifest};

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str = "snitch_fm was built without the `pjrt` feature; \
     rebuild with `--features pjrt` and a vendored `xla` crate to execute \
     the AOT artifacts";

/// A compiled artifact ready to execute.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Output arity (the artifacts are lowered with `return_tuple=True`).
    pub n_outputs: usize,
}

impl Executable {
    /// Execute with f32 tensors / i32 scalars and return each output
    /// flattened to `Vec<f32>`.
    #[cfg(feature = "pjrt")]
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        let mut vecs = Vec::with_capacity(outs.len());
        for o in outs {
            vecs.push(o.to_vec::<f32>()?);
        }
        Ok(vecs)
    }

    /// PJRT-less stub: always errors (the runtime cannot be constructed
    /// without the feature, so this is unreachable in practice).
    #[cfg(not(feature = "pjrt"))]
    pub fn run(&self, _args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!(NO_PJRT)
    }
}

/// One runtime argument.
#[derive(Debug, Clone)]
pub enum Arg {
    /// f32 tensor with shape.
    F32(Vec<f32>, Vec<i64>),
    /// i32 scalar (e.g. the AR `kv_len`).
    I32(i32),
}

impl Arg {
    /// Borrowed-slice constructor to avoid clones on the hot path.
    pub fn f32(data: &[f32], shape: &[usize]) -> Arg {
        Arg::F32(data.to_vec(), shape.iter().map(|&d| d as i64).collect())
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Arg::F32(data, shape) => {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    // rank-0: reshape to scalar
                    Ok(lit.reshape(&[])?)
                } else {
                    Ok(lit.reshape(shape)?)
                }
            }
            Arg::I32(v) => Ok(xla::Literal::scalar(*v)),
        }
    }
}

/// The runtime: one PJRT CPU client + a compiled-executable cache.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    #[allow(dead_code)]
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a runtime over the default artifacts directory.
    pub fn new() -> Result<Runtime> {
        Self::with_dir(&Manifest::default_dir())
    }

    /// Create a runtime over a specific artifacts directory.
    #[cfg(feature = "pjrt")]
    pub fn with_dir(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn with_dir(_dir: &Path) -> Result<Runtime> {
        anyhow::bail!(NO_PJRT)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "pjrt-disabled".to_string()
        }
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    #[cfg(feature = "pjrt")]
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let entry = self.manifest.get(name)?.clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("{e:?}"))
                .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("{e:?}"))
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(
                name.to_string(),
                Executable { exe, n_outputs: entry.outputs.len().max(1) },
            );
        }
        Ok(&self.cache[name])
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn load(&mut self, _name: &str) -> Result<&Executable> {
        anyhow::bail!(NO_PJRT)
    }

    /// Generate the manifest's deterministic inputs for an artifact
    /// (integration tests / golden verification).
    pub fn manifest_args(&self, name: &str) -> Result<Vec<Arg>> {
        let entry = self.manifest.get(name)?;
        entry
            .args
            .iter()
            .map(|spec| match &spec.gen {
                GenSpec::Det { .. } => {
                    let data = spec.generate_f32().unwrap();
                    let shape: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    Ok(Arg::F32(data, shape))
                }
                GenSpec::I32 { value } => Ok(Arg::I32(*value)),
            })
            .collect()
    }

    /// Run an artifact on its manifest inputs and verify every output's
    /// golden fingerprint (L2 norm + first elements). Returns the outputs.
    pub fn run_golden(&mut self, name: &str, rtol: f64) -> Result<Vec<Vec<f32>>> {
        let args = self.manifest_args(name)?;
        let outs = {
            let exe = self.load(name)?;
            exe.run(&args)?
        };
        let entry = self.manifest.get(name)?;
        anyhow::ensure!(
            outs.len() == entry.outputs.len(),
            "{name}: {} outputs, manifest says {}",
            outs.len(),
            entry.outputs.len()
        );
        for (i, (got, want)) in outs.iter().zip(&entry.outputs).enumerate() {
            let l2 = got.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            anyhow::ensure!(
                (l2 - want.l2).abs() <= rtol * want.l2.abs().max(1e-6),
                "{name} output {i}: l2 {l2} vs golden {}",
                want.l2
            );
            for (j, (&g, &w)) in got.iter().zip(&want.first).enumerate() {
                anyhow::ensure!(
                    (g as f64 - w).abs() <= rtol * w.abs().max(1e-4),
                    "{name} output {i}[{j}]: {g} vs golden {w}"
                );
            }
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn arg_literal_shapes() {
        let a = Arg::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = a.to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
        let s = Arg::I32(5).to_literal().unwrap();
        assert_eq!(s.element_count(), 1);
    }

    #[test]
    fn default_dir_points_at_workspace_artifacts() {
        let d = Manifest::default_dir();
        assert!(d.ends_with("artifacts"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn runtime_without_pjrt_errors_descriptively() {
        let err = Runtime::new().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn arg_constructor_shapes() {
        let a = Arg::f32(&[1.0, 2.0], &[2, 1]);
        match a {
            Arg::F32(d, s) => {
                assert_eq!(d.len(), 2);
                assert_eq!(s, vec![2, 1]);
            }
            _ => panic!("wrong variant"),
        }
    }
}
