//! Scalability studies (paper Fig. 9).
//!
//! Left pane: GPT3-XL / GPT-J throughput vs sequence length in both NAR
//! and AR modes. Right pane: ViT images/s vs cluster count (1/4/8/16) —
//! the close-to-linear scaling claim of Sec. VII-B.
//!
//! Run: `cargo run --release --example scaling`.

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::ModelConfig;

fn main() {
    let fmt = FpFormat::Fp8;
    let engine = InferenceEngine::new(PlatformConfig::occamy());

    println!("GPT throughput vs sequence length ({}):", fmt.name());
    println!("{:<10} {:>6} {:>14} {:>14}", "model", "S", "NAR tok/s", "AR tok/s");
    for cfg in [ModelConfig::gpt3_xl(), ModelConfig::gpt_j()] {
        for s in [128u64, 256, 512, 1024, 2048] {
            let nar = engine.run_nar(&cfg, s, fmt);
            let ar = engine.run_ar_step(&cfg, s, fmt);
            println!(
                "{:<10} {:>6} {:>14.1} {:>14.2}",
                cfg.name, s, nar.throughput, ar.throughput
            );
        }
    }
    println!("paper (Fig. 9): GPT3-XL 429->136 tok/s, GPT-J 174->74 tok/s NAR;");
    println!("               7.9->5.8 and 3.8->1 tok/s AR over S=128..2048\n");

    println!("ViT images/s vs clusters ({}):", fmt.name());
    println!("{:<8} {:>4} {:>12} {:>9}", "model", "C", "images/s", "speedup");
    for cfg in [ModelConfig::vit_b(), ModelConfig::vit_l(), ModelConfig::vit_h()] {
        let mut base = 0.0;
        for clusters in [1u32, 4, 8, 16] {
            let engine = InferenceEngine::new(PlatformConfig::with_clusters(clusters));
            let r = engine.run_nar(&cfg, cfg.seq, fmt);
            if clusters == 1 {
                base = r.throughput;
            }
            println!(
                "{:<8} {:>4} {:>12.2} {:>8.1}x",
                cfg.name,
                clusters,
                r.throughput,
                r.throughput / base
            );
        }
    }
    println!("paper (Fig. 9 right): 4/6/12x (B), 4/6/11.9x (L), 4/7.9/15.8x (H)");
}
