"""i-GELU Pallas kernel (paper Sec. V-A4).

The paper approximates GELU with the i-GELU polynomial of Kim et al.
(I-BERT) to avoid divisions and tanh on the Snitch FPU. The polynomial is
evaluated in fp32 (the paper executes activations in FP32 even in the FP8
variants, with conversions before/after).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import pick_block

from . import ref


def _igelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    inv_sqrt2 = 0.7071067811865475
    z = x * inv_sqrt2
    sign = jnp.sign(z)
    az = jnp.minimum(jnp.abs(z), -ref.IGELU_B)
    erf = sign * (ref.IGELU_A * jnp.square(az + ref.IGELU_B) + ref.IGELU_C)
    o_ref[...] = (x * 0.5 * (1.0 + erf)).astype(o_ref.dtype)



@functools.partial(jax.jit, static_argnames=("br",))
def i_gelu(x, br=64):
    """Elementwise i-GELU over x: [S, F], row-block tiled."""
    s, f = x.shape
    br = pick_block(s, br)
    return pl.pallas_call(
        _igelu_kernel,
        grid=(s // br,),
        in_specs=[pl.BlockSpec((br, f), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, f), x.dtype),
        interpret=True,
    )(x)
