//! Multi-cluster simulation: barriers, parallel sections, and the
//! logarithmic cluster-to-cluster reduction (paper Sec. V-B).

use crate::arch::{Features, MemLevel, PlatformConfig};
use crate::sim::dma::{DmaEngine, Transfer};
use crate::sim::noc;
use crate::sim::KernelCost;

/// Cycles for a hardware-barrier synchronization across clusters.
const BARRIER_CYCLES: u64 = 50;

/// Result of simulating a tree reduction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReductionOutcome {
    pub cycles: u64,
    pub c2c_bytes: u64,
    pub hbm_bytes: u64,
    pub levels: u32,
}

/// Simulates work spread across the platform's clusters.
#[derive(Debug, Clone)]
pub struct MultiClusterSim {
    pub platform: PlatformConfig,
}

impl MultiClusterSim {
    pub fn new(platform: &PlatformConfig) -> MultiClusterSim {
        MultiClusterSim { platform: platform.clone() }
    }

    pub fn features(&self) -> Features {
        self.platform.features
    }

    /// Combine per-cluster costs of one parallel section: wall-clock is the
    /// slowest cluster plus a barrier; traffic/flops aggregate.
    pub fn parallel(&self, per_cluster: &[KernelCost]) -> KernelCost {
        let mut total = KernelCost::default();
        if per_cluster.is_empty() {
            return total;
        }
        let mut crit = KernelCost::default();
        for c in per_cluster {
            total.flops += c.flops;
            total.hbm_read_bytes += c.hbm_read_bytes;
            total.hbm_write_bytes += c.hbm_write_bytes;
            total.c2c_bytes += c.c2c_bytes;
            total.d2d_bytes += c.d2d_bytes;
            total.dma_transfers += c.dma_transfers;
            if c.cycles > crit.cycles {
                crit = *c;
            }
        }
        total.cycles = crit.cycles + BARRIER_CYCLES;
        total.compute_cycles = crit.compute_cycles;
        total.dma_exposed_cycles = crit.dma_exposed_cycles;
        total
    }

    /// Simulate the binary-tree sum reduction of one partial tile of
    /// `tile_bytes` living in every cluster's SPM, with `add_cycles_per_level`
    /// the receiver's elementwise-add time (paper Sec. V-B):
    ///
    /// * with `cluster_to_cluster`: sends ride the group/global crossbars,
    ///   all sends of one level run in parallel, `log2(n)` levels.
    /// * without it (baseline ablation): every partial bounces through HBM
    ///   (write + read back), and HBM serializes the level's transfers.
    pub fn tree_reduce(
        &self,
        tile_bytes: u64,
        add_cycles_per_level: u64,
    ) -> ReductionOutcome {
        let n = self.platform.total_clusters();
        if n <= 1 || tile_bytes == 0 {
            return ReductionOutcome { cycles: 0, c2c_bytes: 0, hbm_bytes: 0, levels: 0 };
        }
        let schedule = noc::reduction_schedule(&self.platform);
        let dma = DmaEngine::new(&self.platform);
        let mut cycles = 0u64;
        let mut c2c = 0u64;
        let mut hbm = 0u64;
        for level in &schedule {
            if level.is_empty() {
                continue;
            }
            if self.platform.features.cluster_to_cluster {
                // Parallel sends over dedicated links; level cost = one
                // transfer + receiver add + barrier.
                let worst = level
                    .iter()
                    .map(|s| dma.transfer_cycles(Transfer::d1(tile_bytes, s.link)))
                    .max()
                    .unwrap_or(0);
                cycles += worst + add_cycles_per_level + BARRIER_CYCLES;
                c2c += tile_bytes * level.len() as u64;
            } else {
                // Baseline: write partial to HBM, partner reads it back.
                // The level's transfers share the HBM.
                let sharers = (level.len() as u64 * 2).max(1);
                let shared = dma.clone().with_hbm_sharers(sharers);
                let write =
                    shared.transfer_cycles(Transfer::d1(tile_bytes, MemLevel::Hbm));
                let read =
                    shared.transfer_cycles(Transfer::d1(tile_bytes, MemLevel::Hbm));
                cycles += write + read + add_cycles_per_level + BARRIER_CYCLES;
                hbm += 2 * tile_bytes * level.len() as u64;
            }
        }
        ReductionOutcome { cycles, c2c_bytes: c2c, hbm_bytes: hbm, levels: schedule.len() as u32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_takes_max() {
        let sim = MultiClusterSim::new(&PlatformConfig::occamy());
        let costs = vec![
            KernelCost { cycles: 100, flops: 10, ..Default::default() },
            KernelCost { cycles: 300, flops: 10, ..Default::default() },
            KernelCost { cycles: 200, flops: 10, ..Default::default() },
        ];
        let c = sim.parallel(&costs);
        assert_eq!(c.cycles, 300 + BARRIER_CYCLES);
        assert_eq!(c.flops, 30);
    }

    #[test]
    fn tree_reduce_has_log_levels() {
        let sim = MultiClusterSim::new(&PlatformConfig::occamy());
        let out = sim.tree_reduce(64 * 1024, 100);
        assert_eq!(out.levels, 4); // log2(16)
        assert!(out.c2c_bytes > 0);
        assert_eq!(out.hbm_bytes, 0);
    }

    #[test]
    fn c2c_reduction_beats_hbm_bounce() {
        // The paper's claim: hierarchical-interconnect reduction avoids
        // serialized HBM round trips.
        let opt = MultiClusterSim::new(&PlatformConfig::occamy());
        let base = MultiClusterSim::new(&PlatformConfig {
            features: Features { cluster_to_cluster: false, ..Features::all() },
            ..PlatformConfig::occamy()
        });
        let tile = 128 * 1024;
        let a = opt.tree_reduce(tile, 200);
        let b = base.tree_reduce(tile, 200);
        assert!(a.cycles < b.cycles, "c2c {} vs hbm {}", a.cycles, b.cycles);
        assert_eq!(a.hbm_bytes, 0);
        assert_eq!(b.c2c_bytes, 0);
        assert_eq!(b.hbm_bytes, 2 * tile * 15);
    }

    #[test]
    fn single_cluster_no_reduction() {
        let sim = MultiClusterSim::new(&PlatformConfig::with_clusters(1));
        let out = sim.tree_reduce(1 << 20, 10);
        assert_eq!(out.cycles, 0);
        assert_eq!(out.levels, 0);
    }
}
