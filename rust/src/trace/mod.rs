//! Serving telemetry: typed spans on the simulated-cycle clock, exported
//! as Chrome trace-event JSON (`serve --trace out.json`) that opens
//! directly in Perfetto or `chrome://tracing`.
//!
//! The recorder is strictly *passive*: the batcher's run loops call its
//! hooks after every scheduling decision is already made, so a traced run
//! prices and schedules bit-identically to an untraced one (asserted under
//! `ServeReport::same_outcome` in `tests/event_equivalence.rs` and the
//! randomized invariants suite). When tracing is off the recorder is
//! simply absent (`Option`-gated in the run state) and the hot loops pay
//! one branch per hook.
//!
//! # Track taxonomy
//!
//! One [`TraceRecorder`] covers one engine (= one replica). The fleet
//! paths stitch per-replica recorders into a [`FleetTrace`], which assigns
//! each replica a distinct Chrome *process* (pid) at export:
//!
//! * **tid 0 — engine.** Every priced pass as a complete span
//!   ([`PassSpan`]: phase, batch, tokens, per-kernel-class cycle split,
//!   collective share), plus fault stalls ([`StallSpan`]) and explicit
//!   `idle` filler spans, so busy + stall + idle tile the makespan exactly
//!   (asserted by [`TraceRecorder::track_accounting`]).
//! * **tid 1 — d2d/collectives.** The communication share of each sharded
//!   pass as a tail sub-span, so the TP tax is visible as its own track.
//! * **tid `REQUEST_TID_BASE + id` — one thread per request.** A `queued`
//!   span (arrival → admission), a `serve` span (admission → retirement)
//!   and nested `prefill-chunk` spans, with preemption / salvage instants.
//! * **counters.** Fixed-cadence gauge samples ([`GaugeSample`]) at the
//!   `--metrics-interval` cadence: resident requests, queue depth, KV pool
//!   fill, cumulative FPU-utilization proxy and d2d link bytes.
//! * **pid 0 — kv-migration.** Disaggregated prefill→decode KV handoffs
//!   ([`MigrationSpan`]), one thread per migrating request.
//!
//! Cycle timestamps convert to trace microseconds at the platform clock
//! (`cycles / freq_ghz / 1000`), so span durations read directly as
//! simulated time. See `docs/observability.md` for the full flag and
//! track reference.

#![warn(missing_docs)]

use std::collections::HashMap;

use crate::coordinator::breakdown::KindCycles;
use crate::coordinator::kv_paging::KvPoolGauges;

/// Default gauge cadence in simulated microseconds (`--metrics-interval`).
pub const DEFAULT_METRICS_INTERVAL_US: f64 = 1000.0;

/// First tid used for request lifecycle threads (request `id` maps to tid
/// `REQUEST_TID_BASE + id`); tids below are engine-owned tracks.
pub const REQUEST_TID_BASE: u64 = 16;

/// Knobs a traced run is launched with (`serve --trace --metrics-interval`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSettings {
    /// Gauge sampling cadence in simulated microseconds.
    pub metrics_interval_us: f64,
}

impl Default for TraceSettings {
    fn default() -> Self {
        TraceSettings { metrics_interval_us: DEFAULT_METRICS_INTERVAL_US }
    }
}

/// Which kind of work a priced pass performed, derived from the pass
/// shape (chunk continuations only, decode slots only, or both fused).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassPhase {
    /// Chunk continuations only.
    Prefill,
    /// Decode slots only.
    Decode,
    /// A fused Sarathi-style prefill + decode iteration.
    Mixed,
}

impl PassPhase {
    /// Stable lowercase label ("prefill" / "decode" / "mixed").
    pub fn name(&self) -> &'static str {
        match self {
            PassPhase::Prefill => "prefill",
            PassPhase::Decode => "decode",
            PassPhase::Mixed => "mixed",
        }
    }
}

/// One priced pass on the engine track (cycle timestamps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PassSpan {
    /// Cycle the pass started.
    pub start: u64,
    /// Cycle the pass retired (`start` + priced cycles).
    pub end: u64,
    /// What the pass did.
    pub phase: PassPhase,
    /// Requests stacked into the pass.
    pub batch: u64,
    /// Prompt tokens prefilled by the pass's chunk continuations.
    pub prefill_tokens: u64,
    /// Decode slots advanced (one generated token each).
    pub decode_tokens: u64,
    /// Compute cycles split by kernel class.
    pub kind_cycles: KindCycles,
    /// Cycles inside TP all-reduces / PP sends (the `end - start` tail).
    pub collective_cycles: u64,
}

/// A fault-injected freeze on the engine track.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StallSpan {
    /// Cycle the stall fired.
    pub start: u64,
    /// Cycle the engine resumed.
    pub end: u64,
}

/// An instantaneous fault marker (fail / die / link events).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultMarker {
    /// Cycle the fault fired.
    pub at: u64,
    /// Spec-clause label (`"fail"`, `"die"`, `"stall"`, `"link"`).
    pub label: &'static str,
}

/// One prefill chunk attributed to a request's lifecycle thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpan {
    /// Request the chunk belongs to.
    pub id: usize,
    /// Cycle the chunk's pass started.
    pub start: u64,
    /// Cycle the chunk's pass retired.
    pub end: u64,
    /// Prompt tokens the chunk materialized.
    pub tokens: u64,
}

/// A request's lifecycle on its own thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestLifecycle {
    /// Request id (tid = [`REQUEST_TID_BASE`] + id).
    pub id: usize,
    /// Cycle the request arrived (starts the `queued` span).
    pub arrival: u64,
    /// Cycle the request was admitted (starts the `serve` span).
    pub admitted: u64,
    /// Cycle the span closed — retirement, preemption, or salvage;
    /// `None` when the trace ended with the request still resident.
    pub retired: Option<u64>,
    /// Whether the span closed by *finishing* (a preempted request's
    /// partial span closes unfinished and a fresh span opens when it is
    /// re-admitted).
    pub finished: bool,
    /// Prompt length in tokens.
    pub prompt_tokens: u64,
    /// Tokens generated by the time the span closed (only meaningful on
    /// the finished span).
    pub gen_tokens: u64,
    /// Times the request had been preempted when this span opened.
    pub preemptions: u32,
}

/// An instantaneous request marker (preemption, rejection, salvage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestMarker {
    /// Request the marker belongs to.
    pub id: usize,
    /// Cycle it happened.
    pub at: u64,
    /// What happened (`"preempt"`, `"reject"`, `"salvage"`).
    pub label: &'static str,
}

/// One fixed-cadence gauge sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaugeSample {
    /// Cycle the sample was taken.
    pub at: u64,
    /// Requests resident in the batch (admitted, not yet retired).
    pub resident: u64,
    /// Requests waiting in the ready queue.
    pub queue_depth: u64,
    /// KV pool occupancy.
    pub kv: KvPoolGauges,
    /// Cumulative FPU-utilization proxy over busy cycles so far, in
    /// `[0, 1]`.
    pub fpu_utilization: f64,
    /// Cumulative die-to-die link bytes moved so far.
    pub d2d_bytes: u64,
}

/// Busy / stall / idle split of the engine track, in cycles; the three
/// sum exactly to the recorded makespan by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TrackAccounting {
    /// Cycles inside priced passes.
    pub busy: u64,
    /// Cycles inside fault stalls.
    pub stall: u64,
    /// Everything else up to the makespan.
    pub idle: u64,
}

/// Per-engine telemetry recorder. Constructed by the traced run entry
/// points (`ContinuousBatcher::run_traced`), filled by passive hooks in
/// the run loops, sealed with [`TraceRecorder::finish`].
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    /// Platform clock, for cycle → microsecond conversion at export.
    freq_ghz: f64,
    /// Gauge cadence in cycles (>= 1).
    interval_cycles: u64,
    /// Next cadence boundary a sample may be taken at.
    next_sample: u64,
    /// Priced passes, in start order (engine time is monotone).
    passes: Vec<PassSpan>,
    /// Fault stalls, in start order.
    stalls: Vec<StallSpan>,
    /// Instant fault markers.
    faults: Vec<FaultMarker>,
    /// Per-request prefill chunks.
    chunks: Vec<ChunkSpan>,
    /// Closed request lifecycles (retired, or open at finish).
    requests: Vec<RequestLifecycle>,
    /// Requests admitted but not yet retired.
    open: HashMap<usize, RequestLifecycle>,
    /// Preemptions seen so far per request id (survives re-admission).
    preempt_counts: HashMap<usize, u32>,
    /// Instant request markers.
    markers: Vec<RequestMarker>,
    /// Fixed-cadence gauge samples.
    gauges: Vec<GaugeSample>,
    /// Makespan, set by [`TraceRecorder::finish`].
    total_cycles: Option<u64>,
}

impl TraceRecorder {
    /// A recorder for one engine running at `freq_ghz`, sampling gauges
    /// every `settings.metrics_interval_us` simulated microseconds.
    pub fn new(settings: &TraceSettings, freq_ghz: f64) -> TraceRecorder {
        let interval_cycles =
            (settings.metrics_interval_us.max(0.001) * freq_ghz * 1000.0).round() as u64;
        TraceRecorder {
            freq_ghz,
            interval_cycles: interval_cycles.max(1),
            next_sample: 0,
            passes: Vec::new(),
            stalls: Vec::new(),
            faults: Vec::new(),
            chunks: Vec::new(),
            requests: Vec::new(),
            open: HashMap::new(),
            preempt_counts: HashMap::new(),
            markers: Vec::new(),
            gauges: Vec::new(),
            total_cycles: None,
        }
    }

    /// The platform clock this recorder converts cycles with.
    pub fn freq_ghz(&self) -> f64 {
        self.freq_ghz
    }

    /// Gauge cadence in cycles.
    pub fn interval_cycles(&self) -> u64 {
        self.interval_cycles
    }

    /// Record one priced pass on the engine track.
    #[allow(clippy::too_many_arguments)]
    pub fn pass(
        &mut self,
        phase: PassPhase,
        start: u64,
        end: u64,
        batch: u64,
        prefill_tokens: u64,
        decode_tokens: u64,
        kind_cycles: KindCycles,
        collective_cycles: u64,
    ) {
        debug_assert!(end >= start, "pass span runs backwards");
        self.passes.push(PassSpan {
            start,
            end,
            phase,
            batch,
            prefill_tokens,
            decode_tokens,
            kind_cycles,
            collective_cycles,
        });
    }

    /// Record a fault-injected stall on the engine track.
    pub fn stall(&mut self, start: u64, end: u64) {
        self.stalls.push(StallSpan { start, end });
    }

    /// Record an instantaneous fault marker.
    pub fn fault(&mut self, at: u64, label: &'static str) {
        self.faults.push(FaultMarker { at, label });
    }

    /// Record one prefill chunk on a request's lifecycle thread.
    pub fn prefill_chunk(&mut self, id: usize, start: u64, end: u64, tokens: u64) {
        self.chunks.push(ChunkSpan { id, start, end, tokens });
    }

    /// A request was admitted at `now` (its `queued` span closes, its
    /// `serve` span opens). Called again after a preemption when the
    /// request is re-admitted — the new span carries the running
    /// preemption count.
    pub fn request_admitted(&mut self, id: usize, arrival: u64, now: u64, prompt: u64) {
        let preemptions = self.preempt_counts.get(&id).copied().unwrap_or(0);
        self.open.insert(
            id,
            RequestLifecycle {
                id,
                arrival,
                admitted: now,
                retired: None,
                finished: false,
                prompt_tokens: prompt,
                gen_tokens: 0,
                preemptions,
            },
        );
    }

    /// A request retired at `now` with `gen_tokens` generated.
    pub fn request_retired(&mut self, id: usize, now: u64, gen_tokens: u64) {
        if let Some(mut r) = self.open.remove(&id) {
            r.retired = Some(now);
            r.finished = true;
            r.gen_tokens = gen_tokens;
            self.requests.push(r);
        }
    }

    /// A request was preempted at `now`: its partial `serve` span closes
    /// unfinished and it goes back to the queue (a later
    /// [`TraceRecorder::request_admitted`] reopens it).
    pub fn request_preempted(&mut self, id: usize, now: u64) {
        *self.preempt_counts.entry(id).or_insert(0) += 1;
        if let Some(mut r) = self.open.remove(&id) {
            r.retired = Some(now);
            self.requests.push(r);
        }
        self.markers.push(RequestMarker { id, at: now, label: "preempt" });
    }

    /// A request was rejected outright at `now` (never admitted).
    pub fn request_rejected(&mut self, id: usize, now: u64) {
        self.markers.push(RequestMarker { id, at: now, label: "reject" });
    }

    /// A request was salvaged off a failed replica at `now` (its span
    /// closes unfinished here; it continues on the adopting replica).
    pub fn request_salvaged(&mut self, id: usize, now: u64) {
        if let Some(mut r) = self.open.remove(&id) {
            r.retired = Some(now);
            self.requests.push(r);
        }
        self.markers.push(RequestMarker { id, at: now, label: "salvage" });
    }

    /// Whether a [`TraceRecorder::maybe_sample`] call at `now` would take
    /// a sample — lets hot call sites skip computing gauge values (pool
    /// scans, power-model queries) between cadence boundaries.
    pub fn sample_due(&self, now: u64) -> bool {
        now >= self.next_sample
    }

    /// Take a gauge sample if `now` crossed the cadence boundary. The
    /// sample is stamped at `now` and the next boundary is the next
    /// multiple of the interval after `now`.
    #[allow(clippy::too_many_arguments)]
    pub fn maybe_sample(
        &mut self,
        now: u64,
        resident: u64,
        queue_depth: u64,
        kv: KvPoolGauges,
        fpu_utilization: f64,
        d2d_bytes: u64,
    ) {
        if now < self.next_sample {
            return;
        }
        self.gauges.push(GaugeSample {
            at: now,
            resident,
            queue_depth,
            kv,
            fpu_utilization,
            d2d_bytes,
        });
        self.next_sample = (now / self.interval_cycles + 1) * self.interval_cycles;
    }

    /// Seal the recorder at the run's makespan: open requests are closed
    /// as unfinished (sorted by id, deterministically) and the idle
    /// accounting becomes final.
    pub fn finish(&mut self, total_cycles: u64) {
        let mut open: Vec<RequestLifecycle> = self.open.drain().map(|(_, r)| r).collect();
        open.sort_by_key(|r| r.id);
        self.requests.extend(open);
        self.total_cycles = Some(total_cycles);
    }

    /// Makespan the recorder was sealed at (`None` before
    /// [`TraceRecorder::finish`]).
    pub fn total_cycles(&self) -> Option<u64> {
        self.total_cycles
    }

    /// Priced passes in start order.
    pub fn passes(&self) -> &[PassSpan] {
        &self.passes
    }

    /// Fault stalls in start order.
    pub fn stalls(&self) -> &[StallSpan] {
        &self.stalls
    }

    /// Instant fault markers.
    pub fn faults(&self) -> &[FaultMarker] {
        &self.faults
    }

    /// Per-request prefill chunks.
    pub fn chunks(&self) -> &[ChunkSpan] {
        &self.chunks
    }

    /// Request lifecycles (closed; call after [`TraceRecorder::finish`]).
    pub fn requests(&self) -> &[RequestLifecycle] {
        &self.requests
    }

    /// Instant request markers.
    pub fn markers(&self) -> &[RequestMarker] {
        &self.markers
    }

    /// Gauge samples in time order.
    pub fn gauges(&self) -> &[GaugeSample] {
        &self.gauges
    }

    /// Busy / stall / idle spans of the engine track merged in start
    /// order, with explicit idle filler covering every gap up to the
    /// makespan. Requires [`TraceRecorder::finish`].
    pub fn track_spans(&self) -> Vec<(u64, u64, &'static str)> {
        let total = self.total_cycles.unwrap_or_else(|| {
            self.passes
                .iter()
                .map(|p| p.end)
                .chain(self.stalls.iter().map(|s| s.end))
                .max()
                .unwrap_or(0)
        });
        let mut busy: Vec<(u64, u64, &'static str)> = self
            .passes
            .iter()
            .map(|p| (p.start, p.end, p.phase.name()))
            .chain(self.stalls.iter().map(|s| (s.start, s.end, "stall")))
            .collect();
        busy.sort_by_key(|&(start, end, _)| (start, end));
        let mut out = Vec::with_capacity(busy.len() * 2 + 1);
        let mut cursor = 0u64;
        for (start, end, kind) in busy {
            if start > cursor {
                out.push((cursor, start, "idle"));
            }
            out.push((start, end, kind));
            cursor = cursor.max(end);
        }
        if total > cursor {
            out.push((cursor, total, "idle"));
        }
        out
    }

    /// Cycle totals of the engine track. Busy + stall + idle equals the
    /// sealed makespan exactly (the tiling invariant the tests assert).
    pub fn track_accounting(&self) -> TrackAccounting {
        let mut acc = TrackAccounting::default();
        for (start, end, kind) in self.track_spans() {
            let d = end - start;
            match kind {
                "idle" => acc.idle += d,
                "stall" => acc.stall += d,
                _ => acc.busy += d,
            }
        }
        acc
    }
}

/// One disaggregated KV migration (prefill die → decode die).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationSpan {
    /// Migrating request id (also the thread the span lands on).
    pub id: usize,
    /// Cycle the handoff started (prefill finish time).
    pub start: u64,
    /// Cycle the KV landed on the decode die (includes retries).
    pub end: u64,
    /// Wire bytes moved over the d2d links.
    pub bytes: u64,
    /// Transfer attempts (1 = clean, >1 = corruption retries).
    pub attempts: u32,
}

/// A whole run's telemetry: per-replica recorders stitched under distinct
/// Chrome pids, plus fleet-level KV migration spans. The single-engine
/// path wraps its one recorder in a one-replica fleet.
#[derive(Debug, Clone, Default)]
pub struct FleetTrace {
    /// `(process label, recorder)` per replica; pid = index + 1.
    replicas: Vec<(String, TraceRecorder)>,
    /// Disaggregated KV handoffs (pid 0).
    migrations: Vec<MigrationSpan>,
}

impl FleetTrace {
    /// An empty fleet trace (stitch replicas in with
    /// [`FleetTrace::push_replica`]).
    pub fn new() -> FleetTrace {
        FleetTrace::default()
    }

    /// Wrap one engine's recorder as a single-replica fleet.
    pub fn single(label: &str, rec: TraceRecorder) -> FleetTrace {
        let mut fleet = FleetTrace::new();
        fleet.push_replica(label, rec);
        fleet
    }

    /// Stitch one replica's sealed recorder in under the next pid.
    pub fn push_replica(&mut self, label: &str, rec: TraceRecorder) {
        self.replicas.push((label.to_string(), rec));
    }

    /// Record one disaggregated KV migration.
    pub fn push_migration(&mut self, span: MigrationSpan) {
        self.migrations.push(span);
    }

    /// Stitched replicas, in pid order (pid = index + 1).
    pub fn replicas(&self) -> &[(String, TraceRecorder)] {
        &self.replicas
    }

    /// Fleet-level migration spans.
    pub fn migrations(&self) -> &[MigrationSpan] {
        &self.migrations
    }

    /// Render the whole trace as Chrome trace-event JSON (a
    /// `{"traceEvents": [...]}` document Perfetto opens directly).
    pub fn to_chrome_json(&self) -> String {
        let mut ev: Vec<String> = Vec::new();
        for (i, (label, rec)) in self.replicas.iter().enumerate() {
            let pid = i as u64 + 1;
            let us = |cycles: u64| cycles_to_us(cycles, rec.freq_ghz);
            ev.push(meta_event(pid, None, "process_name", label));
            ev.push(meta_event(pid, Some(0), "thread_name", "engine"));
            ev.push(meta_event(pid, Some(1), "thread_name", "d2d/collectives"));
            for (start, end, kind) in rec.track_spans() {
                if kind != "idle" {
                    continue;
                }
                ev.push(x_event("idle", "idle", us(start), us(end - start), pid, 0, "{}"));
            }
            for p in rec.passes() {
                let args = format!(
                    "{{\"batch\":{},\"prefill_tokens\":{},\"decode_tokens\":{},\
                     \"collective_cycles\":{},{}}}",
                    p.batch,
                    p.prefill_tokens,
                    p.decode_tokens,
                    p.collective_cycles,
                    kind_cycles_json(&p.kind_cycles),
                );
                ev.push(x_event(
                    p.phase.name(),
                    "pass",
                    us(p.start),
                    us(p.end - p.start),
                    pid,
                    0,
                    &args,
                ));
                if p.collective_cycles > 0 {
                    let cc = p.collective_cycles.min(p.end - p.start);
                    ev.push(x_event(
                        "collective",
                        "d2d",
                        us(p.end - cc),
                        us(cc),
                        pid,
                        1,
                        "{}",
                    ));
                }
            }
            for s in rec.stalls() {
                ev.push(x_event("stall", "fault", us(s.start), us(s.end - s.start), pid, 0, "{}"));
            }
            for f in rec.faults() {
                ev.push(i_event(f.label, "fault", us(f.at), pid, 0));
            }
            for r in rec.requests() {
                let tid = REQUEST_TID_BASE + r.id as u64;
                ev.push(meta_event(pid, Some(tid), "thread_name", &format!("req {}", r.id)));
                if r.admitted > r.arrival {
                    ev.push(x_event(
                        "queued",
                        "request",
                        us(r.arrival),
                        us(r.admitted - r.arrival),
                        pid,
                        tid,
                        "{}",
                    ));
                }
                let end = r.retired.or(rec.total_cycles).unwrap_or(r.admitted);
                let args = format!(
                    "{{\"prompt_tokens\":{},\"gen_tokens\":{},\"preemptions\":{},\
                     \"finished\":{}}}",
                    r.prompt_tokens,
                    r.gen_tokens,
                    r.preemptions,
                    r.finished,
                );
                ev.push(x_event(
                    "serve",
                    "request",
                    us(r.admitted),
                    us(end.saturating_sub(r.admitted)),
                    pid,
                    tid,
                    &args,
                ));
            }
            for c in rec.chunks() {
                let args = format!("{{\"tokens\":{}}}", c.tokens);
                ev.push(x_event(
                    "prefill-chunk",
                    "request",
                    us(c.start),
                    us(c.end - c.start),
                    pid,
                    REQUEST_TID_BASE + c.id as u64,
                    &args,
                ));
            }
            for m in rec.markers() {
                ev.push(i_event(m.label, "request", us(m.at), pid, REQUEST_TID_BASE + m.id as u64));
            }
            for g in rec.gauges() {
                let t = us(g.at);
                ev.push(c_event("resident", t, pid, g.resident as f64));
                ev.push(c_event("queue_depth", t, pid, g.queue_depth as f64));
                ev.push(c_event("kv_pages_used", t, pid, g.kv.used_pages as f64));
                ev.push(c_event("kv_bytes_in_use", t, pid, g.kv.bytes_in_use as f64));
                ev.push(c_event("fpu_utilization", t, pid, g.fpu_utilization));
                ev.push(c_event("d2d_bytes", t, pid, g.d2d_bytes as f64));
            }
        }
        if !self.migrations.is_empty() {
            let freq = self.replicas.first().map(|(_, r)| r.freq_ghz).unwrap_or(1.0);
            ev.push(meta_event(0, None, "process_name", "kv-migration"));
            for m in &self.migrations {
                let args = format!("{{\"bytes\":{},\"attempts\":{}}}", m.bytes, m.attempts);
                ev.push(x_event(
                    "kv-migrate",
                    "d2d",
                    cycles_to_us(m.start, freq),
                    cycles_to_us(m.end.saturating_sub(m.start), freq),
                    0,
                    m.id as u64,
                    &args,
                ));
            }
        }
        let mut out = String::with_capacity(ev.iter().map(|e| e.len() + 2).sum::<usize>() + 32);
        out.push_str("{\"traceEvents\":[\n");
        for (i, e) in ev.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(e);
        }
        out.push_str("\n]}\n");
        out
    }
}

/// Convert cycles to trace microseconds at `freq_ghz`.
pub fn cycles_to_us(cycles: u64, freq_ghz: f64) -> f64 {
    cycles as f64 / freq_ghz / 1000.0
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn kind_cycles_json(kc: &KindCycles) -> String {
    let mut out = String::new();
    for (i, (kind, cycles)) in kc.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}_cycles\":{}", kind.name(), cycles));
    }
    out
}

fn x_event(name: &str, cat: &str, ts: f64, dur: f64, pid: u64, tid: u64, args: &str) -> String {
    format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\
         \"pid\":{},\"tid\":{},\"args\":{}}}",
        esc(name),
        esc(cat),
        ts,
        dur,
        pid,
        tid,
        args
    )
}

fn i_event(name: &str, cat: &str, ts: f64, pid: u64, tid: u64) -> String {
    format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{:.3},\
         \"pid\":{},\"tid\":{},\"args\":{{}}}}",
        esc(name),
        esc(cat),
        ts,
        pid,
        tid
    )
}

fn c_event(name: &str, ts: f64, pid: u64, value: f64) -> String {
    format!(
        "{{\"name\":\"{}\",\"ph\":\"C\",\"ts\":{:.3},\"pid\":{},\"tid\":0,\
         \"args\":{{\"value\":{:.4}}}}}",
        esc(name),
        ts,
        pid,
        value
    )
}

fn meta_event(pid: u64, tid: Option<u64>, name: &str, value: &str) -> String {
    format!(
        "{{\"name\":\"{}\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\
         \"args\":{{\"name\":\"{}\"}}}}",
        esc(name),
        pid,
        tid.unwrap_or(0),
        esc(value)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> TraceRecorder {
        TraceRecorder::new(&TraceSettings { metrics_interval_us: 1.0 }, 1.0)
    }

    #[test]
    fn track_tiling_covers_makespan_exactly() {
        let mut r = rec();
        r.pass(PassPhase::Prefill, 100, 300, 1, 64, 0, KindCycles::default(), 0);
        r.stall(400, 450);
        r.pass(PassPhase::Decode, 450, 700, 4, 0, 4, KindCycles::default(), 0);
        r.finish(1000);
        let acc = r.track_accounting();
        assert_eq!(acc.busy, 450);
        assert_eq!(acc.stall, 50);
        assert_eq!(acc.idle, 500);
        assert_eq!(acc.busy + acc.stall + acc.idle, 1000);
        // Spans tile: each begins where the previous ended.
        let spans = r.track_spans();
        assert_eq!(spans.first().unwrap().0, 0);
        assert_eq!(spans.last().unwrap().1, 1000);
        for w in spans.windows(2) {
            assert_eq!(w[0].1, w[1].0, "gap or overlap at {w:?}");
        }
    }

    #[test]
    fn gauge_sampling_respects_cadence() {
        let mut r = TraceRecorder::new(&TraceSettings { metrics_interval_us: 1.0 }, 1.0);
        assert_eq!(r.interval_cycles(), 1000);
        let kv = KvPoolGauges { total_pages: 8, used_pages: 0, bytes_in_use: 0 };
        r.maybe_sample(0, 0, 0, kv, 0.0, 0); // boundary 0: sampled
        r.maybe_sample(400, 1, 1, kv, 0.0, 0); // before next boundary: skipped
        r.maybe_sample(1500, 2, 2, kv, 0.5, 64); // crossed 1000: sampled
        r.maybe_sample(1700, 3, 3, kv, 0.5, 64); // before 2000: skipped
        assert_eq!(r.gauges().len(), 2);
        assert_eq!(r.gauges()[1].at, 1500);
        assert_eq!(r.gauges()[1].resident, 2);
    }

    #[test]
    fn request_lifecycle_round_trips() {
        let mut r = rec();
        r.request_admitted(7, 10, 50, 128);
        r.request_retired(7, 900, 16);
        r.request_admitted(8, 20, 60, 64);
        r.request_rejected(9, 70);
        r.finish(1000);
        assert_eq!(r.requests().len(), 2);
        let done = r.requests().iter().find(|q| q.id == 7).unwrap();
        assert_eq!(done.retired, Some(900));
        assert_eq!(done.gen_tokens, 16);
        let open = r.requests().iter().find(|q| q.id == 8).unwrap();
        assert_eq!(open.retired, None, "unfinished requests close as open");
        assert_eq!(r.markers().len(), 1);
    }

    #[test]
    fn chrome_export_is_wellformed_and_ordered() {
        let mut r = rec();
        r.pass(PassPhase::Mixed, 0, 500, 3, 32, 2, KindCycles::default(), 100);
        r.request_admitted(0, 0, 0, 32);
        r.request_retired(0, 500, 2);
        let kv = KvPoolGauges { total_pages: 8, used_pages: 2, bytes_in_use: 1024 };
        r.maybe_sample(0, 1, 0, kv, 0.25, 0);
        r.finish(600);
        let mut fleet = FleetTrace::single("replica 0", r);
        fleet.push_migration(MigrationSpan { id: 0, start: 500, end: 550, bytes: 1024, attempts: 1 });
        let json = fleet.to_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("\"mixed\""));
        assert!(json.contains("\"collective\""));
        assert!(json.contains("\"kv-migrate\""));
        assert!(json.contains("\"gemm_cycles\":0"));
        assert!(json.contains("\"fpu_utilization\""));
        // Exactly one top-level object, balanced braces.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }
}
