//! Ablation study: each platform feature knocked out individually on the
//! GPT-J NAR FP32 workload (S=1024) — quantifies what every ingredient of
//! the paper's 4.6-5.0x "optimized" jump contributes (Sec. VII-A discusses
//! them only jointly).

mod common;

use snitch_fm::arch::{Features, FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::ModelConfig;

fn throughput(features: Features, fmt: FpFormat) -> f64 {
    let mut p = PlatformConfig::occamy();
    p.features = features;
    InferenceEngine::new(p).run_nar(&ModelConfig::gpt_j(), 1024, fmt).throughput
}

fn main() {
    common::header("ablations", "single-feature knockouts, GPT-J NAR S=1024");
    let fmt = FpFormat::Fp32;
    let (t, full) = common::time_median(3, || throughput(Features::all(), fmt));
    println!("{:<28} {:>10} {:>9}", "configuration", "tok/s", "vs full");
    println!("{:<28} {:>10.2} {:>8.2}x", "full (all features)", full, 1.0);
    let knockouts: [(&str, Features); 6] = [
        ("no Xssr", Features { xssr: false, ..Features::all() }),
        ("no Xfrep", Features { xfrep: false, ..Features::all() }),
        ("no SIMD", Features { simd: false, ..Features::all() }),
        ("no cluster-to-cluster", Features { cluster_to_cluster: false, ..Features::all() }),
        ("no double buffering", Features { double_buffering: false, ..Features::all() }),
        ("baseline (paper)", Features::baseline()),
    ];
    for (name, f) in knockouts {
        let tp = throughput(f, fmt);
        println!("{name:<28} {tp:>10.2} {:>8.2}x", tp / full);
    }
    // Precision effect of SIMD alone: FP8 with SIMD off collapses to ~FP64.
    let fp8_simd = throughput(Features::all(), FpFormat::Fp8);
    let fp8_nosimd = throughput(Features { simd: false, ..Features::all() }, FpFormat::Fp8);
    println!(
        "\nFP8 with/without SIMD lanes: {fp8_simd:.1} / {fp8_nosimd:.1} tok/s ({:.2}x from packed SIMD)",
        fp8_simd / fp8_nosimd
    );
    common::report_timing("ablation-point", t);
}
