//! Data-parallel serving router: N engine replicas — single-die engines
//! or `tp x pp` sharded replica groups, per the batcher options' shard
//! plan — each running the continuous batcher against its own KV budget.
//!
//! The router assigns arriving requests to replicas with a deterministic
//! backlog model (virtual finish times over modeled per-token service
//! cost), runs each replica's [`ContinuousBatcher`] on its share, and
//! merges the per-replica [`ServeReport`]s into one fleet view:
//!
//! * [`RoutePolicy::JoinShortestQueue`] — each request joins the replica
//!   whose modeled backlog clears first.
//! * [`RoutePolicy::PrefixAffinity`] — requests carrying a shared prompt
//!   template (`Request::prefix_seed`) prefer the replica whose
//!   `PrefixCache` already holds their pages (the template's home,
//!   pinned on first sight), falling back to join-shortest-queue when
//!   the home replica's backlog runs too far ahead — so one hot template
//!   cannot melt a single die.
//!
//! `replicas = 1` returns the single batcher's report unchanged
//! (bit-identical to `InferenceEngine::serve_with`, asserted in
//! `tests/parallel_plans.rs`).
//!
//! The `*_with_faults` entry points run the same fleets under an
//! injected [`FaultPlan`]: replica failures surrender their backlog for
//! re-routing across survivors (with KV re-export priced over the —
//! possibly degraded — die-to-die link), and corrupted disaggregated KV
//! migrations retry with capped exponential backoff before falling back
//! to decode-side recompute. `docs/serving.md` documents the fault spec
//! grammar and the recovery lifecycle.

use std::collections::{BTreeMap, HashMap};

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::batcher::{BatcherConfig, ClassStats, ContinuousBatcher, ServeReport};
use crate::coordinator::breakdown::KindCycles;
use crate::coordinator::faults::{FaultPlan, ReplicaFaults, SalvagedRequest};
use crate::coordinator::kv_paging::KvGeometry;
use crate::coordinator::schedule::model_cost_batched;
use crate::coordinator::workload::{Request, Workload};
use crate::energy;
use crate::metrics::sketch::StreamSketch;
use crate::model::{Mode, ModelConfig};
use crate::parallel::collectives::{degrade_link, p2p_cost};
use crate::trace::{FleetTrace, MigrationSpan, TraceRecorder, TraceSettings};

/// How the router spreads requests over replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Least modeled backlog at arrival.
    JoinShortestQueue,
    /// Shared-prefix requests chase their template's home replica.
    PrefixAffinity,
}

impl RoutePolicy {
    /// Parse `jsq` | `affinity`.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "jsq" => Some(RoutePolicy::JoinShortestQueue),
            "affinity" => Some(RoutePolicy::PrefixAffinity),
            _ => None,
        }
    }

    /// The CLI/report spelling of the policy.
    pub const fn name(self) -> &'static str {
        match self {
            RoutePolicy::JoinShortestQueue => "jsq",
            RoutePolicy::PrefixAffinity => "affinity",
        }
    }
}

/// The fleet-level serving outcome.
#[derive(Debug, Clone)]
pub struct RouterReport {
    /// Replica engines (or sharded replica groups) in the fleet.
    pub replicas: usize,
    /// Routing policy name (`jsq` | `affinity`).
    pub policy: &'static str,
    /// Requests routed to each replica.
    pub assigned: Vec<usize>,
    /// The merged fleet view (see [`merge_reports`] for the semantics of
    /// each aggregated field).
    pub merged: ServeReport,
    /// Each replica's own report, in replica-index order.
    pub per_replica: Vec<ServeReport>,
}

/// Modeled service cost (cycles) of one request: prefill priced per
/// prompt token, decode per generated token at the workload's mean
/// context. Only *relative* weights matter to the routing decisions, so
/// the unsharded pricing serves sharded replica groups too (TP scales
/// both terms by roughly the same factor).
struct ServiceModel {
    prefill_per_token: f64,
    decode_per_token: f64,
    freq_ghz: f64,
}

impl ServiceModel {
    fn new(
        cfg: &ModelConfig,
        fmt: FpFormat,
        platform: &PlatformConfig,
        workload: &Workload,
        max_batch: usize,
    ) -> ServiceModel {
        let n = workload.len().max(1) as u64;
        let mean_prompt = (workload.total_prompt_tokens() / n).max(1);
        let mean_ctx = mean_prompt + (workload.total_gen_tokens() / n).max(1);
        let b = max_batch.max(1) as u64;
        let prefill =
            model_cost_batched(cfg, Mode::Nar, 1, mean_prompt, fmt, platform).cycles;
        let decode =
            model_cost_batched(cfg, Mode::Ar, b, mean_ctx, fmt, platform).cycles;
        ServiceModel {
            prefill_per_token: prefill as f64 / mean_prompt as f64,
            decode_per_token: decode as f64 / b as f64,
            freq_ghz: platform.freq_ghz,
        }
    }

    fn work_cycles(&self, prompt: u64, gen: u64) -> f64 {
        prompt as f64 * self.prefill_per_token + gen as f64 * self.decode_per_token
    }

    fn arrival_cycles(&self, arrival_ns: u64) -> f64 {
        arrival_ns as f64 * self.freq_ghz
    }
}

/// Split `workload` over `replicas` sub-workloads (requests keep their
/// ids). Deterministic: requests are routed in arrival order against
/// virtual per-replica finish times under the service model.
fn route_workload(
    workload: &Workload,
    replicas: usize,
    policy: RoutePolicy,
    model: &ServiceModel,
) -> Vec<Workload> {
    route_workload_penalized(workload, replicas, policy, model, &vec![0.0; replicas])
}

/// [`route_workload`] with per-replica starting backlogs (cycles). The
/// fault path seeds these with each survivor's current clock so salvaged
/// requests spread toward the least-loaded survivors; an all-zero
/// `penalty` is exactly the fresh-fleet routing.
fn route_workload_penalized(
    workload: &Workload,
    replicas: usize,
    policy: RoutePolicy,
    model: &ServiceModel,
    penalty: &[f64],
) -> Vec<Workload> {
    debug_assert_eq!(penalty.len(), replicas);
    let mut shards: Vec<Workload> = (0..replicas).map(|_| Workload::default()).collect();
    let mut ready_at = penalty.to_vec();
    let mut home: HashMap<u64, usize> = HashMap::new();

    let mut order: Vec<usize> = (0..workload.requests.len()).collect();
    order.sort_by_key(|&i| (workload.requests[i].arrival_ns, workload.requests[i].id));

    for i in order {
        let r = &workload.requests[i];
        let now = model.arrival_cycles(r.arrival_ns);
        let backlog = |j: usize| (ready_at[j] - now).max(0.0);
        let jsq = (0..replicas)
            .min_by(|&a, &b| backlog(a).partial_cmp(&backlog(b)).unwrap())
            .unwrap_or(0);
        let work = model.work_cycles(r.prompt_len, r.gen_tokens);
        let target = match policy {
            RoutePolicy::JoinShortestQueue => jsq,
            RoutePolicy::PrefixAffinity if r.prefix_len > 0 => {
                match home.get(&r.prefix_seed).copied() {
                    // Spill guard: chase the cached prefix only while the
                    // home replica's backlog is within a few requests of
                    // the shortest queue.
                    Some(h) if backlog(h) <= backlog(jsq) + 4.0 * work => h,
                    Some(_) => jsq,
                    None => {
                        home.insert(r.prefix_seed, jsq);
                        jsq
                    }
                }
            }
            RoutePolicy::PrefixAffinity => jsq,
        };
        ready_at[target] = ready_at[target].max(now) + work;
        shards[target].requests.push(r.clone());
    }
    shards
}

/// Merge per-replica reports into one fleet view. Wall-clock-like fields
/// take the slowest replica (the fleet runs in parallel), counters sum,
/// latency/TTFT/queue percentiles come from merging the per-replica
/// [`crate::metrics::sketch::StreamSketch`]es (exact below the sketch's
/// spill limit, ~1% relative error above — never a re-sort of the union
/// of per-request samples), and EVERY derived rate — aggregate and
/// decode tokens/s, occupancy, hit rates, FPU utilization, power, budget
/// fill — is rebuilt from the merged *raw* counters over the merged
/// clock. Deterministic: the result depends only on the slice order of
/// `per` (replica index), never on which replica thread finished first.
pub fn merge_reports(per: &[ServeReport], fmt: FpFormat, platform: &PlatformConfig) -> ServeReport {
    assert!(!per.is_empty(), "merge needs at least one replica report");
    let first = &per[0];
    for (i, r) in per.iter().enumerate().skip(1) {
        // Replicas of one fleet share one precision policy; a mixed merge
        // would average incomparable runs (different page geometry, pass
        // pricing, and budgets) into one meaningless report, so reject it
        // outright instead of merging.
        assert!(
            r.format == first.format
                && r.kv_format == first.kv_format
                && r.class_precision == first.class_precision,
            "replica {i} served under policy (fmt={}, kv={}, ladder=\"{}\") but replica 0 \
             used (fmt={}, kv={}, ladder=\"{}\"); reports under different precision \
             policies cannot be merged",
            r.format,
            r.kv_format,
            r.class_precision,
            first.format,
            first.kv_format,
            first.class_precision,
        );
    }
    if per.len() == 1 {
        return per[0].clone();
    }
    let mut merged = first.clone();

    let mut per_request: Vec<_> =
        per.iter().flat_map(|r| r.per_request.iter().cloned()).collect();
    per_request.sort_by_key(|s| s.id);
    let mut rejected: Vec<usize> =
        per.iter().flat_map(|r| r.rejected.iter().copied()).collect();
    rejected.sort_unstable();

    let total_cycles: u64 = per.iter().map(|r| r.total_cycles).max().unwrap_or(0);

    merged.requests = per.iter().map(|r| r.requests).sum();
    merged.completed = per.iter().map(|r| r.completed).sum();
    merged.rejected = rejected;
    merged.kv_budget_bytes = per.iter().map(|r| r.kv_budget_bytes).sum();
    merged.total_pages = per.iter().map(|r| r.total_pages).sum();
    merged.peak_kv_bytes = per.iter().map(|r| r.peak_kv_bytes).sum();
    merged.total_cycles = total_cycles;
    merged.total_seconds = platform.cycles_to_seconds(total_cycles);
    merged.prefill_tokens = per.iter().map(|r| r.prefill_tokens).sum();
    merged.prefill_chunks = per.iter().map(|r| r.prefill_chunks).sum();
    merged.gen_tokens = per.iter().map(|r| r.gen_tokens).sum();
    merged.preemptions = per.iter().map(|r| r.preemptions).sum();
    merged.prefix_hit_tokens = per.iter().map(|r| r.prefix_hit_tokens).sum();
    merged.prefix_late_hits = per.iter().map(|r| r.prefix_late_hits).sum();
    merged.fused_first_tokens = per.iter().map(|r| r.fused_first_tokens).sum();
    merged.decode_tokens = per.iter().map(|r| r.decode_tokens).sum();
    merged.decode_cycles = per.iter().map(|r| r.decode_cycles).max().unwrap_or(0);
    merged.collective_cycles = per.iter().map(|r| r.collective_cycles).sum();
    merged.d2d_bytes = per.iter().map(|r| r.d2d_bytes).sum();
    let sum_kinds = |f: fn(&ServeReport) -> &KindCycles| {
        per.iter().fold(KindCycles::default(), |mut acc, r| {
            acc.accum(f(r));
            acc
        })
    };
    merged.prefill_kind_cycles = sum_kinds(|r| &r.prefill_kind_cycles);
    merged.decode_kind_cycles = sum_kinds(|r| &r.decode_kind_cycles);
    merged.mixed_kind_cycles = sum_kinds(|r| &r.mixed_kind_cycles);
    merged.budget_tokens = per.iter().map(|r| r.budget_tokens).sum();
    merged.budget_iterations = per.iter().map(|r| r.budget_iterations).sum();
    merged.kv_imports = per.iter().map(|r| r.kv_imports).sum();
    merged.imported_kv_tokens = per.iter().map(|r| r.imported_kv_tokens).sum();
    merged.pricing_cache_hits = per.iter().map(|r| r.pricing_cache_hits).sum();
    merged.pricing_cache_misses = per.iter().map(|r| r.pricing_cache_misses).sum();
    merged.arrival_events = per.iter().map(|r| r.arrival_events).sum();
    merged.pass_events = per.iter().map(|r| r.pass_events).sum();
    merged.pass_cache_hits = per.iter().map(|r| r.pass_cache_hits).sum();
    merged.pass_cache_misses = per.iter().map(|r| r.pass_cache_misses).sum();
    merged.work = per
        .iter()
        .fold(crate::sim::KernelCost::default(), |acc, r| acc.then(r.work));

    // Fault and recovery accounting: counters sum, warnings concatenate
    // in replica order, and the fleet's degraded-capacity fraction is the
    // capacity lost to faults — injected stall cycles plus each failed
    // replica's dead time from its failure to the fleet's end of trace —
    // over `replicas x fleet wall-clock`. Exactly 0.0 on a fault-free
    // run, where every term is zero.
    merged.replica_failures = per.iter().map(|r| r.replica_failures).sum();
    merged.stall_cycles = per.iter().map(|r| r.stall_cycles).sum();
    merged.link_faults = per.iter().map(|r| r.link_faults).sum();
    merged.salvaged_requests = per.iter().map(|r| r.salvaged_requests).sum();
    merged.salvaged_kv_bytes = per.iter().map(|r| r.salvaged_kv_bytes).sum();
    merged.retries = per.iter().map(|r| r.retries).sum();
    merged.recovery_cycles = per.iter().map(|r| r.recovery_cycles).sum();
    merged.warnings = per.iter().flat_map(|r| r.warnings.iter().cloned()).collect();
    let lost_cycles: u64 = per
        .iter()
        .map(|r| {
            let dead = if r.replica_failures > 0 {
                total_cycles.saturating_sub(r.total_cycles)
            } else {
                0
            };
            r.stall_cycles + dead
        })
        .sum();
    merged.degraded_capacity_fraction = if total_cycles > 0 {
        (lost_cycles as f64 / (per.len() as u64 * total_cycles) as f64).clamp(0.0, 1.0)
    } else {
        0.0
    };

    // Latency views: fold the per-replica streaming sketches instead of
    // re-walking the union of per-request stats (which is gigabytes at
    // fleet scale). Exact-mode folds reproduce the old union-recompute
    // bit-for-bit — nearest-rank percentiles and the sorted-sum mean
    // depend only on the sample multiset — and sketch merging is
    // order-independent, so the fleet view is identical no matter how
    // replica execution interleaved.
    let mut ttft = per[0].ttft_sketch.clone();
    let mut lat = per[0].latency_sketch.clone();
    let mut tpot = per[0].tpot_sketch.clone();
    let mut queue = per[0].queue_sketch.clone();
    for r in &per[1..] {
        ttft.merge(&r.ttft_sketch);
        lat.merge(&r.latency_sketch);
        tpot.merge(&r.tpot_sketch);
        queue.merge(&r.queue_sketch);
    }
    merged.ttft_mean_s = ttft.mean();
    merged.ttft_p50_s = ttft.p(50.0);
    merged.ttft_p99_s = ttft.p(99.0);
    merged.latency_mean_s = lat.mean();
    merged.latency_p50_s = lat.p(50.0);
    merged.latency_p99_s = lat.p(99.0);
    merged.tpot_mean_s = tpot.mean();
    merged.tpot_p50_s = tpot.p(50.0);
    merged.tpot_p99_s = tpot.p(99.0);
    merged.queue_mean_s = queue.mean();
    merged.queue_p99_s = queue.p(99.0);
    merged.ttft_sketch = ttft;
    merged.latency_sketch = lat;
    merged.tpot_sketch = tpot;
    merged.queue_sketch = queue;

    // Per-class breakdown: merge each class's sketches across the
    // replicas that saw it (keyed and emitted in class order, matching
    // the single-engine report).
    let mut classes: BTreeMap<u8, ClassStats> = BTreeMap::new();
    for r in per {
        for c in &r.per_class {
            classes
                .entry(c.class)
                .and_modify(|m| {
                    m.completed += c.completed;
                    m.ttft.merge(&c.ttft);
                    m.latency.merge(&c.latency);
                })
                .or_insert_with(|| c.clone());
        }
    }
    merged.per_class = classes
        .into_values()
        .map(|mut c| {
            c.ttft_p50_s = c.ttft.p(50.0);
            c.ttft_p99_s = c.ttft.p(99.0);
            c.latency_p50_s = c.latency.p(50.0);
            c.latency_p99_s = c.latency.p(99.0);
            c
        })
        .collect();

    merged.tokens_per_s = if merged.total_seconds > 0.0 {
        merged.gen_tokens as f64 / merged.total_seconds
    } else {
        0.0
    };
    let decode_seconds = platform.cycles_to_seconds(merged.decode_cycles);
    merged.decode_tokens_per_s = if decode_seconds > 0.0 {
        merged.decode_tokens as f64 / decode_seconds
    } else {
        0.0
    };
    // Occupancy: decode steps recovered per replica from its counters.
    let steps: u64 = per.iter().map(|r| r.decode_steps).sum();
    merged.avg_batch_occupancy = if steps > 0 {
        merged.decode_tokens as f64 / steps as f64
    } else {
        0.0
    };
    merged.decode_steps = steps;
    let hit_denom = merged.prefix_hit_tokens + merged.prefill_tokens;
    merged.prefix_hit_rate = if hit_denom > 0 {
        merged.prefix_hit_tokens as f64 / hit_denom as f64
    } else {
        0.0
    };
    // Rate-like fields from the merged raw counters — the exact formulas
    // the single-engine report applies to its own counters, so a fleet of
    // one can never drift and uneven fleets stay counter-true.
    let power = energy::power_report(&merged.work, fmt, platform);
    merged.fpu_utilization = power.fpu_utilization;
    merged.power_w = power.power_w;
    merged.budget_utilization = if merged.budget_iterations > 0 {
        merged.budget_tokens as f64
            / (merged.budget_iterations * merged.token_budget.max(1)) as f64
    } else {
        0.0
    };
    let lookups = merged.pricing_cache_hits + merged.pricing_cache_misses;
    merged.pricing_cache_hit_rate = if lookups > 0 {
        merged.pricing_cache_hits as f64 / lookups as f64
    } else {
        0.0
    };
    merged.hbm_gb = merged.work.hbm_bytes() as f64 / 1e9;
    merged.per_request = per_request;
    merged
}

/// Derive a replica-local RNG seed from a fleet base seed. Splitmix64
/// finalizer over `base ^ f(replica)`: deterministic, and avalanching,
/// so replica streams decorrelate even for adjacent indices (a plain
/// `seed ^ replica` would only flip low bits, which the workload LCG
/// forgives slowly). Used by fleet drivers that give every replica its
/// own arrival stream.
pub fn replica_seed(base: u64, replica: usize) -> u64 {
    let mut z = base ^ (replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Serve `workload` on `replicas` independent engine replicas, each the
/// continuous batcher configured by `opts` — including its shard plan, so
/// with `opts.plan.tp > 1` (or `pp > 1`) the fleet is N *sharded* replica
/// groups of `tp * pp` dies each, every group pricing its passes through
/// the rank-local layers and per-iteration collectives against its own
/// [`crate::parallel::ShardPlan::replica_kv_budget_bytes`] KV budget —
/// routing requests by `policy`. `replicas = 1` is bit-identical to
/// running the single batcher.
///
/// ```
/// use snitch_fm::arch::{FpFormat, PlatformConfig};
/// use snitch_fm::coordinator::{BatcherConfig, Workload};
/// use snitch_fm::model::ModelConfig;
/// use snitch_fm::parallel::{serve_replicated, RoutePolicy};
///
/// let cfg = ModelConfig::tiny();
/// let platform = PlatformConfig::with_dies(4);
/// let workload = Workload::uniform(8, 32, 8);
/// let fleet = serve_replicated(
///     &cfg,
///     &platform,
///     FpFormat::Fp32,
///     BatcherConfig::new(4, 0),
///     &workload,
///     4,
///     RoutePolicy::JoinShortestQueue,
/// );
/// assert_eq!(fleet.assigned, vec![2, 2, 2, 2]);
/// assert_eq!(fleet.merged.completed, 8);
/// ```
pub fn serve_replicated(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    replicas: usize,
    policy: RoutePolicy,
) -> RouterReport {
    serve_replicated_with_faults(
        cfg,
        platform,
        fmt,
        opts,
        workload,
        replicas,
        policy,
        &FaultPlan::off(),
    )
}

/// [`serve_replicated`] under an injected [`FaultPlan`]: the failure-aware
/// fleet. With `faults.is_off()` this IS `serve_replicated`, bit for bit.
///
/// With faults armed, every replica runs the batcher with its own
/// [`FaultPlan::for_replica`] view (stalls and permanent failures land on
/// their targeted replica; link degradations land on everyone, since the
/// die-to-die links are shared). The router then plays rounds until the
/// fleet settles:
///
/// 1. Run every replica whose workload changed (threaded, joined in
///    replica-index order, so the result is schedule-independent).
/// 2. Replicas that failed keep their *partial* report — completions up
///    to the failure stand — and surrender their salvage: queued and
///    in-flight requests, each carrying the KV bytes that survive for
///    re-export (see `ContinuousBatcher::run_salvage`).
/// 3. Each salvaged request re-arrives at
///    `max(old arrival, fail cycle + KV re-export p2p cycles)` — the
///    export priced over the link state *at the failure instant* — and is
///    re-routed across the survivors by the usual policy (affinity
///    pinning with its spill override), with every survivor's virtual
///    queue seeded at its current clock so the backlog spreads toward
///    the least-loaded dies. Requests whose pool died re-arrive without
///    KV and recompute prefill from scratch.
/// 4. Survivors that adopted work re-run on their augmented trace (the
///    engines are deterministic, so a re-run IS the adopted schedule); a
///    survivor whose own fail event lay beyond its old trace end may now
///    die, which loops back to step 2. The dead set grows monotonically,
///    so at most `replicas` rounds run.
///
/// When no survivor remains, unplaced salvage lands in
/// `merged.rejected`. Per-request `retries` / `recovery_cycles` are
/// patched onto the adopting replica's stats by id, and the fleet totals
/// count every re-route hop — including hops of requests that ultimately
/// died with the whole fleet.
#[allow(clippy::too_many_arguments)]
pub fn serve_replicated_with_faults(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    replicas: usize,
    policy: RoutePolicy,
    faults: &FaultPlan,
) -> RouterReport {
    serve_replicated_impl(cfg, platform, fmt, opts, workload, replicas, policy, faults, None).0
}

/// [`serve_replicated_with_faults`] with the cycle-level trace recorder
/// armed on every replica engine: returns the identical [`RouterReport`]
/// (the recorder is passive, see [`ContinuousBatcher::run_traced`])
/// together with a [`FleetTrace`] stitching the per-replica recorders —
/// one Chrome-trace process per replica, labelled `replica {i}`. Under a
/// fault plan each replica contributes the recorder of its *last* round,
/// i.e. the run whose schedule the router actually adopted.
#[allow(clippy::too_many_arguments)]
pub fn serve_replicated_traced(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    replicas: usize,
    policy: RoutePolicy,
    faults: &FaultPlan,
    settings: &TraceSettings,
) -> (RouterReport, FleetTrace) {
    let (report, recs) = serve_replicated_impl(
        cfg,
        platform,
        fmt,
        opts,
        workload,
        replicas,
        policy,
        faults,
        Some(settings),
    );
    let mut fleet = FleetTrace::new();
    for (i, rec) in recs.into_iter().enumerate() {
        fleet.push_replica(&format!("replica {i}"), rec);
    }
    (report, fleet)
}

/// Shared body of the replicated-serving entry points. `trace: None` is
/// the exact pre-tracing code path (every engine runs `run`/`run_salvage`
/// and the recorder vec comes back empty); `trace: Some` arms one
/// [`TraceRecorder`] per replica and returns them in replica-index order.
#[allow(clippy::too_many_arguments)]
fn serve_replicated_impl(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    replicas: usize,
    policy: RoutePolicy,
    faults: &FaultPlan,
    trace: Option<&TraceSettings>,
) -> (RouterReport, Vec<TraceRecorder>) {
    let replicas = replicas.max(1);
    // Unconditional: a release build silently modeling more dies than the
    // package has would report optimistic fleet numbers (the CLI path
    // additionally runs the full `ShardPlan::legality_error` check).
    assert!(
        opts.plan.tp.max(1) * opts.plan.pp.max(1) * replicas as u32
            <= platform.die.dies.max(1),
        "{} replica groups of tp={} x pp={} exceed the package's {} dies",
        replicas,
        opts.plan.tp.max(1),
        opts.plan.pp.max(1),
        platform.die.dies
    );
    if faults.is_off() {
        if replicas == 1 {
            let b = ContinuousBatcher::new(cfg, platform, fmt, opts);
            let (r, recs) = match trace {
                Some(ts) => {
                    let (r, rec) = b.run_traced(workload, ts);
                    (r, vec![rec])
                }
                None => (b.run(workload), Vec::new()),
            };
            return (
                RouterReport {
                    replicas: 1,
                    policy: policy.name(),
                    assigned: vec![workload.len()],
                    merged: r.clone(),
                    per_replica: vec![r],
                },
                recs,
            );
        }
        let model = ServiceModel::new(cfg, fmt, platform, workload, opts.max_batch);
        let shards = route_workload(workload, replicas, policy, &model);
        let assigned: Vec<usize> = shards.iter().map(|w| w.len()).collect();
        // One OS thread per replica engine (scoped: borrows the shards).
        // The engines are deterministic and fully independent — each owns
        // its KV pool, pricing memo, and prefix cache — so threading
        // changes only wall-clock time. Handles are joined in
        // replica-index order, and `merge_reports` folds in slice order,
        // so the merged report is byte-identical to the old sequential
        // map regardless of which thread finishes first.
        let per_rec: Vec<(ServeReport, Option<TraceRecorder>)> = std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .iter()
                .map(|w| {
                    s.spawn(move || {
                        let b = ContinuousBatcher::new(cfg, platform, fmt, opts);
                        match trace {
                            Some(ts) => {
                                let (r, rec) = b.run_traced(w, ts);
                                (r, Some(rec))
                            }
                            None => (b.run(w), None),
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("replica engine panicked"))
                .collect()
        });
        let (per, recs): (Vec<ServeReport>, Vec<Option<TraceRecorder>>) =
            per_rec.into_iter().unzip();
        let merged = merge_reports(&per, fmt, platform);
        return (
            RouterReport {
                replicas,
                policy: policy.name(),
                assigned,
                merged,
                per_replica: per,
            },
            recs.into_iter().flatten().collect(),
        );
    }

    // Fault path: the round loop described above. A 1-replica fleet runs
    // it too — with nobody to adopt its salvage, a failure rejects the
    // backlog instead of silently dropping it.
    let views: Vec<ReplicaFaults> = (0..replicas)
        .map(|r| faults.for_replica(r, replicas, platform.freq_ghz))
        .collect();
    let mut shard_w: Vec<Workload> = if replicas == 1 {
        vec![workload.clone()]
    } else {
        let model = ServiceModel::new(cfg, fmt, platform, workload, opts.max_batch);
        route_workload(workload, replicas, policy, &model)
    };
    let assigned: Vec<usize> = shard_w.iter().map(|w| w.len()).collect();

    let mut reports: Vec<Option<ServeReport>> = vec![None; replicas];
    // Each replica's recorder from its LAST round — overwritten on every
    // re-run, so what survives is the trace of the adopted schedule.
    let mut recs: Vec<Option<TraceRecorder>> = vec![None; replicas];
    let mut salvages: Vec<Vec<SalvagedRequest>> = vec![Vec::new(); replicas];
    let mut alive = vec![true; replicas];
    let mut needs_run = vec![true; replicas];
    // id -> (re-route hops, cycles from each hop's old arrival to its
    // re-arrival, summed over hops).
    let mut retry_map: HashMap<usize, (u32, u64)> = HashMap::new();
    // Salvage with no survivor left to adopt it.
    let mut lost: Vec<usize> = Vec::new();

    loop {
        let todo: Vec<usize> = (0..replicas).filter(|&r| alive[r] && needs_run[r]).collect();
        type RoundOut = (ServeReport, Vec<SalvagedRequest>, Option<TraceRecorder>);
        let outs: Vec<(usize, RoundOut)> = std::thread::scope(|s| {
            let handles: Vec<_> = todo
                .iter()
                .map(|&r| {
                    let w = &shard_w[r];
                    let view = views[r].clone();
                    let h = s.spawn(move || {
                        let b =
                            ContinuousBatcher::new(cfg, platform, fmt, opts).with_faults(view);
                        match trace {
                            Some(ts) => {
                                let (rep, sal, rec) = b.run_salvage_traced(w, ts);
                                (rep, sal, Some(rec))
                            }
                            None => {
                                let (rep, sal) = b.run_salvage(w);
                                (rep, sal, None)
                            }
                        }
                    });
                    (r, h)
                })
                .collect();
            handles
                .into_iter()
                .map(|(r, h)| (r, h.join().expect("replica engine panicked")))
                .collect()
        });
        for (r, (rep, sal, rec)) in outs {
            needs_run[r] = false;
            reports[r] = Some(rep);
            salvages[r] = sal;
            recs[r] = rec;
        }
        let dead_now: Vec<usize> = (0..replicas)
            .filter(|&r| {
                alive[r] && reports[r].as_ref().is_some_and(|p| p.replica_failures > 0)
            })
            .collect();
        if dead_now.is_empty() {
            break;
        }
        for &d in &dead_now {
            alive[d] = false;
        }
        let survivors: Vec<usize> = (0..replicas).filter(|&r| alive[r]).collect();
        for &d in &dead_now {
            let sal = std::mem::take(&mut salvages[d]);
            if sal.is_empty() {
                continue;
            }
            // Re-arrive every salvaged request: the failure instant plus
            // the KV re-export over the link as degraded at that instant
            // (requests without surviving KV export nothing and recompute
            // prefill on the adopter).
            let mut adopt = Workload::default();
            for s in sal {
                let old_cycle = platform.ns_to_cycles(s.req.arrival_ns as f64);
                let export_cycles = if s.export_bytes > 0 {
                    let frac = faults.link_fraction_at(platform.cycles_to_seconds(s.fail_cycle));
                    if frac < 1.0 {
                        p2p_cost(s.export_bytes, &degrade_link(platform, frac)).cycles
                    } else {
                        p2p_cost(s.export_bytes, platform).cycles
                    }
                } else {
                    0
                };
                let re_arrival = (s.fail_cycle + export_cycles).max(old_cycle);
                let e = retry_map.entry(s.req.id).or_insert((0, 0));
                e.0 += 1;
                e.1 += re_arrival - old_cycle;
                let mut req = s.req;
                req.arrival_ns = (re_arrival as f64 / platform.freq_ghz).round() as u64;
                adopt.requests.push(req);
            }
            if survivors.is_empty() {
                lost.extend(adopt.requests.iter().map(|r| r.id));
                continue;
            }
            let model = ServiceModel::new(cfg, fmt, platform, workload, opts.max_batch);
            let penalty: Vec<f64> = survivors
                .iter()
                .map(|&r| reports[r].as_ref().map_or(0.0, |p| p.total_cycles as f64))
                .collect();
            let routed =
                route_workload_penalized(&adopt, survivors.len(), policy, &model, &penalty);
            for (k, w) in routed.into_iter().enumerate() {
                if w.requests.is_empty() {
                    continue;
                }
                shard_w[survivors[k]].requests.extend(w.requests);
                needs_run[survivors[k]] = true;
            }
        }
        if survivors.is_empty() {
            break;
        }
    }

    let mut per: Vec<ServeReport> = reports
        .into_iter()
        .map(|r| r.expect("every replica ran at least once"))
        .collect();
    // Patch retry/recovery detail onto the report that finally served
    // each re-routed request (per-request mode only; the report-level
    // sums exist either way).
    for rep in per.iter_mut() {
        let (mut rt, mut rc) = (0u64, 0u64);
        for s in rep.per_request.iter_mut() {
            if let Some(&(hops, cycles)) = retry_map.get(&s.id) {
                s.retries = hops;
                s.recovery_cycles = cycles;
                rt += hops as u64;
                rc += cycles;
            }
        }
        rep.retries = rt;
        rep.recovery_cycles = rc;
    }
    let mut merged = merge_reports(&per, fmt, platform);
    // Salvaged re-arrivals were offered to two engines; the fleet saw
    // each id once.
    merged.requests = workload.len();
    if !lost.is_empty() {
        merged.rejected.extend(lost);
        merged.rejected.sort_unstable();
    }
    // Fleet retry totals count every hop, whether or not the request
    // ultimately completed (the per-replica sums only see completions).
    merged.retries = retry_map.values().map(|&(hops, _)| hops as u64).sum();
    merged.recovery_cycles = retry_map.values().map(|&(_, cycles)| cycles).sum();
    (
        RouterReport {
            replicas,
            policy: policy.name(),
            assigned,
            merged,
            per_replica: per,
        },
        recs.into_iter().flatten().collect(),
    )
}

/// The two-stage fleet outcome of [`serve_disaggregated`]: dedicated
/// prefill dies hand each finished prompt's KV pages to dedicated decode
/// dies over the die-to-die links.
///
/// End-to-end views (`ttft_*`, `latency_*`) are measured against each
/// request's ORIGINAL arrival — they include prefill queueing, the
/// prefill passes, and the migration delay — while `tpot_*` is the decode
/// pace, which the handoff shifts but never stretches.
#[derive(Debug, Clone, PartialEq)]
pub struct DisaggReport {
    /// Replica engines (or sharded replica groups) dedicated to prefill.
    pub prefill_replicas: usize,
    /// Replica engines (or sharded replica groups) dedicated to decode.
    pub decode_replicas: usize,
    /// Routing policy name, applied independently at each stage.
    pub policy: &'static str,
    /// Merged prefill-fleet view: the trace truncated at prefill-complete.
    pub prefill: ServeReport,
    /// Merged decode-fleet view: decode-only requests whose prompt KV
    /// arrives imported (its `kv_imports` equals `migrations`).
    pub decode: ServeReport,
    /// KV handoffs performed — one per generating request that finished
    /// prefill (prefill-only requests retire on the prefill die).
    pub migrations: u64,
    /// KV bytes moved over the die-to-die links by those handoffs.
    pub migrated_kv_bytes: u64,
    /// Link cycles spent migrating. Overlapped with decode-side compute:
    /// a migration delays only its own request's decode arrival, never
    /// the decode die's current pass.
    pub migration_cycles: u64,
    /// Requests offered to the fleet.
    pub requests: usize,
    /// Requests fully served across both stages.
    pub completed: usize,
    /// Ids rejected at either stage (KV footprint exceeds the stage's
    /// pool), ascending.
    pub rejected: Vec<usize>,
    /// Mean seconds from original arrival to the first decoded token.
    pub ttft_mean_s: f64,
    /// p50 of end-to-end TTFT.
    pub ttft_p50_s: f64,
    /// p99 of end-to-end TTFT.
    pub ttft_p99_s: f64,
    /// Mean decode pace (seconds per generated token after the first).
    pub tpot_mean_s: f64,
    /// p50 of the decode pace.
    pub tpot_p50_s: f64,
    /// p99 of the decode pace — the headline the split fleet buys.
    pub tpot_p99_s: f64,
    /// Mean seconds from original arrival to retirement.
    pub latency_mean_s: f64,
    /// p50 of end-to-end latency.
    pub latency_p50_s: f64,
    /// p99 of end-to-end latency.
    pub latency_p99_s: f64,
    /// Fleet makespan in seconds (the later of the two stages' clocks).
    pub total_seconds: f64,
    /// Generated tokens per second over the makespan.
    pub tokens_per_s: f64,
    /// Extra migration attempts forced by injected KV corruption (each
    /// re-bills the link and backs off exponentially before retrying).
    pub migration_retries: u64,
    /// Migrations that exhausted the retry cap: the request re-arrives
    /// without imported KV and the decode die recomputes its prefill.
    pub recompute_fallbacks: u64,
    /// Decode-fleet capacity fraction lost to injected faults (replica
    /// faults target the decode fleet; prefill dies run fault-free).
    pub degraded_capacity_fraction: f64,
    /// Warnings surfaced by either stage fleet.
    pub warnings: Vec<String>,
}

/// Serve `workload` on a disaggregated fleet: `prefill_replicas` engines
/// run every request truncated at prefill-complete, each finished
/// prompt's KV pages then migrate to one of `decode_replicas` engines
/// over the die-to-die links (priced by the same
/// [`p2p_cost`][crate::parallel::collectives::p2p_cost] machinery the
/// collectives use), where the request resumes decode-only via the
/// imported-KV admission path (`Request::kv_imported`).
///
/// The migration is overlappable: its cycles delay the migrating
/// request's decode-side arrival but never stall the decode die, which
/// keeps batching whatever is already resident. Per-request detail is
/// forced on internally (the handoff needs per-request finish times);
/// the emitted reports honor `opts.per_request`.
///
/// Both stage fleets run under `opts.plan`, so
/// `tp * pp * (prefill_replicas + decode_replicas)` dies must fit the
/// package (asserted, mirroring [`serve_replicated`]).
#[allow(clippy::too_many_arguments)]
pub fn serve_disaggregated(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    prefill_replicas: usize,
    decode_replicas: usize,
    policy: RoutePolicy,
) -> DisaggReport {
    serve_disaggregated_with_faults(
        cfg,
        platform,
        fmt,
        opts,
        workload,
        prefill_replicas,
        decode_replicas,
        policy,
        &FaultPlan::off(),
    )
}

/// Migration attempts (first try + retries) before a corrupted handoff
/// gives up and falls back to decode-side prefill recompute.
const MAX_MIGRATION_ATTEMPTS: u32 = 3;

/// [`serve_disaggregated`] under an injected [`FaultPlan`]. Bit-identical
/// to the plain entry when `faults.is_off()`.
///
/// Fault semantics at the split fleet:
///
/// * **Replica faults target the decode fleet** (stalls, failures, and
///   the salvage/re-route machinery of
///   [`serve_replicated_with_faults`]); the prefill dies run fault-free.
///   Decode holds the long-lived KV state, so it is where failure is
///   interesting — a failed prefill die would merely re-run stateless
///   prompt passes.
/// * **Link faults degrade the migration path**: each handoff is priced
///   over the link as degraded at its prefill-finish instant.
/// * **KV corruption** (`corrupt:<p>`) hits individual migrations: a
///   corrupted attempt still moved its bytes (billed once per attempt),
///   then backs off exponentially — `static link overhead x 2^k` — and
///   retries, up to [`MAX_MIGRATION_ATTEMPTS`] attempts total. Past the
///   cap the request re-arrives WITHOUT imported KV and the decode die
///   recomputes its prefill from the prompt (`recompute_fallbacks`).
#[allow(clippy::too_many_arguments)]
pub fn serve_disaggregated_with_faults(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    prefill_replicas: usize,
    decode_replicas: usize,
    policy: RoutePolicy,
    faults: &FaultPlan,
) -> DisaggReport {
    serve_disaggregated_impl(
        cfg,
        platform,
        fmt,
        opts,
        workload,
        prefill_replicas,
        decode_replicas,
        policy,
        faults,
        None,
    )
    .0
}

/// [`serve_disaggregated_with_faults`] with tracing armed across the whole
/// split fleet: returns the identical [`DisaggReport`] plus a
/// [`FleetTrace`] whose processes are the prefill engines (`prefill {i}`),
/// the decode engines (`decode {i}`), and a synthetic `kv-migration`
/// process carrying one span per handoff (bytes and attempt count
/// annotated, corruption retries included in the span's duration).
#[allow(clippy::too_many_arguments)]
pub fn serve_disaggregated_traced(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    prefill_replicas: usize,
    decode_replicas: usize,
    policy: RoutePolicy,
    faults: &FaultPlan,
    settings: &TraceSettings,
) -> (DisaggReport, FleetTrace) {
    let (report, fleet) = serve_disaggregated_impl(
        cfg,
        platform,
        fmt,
        opts,
        workload,
        prefill_replicas,
        decode_replicas,
        policy,
        faults,
        Some(settings),
    );
    (report, fleet.expect("tracing was armed"))
}

/// Shared body of the disaggregated entry points. `trace: None` is the
/// exact pre-tracing code path; `trace: Some` arms recorders on both
/// stage fleets and collects one [`MigrationSpan`] per handoff.
#[allow(clippy::too_many_arguments)]
fn serve_disaggregated_impl(
    cfg: &ModelConfig,
    platform: &PlatformConfig,
    fmt: FpFormat,
    opts: BatcherConfig,
    workload: &Workload,
    prefill_replicas: usize,
    decode_replicas: usize,
    policy: RoutePolicy,
    faults: &FaultPlan,
    trace: Option<&TraceSettings>,
) -> (DisaggReport, Option<FleetTrace>) {
    let p_n = prefill_replicas.max(1);
    let d_n = decode_replicas.max(1);
    assert!(
        opts.plan.tp.max(1) * opts.plan.pp.max(1) * (p_n + d_n) as u32
            <= platform.die.dies.max(1),
        "prefill {} + decode {} replica groups of tp={} x pp={} exceed the package's {} dies",
        p_n,
        d_n,
        opts.plan.tp.max(1),
        opts.plan.pp.max(1),
        platform.die.dies
    );

    // Stage 1 — prefill fleet: the same trace with `gen_tokens = 0`, so
    // every request retires the moment its prompt is materialized.
    let mut stage_opts = opts;
    stage_opts.per_request = true;
    let mut prefill_w = workload.clone();
    for r in &mut prefill_w.requests {
        r.gen_tokens = 0;
    }
    let (pre, pre_recs) = serve_replicated_impl(
        cfg,
        platform,
        fmt,
        stage_opts,
        &prefill_w,
        p_n,
        policy,
        &FaultPlan::off(),
        trace,
    );

    // Stage 2 — the handoff: price each finished prompt's pages across
    // the die-to-die link and re-arrive the request, decode-only with
    // imported KV, at `prefill finish + migration`. Whole-model geometry:
    // with a sharded plan the per-rank pages are smaller but `tp * pp`
    // ranks move them, so the link sees the whole-model footprint either
    // way.
    let by_id: HashMap<usize, &Request> =
        workload.requests.iter().map(|r| (r.id, r)).collect();
    // Migration manifests move pages at the KV *storage* format: with a
    // narrow `--kv-format` the handoff's wire bytes shrink by the same
    // ratio as the pools (the engines on both sides use this geometry).
    let geom = KvGeometry::new(cfg, opts.policy_for(fmt).kv, stage_opts.page_tokens);
    // Backoff unit for corrupted-migration retries: the link's static
    // overhead (DMA setup + hop latency), the natural "re-arm the
    // transfer" cost.
    let backoff_unit = platform
        .ns_to_cycles(platform.interconnect.dma_setup_ns + platform.die.latency_ns)
        .max(1);
    let mut migrations = 0u64;
    let mut migrated_kv_bytes = 0u64;
    let mut migration_cycles = 0u64;
    let mut migration_retries = 0u64;
    let mut recompute_fallbacks = 0u64;
    let mut migration_spans: Vec<MigrationSpan> = Vec::new();
    let mut decode_w = Workload::default();
    for s in &pre.merged.per_request {
        let orig = by_id[&s.id];
        if orig.gen_tokens == 0 {
            continue; // prefill-only: served entirely by the prefill fleet
        }
        let bytes = geom.pages_for(orig.prompt_len) * geom.page_bytes();
        let finish_s = s.arrival_s + s.latency_s;
        // Price the transfer over the link as degraded at the handoff
        // instant (1.0 borrows the nominal platform: bit-identical).
        let degraded;
        let link_platform = {
            let frac = faults.link_fraction_at(finish_s);
            if frac < 1.0 {
                degraded = degrade_link(platform, frac);
                &degraded
            } else {
                platform
            }
        };
        let link = p2p_cost(bytes, link_platform);
        migrations += 1;
        // Corruption retry loop: every attempt moves (and bills) the
        // bytes once; a corrupted attempt backs off exponentially before
        // the next, and the cap downgrades the handoff to a decode-side
        // prefill recompute.
        let mut delay_cycles = 0u64;
        let mut attempt = 0u32;
        let imported = loop {
            migrated_kv_bytes += bytes;
            migration_cycles += link.cycles;
            delay_cycles += link.cycles;
            if !faults.migration_corrupted(s.id, attempt) {
                break true;
            }
            attempt += 1;
            if attempt >= MAX_MIGRATION_ATTEMPTS {
                recompute_fallbacks += 1;
                break false;
            }
            migration_retries += 1;
            delay_cycles += backoff_unit << (attempt - 1);
        };
        if trace.is_some() {
            // Attempts actually made: a clean break leaves `attempt` at
            // the index of the successful try; the give-up path has
            // already counted every try in `attempt`.
            let attempts = if imported { attempt + 1 } else { attempt };
            let start = platform.ns_to_cycles(finish_s * 1e9);
            migration_spans.push(MigrationSpan {
                id: s.id,
                start,
                end: start + delay_cycles,
                bytes: bytes * attempts as u64,
                attempts,
            });
        }
        let handoff_s = finish_s + platform.cycles_to_seconds(delay_cycles);
        let mut dr = if imported {
            orig.clone().with_imported_kv()
        } else {
            orig.clone()
        };
        dr.arrival_ns = (handoff_s * 1e9).round() as u64;
        decode_w.requests.push(dr);
    }

    // Stage 3 — decode fleet: admission maps the imported pages without a
    // prefill pass, so these engines run pure AR decode (recompute
    // fallbacks prefill their prompt here first). Injected replica faults
    // land on this fleet.
    let (dec, dec_recs) = serve_replicated_impl(
        cfg, platform, fmt, stage_opts, &decode_w, d_n, policy, faults, trace,
    );

    // Combined end-to-end views against each request's original arrival.
    // Decode-stage stats are relative to the migration-delayed arrival,
    // so `arrival_s + x_s - original_arrival_s` re-bases them.
    let mut ttft = StreamSketch::new();
    let mut lat = StreamSketch::new();
    for s in &dec.merged.per_request {
        let orig_arrival_s = by_id[&s.id].arrival_ns as f64 / 1e9;
        if s.gen_tokens > 0 {
            ttft.push(s.arrival_s + s.ttft_s - orig_arrival_s);
        }
        lat.push(s.arrival_s + s.latency_s - orig_arrival_s);
    }
    let mut prefill_only_done = 0usize;
    for s in &pre.merged.per_request {
        if by_id[&s.id].gen_tokens == 0 {
            prefill_only_done += 1;
            lat.push(s.latency_s);
        }
    }
    let mut rejected: Vec<usize> = pre
        .merged
        .rejected
        .iter()
        .chain(dec.merged.rejected.iter())
        .copied()
        .collect();
    rejected.sort_unstable();
    let completed = dec.merged.completed + prefill_only_done;
    let total_seconds = pre.merged.total_seconds.max(dec.merged.total_seconds);
    let tokens_per_s = if total_seconds > 0.0 {
        dec.merged.gen_tokens as f64 / total_seconds
    } else {
        0.0
    };

    let mut prefill = pre.merged;
    let mut decode = dec.merged;
    if !opts.per_request {
        prefill.per_request = Vec::new();
        decode.per_request = Vec::new();
    }
    let degraded_capacity_fraction = decode.degraded_capacity_fraction;
    let mut warnings = prefill.warnings.clone();
    warnings.extend(decode.warnings.iter().cloned());
    let fleet = trace.map(|_| {
        let mut fleet = FleetTrace::new();
        for (i, rec) in pre_recs.into_iter().enumerate() {
            fleet.push_replica(&format!("prefill {i}"), rec);
        }
        for (i, rec) in dec_recs.into_iter().enumerate() {
            fleet.push_replica(&format!("decode {i}"), rec);
        }
        for m in migration_spans {
            fleet.push_migration(m);
        }
        fleet
    });
    let report = DisaggReport {
        migration_retries,
        recompute_fallbacks,
        degraded_capacity_fraction,
        warnings,
        prefill_replicas: p_n,
        decode_replicas: d_n,
        policy: policy.name(),
        migrations,
        migrated_kv_bytes,
        migration_cycles,
        requests: workload.len(),
        completed,
        rejected,
        ttft_mean_s: ttft.mean(),
        ttft_p50_s: ttft.p(50.0),
        ttft_p99_s: ttft.p(99.0),
        tpot_mean_s: decode.tpot_mean_s,
        tpot_p50_s: decode.tpot_p50_s,
        tpot_p99_s: decode.tpot_p99_s,
        latency_mean_s: lat.mean(),
        latency_p50_s: lat.p(50.0),
        latency_p99_s: lat.p(99.0),
        total_seconds,
        tokens_per_s,
        prefill,
        decode,
    };
    (report, fleet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::workload::Request;

    fn service() -> ServiceModel {
        ServiceModel {
            prefill_per_token: 1.0,
            decode_per_token: 10.0,
            freq_ghz: 1.0,
        }
    }

    #[test]
    fn route_policy_parse() {
        assert_eq!(RoutePolicy::parse("jsq"), Some(RoutePolicy::JoinShortestQueue));
        assert_eq!(RoutePolicy::parse("affinity"), Some(RoutePolicy::PrefixAffinity));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }

    #[test]
    fn jsq_balances_identical_requests() {
        let w = Workload::uniform(8, 64, 16);
        let shards = route_workload(&w, 4, RoutePolicy::JoinShortestQueue, &service());
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes, vec![2, 2, 2, 2]);
        // Every request routed exactly once, ids preserved.
        let mut ids: Vec<usize> =
            shards.iter().flat_map(|s| s.requests.iter().map(|r| r.id)).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn affinity_keeps_template_groups_together() {
        // 4 groups of 4 requests behind shared templates: affinity pins
        // each group to one replica, so no group is split.
        let w = Workload::uniform(16, 32, 8).with_shared_prefix(64, 4);
        let shards = route_workload(&w, 4, RoutePolicy::PrefixAffinity, &service());
        for shard in &shards {
            let mut seeds: Vec<u64> = shard.requests.iter().map(|r| r.prefix_seed).collect();
            seeds.sort_unstable();
            seeds.dedup();
            assert!(seeds.len() <= 1, "one template home per replica here: {seeds:?}");
        }
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 16);
    }

    #[test]
    fn affinity_spills_when_home_overloads() {
        // One giant template group: the spill guard must eventually move
        // requests off the home replica instead of queueing forever.
        let mut w = Workload::uniform(32, 32, 8).with_shared_prefix(64, 32);
        for r in &mut w.requests {
            r.arrival_ns = 0; // all at once: backlog builds immediately
        }
        let shards = route_workload(&w, 4, RoutePolicy::PrefixAffinity, &service());
        let home_size = shards.iter().map(|s| s.len()).max().unwrap();
        assert!(home_size < 32, "spill guard must cap the home replica");
    }

    #[test]
    fn unshared_requests_fall_back_to_jsq_under_affinity() {
        let w = Workload::uniform(8, 64, 16); // prefix_len = 0 everywhere
        let a = route_workload(&w, 4, RoutePolicy::PrefixAffinity, &service());
        let b = route_workload(&w, 4, RoutePolicy::JoinShortestQueue, &service());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.requests, y.requests);
        }
    }

    #[test]
    fn replica_seed_deterministic_and_distinct() {
        let seeds: Vec<u64> = (0..64).map(|r| replica_seed(42, r)).collect();
        let again: Vec<u64> = (0..64).map(|r| replica_seed(42, r)).collect();
        assert_eq!(seeds, again);
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "64 replicas -> 64 distinct seeds");
        assert_ne!(replica_seed(42, 1), replica_seed(43, 1));
        // Adjacent replicas differ in high bits too (avalanche, not xor).
        let d = replica_seed(7, 0) ^ replica_seed(7, 1);
        assert!(d.count_ones() > 8, "adjacent seeds too correlated: {d:#x}");
    }

    #[test]
    fn threaded_fleet_is_deterministic_across_runs() {
        // The replica engines run on threads; the merged fleet view must
        // depend only on replica *index*, never on completion order.
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(11, 32, (8, 48), (2, 10)).with_poisson_arrivals(5, 800.0);
        let opts = BatcherConfig::new(4, 0);
        let policy = RoutePolicy::JoinShortestQueue;
        let a = serve_replicated(&cfg, &p, FpFormat::Fp32, opts, &w, 4, policy);
        let b = serve_replicated(&cfg, &p, FpFormat::Fp32, opts, &w, 4, policy);
        assert_eq!(a.assigned, b.assigned);
        assert_eq!(a.per_replica, b.per_replica);
        assert_eq!(a.merged, b.merged);
    }

    #[test]
    fn merged_latency_view_matches_union_recompute_in_exact_mode() {
        // Below the sketch spill limit, folding per-replica sketches must
        // reproduce the old recompute-over-the-union bit-for-bit.
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(13, 24, (8, 40), (2, 8)).with_poisson_arrivals(3, 600.0);
        let opts = BatcherConfig::new(4, 0);
        let fleet =
            serve_replicated(&cfg, &p, FpFormat::Fp32, opts, &w, 4, RoutePolicy::JoinShortestQueue);
        let (ttft, lat, tpot, queue, per_class) =
            crate::coordinator::batcher::latency_aggregates(&fleet.merged.per_request);
        assert!(fleet.merged.ttft_sketch.is_exact());
        assert_eq!(fleet.merged.ttft_mean_s, ttft.mean());
        assert_eq!(fleet.merged.ttft_p50_s, ttft.p(50.0));
        assert_eq!(fleet.merged.ttft_p99_s, ttft.p(99.0));
        assert_eq!(fleet.merged.latency_mean_s, lat.mean());
        assert_eq!(fleet.merged.latency_p50_s, lat.p(50.0));
        assert_eq!(fleet.merged.latency_p99_s, lat.p(99.0));
        assert_eq!(fleet.merged.tpot_mean_s, tpot.mean());
        assert_eq!(fleet.merged.tpot_p50_s, tpot.p(50.0));
        assert_eq!(fleet.merged.tpot_p99_s, tpot.p(99.0));
        assert_eq!(fleet.merged.queue_mean_s, queue.mean());
        assert_eq!(fleet.merged.queue_p99_s, queue.p(99.0));
        let merged_classes: Vec<(u8, usize, f64, f64)> = fleet
            .merged
            .per_class
            .iter()
            .map(|c| (c.class, c.completed, c.ttft_p99_s, c.latency_p99_s))
            .collect();
        let union_classes: Vec<(u8, usize, f64, f64)> = per_class
            .iter()
            .map(|c| (c.class, c.completed, c.ttft_p99_s, c.latency_p99_s))
            .collect();
        assert_eq!(merged_classes, union_classes);
    }

    #[test]
    fn disagg_serves_everything_and_prices_each_handoff() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(7, 9, (8, 48), (2, 10)).with_poisson_arrivals(7, 700.0);
        let opts = BatcherConfig::new(4, 0);
        let r = serve_disaggregated(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            1,
            1,
            RoutePolicy::JoinShortestQueue,
        );
        assert_eq!(r.requests, 9);
        assert_eq!(r.completed, 9);
        assert!(r.rejected.is_empty());
        // Every generating request migrated exactly once, and the decode
        // fleet admitted every migrated prompt through the import path.
        assert_eq!(r.migrations, 9);
        assert_eq!(r.decode.kv_imports, 9);
        assert_eq!(r.decode.imported_kv_tokens, w.total_prompt_tokens());
        // Imported prompts skip prefill entirely on the decode dies.
        assert_eq!(r.decode.prefill_tokens, 0);
        assert_eq!(r.prefill.gen_tokens, 0);
        assert_eq!(r.decode.gen_tokens, w.total_gen_tokens());
        // The handoff moved exactly the page-rounded prompt KV, at a
        // nonzero link price.
        let geom = KvGeometry::new(&cfg, FpFormat::Fp32, opts.page_tokens);
        let bytes: u64 = w
            .requests
            .iter()
            .map(|q| geom.pages_for(q.prompt_len) * geom.page_bytes())
            .sum();
        assert_eq!(r.migrated_kv_bytes, bytes);
        assert!(r.migration_cycles > 0);
        // End-to-end TTFT covers prefill + migration, so it must exceed
        // the decode stage's own (re-based) first-token wait.
        assert!(r.ttft_mean_s > r.decode.ttft_mean_s);
        assert!(r.latency_p99_s >= r.ttft_p50_s);
        assert!(r.tpot_p99_s > 0.0);
    }

    #[test]
    fn disagg_is_deterministic_across_runs() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(8);
        let w = Workload::synthetic(13, 21, (8, 64), (2, 12)).with_poisson_arrivals(3, 900.0);
        let opts = BatcherConfig::new(4, 0);
        let a = serve_disaggregated(
            &cfg, &p, FpFormat::Fp32, opts, &w, 2, 2, RoutePolicy::JoinShortestQueue,
        );
        let b = serve_disaggregated(
            &cfg, &p, FpFormat::Fp32, opts, &w, 2, 2, RoutePolicy::JoinShortestQueue,
        );
        assert_eq!(a, b);
    }

    #[test]
    fn disagg_prefill_only_requests_never_migrate() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let mut w = Workload::uniform(4, 32, 8);
        w.requests.push(Request::new(4, 48, 0)); // embedding-style: no decode
        let opts = BatcherConfig::new(4, 0);
        let r = serve_disaggregated(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            1,
            1,
            RoutePolicy::JoinShortestQueue,
        );
        assert_eq!(r.migrations, 4);
        assert_eq!(r.completed, 5, "the prefill-only request retires on stage 1");
        assert_eq!(r.decode.requests, 4);
    }

    #[test]
    fn disagg_honors_per_request_opt_out() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::uniform(6, 24, 6);
        let mut opts = BatcherConfig::new(4, 0);
        opts.per_request = false;
        let r = serve_disaggregated(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            1,
            1,
            RoutePolicy::JoinShortestQueue,
        );
        // The stages run with detail internally (the handoff needs finish
        // times) but the emitted reports respect the opt-out; aggregates
        // survive it.
        assert!(r.prefill.per_request.is_empty());
        assert!(r.decode.per_request.is_empty());
        assert_eq!(r.completed, 6);
        assert!(r.tpot_p99_s > 0.0);
    }

    #[test]
    fn later_arrivals_see_drained_backlogs() {
        // Two requests long apart: the second must land on the same
        // replica-0 (its backlog has drained), not ping-pong.
        let mut w = Workload::default();
        w.requests.push(Request::new(0, 16, 1));
        w.requests.push(Request::new(1, 16, 1).with_arrival_ns(1 << 30));
        let shards = route_workload(&w, 2, RoutePolicy::JoinShortestQueue, &service());
        assert_eq!(shards[0].len(), 2);
        assert_eq!(shards[1].len(), 0);
    }

    #[test]
    fn armed_but_physically_nominal_plan_matches_plain_fleet() {
        // A 0-cycle stall arms the whole fault round-loop machinery
        // (run_salvage, penalized re-routing scaffolding, report
        // patch-up) while injecting nothing physical: the fleet view
        // must be byte-identical to the plain path.
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(11, 32, (8, 48), (2, 10)).with_poisson_arrivals(5, 800.0);
        let opts = BatcherConfig::new(4, 0);
        // The CLI grammar rejects 0-cycle stalls (surely a typo there),
        // so build the nominal plan directly.
        let plan = FaultPlan {
            seed: 7,
            events: vec![crate::coordinator::faults::FaultEvent {
                at_s: 0.0,
                replica: Some(0),
                kind: crate::coordinator::faults::FaultKind::ReplicaStall { cycles: 0 },
            }],
            corrupt_prob: 0.0,
        };
        assert!(!plan.is_off());
        let a = serve_replicated(
            &cfg, &p, FpFormat::Fp32, opts, &w, 4, RoutePolicy::JoinShortestQueue,
        );
        let b = serve_replicated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 4, RoutePolicy::JoinShortestQueue, &plan,
        );
        assert_eq!(a.assigned, b.assigned);
        assert_eq!(a.per_replica, b.per_replica);
        assert_eq!(a.merged, b.merged);
    }

    #[test]
    fn failed_replica_backlog_lands_on_survivors() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let w = Workload::synthetic(17, 12, (8, 40), (2, 8)).with_poisson_arrivals(9, 700.0);
        let opts = BatcherConfig::new(4, 0);
        // Replica 0 dies at t = 0: everything it was assigned re-routes
        // to replica 1 before any of it completes.
        let plan = FaultPlan::parse("fail@0:r0", 1).unwrap();
        let fleet = serve_replicated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 2, RoutePolicy::JoinShortestQueue, &plan,
        );
        assert_eq!(fleet.merged.replica_failures, 1);
        assert_eq!(fleet.merged.requests, 12);
        assert_eq!(fleet.merged.completed, 12, "the survivor adopts the whole backlog");
        assert!(fleet.merged.rejected.is_empty());
        assert_eq!(fleet.per_replica[0].completed, 0);
        assert_eq!(fleet.per_replica[1].completed, 12);
        // Every request replica 0 held was salvaged and hopped once.
        let assigned0 = fleet.assigned[0] as u64;
        assert!(assigned0 > 0, "routing must have given replica 0 work");
        assert_eq!(fleet.merged.salvaged_requests, assigned0);
        assert_eq!(fleet.merged.retries, assigned0);
        let hopped = fleet
            .merged
            .per_request
            .iter()
            .filter(|s| s.retries == 1)
            .count() as u64;
        assert_eq!(hopped, assigned0);
        // No request served twice: ids in the merged detail are unique
        // and cover the trace.
        let mut ids: Vec<usize> = fleet.merged.per_request.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
        // A dead replica counts as lost capacity.
        assert!(fleet.merged.degraded_capacity_fraction > 0.0);
        assert!(fleet.merged.degraded_capacity_fraction <= 1.0);
        // Deterministic replay, fault seed and all.
        let again = serve_replicated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 2, RoutePolicy::JoinShortestQueue, &plan,
        );
        assert_eq!(fleet.merged, again.merged);
        assert_eq!(fleet.per_replica, again.per_replica);
    }

    #[test]
    fn fleet_with_no_survivors_rejects_the_backlog() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(1);
        let w = Workload::uniform(4, 32, 8);
        let opts = BatcherConfig::new(4, 0);
        let plan = FaultPlan::parse("fail@0:r0", 1).unwrap();
        let fleet = serve_replicated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 1, RoutePolicy::JoinShortestQueue, &plan,
        );
        assert_eq!(fleet.merged.completed, 0);
        assert_eq!(fleet.merged.rejected, vec![0, 1, 2, 3]);
        assert_eq!(fleet.merged.replica_failures, 1);
        assert_eq!(fleet.merged.requests, 4);
    }

    #[test]
    fn stalled_replica_shows_up_as_degraded_capacity() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let w = Workload::synthetic(23, 10, (8, 32), (2, 6)).with_poisson_arrivals(4, 600.0);
        let opts = BatcherConfig::new(4, 0);
        let plan = FaultPlan::parse("stall@0:5000000:r1", 3).unwrap();
        let fleet = serve_replicated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 2, RoutePolicy::JoinShortestQueue, &plan,
        );
        assert_eq!(fleet.merged.completed, 10, "stalls delay, they never drop");
        assert_eq!(fleet.merged.stall_cycles, 5_000_000);
        assert_eq!(fleet.merged.replica_failures, 0);
        assert!(fleet.merged.degraded_capacity_fraction > 0.0);
        assert!(fleet.merged.degraded_capacity_fraction < 1.0);
    }

    #[test]
    fn degraded_link_inflates_a_sharded_fleet_tp_tax() {
        // tp = 2 replica group: the injected link fault must grow the
        // per-pass collective tax without changing what completes.
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let w = Workload::uniform(6, 32, 8);
        let mut opts = BatcherConfig::new(4, 0);
        opts.plan = crate::parallel::ShardPlan { tp: 2, pp: 1, replicas: 1 };
        let nominal =
            serve_replicated(&cfg, &p, FpFormat::Fp32, opts, &w, 1, RoutePolicy::JoinShortestQueue);
        let plan = FaultPlan::parse("link@0:0.25", 5).unwrap();
        let faulted = serve_replicated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 1, RoutePolicy::JoinShortestQueue, &plan,
        );
        assert_eq!(faulted.merged.link_faults, 1);
        assert_eq!(faulted.merged.completed, nominal.merged.completed);
        assert_eq!(faulted.merged.gen_tokens, nominal.merged.gen_tokens);
        assert!(
            faulted.merged.collective_cycles > nominal.merged.collective_cycles,
            "quartered link bandwidth must inflate the collective tax: {} vs {}",
            faulted.merged.collective_cycles,
            nominal.merged.collective_cycles
        );
        assert!(faulted.merged.total_cycles > nominal.merged.total_cycles);
    }

    #[test]
    fn disagg_corruption_retries_then_falls_back_to_recompute() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(7, 9, (8, 48), (2, 10)).with_poisson_arrivals(7, 700.0);
        let opts = BatcherConfig::new(4, 0);
        let clean = serve_disaggregated(
            &cfg, &p, FpFormat::Fp32, opts, &w, 1, 1, RoutePolicy::JoinShortestQueue,
        );
        // corrupt:1 poisons every attempt: each migration burns the full
        // retry budget, re-billing the link per attempt, then every
        // request falls back to decode-side prefill recompute.
        let plan = FaultPlan::parse("corrupt:1.0", 11).unwrap();
        let r = serve_disaggregated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 1, 1, RoutePolicy::JoinShortestQueue, &plan,
        );
        assert_eq!(r.migrations, clean.migrations);
        assert_eq!(r.recompute_fallbacks, r.migrations);
        assert_eq!(
            r.migration_retries,
            (MAX_MIGRATION_ATTEMPTS as u64 - 1) * r.migrations
        );
        assert_eq!(
            r.migrated_kv_bytes,
            MAX_MIGRATION_ATTEMPTS as u64 * clean.migrated_kv_bytes,
            "every attempt moves (and bills) the pages once"
        );
        assert_eq!(r.decode.kv_imports, 0, "nothing arrives imported");
        assert_eq!(
            r.decode.prefill_tokens,
            w.total_prompt_tokens(),
            "the decode dies recompute every prompt"
        );
        assert_eq!(r.completed, clean.completed, "corruption degrades, it never drops");
        assert!(r.latency_p99_s >= clean.latency_p99_s);
    }

    #[test]
    fn disagg_decode_replica_failure_recovers_on_the_survivor() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(13, 8, (8, 40), (2, 8)).with_poisson_arrivals(3, 900.0);
        let opts = BatcherConfig::new(4, 0);
        let plan = FaultPlan::parse("fail@0:r0", 2).unwrap();
        let r = serve_disaggregated_with_faults(
            &cfg, &p, FpFormat::Fp32, opts, &w, 1, 2, RoutePolicy::JoinShortestQueue, &plan,
        );
        // The prefill fleet runs fault-free; the failure lands on decode
        // replica 0 and its backlog recovers on decode replica 1.
        assert_eq!(r.prefill.replica_failures, 0);
        assert_eq!(r.decode.replica_failures, 1);
        assert_eq!(r.completed, 8);
        assert!(r.rejected.is_empty());
        assert!(r.degraded_capacity_fraction > 0.0);
    }

    #[test]
    fn traced_fleet_is_bit_identical_and_stitches_every_replica() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(19, 16, (8, 48), (2, 8)).with_poisson_arrivals(5, 800.0);
        let opts = BatcherConfig::new(4, 0);
        let plain = serve_replicated(
            &cfg, &p, FpFormat::Fp32, opts, &w, 4, RoutePolicy::JoinShortestQueue,
        );
        let (traced, fleet) = serve_replicated_traced(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            4,
            RoutePolicy::JoinShortestQueue,
            &FaultPlan::off(),
            &TraceSettings::default(),
        );
        // Arming the recorder must not perturb the fleet outcome, down to
        // the pricing-cache counters.
        assert_eq!(plain.assigned, traced.assigned);
        assert_eq!(plain.per_replica, traced.per_replica);
        assert_eq!(plain.merged, traced.merged);
        // One stitched recorder per replica, sealed at that replica's
        // makespan, busy exactly covering that replica's priced work.
        assert_eq!(fleet.replicas().len(), 4);
        for ((label, rec), rep) in fleet.replicas().iter().zip(&traced.per_replica) {
            assert!(label.starts_with("replica "));
            assert_eq!(rec.total_cycles(), Some(rep.total_cycles));
            let busy: u64 = rec.passes().iter().map(|s| s.end - s.start).sum();
            assert_eq!(busy, rep.work.cycles);
        }
        assert!(fleet.to_chrome_json().starts_with("{\"traceEvents\":["));
    }

    #[test]
    fn traced_disagg_traces_both_stages_and_every_migration() {
        let cfg = crate::model::ModelConfig::tiny();
        let p = PlatformConfig::with_dies(4);
        let w = Workload::synthetic(7, 9, (8, 48), (2, 10)).with_poisson_arrivals(7, 700.0);
        let opts = BatcherConfig::new(4, 0);
        let plain = serve_disaggregated(
            &cfg, &p, FpFormat::Fp32, opts, &w, 1, 2, RoutePolicy::JoinShortestQueue,
        );
        let (traced, fleet) = serve_disaggregated_traced(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            1,
            2,
            RoutePolicy::JoinShortestQueue,
            &FaultPlan::off(),
            &TraceSettings::default(),
        );
        assert_eq!(plain, traced, "the recorder must be invisible to the split fleet");
        // 1 prefill + 2 decode processes, labelled by stage, plus one
        // migration span per handoff on the synthetic migration process.
        assert_eq!(fleet.replicas().len(), 3);
        assert!(fleet.replicas()[0].0.starts_with("prefill "));
        assert!(fleet.replicas()[1].0.starts_with("decode "));
        assert!(fleet.replicas()[2].0.starts_with("decode "));
        assert_eq!(fleet.migrations().len() as u64, traced.migrations);
        for m in fleet.migrations() {
            assert!(m.end >= m.start);
            assert_eq!(m.attempts, 1, "no corruption injected: single attempt each");
            assert!(m.bytes > 0);
        }
        assert!(fleet.to_chrome_json().contains("kv-migration"));
    }
}
