//! Shard plans: tensor / pipeline / data parallelism across dies, and
//! the sharded pricing built on them.
//!
//! A [`ShardPlan`] maps a model onto `tp * pp * replicas` dies:
//!
//! * `tp` — tensor-parallel ranks per pipeline stage. Each block's
//!   projections are column/row-split Megatron-style
//!   ([`crate::model::block_layers_sharded`]); the row-split halves leave
//!   partial activations that cost one all-reduce each per block. KV
//!   heads split with the attention heads, so each rank stores `1/tp` of
//!   every request's KV pages — the per-replica paged-KV pool grows
//!   accordingly ([`ShardPlan::replica_kv_budget_bytes`]).
//! * `pp` — pipeline stages. Blocks are cut into `pp` contiguous runs;
//!   each stage boundary ships the `rows x E` activations to the next
//!   stage's die ([`collectives::p2p_cost`]).
//! * `replicas` — data-parallel engine replicas, each a full `tp x pp`
//!   instance served by the replica router ([`super::router`]).
//!
//! The degenerate plan `tp = 1, pp = 1, replicas = 1` prices
//! bit-identically to [`block_cost_batched`] / the single-engine serve
//! path (asserted in `tests/parallel_plans.rs`).

use crate::arch::{FpFormat, PlatformConfig, PrecisionPolicy};
use crate::coordinator::kv_paging::KvGeometry;
use crate::coordinator::breakdown::KindCycles;
use crate::coordinator::schedule::{
    kv_requant_layer, layer_cost, model_total_mixed_policy_by_kind, LayerCostCache,
};
use crate::model::{block_layers_mixed_sharded, block_layers_sharded, Mode, ModelConfig};
use crate::parallel::collectives::{self, Algorithm};
use crate::sim::KernelCost;

/// One way to spread a model over the package's dies.
///
/// ```
/// use snitch_fm::arch::PlatformConfig;
/// use snitch_fm::model::ModelConfig;
/// use snitch_fm::parallel::ShardPlan;
///
/// let plan = ShardPlan { tp: 2, pp: 2, replicas: 1 };
/// assert_eq!(plan.dies(), 4);
/// let p = PlatformConfig::with_dies(4);
/// assert!(plan.is_legal(&ModelConfig::gpt_j(), &p));
/// // 16 attention heads do not split three ways:
/// let bad = ShardPlan { tp: 3, pp: 1, replicas: 1 };
/// assert!(!bad.is_legal(&ModelConfig::gpt_j(), &p));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Tensor-parallel ranks per pipeline stage.
    pub tp: u32,
    /// Pipeline stages.
    pub pp: u32,
    /// Data-parallel engine replicas.
    pub replicas: u32,
}

impl ShardPlan {
    /// The degenerate single-engine plan (bit-identical to today's
    /// pricing and scheduling).
    pub fn single() -> ShardPlan {
        ShardPlan { tp: 1, pp: 1, replicas: 1 }
    }

    /// Dies the plan occupies.
    pub fn dies(&self) -> u32 {
        self.tp * self.pp * self.replicas
    }

    /// Why this plan cannot run `cfg` on `platform`, or `None` if legal:
    /// every factor >= 1, the dies fit the package, `tp` divides the
    /// head and MLP dimensions (column/row splits must be exact), and
    /// `pp` does not exceed the block count.
    pub fn legality_error(&self, cfg: &ModelConfig, platform: &PlatformConfig) -> Option<String> {
        if self.tp == 0 || self.pp == 0 || self.replicas == 0 {
            return Some("tp/pp/replicas must all be >= 1".into());
        }
        if self.dies() > platform.die.dies {
            return Some(format!(
                "plan needs {} dies, package has {}",
                self.dies(),
                platform.die.dies
            ));
        }
        if cfg.heads % self.tp as u64 != 0 {
            return Some(format!("tp={} does not divide heads={}", self.tp, cfg.heads));
        }
        if cfg.ff % self.tp as u64 != 0 {
            return Some(format!("tp={} does not divide ff={}", self.tp, cfg.ff));
        }
        if self.pp as u64 > cfg.blocks {
            return Some(format!("pp={} exceeds blocks={}", self.pp, cfg.blocks));
        }
        None
    }

    pub fn is_legal(&self, cfg: &ModelConfig, platform: &PlatformConfig) -> bool {
        self.legality_error(cfg, platform).is_none()
    }

    /// Blocks per pipeline stage (earlier stages take the remainder).
    pub fn stage_blocks(&self, cfg: &ModelConfig) -> Vec<u64> {
        let pp = self.pp.max(1) as u64;
        let base = cfg.blocks / pp;
        let extra = cfg.blocks % pp;
        (0..pp).map(|i| base + u64::from(i < extra)).collect()
    }

    /// Split `total` bytes over the plan's `tp * pp` dies proportionally
    /// to each stage's block count, stage-major (stage 0's ranks first).
    /// The shares telescope — stage boundaries are cumulative-exact, and
    /// within a stage the remainder is spread one byte at a time — so
    /// they sum EXACTLY to `total` for every (possibly uneven) `tp`/`pp`.
    fn split_by_stage(&self, total: u64, cfg: &ModelConfig) -> Vec<u64> {
        let tp = self.tp.max(1) as u64;
        let blocks = cfg.blocks.max(1);
        let mut out = Vec::with_capacity((tp * self.pp.max(1) as u64) as usize);
        let mut cum_blocks = 0u64;
        let mut cum_bytes = 0u64;
        for stage in self.stage_blocks(cfg) {
            cum_blocks += stage;
            let next = total * cum_blocks / blocks;
            let stage_bytes = next - cum_bytes;
            cum_bytes = next;
            let base = stage_bytes / tp;
            let extra = stage_bytes % tp;
            out.extend((0..tp).map(|r| base + u64::from(r < extra)));
        }
        out
    }

    /// Weight bytes resident on each of the plan's `tp * pp` dies
    /// (stage-major): a stage holds its `stage_blocks` blocks' weights,
    /// split across its `tp` ranks. The shares sum exactly to
    /// `cfg.weight_bytes(fmt)` — the old uniform `weights / (tp*pp)`
    /// split both dropped the remainder and, worse, ignored that uneven
    /// pipeline stages hold whole extra blocks, understating the most
    /// loaded die by up to a block's weights.
    pub fn rank_weight_bytes(&self, cfg: &ModelConfig, fmt: FpFormat) -> Vec<u64> {
        self.split_by_stage(cfg.weight_bytes(fmt), cfg)
    }

    /// KV bytes ONE cached token costs each of the plan's `tp * pp` dies
    /// (stage-major): a die stores its stage's blocks' KV for its `1/tp`
    /// share of the heads. The shares sum exactly to the whole-model
    /// `KvGeometry::token_bytes`.
    pub fn rank_token_bytes(&self, cfg: &ModelConfig, fmt: FpFormat) -> Vec<u64> {
        self.split_by_stage(KvGeometry::new(cfg, fmt, 1).token_bytes, cfg)
    }

    /// The KV budget ONE replica of this plan offers the serving
    /// scheduler, expressed in whole-model token bytes (what the
    /// batcher's [`KvGeometry`] accounts in).
    ///
    /// Each die holds its exact weight shard ([`Self::rank_weight_bytes`])
    /// and pays its exact per-token KV share ([`Self::rank_token_bytes`]);
    /// the replica's capacity in tokens is bounded by its most loaded die
    /// (the one whose free HBM runs out of token shares first), and that
    /// capacity is handed back in full-token bytes. Every die can hold its
    /// share of the returned budget — the old truncating splits let the
    /// most loaded die of an uneven-`pp` plan overcommit. The single plan
    /// reproduces `platform_kv_budget_bytes` exactly.
    pub fn replica_kv_budget_bytes(
        &self,
        cfg: &ModelConfig,
        fmt: FpFormat,
        platform: &PlatformConfig,
    ) -> u64 {
        self.replica_kv_budget_bytes_policy(cfg, PrecisionPolicy::uniform(fmt), platform)
    }

    /// [`Self::replica_kv_budget_bytes`] under a decoupled precision
    /// policy: weight shards resident at `policy.weights`, KV token
    /// shares at `policy.kv`. A narrow KV format shrinks every token
    /// share, so the same dies cache proportionally more tokens. The
    /// uniform policy is bit-identical to the format-scalar version.
    pub fn replica_kv_budget_bytes_policy(
        &self,
        cfg: &ModelConfig,
        policy: PrecisionPolicy,
        platform: &PlatformConfig,
    ) -> u64 {
        if self.tp <= 1 && self.pp <= 1 {
            // Exactly the single-engine budget formula, bit-for-bit.
            return platform
                .interconnect
                .hbm_capacity_bytes
                .saturating_sub(cfg.weight_bytes(policy.weights));
        }
        let hbm = platform.interconnect.hbm_capacity_bytes;
        let token_bytes = KvGeometry::new(cfg, policy.kv, 1).token_bytes.max(1);
        let capacity_tokens = self
            .rank_weight_bytes(cfg, policy.weights)
            .iter()
            .zip(&self.rank_token_bytes(cfg, policy.kv))
            .map(|(&w, &t)| hbm.saturating_sub(w) / t.max(1))
            .min()
            .unwrap_or(0);
        capacity_tokens * token_bytes
    }
}

/// Cost of one transformer block on ONE TP rank, including the induced
/// all-reduces (cheapest of ring/tree per payload). At `tp = 1` this is
/// bit-identical to `block_cost_batched(...).total`: same layers, same
/// pricing order, no collective.
#[allow(clippy::too_many_arguments)]
pub fn sharded_block_cost(
    cfg: &ModelConfig,
    tp: u32,
    mode: Mode,
    b: u64,
    s: u64,
    kv_len: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> KernelCost {
    let sb = block_layers_sharded(cfg, mode, b.max(1), s, kv_len, tp.max(1) as u64);
    let mut total = KernelCost::default();
    for layer in &sb.layers {
        total = total.then(layer_cost(layer, fmt, platform));
    }
    let ranks: Vec<u32> = (0..tp.max(1)).collect();
    for &elems in &sb.allreduce_elems {
        total = total.then(collectives::all_reduce_cost(
            elems * fmt.bytes(),
            &ranks,
            Algorithm::Auto,
            fmt,
            platform,
        ));
    }
    total
}

/// One serving iteration priced under a shard plan.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardedPass {
    /// Wall-clock and resources of the pass through the whole pipe (one
    /// rank's compute per block, like [`plan_cost`], plus the
    /// collectives' full cross-die accounting).
    pub total: KernelCost,
    /// Cycles inside the TP all-reduces and PP activation sends — the
    /// communication share of `total.cycles` (the "TP tax" the serve
    /// report surfaces).
    pub collective_cycles: u64,
    /// Rank-local compute cycles split by kernel class. Collectives and
    /// activation sends are excluded (they live in `collective_cycles`),
    /// so `kind_cycles.total() + collective_cycles == total.cycles`.
    pub kind_cycles: KindCycles,
}

/// Price ONE mixed serving iteration (`prefills` chunk continuations plus
/// one decode token per `decode_kv` entry, the
/// [`crate::model::block_layers_mixed`] shapes) executed under `plan`:
/// the rank-local layers of [`block_layers_mixed_sharded`] go through the
/// pricing memo, each block charges its two TP all-reduces (cheapest of
/// ring/tree), and each pipeline boundary ships the stacked `rows x E`
/// activations ([`collectives::p2p_cost`]; the pipe runs without
/// inter-iteration overlap, so the pass crosses every stage in sequence
/// exactly as [`plan_cost`]'s `token_latency_cycles` does).
///
/// The degenerate plan delegates to [`model_total_mixed_by_kind`] —
/// bit-identical to the single-die serving path, zero collective cycles.
pub fn plan_pass_cost(
    costs: &mut LayerCostCache,
    cfg: &ModelConfig,
    plan: ShardPlan,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> ShardedPass {
    plan_pass_cost_policy(
        costs,
        cfg,
        plan,
        prefills,
        decode_kv,
        PrecisionPolicy::uniform(fmt),
        platform,
    )
}

/// [`plan_pass_cost`] under a decoupled precision policy: rank-local
/// layers price at `(policy.compute, policy.kv)` through the layer memo,
/// collectives move activation bytes at `policy.compute`, and when KV is
/// stored narrower than compute each block additionally bills the
/// dequant-on-read / requant-on-write kernel over this rank's `1/tp`
/// share of the heads ([`kv_requant_layer`]). The uniform policy is
/// bit-identical to the format-scalar version.
pub fn plan_pass_cost_policy(
    costs: &mut LayerCostCache,
    cfg: &ModelConfig,
    plan: ShardPlan,
    prefills: &[(u64, u64)],
    decode_kv: &[u64],
    policy: PrecisionPolicy,
    platform: &PlatformConfig,
) -> ShardedPass {
    if plan.tp <= 1 && plan.pp <= 1 {
        let (total, kind_cycles) =
            model_total_mixed_policy_by_kind(costs, cfg, prefills, decode_kv, policy, platform);
        return ShardedPass { total, collective_cycles: 0, kind_cycles };
    }
    let rows: u64 =
        prefills.iter().map(|&(s, _)| s).sum::<u64>() + decode_kv.len() as u64;
    if rows == 0 {
        return ShardedPass::default();
    }
    costs.ensure_platform(platform);
    let sb = block_layers_mixed_sharded(cfg, prefills, decode_kv, plan.tp as u64);
    let mut one = KernelCost::default();
    let mut kinds = KindCycles::default();
    for layer in &sb.layers {
        let c = costs.layer_cost_kv(layer, policy.compute, policy.kv, platform);
        one = one.then(c);
        kinds.add(layer.kind, c.cycles);
    }
    if policy.kv_conversion_active() {
        if let Some(mut layer) = kv_requant_layer(cfg, prefills, decode_kv) {
            // Each TP rank converts only its own 1/tp share of the KV
            // heads (tp divides heads by plan legality).
            layer.heads = (cfg.heads / plan.tp.max(1) as u64).max(1);
            let c = costs.layer_cost_kv(&layer, policy.compute, policy.kv, platform);
            one = one.then(c);
            kinds.add(layer.kind, c.cycles);
        }
    }
    let ranks: Vec<u32> = (0..plan.tp.max(1)).collect();
    let mut block_coll = KernelCost::default();
    for &elems in &sb.allreduce_elems {
        block_coll = block_coll.then(collectives::all_reduce_cost(
            elems * policy.compute.bytes(),
            &ranks,
            Algorithm::Auto,
            policy.compute,
            platform,
        ));
    }
    let mut total = one.then(block_coll).repeat(cfg.blocks);
    let mut collective_cycles = block_coll.cycles * cfg.blocks;
    if plan.pp > 1 {
        let send_bytes =
            (rows * cfg.e * policy.compute.bytes()).div_ceil(plan.tp.max(1) as u64);
        let send = collectives::p2p_cost(send_bytes, platform);
        for _ in 1..plan.pp {
            total = total.then(send);
        }
        collective_cycles += (plan.pp as u64 - 1) * send.cycles;
    }
    ShardedPass { total, collective_cycles, kind_cycles: kinds.scaled(cfg.blocks) }
}

/// A plan priced on a concrete model pass.
#[derive(Debug, Clone)]
pub struct PlanCost {
    pub plan: ShardPlan,
    /// Per-stage cycles of one pass (blocks share + TP collectives).
    pub stage_cycles: Vec<u64>,
    /// One token (AR) / one pass (NAR) through the whole pipe: the sum of
    /// the stages plus the inter-stage activation sends.
    pub token_latency_cycles: u64,
    /// Steady-state step cycles with the pipe full (the slowest stage
    /// plus its outbound send) — the per-replica throughput bound.
    pub steady_cycles: u64,
    /// Aggregate resources of one pass across all of one replica's dies.
    pub total: KernelCost,
    /// Aggregate tokens/s across all replicas at the priced batch.
    pub tokens_per_s: f64,
}

/// Price one model pass under `plan`: per-stage sharded block costs, the
/// pipeline's activation sends, pipe latency and steady-state rate, and
/// the aggregate tokens/s `replicas` such engines deliver.
///
/// In AR mode `s` is the KV length and each pass advances `b` tokens per
/// replica; in NAR mode each pass produces `b * s` tokens. Pipeline
/// stages are assumed kept full by independent requests (the serving
/// router's job), so the steady rate is bounded by the slowest stage.
pub fn plan_cost(
    cfg: &ModelConfig,
    plan: ShardPlan,
    mode: Mode,
    b: u64,
    s: u64,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> PlanCost {
    let plan = ShardPlan {
        tp: plan.tp.max(1),
        pp: plan.pp.max(1),
        replicas: plan.replicas.max(1),
    };
    let b = b.max(1);
    let (bs, kv) = match mode {
        Mode::Nar => (s, 0),
        Mode::Ar => (1, s),
    };
    let one = sharded_block_cost(cfg, plan.tp, mode, b, bs, kv, fmt, platform);
    let stage_blocks = plan.stage_blocks(cfg);
    let stage_cycles: Vec<u64> =
        stage_blocks.iter().map(|&blocks| one.cycles * blocks).collect();

    // Each boundary ships the b*rows x E activations; the tp ranks of a
    // stage each send their row shard to the peer rank in parallel.
    let rows = b * bs;
    let send_bytes = (rows * cfg.e * fmt.bytes()).div_ceil(plan.tp as u64);
    let send = if plan.pp > 1 {
        collectives::p2p_cost(send_bytes, platform)
    } else {
        KernelCost::default()
    };

    let mut total = KernelCost::default();
    for &blocks in &stage_blocks {
        total = total.then(one.repeat(blocks));
    }
    for _ in 1..plan.pp {
        total = total.then(send);
    }

    let token_latency_cycles = stage_cycles.iter().sum::<u64>()
        + (plan.pp as u64 - 1) * send.cycles;
    let steady_cycles = stage_cycles
        .iter()
        .enumerate()
        .map(|(i, &c)| c + if i + 1 < plan.pp as usize { send.cycles } else { 0 })
        .max()
        .unwrap_or(0);

    let tokens_per_pass = match mode {
        Mode::Nar => b * s,
        Mode::Ar => b,
    };
    let steady_s = platform.cycles_to_seconds(steady_cycles.max(1));
    let tokens_per_s = plan.replicas as f64 * tokens_per_pass as f64 / steady_s;

    PlanCost {
        plan,
        stage_cycles,
        token_latency_cycles,
        steady_cycles,
        total,
        tokens_per_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::{block_cost_batched, model_total_mixed};

    #[test]
    fn stage_blocks_cover_all_blocks() {
        let cfg = ModelConfig::gpt_j(); // 28 blocks
        for pp in [1u32, 2, 3, 4, 7] {
            let plan = ShardPlan { tp: 1, pp, replicas: 1 };
            let stages = plan.stage_blocks(&cfg);
            assert_eq!(stages.len(), pp as usize);
            assert_eq!(stages.iter().sum::<u64>(), cfg.blocks);
            assert!(stages.iter().max().unwrap() - stages.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn tp_tax_grows_under_a_degraded_link() {
        use crate::parallel::collectives::degrade_link;
        let cfg = ModelConfig::tiny();
        let p = PlatformConfig::with_dies(2);
        let slow = degrade_link(&p, 0.25);
        let plan = ShardPlan { tp: 2, pp: 1, replicas: 1 };
        let prefills = [(32u64, 0u64)];
        let decode = [64u64, 96];
        let mut costs_n = LayerCostCache::new(&p);
        let nominal = plan_pass_cost(&mut costs_n, &cfg, plan, &prefills, &decode, FpFormat::Fp32, &p);
        let mut costs_d = LayerCostCache::new(&slow);
        let degraded =
            plan_pass_cost(&mut costs_d, &cfg, plan, &prefills, &decode, FpFormat::Fp32, &slow);
        // The all-reduce tax visibly grows; the bytes moved do not.
        assert!(
            degraded.collective_cycles > nominal.collective_cycles,
            "{} !> {}",
            degraded.collective_cycles,
            nominal.collective_cycles
        );
        assert_eq!(degraded.total.d2d_bytes, nominal.total.d2d_bytes);
        assert!(degraded.total.cycles > nominal.total.cycles);
    }

    #[test]
    fn legality_rules() {
        let cfg = ModelConfig::gpt_j(); // 16 heads
        let p = PlatformConfig::with_dies(4);
        assert!(ShardPlan::single().is_legal(&cfg, &p));
        assert!(ShardPlan { tp: 2, pp: 2, replicas: 1 }.is_legal(&cfg, &p));
        // Too many dies.
        assert!(!ShardPlan { tp: 4, pp: 2, replicas: 1 }.is_legal(&cfg, &p));
        // tp must divide heads (ViT-B has 12).
        let vit = ModelConfig::vit_b();
        assert!(!ShardPlan { tp: 8, pp: 1, replicas: 1 }
            .is_legal(&vit, &PlatformConfig::with_dies(8)));
        assert!(ShardPlan { tp: 4, pp: 1, replicas: 1 }
            .is_legal(&vit, &PlatformConfig::with_dies(8)));
        // pp bounded by blocks.
        let tiny = ModelConfig::tiny(); // 2 blocks
        assert!(!ShardPlan { tp: 1, pp: 3, replicas: 1 }
            .is_legal(&tiny, &PlatformConfig::with_dies(8)));
    }

    #[test]
    fn single_plan_budget_matches_platform_budget() {
        use crate::coordinator::kv_paging::platform_kv_budget_bytes;
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::occamy();
        for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
            let single = ShardPlan::single().replica_kv_budget_bytes(&cfg, fmt, &p);
            assert_eq!(single, platform_kv_budget_bytes(&cfg, fmt, &p));
        }
    }

    #[test]
    fn tp_sharding_grows_the_replica_kv_pool() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let single = ShardPlan::single().replica_kv_budget_bytes(&cfg, fmt, &p);
        let tp2 = ShardPlan { tp: 2, pp: 1, replicas: 1 }
            .replica_kv_budget_bytes(&cfg, fmt, &p);
        // Two dies hold half the weights each and split every token's KV
        // heads: the replica fits strictly more tokens.
        assert!(tp2 > single, "tp2 {tp2} !> single {single}");
    }

    #[test]
    fn rank_splits_sum_exactly_across_uneven_tp_pp() {
        // The rounding property the budget rests on: per-die weight and
        // per-token KV shares sum EXACTLY to the single-die values, for
        // every legal (and deliberately uneven) tp/pp combination.
        let p = PlatformConfig::with_dies(16);
        for cfg in [ModelConfig::tiny(), ModelConfig::gpt_j(), ModelConfig::vit_b()] {
            for tp in [1u32, 2, 4] {
                for pp in [1u32, 2, 3, 5, 7] {
                    let plan = ShardPlan { tp, pp, replicas: 1 };
                    if !plan.is_legal(&cfg, &p) {
                        continue;
                    }
                    for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
                        let w = plan.rank_weight_bytes(&cfg, fmt);
                        let t = plan.rank_token_bytes(&cfg, fmt);
                        assert_eq!(w.len(), (tp * pp) as usize);
                        assert_eq!(t.len(), (tp * pp) as usize);
                        assert_eq!(
                            w.iter().sum::<u64>(),
                            cfg.weight_bytes(fmt),
                            "{} tp={tp} pp={pp} {fmt:?}: weight shares must conserve",
                            cfg.name
                        );
                        assert_eq!(
                            t.iter().sum::<u64>(),
                            KvGeometry::new(&cfg, fmt, 1).token_bytes,
                            "{} tp={tp} pp={pp} {fmt:?}: token shares must conserve",
                            cfg.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_budget_never_overcommits_any_die() {
        // Regression: the old budget split weights uniformly over tp*pp
        // dies, so with uneven pipeline stages (28 blocks over pp=3 ->
        // 10/9/9) the most loaded die's weights were understated by a
        // third of a block and the returned budget did not actually fit
        // on that die. Every die must be able to hold its weight shard
        // plus its token share of the full budget.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(16);
        for (tp, pp) in [(1u32, 3u32), (2, 3), (1, 5), (2, 5), (4, 3)] {
            let plan = ShardPlan { tp, pp, replicas: 1 };
            assert!(plan.is_legal(&cfg, &p), "tp={tp} pp={pp}");
            for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
                let token_bytes = KvGeometry::new(&cfg, fmt, 1).token_bytes;
                let budget = plan.replica_kv_budget_bytes(&cfg, fmt, &p);
                assert!(budget > 0, "tp={tp} pp={pp} {fmt:?}");
                let tokens = budget / token_bytes;
                let weights = plan.rank_weight_bytes(&cfg, fmt);
                let shares = plan.rank_token_bytes(&cfg, fmt);
                for (die, (&w, &t)) in weights.iter().zip(&shares).enumerate() {
                    assert!(
                        w + tokens * t <= p.interconnect.hbm_capacity_bytes,
                        "tp={tp} pp={pp} {fmt:?}: die {die} overcommitted \
                         ({w} weights + {tokens} x {t} KV > {} HBM)",
                        p.interconnect.hbm_capacity_bytes
                    );
                }
            }
        }
    }

    #[test]
    fn sharded_tp1_block_cost_bit_identical() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::occamy();
        for (mode, b, s, kv) in
            [(Mode::Nar, 1, 256, 0), (Mode::Nar, 4, 64, 512), (Mode::Ar, 8, 1, 1024)]
        {
            for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
                let sharded = sharded_block_cost(&cfg, 1, mode, b, s, kv, fmt, &p);
                let batched = block_cost_batched(&cfg, mode, b, s, kv, fmt, &p).total;
                assert_eq!(sharded, batched, "{mode:?} b={b} s={s} {fmt:?}");
            }
        }
    }

    #[test]
    fn plan_pass_degenerate_is_bit_identical_to_mixed_total() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::occamy();
        let fmt = FpFormat::Fp8;
        let prefills = [(64, 128)];
        let lens = [256u64, 256, 512];
        let mut costs = LayerCostCache::new(&p);
        let pass =
            plan_pass_cost(&mut costs, &cfg, ShardPlan::single(), &prefills, &lens, fmt, &p);
        let mut fresh = LayerCostCache::new(&p);
        assert_eq!(
            pass.total,
            model_total_mixed(&mut fresh, &cfg, &prefills, &lens, fmt, &p)
        );
        assert_eq!(pass.collective_cycles, 0);
        assert_eq!(pass.total.d2d_bytes, 0);
        // Empty iterations are free under any plan.
        let empty = plan_pass_cost(
            &mut costs,
            &cfg,
            ShardPlan { tp: 2, pp: 2, replicas: 1 },
            &[(0, 64)],
            &[],
            fmt,
            &p,
        );
        assert_eq!(empty.total, KernelCost::default());
    }

    #[test]
    fn plan_pass_uniform_pass_matches_plan_cost_analytics() {
        // The serving iteration and the offline ranker price the same
        // pass through different expansions; on a uniform batch they must
        // agree bit-for-bit — decode and monolithic prefill alike —
        // including the d2d traffic of the all-reduces and sends.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(8);
        let fmt = FpFormat::Fp8;
        for plan in [
            ShardPlan { tp: 2, pp: 1, replicas: 1 },
            ShardPlan { tp: 2, pp: 2, replicas: 1 },
            ShardPlan { tp: 1, pp: 4, replicas: 1 },
        ] {
            let mut costs = LayerCostCache::new(&p);
            let (b, kv) = (4u64, 512u64);
            let decode: Vec<u64> = vec![kv; b as usize];
            let pass = plan_pass_cost(&mut costs, &cfg, plan, &[], &decode, fmt, &p);
            let analytic = plan_cost(&cfg, plan, Mode::Ar, b, kv, fmt, &p);
            assert_eq!(pass.total, analytic.total, "{plan:?} decode");
            let pass = plan_pass_cost(&mut costs, &cfg, plan, &[(256, 0)], &[], fmt, &p);
            let analytic = plan_cost(&cfg, plan, Mode::Nar, 1, 256, fmt, &p);
            assert_eq!(pass.total, analytic.total, "{plan:?} prefill");
            if plan.tp > 1 {
                assert!(pass.collective_cycles > 0, "{plan:?}");
                assert!(pass.total.d2d_bytes > 0, "{plan:?}");
            }
        }
    }

    #[test]
    fn pass_kind_split_covers_compute_exactly() {
        // kind_cycles + collective_cycles must tile total.cycles exactly,
        // for the degenerate plan (no collectives) and genuinely sharded
        // tp/pp plans (all-reduces + activation sends) alike.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(8);
        let fmt = FpFormat::Fp8;
        let prefills = [(64u64, 128u64)];
        let lens = [256u64, 512, 1024];
        for plan in [
            ShardPlan::single(),
            ShardPlan { tp: 2, pp: 1, replicas: 1 },
            ShardPlan { tp: 2, pp: 2, replicas: 1 },
            ShardPlan { tp: 1, pp: 4, replicas: 1 },
        ] {
            let mut costs = LayerCostCache::new(&p);
            let pass = plan_pass_cost(&mut costs, &cfg, plan, &prefills, &lens, fmt, &p);
            assert_eq!(
                pass.kind_cycles.total() + pass.collective_cycles,
                pass.total.cycles,
                "{plan:?}"
            );
            assert!(!pass.kind_cycles.is_zero(), "{plan:?}");
        }
        // Empty pass: all-zero split.
        let mut costs = LayerCostCache::new(&p);
        let empty = plan_pass_cost(
            &mut costs,
            &cfg,
            ShardPlan { tp: 2, pp: 1, replicas: 1 },
            &[],
            &[],
            fmt,
            &p,
        );
        assert!(empty.kind_cycles.is_zero());
    }

    #[test]
    fn tp_sharding_cuts_decode_step_latency() {
        // GPT-J decode is weight-streaming-bound: halving each rank's
        // weight stream must beat the (activation-sized) all-reduce.
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let one = plan_cost(&cfg, ShardPlan::single(), Mode::Ar, 4, 1024, fmt, &p);
        let tp2 = plan_cost(
            &cfg,
            ShardPlan { tp: 2, pp: 1, replicas: 1 },
            Mode::Ar,
            4,
            1024,
            fmt,
            &p,
        );
        assert!(
            tp2.token_latency_cycles < one.token_latency_cycles,
            "tp2 {} !< single {}",
            tp2.token_latency_cycles,
            one.token_latency_cycles
        );
        assert!(tp2.total.d2d_bytes > 0, "the all-reduce must show up as d2d traffic");
    }

    #[test]
    fn pipeline_raises_steady_rate_but_not_latency() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let one = plan_cost(&cfg, ShardPlan::single(), Mode::Ar, 4, 1024, fmt, &p);
        let pp4 = plan_cost(
            &cfg,
            ShardPlan { tp: 1, pp: 4, replicas: 1 },
            Mode::Ar,
            4,
            1024,
            fmt,
            &p,
        );
        // A 4-stage pipe steps ~4x faster once full...
        assert!(pp4.steady_cycles < one.steady_cycles / 2);
        assert!(pp4.tokens_per_s > one.tokens_per_s);
        // ...but a single token still traverses every block plus sends.
        assert!(pp4.token_latency_cycles >= one.token_latency_cycles);
    }

    #[test]
    fn replicas_multiply_throughput_only() {
        let cfg = ModelConfig::gpt_j();
        let p = PlatformConfig::with_dies(4);
        let fmt = FpFormat::Fp8;
        let one = plan_cost(&cfg, ShardPlan::single(), Mode::Ar, 4, 1024, fmt, &p);
        let dp4 = plan_cost(
            &cfg,
            ShardPlan { tp: 1, pp: 1, replicas: 4 },
            Mode::Ar,
            4,
            1024,
            fmt,
            &p,
        );
        assert_eq!(dp4.token_latency_cycles, one.token_latency_cycles);
        assert!((dp4.tokens_per_s - 4.0 * one.tokens_per_s).abs() < 1e-6);
    }
}
