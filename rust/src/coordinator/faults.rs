//! Seeded, deterministic fault injection for the serving fleet.
//!
//! The platform modeled here is a package of many small dies joined by
//! die-to-die links; dies, links and DMA engines are independent failure
//! domains. This module defines the *fault plan* — a seeded stream of
//! timed [`FaultEvent`]s parsed from `serve --faults <spec>` — and the
//! per-replica view ([`ReplicaFaults`], in cycles) that the batcher run
//! loops consume. Everything is deterministic: the same spec and
//! `--fault-seed` reproduce byte-identical reports, and an empty plan
//! (`--faults off`) leaves every serving path bit-identical to the
//! fault-free engine.
//!
//! # Spec grammar
//!
//! A spec is `off` or a comma-separated list of clauses:
//!
//! ```text
//! fail@<s>[:r<i>]       permanent replica failure at <s> seconds; the
//!                       die's KV pool stays addressable over the d2d
//!                       fabric, so finished-prefill requests re-export
//!                       their KV to a survivor (salvage).
//! die@<s>[:r<i>]        permanent replica failure, KV pool lost with the
//!                       die: every salvaged request fully recomputes.
//! stall@<s>:<c>[:r<i>]  transient stall: the replica freezes for <c>
//!                       cycles at <s> seconds, then resumes.
//! link@<s>:<f>          the d2d link degrades to fraction <f> of nominal
//!                       bandwidth at <s> seconds (package-wide).
//! corrupt:<p>           each disaggregated KV migration is corrupted
//!                       with probability <p> (seeded draw per attempt)
//!                       and must be retried over the link.
//! ```
//!
//! Replica-targeted clauses may omit `:r<i>`; the target is then drawn
//! deterministically from `--fault-seed` when the plan is split per
//! replica. See `docs/serving.md` ("Failure model & recovery") for the
//! recovery lifecycle and the retry/backoff policy.
//!
//! # Example
//!
//! ```
//! use snitch_fm::coordinator::faults::{FaultKind, FaultPlan};
//!
//! let plan = FaultPlan::parse("fail@2.5:r1,stall@1.0:2000,link@3.0:0.25", 7).unwrap();
//! assert_eq!(plan.events.len(), 3);
//! let view = plan.for_replica(1, 4, 1.0);
//! // replica 1 sees its pinned failure plus the package-wide link fault
//! assert!(view
//!     .events
//!     .iter()
//!     .any(|e| matches!(e.kind, FaultKind::ReplicaFail { .. })));
//! assert!(FaultPlan::parse("off", 0).unwrap().is_off());
//! ```

use crate::coordinator::workload::Request;

/// What a single fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The replica stops executing permanently. When `pool_survives` the
    /// die's KV pool remains reachable over the d2d fabric and salvaged
    /// requests that finished prefill re-export their KV pages to the
    /// replica that adopts them; otherwise they recompute from scratch.
    ReplicaFail {
        /// Whether the failed die's KV pool stays addressable (a compute
        /// failure) or is lost with the die (a power/package failure).
        pool_survives: bool,
    },
    /// The replica freezes for `cycles` cycles, then resumes where it
    /// left off. Arrivals during the stall queue up and are admitted
    /// when the replica wakes.
    ReplicaStall {
        /// Length of the freeze in core cycles.
        cycles: u64,
    },
    /// The die-to-die link drops to `fraction` of its nominal bandwidth.
    /// Collectives, pipeline sends and KV migrations all get more
    /// expensive; the last event before a given time wins.
    LinkDegrade {
        /// New bandwidth as a fraction of nominal, in `(0, 1]`.
        fraction: f64,
    },
}

impl FaultKind {
    /// Short stable label for telemetry markers and trace events:
    /// `"fail"`, `"die"`, `"stall"` or `"link"` (the spec clause names).
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::ReplicaFail { pool_survives: true } => "fail",
            FaultKind::ReplicaFail { pool_survives: false } => "die",
            FaultKind::ReplicaStall { .. } => "stall",
            FaultKind::LinkDegrade { .. } => "link",
        }
    }
}

/// One timed fault in wall-clock (trace) seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// When the fault fires, in seconds from trace start.
    pub at_s: f64,
    /// Replica the fault targets. `None` means "drawn from the seed"
    /// for replica-scoped kinds, and "package-wide" for link faults.
    pub replica: Option<usize>,
    /// What happens.
    pub kind: FaultKind,
}

/// A parsed, seeded fault plan (see the module docs for the grammar).
///
/// The plan lives in the wall-clock domain; [`FaultPlan::for_replica`]
/// projects it onto one replica's cycle domain for the batcher.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for unpinned replica assignment and migration-corruption
    /// draws (`--fault-seed`).
    pub seed: u64,
    /// All timed events, in spec order.
    pub events: Vec<FaultEvent>,
    /// Probability that one disaggregated KV-migration attempt is
    /// corrupted and must be retried (`corrupt:<p>`).
    pub corrupt_prob: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::off()
    }
}

/// One fault projected onto a replica's cycle clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaFaultEvent {
    /// Cycle (on the replica's own clock) at which the fault fires.
    pub cycle: u64,
    /// What happens.
    pub kind: FaultKind,
}

/// The faults one replica will observe, sorted by cycle. An empty view
/// (the default) makes the run loops bit-identical to the fault-free
/// engine.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaFaults {
    /// Events in non-decreasing cycle order.
    pub events: Vec<ReplicaFaultEvent>,
}

impl ReplicaFaults {
    /// The empty view: no faults, bit-identical serving.
    pub fn none() -> ReplicaFaults {
        ReplicaFaults::default()
    }

    /// True when the view carries no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// A request rescued from a failed replica, to be re-arrived elsewhere.
#[derive(Debug, Clone, PartialEq)]
pub struct SalvagedRequest {
    /// The request to re-route. `req.kv_imported` is set when its prompt
    /// KV was re-exported from the failed die's surviving pool (the
    /// adopting replica imports it and skips prefill); it is cleared
    /// when the pool died and the prompt must be recomputed.
    pub req: Request,
    /// Cycle (failed replica's clock) at which the failure fired.
    pub fail_cycle: u64,
    /// Bytes of KV re-exported over the d2d link for this request
    /// (0 when the request recomputes from scratch).
    pub export_bytes: u64,
}

/// SplitMix64 finalizer — the same mixing used by the workload
/// generators, kept local so fault draws never perturb trace seeds.
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn parse_seconds(tok: &str, clause: &str) -> Result<f64, String> {
    let s: f64 = tok
        .parse()
        .map_err(|_| format!("bad time {tok:?} in fault clause {clause:?}"))?;
    if !s.is_finite() || s < 0.0 {
        return Err(format!("fault time must be finite and >= 0 in {clause:?}"));
    }
    Ok(s)
}

fn parse_replica(tok: &str, clause: &str) -> Result<usize, String> {
    let idx = tok
        .strip_prefix('r')
        .ok_or_else(|| format!("expected r<i> replica target in fault clause {clause:?}"))?;
    idx.parse()
        .map_err(|_| format!("bad replica index {tok:?} in fault clause {clause:?}"))
}

impl FaultPlan {
    /// The empty plan: nothing ever fails.
    pub fn off() -> FaultPlan {
        FaultPlan { seed: 0, events: Vec::new(), corrupt_prob: 0.0 }
    }

    /// True when the plan injects nothing (serving stays bit-identical).
    pub fn is_off(&self) -> bool {
        self.events.is_empty() && self.corrupt_prob == 0.0
    }

    /// Parse a `--faults` spec (see the module docs for the grammar).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let spec = spec.trim();
        let mut plan = FaultPlan { seed, events: Vec::new(), corrupt_prob: 0.0 };
        if spec.is_empty() || spec == "off" || spec == "none" {
            return Ok(plan);
        }
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(p) = clause.strip_prefix("corrupt:") {
                let prob: f64 = p
                    .parse()
                    .map_err(|_| format!("bad probability in fault clause {clause:?}"))?;
                if !(0.0..=1.0).contains(&prob) {
                    return Err(format!("corrupt probability must be in [0, 1]: {clause:?}"));
                }
                plan.corrupt_prob = prob;
                continue;
            }
            let (head, rest) = clause
                .split_once('@')
                .ok_or_else(|| format!("unknown fault clause {clause:?}"))?;
            let parts: Vec<&str> = rest.split(':').collect();
            let (at_s, replica, kind) = match head {
                "fail" | "die" => {
                    let at_s = parse_seconds(parts[0], clause)?;
                    let replica = match parts.len() {
                        1 => None,
                        2 => Some(parse_replica(parts[1], clause)?),
                        _ => return Err(format!("too many fields in fault clause {clause:?}")),
                    };
                    let kind = FaultKind::ReplicaFail { pool_survives: head == "fail" };
                    (at_s, replica, kind)
                }
                "stall" => {
                    if parts.len() < 2 || parts.len() > 3 {
                        return Err(format!("stall wants stall@<s>:<cycles>[:r<i>]: {clause:?}"));
                    }
                    let at_s = parse_seconds(parts[0], clause)?;
                    let cycles: u64 = parts[1]
                        .parse()
                        .map_err(|_| format!("bad stall cycles in fault clause {clause:?}"))?;
                    if cycles == 0 {
                        return Err(format!("stall cycles must be > 0: {clause:?}"));
                    }
                    let replica =
                        if parts.len() == 3 { Some(parse_replica(parts[2], clause)?) } else { None };
                    (at_s, replica, FaultKind::ReplicaStall { cycles })
                }
                "link" => {
                    if parts.len() != 2 {
                        return Err(format!("link wants link@<s>:<fraction>: {clause:?}"));
                    }
                    let at_s = parse_seconds(parts[0], clause)?;
                    let fraction: f64 = parts[1]
                        .parse()
                        .map_err(|_| format!("bad link fraction in fault clause {clause:?}"))?;
                    if !(fraction > 0.0 && fraction <= 1.0) {
                        return Err(format!("link fraction must be in (0, 1]: {clause:?}"));
                    }
                    (at_s, None, FaultKind::LinkDegrade { fraction })
                }
                _ => return Err(format!("unknown fault clause {clause:?}")),
            };
            plan.events.push(FaultEvent { at_s, replica, kind });
        }
        Ok(plan)
    }

    /// The replica a replica-scoped event targets: its pinned `r<i>` when
    /// given, otherwise a deterministic draw from the plan seed and the
    /// event's position (so the same spec + seed always picks the same
    /// victims, independent of which replica asks).
    pub fn target_of(&self, event_index: usize, replicas: usize) -> usize {
        let replicas = replicas.max(1);
        match self.events.get(event_index).and_then(|e| e.replica) {
            Some(r) => r % replicas,
            None => (splitmix(self.seed ^ ((event_index as u64 + 1) << 17)) % replicas as u64)
                as usize,
        }
    }

    /// Project the plan onto one replica's cycle clock. Replica-scoped
    /// events land only on their target; link faults land on every
    /// replica (the d2d fabric is shared). Events are sorted by cycle,
    /// ties kept in spec order.
    pub fn for_replica(&self, replica: usize, replicas: usize, freq_ghz: f64) -> ReplicaFaults {
        let mut events = Vec::new();
        for (i, e) in self.events.iter().enumerate() {
            let mine = match e.kind {
                FaultKind::LinkDegrade { .. } => true,
                _ => self.target_of(i, replicas) == replica,
            };
            if mine {
                events.push(ReplicaFaultEvent { cycle: seconds_to_cycles(e.at_s, freq_ghz), kind: e.kind });
            }
        }
        events.sort_by_key(|e| e.cycle);
        ReplicaFaults { events }
    }

    /// The d2d link bandwidth fraction in force at `at_s` seconds: the
    /// last link event at or before that time, 1.0 before any.
    pub fn link_fraction_at(&self, at_s: f64) -> f64 {
        let mut fraction = 1.0;
        let mut when = f64::NEG_INFINITY;
        for e in &self.events {
            if let FaultKind::LinkDegrade { fraction: f } = e.kind {
                if e.at_s <= at_s && e.at_s >= when {
                    fraction = f;
                    when = e.at_s;
                }
            }
        }
        fraction
    }

    /// Seeded corruption draw for one KV-migration attempt: true when
    /// the attempt is corrupted and must be retried. Deterministic in
    /// `(seed, request id, attempt)` so reruns are byte-identical.
    pub fn migration_corrupted(&self, request_id: usize, attempt: u32) -> bool {
        if self.corrupt_prob <= 0.0 {
            return false;
        }
        let draw = splitmix(self.seed ^ ((request_id as u64) << 20) ^ attempt as u64);
        // Map the top 53 bits onto [0, 1).
        let unit = (draw >> 11) as f64 / (1u64 << 53) as f64;
        unit < self.corrupt_prob
    }
}

/// Convert trace seconds to core cycles at `freq_ghz` (round-to-nearest,
/// the same convention the arrival stamping uses).
pub fn seconds_to_cycles(at_s: f64, freq_ghz: f64) -> u64 {
    (at_s * freq_ghz * 1e9).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_specs_parse_to_empty_plans() {
        for spec in ["off", "none", "", "  "] {
            let plan = FaultPlan::parse(spec, 42).unwrap();
            assert!(plan.is_off(), "{spec:?} should be off");
            assert!(plan.for_replica(0, 4, 1.0).is_empty());
        }
    }

    #[test]
    fn full_grammar_round_trips() {
        let plan =
            FaultPlan::parse("fail@2.5:r1,die@4.0,stall@1.0:2000:r0,link@3.0:0.25,corrupt:0.1", 7)
                .unwrap();
        assert_eq!(plan.events.len(), 4);
        assert_eq!(plan.corrupt_prob, 0.1);
        assert_eq!(
            plan.events[0].kind,
            FaultKind::ReplicaFail { pool_survives: true }
        );
        assert_eq!(plan.events[0].replica, Some(1));
        assert_eq!(
            plan.events[1].kind,
            FaultKind::ReplicaFail { pool_survives: false }
        );
        assert_eq!(plan.events[1].replica, None);
        assert_eq!(plan.events[2].kind, FaultKind::ReplicaStall { cycles: 2000 });
        assert_eq!(plan.events[3].kind, FaultKind::LinkDegrade { fraction: 0.25 });
    }

    #[test]
    fn bad_specs_are_rejected() {
        for spec in [
            "explode@1.0",
            "fail@-1.0",
            "fail@nan",
            "stall@1.0",
            "stall@1.0:0",
            "link@1.0:0.0",
            "link@1.0:1.5",
            "link@1.0",
            "corrupt:1.5",
            "fail@1.0:x3",
        ] {
            assert!(FaultPlan::parse(spec, 0).is_err(), "{spec:?} should fail");
        }
    }

    #[test]
    fn unpinned_targets_are_seeded_and_stable() {
        let plan = FaultPlan::parse("fail@1.0,die@2.0", 123).unwrap();
        let t0 = plan.target_of(0, 8);
        let t1 = plan.target_of(1, 8);
        assert!(t0 < 8 && t1 < 8);
        // Same seed, same answer, no matter how often we ask.
        assert_eq!(t0, plan.target_of(0, 8));
        // Exactly one replica sees each event.
        let holders: Vec<usize> = (0..8)
            .filter(|&r| !plan.for_replica(r, 8, 1.0).is_empty())
            .collect();
        assert!(!holders.is_empty() && holders.len() <= 2);
    }

    #[test]
    fn link_faults_land_on_every_replica() {
        let plan = FaultPlan::parse("link@1.0:0.5", 0).unwrap();
        for r in 0..4 {
            let view = plan.for_replica(r, 4, 1.0);
            assert_eq!(view.events.len(), 1);
            assert_eq!(view.events[0].cycle, 1_000_000_000);
            assert_eq!(view.events[0].kind, FaultKind::LinkDegrade { fraction: 0.5 });
        }
    }

    #[test]
    fn link_fraction_tracks_the_last_event() {
        let plan = FaultPlan::parse("link@1.0:0.5,link@2.0:0.25", 0).unwrap();
        assert_eq!(plan.link_fraction_at(0.5), 1.0);
        assert_eq!(plan.link_fraction_at(1.5), 0.5);
        assert_eq!(plan.link_fraction_at(2.5), 0.25);
    }

    #[test]
    fn corruption_draws_are_deterministic_and_roughly_calibrated() {
        let plan = FaultPlan::parse("corrupt:0.3", 99).unwrap();
        let hits = (0..10_000)
            .filter(|&id| plan.migration_corrupted(id, 1))
            .count();
        // Seeded Bernoulli(0.3) over 10k draws: comfortably within +-5%.
        assert!((2500..=3500).contains(&hits), "hits = {hits}");
        for id in 0..64 {
            assert_eq!(
                plan.migration_corrupted(id, 1),
                plan.migration_corrupted(id, 1)
            );
        }
        assert!(!FaultPlan::off().migration_corrupted(0, 1));
    }

    #[test]
    fn replica_views_sort_by_cycle() {
        let plan = FaultPlan::parse("stall@2.0:100:r0,stall@1.0:50:r0", 0).unwrap();
        let view = plan.for_replica(0, 2, 1.0);
        assert_eq!(view.events.len(), 2);
        assert!(view.events[0].cycle <= view.events[1].cycle);
        assert_eq!(view.events[0].kind, FaultKind::ReplicaStall { cycles: 50 });
    }
}
