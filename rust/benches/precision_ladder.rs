//! Precision ladder: the PR-10 headline claim — decoupling the KV-cache
//! format from the compute format buys residency, not speed, and costs
//! nothing when unused.
//!
//! One engine serves a KV-pressured open-loop trace twice at an
//! *identical* byte budget and die count: FP16 compute / FP16 KV
//! (uniform) vs FP16 compute / FP8 KV (`--kv-format fp8`). The narrow
//! cache carves twice the pages from the same pool, so more requests
//! stay resident and fewer get preempted; the kernels still price at
//! FP16 either way, so decode throughput moves only through scheduling.
//!
//! Claims defended here:
//!
//! 1. **Residency.** FP8 KV strictly reduces preemptions and strictly
//!    raises batch occupancy on the pressured trace.
//! 2. **No compute regression.** Decode tokens/s stays within noise
//!    (±10%) of the uniform run — the dequant tax is bounded by the
//!    residency win.
//! 3. **Degenerate bit-identity.** Spelling the policy out
//!    (`--kv-format fp16` on an FP16 engine, empty ladder) replays the
//!    legacy run byte-for-byte (`same_outcome`).
//!
//! Short mode (`BENCH_SMOKE=1`) serves 96 requests instead of 384; with
//! `BENCH_JSON_DIR` set the results land in `BENCH_precision.json`
//! (the FP8-KV preemption ratio and decode-throughput ratio are
//! trend-tracked).

mod common;

use snitch_fm::arch::FpFormat;
use snitch_fm::arch::PlatformConfig;
use snitch_fm::coordinator::{
    BatcherConfig, ContinuousBatcher, Request, ServeReport, Workload,
};
use snitch_fm::model::ModelConfig;

const SEED: u64 = 0x9C1AD;

fn main() {
    let cfg = ModelConfig::tiny();
    let platform = PlatformConfig::occamy();
    let fmt = FpFormat::Fp16;
    let n = if common::smoke() { 96 } else { 384 };
    let workload = Workload::synthetic(SEED, n, (16, 96), (16, 64))
        .with_poisson_arrivals(SEED ^ 0x1AD, 2_000.0);

    // The pool holds ~6 worst-case FP16 caches against 16 batch slots:
    // tight enough that the uniform run preempts, roomy enough that
    // everything completes.
    let budget = Request::new(0, 96, 64).kv_bytes_at(&cfg, fmt) * 6;
    let mut uniform = BatcherConfig::new(16, budget);
    uniform.page_tokens = 16;
    uniform.prefill_chunk = 32;
    let mut narrow = uniform;
    narrow.kv_format = Some(FpFormat::Fp8);

    let run = |opts: BatcherConfig| -> ServeReport {
        ContinuousBatcher::new(&cfg, &platform, fmt, opts).run(&workload)
    };
    let (t_uniform, base) = common::time_median(3, || run(uniform));
    let (t_narrow, fp8kv) = common::time_median(3, || run(narrow));

    common::header(
        "precision ladder",
        "FP16 compute, FP16 vs FP8 KV cache at an identical byte budget",
    );
    println!(
        "{n} requests, {} gen tokens, {budget} B KV pool ({} vs {} pages)",
        workload.total_gen_tokens(),
        base.total_pages,
        fp8kv.total_pages
    );
    for (label, r) in [("fp16 kv", &base), ("fp8  kv", &fp8kv)] {
        println!(
            "{label}: {:>8.1} decode tok/s  occupancy {:>5.2}  preemptions {:>4}  \
             TTFT p99 {:.4}",
            r.decode_tokens_per_s, r.avg_batch_occupancy, r.preemptions, r.ttft_p99_s
        );
    }
    common::report_timing("precision-fp16kv", t_uniform);
    common::report_timing("precision-fp8kv", t_narrow);

    // Claim 1: residency strictly improves at the same byte budget.
    assert_eq!(base.completed, n, "uniform run must serve the whole trace");
    assert_eq!(fp8kv.completed, n, "fp8-kv run must serve the whole trace");
    assert_eq!(base.kv_budget_bytes, fp8kv.kv_budget_bytes);
    assert!(
        base.preemptions > 0,
        "the trace must pressure the uniform pool ({} preemptions)",
        base.preemptions
    );
    assert!(
        fp8kv.preemptions < base.preemptions,
        "fp8 KV must preempt strictly less: {} vs {}",
        fp8kv.preemptions,
        base.preemptions
    );
    assert!(
        fp8kv.avg_batch_occupancy > base.avg_batch_occupancy,
        "fp8 KV must keep more requests resident: {:.3} vs {:.3}",
        fp8kv.avg_batch_occupancy,
        base.avg_batch_occupancy
    );

    // Claim 2: decode throughput stays within noise of the uniform run.
    let decode_ratio = fp8kv.decode_tokens_per_s / base.decode_tokens_per_s;
    assert!(
        decode_ratio > 0.90,
        "fp8 KV decode throughput regressed past noise: ratio {decode_ratio:.4}"
    );

    // Claim 3: the spelled-out degenerate policy is bit-identical.
    let mut spelled = uniform;
    spelled.kv_format = Some(fmt);
    let replay = run(spelled);
    assert!(
        replay.same_outcome(&base),
        "--kv-format fp16 on an fp16 engine must be bit-identical"
    );
    println!(
        "degenerate policy bit-identical; preemption ratio {:.3}, decode ratio {:.4}",
        fp8kv.preemptions as f64 / base.preemptions as f64,
        decode_ratio
    );

    common::write_bench_json(
        "precision",
        &format!(
            "{{\"requests\":{n},\"kv_budget_bytes\":{budget},\
             \"fp16_kv\":{{\"decode_tokens_per_s\":{},\"preemptions\":{},\
             \"avg_batch_occupancy\":{}}},\
             \"fp8_kv\":{{\"decode_tokens_per_s\":{},\"preemptions\":{},\
             \"avg_batch_occupancy\":{}}},\
             \"preemption_ratio\":{},\"decode_throughput_ratio\":{}}}",
            base.decode_tokens_per_s,
            base.preemptions,
            base.avg_batch_occupancy,
            fp8kv.decode_tokens_per_s,
            fp8kv.preemptions,
            fp8kv.avg_batch_occupancy,
            fp8kv.preemptions as f64 / base.preemptions.max(1) as f64,
            decode_ratio,
        ),
    );
}
