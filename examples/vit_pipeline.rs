//! Batched ViT classification pipeline (the paper's encoder workload).
//!
//! The coordinator prices a stream of classification requests across the
//! precision ladder and the three ViT variants, reporting the images/s,
//! utilization and energy-per-image the platform would deliver — the
//! numbers behind Fig. 8 and the H100 comparison of Sec. VII-E. The tiny
//! encoder artifact additionally runs through PJRT to prove the numeric
//! path composes with the same block topology.
//!
//! Run: `cargo run --release --example vit_pipeline` (after `make artifacts`).

use anyhow::Result;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::ModelConfig;
use snitch_fm::report;
use snitch_fm::runtime::Runtime;

const BATCH: usize = 64;

fn main() -> Result<()> {
    // Numeric sanity of the encoder block path.
    let mut rt = Runtime::new()?;
    rt.run_golden("vit_block_tiny", 1e-3)?;
    println!("encoder block numerics OK (vit_block_tiny via PJRT)\n");

    let engine = InferenceEngine::new(PlatformConfig::occamy());
    let models = [ModelConfig::vit_b(), ModelConfig::vit_l(), ModelConfig::vit_h()];

    let mut rows = Vec::new();
    for m in &models {
        for fmt in FpFormat::LADDER {
            rows.push(engine.run_nar(m, m.seq, fmt));
        }
    }
    println!("per-image metrics (one classification per model pass):");
    print!("{}", report::runs_table(&rows));

    // Batched pipeline: images are independent so the coordinator streams
    // them back-to-back; throughput is per-image latency amortized.
    println!("\nbatch of {BATCH} images, FP8:");
    for m in &models {
        let r = engine.run_nar(m, m.seq, FpFormat::Fp8);
        let batch_seconds = r.seconds * BATCH as f64;
        let energy_j = r.power_w * batch_seconds;
        println!(
            "  {:<6} {:>8.1} images/s  {:>7.2} s/batch  {:>7.2} J/batch  {:>6.1} mJ/image",
            m.name,
            r.throughput,
            batch_seconds,
            energy_j,
            energy_j / BATCH as f64 * 1e3,
        );
    }
    println!("\npaper reference (Fig. 8, FP8): 26 / 12 / 8 images/s for B/L/H");
    Ok(())
}
