//! Prefix-cache + token-budget serving bench — the PR-3 acceptance sweep.
//!
//! Three claims defended here:
//!
//! 1. On a shared-prefix trace (groups of requests behind common system
//!    prompts, arriving open-loop), prefix caching ON strictly improves
//!    p99 TTFT *and* aggregate tokens/s over OFF — the serving analogue
//!    of the paper's redundant-HBM-traffic elimination.
//! 2. `--no-prefix-cache` with chunked prefill keeps the PR-2 scheduler:
//!    with no shared content the ON and OFF paths price the same trace
//!    to the cycle, and the OFF path is exactly reproducible run to run.
//!    (The one scheduling refinement over PR 2 — the priority order is
//!    computed once per iteration, making aging iteration-atomic — is
//!    inert on this trace.)
//! 3. With memoized layer pricing and token-budget mixed passes, a
//!    50k-request open-loop Poisson trace completes inside the CI
//!    bench-smoke job (it runs in *both* smoke and full modes — making
//!    that scale tractable is the point of the memo).
//!
//! `BENCH_SMOKE=1` shrinks the comparison sweeps; with `BENCH_JSON_DIR`
//! set the results land in `BENCH_prefix_cache.json` for the CI trend
//! comparison.

mod common;

use std::time::Instant;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, InferenceEngine, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::report;

/// Chat traffic behind shared system prompts: groups of `fanout` requests
/// share a `prefix`-token template, user turns are short, arrivals are
/// open-loop and slow enough that group leaders usually finish their
/// template prefill before the followers show up.
fn shared_prefix_trace(n: usize, prefix: u64, fanout: usize, rate: f64) -> Workload {
    Workload::synthetic(11, n, (48, 160), (8, 24))
        .with_shared_prefix(prefix, fanout)
        .with_poisson_arrivals(13, rate)
}

fn main() {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let gpt = ModelConfig::gpt_j();
    let fmt = FpFormat::Fp8;
    let n = if common::smoke() { 16 } else { 48 };
    let mut json = Vec::new();

    // ---- Claim 1: prefix cache ON strictly beats OFF on shared prefixes.
    let w = shared_prefix_trace(n, 2048, 8, 0.5);
    let on = BatcherConfig::new(8, 0);
    let mut off = on;
    off.prefix_cache = false;
    let (t, (r_on, r_off)) = common::time_median(3, || {
        (e.serve_with(&gpt, &w, on, fmt), e.serve_with(&gpt, &w, off, fmt))
    });
    common::header(
        "prefix cache",
        "GPT-J FP8, 2048-token shared system prompts, fanout 8, poisson 0.5/s",
    );
    println!(
        "{:<10} {:>10} {:>9} {:>9} {:>12} {:>9}",
        "config", "tokens/s", "ttftP50", "ttftP99", "hit tokens", "hit rate"
    );
    for (label, r) in [("cache off", &r_off), ("cache on", &r_on)] {
        println!(
            "{label:<10} {:>10.2} {:>9.3} {:>9.3} {:>12} {:>8.1}%",
            r.tokens_per_s,
            r.ttft_p50_s,
            r.ttft_p99_s,
            r.prefix_hit_tokens,
            r.prefix_hit_rate * 100.0,
        );
    }
    common::report_timing("prefix-cache-on-off", t);
    assert_eq!(r_on.completed, n);
    assert_eq!(r_off.completed, n);
    assert_eq!(r_on.gen_tokens, r_off.gen_tokens, "same service delivered");
    assert!(r_on.prefix_hit_tokens > 0, "shared prefixes must hit");
    assert!(
        r_on.ttft_p99_s < r_off.ttft_p99_s,
        "prefix cache must strictly improve p99 TTFT: {} !< {}",
        r_on.ttft_p99_s,
        r_off.ttft_p99_s
    );
    assert!(
        r_on.tokens_per_s > r_off.tokens_per_s,
        "prefix cache must strictly improve aggregate tokens/s: {} !> {}",
        r_on.tokens_per_s,
        r_off.tokens_per_s
    );
    json.push(format!(
        "{{\"config\":\"shared-prefix cache-on\",\"report\":{}}}",
        report::serve_json(&r_on)
    ));
    json.push(format!(
        "{{\"config\":\"shared-prefix cache-off\",\"report\":{}}}",
        report::serve_json(&r_off)
    ));

    // ---- Claim 2: --no-prefix-cache + --prefill-chunk == the PR-2 path.
    // With unique prompt content the cache can never hit, so the ON path
    // must price the identical trace to the cycle — and the OFF (PR-2)
    // path must be exactly reproducible.
    let w2 = Workload::synthetic(7, n, (256, 1024), (32, 128))
        .with_poisson_arrivals(3, 1.0);
    let mut chunked_off = BatcherConfig::new(8, 0);
    chunked_off.prefill_chunk = 256;
    chunked_off.prefix_cache = false;
    let mut chunked_on = chunked_off;
    chunked_on.prefix_cache = true;
    let a = e.serve_with(&gpt, &w2, chunked_off, fmt);
    let b = e.serve_with(&gpt, &w2, chunked_off, fmt);
    let c = e.serve_with(&gpt, &w2, chunked_on, fmt);
    assert_eq!(a.total_cycles, b.total_cycles, "PR-2 path must be deterministic");
    assert_eq!(a.ttft_p99_s, b.ttft_p99_s);
    assert_eq!(c.prefix_hit_tokens, 0, "unique content cannot hit");
    assert_eq!(
        a.total_cycles, c.total_cycles,
        "cache without hits must not change the trace"
    );
    assert_eq!(a.prefill_tokens, c.prefill_tokens);
    assert_eq!(a.prefill_chunks, c.prefill_chunks);
    assert_eq!(a.ttft_p99_s, c.ttft_p99_s);
    assert_eq!(a.tokens_per_s, c.tokens_per_s);
    println!(
        "\nno-prefix-cache + prefill-chunk keeps the PR-2 scheduler: \
         deterministic and cycle-identical to the cache-on no-hit path \
         ({} cycles)",
        a.total_cycles
    );

    // ---- Claim 3: 50k-request open-loop trace, tractable via the memo.
    let n_big = 50_000;
    let big = Workload::synthetic(3, n_big, (16, 48), (4, 12))
        .with_shared_prefix(64, 16)
        .with_poisson_arrivals(17, 5000.0);
    let tiny = ModelConfig::tiny();
    let mut opts = BatcherConfig::new(64, 0);
    opts.token_budget = 256;
    opts.prefill_chunk = 64;
    let wall = Instant::now();
    let r = e.serve_with(&tiny, &big, opts, FpFormat::Fp32);
    let wall_s = wall.elapsed().as_secs_f64();
    common::header("50k trace", "tiny FP32, poisson 5k/s, token budget 256");
    println!(
        "completed {}/{} in {wall_s:.2} s wall ({:.1} sim-s): {:.0} tokens/s, \
         hit rate {:.1}%, memo hit {:.2}%, budget fill {:.1}%",
        r.completed,
        n_big,
        r.total_seconds,
        r.tokens_per_s,
        r.prefix_hit_rate * 100.0,
        r.pricing_cache_hit_rate * 100.0,
        r.budget_utilization * 100.0,
    );
    assert_eq!(r.completed, n_big, "50k-request trace must fully drain");
    assert_eq!(r.gen_tokens, big.total_gen_tokens());
    assert!(
        r.pricing_cache_hit_rate > 0.9,
        "the memo must absorb the pricing hot path, got {}",
        r.pricing_cache_hit_rate
    );
    common::report_timing("serve-50k-requests", wall_s);
    json.push(format!(
        "{{\"config\":\"50k-open-loop\",\"wall_seconds\":{wall_s},\"report\":{}}}",
        report::serve_json(&r)
    ));

    common::write_bench_json("prefix_cache", &format!("[{}]", json.join(",")));
}
