//! Paged KV-cache allocation (vLLM-style PagedAttention bookkeeping).
//!
//! The PR-1 batcher reserved every request's *full-length* KV cache
//! (prompt + all tokens it may ever generate) at admission, so the HBM
//! budget was exhausted by reservations that mostly sat empty during
//! decode. This module carves the KV budget into fixed-size pages of
//! `page_tokens` tokens each; a request holds a [`PageTable`] of pages
//! covering exactly the tokens it has materialized so far, grows it
//! on demand one decode token at a time, and returns every page on
//! retirement (or preemption).
//!
//! The allocator is pure bookkeeping — the timing model prices KV traffic
//! through the kernel costs — but its invariants are the serving
//! scheduler's safety argument: pages are never double-allocated, bytes
//! in use never exceed the budget, and a drained allocator is whole again.

use crate::arch::{FpFormat, PlatformConfig};
use crate::coordinator::kv_cache::KvCache;
use crate::model::ModelConfig;

/// HBM bytes left for KV caches once the model weights are resident at
/// the serving precision — zero when the weights alone exceed capacity
/// (the serve path then rejects everything rather than pretending).
/// Single source of the budget formula for `InferenceEngine` and
/// `ContinuousBatcher`.
pub fn platform_kv_budget_bytes(
    cfg: &ModelConfig,
    fmt: FpFormat,
    platform: &PlatformConfig,
) -> u64 {
    platform.interconnect.hbm_capacity_bytes.saturating_sub(cfg.weight_bytes(fmt))
}

/// Geometry of one request's KV footprint: bytes per cached token (across
/// all transformer blocks, K + V, at the serving precision) and the page
/// granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvGeometry {
    /// KV bytes one token occupies across every block (K and V).
    pub token_bytes: u64,
    /// Tokens per page (fixed allocation granularity).
    pub page_tokens: u64,
}

impl KvGeometry {
    /// Geometry for `cfg` served at `fmt`, consistent with
    /// [`KvCache::bytes_for`] scaled to the serving element size (the same
    /// accounting `Request::kv_bytes_at` uses).
    pub fn new(cfg: &ModelConfig, fmt: FpFormat, page_tokens: u64) -> KvGeometry {
        let f32_token =
            cfg.blocks * KvCache::bytes_for(cfg.heads as usize, 1, cfg.p as usize) as u64;
        KvGeometry {
            token_bytes: f32_token / std::mem::size_of::<f32>() as u64 * fmt.bytes(),
            page_tokens: page_tokens.max(1),
        }
    }

    /// Bytes one page occupies.
    pub fn page_bytes(&self) -> u64 {
        self.token_bytes * self.page_tokens
    }

    /// Pages needed to hold `tokens` cached tokens.
    pub fn pages_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.page_tokens)
    }
}

/// Per-request mapping from KV positions to allocated pages. Page `i`
/// holds tokens `[i * page_tokens, (i + 1) * page_tokens)` of the
/// request's cache.
#[derive(Debug, Clone, Default)]
pub struct PageTable {
    pages: Vec<u32>,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable::default()
    }

    /// Allocated pages, in position order.
    pub fn pages(&self) -> &[u32] {
        &self.pages
    }

    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// Tokens this table can hold.
    pub fn capacity_tokens(&self, geom: &KvGeometry) -> u64 {
        self.pages.len() as u64 * geom.page_tokens
    }
}

/// Fixed-pool page allocator over the HBM KV budget.
///
/// Pages are identified by dense `u32` ids; a never-yet-used id is handed
/// out from a cursor, retired pages go to a recycle stack. A page id is
/// therefore owned by at most one [`PageTable`] at any time (the no-double-
/// allocation invariant the property tests check from the outside).
#[derive(Debug, Clone)]
pub struct PagedKvAllocator {
    geom: KvGeometry,
    total_pages: u64,
    next_fresh: u32,
    recycled: Vec<u32>,
    in_use: u64,
    peak_in_use: u64,
}

impl PagedKvAllocator {
    /// Carve `budget_bytes` into pages of `geom.page_bytes()`.
    pub fn new(budget_bytes: u64, geom: KvGeometry) -> PagedKvAllocator {
        let total_pages =
            (budget_bytes / geom.page_bytes().max(1)).min(u32::MAX as u64);
        PagedKvAllocator {
            geom,
            total_pages,
            next_fresh: 0,
            recycled: Vec::new(),
            in_use: 0,
            peak_in_use: 0,
        }
    }

    pub fn geometry(&self) -> KvGeometry {
        self.geom
    }

    pub fn total_pages(&self) -> u64 {
        self.total_pages
    }

    pub fn free_pages(&self) -> u64 {
        self.total_pages - self.in_use
    }

    pub fn used_pages(&self) -> u64 {
        self.in_use
    }

    /// Bytes currently mapped (always <= the budget by construction).
    pub fn bytes_in_use(&self) -> u64 {
        self.in_use * self.geom.page_bytes()
    }

    /// High-water mark of mapped bytes over the allocator's lifetime.
    pub fn peak_bytes_in_use(&self) -> u64 {
        self.peak_in_use * self.geom.page_bytes()
    }

    /// Whether a request that will cache `tokens` tokens can *ever* be
    /// served from this pool (upfront-rejection check).
    pub fn fits_pool(&self, tokens: u64) -> bool {
        self.geom.pages_for(tokens) <= self.total_pages
    }

    /// Grow `table` until it holds at least `tokens` tokens. All-or-
    /// nothing: on failure the table is unchanged and `false` returns.
    pub fn try_grow(&mut self, table: &mut PageTable, tokens: u64) -> bool {
        let want = self.geom.pages_for(tokens);
        let have = table.pages.len() as u64;
        if want <= have {
            return true;
        }
        let need = want - have;
        if need > self.free_pages() {
            return false;
        }
        for _ in 0..need {
            let id = match self.recycled.pop() {
                Some(id) => id,
                None => {
                    let id = self.next_fresh;
                    self.next_fresh += 1;
                    id
                }
            };
            table.pages.push(id);
        }
        self.in_use += need;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        true
    }

    /// Return every page of `table` to the pool (retirement/preemption).
    pub fn release(&mut self, table: &mut PageTable) {
        self.in_use -= table.pages.len() as u64;
        self.recycled.append(&mut table.pages);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> KvGeometry {
        KvGeometry { token_bytes: 1024, page_tokens: 16 }
    }

    #[test]
    fn geometry_matches_request_accounting() {
        use crate::coordinator::workload::Request;
        let cfg = ModelConfig::tiny();
        for fmt in [FpFormat::Fp32, FpFormat::Fp8] {
            let g = KvGeometry::new(&cfg, fmt, 16);
            let r = Request::new(0, 48, 16);
            assert_eq!(g.token_bytes * r.kv_capacity(), r.kv_bytes_at(&cfg, fmt));
        }
    }

    #[test]
    fn pages_round_up() {
        let g = geom();
        assert_eq!(g.pages_for(0), 0);
        assert_eq!(g.pages_for(1), 1);
        assert_eq!(g.pages_for(16), 1);
        assert_eq!(g.pages_for(17), 2);
        assert_eq!(g.page_bytes(), 16 * 1024);
    }

    #[test]
    fn grow_is_incremental_and_all_or_nothing() {
        let mut a = PagedKvAllocator::new(4 * 16 * 1024, geom()); // 4 pages
        let mut t = PageTable::new();
        assert!(a.try_grow(&mut t, 17)); // 2 pages
        assert_eq!(t.len(), 2);
        assert_eq!(a.free_pages(), 2);
        assert!(a.try_grow(&mut t, 32)); // already covered
        assert_eq!(t.len(), 2);
        assert!(!a.try_grow(&mut t, 16 * 7)); // needs 5 more than exist
        assert_eq!(t.len(), 2, "failed grow must not partially allocate");
        assert!(a.try_grow(&mut t, 64));
        assert_eq!(a.free_pages(), 0);
    }

    #[test]
    fn release_returns_every_page() {
        let mut a = PagedKvAllocator::new(8 * 16 * 1024, geom());
        let mut t1 = PageTable::new();
        let mut t2 = PageTable::new();
        assert!(a.try_grow(&mut t1, 50));
        assert!(a.try_grow(&mut t2, 60));
        assert_eq!(a.used_pages(), 8);
        assert_eq!(a.peak_bytes_in_use(), 8 * 16 * 1024);
        a.release(&mut t1);
        a.release(&mut t2);
        assert_eq!(a.used_pages(), 0);
        assert_eq!(a.free_pages(), a.total_pages());
        assert!(t1.is_empty() && t2.is_empty());
        // Recycled pages are reusable.
        let mut t3 = PageTable::new();
        assert!(a.try_grow(&mut t3, 8 * 16));
        assert_eq!(a.free_pages(), 0);
    }

    #[test]
    fn pool_fit_check() {
        let a = PagedKvAllocator::new(4 * 16 * 1024, geom());
        assert!(a.fits_pool(64));
        assert!(!a.fits_pool(65));
    }
}
