//! DMA engine timing (paper Sec. IV-A, VI-B).
//!
//! Each cluster's ninth core drives a DMA unit with 1D and 2D transfer
//! support. Measured constants from the paper: 27 ns setup per transfer,
//! 88 ns HBM round-trip latency, 56 B/cycle sustained per-cluster HBM
//! bandwidth — i.e. a 115 ns static overhead before a main-memory transfer
//! streams. Cluster-to-cluster transfers skip the HBM latency and ride the
//! group crossbars instead.

use crate::arch::{MemLevel, PlatformConfig};

/// One DMA transfer request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    /// Payload size in bytes.
    pub bytes: u64,
    /// Where the remote end of the transfer lives.
    pub level: MemLevel,
    /// Rows for a 2D (strided) transfer; 1 for plain 1D.
    pub rows: u64,
    /// Direction: true when the cluster writes to the remote end.
    pub write: bool,
}

impl Transfer {
    /// 1D read of `bytes` from `level`.
    pub fn d1(bytes: u64, level: MemLevel) -> Transfer {
        Transfer { bytes, level, rows: 1, write: false }
    }

    /// 2D read: `rows` strided rows totalling `bytes`.
    pub fn d2(bytes: u64, rows: u64, level: MemLevel) -> Transfer {
        Transfer { bytes, level, rows: rows.max(1), write: false }
    }

    /// Mark this transfer as a write to the remote end.
    pub fn to_write(mut self) -> Transfer {
        self.write = true;
        self
    }
}

/// Per-cluster DMA timing model.
#[derive(Debug, Clone)]
pub struct DmaEngine {
    platform: PlatformConfig,
    /// Extra cycles per row of a 2D transfer (descriptor advance).
    pub row_overhead_cycles: u64,
    /// Contention divisor: how many clusters concurrently share the HBM
    /// (set by the multi-cluster engine; 1 = full per-cluster bandwidth).
    pub hbm_sharers: u64,
    /// HBM efficiency derate in (0, 1]. The AR/GEMV access pattern —
    /// short strided weight rows with zero reuse and a single token in
    /// flight — cannot saturate HBM the way blocked NAR GEMMs do; the
    /// paper measures <10% FPU utilization in AR mode (Table III).
    /// `gemv_cost` sets this to `InterconnectConfig::gemv_hbm_efficiency`
    /// (calibrated against Table III / Fig. 9 AR numbers); everything
    /// else leaves it at 1.0.
    pub hbm_derate: f64,
}

impl DmaEngine {
    pub fn new(platform: &PlatformConfig) -> DmaEngine {
        DmaEngine {
            platform: platform.clone(),
            row_overhead_cycles: 2,
            hbm_sharers: 1,
            hbm_derate: 1.0,
        }
    }

    /// Apply an HBM-efficiency derate (see `hbm_derate`).
    pub fn with_hbm_derate(mut self, derate: f64) -> DmaEngine {
        self.hbm_derate = derate.clamp(1e-3, 1.0);
        self
    }

    /// Set the number of clusters concurrently hammering HBM; effective
    /// per-cluster bandwidth is `min(per_cluster, aggregate / sharers)`.
    pub fn with_hbm_sharers(mut self, sharers: u64) -> DmaEngine {
        self.hbm_sharers = sharers.max(1);
        self
    }

    /// Effective bytes/cycle for a transfer at `level`.
    pub fn bytes_per_cycle(&self, level: MemLevel) -> f64 {
        let raw = self.platform.link_bytes_per_cycle(level);
        if level == MemLevel::Hbm {
            let aggregate =
                self.platform.interconnect.hbm_bw_gbps / self.platform.freq_ghz;
            raw.min(aggregate / self.hbm_sharers as f64) * self.hbm_derate
        } else {
            raw
        }
    }

    /// Static overhead cycles before `level`'s payload streams.
    pub fn static_cycles(&self, level: MemLevel) -> u64 {
        let ic = &self.platform.interconnect;
        let ns = match level {
            // Main memory: DMA setup + HBM round trip (115 ns).
            MemLevel::Hbm => ic.dma_setup_ns + ic.hbm_latency_ns,
            // On-chip: setup + a short crossbar traversal.
            MemLevel::PeerClusterSameGroup => ic.dma_setup_ns + 5.0,
            MemLevel::PeerClusterOtherGroup => ic.dma_setup_ns + 10.0,
            // SPM-to-SPM within the cluster: just the setup.
            MemLevel::Spm => ic.dma_setup_ns,
        };
        self.platform.ns_to_cycles(ns)
    }

    /// Total cycles for one transfer.
    pub fn transfer_cycles(&self, t: Transfer) -> u64 {
        if t.bytes == 0 {
            return 0;
        }
        let stream = (t.bytes as f64 / self.bytes_per_cycle(t.level)).ceil() as u64;
        self.static_cycles(t.level) + stream + (t.rows - 1) * self.row_overhead_cycles
    }

    /// Cycles for a batch of transfers issued back-to-back by the DMA core.
    pub fn batch_cycles(&self, ts: &[Transfer]) -> u64 {
        ts.iter().map(|&t| self.transfer_cycles(t)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dma() -> DmaEngine {
        DmaEngine::new(&PlatformConfig::occamy())
    }

    #[test]
    fn static_overhead_matches_paper() {
        // 115 ns at 1 GHz = 115 cycles before an HBM payload moves.
        assert_eq!(dma().static_cycles(MemLevel::Hbm), 115);
    }

    #[test]
    fn hbm_streaming_rate() {
        // 56 kB at 56 B/cycle = 1000 cycles + 115 static.
        let c = dma().transfer_cycles(Transfer::d1(56_000, MemLevel::Hbm));
        assert_eq!(c, 1115);
    }

    #[test]
    fn c2c_beats_hbm_for_small_tiles() {
        // The motivation for cluster-to-cluster transfers (Sec. V-B): a
        // tile bounced via HBM pays the round trip twice.
        let d = dma();
        let tile = 8 * 1024;
        let c2c = d.transfer_cycles(Transfer::d1(tile, MemLevel::PeerClusterSameGroup));
        let via_hbm = d.transfer_cycles(Transfer::d1(tile, MemLevel::Hbm)) * 2;
        assert!(c2c < via_hbm, "c2c {c2c} vs hbm bounce {via_hbm}");
    }

    #[test]
    fn contention_halves_bandwidth() {
        let alone = dma().transfer_cycles(Transfer::d1(1 << 20, MemLevel::Hbm));
        // 16 sharers: aggregate 410 B/cycle / 16 = 25.6 B/cycle < 56.
        let shared = dma()
            .with_hbm_sharers(16)
            .transfer_cycles(Transfer::d1(1 << 20, MemLevel::Hbm));
        assert!(shared > 2 * alone, "shared {shared} vs alone {alone}");
    }

    #[test]
    fn contention_caps_at_per_cluster_bw() {
        // Few sharers: per-cluster 56 B/cycle is the binding limit.
        let d4 = dma().with_hbm_sharers(4);
        assert_eq!(d4.bytes_per_cycle(MemLevel::Hbm), 56.0);
    }

    #[test]
    fn d2_rows_add_overhead() {
        let d = dma();
        let one = d.transfer_cycles(Transfer::d1(4096, MemLevel::Hbm));
        let many = d.transfer_cycles(Transfer::d2(4096, 64, MemLevel::Hbm));
        assert_eq!(many - one, 63 * d.row_overhead_cycles);
    }

    #[test]
    fn zero_bytes_free() {
        assert_eq!(dma().transfer_cycles(Transfer::d1(0, MemLevel::Hbm)), 0);
    }
}
