//! # snitch-fm
//!
//! Reproduction of *"Optimizing Foundation Model Inference on a
//! Many-tiny-core Open-source RISC-V Platform"* (Potocnik et al., 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time, `python/`)** — Pallas kernels
//!   (FlashAttention-2, tiled GEMM, LayerNorm, i-GELU) and JAX transformer
//!   blocks, AOT-lowered to HLO text artifacts.
//! * **Layer 3 (this crate)** — the inference coordinator: model graphs,
//!   tile planning, the cycle-level timing simulator standing in for the
//!   paper's RTL testbed, the energy model, and a PJRT runtime executing
//!   the HLO artifacts for real numerics. Python never runs at inference
//!   time.
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! mapping every paper table/figure to a bench target.

pub mod arch;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod parallel;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod soa;
pub mod tiling;
pub mod trace;
pub mod util;
