"""L2 correctness: model blocks vs the pure-jnp reference transformer.

Key invariant (paper Sec. II-B): AR decode must produce exactly the same
activations as the corresponding NAR/prefill row — the KV cache is a pure
latency optimization, never a numerical one.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

DIMS = M.TINY


def make_weights(dims, seed=0):
    rng = np.random.default_rng(seed)
    shapes = M.weight_shapes(dims)
    w = {}
    for name, _ in M.BLOCK_WEIGHT_SCHEMA:
        shape = shapes[name]
        if name in ("ln1_g", "ln2_g"):
            w[name] = (1.0 + 0.1 * rng.standard_normal(shape)).astype(np.float32)
        elif len(shape) == 1:
            w[name] = (0.1 * rng.standard_normal(shape)).astype(np.float32)
        else:
            w[name] = (rng.standard_normal(shape) / np.sqrt(shape[0])).astype(
                np.float32)
    return w


def wlist(w):
    return [w[name] for name, _ in M.BLOCK_WEIGHT_SCHEMA]


@pytest.fixture(scope="module")
def weights():
    return make_weights(DIMS)


@pytest.fixture(scope="module")
def x():
    rng = np.random.default_rng(42)
    return (0.5 * rng.standard_normal((DIMS.seq, DIMS.e))).astype(np.float32)


def test_vit_block_vs_ref(x, weights):
    (got,) = M.vit_block(x, *wlist(weights), dims=DIMS)
    want = ref.transformer_block(x, weights, DIMS.heads, causal=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gpt_nar_block_vs_ref(x, weights):
    got, k, v = M.gpt_block_nar(x, *wlist(weights), dims=DIMS)
    want = ref.transformer_block(x, weights, DIMS.heads, causal=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert k.shape == (DIMS.heads, DIMS.seq, DIMS.p)
    assert v.shape == (DIMS.heads, DIMS.seq, DIMS.p)


def test_gpt_nar_kv_matches_projections(x, weights):
    """Returned K/V must equal the plain projections of the LN'd input."""
    _, k, v = M.gpt_block_nar(x, *wlist(weights), dims=DIMS)
    h = ref.layernorm(x, weights["ln1_g"], weights["ln1_b"])
    want_k = ref.gemm(h, weights["wk"]).reshape(DIMS.seq, DIMS.heads, DIMS.p)
    want_v = ref.gemm(h, weights["wv"]).reshape(DIMS.seq, DIMS.heads, DIMS.p)
    np.testing.assert_allclose(k, want_k.transpose(1, 0, 2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(v, want_v.transpose(1, 0, 2), rtol=1e-4,
                               atol=1e-4)


def test_ar_decode_matches_nar(x, weights):
    """Prefill S-1 tokens, decode token S-1 autoregressively: the decoded
    activations must match the NAR row (the paper's KV-cache equivalence)."""
    smax = DIMS.seq + 8
    full, _, _ = M.gpt_block_nar(x, *wlist(weights), dims=DIMS)

    # Prefill on the first S-1 rows.
    prefix = x[:-1]
    _, k_pre, v_pre = M.gpt_block_nar(prefix, *wlist(weights), dims=DIMS)
    k_cache = np.zeros((DIMS.heads, smax, DIMS.p), np.float32)
    v_cache = np.zeros((DIMS.heads, smax, DIMS.p), np.float32)
    k_cache[:, : DIMS.seq - 1] = np.asarray(k_pre)
    v_cache[:, : DIMS.seq - 1] = np.asarray(v_pre)

    out, k2, v2 = M.gpt_block_ar(
        x[-1:], k_cache, v_cache, np.int32(DIMS.seq - 1),
        *wlist(weights), dims=DIMS)
    np.testing.assert_allclose(out[0], full[-1], rtol=1e-3, atol=1e-3)
    # Cache write-back lands at position S-1.
    h = ref.layernorm(x[-1:], weights["ln1_g"], weights["ln1_b"])
    want_k = ref.gemm(h, weights["wk"]).reshape(1, DIMS.heads, DIMS.p)
    np.testing.assert_allclose(np.asarray(k2)[:, DIMS.seq - 1],
                               want_k[0], rtol=1e-4, atol=1e-4)


def test_ar_ignores_garbage_beyond_kv_len(weights):
    """Cache slots >= kv_len+1 must not influence the output (masking)."""
    rng = np.random.default_rng(7)
    xt = (0.5 * rng.standard_normal((1, DIMS.e))).astype(np.float32)
    smax = 32
    kv_len = 10
    k_cache = (0.5 * rng.standard_normal(
        (DIMS.heads, smax, DIMS.p))).astype(np.float32)
    v_cache = (0.5 * rng.standard_normal(
        (DIMS.heads, smax, DIMS.p))).astype(np.float32)
    out1, _, _ = M.gpt_block_ar(xt, k_cache, v_cache, np.int32(kv_len),
                                *wlist(weights), dims=DIMS)
    k_cache2, v_cache2 = k_cache.copy(), v_cache.copy()
    k_cache2[:, kv_len + 1:] = 1e3   # poison the invalid tail
    v_cache2[:, kv_len + 1:] = -1e3
    out2, _, _ = M.gpt_block_ar(xt, k_cache2, v_cache2, np.int32(kv_len),
                                *wlist(weights), dims=DIMS)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_gpt_head(weights):
    rng = np.random.default_rng(3)
    xt = (0.5 * rng.standard_normal((1, DIMS.e))).astype(np.float32)
    ln_g = np.ones(DIMS.e, np.float32)
    ln_b = np.zeros(DIMS.e, np.float32)
    w_head = (rng.standard_normal((DIMS.e, 64)) /
              np.sqrt(DIMS.e)).astype(np.float32)
    (logits,) = M.gpt_head(xt, ln_g, ln_b, w_head)
    want = ref.gemm(ref.layernorm(xt, ln_g, ln_b), w_head)
    np.testing.assert_allclose(logits, want, rtol=1e-4, atol=1e-4)
    assert logits.shape == (1, 64)


def test_block_stack_stable(x, weights):
    """A deep stack of blocks must not blow up numerically (pre-LN)."""
    h = x
    for _ in range(6):
        (h,) = M.vit_block(h, *wlist(weights), dims=DIMS)
    assert np.isfinite(np.asarray(h)).all()


@pytest.mark.parametrize("preset,e,heads", [
    ("vit-b", 768, 12), ("vit-l", 1024, 16), ("vit-h", 1280, 16),
    ("gpt3-xl", 2048, 16), ("gpt-j", 4096, 16),
])
def test_table2_presets(preset, e, heads):
    dims = M.PRESETS[preset]
    assert dims.e == e and dims.heads == heads
    assert dims.hp == dims.heads * dims.p


def test_weight_shapes_cover_schema():
    shapes = M.weight_shapes(DIMS)
    assert set(shapes) == {n for n, _ in M.BLOCK_WEIGHT_SCHEMA}
    assert shapes["wq"] == (DIMS.e, DIMS.hp)
    assert shapes["w1"] == (DIMS.e, DIMS.ff)
