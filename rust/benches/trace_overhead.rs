//! Trace overhead: the PR-9 observability claim — arming the
//! [`TraceRecorder`] is passive (bit-identical schedule) and cheap
//! (enabled-mode wall-clock inside a fixed bound of the untraced run).
//!
//! One engine serves an open-loop Poisson trace twice: plain
//! (`ContinuousBatcher::run`) and traced (`run_traced` with a
//! deliberately aggressive 200 µs gauge cadence). Claims defended:
//!
//! 1. **Passivity.** The traced [`ServeReport`] is byte-identical to the
//!    plain one — not merely `same_outcome`, full equality.
//! 2. **Bounded overhead.** The traced median wall-clock stays under
//!    `OVERHEAD_BOUND`× the plain median (plus a small absolute slack so
//!    sub-millisecond smoke runs can't fail on timer noise).
//! 3. **The record is complete.** Busy + stall + idle spans tile the
//!    makespan exactly, and the Chrome export is non-trivial.
//!
//! Short mode (`BENCH_SMOKE=1`) serves 120 requests instead of 480; with
//! `BENCH_JSON_DIR` set the results land in `BENCH_trace_overhead.json`
//! (`trace_overhead_ratio` is trend-tracked with its own noise floor).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, ContinuousBatcher, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::trace::{FleetTrace, TraceSettings};

const SEED: u64 = 0x7C0DE;
/// Enabled-mode budget: the traced run's median wall-clock must stay
/// under this multiple of the plain run's.
const OVERHEAD_BOUND: f64 = 1.50;
/// Absolute slack absorbing scheduler/timer noise on short smoke runs.
const SLACK_S: f64 = 0.005;

fn main() {
    let cfg = ModelConfig::tiny();
    let fmt = FpFormat::Fp8;
    let platform = PlatformConfig::occamy();
    let n = if common::smoke() { 120 } else { 480 };
    let workload = Workload::synthetic(SEED, n, (16, 96), (8, 32))
        .with_poisson_arrivals(SEED ^ 0x11, 2_000.0);
    let mut opts = BatcherConfig::new(8, 0);
    opts.prefill_chunk = 32;
    let settings = TraceSettings { metrics_interval_us: 200.0 };

    let (t_plain, plain) = common::time_median(5, || {
        ContinuousBatcher::new(&cfg, &platform, fmt, opts).run(&workload)
    });
    let (t_traced, (traced, rec)) = common::time_median(5, || {
        ContinuousBatcher::new(&cfg, &platform, fmt, opts).run_traced(&workload, &settings)
    });

    // Passivity: full equality, stronger than `same_outcome`.
    assert_eq!(plain, traced, "tracing must not perturb the schedule");

    // Completeness: the span record tiles the makespan with no gaps.
    let total = rec.total_cycles().expect("sealed recorder");
    assert_eq!(total, traced.total_cycles);
    let acct = rec.track_accounting();
    assert_eq!(
        acct.busy + acct.stall + acct.idle,
        total,
        "busy+stall+idle spans must tile the makespan"
    );
    assert_eq!(acct.busy, traced.work.cycles);
    let passes = rec.passes().len();
    let gauges = rec.gauges().len();
    let requests = rec.requests().len();
    assert!(passes > 0 && gauges > 0 && requests >= n);

    let fleet = FleetTrace::single("replica 0", rec);
    let json = fleet.to_chrome_json();
    assert!(json.starts_with("{\"traceEvents\":["), "Chrome export shape");

    common::header(
        "trace overhead",
        "continuous batcher, recorder armed vs off, 200 us gauge cadence",
    );
    println!(
        "{n} requests, {} passes, {} gauge samples, {} lifecycle spans, \
         {:.1} KiB Chrome JSON",
        passes,
        gauges,
        requests,
        json.len() as f64 / 1024.0
    );
    common::report_timing("trace-off", t_plain);
    common::report_timing("trace-on", t_traced);
    let ratio = t_traced / t_plain.max(1e-9);
    println!("trace overhead ratio: {ratio:.3}x (bound {OVERHEAD_BOUND}x)");
    assert!(
        t_traced <= t_plain * OVERHEAD_BOUND + SLACK_S,
        "enabled-mode overhead blew the bound: {:.3} ms traced vs {:.3} ms \
         plain ({ratio:.3}x > {OVERHEAD_BOUND}x)",
        t_traced * 1e3,
        t_plain * 1e3
    );

    common::write_bench_json(
        "trace_overhead",
        &format!(
            "{{\"requests\":{n},\"trace_overhead_ratio\":{ratio},\
             \"plain_ms\":{},\"traced_ms\":{},\"passes\":{passes},\
             \"gauge_samples\":{gauges},\"chrome_json_bytes\":{},\
             \"tokens_per_s\":{}}}",
            t_plain * 1e3,
            t_traced * 1e3,
            json.len(),
            traced.tokens_per_s,
        ),
    );
}
