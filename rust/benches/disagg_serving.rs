//! Disaggregated prefill/decode serving: the PR-7 headline claim.
//!
//! On a mixed trace — one third long-prefill summarization requests
//! (prompt 256–512, gen 4–8) interleaved with chatty decode-heavy
//! requests (prompt 16–32, gen 64–128) under open-loop Poisson arrivals
//! — a symmetric fleet suffers at the tail: whenever a 512-token prefill
//! pass lands on a die, every co-resident chatty request's next token
//! waits behind it, inflating p99 TPOT. Splitting the same dies into
//! dedicated prefill and decode stages isolates the decode pace: the
//! decode dies never run a prefill pass (each prompt's KV pages arrive
//! pre-migrated over the die-to-die links), so their inter-token gaps
//! stay uniform.
//!
//! Claims defended here:
//!
//! 1. **Tail isolation.** The best prefill/decode split of 4 dies beats
//!    the 4-replica symmetric fleet on p99 TPOT for this trace, at equal
//!    die count, with every migration priced on the die-to-die link.
//! 2. **`--disagg off` is inert.** The symmetric path PR 7 leaves behind
//!    is bit-identical to the PR-6 engine: event vs legacy core
//!    `same_outcome` on this trace, and the `--no-per-request` opt-out
//!    changes only the per-request payload, never the schedule.
//!
//! Short mode (`BENCH_SMOKE=1`) serves 240 requests instead of 960; with
//! `BENCH_JSON_DIR` set the results land in `BENCH_disagg.json`
//! (tpot_p99_ratio / split_tpot_p99_s are trend-tracked).

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, ContinuousBatcher, EngineMode, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::parallel::{
    rank_fleet_splits, serve_disaggregated, serve_replicated, RoutePolicy,
};

const SEED: u64 = 0xD15A66;
const DIES: usize = 4;

/// One third long-prefill requests interleaved with chatty decode-heavy
/// requests, Poisson arrivals. Deterministic from `SEED`.
fn mixed_trace(n: usize, rate_per_s: f64) -> Workload {
    let long = Workload::synthetic(SEED, n, (256, 512), (4, 8));
    let chat = Workload::synthetic(SEED ^ 0xC4A7, n, (16, 32), (64, 128));
    let requests = (0..n)
        .map(|id| {
            let mut r = if id % 3 == 0 {
                long.requests[id].clone()
            } else {
                chat.requests[id].clone()
            };
            r.id = id;
            r
        })
        .collect();
    Workload { requests }.with_poisson_arrivals(SEED, rate_per_s)
}

fn main() {
    let cfg = ModelConfig::tiny();
    let fmt = FpFormat::Fp8;
    let platform = PlatformConfig::with_dies(DIES as u32);
    let n = if common::smoke() { 240 } else { 960 };
    let rate = 3_000.0;
    let workload = mixed_trace(n, rate);
    let opts = BatcherConfig::new(8, 0);
    let policy = RoutePolicy::JoinShortestQueue;

    // ---- Part 1: split fleet vs symmetric fleet at equal dies ----
    let (t_sym, sym) = common::time_median(3, || {
        serve_replicated(&cfg, &platform, fmt, opts, &workload, DIES, policy)
    });
    assert_eq!(sym.merged.completed, n, "symmetric fleet must serve the whole trace");

    let ranking = rank_fleet_splits(&cfg, fmt, &platform, &workload, opts.max_batch, DIES);
    let modeled = ranking.splits.first().expect("4 dies admit at least one split");

    let mut best = None;
    let mut t_best = 0.0;
    for prefill in 1..DIES {
        let decode = DIES - prefill;
        let (t, r) = common::time_median(3, || {
            serve_disaggregated(&cfg, &platform, fmt, opts, &workload, prefill, decode, policy)
        });
        assert_eq!(r.completed, n, "split {prefill}:{decode} must serve the whole trace");
        assert_eq!(r.migrations, n as u64, "every generating request hands off once");
        assert_eq!(r.decode.kv_imports, n as u64);
        assert_eq!(
            r.decode.prefill_tokens, 0,
            "decode dies must never run a prefill pass"
        );
        assert!(r.migrated_kv_bytes > 0 && r.migration_cycles > 0);
        let better = match &best {
            None => true,
            Some((b, _)) => r.tpot_p99_s < b.tpot_p99_s,
        };
        if better {
            best = Some((r, prefill));
            t_best = t;
        }
    }
    let (split, split_prefill) = best.expect("at least one split evaluated");

    common::header(
        "disagg serving",
        "mixed long-prefill/chatty trace: prefill/decode split vs symmetric, 4 dies",
    );
    println!(
        "{n} requests, {} prompt tokens, {} gen tokens, {rate:.0} req/s offered",
        workload.total_prompt_tokens(),
        workload.total_gen_tokens()
    );
    println!(
        "symmetric {DIES}x1: TPOT p50 {:.6} p99 {:.6}  TTFT p99 {:.4}",
        sym.merged.tpot_p50_s, sym.merged.tpot_p99_s, sym.merged.ttft_p99_s
    );
    println!(
        "split {}p+{}d:    TPOT p50 {:.6} p99 {:.6}  TTFT p99 {:.4}  \
         ({} migrations, {:.1} MiB over d2d links)",
        split.prefill_replicas,
        split.decode_replicas,
        split.tpot_p50_s,
        split.tpot_p99_s,
        split.ttft_p99_s,
        split.migrations,
        split.migrated_kv_bytes as f64 / (1 << 20) as f64
    );
    println!(
        "planner pick: {}p+{}d ({}-bound, {:.1} req/s modeled); measured best: {}p+{}d",
        modeled.prefill,
        modeled.decode,
        modeled.bottleneck,
        modeled.rate,
        split_prefill,
        DIES - split_prefill
    );
    common::report_timing("disagg-split", t_best);
    common::report_timing("disagg-symmetric", t_sym);

    let ratio = split.tpot_p99_s / sym.merged.tpot_p99_s;
    assert!(
        split.tpot_p99_s < sym.merged.tpot_p99_s,
        "the split fleet must beat the symmetric fleet on p99 TPOT at equal dies: \
         split {:.6}s vs symmetric {:.6}s",
        split.tpot_p99_s,
        sym.merged.tpot_p99_s
    );
    println!("p99 TPOT ratio (split/symmetric): {ratio:.3}");

    // ---- Part 2: the `--disagg off` path is bit-identical to PR 6 ----
    // (a) The event core still reproduces the legacy loop on this trace.
    let mut ev_opts = opts;
    ev_opts.engine = EngineMode::Event;
    let mut it_opts = opts;
    it_opts.engine = EngineMode::Iteration;
    let ev = ContinuousBatcher::new(&cfg, &platform, fmt, ev_opts).run(&workload);
    let it = ContinuousBatcher::new(&cfg, &platform, fmt, it_opts).run(&workload);
    assert!(
        ev.same_outcome(&it),
        "disagg off: event core must reproduce the legacy loop bit-for-bit"
    );
    // (b) The symmetric fleet is deterministic across runs.
    let again = serve_replicated(&cfg, &platform, fmt, opts, &workload, DIES, policy);
    assert!(
        again.merged.same_outcome(&sym.merged),
        "symmetric serving must be deterministic"
    );
    // (c) `--no-per-request` drops only the per-request payload.
    let mut lean_opts = opts;
    lean_opts.per_request = false;
    let mut lean =
        serve_replicated(&cfg, &platform, fmt, lean_opts, &workload, DIES, policy).merged;
    assert!(lean.per_request.is_empty(), "opt-out must empty the per-request vec");
    lean.per_request = sym.merged.per_request.clone();
    assert!(
        lean.same_outcome(&sym.merged),
        "--no-per-request must change aggregates and schedule in no way"
    );
    println!("disagg off: event==legacy, deterministic, per-request opt-out inert");

    common::write_bench_json(
        "disagg",
        &format!(
            "{{\"requests\":{n},\"dies\":{DIES},\"split_prefill\":{},\
             \"split_decode\":{},\"split_tpot_p99_s\":{},\"symmetric_tpot_p99_s\":{},\
             \"tpot_p99_ratio\":{ratio},\"split_ttft_p99_s\":{},\
             \"symmetric_ttft_p99_s\":{},\"migrations\":{},\"migrated_kv_bytes\":{},\
             \"migration_cycles\":{}}}",
            split.prefill_replicas,
            split.decode_replicas,
            split.tpot_p99_s,
            sym.merged.tpot_p99_s,
            split.ttft_p99_s,
            sym.merged.ttft_p99_s,
            split.migrations,
            split.migrated_kv_bytes,
            split.migration_cycles,
        ),
    );
}
