//! Timing models of the FM kernel library (paper Sec. V).
//!
//! Each function mirrors one kernel of the paper's software library and
//! returns a [`crate::sim::KernelCost`]: the same tile schedule the Pallas
//! artifacts express with BlockSpecs, priced by the cycle model in
//! [`crate::sim`]. The coordinator composes these into per-layer and
//! per-model costs; the benches regenerate the paper's figures from them.

pub mod flash_attention;
pub mod gelu;
pub mod gemm;
pub mod layernorm;
pub mod softmax;
pub mod tree_reduce;

pub use flash_attention::flash_attention_cost;
pub use gelu::gelu_cost;
pub use gemm::{gemm_cost, gemv_cost};
pub use layernorm::layernorm_cost;
pub use softmax::softmax_cost;
pub use tree_reduce::{fused_concat_linear_cost, unfused_concat_linear_cost};
