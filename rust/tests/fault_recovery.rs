//! Fault-injection and recovery suite: zero-fault bit-identity against
//! the PR-7 engine, byte-identical reruns at a fixed `--fault-seed`, and
//! a randomized-fault `same_outcome` sweep asserting the fleet never
//! loses or duplicates a request no matter where the faults land.

mod common;

use common::Rng;
use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, EngineMode, FaultPlan, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::parallel::{
    serve_disaggregated, serve_disaggregated_with_faults, serve_replicated,
    serve_replicated_with_faults, RoutePolicy, RouterReport,
};

fn trace(seed: u64, n: usize) -> Workload {
    Workload::synthetic(seed, n, (16, 96), (4, 16)).with_poisson_arrivals(seed ^ 0x9E37, 2_000.0)
}

/// Every request offered to the fleet retires exactly once: the merged
/// per-request ids plus the rejected ids reproduce `0..n` with no gaps
/// and no duplicates.
fn assert_conserved(fleet: &RouterReport, n: usize) {
    assert_eq!(fleet.merged.requests, n);
    assert_eq!(fleet.merged.completed + fleet.merged.rejected.len(), n);
    let mut ids: Vec<usize> = fleet.merged.per_request.iter().map(|s| s.id).collect();
    ids.extend(fleet.merged.rejected.iter().copied());
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "request set not conserved");
    let f = fleet.merged.degraded_capacity_fraction;
    assert!((0.0..=1.0).contains(&f), "capacity fraction out of range: {f}");
}

#[test]
fn faults_off_replicated_is_bit_identical_to_pr7() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(2);
    let w = trace(11, 24).with_shared_prefix(32, 4);
    let opts = BatcherConfig::new(4, 0);
    let plain = serve_replicated(&cfg, &p, FpFormat::Fp32, opts, &w, 2, RoutePolicy::PrefixAffinity);
    for plan in [FaultPlan::off(), FaultPlan::parse("off", 7).unwrap()] {
        assert!(plan.is_off());
        let armed = serve_replicated_with_faults(
            &cfg,
            &p,
            FpFormat::Fp32,
            opts,
            &w,
            2,
            RoutePolicy::PrefixAffinity,
            &plan,
        );
        assert_eq!(armed.assigned, plain.assigned);
        assert!(armed.merged.same_outcome(&plain.merged), "--faults off must be inert");
        for (a, b) in armed.per_replica.iter().zip(&plain.per_replica) {
            assert!(a.same_outcome(b));
        }
    }
}

#[test]
fn faults_off_disagg_is_bit_identical_to_pr7() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(4);
    let w = trace(5, 20);
    let opts = BatcherConfig::new(4, 0);
    let plain =
        serve_disaggregated(&cfg, &p, FpFormat::Fp32, opts, &w, 2, 2, RoutePolicy::JoinShortestQueue);
    let armed = serve_disaggregated_with_faults(
        &cfg,
        &p,
        FpFormat::Fp32,
        opts,
        &w,
        2,
        2,
        RoutePolicy::JoinShortestQueue,
        &FaultPlan::off(),
    );
    assert_eq!(armed, plain, "--faults off disagg must be bit-identical");
    assert_eq!(armed.migration_retries, 0);
    assert_eq!(armed.recompute_fallbacks, 0);
    assert_eq!(armed.degraded_capacity_fraction, 0.0);
}

#[test]
fn fault_spec_grammar_accepts_the_documented_forms_and_rejects_junk() {
    for spec in [
        "off",
        "",
        "fail@0.5",
        "die@1.25:r2",
        "stall@0.1:50000",
        "stall@0.1:50000:r1",
        "link@0.2:0.5",
        "corrupt:0.25",
        "fail@0.5:r0,link@1:0.25,corrupt:0.1",
    ] {
        assert!(FaultPlan::parse(spec, 3).is_ok(), "spec {spec:?} must parse");
    }
    for spec in ["fail", "stall@1", "link@1:0", "link@1:1.5", "corrupt:2", "explode@1"] {
        assert!(FaultPlan::parse(spec, 3).is_err(), "spec {spec:?} must be rejected");
    }
}

#[test]
fn identical_fault_seeds_reproduce_byte_identical_reports() {
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(3);
    let w = trace(23, 30);
    let opts = BatcherConfig::new(4, 0);
    // Unpinned targets: the victim of each event is drawn from the seed.
    let spec = "fail@0.002,stall@0.001:80000,link@0.003:0.5";
    let a_plan = FaultPlan::parse(spec, 42).unwrap();
    let b_plan = FaultPlan::parse(spec, 42).unwrap();
    let a = serve_replicated_with_faults(
        &cfg, &p, FpFormat::Fp32, opts, &w, 3, RoutePolicy::JoinShortestQueue, &a_plan,
    );
    let b = serve_replicated_with_faults(
        &cfg, &p, FpFormat::Fp32, opts, &w, 3, RoutePolicy::JoinShortestQueue, &b_plan,
    );
    assert_eq!(a.assigned, b.assigned);
    assert!(a.merged.same_outcome(&b.merged), "fixed seed must replay byte-identically");
    assert_eq!(a.merged.warnings, b.merged.warnings);
    for (x, y) in a.per_replica.iter().zip(&b.per_replica) {
        assert!(x.same_outcome(y));
    }

    let d_plan = FaultPlan::parse("fail@0.004,corrupt:0.5", 9).unwrap();
    let d1 = serve_disaggregated_with_faults(
        &cfg, &p, FpFormat::Fp32, opts, &w, 1, 2, RoutePolicy::JoinShortestQueue, &d_plan,
    );
    let d2 = serve_disaggregated_with_faults(
        &cfg, &p, FpFormat::Fp32, opts, &w, 1, 2, RoutePolicy::JoinShortestQueue, &d_plan,
    );
    assert_eq!(d1, d2, "disagg fault replay must be byte-identical");
}

#[test]
fn randomized_fault_plans_conserve_and_replay_deterministically() {
    let cfg = ModelConfig::tiny();
    let mut rng = Rng(0xFA17);
    for case in 0..30 {
        let replicas = rng.next(2, 4) as usize;
        let n = rng.next(8, 24) as usize;
        let p = PlatformConfig::with_dies(replicas as u32);
        let w = trace(rng.next(1, 1 << 20), n);
        let opts = BatcherConfig::new(rng.next(2, 6) as usize, 0);
        // 1-3 random events; times span "immediately" through "past the
        // end of the trace" (trailing events must stay inert).
        let mut parts = Vec::new();
        for _ in 0..rng.next(1, 3) {
            let at = rng.next(0, 80) as f64 / 4e3; // 0 .. 0.02 s
            match rng.next(0, 3) {
                0 => parts.push(format!("fail@{at}")),
                1 => parts.push(format!("die@{at}:r{}", rng.next(0, 5))),
                2 => parts.push(format!("stall@{at}:{}", rng.next(1, 200_000))),
                _ => parts.push(format!("link@{at}:0.{}", rng.next(2, 9))),
            }
        }
        let spec = parts.join(",");
        let plan = FaultPlan::parse(&spec, rng.next(0, u64::MAX - 1)).unwrap();
        let policy = rng.pick(&[RoutePolicy::JoinShortestQueue, RoutePolicy::PrefixAffinity]);
        let a = serve_replicated_with_faults(&cfg, &p, FpFormat::Fp32, opts, &w, replicas, policy, &plan);
        assert_conserved(&a, n);
        let b = serve_replicated_with_faults(&cfg, &p, FpFormat::Fp32, opts, &w, replicas, policy, &plan);
        assert!(
            a.merged.same_outcome(&b.merged),
            "case {case} ({spec}): replay must be byte-identical"
        );
    }
}

#[test]
fn event_and_iteration_cores_agree_under_faults() {
    // Fault events are first-class in both engine cores; the schedules
    // they produce under an armed plan must stay bit-identical, exactly
    // as they do fault-free.
    let cfg = ModelConfig::tiny();
    let p = PlatformConfig::with_dies(2);
    let w = trace(31, 20);
    let plan = FaultPlan::parse("stall@0.001:60000:r0,fail@0.003:r1", 1).unwrap();
    let mut ev = BatcherConfig::new(4, 0);
    ev.engine = EngineMode::Event;
    let mut it = BatcherConfig::new(4, 0);
    it.engine = EngineMode::Iteration;
    let a = serve_replicated_with_faults(
        &cfg, &p, FpFormat::Fp32, ev, &w, 2, RoutePolicy::JoinShortestQueue, &plan,
    );
    let b = serve_replicated_with_faults(
        &cfg, &p, FpFormat::Fp32, it, &w, 2, RoutePolicy::JoinShortestQueue, &plan,
    );
    assert!(a.merged.same_outcome(&b.merged), "engine cores must agree under faults");
    assert_eq!(a.merged.replica_failures, 1);
    assert!(a.merged.stall_cycles >= 60_000);
    assert_conserved(&a, 20);
}
