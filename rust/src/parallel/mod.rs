//! Multi-die parallelism subsystem.
//!
//! The paper's platform is explicitly hierarchical — clusters, groups and
//! a die-to-die "wide" interconnect with dedicated DMA engines (Sec.
//! IV-B) — but everything below this module prices a model onto ONE die.
//! This subsystem makes parallelism across dies a first-class citizen:
//!
//! * [`collectives`] — prices all-reduce / reduce-scatter / all-gather /
//!   point-to-point pipeline sends over the die-to-die links, with ring
//!   and binary-tree algorithms (the tree reuses the Sec. V-B reduction
//!   schedule via [`crate::sim::noc::pair_schedule`]) and a
//!   DMA-engine-contention model.
//! * [`shard`] — [`shard::ShardPlan`]`{ tp, pp, replicas }` and the
//!   sharded block/model pricing built on
//!   [`crate::model::block_layers_sharded`]: column/row-split GEMMs with
//!   the induced all-reduce per block, per-stage pipeline cuts with
//!   activation-send costs, and the per-replica KV budget shrink from
//!   splitting KV heads across TP ranks.
//! * [`planner`] — enumerates the legal plans for a platform's die count
//!   and ranks them by modeled per-token latency or aggregate tokens/s
//!   (the `snitch-fm shard` subcommand).
//! * [`router`] — a data-parallel serving router: N engine replicas each
//!   running the existing continuous batcher against its own KV budget,
//!   with join-shortest-queue and prefix-affinity request routing and a
//!   merged [`crate::coordinator::ServeReport`]. Its
//!   [`router::serve_disaggregated`] entry splits the fleet into
//!   dedicated prefill and decode dies, migrating each finished prompt's
//!   KV pages over the die-to-die links (priced with
//!   [`collectives::p2p_cost`]).
//!
//! The degenerate plan `tp = 1, pp = 1, replicas = 1` prices and
//! schedules bit-identically to the single-engine paths, so the whole
//! subsystem is testable against the existing baselines. The CLI flags
//! and JSON schema this subsystem feeds are documented in
//! `docs/serving.md`.

#![warn(missing_docs)]

pub mod collectives;
pub mod planner;
pub mod router;
pub mod shard;

pub use collectives::{
    all_gather_cost, all_reduce_cost, degrade_link, p2p_cost, reduce_scatter_cost, Algorithm,
};
pub use planner::{
    best_plans, best_plans_policy, disagg_split_feasible, enumerate_plans, rank_fleet_splits,
    rank_fleet_splits_policy, FleetSplit, Objective, RankedPlan, SplitRanking,
};
pub use router::{
    merge_reports, replica_seed, serve_disaggregated, serve_disaggregated_traced,
    serve_disaggregated_with_faults, serve_replicated, serve_replicated_traced,
    serve_replicated_with_faults, DisaggReport, RoutePolicy, RouterReport,
};
pub use shard::{
    plan_cost, plan_pass_cost, plan_pass_cost_policy, sharded_block_cost, PlanCost, ShardPlan,
    ShardedPass,
};
