//! Table IV — comparison with SoA accelerators on the GPT NAR pass in
//! FP16 (SoA numbers: Emani et al.'s GPT2-XL training-forward study),
//! plus the Sec. VII-E H100 / AccelTran / Tambe comparisons.
//! Paper headline: 70.6% FPU utilization, 2.04x above the best SoA
//! (Gaudi2), 0.0056 TFLOPS/CU.

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::ModelConfig;
use snitch_fm::soa;

fn main() {
    common::header("Table IV", "SoA comparison, GPT NAR FP16");
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let (t, r) =
        common::time_median(5, || e.run_nar(&ModelConfig::gpt3_xl(), 1024, FpFormat::Fp16));
    let ours = soa::OursRow::from_run(r.gflops, r.fpu_utilization, e.platform.total_cores());
    println!("{:<10} {:>8} {:>9} {:>12} {:>8}", "platform", "CUs", "TFLOPS", "TFLOPS/CU", "util%");
    for s in soa::table4_soa() {
        println!(
            "{:<10} {:>8} {:>9.2} {:>12.4} {:>8.1}",
            s.name, s.compute_units, s.tflops, s.tflops_per_cu, s.fpu_utilization_pct
        );
    }
    println!(
        "{:<10} {:>8} {:>9.2} {:>12.4} {:>8.1}   (paper ours: 0.72 / 0.0056 / 70.6)",
        "ours", ours.compute_units, ours.tflops, ours.tflops_per_cu, ours.fpu_utilization_pct
    );
    println!(
        "utilization advantage over best SoA: {:.2}x (paper: 2.04x)\n",
        ours.utilization_advantage()
    );
    common::report_timing("table4-ours-row", t);

    // --- H100 ViT-L FP8 (Sec. VII-E) -----------------------------------
    let rv = e.run_nar(&ModelConfig::vit_l(), 197, FpFormat::Fp8);
    let h = soa::h100_vit_l_fp8();
    println!(
        "H100 ViT-L FP8: {:.2}/CU {:.1}/W | ours: {:.3}/CU {:.2}/W (paper ours: 0.2/CU, 6/W at its claimed 27 samples/s)",
        h.samples_per_s_per_cu,
        h.samples_per_s_per_w,
        rv.throughput / e.platform.total_cores() as f64,
        rv.throughput / rv.power_w
    );

    // --- academic accelerators ------------------------------------------
    let rj = e.run_nar(&ModelConfig::gpt_j(), 1024, FpFormat::Fp8);
    let w_per_pe = rj.power_w / e.platform.total_cores() as f64;
    println!(
        "AccelTran {:.2} W/PE vs ours {:.3} W/PE ({:.1}x; paper: 6.3x)",
        soa::acceltran().watts_per_pe.unwrap(),
        w_per_pe,
        soa::acceltran().watts_per_pe.unwrap() / w_per_pe
    );
    let rb = e.run_nar(&ModelConfig::vit_b(), 197, FpFormat::Fp8);
    println!(
        "Tambe et al. 489 ms vs ours {:.1} ms ({:.1}x; paper: 12.8x at 38 ms)",
        rb.seconds * 1e3,
        489.0 / (rb.seconds * 1e3)
    );
}
