//! Serving-scheduler sweep: paged KV vs full reservation, chunked vs
//! monolithic prefill, priority classes, open-loop Poisson arrivals.
//!
//! The headline claim this bench defends: on a mixed workload where a
//! long batch-class prompt shares the system with short interactive
//! requests, chunked prefill cuts the interactive p99 TTFT against the
//! monolithic-prefill FCFS configuration, and paged KV admits more
//! concurrent work than full-length reservation from the same HBM budget.
//!
//! Short mode (`BENCH_SMOKE=1`) shrinks the request count for CI; with
//! `BENCH_JSON_DIR` set the sweep is written to `BENCH_serve_scheduler.json`.

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::{BatcherConfig, InferenceEngine, Request, Workload};
use snitch_fm::model::ModelConfig;
use snitch_fm::report;

/// Mixed serving trace: one long batch-ingest prompt (prefill-only,
/// patient class) offered at t=0, plus `n` short interactive requests
/// arriving open-loop at `rate_per_s`. The rate keeps the interactive
/// side underloaded and the arrivals inside the long prompt's prefill
/// window — the regime where monolithic prefill visibly blocks TTFT.
fn mixed_workload(n: usize, rate_per_s: f64) -> Workload {
    let mut w = Workload::synthetic(42, n, (64, 160), (16, 32))
        .with_priority_classes(2)
        .with_poisson_arrivals(7, rate_per_s);
    w.requests.push(Request::new(n, 2048, 0).with_class(1));
    w
}

fn main() {
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::gpt_j();
    let n = if common::smoke() { 8 } else { 32 };
    let w = mixed_workload(n, 1.0);

    let sweep: Vec<(&str, BatcherConfig)> = [
        ("reserve-full fcfs", {
            let mut o = BatcherConfig::new(8, 0);
            o.reserve_full = true;
            o
        }),
        ("paged", BatcherConfig::new(8, 0)),
        ("paged+chunk512", {
            let mut o = BatcherConfig::new(8, 0);
            o.prefill_chunk = 512;
            o
        }),
        ("paged+chunk256", {
            let mut o = BatcherConfig::new(8, 0);
            o.prefill_chunk = 256;
            o
        }),
        ("paged+chunk128", {
            let mut o = BatcherConfig::new(8, 0);
            o.prefill_chunk = 128;
            o
        }),
    ]
    .into_iter()
    .collect();

    let (t, rows) = common::time_median(3, || {
        sweep
            .iter()
            .map(|(label, opts)| (*label, e.serve_with(&cfg, &w, *opts, FpFormat::Fp8)))
            .collect::<Vec<_>>()
    });

    common::header(
        "serve scheduler",
        "GPT-J FP8, long batch prompt + short interactive poisson traffic",
    );
    println!(
        "{:<20} {:>10} {:>7} {:>9} {:>9} {:>9} {:>6} {:>7}",
        "config", "tokens/s", "occup", "ttftP50", "ttftP99", "queueP99", "evict", "chunks"
    );
    for (label, r) in &rows {
        println!(
            "{label:<20} {:>10.1} {:>7.2} {:>9.4} {:>9.4} {:>9.4} {:>6} {:>7}",
            r.tokens_per_s,
            r.avg_batch_occupancy,
            r.ttft_p50_s,
            r.ttft_p99_s,
            r.queue_p99_s,
            r.preemptions,
            r.prefill_chunks,
        );
    }
    common::report_timing("serve-scheduler-sweep", t);

    let monolithic = &rows[1].1;
    let chunked = &rows[3].1;
    assert_eq!(monolithic.completed, n + 1);
    assert_eq!(chunked.completed, n + 1);
    assert!(
        chunked.ttft_p99_s < monolithic.ttft_p99_s,
        "chunked prefill must cut interactive p99 TTFT: {} !< {}",
        chunked.ttft_p99_s,
        monolithic.ttft_p99_s
    );

    // Page-size sensitivity at the chunked operating point.
    println!();
    common::header("page size", "KV page granularity sweep (chunk 256)");
    for page_tokens in [8u64, 16, 64, 256] {
        let mut opts = BatcherConfig::new(8, 0);
        opts.prefill_chunk = 256;
        opts.page_tokens = page_tokens;
        let r = e.serve_with(&cfg, &w, opts, FpFormat::Fp8);
        println!(
            "page {page_tokens:>4} tokens: {:>8} pages, peak {:>6.2} GB, {:>8.1} tokens/s",
            r.total_pages,
            r.peak_kv_bytes as f64 / 1e9,
            r.tokens_per_s
        );
        assert!(r.peak_kv_bytes <= r.kv_budget_bytes);
    }

    let json: Vec<String> = rows
        .iter()
        .map(|(label, r)| {
            format!("{{\"config\":\"{label}\",\"report\":{}}}", report::serve_json(r))
        })
        .collect();
    common::write_bench_json("serve_scheduler", &format!("[{}]", json.join(",")));
}
