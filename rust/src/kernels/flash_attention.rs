//! FlashAttention-2 timing model (paper Sec. V-A2, Fig. 6).
//!
//! Heads map spatially to clusters (temporal when H > C·G); each cluster
//! iterates the FA-2 KV-tile loop with SPM-resident running statistics.
//! The online softmax runs in FP32 in every precision variant, with
//! pack/unpack conversions at the QKᵀ output and before the A·V GEMM for
//! sub-32-bit formats — the reason the FA-2 share of the latency grows at
//! FP8 (Fig. 10).

use crate::arch::{FpFormat, MemLevel, PlatformConfig};
use crate::sim::cluster::{ClusterSim, TilePhase};
use crate::sim::core::{opcost, CoreModel};
use crate::sim::dma::Transfer;
use crate::sim::{KernelCost, MultiClusterSim};
use crate::tiling::plan_flash_attention;

/// Cost of multi-head FA-2: `heads` heads of `sq x skv` attention with
/// projection dim `p`. `causal` halves the score work (GPT masking).
/// Q/K/V are read from HBM; the per-head output tiles stay SPM-resident
/// for the fused concat+linear that follows (Sec. V-B).
pub fn flash_attention_cost(
    heads: u64,
    sq: u64,
    skv: u64,
    p: u64,
    fmt: FpFormat,
    causal: bool,
    platform: &PlatformConfig,
) -> KernelCost {
    if heads == 0 || sq == 0 || skv == 0 || p == 0 {
        return KernelCost::default();
    }
    let plan = plan_flash_attention(heads, sq, skv, p, fmt, platform);
    let core = CoreModel::new(platform.cluster, platform.features);
    let cores = platform.cluster.compute_cores;
    let el = fmt.bytes();
    let active = heads.min(platform.total_clusters() as u64).max(1);

    // Causal masking skips ~half the KV tiles on average.
    let kv_steps_effective = if causal && sq == skv {
        (plan.kv_steps + 1).div_ceil(2).max(1)
    } else {
        plan.kv_steps
    };

    // One kv-step phase shape (edge tiles priced as full tiles; grouped
    // for the §Perf fast path — see ClusterSim::run_grouped).
    let (bq, bkv) = (plan.bq, plan.bkv);
    let rows_per_core = bq.div_ceil(cores);
    let make = |kv_first: bool, kv_last: bool| -> TilePhase {
        // s = Q Kᵀ tile: bq x bkv dots of length p (io precision,
        // widening accumulation).
        let mut compute = core.row_dots_cycles(rows_per_core, bkv, p, fmt);
        // Online softmax on the fp32 island: row max, exp, row sum,
        // rescale of acc — all per bq x bkv elements, scalar FP32 exp.
        let elems = rows_per_core * bkv;
        compute += core.elementwise_cycles(elems, opcost::SIMPLE, FpFormat::Fp32, true); // max
        compute += core.elementwise_cycles(elems, opcost::EXP, FpFormat::Fp32, false); // exp
        compute += core.elementwise_cycles(elems, opcost::SIMPLE, FpFormat::Fp32, true); // sum
        if fmt.needs_fp32_conversion() {
            // unpack s to fp32 + repack probabilities to io format
            compute += 2 * core.elementwise_cycles(elems, opcost::CONVERT, fmt, true);
        }
        // acc rescale (bq x p fp32 FMAs) + P·V tile GEMM:
        compute +=
            core.elementwise_cycles(rows_per_core * p, opcost::SIMPLE, FpFormat::Fp32, true);
        compute += core.row_dots_cycles(rows_per_core, p, bkv, fmt);
        if kv_last {
            // Final normalize: bq x p divisions in fp32; the output tile
            // stays in SPM for the fused concat+linear.
            compute +=
                core.elementwise_cycles(rows_per_core * p, opcost::DIV, FpFormat::Fp32, false);
        }
        let flops = 2 * bq * bkv * p  // QK^T
            + 5 * bq * bkv            // softmax update
            + 2 * bq * bkv * p        // PV
            + 2 * bq * p; // rescale
        let mut phase = TilePhase::compute(compute, flops);
        // K and V tiles stream from HBM each kv step.
        phase = phase
            .with_transfer(Transfer::d2(bkv * p * el, bkv, MemLevel::Hbm))
            .with_transfer(Transfer::d2(bkv * p * el, bkv, MemLevel::Hbm));
        if kv_first {
            // Q tile loaded once per q step.
            phase = phase.with_transfer(Transfer::d2(bq * p * el, bq, MemLevel::Hbm));
        }
        phase
    };
    let per_q = kv_steps_effective;
    let reps = plan.heads * plan.q_steps; // (head, q-tile) pairs per cluster
    let kv_first = 1u64;
    let kv_last = if per_q > 1 { 1 } else { 0 };
    let kv_mid = per_q - kv_first - kv_last;
    let mut groups = Vec::with_capacity(3);
    for (first, last, count) in [
        (true, per_q == 1, kv_first * reps),
        (false, false, kv_mid * reps),
        (false, true, kv_last * reps),
    ] {
        if count > 0 {
            groups.push((make(first, last), count));
        }
    }

    let csim = ClusterSim::new(platform).with_hbm_sharers(active);
    let one = csim.run_grouped(&groups);
    let sim = MultiClusterSim::new(platform);
    let per: Vec<KernelCost> = (0..active).map(|_| one).collect();
    sim.parallel(&per)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::{gemm_cost, OperandHome};

    fn occ() -> PlatformConfig {
        PlatformConfig::occamy()
    }

    #[test]
    fn causal_cheaper_than_full() {
        let full = flash_attention_cost(16, 1024, 1024, 128, FpFormat::Fp32, false, &occ());
        let causal = flash_attention_cost(16, 1024, 1024, 128, FpFormat::Fp32, true, &occ());
        assert!(causal.cycles < full.cycles);
        assert!(causal.cycles * 3 > full.cycles, "should be ~half, not free");
    }

    #[test]
    fn fa_flops_scale_quadratically_in_s() {
        let a = flash_attention_cost(16, 512, 512, 128, FpFormat::Fp32, false, &occ());
        let b = flash_attention_cost(16, 1024, 1024, 128, FpFormat::Fp32, false, &occ());
        let ratio = b.flops as f64 / a.flops as f64;
        assert!((3.8..=4.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fp8_speedup_damped_by_fp32_softmax() {
        // FP32 -> FP8 is 4x on pure GEMM lanes but less on FA-2 because
        // the exp/conversions stay FP32 (paper Sec. VII-C).
        let f32c = flash_attention_cost(16, 1024, 1024, 128, FpFormat::Fp32, true, &occ());
        let f8c = flash_attention_cost(16, 1024, 1024, 128, FpFormat::Fp8, true, &occ());
        let fa_speedup = f32c.cycles as f64 / f8c.cycles as f64;
        let g32 = gemm_cost(1024, 1024, 1024, FpFormat::Fp32, &occ(), OperandHome::default());
        let g8 = gemm_cost(1024, 1024, 1024, FpFormat::Fp8, &occ(), OperandHome::default());
        let gemm_speedup = g32.cycles as f64 / g8.cycles as f64;
        assert!(fa_speedup > 1.0, "fa {fa_speedup}");
        assert!(fa_speedup < gemm_speedup, "fa {fa_speedup} vs gemm {gemm_speedup}");
    }

    #[test]
    fn heads_scale_across_clusters() {
        // 16 heads on 16 clusters vs 4 clusters: about 4x faster.
        let c16 = flash_attention_cost(16, 512, 512, 64, FpFormat::Fp32, false, &occ());
        let four = PlatformConfig::with_clusters(4);
        let c4 = flash_attention_cost(16, 512, 512, 64, FpFormat::Fp32, false, &four);
        let ratio = c4.cycles as f64 / c16.cycles as f64;
        assert!((2.0..=5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn decode_shape_single_query() {
        // AR decode: one query, long history — must be cheap & memory-heavy.
        let c = flash_attention_cost(16, 1, 1024, 128, FpFormat::Fp32, true, &occ());
        assert!(c.cycles > 0);
        assert!(c.hbm_read_bytes >= 16 * 1024 * 128 * 4 * 2); // K+V per head
    }

    #[test]
    fn zero_work_free() {
        assert_eq!(
            flash_attention_cost(0, 1024, 1024, 64, FpFormat::Fp32, false, &occ()).cycles,
            0
        );
    }
}
