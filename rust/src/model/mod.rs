//! Foundation-model definitions (paper Table II) and their layer graphs.
//!
//! `ModelConfig` carries the Table-II hyperparameters; `graph` expands one
//! transformer block into the kernel sequence the coordinator prices and
//! (for the tiny variants) executes through the PJRT artifacts.

pub mod graph;

pub use graph::{
    block_layers, block_layers_batched, block_layers_decode, block_layers_mixed,
    block_layers_mixed_sharded, block_layers_sharded, Layer, LayerKind, ShardedBlock,
};

use crate::arch::FpFormat;

/// Encoder-only (ViT) vs decoder-only (GPT) family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Vit,
    Gpt,
}

/// Execution mode for decoder-only models (paper Sec. VI-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Non-autoregressive / prompt encoding / training fwd: S tokens per
    /// pass, causal masking. (ViTs always run this way, non-causal.)
    Nar,
    /// Autoregressive generation: one token per pass against the KV cache.
    Ar,
}

/// One Table-II model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub family: Family,
    /// Transformer blocks.
    pub blocks: u64,
    /// Embedding dim E.
    pub e: u64,
    /// Per-head projection dim P.
    pub p: u64,
    /// Heads H.
    pub heads: u64,
    /// MLP hidden dim FF.
    pub ff: u64,
    /// Default sequence length S (ViT: fixed 197; GPT: sweep default 1024).
    pub seq: u64,
}

impl ModelConfig {
    pub fn vit_b() -> ModelConfig {
        Self::preset_cfg("vit-b", Family::Vit, 12, 768, 64, 12, 3072, 197)
    }
    pub fn vit_l() -> ModelConfig {
        Self::preset_cfg("vit-l", Family::Vit, 24, 1024, 64, 16, 4096, 197)
    }
    pub fn vit_h() -> ModelConfig {
        Self::preset_cfg("vit-h", Family::Vit, 32, 1280, 80, 16, 5120, 197)
    }
    pub fn gpt3_xl() -> ModelConfig {
        Self::preset_cfg("gpt3-xl", Family::Gpt, 40, 2048, 128, 16, 8192, 1024)
    }
    pub fn gpt_j() -> ModelConfig {
        Self::preset_cfg("gpt-j", Family::Gpt, 28, 4096, 256, 16, 16384, 1024)
    }
    /// Tiny stand-in matching the Python TINY preset (integration tests).
    pub fn tiny() -> ModelConfig {
        Self::preset_cfg("tiny", Family::Gpt, 2, 64, 16, 4, 128, 32)
    }

    #[allow(clippy::too_many_arguments)]
    fn preset_cfg(
        name: &str,
        family: Family,
        blocks: u64,
        e: u64,
        p: u64,
        heads: u64,
        ff: u64,
        seq: u64,
    ) -> ModelConfig {
        ModelConfig { name: name.into(), family, blocks, e, p, heads, ff, seq }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        match name {
            "vit-b" => Some(Self::vit_b()),
            "vit-l" => Some(Self::vit_l()),
            "vit-h" => Some(Self::vit_h()),
            "gpt3-xl" => Some(Self::gpt3_xl()),
            "gpt-j" => Some(Self::gpt_j()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// All five paper models.
    pub fn paper_models() -> Vec<ModelConfig> {
        vec![Self::vit_b(), Self::vit_l(), Self::vit_h(), Self::gpt3_xl(), Self::gpt_j()]
    }

    /// H * P.
    pub fn hp(&self) -> u64 {
        self.heads * self.p
    }

    /// Weight parameters of one block (attention + MLP, no embeddings).
    pub fn params_per_block(&self) -> u64 {
        let attn = 3 * self.e * self.hp() + self.hp() * self.e;
        let mlp = 2 * self.e * self.ff;
        let norms = 4 * self.e + self.ff + self.e; // gammas/betas/biases
        attn + mlp + norms
    }

    /// Total block parameters of the model.
    pub fn params(&self) -> u64 {
        self.blocks * self.params_per_block()
    }

    /// FLOPs of one block at sequence length `s` in `mode`.
    /// `kv_len` only matters in AR mode (attention against the cache).
    pub fn flops_per_block(&self, mode: Mode, s: u64, kv_len: u64) -> u64 {
        match mode {
            Mode::Nar => {
                let proj = 3 * 2 * s * self.e * self.hp() + 2 * s * self.hp() * self.e;
                // Causal attention for GPT halves the score work; ViT full.
                let att = if self.family == Family::Gpt {
                    2 * s * s * self.p * self.heads * 2 / 2
                } else {
                    2 * s * s * self.p * self.heads * 2
                };
                let mlp = 2 * s * self.e * self.ff * 2;
                let norms = 2 * 7 * s * self.e;
                proj + att + mlp + norms
            }
            Mode::Ar => {
                let proj = 3 * 2 * self.e * self.hp() + 2 * self.hp() * self.e;
                let att = 2 * kv_len * self.p * self.heads * 2;
                let mlp = 2 * self.e * self.ff * 2;
                let norms = 2 * 7 * self.e;
                proj + att + mlp + norms
            }
        }
    }

    /// End-to-end FLOPs for one forward pass.
    pub fn flops(&self, mode: Mode, s: u64, kv_len: u64) -> u64 {
        self.blocks * self.flops_per_block(mode, s, kv_len)
    }

    /// Model weight bytes at a given precision.
    pub fn weight_bytes(&self, fmt: FpFormat) -> u64 {
        self.params() * fmt.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_param_counts_roughly_match() {
        // Table II: ViT-B 86M, ViT-L 307M, ViT-H 632M, GPT3-XL 1.3B, GPT-J 6B.
        // We count block weights only (no embeddings/heads), so expect the
        // right order of magnitude and ranking.
        // Note: Table II itself is internally inconsistent for GPT3-XL —
        // 40 blocks x (E=2048, FF=8192) is ~2.0B block weights, not 1.3B
        // (GPT-3 XL 1.3B has 24 layers). We follow Table II's dims, so the
        // GPT3-XL bound is wide.
        let cases = [
            (ModelConfig::vit_b(), 86e6, 0.70, 1.3),
            (ModelConfig::vit_l(), 307e6, 0.70, 1.3),
            (ModelConfig::vit_h(), 632e6, 0.70, 1.3),
            (ModelConfig::gpt3_xl(), 1.3e9, 0.55, 1.65),
            (ModelConfig::gpt_j(), 6e9, 0.70, 1.3),
        ];
        for (m, paper, min_frac, max_frac) in cases {
            let got = m.params() as f64;
            assert!(
                got > min_frac * paper && got < max_frac * paper,
                "{}: {got:.2e} vs paper {paper:.2e}",
                m.name
            );
        }
    }

    #[test]
    fn nar_flops_quadratic_attention() {
        let m = ModelConfig::gpt_j();
        let f1 = m.flops_per_block(Mode::Nar, 1024, 0) as f64;
        let f2 = m.flops_per_block(Mode::Nar, 2048, 0) as f64;
        assert!(f2 / f1 > 2.0 && f2 / f1 < 4.0);
    }

    #[test]
    fn ar_flops_much_smaller_than_nar_per_token() {
        let m = ModelConfig::gpt_j();
        let nar_per_token = m.flops_per_block(Mode::Nar, 1024, 0) / 1024;
        let ar = m.flops_per_block(Mode::Ar, 1, 1024);
        // AR per-token ~= NAR per-token (same math) — the *rate* differs.
        let ratio = ar as f64 / nar_per_token as f64;
        assert!((0.5..=2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn presets_resolve() {
        for name in ["vit-b", "vit-l", "vit-h", "gpt3-xl", "gpt-j", "tiny"] {
            assert!(ModelConfig::preset(name).is_some(), "{name}");
        }
        assert!(ModelConfig::preset("nope").is_none());
    }

    #[test]
    fn gptj_weight_bytes() {
        let m = ModelConfig::gpt_j();
        // ~5.6B block params -> ~22 GB FP32, ~5.6 GB FP8.
        assert!(m.weight_bytes(FpFormat::Fp32) > 20_000_000_000);
        assert_eq!(m.weight_bytes(FpFormat::Fp8), m.params());
    }
}
