//! Table III — power, GFLOPS/W and FPU utilization on GPT-J S=1024 for
//! NAR and AR across the precision ladder. Paper: NAR 5.0/5.2/4.8/4.5 W,
//! 38.8/78.8/151/294 GFLOPS/W, 76.3/79.7/70.6/65.2% util; AR ~2.1 W,
//! 10/20.1/38.3/65.6 GFLOPS/W, 6.4-8.5% util.

mod common;

use snitch_fm::arch::{FpFormat, PlatformConfig};
use snitch_fm::coordinator::InferenceEngine;
use snitch_fm::model::{Mode, ModelConfig};

const PAPER: [(&str, FpFormat, f64, f64, f64); 8] = [
    ("NAR", FpFormat::Fp64, 5.0, 38.8, 76.3),
    ("NAR", FpFormat::Fp32, 5.2, 78.8, 79.7),
    ("NAR", FpFormat::Fp16, 4.8, 151.0, 70.6),
    ("NAR", FpFormat::Fp8, 4.5, 294.0, 65.2),
    ("AR", FpFormat::Fp64, 2.1, 10.0, 8.32),
    ("AR", FpFormat::Fp32, 2.2, 20.1, 8.46),
    ("AR", FpFormat::Fp16, 2.1, 38.3, 7.89),
    ("AR", FpFormat::Fp8, 2.0, 65.6, 6.39),
];

fn main() {
    common::header("Table III", "power & efficiency, GPT-J S=1024");
    let e = InferenceEngine::new(PlatformConfig::occamy());
    let cfg = ModelConfig::gpt_j();
    println!(
        "{:<5} {:<6} {:>8} {:>8} | {:>10} {:>10} | {:>8} {:>8}",
        "mode", "fmt", "P[W]", "paper", "GFLOPS/W", "paper", "util%", "paper"
    );
    let (t, _) = common::time_median(3, || {
        for (mode_name, fmt, p_w, p_eff, p_util) in PAPER {
            let mode = if mode_name == "NAR" { Mode::Nar } else { Mode::Ar };
            let r = match mode {
                Mode::Nar => e.run_nar(&cfg, 1024, fmt),
                Mode::Ar => e.run_ar_step(&cfg, 1024, fmt),
            };
            println!(
                "{:<5} {:<6} {:>8.2} {:>8.1} | {:>10.1} {:>10.1} | {:>8.2} {:>8.2}",
                mode_name,
                fmt.name(),
                r.power_w,
                p_w,
                r.gflops_per_w,
                p_eff,
                r.fpu_utilization * 100.0,
                p_util
            );
        }
    });
    common::report_timing("table3", t / 8.0);
}
